"""Kernel-backed execution layer: dispatch + prepared weight layouts.

This module makes the fused Pallas ICQ kernels the *default* compute
path for every model matmul, instead of a standalone benchmark toy.
``models/linear.py`` (and through it the whole model zoo and the
serving engine) routes any ``ICQPrepared`` weight through
``linear_apply`` below.

Prepared layout
---------------
``prepare()`` converts a storage-format ``ICQPacked`` (or serving-format
``ICQRuntime`` / runtime dict) into an ``ICQPrepared`` **once at model
load time**. The layout is the kernel runtime format, pre-padded and
pre-blocked so the per-call ``jnp.pad`` + reshape work in the kernel
wrappers disappears from the hot path:

  codes:     (*lead, pn, pk // k)  uint32 — k = 32 // n_bits packed
             codes; rows padded d_out -> pn = round_up(d_out, block_n),
             columns padded d_in -> pk = round_up(d_in, block_k) where
             block_k is a multiple of lcm(k, 32) so code words and
             bitmap words block on the same column tiles.
  bitmap:    (*lead, pn, pk // 32) uint32 — 1-bit outlier selector.
  codebooks: (*lead, pn, 2^(n+1))  f32    — [inlier ++ outlier] levels;
             padded rows are zero so they contribute nothing.
  static aux: n_bits, d_out, d_in (true shapes), block_m (cap for the M
             tile), block_n, block_k (exact divisors of pn / pk),
             backend ('pallas' | 'xla'), interpret (bool).

Zero padding is safe end-to-end: padded K columns meet zero-padded
activations in the matmul, padded N rows are sliced off the output, and
the pure-XLA arm slices to (d_out, d_in) before the dense matmul.

Leading axes (layer-scanned stacks, expert stacks) are kept on the array
children, so ``ICQPrepared`` nodes slice transparently under
``jax.lax.scan`` exactly like ``ICQPacked`` does.

Dispatch
--------
``linear_apply(x, prep)`` picks per call, keyed on M (= batched tokens),
shape, and platform (see kernels/platform.py):

  * backend 'xla' (default off-TPU): prepared-layout XLA reconstruction
    (unpack + take_along_axis; no gap-stream decode) then a dense
    matmul — bitwise-identical results to the reference ``dequantize``
    path, without its in-graph index-coding cumsum/scatter.
  * backend 'pallas', M <= ICQ_DECODE_M (decode): the fused
    ``icq_matmul`` kernel — packed weights go HBM->VMEM, dense bf16
    weights never touch HBM.
  * backend 'pallas', M > ICQ_DECODE_M (prefill): ``icq_dequant`` once,
    then a dense MXU matmul in the padded space.

Block sizes come from the autotune cache (kernels/autotune.py) when a
winner for this (shape, n_bits, backend) exists, else static defaults.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Tuple, Union

import jax
import jax.numpy as jnp

from repro.core import packing
from repro.core.icquant import ICQPacked, ICQRuntime, to_runtime_format
from repro.kernels import autotune
from repro.kernels.icq_dequant import _round_up, dequant_padded
from repro.kernels.icq_matmul import matmul_blocks, matmul_padded
from repro.kernels.platform import (
    decode_m_threshold,
    default_backend,
    default_interpret,
)

DEFAULT_BLOCKS = (128, 128, 512)  # (block_m cap, block_n, block_k)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class ICQPrepared:
    """Pre-padded, pre-blocked kernel runtime weight (see module doc)."""

    codes: jnp.ndarray        # (*lead, pn, pk // k) uint32
    bitmap: jnp.ndarray       # (*lead, pn, pk // 32) uint32
    codebooks: jnp.ndarray    # (*lead, pn, 2^(n+1)) f32
    n_bits: int = dataclasses.field(metadata=dict(static=True))
    d_out: int = dataclasses.field(metadata=dict(static=True))
    d_in: int = dataclasses.field(metadata=dict(static=True))
    block_m: int = dataclasses.field(metadata=dict(static=True))
    block_n: int = dataclasses.field(metadata=dict(static=True))
    block_k: int = dataclasses.field(metadata=dict(static=True))
    backend: str = dataclasses.field(metadata=dict(static=True))
    interpret: bool = dataclasses.field(metadata=dict(static=True))

    def tree_flatten(self):
        return ((self.codes, self.bitmap, self.codebooks),
                (self.n_bits, self.d_out, self.d_in, self.block_m,
                 self.block_n, self.block_k, self.backend, self.interpret))

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, *aux)

    def bits_per_weight(self) -> float:
        """HBM bits per logical weight actually resident (padding included)."""
        cb_bits = jnp.dtype(self.codebooks.dtype).itemsize * 8
        lead = int(math.prod(self.codes.shape[:-2]))
        total = (self.codes.size * 32 + self.bitmap.size * 32
                 + self.codebooks.size * cb_bits)
        return total / (lead * self.d_out * self.d_in)


def _pad_last2(x: jnp.ndarray, rows: int, cols: int) -> jnp.ndarray:
    pad = [(0, 0)] * (x.ndim - 2)
    pad += [(0, rows - x.shape[-2]), (0, cols - x.shape[-1])]
    return jnp.pad(x, pad)


def _as_runtime(w: Union[ICQPacked, ICQRuntime, Dict]) -> ICQRuntime:
    if isinstance(w, ICQPacked):
        return to_runtime_format(w)
    if isinstance(w, dict):
        return ICQRuntime(
            codes=w["codes"], bitmap=w["bitmap"], codebooks=w["codebooks"],
            n_bits=w["n_bits"], d_out=w["codes"].shape[-2], d_in=w["d_in"],
        )
    return w


def prepare(
    w: Union[ICQPacked, ICQRuntime, Dict],
    *,
    blocks: Optional[Tuple[int, int, int]] = None,
    backend: Optional[str] = None,
    interpret: Optional[bool] = None,
) -> ICQPrepared:
    """Expand + pad + block a quantized weight for the execution layer.

    ``blocks`` is (block_m_cap, block_n, block_k); when None the
    autotune cache is consulted (decode-shape key, M=1) and static
    defaults are used on a miss.
    """
    rt = _as_runtime(w)
    backend = default_backend() if backend is None else backend
    interpret = default_interpret() if interpret is None else interpret

    if blocks is None:
        hit = autotune.lookup(autotune.matmul_key(
            1, rt.d_out, rt.d_in, rt.n_bits, "pallas", interpret))
        blocks = tuple(hit) if hit is not None else DEFAULT_BLOCKS
    bm_cap, bn, bk = blocks
    # snap to hardware/packing granularity (M slot resolved per call)
    _, bn, bk = matmul_blocks(8, rt.d_out, rt.d_in, rt.n_bits,
                              bm_cap, bn, bk)

    k = 32 // rt.n_bits
    pn = _round_up(rt.d_out, bn)
    pk = _round_up(rt.d_in, bk)
    return ICQPrepared(
        codes=_pad_last2(rt.codes, pn, pk // k),
        bitmap=_pad_last2(rt.bitmap, pn, pk // 32),
        codebooks=_pad_last2(
            rt.codebooks.astype(jnp.float32), pn, rt.codebooks.shape[-1]),
        n_bits=rt.n_bits,
        d_out=rt.d_out,
        d_in=rt.d_in,
        block_m=bm_cap,
        block_n=bn,
        block_k=bk,
        backend=backend,
        interpret=interpret,
    )


def prepare_tree(params: Any, **kw) -> Any:
    """Convert every ICQPacked/ICQRuntime leaf of a param tree (load time)."""
    return jax.tree.map(
        lambda w: prepare(w, **kw)
        if isinstance(w, (ICQPacked, ICQRuntime)) else w,
        params,
        is_leaf=lambda w: isinstance(w, (ICQPacked, ICQRuntime)),
    )


def choose_path(M: int, prep: ICQPrepared) -> str:
    """'fused' | 'dequant' | 'xla' for a call with M batched tokens."""
    if prep.backend != "pallas" or prep.codes.ndim != 2:
        return "xla"
    return "fused" if M <= decode_m_threshold() else "dequant"


def _xla_weight(prep: ICQPrepared) -> jnp.ndarray:
    """Prepared tensors -> (*lead, d_out, d_in) f32, pure XLA (no kernels)."""
    codes = packing.unpack_codes(
        prep.codes[..., : prep.d_out, :], prep.n_bits, prep.d_in
    ).astype(jnp.int32)
    sel = packing.unpack_codes(
        prep.bitmap[..., : prep.d_out, :], 1, prep.d_in
    ).astype(jnp.int32)
    idx = sel * (1 << prep.n_bits) + codes
    return jnp.take_along_axis(
        prep.codebooks[..., : prep.d_out, :], idx, axis=-1)


def dequantize_prepared(prep: ICQPrepared) -> jnp.ndarray:
    """Materialize (*lead, d_out, d_in) f32. Pallas backend runs the
    dequant kernel (leading axes fold into grid rows — dequantization is
    row-independent, so stacks need one kernel call, not a vmap)."""
    if prep.backend != "pallas":
        return _xla_weight(prep)
    k = 32 // prep.n_bits
    lead = prep.codes.shape[:-2]
    pn = prep.codes.shape[-2]
    pk = prep.codes.shape[-1] * k
    rows = int(math.prod(lead)) * pn
    out = dequant_padded(
        prep.codes.reshape(rows, -1),
        prep.bitmap.reshape(rows, -1),
        prep.codebooks.reshape(rows, -1),
        n_bits=prep.n_bits, block_r=prep.block_n, block_c=prep.block_k,
        interpret=prep.interpret,
    )
    out = out.reshape(*lead, pn, pk)
    return out[..., : prep.d_out, : prep.d_in]


def linear_apply(x: jnp.ndarray, prep: ICQPrepared) -> jnp.ndarray:
    """y = x @ W_hat^T for x (..., d_in) -> (..., d_out), dispatching on M.

    Output dtype follows x (matching models/linear.py's dense contract).
    """
    M = int(math.prod(x.shape[:-1]))
    if M == 0:   # empty wave: keep the drop-in (0, d_out) contract
        return jnp.zeros(x.shape[:-1] + (prep.d_out,), x.dtype)
    path = choose_path(M, prep)

    if path == "xla":
        # exact-shape slice first: bitwise-identical to the reference
        # dequantize()-then-matmul path (token-parity guarantee).
        w = _xla_weight(prep)
        return x @ jnp.swapaxes(w, -1, -2).astype(x.dtype)

    pk = prep.codes.shape[-1] * (32 // prep.n_bits)
    x2 = x.reshape(M, prep.d_in).astype(jnp.float32)

    if path == "fused":
        bm = min(prep.block_m, _round_up(M, 8))
        pm = _round_up(M, bm)
        x_p = jnp.pad(x2, ((0, pm - M), (0, pk - prep.d_in)))
        y = matmul_padded(
            x_p, prep.codes, prep.bitmap, prep.codebooks,
            n_bits=prep.n_bits, block_m=bm, block_n=prep.block_n,
            block_k=prep.block_k, interpret=prep.interpret,
        )[:M, : prep.d_out]
    else:  # 'dequant': reconstruct once, ride the dense MXU matmul
        w = dequant_padded(
            prep.codes, prep.bitmap, prep.codebooks,
            n_bits=prep.n_bits, block_r=prep.block_n, block_c=prep.block_k,
            interpret=prep.interpret,
        )                                            # (pn, pk)
        x_p = jnp.pad(x2, ((0, 0), (0, pk - prep.d_in)))
        y = jax.lax.dot_general(
            x_p, w, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )[:, : prep.d_out]

    return y.reshape(*x.shape[:-1], prep.d_out).astype(x.dtype)


__all__ = [
    "ICQPrepared",
    "prepare",
    "prepare_tree",
    "choose_path",
    "dequantize_prepared",
    "linear_apply",
    "DEFAULT_BLOCKS",
]
