"""Kernel-backed execution layer: dispatch + prepared weight layouts.

This module makes the fused Pallas ICQ kernels the *default* compute
path for every model matmul, instead of a standalone benchmark toy.
``models/linear.py`` (and through it the whole model zoo and the
serving engine) routes any ``ICQPrepared`` weight through
``linear_apply`` below.

Prepared layout
---------------
``prepare()`` converts a storage-format ``ICQPacked`` (or serving-format
``ICQRuntime`` / runtime dict) into an ``ICQPrepared`` **once at model
load time**. The layout is the kernel runtime format, pre-padded and
pre-blocked so the per-call ``jnp.pad`` + reshape work in the kernel
wrappers disappears from the hot path.

Two runtime formats (``fmt``, default ``platform.default_runtime_fmt()``
= 'v2', env override ``ICQ_RUNTIME_FMT=v1|v2``):

  v1 — dense selector bitmap (the PR-1 layout, bitwise-parity fallback):
  codes:     (*lead, pn, pk // k)  uint32 — k = 32 // n_bits packed
             codes; rows padded d_out -> pn = round_up(d_out, block_n),
             columns padded d_in -> pk = round_up(d_in, block_k) where
             block_k is a multiple of lcm(k, 32) so code words and
             bitmap words block on the same column tiles.
  bitmap:    (*lead, pn, pk // 32) uint32 — 1-bit outlier selector
             (~ +1.0 bit/weight of HBM outlier overhead).

  v2 — checkpointed gap stream (the paper-faithful ~0.3 b/w stream,
  served directly; the kernels decode their selector tile in VMEM):
  syms:      (*lead, pn, SW) uint32 — packed b-bit gap symbols
             (value-1 encoding, all-ones = escape flag).
  offs:      (*lead, pn, T+1) uint16 — symbol-stream offset at every
             block_k boundary (T = pk / block_k; last column is the
             per-row symbol count sentinel).
  dbase:     (*lead, pn, T) uint8 (uint16 if b > 8) — checkpoint base
             delta: t*block_k - dbase[t] is the absolute position
             consumed before tile t's first symbol.
             Outlier overhead ~= stream (~0.31-0.38 with word/row
             padding) + 24/block_k checkpoint bits ~= 0.40-0.45 b/w.
             block_k IS the checkpoint tile: re-blocking requires
             re-preparing. v2 column granularity is k alone (no bitmap
             to 32-align), so n=3 keeps large tiles.

  Shared:
  codebooks: (*lead, pn, 2^(n+1)) f32 (or bf16 with
             ``codebook_dtype='bf16'``) — [inlier ++ outlier] levels;
             padded rows are zero so they contribute nothing.
  static aux: n_bits, d_out, d_in (true shapes), block_m (cap for the M
             tile), block_n, block_k (exact divisors of pn / pk),
             backend ('pallas' | 'xla'), interpret (bool), fmt
             ('v1' | 'v2'), b (gap-symbol width; 0 for v1).

Zero padding is safe end-to-end: padded K columns meet zero-padded
activations in the matmul, padded N rows are sliced off the output, and
the pure-XLA arm slices to (d_out, d_in) before the dense matmul.
Padded rows have offs = 0 (empty symbol runs), so v2 decodes them to an
all-zero selector.

Leading axes (layer-scanned stacks, expert stacks) are kept on the array
children, so ``ICQPrepared`` nodes slice transparently under
``jax.lax.scan`` exactly like ``ICQPacked`` does.

Dispatch
--------
``linear_apply(x, prep)`` picks per call, keyed on M (= batched tokens),
shape, and platform (see kernels/platform.py):

  * backend 'xla' (default off-TPU): prepared-layout XLA reconstruction,
    then a dense matmul — bitwise-identical results to the reference
    ``dequantize`` path. For v1 that is bitmap unpack + take_along_axis;
    for v2 the checkpointed stream is decoded in-graph (global cumsum +
    scatter — exact integer math, so v1/v2/reference agree bit-for-bit;
    unlike the kernel arms this re-decodes per call, the price of the
    fallback arm keeping v2's HBM footprint).
  * backend 'pallas', M <= ICQ_DECODE_M (decode): the fused
    ``icq_matmul`` kernel — packed weights go HBM->VMEM, dense bf16
    weights never touch HBM.
  * backend 'pallas', M > ICQ_DECODE_M (prefill): ``icq_dequant`` once,
    then a dense MXU matmul in the padded space.

Block sizes come from the autotune cache (kernels/autotune.py) when a
winner for this (shape, n_bits, backend, fmt) exists, else static
defaults; either way candidates are clamped so the kernel's VMEM
working set (one-hot codebook temporary + accumulator + selector-decode
temporaries) stays under ``ICQ_VMEM_BUDGET_MB`` (default 16) instead of
failing in the compiler. The prepare-time table is keyed on the decode
shape (M=1); at call time ``arm_blocks`` re-consults the cache for the
arm the call actually lands on — fused-matmul winners at the bucketed
prefill M (``autotune.PREFILL_MS``) and the M-free dequant winner — so
decode and prefill block independently when both have been tuned.
"""
from __future__ import annotations

import contextlib
import dataclasses
import math
import os
import zlib
from typing import Any, Dict, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import packing
from repro.core.icquant import ICQPacked, ICQRuntime, to_runtime_format
from repro.core.index_coding import (
    selector_from_stream_cols,
    stream_checkpoints,
)
from repro.kernels import autotune
from repro.kernels.icq_dequant import (
    SEL_CHUNK,
    _round_up,
    column_granularity,
    dequant_padded,
    dequant_padded_v2,
    onehot_itemsize,
    snap_block_k,
)
from repro.kernels.icq_matmul import (
    matmul_blocks,
    matmul_padded,
    matmul_padded_v2,
)
from repro.kernels.platform import (
    decode_m_threshold,
    default_accum_dtype,
    default_backend,
    default_interpret,
    default_onehot_dtype,
    default_runtime_fmt,
)

DEFAULT_BLOCKS = (128, 128, 512)  # (block_m cap, block_n, block_k)

_CODEBOOK_DTYPES = {None: jnp.float32, "f32": jnp.float32,
                    "bf16": jnp.bfloat16}


class WeightIntegrityError(ValueError):
    """A packed v2 sidecar failed its crc32 check: the gap stream was
    corrupted between encode and load. Raised loudly at load time —
    a corrupted outlier index stream must never reach the kernels,
    where it would decode to silently-wrong weights."""


def _crc32(x) -> int:
    return zlib.crc32(np.asarray(jax.device_get(x)).tobytes()) & 0xFFFFFFFF


def _sidecar_crcs(syms, offs, dbase) -> Tuple[Tuple[str, int], ...]:
    """crc32 of each present v2 sidecar, as stored (padding included)."""
    return tuple(
        (name, _crc32(t))
        for name, t in (("syms", syms), ("offs", offs), ("dbase", dbase))
        if t is not None
    )


def verify_runtime_integrity(rt: Dict) -> None:
    """Verify a v2 runtime dict (``ops.to_runtime(fmt='v2')``) against
    the crc32 checksums it recorded at encode time. No-op for v1 dicts
    or dicts without a ``crc`` entry; raises ``WeightIntegrityError``
    naming the corrupted tensor otherwise. ``prepare()`` calls this on
    every v2 dict it loads, so checkpointed/transmitted streams fail
    loudly at load instead of serving garbage tokens."""
    crc = rt.get("crc") if isinstance(rt, dict) else None
    if not crc or rt.get("fmt", "v1") != "v2":
        return
    for name, want in crc.items():
        t = rt.get(name)
        got = _crc32(t) if t is not None else 0
        if got != want:
            raise WeightIntegrityError(
                f"v2 runtime sidecar {name!r} failed its crc32 check "
                f"(stored 0x{want:08x}, recomputed 0x{got:08x}): the "
                f"packed stream was corrupted after to_runtime() — a "
                f"flipped bit here reassigns outlier indices across "
                f"quantization groups, so the load is refused")


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class ICQPrepared:
    """Pre-padded, pre-blocked kernel runtime weight (see module doc).

    v1 carries ``bitmap`` (``syms``/``offs``/``dbase`` are None);
    v2 carries the checkpointed stream (``bitmap`` is None).

    ``sel_memo`` is the pure-XLA arm's decoded-selector memo: a packed
    1-bit bitmap (v1 layout, unpadded) materialized once at prepare time
    when the weight will execute on the XLA arm, so per-call graphs
    unpack it with a shift/mask instead of re-decoding the v2 gap stream
    in-graph (cumsum + scatter per launch — the chunked-prefill TTFT
    regression PR 4 measured). The memo is bit-derived from the exact
    decode it replaces, so XLA-arm outputs are unchanged bitwise. It is
    *excluded* from the bits/weight accounting: it exists only on the
    off-TPU fallback arm (where HBM residency is not the constraint the
    runtime-format numbers are about) and never ships to the Pallas
    kernels. ``ICQ_XLA_SEL_MEMO=0`` disables it.
    """

    codes: jnp.ndarray        # (*lead, pn, pk // k) uint32
    bitmap: Optional[jnp.ndarray]     # v1: (*lead, pn, pk // 32) uint32
    codebooks: jnp.ndarray    # (*lead, pn, 2^(n+1)) f32/bf16
    syms: Optional[jnp.ndarray]       # v2: (*lead, pn, SW) uint32
    offs: Optional[jnp.ndarray]       # v2: (*lead, pn, T+1) uint16
    dbase: Optional[jnp.ndarray]      # v2: (*lead, pn, T) uint8/uint16
    n_bits: int = dataclasses.field(metadata=dict(static=True))
    d_out: int = dataclasses.field(metadata=dict(static=True))
    d_in: int = dataclasses.field(metadata=dict(static=True))
    block_m: int = dataclasses.field(metadata=dict(static=True))
    block_n: int = dataclasses.field(metadata=dict(static=True))
    block_k: int = dataclasses.field(metadata=dict(static=True))
    backend: str = dataclasses.field(metadata=dict(static=True))
    interpret: bool = dataclasses.field(metadata=dict(static=True))
    fmt: str = dataclasses.field(default="v1", metadata=dict(static=True))
    b: int = dataclasses.field(default=0, metadata=dict(static=True))
    # v2 integrity sidecar: (('syms', crc32), ('offs', crc32), ...) over
    # the padded stored bytes — None for v1 (see verify_integrity)
    crc: Optional[Tuple[Tuple[str, int], ...]] = dataclasses.field(
        default=None, metadata=dict(static=True))
    sel_memo: Optional[jnp.ndarray] = None  # (*lead, d_out, ceil(d_in/32))

    def tree_flatten(self):
        return ((self.codes, self.bitmap, self.codebooks,
                 self.syms, self.offs, self.dbase, self.sel_memo),
                (self.n_bits, self.d_out, self.d_in, self.block_m,
                 self.block_n, self.block_k, self.backend, self.interpret,
                 self.fmt, self.b, self.crc))

    @classmethod
    def tree_unflatten(cls, aux, children):
        *tensors, sel_memo = children
        return cls(*tensors, *aux, sel_memo=sel_memo)

    def verify_integrity(self) -> None:
        """Recompute the v2 sidecar checksums and compare to the crc
        recorded at prepare time, raising ``WeightIntegrityError`` on the
        first mismatch.

        The failure mode this guards is specific to index-coded
        formats: a flipped bit in the packed gap stream (or its
        offset/base checkpoints) silently *reassigns an outlier index
        across quantization groups* — every weight after the corrupted
        symbol decodes against the wrong codebook half, and generation
        degrades to plausible-looking garbage instead of crashing.
        Verification costs one host pass over the sidecars; call it at
        load/restore boundaries, never per step. No-op when ``crc`` is
        None (v1, or a layout prepared before checksums existed)."""
        if self.crc is None:
            return
        have = dict(_sidecar_crcs(self.syms, self.offs, self.dbase))
        for name, want in self.crc:
            got = have.get(name, 0)
            if got != want:
                raise WeightIntegrityError(
                    f"ICQPrepared v2 sidecar {name!r} failed its crc32 "
                    f"check (stored 0x{want:08x}, recomputed "
                    f"0x{got:08x} over {self.d_out}x{self.d_in}): the "
                    f"packed gap stream was corrupted after prepare() — "
                    f"refusing to serve weights whose outlier indices "
                    f"would silently shift across groups")

    def _tensors(self):
        # sel_memo deliberately absent: XLA-fallback compute cache, not
        # part of the runtime format (see class doc)
        return [t for t in (self.codes, self.bitmap, self.codebooks,
                            self.syms, self.offs, self.dbase)
                if t is not None]

    def bits_per_weight(self) -> float:
        """HBM bits per logical weight actually resident (padding included).

        Widths derive from each array's itemsize, so uint16/uint8
        checkpoint sidecars and bf16 codebooks are charged at their true
        stored width."""
        lead = int(math.prod(self.codes.shape[:-2]))
        total = sum(t.size * jnp.dtype(t.dtype).itemsize * 8
                    for t in self._tensors())
        return total / (lead * self.d_out * self.d_in)

    def outlier_bits_per_weight(self) -> float:
        """HBM bits/weight spent on outlier *selection* only (v1 bitmap,
        or v2 stream + checkpoints) — the quantity the paper's ~0.3 b/w
        index coding result is about."""
        lead = int(math.prod(self.codes.shape[:-2]))
        sel = [t for t in (self.bitmap, self.syms, self.offs, self.dbase)
               if t is not None]
        total = sum(t.size * jnp.dtype(t.dtype).itemsize * 8 for t in sel)
        return total / (lead * self.d_out * self.d_in)


def _pad_last2(x: jnp.ndarray, rows: int, cols: int) -> jnp.ndarray:
    pad = [(0, 0)] * (x.ndim - 2)
    pad += [(0, rows - x.shape[-2]), (0, cols - x.shape[-1])]
    return jnp.pad(x, pad)


def _as_runtime(w: Union[ICQPacked, ICQRuntime, Dict]) -> ICQRuntime:
    if isinstance(w, ICQPacked):
        return to_runtime_format(w)
    if isinstance(w, dict):
        return ICQRuntime(
            codes=w["codes"], bitmap=w["bitmap"], codebooks=w["codebooks"],
            n_bits=w["n_bits"], d_out=w["codes"].shape[-2], d_in=w["d_in"],
        )
    return w


# ---------------------------------------------------------------------------
# VMEM budgeting
# ---------------------------------------------------------------------------

def vmem_budget_bytes() -> int:
    """Per-kernel VMEM working-set budget (ICQ_VMEM_BUDGET_MB, default 16)."""
    env = os.environ.get("ICQ_VMEM_BUDGET_MB")
    mb = float(env) if env else 16.0
    return int(mb * 2**20)


def vmem_bytes_estimate(block_m: int, block_n: int, block_k: int, *,
                        n_bits: int, C: int, fmt: str = "v1",
                        s_cols: int = 0,
                        onehot: Optional[str] = None,
                        accum: Optional[str] = None) -> int:
    """Rough VMEM bytes for one fused-matmul block (dequant is a subset).

    Dominated by the (BN, BK, C) one-hot codebook-select temporary —
    charged at the ``ICQ_ONEHOT_DTYPE`` width (``onehot`` overrides), so
    a bf16 one-hot halves the dominant term and lets the autotuner admit
    larger prefill blocks under the same budget; v2 adds the unpacked
    symbol stream and the (BN, SEL_CHUNK, BK) selector compare chunk.
    Deliberately coarse — used to reject/clamp block candidates before
    the compiler OOMs, not to bill exact bytes."""
    f32 = 4
    if accum is None:
        accum = default_accum_dtype()
    est = block_m * block_k * f32                      # x tile
    est += block_m * block_n * f32                     # out tile
    est += block_m * block_n * (2 if accum == "bf16" else 4)  # acc scratch
    est += block_n * block_k * f32                     # dequantized W tile
    est += block_n * block_k * C * onehot_itemsize(onehot)  # one-hot temp
    est += block_n * (block_k // (32 // n_bits)) * 4   # packed codes
    if fmt == "v2":
        est += 3 * block_n * s_cols * 4                # syms + pos/rel temps
        est += block_n * min(SEL_CHUNK, max(s_cols, 1)) * block_k * f32
    else:
        est += block_n * (block_k // 32) * 4           # bitmap words
    return est


def _clamp_blocks_to_budget(bm: int, bn: int, bk: int, *, n_bits: int,
                            C: int, fmt: str, d_in: int, s_cols: int,
                            allow_bk: bool = True):
    """Shrink (bn, bk, bm) until the VMEM estimate fits the budget."""
    budget = vmem_budget_bytes()
    lcm = column_granularity(n_bits, fmt)
    while vmem_bytes_estimate(bm, bn, bk, n_bits=n_bits, C=C, fmt=fmt,
                              s_cols=s_cols) > budget:
        if allow_bk and bk > lcm:
            nbk = snap_block_k(d_in, lcm, max(lcm, bk // 2))
            if nbk < bk:
                bk = nbk
                continue
        if bn > 8:
            bn //= 2
            continue
        if bm > 8:
            bm //= 2
            continue
        break  # minimal blocks; let the compiler have the final word
    return bm, bn, bk


# ---------------------------------------------------------------------------
# prepare
# ---------------------------------------------------------------------------

def _encode_v2_sidecar(symbols, counts, b: int, d_out: int, tile: int,
                       total_len: int):
    """Pack the gap stream + build checkpoints, host-side (load time).

    symbols/counts may carry leading stack axes; returns jnp arrays
    (syms uint32, offs uint16, dbase uint8/16) with those axes restored.
    """
    sym_np = np.asarray(jax.device_get(symbols))
    cnt_np = np.asarray(jax.device_get(counts))
    lead = sym_np.shape[:-2] if sym_np.ndim > 2 else ()
    rows = int(np.prod(lead, dtype=np.int64)) * d_out if lead else d_out
    sym2 = sym_np.reshape(rows, sym_np.shape[-1])
    cnt2 = cnt_np.reshape(rows)
    words = packing.pack_symbols_np(sym2, b)
    offs, dbase = stream_checkpoints(sym2, cnt2, b, tile, total_len)
    return (
        jnp.asarray(words.reshape(*lead, d_out, words.shape[-1])),
        jnp.asarray(offs.reshape(*lead, d_out, offs.shape[-1])),
        jnp.asarray(dbase.reshape(*lead, d_out, dbase.shape[-1])),
    )


def prepare(
    w: Union[ICQPacked, ICQRuntime, Dict],
    *,
    blocks: Optional[Tuple[int, int, int]] = None,
    backend: Optional[str] = None,
    interpret: Optional[bool] = None,
    fmt: Optional[str] = None,
    codebook_dtype: Optional[str] = None,
) -> ICQPrepared:
    """Expand + pad + block a quantized weight for the execution layer.

    ``blocks`` is (block_m_cap, block_n, block_k); when None the
    autotune cache is consulted (decode-shape key, M=1) and static
    defaults are used on a miss. Either way blocks are clamped to the
    VMEM budget.

    ``fmt`` is 'v1' | 'v2' | None (None = platform default, normally
    'v2'). v2 needs the gap stream, so it requires an ``ICQPacked`` (or
    a v2 runtime dict from ``ops.to_runtime(fmt='v2')``); bitmap-only
    sources (``ICQRuntime``, v1 dicts) silently fall back to v1 — they
    already paid the dense-bitmap expansion.

    ``codebook_dtype`` is 'f32' (default) or 'bf16' — bf16 halves the
    codebook HBM charge at ~3 decimal digits of level precision.
    """
    backend = default_backend() if backend is None else backend
    interpret = default_interpret() if interpret is None else interpret
    want = default_runtime_fmt() if fmt is None else fmt
    if want not in ("v1", "v2"):
        raise ValueError(f"fmt must be 'v1' or 'v2', got {want!r}")
    if codebook_dtype not in _CODEBOOK_DTYPES:
        raise ValueError(
            f"codebook_dtype must be 'f32' or 'bf16', got {codebook_dtype!r}")
    cb_dtype = _CODEBOOK_DTYPES[codebook_dtype]

    is_v2_dict = isinstance(w, dict) and w.get("fmt", "v1") == "v2"
    if is_v2_dict:
        # load boundary: a checkpointed/transmitted stream is verified
        # against its encode-time checksums before any decoding happens
        verify_runtime_integrity(w)
    if is_v2_dict and want == "v1":
        raise ValueError("cannot prepare a v2 runtime dict as fmt='v1' — "
                         "the dense bitmap was never materialized")
    has_stream = isinstance(w, ICQPacked) or is_v2_dict
    fmt = "v2" if (want == "v2" and has_stream) else "v1"

    # -- source tensors ------------------------------------------------
    bitmap = syms = offs = dbase = None
    b = 0
    if is_v2_dict:
        codes, codebooks = w["codes"], w["codebooks"]
        n_bits, d_in, b = w["n_bits"], w["d_in"], w["b"]
        d_out = codes.shape[-2]
        syms, offs, dbase = w["syms"], w["offs"], w["dbase"]
        tile_src = w["tile"]
    elif fmt == "v2":  # ICQPacked, stream kept — never build the bitmap
        n_bits, d_out, d_in, b = w.n_bits, w.d_out, w.d_in, w.b
        codes = w.codes
        codebooks = w.codebooks.reshape(*w.codes.shape[:-2], d_out, -1)
    else:
        rt = _as_runtime(w)
        codes, bitmap, codebooks = rt.codes, rt.bitmap, rt.codebooks
        n_bits, d_out, d_in = rt.n_bits, rt.d_out, rt.d_in

    # -- block selection ----------------------------------------------
    if blocks is None:
        hit = autotune.lookup(autotune.matmul_key(
            1, d_out, d_in, n_bits, "pallas", interpret, fmt=fmt))
        blocks = tuple(hit) if hit is not None else DEFAULT_BLOCKS
    bm_cap, bn, bk = blocks
    # snap to hardware/packing granularity (M slot resolved per call)
    _, bn, bk = matmul_blocks(8, d_out, d_in, n_bits, bm_cap, bn, bk,
                              fmt=fmt)
    if is_v2_dict:
        bk = tile_src  # checkpoints were built for this tile
    C = codebooks.shape[-1]
    s_cols = 0
    if fmt == "v2":
        words = syms.shape[-1] if is_v2_dict else \
            max(packing.packed_width(w.symbols.shape[-1], b), 1)
        s_cols = packing.symbol_cols(words, b)
    bm_cap, bn, bk = _clamp_blocks_to_budget(
        bm_cap, bn, bk, n_bits=n_bits, C=C, fmt=fmt, d_in=d_in,
        s_cols=s_cols, allow_bk=not is_v2_dict)

    k = 32 // n_bits
    pn = _round_up(d_out, bn)
    pk = _round_up(d_in, bk)

    # -- v2 sidecar -----------------------------------------------------
    if fmt == "v2" and not is_v2_dict:
        syms, offs, dbase = _encode_v2_sidecar(
            w.symbols, w.counts, b, d_out, tile=bk, total_len=pk)

    def pad_rows(x):
        return None if x is None else _pad_last2(x, pn, x.shape[-1])

    prep = ICQPrepared(
        codes=_pad_last2(codes, pn, pk // k),
        bitmap=None if fmt == "v2" else _pad_last2(bitmap, pn, pk // 32),
        codebooks=_pad_last2(codebooks.astype(cb_dtype), pn, C),
        syms=pad_rows(syms),
        offs=pad_rows(offs),
        dbase=pad_rows(dbase),
        n_bits=n_bits,
        d_out=d_out,
        d_in=d_in,
        block_m=bm_cap,
        block_n=bn,
        block_k=bk,
        backend=backend,
        interpret=interpret,
        fmt=fmt,
        b=b,
    )
    if fmt == "v2":
        # record crc32 of the padded sidecars as stored: cheap (one host
        # pass at load time), and verify_integrity() can then catch any
        # later corruption of the packed stream before it reaches a
        # kernel. v1's dense bitmap degrades gracefully under bit flips
        # (one weight wrong); the v2 stream does not (every weight after
        # the flip decodes against the wrong group) — hence v2-only.
        prep = dataclasses.replace(
            prep, crc=_sidecar_crcs(prep.syms, prep.offs, prep.dbase))
    if fmt == "v2" and backend != "pallas" and xla_sel_memo_enabled():
        # memoize the decoded selector for the pure-XLA arm: the stream
        # decode below is exactly the per-call computation the memo
        # replaces, so the selector (and every downstream weight gather)
        # is bit-identical with or without it — it runs once here, at
        # load time, instead of inside every jitted launch. Keyed on the
        # *backend*, not on choose_path's per-call arm: a stacked
        # pallas-backend weight does fall to the XLA arm if applied
        # outside its layer scan, but building the memo for that case
        # would charge ~1 b/w of real TPU HBM to speed up a path the
        # scan-sliced serving hot loop never takes.
        sel = _xla_selector(prep).astype(jnp.uint32)
        prep = dataclasses.replace(prep, sel_memo=packing.pack_codes(sel, 1))
    return prep


def xla_sel_memo_enabled() -> bool:
    """ICQ_XLA_SEL_MEMO (default on): memoize the decoded v2 selector as a
    packed bitmap for weights prepared onto the pure-XLA arm."""
    return os.environ.get("ICQ_XLA_SEL_MEMO", "1") not in ("0", "false", "")


def prepare_tree(params: Any, **kw) -> Any:
    """Convert every ICQPacked/ICQRuntime leaf of a param tree (load time)."""
    return jax.tree.map(
        lambda w: prepare(w, **kw)
        if isinstance(w, (ICQPacked, ICQRuntime)) else w,
        params,
        is_leaf=lambda w: isinstance(w, (ICQPacked, ICQRuntime)),
    )


_FORCED_BACKEND: Optional[str] = None


@contextlib.contextmanager
def forced_backend(name: Optional[str]):
    """Per-call dispatch override: every ``choose_path`` decision made
    while the context is active lands on ``name``'s arm, regardless of
    the prepared backend or M.

    Only ``'xla'`` (and None = no-op) is accepted: the pure-XLA arm is
    the bitwise-exact fallback every prepared layout can execute, which
    is what makes it the *degraded mode* of the serving fault-recovery
    path — a step retried under ``forced_backend('xla')`` recomputes
    the same tokens the Pallas arms would have produced (exactly on
    CPU/same-arm configs; greedy-token-identical on TPU). The override
    is consulted at **trace time**: wrap the jitted call so the first
    trace bakes the XLA arm in (wrapping subsequent calls is free).
    """
    if name not in (None, "xla"):
        raise ValueError(
            f"forced_backend supports only 'xla' (the universal fallback "
            f"arm) or None, got {name!r}")
    global _FORCED_BACKEND
    prev = _FORCED_BACKEND
    _FORCED_BACKEND = name
    try:
        yield
    finally:
        _FORCED_BACKEND = prev


def choose_path(M: int, prep: ICQPrepared) -> str:
    """'fused' | 'dequant' | 'xla' for a call with M batched tokens."""
    if _FORCED_BACKEND == "xla":
        return "xla"
    if prep.backend != "pallas" or prep.codes.ndim != 2:
        return "xla"
    return "fused" if M <= decode_m_threshold() else "dequant"


def bucket_m(M: int) -> int:
    """Autotune M bucket for a call with M batched tokens: the largest
    tuned bucket (1, *PREFILL_MS) not exceeding M — small decode batches
    reuse the M=1 decode table, prefill-sized calls graduate to the
    prefill entries as M grows past each bucket."""
    best = 1
    for b in autotune.PREFILL_MS:
        if M >= b:
            best = b
    return best


def arm_blocks(M: int, prep: ICQPrepared) -> Tuple[int, int, int]:
    """Per-call (block_m, block_n, block_k) for the dispatch arm M lands on.

    ``prepare()`` bakes decode-keyed (M=1) blocks into the layout; this
    consults the autotune cache again at call time so prefill-M sweeps
    (``autotune.PREFILL_MS`` entries for the fused arm, the M-free
    ``dequant_key`` winner for the dequant arm) can re-block each arm
    independently. A winner is only adopted when it tiles the prepared
    padding exactly (pn % bn == pk % bk == 0; v2 additionally pins
    block_k to the prepared checkpoint tile — re-tiling K would need a
    re-prepare); otherwise the prepare-time blocks stand.
    """
    base = (prep.block_m, prep.block_n, prep.block_k)
    pn = prep.codes.shape[-2]
    pk = prep.codes.shape[-1] * (32 // prep.n_bits)
    path = choose_path(M, prep)
    if path == "fused":
        hit = autotune.lookup(autotune.matmul_key(
            bucket_m(M), prep.d_out, prep.d_in, prep.n_bits, "pallas",
            prep.interpret, fmt=prep.fmt))
        if hit is None:
            return base
        bm, bn, bk = hit
        if prep.fmt == "v2":
            bk = prep.block_k
        if bm < 1 or bn < 1 or bk < 1 or pn % bn or pk % bk:
            return base
        return bm, bn, bk
    if path == "dequant":
        hit = autotune.lookup(autotune.dequant_key(
            prep.d_out, prep.d_in, prep.n_bits, "pallas", prep.interpret,
            fmt=prep.fmt))
        if hit is None:
            return base
        br, bc = hit
        if prep.fmt == "v2":
            bc = prep.block_k
        if br < 1 or bc < 1 or pn % br or pk % bc:
            return base
        return prep.block_m, br, bc
    return base


# ---------------------------------------------------------------------------
# execution arms
# ---------------------------------------------------------------------------

def _xla_selector(prep: ICQPrepared) -> jnp.ndarray:
    """(*lead, d_out, d_in) int32 selector via the prepared tensors."""
    if prep.sel_memo is not None:
        # prepare-time memo of the v2 stream decode below (bit-identical
        # by construction): per-call cost drops to one shift/mask unpack.
        return packing.unpack_codes(
            prep.sel_memo, 1, prep.d_in).astype(jnp.int32)
    if prep.fmt == "v1":
        return packing.unpack_codes(
            prep.bitmap[..., : prep.d_out, :], 1, prep.d_in
        ).astype(jnp.int32)
    S = packing.symbol_cols(prep.syms.shape[-1], prep.b)
    sym = packing.unpack_codes(prep.syms[..., : prep.d_out, :], prep.b, S)
    lead = sym.shape[:-2]
    rows = int(math.prod(lead)) * prep.d_out
    # counts live in the checkpoint sentinel column; the global-cumsum
    # decode is bit-identical to the kernels' per-tile checkpoint decode
    # (same positions) at a fraction of the work.
    counts = prep.offs[..., : prep.d_out, -1].reshape(rows)
    sel = selector_from_stream_cols(
        sym.reshape(rows, S).astype(jnp.int32), counts,
        b=prep.b, out_len=prep.d_in,
    )
    return sel.reshape(*lead, prep.d_out, prep.d_in)


def _xla_weight(prep: ICQPrepared) -> jnp.ndarray:
    """Prepared tensors -> (*lead, d_out, d_in) weights, pure XLA.

    v1 unpacks the bitmap; v2 decodes the gap stream in-graph with the
    same exact integer math as the kernels' checkpoint decode, so the
    selector — and therefore the gathered weight — is bit-identical
    across formats and to the reference ``dequantize`` path. Output
    dtype follows the stored codebooks (f32, or bf16 codebook cache).
    """
    codes = packing.unpack_codes(
        prep.codes[..., : prep.d_out, :], prep.n_bits, prep.d_in
    ).astype(jnp.int32)
    idx = _xla_selector(prep) * (1 << prep.n_bits) + codes
    return jnp.take_along_axis(
        prep.codebooks[..., : prep.d_out, :], idx, axis=-1)


def _rows2(x: jnp.ndarray) -> jnp.ndarray:
    """Fold leading stack axes of a prepared child into rows."""
    return x.reshape(-1, x.shape[-1])


def dequantize_prepared(prep: ICQPrepared) -> jnp.ndarray:
    """Materialize (*lead, d_out, d_in) weights. Pallas backend runs the
    dequant kernel (leading axes fold into grid rows — dequantization is
    row-independent, so stacks need one kernel call, not a vmap)."""
    if prep.backend != "pallas":
        return _xla_weight(prep)
    k = 32 // prep.n_bits
    lead = prep.codes.shape[:-2]
    pn = prep.codes.shape[-2]
    pk = prep.codes.shape[-1] * k
    onehot = default_onehot_dtype()
    if prep.fmt == "v2":
        out = dequant_padded_v2(
            _rows2(prep.codes),
            _rows2(prep.syms),
            _rows2(prep.offs),
            _rows2(prep.dbase),
            _rows2(prep.codebooks),
            n_bits=prep.n_bits, b=prep.b, block_r=prep.block_n,
            interpret=prep.interpret, onehot=onehot,
        )
    else:
        out = dequant_padded(
            _rows2(prep.codes),
            _rows2(prep.bitmap),
            _rows2(prep.codebooks),
            n_bits=prep.n_bits, block_r=prep.block_n, block_c=prep.block_k,
            interpret=prep.interpret, onehot=onehot,
        )
    out = out.reshape(*lead, pn, pk)
    return out[..., : prep.d_out, : prep.d_in]


def linear_apply(x: jnp.ndarray, prep: ICQPrepared) -> jnp.ndarray:
    """y = x @ W_hat^T for x (..., d_in) -> (..., d_out), dispatching on M.

    Output dtype follows x (matching models/linear.py's dense contract).
    """
    M = int(math.prod(x.shape[:-1]))
    if M == 0:   # empty wave: keep the drop-in (0, d_out) contract
        return jnp.zeros(x.shape[:-1] + (prep.d_out,), x.dtype)
    path = choose_path(M, prep)

    if path == "xla":
        # exact-shape slice first: bitwise-identical to the reference
        # dequantize()-then-matmul path (token-parity guarantee).
        w = _xla_weight(prep)
        return x @ jnp.swapaxes(w, -1, -2).astype(x.dtype)

    pk = prep.codes.shape[-1] * (32 // prep.n_bits)
    x2 = x.reshape(M, prep.d_in).astype(jnp.float32)
    abm, abn, abk = arm_blocks(M, prep)   # per-arm autotuned block table
    onehot = default_onehot_dtype()

    if path == "fused":
        accum = default_accum_dtype()
        bm = min(abm, _round_up(M, 8))
        pm = _round_up(M, bm)
        x_p = jnp.pad(x2, ((0, pm - M), (0, pk - prep.d_in)))
        if prep.fmt == "v2":
            y = matmul_padded_v2(
                x_p, prep.codes, prep.syms, prep.offs, prep.dbase,
                prep.codebooks,
                n_bits=prep.n_bits, b=prep.b, block_m=bm,
                block_n=abn, interpret=prep.interpret, onehot=onehot,
                accum=accum,
            )[:M, : prep.d_out]
        else:
            y = matmul_padded(
                x_p, prep.codes, prep.bitmap, prep.codebooks,
                n_bits=prep.n_bits, block_m=bm, block_n=abn,
                block_k=abk, interpret=prep.interpret, onehot=onehot,
                accum=accum,
            )[:M, : prep.d_out]
    else:  # 'dequant': reconstruct once, ride the dense MXU matmul
        if prep.fmt == "v2":
            w = dequant_padded_v2(
                prep.codes, prep.syms, prep.offs, prep.dbase,
                prep.codebooks,
                n_bits=prep.n_bits, b=prep.b, block_r=abn,
                interpret=prep.interpret, onehot=onehot,
            )                                        # (pn, pk)
        else:
            w = dequant_padded(
                prep.codes, prep.bitmap, prep.codebooks,
                n_bits=prep.n_bits, block_r=abn,
                block_c=abk, interpret=prep.interpret, onehot=onehot,
            )                                        # (pn, pk)
        x_p = jnp.pad(x2, ((0, 0), (0, pk - prep.d_in)))
        y = jax.lax.dot_general(
            x_p, w, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )[:, : prep.d_out]

    return y.reshape(*x.shape[:-1], prep.d_out).astype(x.dtype)


__all__ = [
    "ICQPrepared",
    "WeightIntegrityError",
    "prepare",
    "prepare_tree",
    "arm_blocks",
    "bucket_m",
    "choose_path",
    "dequantize_prepared",
    "forced_backend",
    "linear_apply",
    "verify_runtime_integrity",
    "vmem_bytes_estimate",
    "vmem_budget_bytes",
    "DEFAULT_BLOCKS",
]
