"""Pallas TPU kernel: weighted K-means assignment accumulation.

The calibration hot loop of ICQuant^SK: every Lloyd iteration assigns
each weight to its nearest centroid and accumulates per-cluster weighted
sums. Blocked over (row tiles, column tiles); the per-cluster reduction
is an argmin + one-hot matmul against the value/weight tiles — MXU work,
no scatters. Accumulation across column tiles uses the output-revisiting
grid schedule (column axis innermost).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _assign_kernel(w_ref, wt_ref, c_ref, wsum_ref, vsum_ref, *, n_l: int):
    @pl.when(pl.program_id(1) == 0)
    def _zero():
        wsum_ref[...] = jnp.zeros_like(wsum_ref)
        vsum_ref[...] = jnp.zeros_like(vsum_ref)

    w = w_ref[...]                        # (BR, BL)
    wt = wt_ref[...]
    c = c_ref[...]                        # (BR, C)
    d = jnp.abs(w[:, :, None] - c[:, None, :])          # (BR, BL, C)
    dmin = d.min(axis=-1, keepdims=True)
    onehot = (d == dmin).astype(jnp.float32)
    # ties: keep only the first minimal index
    first = jnp.cumsum(onehot, axis=-1)
    onehot = jnp.where(first == 1.0, onehot, 0.0)
    wsum_ref[...] += (onehot * wt[:, :, None]).sum(axis=1)
    vsum_ref[...] += (onehot * (wt * w)[:, :, None]).sum(axis=1)


@functools.partial(
    jax.jit, static_argnames=("block_r", "block_l", "interpret")
)
def kmeans_assign(
    w: jnp.ndarray,          # (R, L)
    weight: jnp.ndarray,     # (R, L)
    centroids: jnp.ndarray,  # (R, C)
    *,
    block_r: int = 64,
    block_l: int = 1024,
    interpret=None,          # None = platform default (compiled on TPU)
):
    if interpret is None:
        from repro.kernels.platform import default_interpret

        interpret = default_interpret()
    R, L = w.shape
    C = centroids.shape[-1]
    br = min(block_r, R)
    bl = min(block_l, L)
    pr = -(-R // br) * br
    plc = -(-L // bl) * bl
    # zero-pad: padded points carry zero weight, so they contribute nothing
    w_p = jnp.pad(w.astype(jnp.float32), ((0, pr - R), (0, plc - L)))
    wt_p = jnp.pad(weight.astype(jnp.float32), ((0, pr - R), (0, plc - L)))
    c_p = jnp.pad(centroids.astype(jnp.float32), ((0, pr - R), (0, 0)))

    grid = (pr // br, plc // bl)
    wsum, vsum = pl.pallas_call(
        functools.partial(_assign_kernel, n_l=grid[1]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((br, bl), lambda i, j: (i, j)),
            pl.BlockSpec((br, bl), lambda i, j: (i, j)),
            pl.BlockSpec((br, C), lambda i, j: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((br, C), lambda i, j: (i, 0)),
            pl.BlockSpec((br, C), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((pr, C), jnp.float32),
            jax.ShapeDtypeStruct((pr, C), jnp.float32),
        ],
        interpret=interpret,
    )(w_p, wt_p, c_p)
    return wsum[:R], vsum[:R]
