"""Platform detection + execution-mode defaults for the ICQ kernel layer.

Central policy knob for every Pallas entry point in this package:

  * ``detected_platform()``  — jax default backend ('tpu' | 'cpu' | 'gpu'),
    overridable with ``ICQ_PLATFORM`` (useful for forcing the TPU code
    path through eval_shape-style lowering tests on CPU).
  * ``default_interpret()``  — Pallas kernels compile natively on TPU and
    fall back to ``interpret=True`` everywhere else. ``ICQ_INTERPRET=0/1``
    forces either mode.
  * ``default_backend()``    — which dispatch arm family the execution
    layer prefers when the caller does not say: the Pallas kernels on
    TPU, the pure-XLA prepared path elsewhere (interpret-mode Pallas is
    a correctness tool, not a serving path). ``ICQ_BACKEND=pallas|xla``
    overrides.
  * ``decode_m_threshold()`` — largest M routed to the fused
    dequant+matmul kernel; bigger batches dequantize once per call and
    ride the dense MXU matmul. ``ICQ_DECODE_M`` overrides.
  * ``default_runtime_fmt()`` — prepared-weight runtime format:
    'v2' (checkpointed gap stream, ~0.3-0.45 b/w outlier overhead) by
    default, 'v1' (dense 1-bit selector bitmap, ~1 b/w) as the
    bitwise-parity fallback. ``ICQ_RUNTIME_FMT=v1|v2`` overrides.
  * ``default_onehot_dtype()`` — dtype of the (BR, BC, C) one-hot
    codebook-select temporary inside both Pallas kernels: 'f32'
    (default, exact) or 'bf16' (halves the dominant VMEM term, so the
    autotuner can admit larger prefill blocks under ICQ_VMEM_BUDGET_MB;
    codebook levels round to bf16 — ~3 decimal digits).
    ``ICQ_ONEHOT_DTYPE=f32|bf16`` overrides.
  * ``default_accum_dtype()`` — dtype of the fused matmul kernels'
    VMEM accumulator scratch: 'f32' (default, exact) or 'bf16' (halves
    the accumulator VMEM term; partial sums round to bf16 per K-step).
    ``ICQ_ACCUM_DTYPE=f32|bf16`` overrides.
  * ``default_paged_attn()`` — which arm serves paged-KV decode
    attention: the Pallas paged-attention kernel ('pallas', default on
    TPU — streams only live KV blocks through VMEM) or the XLA
    gather-the-logical-view path ('xla', default elsewhere; also the
    bitwise-exact fault-tolerance degrade target).
    ``ICQ_PAGED_ATTN=pallas|xla`` overrides.
"""
from __future__ import annotations

import os

import jax

_TRUTHY = ("1", "true", "yes", "on")
_FALSY = ("0", "false", "no", "off")


def detected_platform() -> str:
    override = os.environ.get("ICQ_PLATFORM")
    if override:
        return override.lower()
    try:
        return jax.default_backend()
    except Exception:  # backend init failure: assume portable host
        return "cpu"


def default_interpret() -> bool:
    """Interpret only off-TPU (satellite: no caller passes this anymore)."""
    env = os.environ.get("ICQ_INTERPRET")
    if env:  # set-but-empty means unset (CI YAML / shell expansion)
        if env.lower() in _TRUTHY:
            return True
        if env.lower() in _FALSY:
            return False
        raise ValueError(
            f"ICQ_INTERPRET must be one of {_TRUTHY + _FALSY}, got {env!r}")
    return detected_platform() != "tpu"


def default_backend() -> str:
    """'pallas' on TPU, 'xla' elsewhere; ICQ_BACKEND overrides."""
    env = os.environ.get("ICQ_BACKEND")
    if env:
        env = env.lower()
        if env not in ("pallas", "xla"):
            raise ValueError(f"ICQ_BACKEND must be 'pallas' or 'xla', got {env!r}")
        return env
    return "pallas" if detected_platform() == "tpu" else "xla"


def default_runtime_fmt() -> str:
    """'v2' checkpointed-stream runtime unless ICQ_RUNTIME_FMT says 'v1'."""
    env = os.environ.get("ICQ_RUNTIME_FMT")
    if env:  # set-but-empty means unset
        env = env.lower()
        if env not in ("v1", "v2"):
            raise ValueError(
                f"ICQ_RUNTIME_FMT must be 'v1' or 'v2', got {env!r}")
        return env
    return "v2"


def default_onehot_dtype() -> str:
    """'f32' (exact) or 'bf16' (half-size one-hot select temporary)."""
    env = os.environ.get("ICQ_ONEHOT_DTYPE")
    if not env:  # unset or set-but-empty
        return "f32"
    env = env.lower()
    if env not in ("f32", "bf16"):
        raise ValueError(
            f"ICQ_ONEHOT_DTYPE must be 'f32' or 'bf16', got {env!r}")
    return env


def default_accum_dtype() -> str:
    """'f32' (exact) or 'bf16' (half-size matmul accumulator scratch)."""
    env = os.environ.get("ICQ_ACCUM_DTYPE")
    if not env:  # unset or set-but-empty
        return "f32"
    env = env.lower()
    if env not in ("f32", "bf16"):
        raise ValueError(
            f"ICQ_ACCUM_DTYPE must be 'f32' or 'bf16', got {env!r}")
    return env


def default_paged_attn() -> str:
    """'pallas' on TPU, 'xla' elsewhere; ICQ_PAGED_ATTN overrides."""
    env = os.environ.get("ICQ_PAGED_ATTN")
    if env:  # set-but-empty means unset
        env = env.lower()
        if env not in ("pallas", "xla"):
            raise ValueError(
                f"ICQ_PAGED_ATTN must be 'pallas' or 'xla', got {env!r}")
        return env
    return "pallas" if detected_platform() == "tpu" else "xla"


def decode_m_threshold() -> int:
    """M at or below this routes to the fused icq_matmul kernel."""
    env = os.environ.get("ICQ_DECODE_M")
    if not env:  # unset or set-but-empty
        return 32
    try:
        return int(env)
    except ValueError:
        raise ValueError(f"ICQ_DECODE_M must be an integer, got {env!r}")
