"""Per-(shape, n_bits, backend) block-size autotuner for the ICQ kernels.

The Pallas kernels take ``block_m/n/k`` tile sizes whose best values
depend on matrix geometry, n_bits (packing granularity) and whether the
kernel runs compiled on TPU or interpreted. ``autotune_matmul`` /
``autotune_dequant`` sweep a small candidate list on synthetic runtime
tensors of the right geometry, time each, and cache the winner:

  * in-memory (process lifetime), and
  * as JSON on disk (``ICQ_AUTOTUNE_CACHE``, default
    ``~/.cache/icq_autotune.json``) so ``benchmarks/run.py`` and the
    serving engine reuse winners across processes.

``lookup(key)`` is cheap and is what ``backend.prepare`` consults; a
miss falls back to the static defaults, so autotuning is always
optional.
"""
from __future__ import annotations

import json
import os
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

# winner blocks per key, e.g. {"matmul/m1_o4096_i4096_n2_xla": [8, 128, 512]}
_MEM: Dict[str, List[int]] = {}
_LOADED_FROM: Optional[str] = None  # cache file the in-memory view mirrors

MATMUL_CANDIDATES: Tuple[Tuple[int, int, int], ...] = (
    (128, 128, 512),
    (128, 256, 512),
    (64, 128, 1024),
    (8, 128, 512),
)
DEQUANT_CANDIDATES: Tuple[Tuple[int, int], ...] = (
    (256, 512),
    (128, 1024),
    (512, 256),
)

# Batched-M buckets tuned in addition to the decode shape (M=1): winners
# at these keys let backend.arm_blocks re-block the fused arm for
# prefill-sized calls instead of reusing the decode-tuned table.
# ``register_prefill_m`` extends the table at runtime — the serving
# engine registers batch * prefill_chunk so chunked-prefill matmuls get
# their own bucket instead of rounding down to a coarser one.
PREFILL_MS: Tuple[int, ...] = (64, 256)


def register_prefill_m(m: int) -> None:
    """Add a batched-M bucket (idempotent; M <= 1 is the decode key and
    is ignored). Affects ``backend.bucket_m`` immediately and adds the
    bucket to subsequent ``autotune_arms`` sweeps."""
    global PREFILL_MS
    m = int(m)
    if m > 1 and m not in PREFILL_MS:
        PREFILL_MS = tuple(sorted((*PREFILL_MS, m)))


def cache_path() -> str:
    return os.environ.get(
        "ICQ_AUTOTUNE_CACHE",
        os.path.join(os.path.expanduser("~"), ".cache", "icq_autotune.json"),
    )


def _key_suffix(fmt: str, onehot: Optional[str],
                accum: Optional[str] = "f32") -> str:
    """Key qualifiers: runtime formats, one-hot dtypes and accumulator
    dtypes tune independently (v1/f32 keep the legacy un-suffixed
    spellings so existing cache files stay valid). The one-hot and
    accumulator dtypes must be part of the key because VMEM admission
    depends on them — a block winner admitted under a half-width bf16
    temporary may bust the budget when replayed at f32."""
    if onehot is None:
        from repro.kernels.platform import default_onehot_dtype

        onehot = default_onehot_dtype()
    if accum is None:
        from repro.kernels.platform import default_accum_dtype

        accum = default_accum_dtype()
    sfx = "" if fmt == "v1" else f"_{fmt}"
    if onehot != "f32":
        sfx += f"_oh-{onehot}"
    if accum != "f32":
        sfx += f"_acc-{accum}"
    return sfx


def matmul_key(M: int, d_out: int, d_in: int, n_bits: int,
               backend: str, interpret: bool, fmt: str = "v1",
               onehot: Optional[str] = None,
               accum: Optional[str] = None) -> str:
    """Cache key (see _key_suffix for the fmt/onehot/accum qualifiers)."""
    mode = f"{backend}{'-int' if interpret else ''}"
    return (f"matmul/m{M}_o{d_out}_i{d_in}_n{n_bits}_{mode}"
            f"{_key_suffix(fmt, onehot, accum)}")


def dequant_key(d_out: int, d_in: int, n_bits: int,
                backend: str, interpret: bool, fmt: str = "v1",
                onehot: Optional[str] = None) -> str:
    mode = f"{backend}{'-int' if interpret else ''}"
    return (f"dequant/o{d_out}_i{d_in}_n{n_bits}_{mode}"
            f"{_key_suffix(fmt, onehot)}")


def _load_disk() -> None:
    """Mirror the current cache file; reload if ICQ_AUTOTUNE_CACHE moved
    (so entries tuned against an old path never leak into the new file)."""
    global _LOADED_FROM
    path = cache_path()
    if _LOADED_FROM == path:
        return
    _MEM.clear()
    _LOADED_FROM = path
    try:
        with open(path) as f:
            _MEM.update(json.load(f))
    except (OSError, ValueError):
        pass


def lookup(key: str) -> Optional[List[int]]:
    _load_disk()
    return _MEM.get(key)


def record(key: str, blocks: Sequence[int]) -> None:
    _load_disk()
    _MEM[key] = list(blocks)
    path = cache_path()
    try:
        parent = os.path.dirname(path)
        if parent:
            os.makedirs(parent, exist_ok=True)
        with open(path, "w") as f:
            json.dump(_MEM, f, indent=1, sort_keys=True)
    except OSError:
        pass  # read-only filesystem: in-memory cache still works


def reset(forget_disk: bool = True) -> None:
    """Drop the in-memory cache (tests). forget_disk=False keeps the
    view empty without re-reading the current file."""
    global _LOADED_FROM
    _MEM.clear()
    _LOADED_FROM = None if forget_disk else cache_path()


def _synthetic_runtime(d_out: int, d_in: int, n_bits: int, seed: int = 0):
    """Random tensors with the exact runtime-format geometry (timing only)."""
    import jax.numpy as jnp

    from repro.core.packing import packed_width

    rng = np.random.default_rng(seed)
    wc, wb = packed_width(d_in, n_bits), packed_width(d_in, 1)
    C = 2 << n_bits
    codes = jnp.asarray(
        rng.integers(0, 2**32, size=(d_out, wc), dtype=np.uint32))
    bitmap = jnp.asarray(
        rng.integers(0, 2**32, size=(d_out, wb), dtype=np.uint32))
    codebooks = jnp.asarray(rng.standard_normal((d_out, C)), jnp.float32)
    return codes, bitmap, codebooks


def _synthetic_stream(d_out: int, d_in: int, gamma: float = 0.05,
                      seed: int = 0):
    """Plausible gap stream (sorted uniform outlier positions) for timing
    the v2 kernels: returns an encoded GapStream of the right geometry."""
    from repro.core.bounds import optimal_b
    from repro.core.index_coding import encode_positions

    rng = np.random.default_rng(seed)
    p = max(1, int(gamma * d_in))
    pos = np.sort(
        rng.random((d_out, d_in)).argpartition(p, axis=1)[:, :p], axis=1)
    return encode_positions(pos, d_in, optimal_b(gamma))


def _v2_sidecar(stream, tile: int, pk: int):
    import jax
    import jax.numpy as jnp

    from repro.core.index_coding import stream_checkpoints
    from repro.core.packing import pack_symbols_np

    sym = np.asarray(jax.device_get(stream.symbols))
    cnt = np.asarray(jax.device_get(stream.counts))
    offs, dbase = stream_checkpoints(sym, cnt, stream.b, tile, pk)
    return (jnp.asarray(pack_symbols_np(sym, stream.b)),
            jnp.asarray(offs), jnp.asarray(dbase))


def _time_once(fn, iters: int) -> float:
    import time

    fn().block_until_ready()                       # compile + warm
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn().block_until_ready()
        times.append(time.perf_counter() - t0)
    return float(np.median(times)) * 1e6


def autotune_matmul(
    M: int, d_out: int, d_in: int, n_bits: int,
    *,
    interpret: Optional[bool] = None,
    candidates: Optional[Sequence[Tuple[int, int, int]]] = None,
    iters: int = 3,
    fmt: str = "v1",
) -> Dict[str, object]:
    """Sweep fused-matmul blocks; cache and return the winner.

    ``fmt`` selects the runtime format being tuned (independent cache
    entries — v2 kernels have different VMEM/decode trade-offs).
    Candidates whose VMEM estimate exceeds the budget are skipped before
    ever reaching the compiler; if every candidate busts the budget the
    most-clamped one still runs so a winner always exists.

    Returns {"blocks": (bm, bn, bk), "us": median_us, "cached": bool}.
    """
    import jax.numpy as jnp

    from repro.core.packing import symbol_cols
    from repro.kernels import backend as _backend
    from repro.kernels.icq_matmul import (
        icq_matmul, icq_matmul_v2, matmul_blocks,
    )
    from repro.kernels.icq_dequant import _round_up
    from repro.kernels.platform import default_interpret

    if interpret is None:
        interpret = default_interpret()
    key = matmul_key(M, d_out, d_in, n_bits, "pallas", interpret, fmt=fmt)
    hit = lookup(key)
    if hit is not None:
        return dict(blocks=tuple(hit), us=None, cached=True)

    codes, bitmap, codebooks = _synthetic_runtime(d_out, d_in, n_bits)
    x = jnp.asarray(
        np.random.default_rng(1).standard_normal((M, d_in)), jnp.float32)
    stream = _synthetic_stream(d_out, d_in) if fmt == "v2" else None
    s_cols = symbol_cols(
        max(-(-stream.symbols.shape[-1] // (32 // stream.b)), 1), stream.b
    ) if fmt == "v2" else 0
    C = 2 << n_bits

    best, best_us = None, float("inf")
    seen = set()
    budget = _backend.vmem_budget_bytes()
    for bm, bn, bk in (candidates or MATMUL_CANDIDATES):
        resolved = matmul_blocks(M, d_out, d_in, n_bits, bm, bn, bk, fmt=fmt)
        if resolved in seen:                        # clamping may collide
            continue
        if _backend.vmem_bytes_estimate(
                *resolved, n_bits=n_bits, C=C, fmt=fmt,
                s_cols=s_cols) > budget:
            continue                                # would bust VMEM
        seen.add(resolved)
        if fmt == "v2":
            tile = resolved[2]
            pk = _round_up(d_in, tile)
            syms, offs, dbase = _v2_sidecar(stream, tile, pk)
            fn = lambda bm=bm, bn=bn, t=tile, s=syms, o=offs, d=dbase: \
                icq_matmul_v2(
                    x, codes, s, o, d, codebooks, n_bits=n_bits,
                    b=stream.b, d_in=d_in, tile=t, block_m=bm, block_n=bn,
                    interpret=interpret,
                )
        else:
            fn = lambda bm=bm, bn=bn, bk=bk: icq_matmul(
                x, codes, bitmap, codebooks, n_bits=n_bits, d_in=d_in,
                block_m=bm, block_n=bn, block_k=bk, interpret=interpret,
            )
        us = _time_once(fn, iters)
        if us < best_us:
            best, best_us = (bm, bn, bk), us
    if best is None:  # every candidate over budget: run the clamped floor
        bm, bn, bk = _backend._clamp_blocks_to_budget(
            *matmul_blocks(M, d_out, d_in, n_bits, *MATMUL_CANDIDATES[0],
                           fmt=fmt),
            n_bits=n_bits, C=C, fmt=fmt, d_in=d_in, s_cols=s_cols)
        best, best_us = (bm, bn, bk), None
    record(key, best)
    return dict(blocks=best, us=best_us, cached=False)


def autotune_dequant(
    d_out: int, d_in: int, n_bits: int,
    *,
    interpret: Optional[bool] = None,
    candidates: Optional[Sequence[Tuple[int, int]]] = None,
    iters: int = 3,
    fmt: str = "v1",
) -> Dict[str, object]:
    """Sweep dequant blocks; cache and return the winner."""
    from repro.kernels import backend as _backend
    from repro.kernels.icq_dequant import (
        _round_up, dequant_blocks, icq_dequant, icq_dequant_v2,
    )
    from repro.kernels.platform import default_interpret

    if interpret is None:
        interpret = default_interpret()
    key = dequant_key(d_out, d_in, n_bits, "pallas", interpret, fmt=fmt)
    hit = lookup(key)
    if hit is not None:
        return dict(blocks=tuple(hit), us=None, cached=True)

    from repro.core.packing import symbol_cols

    codes, bitmap, codebooks = _synthetic_runtime(d_out, d_in, n_bits)
    stream = _synthetic_stream(d_out, d_in) if fmt == "v2" else None
    s_cols = symbol_cols(
        max(-(-stream.symbols.shape[-1] // (32 // stream.b)), 1), stream.b
    ) if fmt == "v2" else 0
    best, best_us = None, float("inf")
    seen = set()
    budget = _backend.vmem_budget_bytes()
    C = 2 << n_bits
    for br, bc in (candidates or DEQUANT_CANDIDATES):
        resolved = dequant_blocks(d_out, d_in, n_bits, br, bc, fmt=fmt)
        if resolved in seen:
            continue
        if _backend.vmem_bytes_estimate(
                8, *resolved, n_bits=n_bits, C=C, fmt=fmt,
                s_cols=s_cols) > budget:
            continue
        seen.add(resolved)
        if fmt == "v2":
            tile = resolved[1]
            syms, offs, dbase = _v2_sidecar(
                stream, tile, _round_up(d_in, tile))
            fn = lambda br=br, t=tile, s=syms, o=offs, d=dbase: \
                icq_dequant_v2(
                    codes, s, o, d, codebooks, n_bits=n_bits, b=stream.b,
                    d_in=d_in, tile=t, block_r=br, interpret=interpret,
                )
        else:
            fn = lambda br=br, bc=bc: icq_dequant(
                codes, bitmap, codebooks, n_bits=n_bits, d_in=d_in,
                block_r=br, block_c=bc, interpret=interpret,
            )
        us = _time_once(fn, iters)
        if us < best_us:
            best, best_us = (br, bc), us
    if best is None:  # every candidate over budget: run the clamped floor
        br, bc = dequant_blocks(d_out, d_in, n_bits,
                                *DEQUANT_CANDIDATES[-1], fmt=fmt)
        _, br, bc = _backend._clamp_blocks_to_budget(
            8, br, bc, n_bits=n_bits, C=C, fmt=fmt, d_in=d_in,
            s_cols=s_cols)
        best, best_us = (br, bc), None
    record(key, best)
    return dict(blocks=best, us=best_us, cached=False)


def paged_attn_key(G: int, d: int, dv: int, bs: int, n_pt: int, *,
                   d2: int = 0, itemsize: int = 4,
                   backend: str = "pallas",
                   interpret: bool = False) -> str:
    """Cache key for the paged-attention pages-per-step sweep. Keyed on
    per-program geometry (head group G, head dims, KV block size, page
    table length, pool itemsize) — batch and kv-head count only scale
    the grid, not the per-step working set."""
    mode = f"{backend}{'-int' if interpret else ''}"
    return (f"paged_attn/g{G}_d{d}_v{dv}_r{d2}_bs{bs}_pt{n_pt}"
            f"_e{itemsize}_{mode}")


def paged_attn_pages_per_step(*, G: int, d: int, dv: int, bs: int,
                              n_pt: int, d2: int = 0,
                              itemsize: int = 4) -> int:
    """Trace-time pages-per-grid-step pick for the paged-attention
    kernel: the cached sweep winner if one exists, else the largest
    candidate fitting the VMEM budget (no timing — what
    ``models/layers.py`` consults per dispatch, mirroring
    ``backend.arm_blocks``)."""
    from repro.kernels.paged_attention import fallback_pages_per_step
    from repro.kernels.platform import default_interpret

    hit = lookup(paged_attn_key(G, d, dv, bs, n_pt, d2=d2,
                                itemsize=itemsize,
                                interpret=default_interpret()))
    if hit:
        return int(hit[0])
    return fallback_pages_per_step(G=G, d=d, dv=dv, bs=bs, n_pt=n_pt,
                                   d2=d2, itemsize=itemsize)


def autotune_paged_attn(
    B: int, Hkv: int, G: int, d: int, dv: int, bs: int, n_pt: int,
    *,
    d2: int = 0,
    interpret: Optional[bool] = None,
    candidates: Optional[Sequence[int]] = None,
    iters: int = 3,
) -> Dict[str, object]:
    """Sweep the paged-attention pages-per-grid-step knob on synthetic
    full-occupancy pools; cache and return the winner.

    Candidates whose per-step VMEM bill exceeds the budget are skipped
    before reaching the compiler; P=1 always fits as the floor.
    Returns {"pages_per_step": P, "us": median_us, "cached": bool}.
    """
    import jax.numpy as jnp

    from repro.kernels import backend as _backend
    from repro.kernels.paged_attention import (
        PAGES_PER_STEP_CANDIDATES, attn_vmem_bytes, paged_attention,
    )
    from repro.kernels.platform import default_interpret

    if interpret is None:
        interpret = default_interpret()
    key = paged_attn_key(G, d, dv, bs, n_pt, d2=d2, interpret=interpret)
    hit = lookup(key)
    if hit is not None:
        return dict(pages_per_step=int(hit[0]), us=None, cached=True)

    rng = np.random.default_rng(0)
    nb = B * n_pt + 1
    k_pool = jnp.asarray(
        rng.standard_normal((nb, bs, Hkv, d)), jnp.float32)
    v_pool = jnp.asarray(
        rng.standard_normal((nb, bs, Hkv, dv)), jnp.float32)
    q = jnp.asarray(rng.standard_normal((B, Hkv, G, d)), jnp.float32)
    q2 = k2_pool = None
    if d2:
        q2 = jnp.asarray(rng.standard_normal((B, Hkv, G, d2)), jnp.float32)
        k2_pool = jnp.asarray(
            rng.standard_normal((nb, bs, Hkv, d2)), jnp.float32)
    # full lanes (worst case): every page mapped, shuffled placement
    pages = jnp.asarray(
        rng.permutation(np.arange(1, nb))[:B * n_pt].reshape(B, n_pt)
        .astype(np.int32))
    kv_len = jnp.full((B,), n_pt * bs, jnp.int32)

    best, best_us = None, float("inf")
    budget = _backend.vmem_budget_bytes()
    for P in (candidates or PAGES_PER_STEP_CANDIDATES):
        P = min(int(P), n_pt)
        if P != 1 and attn_vmem_bytes(P, G=G, d=d, dv=dv, bs=bs,
                                      d2=d2) > budget:
            continue
        fn = lambda P=P: paged_attention(
            q, k_pool, v_pool, pages, kv_len, q2=q2, k2_pool=k2_pool,
            pages_per_step=P, interpret=interpret)
        us = _time_once(fn, iters)
        if us < best_us:
            best, best_us = P, us
    record(key, [best])
    return dict(pages_per_step=best, us=best_us, cached=False)


def autotune_arms(
    d_out: int, d_in: int, n_bits: int,
    *,
    interpret: Optional[bool] = None,
    fmt: str = "v1",
    iters: int = 3,
    prefill_ms: Optional[Sequence[int]] = None,
) -> Dict[str, object]:
    """Tune every dispatch arm of one weight geometry in one shot.

    Populates the decode key (fused matmul, M=1), one fused-matmul key
    per prefill-M bucket (``PREFILL_MS`` by default), and the M-free
    dequant key — i.e. the full per-arm block table that
    ``backend.arm_blocks`` consults at call time. Returns
    {"decode": ..., "prefill": {M: ...}, "dequant": ...} with each
    leaf the corresponding autotune result dict.
    """
    out: Dict[str, object] = dict(
        decode=autotune_matmul(1, d_out, d_in, n_bits,
                               interpret=interpret, iters=iters, fmt=fmt),
        prefill={},
        dequant=autotune_dequant(d_out, d_in, n_bits,
                                 interpret=interpret, iters=iters, fmt=fmt),
    )
    for m in (PREFILL_MS if prefill_ms is None else prefill_ms):
        out["prefill"][int(m)] = autotune_matmul(
            int(m), d_out, d_in, n_bits,
            interpret=interpret, iters=iters, fmt=fmt)
    return out


def kv_block_size_key(max_len: int) -> str:
    """Cache key for the paged-KV block-size sweep. Keyed on the engine
    cache cap only: the tradeoff below is a pure function of sequence
    lengths relative to max_len, independent of model geometry (every
    layer pays the same per-row bytes) and batch (both costs scale
    linearly with lane count)."""
    return f"kv_block/maxlen{int(max_len)}"


def kv_block_size_for(max_len: int) -> Optional[int]:
    """The cached block-size winner for this cache cap, or None (the
    engine's ``kv_block_size='auto'`` consults this and falls back to
    the static default on a miss)."""
    hit = lookup(kv_block_size_key(max_len))
    return int(hit[0]) if hit else None


KV_BLOCK_CANDIDATES: Tuple[int, ...] = (4, 8, 16, 32, 64)


def autotune_kv_block_size(
    seq_lens: Sequence[int],
    max_len: int,
    *,
    row_bytes: float = 4096.0,
    table_entry_bytes: float = 8.0,
    block_touch_bytes: float = 256.0,
    candidates: Optional[Sequence[int]] = None,
) -> Dict[str, object]:
    """Pick the paged-KV block size for a traffic trace by cost model
    and record it in the shared JSON cache.

    Block size trades two overheads (both in byte-equivalents so they
    compare on one axis):

      * **fragmentation** — the last block of every sequence is on
        average half empty: larger blocks waste more pool HBM rows
        (``row_bytes`` per wasted row, i.e. KV bytes across all layers);
      * **page-table + walk overhead** — smaller blocks mean more
        page-table entries shipped per version bump
        (``table_entry_bytes`` each, host int32 + device mirror) and
        more per-block walk/DMA setup in the paged-attention kernel
        (``block_touch_bytes`` per block actually touched).

    Unlike the kernel sweeps this is a closed-form model, not a timing
    loop — allocator cost is host bookkeeping and the dominant terms
    (wasted HBM rows vs table entries) are exactly countable from the
    trace. Returns {"block_size", "cost_bytes", "cached"}.
    """
    if max_len < 1:
        raise ValueError(f"max_len must be >= 1, got {max_len}")
    lens = [min(int(n), int(max_len)) for n in seq_lens]
    if not lens or min(lens) < 1:
        raise ValueError("seq_lens must be non-empty positive lengths")
    key = kv_block_size_key(max_len)
    hit = lookup(key)
    if hit is not None:
        return dict(block_size=int(hit[0]), cost_bytes=None, cached=True)

    best, best_cost = None, float("inf")
    for bs in (candidates or KV_BLOCK_CANDIDATES):
        bs = min(int(bs), int(max_len))
        n_pt = -(-max_len // bs)
        frag_rows = sum(-(-n // bs) * bs - n for n in lens)
        blocks_touched = sum(-(-n // bs) for n in lens)
        cost = (frag_rows * row_bytes
                + len(lens) * n_pt * table_entry_bytes
                + blocks_touched * block_touch_bytes)
        if cost < best_cost:
            best, best_cost = bs, cost
    record(key, [best])
    return dict(block_size=best, cost_bytes=best_cost, cached=False)
