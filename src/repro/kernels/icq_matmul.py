"""Pallas TPU kernel: fused ICQuant dequantize + matmul.

y = x @ W_hat.T with W stored packed (n-bit codes + 1-bit selector +
per-row dual codebooks). The weight tile is dequantized in VMEM and fed
straight to the MXU — HBM never sees the dense bf16 weights, so the
memory roofline term for decode-bound serving drops by ~16/(n+1)x.

Grid (M/BM, N/BN, K/BK), K innermost; f32 accumulator lives in a VMEM
scratch buffer and is flushed to the output tile at the last K step
(standard Pallas matmul schedule, MXU-aligned tiles).

Two entry points, mirroring icq_dequant:
  * ``matmul_padded`` — hot-path core over pre-blocked weights (see
    kernels/backend.py ``prepare``); only the activation was padded by
    the caller, the weight tensors carry no per-call reshape/pad work.
  * ``icq_matmul``    — pad-on-the-fly wrapper (tests, benchmarks).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.icq_dequant import (
    _codebook_select,
    _decode_block_selector,
    _pad2,
    _round_up,
    _unpack_block,
    check_onehot,
    column_granularity,
    snap_block_k,
)
from repro.kernels.platform import (
    default_accum_dtype,
    default_interpret,
    default_onehot_dtype,
)


def check_accum(accum: str) -> None:
    if accum not in ("f32", "bf16"):
        raise ValueError(f"accum must be 'f32' or 'bf16', got {accum!r}")


def accum_scratch_dtype(accum: str):
    """VMEM accumulator dtype for ``ICQ_ACCUM_DTYPE`` (f32 exact; bf16
    halves the scratch and rounds partial sums per K step)."""
    return jnp.float32 if accum == "f32" else jnp.bfloat16


def _matmul_kernel(x_ref, codes_ref, bitmap_ref, cb_ref, out_ref, acc_ref,
                   *, n_bits: int, n_k: int, onehot: str):
    @pl.when(pl.program_id(2) == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    BK = x_ref.shape[-1]
    codes = _unpack_block(codes_ref[...], n_bits, BK)     # (BN, BK)
    sel = _unpack_block(bitmap_ref[...], 1, BK)
    w = _codebook_select(sel * (1 << n_bits) + codes, cb_ref[...], onehot)
    acc_ref[...] += jax.lax.dot_general(
        x_ref[...].astype(jnp.float32), w,
        (((1,), (1,)), ((), ())),                          # x @ w.T
        preferred_element_type=jnp.float32,
    ).astype(acc_ref.dtype)            # MXU still f32; bf16 rounds per step

    @pl.when(pl.program_id(2) == n_k - 1)
    def _flush():
        out_ref[...] = acc_ref[...].astype(out_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("n_bits", "block_m", "block_n", "block_k", "interpret",
                     "onehot", "accum"),
)
def matmul_padded(
    x: jnp.ndarray,          # (pm, pk) f32, pm % block_m == pk % block_k == 0
    codes: jnp.ndarray,      # (pn, pk // k) uint32, pn % block_n == 0
    bitmap: jnp.ndarray,     # (pn, pk // 32) uint32
    codebooks: jnp.ndarray,  # (pn, C) f32
    *,
    n_bits: int,
    block_m: int,
    block_n: int,
    block_k: int,
    interpret: bool,
    onehot: str = "f32",
    accum: str = "f32",
) -> jnp.ndarray:
    """Core fused kernel over pre-blocked inputs -> (pm, pn) f32 (padded)."""
    check_onehot(onehot)
    check_accum(accum)
    k = 32 // n_bits
    pm, pk = x.shape
    pn = codes.shape[0]
    C = codebooks.shape[1]
    grid = (pm // block_m, pn // block_n, pk // block_k)
    return pl.pallas_call(
        functools.partial(_matmul_kernel, n_bits=n_bits, n_k=grid[2],
                          onehot=onehot),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((block_n, block_k // k), lambda i, j, kk: (j, kk)),
            pl.BlockSpec((block_n, block_k // 32), lambda i, j, kk: (j, kk)),
            pl.BlockSpec((block_n, C), lambda i, j, kk: (j, 0)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((pm, pn), jnp.float32),
        scratch_shapes=[pltpu.VMEM((block_m, block_n),
                                   accum_scratch_dtype(accum))],
        interpret=interpret,
    )(x, codes, bitmap, codebooks)


def _matmul_kernel_v2(x_ref, codes_ref, syms_ref, offs_ref, dbase_ref,
                      cb_ref, out_ref, acc_ref, *, n_bits: int, b: int,
                      n_k: int, onehot: str):
    @pl.when(pl.program_id(2) == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    BK = x_ref.shape[-1]
    codes = _unpack_block(codes_ref[...], n_bits, BK)          # (BN, BK)
    sel = _decode_block_selector(
        syms_ref[...], offs_ref[...], dbase_ref[...], pl.program_id(2),
        b=b, block_k=BK,
    )
    w = _codebook_select(sel * (1 << n_bits) + codes, cb_ref[...], onehot)
    acc_ref[...] += jax.lax.dot_general(
        x_ref[...].astype(jnp.float32), w,
        (((1,), (1,)), ((), ())),                              # x @ w.T
        preferred_element_type=jnp.float32,
    ).astype(acc_ref.dtype)            # MXU still f32; bf16 rounds per step

    @pl.when(pl.program_id(2) == n_k - 1)
    def _flush():
        out_ref[...] = acc_ref[...].astype(out_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("n_bits", "b", "block_m", "block_n", "interpret",
                     "onehot", "accum"),
)
def matmul_padded_v2(
    x: jnp.ndarray,          # (pm, pk) f32, pm % block_m == 0
    codes: jnp.ndarray,      # (pn, pk // k) uint32, pn % block_n == 0
    syms: jnp.ndarray,       # (pn, SW) uint32 packed b-bit gap symbols
    offs: jnp.ndarray,       # (pn, T+1) uint16 tile symbol offsets
    dbase: jnp.ndarray,      # (pn, T) uint8/uint16 tile base deltas
    codebooks: jnp.ndarray,  # (pn, C)
    *,
    n_bits: int,
    b: int,
    block_m: int,
    block_n: int,
    interpret: bool,
    onehot: str = "f32",
    accum: str = "f32",
) -> jnp.ndarray:
    """v2 fused core over pre-blocked inputs -> (pm, pn) f32 (padded).

    block_k is the checkpoint tile (pk / T from the sidecar shape); the
    selector never exists as a bitmap in HBM — each K block decodes its
    own tile of the gap stream in VMEM.
    """
    check_onehot(onehot)
    check_accum(accum)
    k = 32 // n_bits
    pm, pk = x.shape
    pn = codes.shape[0]
    C = codebooks.shape[1]
    T = offs.shape[1] - 1
    block_k = pk // T
    SW = syms.shape[1]
    grid = (pm // block_m, pn // block_n, T)
    return pl.pallas_call(
        functools.partial(_matmul_kernel_v2, n_bits=n_bits, b=b, n_k=T,
                          onehot=onehot),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((block_n, block_k // k), lambda i, j, kk: (j, kk)),
            pl.BlockSpec((block_n, SW), lambda i, j, kk: (j, 0)),
            pl.BlockSpec((block_n, T + 1), lambda i, j, kk: (j, 0)),
            pl.BlockSpec((block_n, T), lambda i, j, kk: (j, 0)),
            pl.BlockSpec((block_n, C), lambda i, j, kk: (j, 0)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((pm, pn), jnp.float32),
        scratch_shapes=[pltpu.VMEM((block_m, block_n),
                                   accum_scratch_dtype(accum))],
        interpret=interpret,
    )(x, codes, syms, offs, dbase, codebooks)


def icq_matmul_v2(
    x: jnp.ndarray,          # (M, d_in)
    codes: jnp.ndarray,      # (d_out, Wc) uint32
    syms: jnp.ndarray,       # (d_out, SW) uint32
    offs: jnp.ndarray,       # (d_out, T+1) uint16
    dbase: jnp.ndarray,      # (d_out, T) uint8/uint16
    codebooks: jnp.ndarray,  # (d_out, 2^(n+1))
    *,
    n_bits: int,
    b: int,
    d_in: int,
    tile: int,
    block_m: int = 128,
    block_n: int = 128,
    interpret: Optional[bool] = None,
    onehot: Optional[str] = None,
    accum: Optional[str] = None,
) -> jnp.ndarray:
    """Pad-on-the-fly v2 wrapper -> (M, d_out) f32."""
    if interpret is None:
        interpret = default_interpret()
    if onehot is None:
        onehot = default_onehot_dtype()
    if accum is None:
        accum = default_accum_dtype()
    M = x.shape[0]
    d_out = codes.shape[0]
    k = 32 // n_bits
    T = offs.shape[1] - 1
    pk = T * tile
    bm = min(block_m, _round_up(M, 8))
    bn = min(block_n, _round_up(d_out, 8))
    pm, pn = _round_up(M, bm), _round_up(d_out, bn)
    out = matmul_padded_v2(
        _pad2(x.astype(jnp.float32), pm, pk),
        _pad2(codes, pn, pk // k),
        _pad2(syms, pn, syms.shape[1]),
        _pad2(offs, pn, offs.shape[1]),
        _pad2(dbase, pn, dbase.shape[1]),
        _pad2(codebooks, pn, codebooks.shape[1]),
        n_bits=n_bits, b=b, block_m=bm, block_n=bn, interpret=interpret,
        onehot=onehot, accum=accum,
    )
    return out[:M, :d_out]


def matmul_blocks(M: int, d_out: int, d_in: int, n_bits: int,
                  block_m: int, block_n: int, block_k: int,
                  fmt: str = "v1"):
    """Snap requested blocks to packing/tiling granularities -> (bm, bn, bk)."""
    lcm = column_granularity(n_bits, fmt)
    bm = min(block_m, _round_up(M, 8))
    bn = min(block_n, _round_up(d_out, 8))
    return bm, bn, snap_block_k(d_in, lcm, block_k)


def icq_matmul(
    x: jnp.ndarray,          # (M, d_in)
    codes: jnp.ndarray,      # (d_out, Wc) uint32
    bitmap: jnp.ndarray,     # (d_out, Wb) uint32
    codebooks: jnp.ndarray,  # (d_out, 2^(n+1)) f32
    *,
    n_bits: int,
    d_in: int,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 512,
    interpret: Optional[bool] = None,
    onehot: Optional[str] = None,
    accum: Optional[str] = None,
) -> jnp.ndarray:
    """Pad-on-the-fly wrapper -> (M, d_out) f32."""
    if interpret is None:
        interpret = default_interpret()
    if onehot is None:
        onehot = default_onehot_dtype()
    if accum is None:
        accum = default_accum_dtype()
    M = x.shape[0]
    d_out = codes.shape[0]
    k = 32 // n_bits
    bm, bn, bk = matmul_blocks(M, d_out, d_in, n_bits,
                               block_m, block_n, block_k)
    pm, pk_, pn = _round_up(M, bm), _round_up(d_in, bk), _round_up(d_out, bn)
    x_p = _pad2(x.astype(jnp.float32), pm, pk_)
    codes_p = _pad2(codes, pn, pk_ // k)
    bitmap_p = _pad2(bitmap, pn, pk_ // 32)
    cb_p = _pad2(codebooks, pn, codebooks.shape[1])
    out = matmul_padded(
        x_p, codes_p, bitmap_p, cb_p,
        n_bits=n_bits, block_m=bm, block_n=bn, block_k=bk,
        interpret=interpret, onehot=onehot, accum=accum,
    )
    return out[:M, :d_out]
