"""Pallas TPU kernel: fused ICQuant dequantize + matmul.

y = x @ W_hat.T with W stored packed (n-bit codes + 1-bit selector +
per-row dual codebooks). The weight tile is dequantized in VMEM and fed
straight to the MXU — HBM never sees the dense bf16 weights, so the
memory roofline term for decode-bound serving drops by ~16/(n+1)x.

Grid (M/BM, N/BN, K/BK), K innermost; f32 accumulator lives in a VMEM
scratch buffer and is flushed to the output tile at the last K step
(standard Pallas matmul schedule, MXU-aligned tiles).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.icq_dequant import (
    _codebook_select,
    _gcd,
    _pad2,
    _round_up,
    _unpack_block,
)


def _matmul_kernel(x_ref, codes_ref, bitmap_ref, cb_ref, out_ref, acc_ref,
                   *, n_bits: int, n_k: int):
    @pl.when(pl.program_id(2) == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    BK = x_ref.shape[-1]
    codes = _unpack_block(codes_ref[...], n_bits, BK)     # (BN, BK)
    sel = _unpack_block(bitmap_ref[...], 1, BK)
    w = _codebook_select(sel * (1 << n_bits) + codes, cb_ref[...])
    acc_ref[...] += jax.lax.dot_general(
        x_ref[...].astype(jnp.float32), w,
        (((1,), (1,)), ((), ())),                          # x @ w.T
        preferred_element_type=jnp.float32,
    )

    @pl.when(pl.program_id(2) == n_k - 1)
    def _flush():
        out_ref[...] = acc_ref[...]


@functools.partial(
    jax.jit,
    static_argnames=("n_bits", "d_in", "block_m", "block_n", "block_k",
                     "interpret"),
)
def icq_matmul(
    x: jnp.ndarray,          # (M, d_in)
    codes: jnp.ndarray,      # (d_out, Wc) uint32
    bitmap: jnp.ndarray,     # (d_out, Wb) uint32
    codebooks: jnp.ndarray,  # (d_out, 2^(n+1)) f32
    *,
    n_bits: int,
    d_in: int,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 512,
    interpret: bool = True,
) -> jnp.ndarray:
    M = x.shape[0]
    d_out = codes.shape[0]
    k = 32 // n_bits
    lcm = (k * 32) // _gcd(k, 32)
    bk = min(max(lcm, (block_k // lcm) * lcm), _round_up(d_in, lcm))
    bm = min(block_m, _round_up(M, 8))
    bn = min(block_n, _round_up(d_out, 8))

    pm, pk_, pn = _round_up(M, bm), _round_up(d_in, bk), _round_up(d_out, bn)
    x_p = _pad2(x.astype(jnp.float32), pm, pk_)
    codes_p = _pad2(codes, pn, pk_ // k)
    bitmap_p = _pad2(bitmap, pn, pk_ // 32)
    cb_p = _pad2(codebooks, pn, codebooks.shape[1])

    grid = (pm // bm, pn // bn, pk_ // bk)
    out = pl.pallas_call(
        functools.partial(_matmul_kernel, n_bits=n_bits, n_k=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bn, bk // k), lambda i, j, kk: (j, kk)),
            pl.BlockSpec((bn, bk // 32), lambda i, j, kk: (j, kk)),
            pl.BlockSpec((bn, codebooks.shape[1]), lambda i, j, kk: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((pm, pn), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x_p, codes_p, bitmap_p, cb_p)
    return out[:M, :d_out]
