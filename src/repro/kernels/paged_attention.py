"""Pallas TPU kernel: paged-attention for S=1 decode over a block-pool KV.

The paged KV layout (serving/kv_pool.py) stores cache rows in a global
block pool ``(num_blocks, block_size, ...)`` with per-lane page tables
``(B, n_pt)`` int32 (-1 = unmapped). The XLA serving arm
(``models/layers.py::_paged_gather``) materializes the full logical
``(B, n_pt * block_size)`` view every step — reading every
mapped-or-clamped block from HBM regardless of how many rows a lane
actually holds. This kernel walks the page table *inside* the kernel
instead and streams only live blocks through VMEM (vLLM PagedAttention
semantics, Kwon et al.):

  * grid ``(B, n_kv_heads, ceil(n_pt / P))`` with ``P`` pages fetched
    per grid step (the autotuned "pages-per-program" knob);
  * the page table and per-lane lengths ride scalar prefetch
    (``pltpu.PrefetchScalarGridSpec``) so every K/V BlockSpec index map
    can look up the physical block id before the DMA is issued. Steps
    past a lane's live-block count ``ceil(kv_len / block_size)`` clamp
    to the lane's *last live page*: consecutive grid steps then request
    the same block and Mosaic elides the copy — dead pages cost neither
    HBM reads nor compute (the arithmetic is `pl.when`-gated off);
  * online-softmax accumulation in f32 VMEM scratch (running max m,
    running sum l, f32 acc), so partially-filled tail blocks and
    unmapped (-1) entries are masked in-kernel (score ``-1e30``) rather
    than through a post-hoc validity mask over the logical view.

One kernel serves both paged attention flavors:

  * **GQA** — q ``(B, Hkv, G, hd)`` (pre-scaled by the caller), K/V
    pools ``(nb, bs, Hkv, hd)``;
  * **MLA latent cache** — the absorbed decode attends over the latent
    ``c_kv`` stream with a rope side-channel: pass the rope halves as
    ``q2``/``k2_pool`` (scores add) and the ``c_kv`` pool as *both* K
    and V (``Hkv=1``, ``G=H``).

The XLA gather arm stays bitwise-authoritative: it is the CPU/GPU
default, the fault-tolerance degrade target, and the parity oracle the
property tests pin this kernel against (``ICQ_PAGED_ATTN=pallas|xla``).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.platform import default_interpret

_NEG = -1e30  # python float: a jnp constant would be captured by the kernel

#: pages-per-grid-step candidates for the autotune sweep, largest first
PAGES_PER_STEP_CANDIDATES = (8, 4, 2, 1)


def _cdiv(a: int, b: int) -> int:
    return -(-a // b)


def _pool_index_map(i: int, P: int):
    """Index map for the i-th of P per-step pool fetches.

    Steps past the lane's live-block count repeat the last live page
    (clamped, never negative) so the DMA is elided on TPU; the matching
    compute is `pl.when`-gated off, so interpret-mode correctness does
    not depend on what the repeated fetch holds.
    """
    def index_map(b, h, j, pages_ref, nblk_ref, len_ref):
        last = jnp.maximum(nblk_ref[b] - 1, 0)
        blk = jnp.minimum(j * P + i, last)
        page = jnp.maximum(pages_ref[b, blk], 0)   # -1 unmapped -> block 0
        return (page, 0, h, 0)
    return index_map


def _paged_attn_kernel(pages_ref, nblk_ref, len_ref, *refs,
                       P: int, bs: int, n_steps: int, has_q2: bool):
    q_ref = refs[0]
    pos_ = 1
    if has_q2:
        q2_ref = refs[pos_]
        pos_ += 1
    k_refs = refs[pos_:pos_ + P]
    pos_ += P
    if has_q2:
        k2_refs = refs[pos_:pos_ + P]
        pos_ += P
    v_refs = refs[pos_:pos_ + P]
    out_ref = refs[pos_ + P]
    m_ref, l_ref, acc_ref = refs[pos_ + P + 1:pos_ + P + 4]

    b = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full(m_ref.shape, _NEG, jnp.float32)
        l_ref[...] = jnp.zeros(l_ref.shape, jnp.float32)
        acc_ref[...] = jnp.zeros(acc_ref.shape, jnp.float32)

    q = q_ref[...].astype(jnp.float32)                       # (G, d)
    q2 = q2_ref[...].astype(jnp.float32) if has_q2 else None

    for i in range(P):
        blk = j * P + i

        @pl.when(blk < nblk_ref[b])
        def _live(i=i, blk=blk):
            k = k_refs[i][...].astype(jnp.float32)           # (bs, d)
            s = jax.lax.dot_general(
                q, k, (((1,), (1,)), ((), ())),              # q @ k.T
                preferred_element_type=jnp.float32)          # (G, bs)
            if has_q2:
                k2 = k2_refs[i][...].astype(jnp.float32)
                s = s + jax.lax.dot_general(
                    q2, k2, (((1,), (1,)), ((), ())),
                    preferred_element_type=jnp.float32)
            # mask rows past the lane's live length (partial tail block)
            pos = blk * bs + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
            s = jnp.where(pos < len_ref[b], s, _NEG)
            m_prev = m_ref[:, 0:1]                           # (G, 1)
            l_prev = l_ref[:, 0:1]
            m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
            alpha = jnp.exp(m_prev - m_new)
            p = jnp.exp(s - m_new)                           # (G, bs)
            v = v_refs[i][...].astype(jnp.float32)           # (bs, dv)
            acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
                p, v, (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32)
            l_ref[...] = jnp.broadcast_to(
                alpha * l_prev + jnp.sum(p, axis=-1, keepdims=True),
                l_ref.shape)
            m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)

    @pl.when(j == n_steps - 1)
    def _flush():
        out_ref[...] = (acc_ref[...]
                        / jnp.maximum(l_ref[:, 0:1], 1e-30)
                        ).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("pages_per_step", "interpret"))
def _paged_attention_call(q, k_pool, v_pool, q2, k2_pool, pages, nblk,
                          kv_len, *, pages_per_step: int, interpret: bool):
    B, Hkv, G, d = q.shape
    nb, bs = k_pool.shape[0], k_pool.shape[1]
    dv = v_pool.shape[-1]
    n_pt = pages.shape[1]
    P = max(1, min(pages_per_step, n_pt))
    n_steps = _cdiv(n_pt, P)
    has_q2 = q2 is not None

    def _fixed(shape_map):
        return pl.BlockSpec(shape_map, lambda b, h, j, *_refs: (b, h, 0, 0))

    in_specs = [_fixed((None, None, G, d))]                  # q
    operands = [q]
    if has_q2:
        in_specs.append(_fixed((None, None, G, q2.shape[-1])))
        operands.append(q2)
    for i in range(P):                                       # K pages
        in_specs.append(pl.BlockSpec((None, bs, None, d),
                                     _pool_index_map(i, P)))
        operands.append(k_pool)
    if has_q2:
        for i in range(P):                                   # rope K pages
            in_specs.append(pl.BlockSpec((None, bs, None, k2_pool.shape[-1]),
                                         _pool_index_map(i, P)))
            operands.append(k2_pool)
    for i in range(P):                                       # V pages
        in_specs.append(pl.BlockSpec((None, bs, None, dv),
                                     _pool_index_map(i, P)))
        operands.append(v_pool)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(B, Hkv, n_steps),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((None, None, G, dv),
                               lambda b, h, j, *_refs: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, 128), jnp.float32),               # running max
            pltpu.VMEM((G, 128), jnp.float32),               # running sum
            pltpu.VMEM((G, dv), jnp.float32),                # f32 acc
        ],
    )
    return pl.pallas_call(
        functools.partial(_paged_attn_kernel, P=P, bs=bs, n_steps=n_steps,
                          has_q2=has_q2),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, dv), jnp.float32),
        interpret=interpret,
    )(pages, nblk, kv_len, *operands)


def attn_vmem_bytes(pages_per_step: int, *, G: int, d: int, dv: int,
                    bs: int, d2: int = 0, itemsize: int = 4) -> int:
    """VMEM bill for one grid step: double-buffered page fetches plus
    the resident q/out tiles and the online-softmax scratch."""
    paged = pages_per_step * bs * (d + dv + d2) * itemsize
    fixed = G * (d + d2) * itemsize + G * dv * 4
    scratch = (2 * G * 128 + G * dv) * 4
    return 2 * paged + fixed + scratch


def fallback_pages_per_step(*, G: int, d: int, dv: int, bs: int, n_pt: int,
                            d2: int = 0, itemsize: int = 4,
                            budget: Optional[int] = None) -> int:
    """Largest sweep candidate that fits the VMEM budget (no timing)."""
    if budget is None:
        from repro.kernels import backend as _backend
        budget = _backend.vmem_budget_bytes()
    for cand in PAGES_PER_STEP_CANDIDATES:
        if cand <= max(1, n_pt) and attn_vmem_bytes(
                cand, G=G, d=d, dv=dv, bs=bs, d2=d2,
                itemsize=itemsize) <= budget:
            return cand
    return 1


def paged_attention(
    q: jnp.ndarray,                     # (B, Hkv, G, d), pre-scaled
    k_pool: jnp.ndarray,                # (nb, bs, Hkv, d)
    v_pool: jnp.ndarray,                # (nb, bs, Hkv, dv)
    pages: jnp.ndarray,                 # (B, n_pt) int32, -1 = unmapped
    kv_len: jnp.ndarray,                # (B,) int32 live rows per lane
    *,
    q2: Optional[jnp.ndarray] = None,       # (B, Hkv, G, d2) rope half
    k2_pool: Optional[jnp.ndarray] = None,  # (nb, bs, Hkv, d2)
    pages_per_step: Optional[int] = None,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    """Decode-step paged attention -> (B, Hkv, G, dv) f32.

    ``q`` must already carry the softmax scale (``q * d**-0.5`` — or the
    model's scale of choice); scores are ``q @ k.T (+ q2 @ k2.T)``.
    Lanes with ``kv_len == 0`` produce zeros. Unmapped (-1) pages inside
    a lane's live range clamp to block 0 with positions ``< kv_len``
    still attended — the same contract as the XLA gather arm, so the two
    arms agree even on garbage lanes.
    """
    if interpret is None:
        interpret = default_interpret()
    if (q2 is None) != (k2_pool is None):
        raise ValueError("q2 and k2_pool must be passed together")
    B, Hkv, G, d = q.shape
    bs = k_pool.shape[1]
    n_pt = pages.shape[1]
    if pages_per_step is None:
        pages_per_step = fallback_pages_per_step(
            G=G, d=d, dv=v_pool.shape[-1], bs=bs, n_pt=n_pt,
            d2=0 if q2 is None else q2.shape[-1],
            itemsize=k_pool.dtype.itemsize)
    pages = pages.astype(jnp.int32)
    kv_len = kv_len.astype(jnp.int32)
    nblk = (kv_len + bs - 1) // bs
    return _paged_attention_call(
        q, k_pool, v_pool, q2, k2_pool, pages, nblk, kv_len,
        pages_per_step=int(pages_per_step), interpret=bool(interpret))


__all__ = [
    "PAGES_PER_STEP_CANDIDATES",
    "attn_vmem_bytes",
    "fallback_pages_per_step",
    "paged_attention",
]
