"""Pure-jnp oracles for the Pallas kernels (the ground truth in tests).

Runtime tensor format (DESIGN.md §4.3) shared by kernels and refs:
  codes:     (d_out, ceil(d_in/k)) uint32 — k = 32//n packed n-bit codes
  bitmap:    (d_out, ceil(d_in/32)) uint32 — 1-bit outlier selector
  codebooks: (d_out, 2^(n+1)) f32 — [inlier levels ++ outlier levels]
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.packing import unpack_codes


def dequant_ref(codes, bitmap, codebooks, n_bits: int, d_in: int):
    """-> (d_out, d_in) f32 reconstruction."""
    c = unpack_codes(codes, n_bits, d_in).astype(jnp.int32)
    sel = unpack_codes(bitmap, 1, d_in).astype(jnp.int32)
    idx = sel * (1 << n_bits) + c
    return jnp.take_along_axis(codebooks, idx, axis=-1)


def matmul_ref(x, codes, bitmap, codebooks, n_bits: int, d_in: int):
    """x: (M, d_in) @ W_hat.T -> (M, d_out)."""
    w = dequant_ref(codes, bitmap, codebooks, n_bits, d_in)
    return x.astype(jnp.float32) @ w.T


def kmeans_assign_ref(w, weight, centroids):
    """One weighted-Lloyd accumulation step.

    w, weight: (R, L); centroids: (R, C).
    Returns (wsum (R, C), vsum (R, C)): per-cluster weight and
    weight*value sums under nearest-centroid assignment."""
    d = jnp.abs(w[..., None] - centroids[:, None, :])        # (R, L, C)
    a = jnp.argmin(d, axis=-1)                               # (R, L)
    onehot = jax.nn.one_hot(a, centroids.shape[-1], dtype=jnp.float32)
    wsum = (onehot * weight[..., None]).sum(axis=1)
    vsum = (onehot * (weight * w)[..., None]).sum(axis=1)
    return wsum, vsum
