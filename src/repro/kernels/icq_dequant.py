"""Pallas TPU kernel: ICQuant tile dequantization.

HBM->VMEM traffic per output tile is n/16 + 1/16th of the bf16 baseline
(packed codes + 1-bit selector bitmap + one codebook row pair); the
unpack is shift/mask on the VPU and the codebook lookup is an
iota-compare one-hot reduction (<= 32 fused multiply-adds per element for
n <= 4), avoiding dynamic gathers that don't vectorize on TPU.

Block layout: grid (d_out/BR, d_in/BC); code words and bitmap words are
blocked along the same column tiles (BC is a multiple of lcm(k, 32)).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _unpack_block(words: jnp.ndarray, n_bits: int, out_cols: int) -> jnp.ndarray:
    """(BR, W) uint32 -> (BR, out_cols) int32 of n-bit fields."""
    k = 32 // n_bits
    mask = jnp.uint32((1 << n_bits) - 1)
    shifts = (jnp.arange(k, dtype=jnp.uint32) * n_bits)[None, None, :]
    fields = (words[:, :, None] >> shifts) & mask
    return fields.reshape(words.shape[0], -1)[:, :out_cols].astype(jnp.int32)


def _codebook_select(idx: jnp.ndarray, codebooks: jnp.ndarray) -> jnp.ndarray:
    """idx: (BR, BC) int32 in [0, C); codebooks: (BR, C) -> (BR, BC) f32
    via one-hot reduction (TPU-friendly gather)."""
    C = codebooks.shape[-1]
    acc = jnp.zeros(idx.shape, jnp.float32)
    for c in range(C):  # C <= 32 for n_bits <= 4: unrolled VPU selects
        acc = acc + jnp.where(idx == c, codebooks[:, c][:, None], 0.0)
    return acc


def _dequant_kernel(codes_ref, bitmap_ref, cb_ref, out_ref, *, n_bits: int):
    BC = out_ref.shape[-1]
    codes = _unpack_block(codes_ref[...], n_bits, BC)
    sel = _unpack_block(bitmap_ref[...], 1, BC)
    idx = sel * (1 << n_bits) + codes
    out_ref[...] = _codebook_select(idx, cb_ref[...])


@functools.partial(
    jax.jit, static_argnames=("n_bits", "d_in", "block_r", "block_c",
                              "interpret")
)
def icq_dequant(
    codes: jnp.ndarray,      # (d_out, Wc) uint32
    bitmap: jnp.ndarray,     # (d_out, Wb) uint32
    codebooks: jnp.ndarray,  # (d_out, 2^(n+1)) f32
    *,
    n_bits: int,
    d_in: int,
    block_r: int = 256,
    block_c: int = 512,
    interpret: bool = True,
) -> jnp.ndarray:
    d_out = codes.shape[0]
    k = 32 // n_bits
    # block_c must align to both packing granularities (code and bitmap
    # words): snap down to a multiple of lcm(k, 32)
    lcm = (k * 32) // _gcd(k, 32)
    block_c = max(lcm, (block_c // lcm) * lcm)
    br = min(block_r, d_out)
    bc = min(block_c, _round_up(d_in, lcm))

    pc = _round_up(d_in, bc)                   # padded columns
    pr = _round_up(d_out, br)
    wc_b, wb_b = bc // k, bc // 32
    codes_p = _pad2(codes, pr, pc // k)
    bitmap_p = _pad2(bitmap, pr, pc // 32)
    cb_p = _pad2(codebooks, pr, codebooks.shape[1])

    grid = (pr // br, pc // bc)
    out = pl.pallas_call(
        functools.partial(_dequant_kernel, n_bits=n_bits),
        grid=grid,
        in_specs=[
            pl.BlockSpec((br, wc_b), lambda i, j: (i, j)),
            pl.BlockSpec((br, wb_b), lambda i, j: (i, j)),
            pl.BlockSpec((br, codebooks.shape[1]), lambda i, j: (i, 0)),
        ],
        out_specs=pl.BlockSpec((br, bc), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((pr, pc), jnp.float32),
        interpret=interpret,
    )(codes_p, bitmap_p, cb_p)
    return out[:d_out, :d_in]


def _gcd(a: int, b: int) -> int:
    while b:
        a, b = b, a % b
    return a


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


def _pad2(x, r, c):
    return jnp.pad(x, ((0, r - x.shape[0]), (0, c - x.shape[1])))
