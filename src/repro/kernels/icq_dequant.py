"""Pallas TPU kernel: ICQuant tile dequantization.

HBM->VMEM traffic per output tile is n/16 + 1/16th of the bf16 baseline
(packed codes + 1-bit selector bitmap + one codebook row pair); the
unpack is shift/mask on the VPU and the codebook lookup is a one-hot
``dot_general`` over the C <= 2^(n+1) levels — a (BR, BC, C) x (BR, C)
batched contraction that rides the MXU instead of C serial VPU selects.

Block layout: grid (d_out/BR, d_in/BC); code words and bitmap words are
blocked along the same column tiles (BC is a multiple of lcm(k, 32) for
the v1 bitmap format, of k alone for v2 — there is no bitmap to align).

Two runtime formats share the kernels:
  * v1 — dense 1-bit selector bitmap (``dequant_padded``): selector
    unpack is shift/mask, HBM overhead ~1 bit/weight.
  * v2 — checkpointed gap stream (``dequant_padded_v2``): the block
    reconstructs its selector locally from b-bit gap symbols + per-tile
    checkpoints via ``_decode_block_selector`` (a short masked cumsum),
    HBM overhead ~0.35-0.45 bit/weight. ``block_c`` must equal the
    checkpoint tile the sidecar was built for.

Two entry points per format:
  * ``dequant_padded[_v2]`` — the hot-path cores. Inputs must already be
    padded/blocked (see kernels/backend.py ``prepare``); no per-call
    reshape or ``jnp.pad`` happens here.
  * ``icq_dequant[_v2]``   — convenience wrappers that pad on the fly
    (benchmarks, tests, one-off calls).

``interpret=None`` resolves via kernels.platform: compiled on TPU,
interpreter everywhere else.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.platform import default_interpret, default_onehot_dtype

# v2 selector decode: symbols compared against the column iota in chunks
# of this many symbols, bounding the (BR, chunk, BC) one-hot temporary.
SEL_CHUNK = 16

# dtype of the (BR, BC, C) codebook-select one-hot temporary (the
# dominant VMEM term): 'f32' is exact, 'bf16' halves it — see
# platform.default_onehot_dtype / ICQ_ONEHOT_DTYPE.
ONEHOT_DTYPES = {"f32": jnp.float32, "bf16": jnp.bfloat16}


def check_onehot(onehot: str) -> str:
    """Validate an explicit ``onehot`` kwarg (the env route validates in
    platform.default_onehot_dtype; this keeps kwarg misuse a ValueError
    instead of a KeyError mid-trace)."""
    if onehot not in ONEHOT_DTYPES:
        raise ValueError(
            f"onehot must be one of {sorted(ONEHOT_DTYPES)}, got {onehot!r}")
    return onehot


def onehot_itemsize(onehot: Optional[str] = None) -> int:
    """Bytes per element of the one-hot select temporary (VMEM budgeting)."""
    if onehot is None:
        onehot = default_onehot_dtype()
    return jnp.dtype(ONEHOT_DTYPES[check_onehot(onehot)]).itemsize


def _unpack_block(words: jnp.ndarray, n_bits: int, out_cols: int) -> jnp.ndarray:
    """(BR, W) uint32 -> (BR, out_cols) int32 of n-bit fields."""
    k = 32 // n_bits
    mask = jnp.uint32((1 << n_bits) - 1)
    shifts = (jnp.arange(k, dtype=jnp.uint32) * n_bits)[None, None, :]
    fields = (words[:, :, None] >> shifts) & mask
    return fields.reshape(words.shape[0], -1)[:, :out_cols].astype(jnp.int32)


def _codebook_select(idx: jnp.ndarray, codebooks: jnp.ndarray,
                     onehot: str = "f32") -> jnp.ndarray:
    """idx: (BR, BC) int32 in [0, C); codebooks: (BR, C) -> (BR, BC) f32.

    One-hot gather as a single batched dot_general (batch dim = row):
    the (BR, BC, C) one-hot contracts against the row codebook on the
    MXU in one shot, instead of the C-step unrolled where-select chain
    the VPU had to chew through serially.

    ``onehot='bf16'`` halves the (BR, BC, C) temporary (one-hot entries
    are exact 0/1 in bf16; the f32-accumulated dot then returns each
    codebook level rounded to bf16 — ~3 decimal digits of level
    precision, the same loss as a bf16 codebook cache).
    """
    C = codebooks.shape[-1]
    dt = ONEHOT_DTYPES[onehot]
    iota = jax.lax.broadcasted_iota(jnp.int32, (1, 1, C), 2)
    oh = (idx[:, :, None] == iota).astype(dt)
    return jax.lax.dot_general(
        oh, codebooks.astype(dt),
        (((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32,
    )


def _decode_block_selector(syms: jnp.ndarray, offs: jnp.ndarray,
                           dbase: jnp.ndarray, kk, *,
                           b: int, block_k: int) -> jnp.ndarray:
    """Checkpointed gap stream -> (BR, block_k) 0/1 selector for tile kk.

    syms:  (BR, SW) uint32 — the row's full packed b-bit symbol stream
           (value-1 encoding, all-ones = escape flag).
    offs:  (BR, T+1) uint16 — symbol offset at every tile boundary
           (sentinel column = per-row symbol count).
    dbase: (BR, T) uint8/uint16 — kk*block_k - dbase[kk] is the absolute
           position consumed before the tile's first symbol.
    kk:    column-tile index (pl.program_id of the K grid axis).

    Decode is block-local: mask the stream to [offs[kk], offs[kk+1]),
    cumsum the masked gap increments (escape = 2^b - 1 positions, no
    emission) on top of the checkpoint base, then scatter-by-compare the
    emitted positions against the tile's column iota. No row prefix is
    scanned and no dense bitmap ever exists.
    """
    k_b = 32 // b
    S = syms.shape[-1] * k_b
    sym = _unpack_block(syms, b, S)                            # (BR, S)
    off = offs.astype(jnp.int32)
    pair = jax.lax.dynamic_slice_in_dim(off, kk, 2, axis=1)    # (BR, 2)
    o0, o1 = pair[:, :1], pair[:, 1:]
    d0 = jax.lax.dynamic_slice_in_dim(
        dbase.astype(jnp.int32), kk, 1, axis=1)                # (BR, 1)
    j = jax.lax.broadcasted_iota(jnp.int32, (1, S), 1)
    in_tile = (j >= o0) & (j < o1)
    m = (1 << b) - 1
    inc = jnp.where(sym == m, m, sym + 1) * in_tile.astype(jnp.int32)
    rel = jnp.cumsum(inc, axis=-1) - d0 - 1          # position - kk*block_k
    emit = in_tile & (sym != m)
    sel = jnp.zeros((syms.shape[0], block_k), jnp.int32)
    iota_c = jax.lax.broadcasted_iota(jnp.int32, (1, 1, block_k), 2)
    for s0 in range(0, S, SEL_CHUNK):
        r = rel[:, s0:s0 + SEL_CHUNK]
        e = emit[:, s0:s0 + SEL_CHUNK]
        hit = (r[:, :, None] == iota_c) & e[:, :, None]
        sel = sel + hit.astype(jnp.int32).sum(axis=1)
    return sel


def _dequant_kernel(codes_ref, bitmap_ref, cb_ref, out_ref, *, n_bits: int,
                    onehot: str):
    BC = out_ref.shape[-1]
    codes = _unpack_block(codes_ref[...], n_bits, BC)
    sel = _unpack_block(bitmap_ref[...], 1, BC)
    idx = sel * (1 << n_bits) + codes
    out_ref[...] = _codebook_select(idx, cb_ref[...], onehot)


@functools.partial(
    jax.jit,
    static_argnames=("n_bits", "block_r", "block_c", "interpret", "onehot"),
)
def dequant_padded(
    codes: jnp.ndarray,      # (pr, pc // k) uint32, pr % block_r == 0
    bitmap: jnp.ndarray,     # (pr, pc // 32) uint32
    codebooks: jnp.ndarray,  # (pr, C) f32
    *,
    n_bits: int,
    block_r: int,
    block_c: int,
    interpret: bool,
    onehot: str = "f32",
) -> jnp.ndarray:
    """Core kernel over pre-blocked inputs -> (pr, pc) f32 (still padded)."""
    check_onehot(onehot)
    k = 32 // n_bits
    pr, pc = codes.shape[0], codes.shape[1] * k
    grid = (pr // block_r, pc // block_c)
    C = codebooks.shape[1]
    return pl.pallas_call(
        functools.partial(_dequant_kernel, n_bits=n_bits, onehot=onehot),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_r, block_c // k), lambda i, j: (i, j)),
            pl.BlockSpec((block_r, block_c // 32), lambda i, j: (i, j)),
            pl.BlockSpec((block_r, C), lambda i, j: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_r, block_c), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((pr, pc), jnp.float32),
        interpret=interpret,
    )(codes, bitmap, codebooks)


def _dequant_kernel_v2(codes_ref, syms_ref, offs_ref, dbase_ref, cb_ref,
                       out_ref, *, n_bits: int, b: int, onehot: str):
    BC = out_ref.shape[-1]
    codes = _unpack_block(codes_ref[...], n_bits, BC)
    sel = _decode_block_selector(
        syms_ref[...], offs_ref[...], dbase_ref[...], pl.program_id(1),
        b=b, block_k=BC,
    )
    idx = sel * (1 << n_bits) + codes
    out_ref[...] = _codebook_select(idx, cb_ref[...], onehot)


@functools.partial(
    jax.jit,
    static_argnames=("n_bits", "b", "block_r", "interpret", "onehot"),
)
def dequant_padded_v2(
    codes: jnp.ndarray,      # (pr, pc // k) uint32, pr % block_r == 0
    syms: jnp.ndarray,       # (pr, SW) uint32 packed b-bit gap symbols
    offs: jnp.ndarray,       # (pr, T+1) uint16 tile symbol offsets
    dbase: jnp.ndarray,      # (pr, T) uint8/uint16 tile base deltas
    codebooks: jnp.ndarray,  # (pr, C)
    *,
    n_bits: int,
    b: int,
    block_r: int,
    interpret: bool,
    onehot: str = "f32",
) -> jnp.ndarray:
    """v2 core over pre-blocked inputs -> (pr, pc) f32 (still padded).

    The column block is the checkpoint tile: block_c = pc / T, where T
    comes from the sidecar shape (``prepare`` guarantees pc == T * tile).
    """
    check_onehot(onehot)
    k = 32 // n_bits
    pr, pc = codes.shape[0], codes.shape[1] * k
    T = offs.shape[1] - 1
    block_c = pc // T
    grid = (pr // block_r, T)
    C = codebooks.shape[1]
    SW = syms.shape[1]
    return pl.pallas_call(
        functools.partial(_dequant_kernel_v2, n_bits=n_bits, b=b,
                          onehot=onehot),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_r, block_c // k), lambda i, j: (i, j)),
            pl.BlockSpec((block_r, SW), lambda i, j: (i, 0)),
            pl.BlockSpec((block_r, T + 1), lambda i, j: (i, 0)),
            pl.BlockSpec((block_r, T), lambda i, j: (i, 0)),
            pl.BlockSpec((block_r, C), lambda i, j: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_r, block_c), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((pr, pc), jnp.float32),
        interpret=interpret,
    )(codes, syms, offs, dbase, codebooks)


def icq_dequant_v2(
    codes: jnp.ndarray,      # (d_out, Wc) uint32
    syms: jnp.ndarray,       # (d_out, SW) uint32
    offs: jnp.ndarray,       # (d_out, T+1) uint16
    dbase: jnp.ndarray,      # (d_out, T) uint8/uint16
    codebooks: jnp.ndarray,  # (d_out, 2^(n+1))
    *,
    n_bits: int,
    b: int,
    d_in: int,
    tile: int,
    block_r: int = 256,
    interpret: Optional[bool] = None,
    onehot: Optional[str] = None,
) -> jnp.ndarray:
    """Pad-on-the-fly v2 wrapper -> (d_out, d_in) f32 reconstruction."""
    if interpret is None:
        interpret = default_interpret()
    if onehot is None:
        onehot = default_onehot_dtype()
    d_out = codes.shape[0]
    k = 32 // n_bits
    T = offs.shape[1] - 1
    pc = T * tile
    br = min(block_r, _round_up(d_out, 8))
    pr = _round_up(d_out, br)
    out = dequant_padded_v2(
        _pad2(codes, pr, pc // k),
        _pad2(syms, pr, syms.shape[1]),
        _pad2(offs, pr, offs.shape[1]),
        _pad2(dbase, pr, dbase.shape[1]),
        _pad2(codebooks, pr, codebooks.shape[1]),
        n_bits=n_bits, b=b, block_r=br, interpret=interpret, onehot=onehot,
    )
    return out[:d_out, :d_in]


def snap_block_k(d_in: int, lcm: int, block_k: int) -> int:
    """Largest lcm-multiple <= block_k that divides round_up(d_in, lcm).

    Dividing the minimal padded width (instead of rounding the padded
    width up to the block) keeps K padding at < lcm columns — naive
    snapping cost ~17% extra HBM traffic for n_bits=3 geometries."""
    q = _round_up(d_in, lcm) // lcm
    t_req = min(max(1, block_k // lcm), q)
    t = max(d for d in range(1, t_req + 1) if q % d == 0)
    return lcm * t


def column_granularity(n_bits: int, fmt: str = "v1") -> int:
    """Smallest legal column-block unit: code words and (v1 only) bitmap
    words must block on the same column tiles. v2 has no bitmap, so only
    the k = 32//n code-packing granularity binds — for n=3 (k=10) that
    drops the unit from lcm(10, 32)=160 to 10 and lets the checkpoint
    tile stay large (checkpoint cost scales as 1/tile)."""
    k = 32 // n_bits
    return k if fmt == "v2" else (k * 32) // _gcd(k, 32)


def dequant_blocks(d_out: int, d_in: int, n_bits: int,
                   block_r: int, block_c: int, fmt: str = "v1"):
    """Snap requested blocks to the packing granularities -> (br, bc)."""
    lcm = column_granularity(n_bits, fmt)
    br = min(block_r, _round_up(d_out, 8))
    return br, snap_block_k(d_in, lcm, block_c)


def icq_dequant(
    codes: jnp.ndarray,      # (d_out, Wc) uint32
    bitmap: jnp.ndarray,     # (d_out, Wb) uint32
    codebooks: jnp.ndarray,  # (d_out, 2^(n+1)) f32
    *,
    n_bits: int,
    d_in: int,
    block_r: int = 256,
    block_c: int = 512,
    interpret: Optional[bool] = None,
    onehot: Optional[str] = None,
) -> jnp.ndarray:
    """Pad-on-the-fly wrapper -> (d_out, d_in) f32 reconstruction."""
    if interpret is None:
        interpret = default_interpret()
    if onehot is None:
        onehot = default_onehot_dtype()
    d_out = codes.shape[0]
    k = 32 // n_bits
    br, bc = dequant_blocks(d_out, d_in, n_bits, block_r, block_c)
    pc = _round_up(d_in, bc)
    pr = _round_up(d_out, br)
    codes_p = _pad2(codes, pr, pc // k)
    bitmap_p = _pad2(bitmap, pr, pc // 32)
    cb_p = _pad2(codebooks, pr, codebooks.shape[1])
    out = dequant_padded(
        codes_p, bitmap_p, cb_p,
        n_bits=n_bits, block_r=br, block_c=bc, interpret=interpret,
        onehot=onehot,
    )
    return out[:d_out, :d_in]


def _gcd(a: int, b: int) -> int:
    while b:
        a, b = b, a % b
    return a


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


def _pad2(x, r, c):
    return jnp.pad(x, ((0, r - x.shape[0]), (0, c - x.shape[1])))
