"""Pallas TPU kernel: ICQuant tile dequantization.

HBM->VMEM traffic per output tile is n/16 + 1/16th of the bf16 baseline
(packed codes + 1-bit selector bitmap + one codebook row pair); the
unpack is shift/mask on the VPU and the codebook lookup is a one-hot
``dot_general`` over the C <= 2^(n+1) levels — a (BR, BC, C) x (BR, C)
batched contraction that rides the MXU instead of C serial VPU selects.

Block layout: grid (d_out/BR, d_in/BC); code words and bitmap words are
blocked along the same column tiles (BC is a multiple of lcm(k, 32)).

Two entry points:
  * ``dequant_padded`` — the hot-path core. Inputs must already be
    padded/blocked (see kernels/backend.py ``prepare``); no per-call
    reshape or ``jnp.pad`` happens here.
  * ``icq_dequant``   — convenience wrapper that pads on the fly
    (benchmarks, tests, one-off calls).

``interpret=None`` resolves via kernels.platform: compiled on TPU,
interpreter everywhere else.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.platform import default_interpret


def _unpack_block(words: jnp.ndarray, n_bits: int, out_cols: int) -> jnp.ndarray:
    """(BR, W) uint32 -> (BR, out_cols) int32 of n-bit fields."""
    k = 32 // n_bits
    mask = jnp.uint32((1 << n_bits) - 1)
    shifts = (jnp.arange(k, dtype=jnp.uint32) * n_bits)[None, None, :]
    fields = (words[:, :, None] >> shifts) & mask
    return fields.reshape(words.shape[0], -1)[:, :out_cols].astype(jnp.int32)


def _codebook_select(idx: jnp.ndarray, codebooks: jnp.ndarray) -> jnp.ndarray:
    """idx: (BR, BC) int32 in [0, C); codebooks: (BR, C) -> (BR, BC) f32.

    One-hot gather as a single batched dot_general (batch dim = row):
    the (BR, BC, C) one-hot contracts against the row codebook on the
    MXU in one shot, instead of the C-step unrolled where-select chain
    the VPU had to chew through serially.
    """
    C = codebooks.shape[-1]
    iota = jax.lax.broadcasted_iota(jnp.int32, (1, 1, C), 2)
    onehot = (idx[:, :, None] == iota).astype(jnp.float32)
    return jax.lax.dot_general(
        onehot, codebooks.astype(jnp.float32),
        (((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32,
    )


def _dequant_kernel(codes_ref, bitmap_ref, cb_ref, out_ref, *, n_bits: int):
    BC = out_ref.shape[-1]
    codes = _unpack_block(codes_ref[...], n_bits, BC)
    sel = _unpack_block(bitmap_ref[...], 1, BC)
    idx = sel * (1 << n_bits) + codes
    out_ref[...] = _codebook_select(idx, cb_ref[...])


@functools.partial(
    jax.jit, static_argnames=("n_bits", "block_r", "block_c", "interpret")
)
def dequant_padded(
    codes: jnp.ndarray,      # (pr, pc // k) uint32, pr % block_r == 0
    bitmap: jnp.ndarray,     # (pr, pc // 32) uint32
    codebooks: jnp.ndarray,  # (pr, C) f32
    *,
    n_bits: int,
    block_r: int,
    block_c: int,
    interpret: bool,
) -> jnp.ndarray:
    """Core kernel over pre-blocked inputs -> (pr, pc) f32 (still padded)."""
    k = 32 // n_bits
    pr, pc = codes.shape[0], codes.shape[1] * k
    grid = (pr // block_r, pc // block_c)
    C = codebooks.shape[1]
    return pl.pallas_call(
        functools.partial(_dequant_kernel, n_bits=n_bits),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_r, block_c // k), lambda i, j: (i, j)),
            pl.BlockSpec((block_r, block_c // 32), lambda i, j: (i, j)),
            pl.BlockSpec((block_r, C), lambda i, j: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_r, block_c), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((pr, pc), jnp.float32),
        interpret=interpret,
    )(codes, bitmap, codebooks)


def snap_block_k(d_in: int, lcm: int, block_k: int) -> int:
    """Largest lcm-multiple <= block_k that divides round_up(d_in, lcm).

    Dividing the minimal padded width (instead of rounding the padded
    width up to the block) keeps K padding at < lcm columns — naive
    snapping cost ~17% extra HBM traffic for n_bits=3 geometries."""
    q = _round_up(d_in, lcm) // lcm
    t_req = min(max(1, block_k // lcm), q)
    t = max(d for d in range(1, t_req + 1) if q % d == 0)
    return lcm * t


def dequant_blocks(d_out: int, d_in: int, n_bits: int,
                   block_r: int, block_c: int):
    """Snap requested blocks to the packing granularities -> (br, bc)."""
    k = 32 // n_bits
    lcm = (k * 32) // _gcd(k, 32)
    br = min(block_r, _round_up(d_out, 8))
    return br, snap_block_k(d_in, lcm, block_c)


def icq_dequant(
    codes: jnp.ndarray,      # (d_out, Wc) uint32
    bitmap: jnp.ndarray,     # (d_out, Wb) uint32
    codebooks: jnp.ndarray,  # (d_out, 2^(n+1)) f32
    *,
    n_bits: int,
    d_in: int,
    block_r: int = 256,
    block_c: int = 512,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    """Pad-on-the-fly wrapper -> (d_out, d_in) f32 reconstruction."""
    if interpret is None:
        interpret = default_interpret()
    d_out = codes.shape[0]
    k = 32 // n_bits
    br, bc = dequant_blocks(d_out, d_in, n_bits, block_r, block_c)
    pc = _round_up(d_in, bc)
    pr = _round_up(d_out, br)
    codes_p = _pad2(codes, pr, pc // k)
    bitmap_p = _pad2(bitmap, pr, pc // 32)
    cb_p = _pad2(codebooks, pr, codebooks.shape[1])
    out = dequant_padded(
        codes_p, bitmap_p, cb_p,
        n_bits=n_bits, block_r=br, block_c=bc, interpret=interpret,
    )
    return out[:d_out, :d_in]


def _gcd(a: int, b: int) -> int:
    while b:
        a, b = b, a % b
    return a


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


def _pad2(x, r, c):
    return jnp.pad(x, ((0, r - x.shape[0]), (0, c - x.shape[1])))
