"""Pallas TPU kernels + the kernel-backed execution layer.

  icq_dequant.py  — tile dequantization (one-hot dot_general codebook
                    lookup; `dequant_padded` hot-path core)
  icq_matmul.py   — fused dequantize+matmul (`matmul_padded` core)
  paged_attention.py — S=1 decode attention over the paged KV block
                    pool (in-kernel page-table walk, online softmax;
                    streams only live blocks through VMEM)
  kmeans_assign.py— weighted-Lloyd accumulation (calibration hot loop)
  ref.py          — pure-jnp oracles (ground truth in tests)
  ops.py          — jit'd public wrappers + runtime-format conversion
  backend.py      — prepared layouts + per-call dispatch (the path every
                    model matmul takes for ICQ weights)
  autotune.py     — block-size sweeps, JSON-cached winners
  platform.py     — TPU/CPU detection, interpret/backend defaults
"""
