"""Jit'd public wrappers around the Pallas kernels + format conversion.

``to_runtime(packed, fmt=...)`` converts an ICQPacked (storage format:
n-bit codes + ~0.31 b/w gap stream) into a kernel runtime dict. Two
formats exist; ``runtime_bits_per_weight`` charges every tensor at its
true stored width (dtype itemsize), so the numbers below are honest HBM
residency:

  ============  =========================  =======================
  component     v1 (dense bitmap)          v2 (checkpointed stream)
  ============  =========================  =======================
  codes         n bits                     n bits
  selector      ~1.0 (1-bit bitmap)        ~0.33-0.38 (b-bit symbols,
                                           word/row padded)
  checkpoints   —                          ~24/tile (u16 offset +
                                           u8 base delta per tile)
  codebooks     2^(n+1) * 32 / d_in        same (16 with bf16 option)
  ============  =========================  =======================

i.e. v2 serves at ~0.40-0.45 b/w of outlier overhead vs ~1.0 for v1 —
the paper's index-coding saving carried through to the serving path
instead of being given back at load time. The expansion happens once at
model-load time; see kernels/backend.py for the prepared (pre-padded,
pre-blocked) layout the execution layer serves from, and
``ICQ_RUNTIME_FMT`` for the global format override.

``interpret`` defaults to None everywhere = platform-autodetected
(compiled on TPU, interpreter off-TPU; kernels/platform.py) — callers
never pass it explicitly anymore.
"""
from __future__ import annotations

import zlib
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import packing
from repro.core.icquant import ICQPacked
from repro.core.index_coding import decode_to_dense_mask, stream_checkpoints
from repro.kernels.backend import (
    ICQPrepared,
    WeightIntegrityError,
    dequantize_prepared,
    linear_apply,
    prepare,
    prepare_tree,
    verify_runtime_integrity,
)
from repro.kernels.icq_dequant import (
    _round_up,
    column_granularity,
    icq_dequant,
    icq_dequant_v2,
    snap_block_k,
)
from repro.kernels.icq_matmul import icq_matmul, icq_matmul_v2
from repro.kernels.kmeans_assign import kmeans_assign

_CB_DTYPES = {None: jnp.float32, "f32": jnp.float32, "bf16": jnp.bfloat16}


def to_runtime(packed: ICQPacked, fmt: str = "v1", *, tile: int = 512,
               codebook_dtype: Optional[str] = None) -> Dict:
    """ICQPacked (2-D only) -> kernel runtime tensors.

    fmt='v1': dense 1-bit selector bitmap (legacy bench/test format).
    fmt='v2': packed b-bit gap symbols + per-``tile`` checkpoints
              (``tile`` is snapped to the code-packing granularity and
              becomes the kernels' column block).
    """
    assert packed.codes.ndim == 2, "expand stacked weights per slice"
    if codebook_dtype not in _CB_DTYPES:
        raise ValueError(
            f"codebook_dtype must be 'f32' or 'bf16', got {codebook_dtype!r}")
    codebooks = packed.codebooks.reshape(packed.d_out, -1).astype(
        _CB_DTYPES[codebook_dtype])
    common = dict(codes=packed.codes, codebooks=codebooks,
                  n_bits=packed.n_bits, d_in=packed.d_in)
    if fmt == "v1":
        sel = decode_to_dense_mask(packed.stream).astype(jnp.uint32)
        return dict(common, fmt="v1", bitmap=packing.pack_codes(sel, 1))
    if fmt != "v2":
        raise ValueError(f"fmt must be 'v1' or 'v2', got {fmt!r}")
    tile = snap_block_k(packed.d_in, column_granularity(packed.n_bits, "v2"),
                        tile)
    pk = _round_up(packed.d_in, tile)
    sym_np = np.asarray(jax.device_get(packed.symbols))
    cnt_np = np.asarray(jax.device_get(packed.counts))
    offs, dbase = stream_checkpoints(sym_np, cnt_np, packed.b, tile, pk)
    words = packing.pack_symbols_np(sym_np, packed.b)
    # encode-time crc32 of each packed sidecar: verified by prepare()
    # (and verify_runtime_integrity) at every load boundary, so a
    # corrupted stream fails loudly instead of decoding outlier indices
    # into the wrong quantization groups.
    crc = {name: zlib.crc32(np.ascontiguousarray(a).tobytes()) & 0xFFFFFFFF
           for name, a in (("syms", words), ("offs", offs),
                           ("dbase", dbase))}
    return dict(
        common, fmt="v2",
        syms=jnp.asarray(words),
        offs=jnp.asarray(offs),
        dbase=jnp.asarray(dbase),
        b=packed.b,
        tile=tile,
        crc=crc,
    )


_TENSOR_KEYS = ("codes", "bitmap", "syms", "offs", "dbase", "codebooks")


def runtime_bits_per_weight(rt: Dict) -> float:
    """HBM bits per logical weight of a runtime dict.

    Every tensor is charged at its true stored width (dtype itemsize *
    8), so uint32 code/bitmap words, uint16/uint8 checkpoint sidecars
    and f32-vs-bf16 codebooks all bill honestly."""
    d_out = rt["codes"].shape[0]
    total = sum(
        rt[k].size * jnp.dtype(rt[k].dtype).itemsize * 8
        for k in _TENSOR_KEYS if rt.get(k) is not None
    )
    return total / (d_out * rt["d_in"])


def runtime_outlier_bits_per_weight(rt: Dict) -> float:
    """Bits/weight spent on outlier *selection* (bitmap, or stream +
    checkpoints) — the overhead the paper's ~0.3 b/w result bounds."""
    d_out = rt["codes"].shape[0]
    total = sum(
        rt[k].size * jnp.dtype(rt[k].dtype).itemsize * 8
        for k in ("bitmap", "syms", "offs", "dbase") if rt.get(k) is not None
    )
    return total / (d_out * rt["d_in"])


def _check_blocks(blocks: Dict, allowed: tuple, fmt: str) -> None:
    bad = set(blocks) - set(allowed)
    if bad:
        raise TypeError(
            f"block kwargs {sorted(bad)} do not apply to the {fmt} runtime "
            f"format (its column block is the checkpoint tile); "
            f"allowed: {sorted(allowed)}")


def dequant(rt: Dict, interpret: Optional[bool] = None, **blocks) -> jnp.ndarray:
    if rt.get("fmt", "v1") == "v2":
        _check_blocks(blocks, ("block_r", "onehot"), "v2")
        return icq_dequant_v2(
            rt["codes"], rt["syms"], rt["offs"], rt["dbase"], rt["codebooks"],
            n_bits=rt["n_bits"], b=rt["b"], d_in=rt["d_in"], tile=rt["tile"],
            interpret=interpret, **blocks,
        )
    return icq_dequant(
        rt["codes"], rt["bitmap"], rt["codebooks"],
        n_bits=rt["n_bits"], d_in=rt["d_in"], interpret=interpret, **blocks
    )


def matmul(x, rt: Dict, interpret: Optional[bool] = None, **blocks) -> jnp.ndarray:
    if rt.get("fmt", "v1") == "v2":
        _check_blocks(blocks, ("block_m", "block_n", "onehot", "accum"), "v2")
        return icq_matmul_v2(
            x, rt["codes"], rt["syms"], rt["offs"], rt["dbase"],
            rt["codebooks"],
            n_bits=rt["n_bits"], b=rt["b"], d_in=rt["d_in"], tile=rt["tile"],
            interpret=interpret, **blocks,
        )
    return icq_matmul(
        x, rt["codes"], rt["bitmap"], rt["codebooks"],
        n_bits=rt["n_bits"], d_in=rt["d_in"], interpret=interpret, **blocks
    )


__all__ = ["to_runtime", "runtime_bits_per_weight",
           "runtime_outlier_bits_per_weight", "dequant", "matmul",
           "kmeans_assign", "ICQPrepared", "prepare", "prepare_tree",
           "dequantize_prepared", "linear_apply",
           "WeightIntegrityError", "verify_runtime_integrity"]
