"""Jit'd public wrappers around the Pallas kernels + format conversion.

``to_runtime(packed)`` expands an ICQPacked (storage format: n-bit codes
+ ~0.31 b/w gap stream) into the kernel runtime format (codes + 1-bit
selector bitmap + flattened dual codebook). The expansion happens once at
model-load time; see EXPERIMENTS.md §Perf for the v2 checkpointed-stream
format that shrinks the runtime overlay back toward the storage size.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.core import packing
from repro.core.icquant import ICQPacked
from repro.core.index_coding import decode_to_dense_mask
from repro.kernels.icq_dequant import icq_dequant
from repro.kernels.icq_matmul import icq_matmul
from repro.kernels.kmeans_assign import kmeans_assign


def to_runtime(packed: ICQPacked) -> Dict[str, jnp.ndarray]:
    """ICQPacked (2-D only) -> kernel runtime tensors."""
    assert packed.codes.ndim == 2, "expand stacked weights per slice"
    sel = decode_to_dense_mask(packed.stream).astype(jnp.uint32)
    bitmap = packing.pack_codes(sel, 1)
    codebooks = packed.codebooks.reshape(packed.d_out, -1).astype(jnp.float32)
    return dict(
        codes=packed.codes,
        bitmap=bitmap,
        codebooks=codebooks,
        n_bits=packed.n_bits,
        d_in=packed.d_in,
    )


def runtime_bits_per_weight(rt: Dict) -> float:
    """HBM bits per logical weight of the runtime format."""
    d_out = rt["codes"].shape[0]
    total = (
        rt["codes"].size * 32 + rt["bitmap"].size * 32
        + rt["codebooks"].size * 16
    )
    return total / (d_out * rt["d_in"])


def dequant(rt: Dict, interpret: bool = True, **blocks) -> jnp.ndarray:
    return icq_dequant(
        rt["codes"], rt["bitmap"], rt["codebooks"],
        n_bits=rt["n_bits"], d_in=rt["d_in"], interpret=interpret, **blocks
    )


def matmul(x, rt: Dict, interpret: bool = True, **blocks) -> jnp.ndarray:
    return icq_matmul(
        x, rt["codes"], rt["bitmap"], rt["codebooks"],
        n_bits=rt["n_bits"], d_in=rt["d_in"], interpret=interpret, **blocks
    )


__all__ = ["to_runtime", "runtime_bits_per_weight", "dequant", "matmul",
           "kmeans_assign"]
