"""Jit'd public wrappers around the Pallas kernels + format conversion.

``to_runtime(packed)`` expands an ICQPacked (storage format: n-bit codes
+ ~0.31 b/w gap stream) into the kernel runtime format (codes + 1-bit
selector bitmap + flattened dual codebook). The expansion happens once at
model-load time; see kernels/backend.py for the prepared (pre-padded,
pre-blocked) layout the execution layer serves from.

``interpret`` defaults to None everywhere = platform-autodetected
(compiled on TPU, interpreter off-TPU; kernels/platform.py) — callers
never pass it explicitly anymore.
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.core import packing
from repro.core.icquant import ICQPacked
from repro.core.index_coding import decode_to_dense_mask
from repro.kernels.backend import (
    ICQPrepared,
    dequantize_prepared,
    linear_apply,
    prepare,
    prepare_tree,
)
from repro.kernels.icq_dequant import icq_dequant
from repro.kernels.icq_matmul import icq_matmul
from repro.kernels.kmeans_assign import kmeans_assign


def to_runtime(packed: ICQPacked) -> Dict[str, jnp.ndarray]:
    """ICQPacked (2-D only) -> kernel runtime tensors."""
    assert packed.codes.ndim == 2, "expand stacked weights per slice"
    sel = decode_to_dense_mask(packed.stream).astype(jnp.uint32)
    bitmap = packing.pack_codes(sel, 1)
    codebooks = packed.codebooks.reshape(packed.d_out, -1).astype(jnp.float32)
    return dict(
        codes=packed.codes,
        bitmap=bitmap,
        codebooks=codebooks,
        n_bits=packed.n_bits,
        d_in=packed.d_in,
    )


def runtime_bits_per_weight(rt: Dict) -> float:
    """HBM bits per logical weight of the runtime format.

    Codebook entries are charged at their true stored width (``to_runtime``
    casts codebooks to f32, i.e. 32 bits/entry — not the bf16 width of the
    storage format).
    """
    d_out = rt["codes"].shape[0]
    cb_bits = jnp.dtype(rt["codebooks"].dtype).itemsize * 8
    total = (
        rt["codes"].size * 32 + rt["bitmap"].size * 32
        + rt["codebooks"].size * cb_bits
    )
    return total / (d_out * rt["d_in"])


def dequant(rt: Dict, interpret: Optional[bool] = None, **blocks) -> jnp.ndarray:
    return icq_dequant(
        rt["codes"], rt["bitmap"], rt["codebooks"],
        n_bits=rt["n_bits"], d_in=rt["d_in"], interpret=interpret, **blocks
    )


def matmul(x, rt: Dict, interpret: Optional[bool] = None, **blocks) -> jnp.ndarray:
    return icq_matmul(
        x, rt["codes"], rt["bitmap"], rt["codebooks"],
        n_bits=rt["n_bits"], d_in=rt["d_in"], interpret=interpret, **blocks
    )


__all__ = ["to_runtime", "runtime_bits_per_weight", "dequant", "matmul",
           "kmeans_assign", "ICQPrepared", "prepare", "prepare_tree",
           "dequantize_prepared", "linear_apply"]
