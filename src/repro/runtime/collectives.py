"""Cross-pod gradient reduction with int8 compression.

The inter-pod hop is the thin link of the multi-pod mesh (DCN / long ICI),
so the gradient all-reduce is split hierarchically:

  intra-pod: native reduce-scatter/all-reduce (GSPMD-inserted, full bw)
  inter-pod: int8-quantized all-gather + local dequant-sum  (4x fewer
             bytes on the thin link than f32, 2x fewer than bf16)

Exposed as ``compressed_cross_pod_mean`` — a shard_map over the ``pod``
axis only (other mesh axes stay under automatic sharding propagation).
Error feedback (optim.compression.error_feedback_update) runs *before*
this reduction in the train step, keeping the quantization unbiased over
time.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.optim.compression import BLOCK


def _compress(x):
    flat = x.astype(jnp.float32).reshape(-1)
    pad = (-flat.size) % BLOCK
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=-1, keepdims=True) / 127.0
    q = jnp.round(blocks / jnp.maximum(scale, 1e-12)).astype(jnp.int8)
    return q, scale


def _leaf_mean(x, axis_name: str, n_pods: int):
    shape = x.shape
    q, s = _compress(x)
    qg = jax.lax.all_gather(q, axis_name)          # int8 on the wire
    sg = jax.lax.all_gather(s, axis_name)          # f32 scales (tiny)
    summed = (qg.astype(jnp.float32) * sg).sum(axis=0)
    flat = summed.reshape(-1)
    size = 1
    for d in shape:
        size *= d
    return (flat[:size].reshape(shape) / n_pods).astype(x.dtype)


def compressed_cross_pod_mean(tree: Any, mesh, axis_name: str = "pod") -> Any:
    """Mean-reduce a pytree across the pod axis with int8 on the wire.

    Must be called inside a computation already running under `mesh`;
    tensors keep their data/model shardings (auto axes)."""
    n_pods = dict(zip(mesh.axis_names, mesh.devices.shape))[axis_name]
    other = frozenset(a for a in mesh.axis_names if a != axis_name)

    P = jax.sharding.PartitionSpec
    if hasattr(jax, "shard_map"):
        # the gathered+summed result is replicated over `pod` by
        # construction; the static VMA checker can't prove it
        smap = functools.partial(
            jax.shard_map, mesh=mesh, in_specs=P(), out_specs=P(),
            axis_names={axis_name}, check_vma=False,
        )
    else:  # jax < 0.6: experimental spelling (auto axes / check_rep)
        from jax.experimental.shard_map import shard_map as _shard_map

        smap = functools.partial(
            _shard_map, mesh=mesh, in_specs=P(), out_specs=P(),
            auto=other, check_rep=False,
        )

    @smap
    def reduce_tree(t):
        return jax.tree.map(
            lambda x: _leaf_mean(x, axis_name, n_pods), t
        )

    return reduce_tree(tree)
