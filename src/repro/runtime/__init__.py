from repro.runtime.sharding import (
    batch_specs,
    fit_spec,
    param_specs,
)
from repro.runtime.straggler import StragglerMonitor

__all__ = ["param_specs", "batch_specs", "fit_spec", "StragglerMonitor"]
