"""Path-based sharding rules: param pytree -> PartitionSpec pytree.

Megatron-style tensor parallelism on the ``model`` axis (column-parallel
up-projections, row-parallel down-projections, head-sharded attention,
expert-parallel MoE) plus optional FSDP ("zero-3") sharding of the
leftover parameter dim over the ``data`` axis — required to fit the
largest assigned architectures' optimizer state.

Divisibility is enforced by ``fit_spec``: any rule whose dim is not
divisible by its mesh-axis size degrades to replication on that dim (this
absorbs odd vocab sizes like 73448 without special cases).
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# rule per leaf name: spec for the *last* ndim dims (left-padded with None)
_RULES = {
    # embeddings / heads
    "embed": ("model", "data"),
    "lm_head": ("data", "model"),
    # attention
    "wq": ("data", "model"),
    "wk": ("data", "model"),
    "wv": ("data", "model"),
    "wo": ("model", "data"),
    # MLA
    "w_dq": (None, None),
    "w_uq": ("data", "model"),
    "w_dkv": (None, None),
    "w_kr": (None, None),
    "w_uk": ("data", "model"),
    "w_uv": ("data", "model"),
    # MLP
    "w_gate": ("data", "model"),
    "w_up": ("data", "model"),
    "w_down": ("model", "data"),
    # SSM
    "in_proj": ("data", "model"),
    "out_proj": ("model", "data"),
    "conv_w": (None, "model"),
    "conv_b": ("model",),
    "A_log": (None,),
    "D": (None,),
    "dt_bias": (None,),
    # MoE router / MTP
    "router": (None, None),
    "mtp_proj": ("data", "model"),
}

# inside a "moe" subtree, expert weights carry a leading E dim
_MOE_RULES = {
    "w_gate": ("model", "data", None),
    "w_up": ("model", "data", None),
    "w_down": ("model", None, "data"),
}


def fit_spec(shape: Tuple[int, ...], spec: Tuple, mesh: Mesh) -> P:
    """Drop axis names whose size does not divide the dim (graceful
    degradation to replication)."""
    assert len(spec) == len(shape), (shape, spec)
    out = []
    for dim, ax in zip(shape, spec):
        if ax is None:
            out.append(None)
            continue
        axes = ax if isinstance(ax, tuple) else (ax,)
        size = 1
        for a in axes:
            size *= dict(zip(mesh.axis_names, mesh.devices.shape)).get(a, 1)
        out.append(ax if dim % size == 0 and size > 1 else None)
    return P(*out)


def _spec_for(path, leaf, mesh: Mesh, fsdp: bool) -> P:
    names = [getattr(k, "key", getattr(k, "name", str(k))) for k in path]
    name = names[-1] if names else ""
    # ICQuant-packed leaves (codes/symbols/counts/codebooks/bitmap under
    # the weight's name, or FlattenedIndexKey for legacy registration):
    # all packed tensors are per-output-channel -> shard the d_out dim the
    # way the dense rule sharded d_out
    _packed_fields = {"codes": 2, "symbols": 2, "bitmap": 2,
                      "counts": 1, "codebooks": 3}
    if isinstance(name, int) or name in _packed_fields:
        wname = next(
            (n for n in reversed(names[:-1])
             if isinstance(n, str) and n in _RULES), "")
        base = _RULES.get(wname)
        if base is None or leaf.ndim == 0:
            return P()
        out_ax = base[-1]                       # dense rule for d_out
        if not fsdp and out_ax == "data":
            out_ax = None
        if isinstance(name, int):
            trailing = {0: 2, 1: 2, 2: 1, 3: 3}[name]
        else:
            trailing = _packed_fields[name]
        if leaf.ndim < trailing:
            return P()
        if trailing == 1:                       # counts (..., d_out)
            rule = (None,) * (leaf.ndim - 1) + (out_ax,)
        elif trailing == 3:                     # codebooks (..., d_out, 2, C)
            rule = (None,) * (leaf.ndim - 3) + (out_ax, None, None)
        else:                                   # codes/symbols/bitmap
            rule = (None,) * (leaf.ndim - 2) + (out_ax, None)
        return fit_spec(leaf.shape, rule, mesh)
    in_moe = "moe" in names
    rules = _MOE_RULES if (in_moe and name in _MOE_RULES) else _RULES
    rule = rules.get(name)
    if rule is None or leaf.ndim == 0:
        return P()
    if leaf.ndim < len(rule):
        rule = rule[-leaf.ndim:]
    # left-pad for layer stacking
    rule = (None,) * (leaf.ndim - len(rule)) + tuple(rule)
    if not fsdp:  # strip the FSDP ("data") placements, keep TP only
        rule = tuple(None if ax == "data" else ax for ax in rule)
    return fit_spec(leaf.shape, rule, mesh)


def param_specs(params: Any, mesh: Mesh, fsdp: bool = False) -> Any:
    """PartitionSpec pytree matching `params`."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    specs = [_spec_for(path, leaf, mesh, fsdp) for path, leaf in flat]
    return jax.tree.unflatten(treedef, specs)


def param_shardings(params: Any, mesh: Mesh, fsdp: bool = False) -> Any:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), param_specs(params, mesh, fsdp)
    )


def batch_specs(batch: Any, mesh: Mesh) -> Any:
    """Shard the batch dim over all data-like axes present in the mesh."""
    data_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    ax = data_axes if len(data_axes) > 1 else (data_axes[0] if data_axes else None)

    def one(leaf):
        if getattr(leaf, "ndim", 0) == 0:
            return P()
        return fit_spec(leaf.shape, (ax,) + (None,) * (leaf.ndim - 1), mesh)

    return jax.tree.map(one, batch)


def cache_specs(cache: Any, mesh: Mesh) -> Any:
    """KV caches: batch over data axes, head/state dims over model where
    divisible. Heuristic: dim 0 = batch (data), dim -2 = heads (model)
    for 4D cache tensors; SSM states (b, h, p, n): h over model."""
    data_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    ax = data_axes if len(data_axes) > 1 else (data_axes[0] if data_axes else None)

    def one(path, leaf):
        nd = getattr(leaf, "ndim", 0)
        if nd == 0:
            return P()
        names = [getattr(k, "key", getattr(k, "name", str(k))) for k in path]
        name = names[-1]
        # stacked leading layer axis present: treat dims shifted by 1
        if name in ("k", "v"):           # (L, B, T, H, hd)
            spec = (None, ax, None, "model", None)[-nd:]
        elif name == "ssm":              # (L, B, h, p, n)
            spec = (None, ax, "model", None, None)[-nd:]
        elif name == "c_kv":             # (L, B, T, r)
            spec = (None, ax, None, None)[-nd:]
        elif name == "k_rope":
            spec = (None, ax, None, None)[-nd:]
        elif name == "conv":             # (L, B, K-1, convdim)
            spec = (None, ax, None, "model")[-nd:]
        elif name == "pos":
            spec = (None,) * nd
        else:                            # index counters etc.
            spec = (None,) * nd
        return fit_spec(leaf.shape, tuple(spec), mesh)

    flat, treedef = jax.tree_util.tree_flatten_with_path(cache)
    return jax.tree.unflatten(treedef, [one(p, l) for p, l in flat])
