"""Elastic re-meshing: rebuild a production mesh after host loss.

Recovery protocol (driver loop in ``launch/train.py``):
  1. straggler/failure detected -> evict host(s);
  2. ``shrink_mesh`` picks the largest (data' x model) grid that fits the
     surviving device count, preferring to shrink the data axis (so TP
     groups — which hold *shards of single tensors* — stay intact);
  3. params/optimizer are restored from the latest checkpoint with the
     new mesh's shardings (``CheckpointManager.restore(sharding_fn=...)``),
  4. the data pipeline needs no state: batches are a pure function of
     (seed, step, shard) and shard indices are re-assigned densely.
"""
from __future__ import annotations

from typing import List, Sequence, Tuple

import jax
from jax.sharding import Mesh


def shrink_mesh_shape(
    n_devices: int, model_parallel: int
) -> Tuple[int, int]:
    """Largest (data, model) grid with the TP degree preserved."""
    if n_devices < model_parallel:
        raise ValueError(
            f"cannot preserve TP={model_parallel} with {n_devices} devices"
        )
    return (n_devices // model_parallel, model_parallel)


def rebuild_mesh(
    devices: Sequence, model_parallel: int, axis_names=("data", "model")
) -> Mesh:
    data, model = shrink_mesh_shape(len(devices), model_parallel)
    import numpy as np

    grid = np.asarray(devices)[: data * model].reshape(data, model)
    return Mesh(grid, axis_names)


def reassign_shards(
    old_shards: List[int], failed_hosts: List[int], n_hosts_new: int
) -> List[int]:
    """Dense re-assignment of data-pipeline shard ids after eviction."""
    return list(range(n_hosts_new))
