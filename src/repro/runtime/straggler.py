"""Straggler detection for the training driver.

Tracks per-host step times with an EWMA and flags hosts whose latency
exceeds ``threshold x`` the fleet median. On a real cluster the flagged
host set feeds the coordinator's eviction/re-mesh decision (see
``runtime.elastic``); in single-process runs it is exercised by tests
with synthetic timings.
"""
from __future__ import annotations

from typing import Dict, List


class StragglerMonitor:
    def __init__(self, n_hosts: int, alpha: float = 0.2, threshold: float = 2.0,
                 warmup: int = 3):
        self.n_hosts = n_hosts
        self.alpha = alpha
        self.threshold = threshold
        self.warmup = warmup
        self._ewma: Dict[int, float] = {}
        self._counts: Dict[int, int] = {}

    def ewma(self, host: int):
        """Current EWMA step time for ``host`` (None before any record).
        Public accessor so the serving step-time watchdog
        (serving/metrics.py) can reuse this module's smoothing instead
        of duplicating it."""
        return self._ewma.get(host)

    def count(self, host: int) -> int:
        """Recorded samples for ``host`` (warmup gating)."""
        return self._counts.get(host, 0)

    def record(self, host: int, step_time_s: float) -> None:
        prev = self._ewma.get(host)
        self._ewma[host] = (
            step_time_s if prev is None
            else self.alpha * step_time_s + (1 - self.alpha) * prev
        )
        self._counts[host] = self._counts.get(host, 0) + 1

    def median(self) -> float:
        vals = sorted(self._ewma.values())
        if not vals:
            return 0.0
        mid = len(vals) // 2
        return (
            vals[mid] if len(vals) % 2
            else 0.5 * (vals[mid - 1] + vals[mid])
        )

    def stragglers(self) -> List[int]:
        med = self.median()
        if med <= 0:
            return []
        return sorted(
            h for h, t in self._ewma.items()
            if self._counts.get(h, 0) >= self.warmup and t > self.threshold * med
        )
