"""Sharding-aware checkpointing with atomic steps and elastic restore.

Layout:  <root>/step_<k>/
             manifest.json      — flat path -> {shape, dtype, spec}
             <idx>.npy          — one file per leaf

Properties needed at 1000+-node scale and honored by the design:
  * atomicity: a step directory is written under ``.tmp`` and renamed —
    a crash mid-save never corrupts the latest checkpoint. A terminal
    ``MANIFEST-complete`` marker (the last file written before the
    rename) additionally guards against *torn copies*: a step dir
    rsynced or restored halfway has no marker, so ``latest_step()``
    skips it and ``restore()`` refuses it with a ``CheckpointError``
    naming the directory, instead of crashing on a missing leaf file or
    silently loading stale arrays;
  * restart: ``latest_step()`` + ``restore()`` resume training loops;
  * elasticity: arrays are stored with their *global* shape and their
    PartitionSpec recorded; ``restore(..., sharding_fn)`` re-shards to an
    arbitrary (possibly different-size) mesh via ``jax.device_put``;
  * retention: ``keep`` bounds disk usage.

On a production cluster each host writes only its addressable shards
(manifest records per-shard index maps); in this single-process container
leaves are gathered and written whole — the manifest schema carries the
``spec`` either way.
"""
from __future__ import annotations

import json
import os
import shutil
from typing import Any, Callable, Optional

import jax
import numpy as np

#: terminal marker file: present <=> every leaf + manifest was written
_COMPLETE = "MANIFEST-complete"


class CheckpointError(FileNotFoundError):
    """A checkpoint step directory is missing or partial (no terminal
    ``MANIFEST-complete`` marker, or a leaf file absent). Subclasses
    FileNotFoundError so callers treating 'no restorable checkpoint' as
    a not-found condition keep working."""


def _flatten(tree: Any):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


class CheckpointManager:
    def __init__(self, root: str, keep: int = 3):
        self.root = root
        self.keep = keep
        os.makedirs(root, exist_ok=True)

    # ------------------------------------------------------------------
    def _step_dir(self, step: int) -> str:
        return os.path.join(self.root, f"step_{step:08d}")

    def _is_complete(self, d: str) -> bool:
        return os.path.exists(os.path.join(d, _COMPLETE))

    def latest_step(self) -> Optional[int]:
        """Newest step with a *complete* save — ``.tmp`` dirs and step
        dirs missing the terminal marker (torn copies, pre-marker saves)
        are never selected."""
        steps = [
            int(d.split("_")[1])
            for d in os.listdir(self.root)
            if d.startswith("step_") and not d.endswith(".tmp")
            and self._is_complete(os.path.join(self.root, d))
        ]
        return max(steps) if steps else None

    # ------------------------------------------------------------------
    def save(self, step: int, tree: Any, specs: Any = None) -> str:
        leaves, treedef = _flatten(tree)
        spec_leaves = (
            jax.tree.leaves(specs, is_leaf=lambda x: x is None or not isinstance(x, (list, dict)))
            if specs is not None else [None] * len(leaves)
        )
        final = self._step_dir(step)
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        manifest = {"step": step, "treedef": str(treedef), "leaves": []}
        for i, leaf in enumerate(leaves):
            arr = np.asarray(jax.device_get(leaf))
            true_dtype = str(arr.dtype)
            if arr.dtype.kind not in "biufc":  # bf16 etc: store widened
                arr = arr.astype(np.float32)
            np.save(os.path.join(tmp, f"{i}.npy"), arr)
            manifest["leaves"].append(
                dict(
                    index=i,
                    shape=list(arr.shape),
                    dtype=true_dtype,
                    spec=str(spec_leaves[i]) if i < len(spec_leaves) else None,
                )
            )
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        # terminal marker: strictly the last file written, so its
        # presence certifies every leaf + the manifest landed
        with open(os.path.join(tmp, _COMPLETE), "w") as f:
            f.write(f"step {step}: {len(leaves)} leaves\n")
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._gc()
        return final

    def _gc(self):
        # retention counts *complete* saves only: a partial dir must
        # neither crowd out a good checkpoint nor be silently deleted
        steps = sorted(
            int(d.split("_")[1])
            for d in os.listdir(self.root)
            if d.startswith("step_") and not d.endswith(".tmp")
            and self._is_complete(os.path.join(self.root, d))
        )
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)

    # ------------------------------------------------------------------
    def restore(
        self,
        like: Any,
        step: Optional[int] = None,
        sharding_fn: Optional[Callable[[int], Any]] = None,
    ) -> Any:
        """Restore into the structure of `like`. ``sharding_fn(leaf_idx)``
        may return a Sharding to place each leaf on a (new) mesh —
        the elastic-restore path."""
        if step is None:
            step = self.latest_step()
            if step is None:
                raise CheckpointError(
                    f"no complete checkpoints under {self.root}")
        d = self._step_dir(step)
        if not os.path.isdir(d):
            raise CheckpointError(
                f"checkpoint step {step} has no directory at {d}")
        if not self._is_complete(d):
            raise CheckpointError(
                f"checkpoint at {d} is partial (no {_COMPLETE} marker — "
                f"interrupted save or torn copy); refusing to load it")
        leaves, treedef = _flatten(like)
        out = []
        for i, leaf in enumerate(leaves):
            path = os.path.join(d, f"{i}.npy")
            if not os.path.exists(path):
                raise CheckpointError(
                    f"checkpoint at {d} is missing leaf file {i}.npy "
                    f"({len(leaves)} leaves expected)")
            arr = np.load(path)
            if hasattr(leaf, "dtype"):
                arr = arr.astype(leaf.dtype)
            if sharding_fn is not None:
                out.append(jax.device_put(arr, sharding_fn(i)))
            else:
                out.append(jax.numpy.asarray(arr))
        return jax.tree.unflatten(treedef, out)
