"""Bit-packing of n-bit codes into uint32 words.

Layout: k = 32 // n codes per word, code j of a word occupying bits
[j*n, (j+1)*n). Rows are padded to a multiple of k with zeros. The
layout is little-endian-in-word so the Pallas kernels unpack with plain
shift/mask on the VPU.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def codes_per_word(n_bits: int) -> int:
    if not 1 <= n_bits <= 16:
        raise ValueError(f"n_bits must be in [1, 16], got {n_bits}")
    return 32 // n_bits


def packed_width(length: int, n_bits: int) -> int:
    k = codes_per_word(n_bits)
    return -(-length // k)


def pack_codes(codes: jnp.ndarray, n_bits: int) -> jnp.ndarray:
    """Pack (..., L) integer codes in [0, 2^n) into (..., ceil(L/k)) uint32."""
    k = codes_per_word(n_bits)
    L = codes.shape[-1]
    pad = (-L) % k
    codes = jnp.asarray(codes, dtype=jnp.uint32)
    if pad:
        codes = jnp.pad(codes, [(0, 0)] * (codes.ndim - 1) + [(0, pad)])
    grouped = codes.reshape(codes.shape[:-1] + (-1, k))
    shifts = jnp.arange(k, dtype=jnp.uint32) * n_bits
    # disjoint bit ranges: sum == bitwise or
    return (grouped << shifts).sum(axis=-1).astype(jnp.uint32)


def unpack_codes(words: jnp.ndarray, n_bits: int, length: int) -> jnp.ndarray:
    """Unpack uint32 words back to (..., length) uint32 codes."""
    k = codes_per_word(n_bits)
    mask = jnp.uint32((1 << n_bits) - 1)
    shifts = jnp.arange(k, dtype=jnp.uint32) * n_bits
    expanded = (words[..., None] >> shifts) & mask
    flat = expanded.reshape(words.shape[:-1] + (-1,))
    return flat[..., :length]


def pack_symbols_np(symbols: np.ndarray, b: int) -> np.ndarray:
    """Pack (rows, s_max) b-bit gap symbols into uint32 words (v2 runtime).

    Same little-endian-in-word field layout as ``pack_codes`` (the kernels
    unpack both with one shift/mask helper); symbols are stored value-1 so
    they fit exactly b bits. Rows with no symbols still get one zero word
    so downstream block shapes never collapse to width 0.
    """
    symbols = np.asarray(symbols)
    if symbols.shape[-1] == 0:
        return np.zeros(symbols.shape[:-1] + (1,), dtype=np.uint32)
    return pack_codes_np(symbols.astype(np.uint32), b)


def symbol_cols(words_width: int, b: int) -> int:
    """Unpacked column count of a packed symbol tensor of given width."""
    return words_width * codes_per_word(b)


def pack_codes_np(codes: np.ndarray, n_bits: int) -> np.ndarray:
    """Host-side numpy packer (pack time)."""
    k = codes_per_word(n_bits)
    L = codes.shape[-1]
    pad = (-L) % k
    codes = np.asarray(codes, dtype=np.uint32)
    if pad:
        codes = np.pad(codes, [(0, 0)] * (codes.ndim - 1) + [(0, pad)])
    grouped = codes.reshape(codes.shape[:-1] + (-1, k))
    shifts = (np.arange(k, dtype=np.uint32) * n_bits)
    return np.bitwise_or.reduce(grouped << shifts, axis=-1).astype(np.uint32)
