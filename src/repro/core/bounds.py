"""Lemma 1: storage-overhead bound for the gap index coding scheme.

E(B) <= gamma * b * (1 + 1 / (exp(gamma * (2^b - 1)) - 1))   [bits/weight]

where gamma is the outlier ratio and b the bits per stored gap symbol.
"""
from __future__ import annotations

import math


def lemma1_bound(gamma: float, b: int) -> float:
    """Upper bound on expected index-coding overhead in bits per weight."""
    if not (0.0 < gamma < 1.0):
        raise ValueError(f"gamma must be in (0, 1), got {gamma}")
    if b < 1:
        raise ValueError(f"b must be >= 1, got {b}")
    m = float(2**b - 1)
    x = gamma * m
    if x > 700.0:  # e^x overflows f64; the correction term is ~0
        return gamma * b
    denom = math.expm1(x)  # e^{gamma m} - 1, stable for small args
    return gamma * b * (1.0 + 1.0 / denom)


def flag_overhead_fraction(gamma: float, b: int) -> float:
    """Expected fraction of symbols that are escape flags (bound)."""
    m = float(2**b - 1)
    x = gamma * m
    if x > 700.0:
        return 0.0
    return 1.0 / math.expm1(x)


def optimal_b(gamma: float, b_max: int = 16) -> int:
    """The symbol width minimizing the Lemma-1 bound for a given ratio."""
    return min(range(1, b_max + 1), key=lambda b: lemma1_bound(gamma, b))


def naive_flag_bits() -> float:
    """Binary-flag baseline: 1 bit per weight."""
    return 1.0


def raw_index_bits(gamma: float, d_in: int) -> float:
    """Raw absolute-index baseline: ceil(log2(d_in)) bits per outlier."""
    return gamma * math.ceil(math.log2(max(d_in, 2)))
