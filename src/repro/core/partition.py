"""Outlier/inlier partition: top-gamma weights by magnitude per row."""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np


def num_outliers(d_in: int, gamma: float) -> int:
    return int(np.floor(gamma * d_in))


def outlier_positions(W: jnp.ndarray, gamma: float) -> np.ndarray:
    """Sorted 0-based outlier positions per row, exactly p = floor(gamma*d)
    each (ties broken by column order, deterministically)."""
    W = np.asarray(jax.device_get(W))
    d_in = W.shape[-1]
    p = num_outliers(d_in, gamma)
    if p == 0:
        return np.zeros((W.shape[0], 0), dtype=np.int64)
    mag = np.abs(W)
    # argpartition gives exactly p per row regardless of ties
    top = np.argpartition(mag, d_in - p, axis=-1)[..., d_in - p:]
    return np.sort(top, axis=-1)


def outlier_mask(W: jnp.ndarray, gamma: float) -> jnp.ndarray:
    """Dense boolean mask of per-row top-gamma |w| (jit-friendly)."""
    d_in = W.shape[-1]
    p = num_outliers(d_in, gamma)
    if p == 0:
        return jnp.zeros(W.shape, dtype=bool)
    mag = jnp.abs(W)
    # threshold = p-th largest magnitude per row
    kth = jax.lax.top_k(mag, p)[0][..., -1:]
    mask = mag >= kth
    # Resolve ties so each row has exactly p outliers: keep the first p.
    over = jnp.cumsum(mask.astype(jnp.int32), axis=-1)
    return mask & (over <= p)


def partition_stats(W: jnp.ndarray, gamma: float) -> Tuple[float, float]:
    """(mean fraction of range occupied by outliers, mean inlier range /
    full range) across rows — the paper's Figure 1 quantity."""
    mask = outlier_mask(W, gamma)
    full = W.max(axis=-1) - W.min(axis=-1)
    big = jnp.finfo(W.dtype).max
    inl_max = jnp.where(mask, -big, W).max(axis=-1)
    inl_min = jnp.where(mask, big, W).min(axis=-1)
    inlier = inl_max - inl_min
    frac = 1.0 - inlier / jnp.maximum(full, 1e-12)
    return float(frac.mean()), float((inlier / jnp.maximum(full, 1e-12)).mean())
