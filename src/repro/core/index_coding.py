"""Gap-based outlier index coding (the paper's Section 3.2).

Scheme
------
Per row, outlier positions i_1 < ... < i_p (0-based here; the paper is
1-based) are stored as gaps

    x_0 = i_1 + 1,   x_k = i_{k+1} - i_k          (all gaps >= 1)

Each gap is emitted as b-bit symbols with values in [1, 2^b]:

  * gap <= 2^b - 1           -> one symbol holding the gap,
  * gap  > 2^b - 1           -> n_flag = (gap - 1) // (2^b - 1) escape
                                symbols of value 2^b (each meaning
                                "accumulate 2^b - 1 positions, no
                                outlier"), then the remainder
                                r = gap - n_flag*(2^b - 1) in [1, 2^b - 1].

(The paper stores ``gap mod (2^b - 1)``; we use the remainder-in-[1, m]
convention, which resolves the gap ≡ 0 (mod 2^b - 1) corner case while
keeping identical costs elsewhere.)

Decoding is a prefix sum, TPU-friendly: each symbol s contributes an
increment (2^b - 1 if s == 2^b else s) and emits an outlier iff s < 2^b.
Absolute 0-based positions are cumsum(increments) - 1 at emitting symbols.

Symbols are stored value-1 (i.e. in [0, 2^b - 1]) so they fit exactly b
bits; the escape flag is the all-ones pattern.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np


class GapStream(NamedTuple):
    """Padded per-row gap-symbol streams.

    symbols: (rows, s_max) uint16, raw stored symbols in [0, 2^b - 1]
             (value-1 encoding; 2^b - 1 is the escape flag). Padding
             positions hold the escape flag so a mask-free cumsum decode
             never emits phantom outliers.
    counts:  (rows,) int32, number of real symbols per row.
    b:       symbol width in bits.
    d_in:    row length the positions index into.
    """

    symbols: jnp.ndarray
    counts: jnp.ndarray
    b: int
    d_in: int

    @property
    def flag(self) -> int:
        return (1 << self.b) - 1  # stored (value-1) escape pattern

    def storage_bits_per_weight(self) -> float:
        """Effective overhead B: real symbols * b / (rows * d_in)."""
        total = float(np.asarray(jax.device_get(self.counts)).sum()) * self.b
        rows = int(self.symbols.shape[0])
        return total / (rows * self.d_in)


def encode_positions(positions: np.ndarray, d_in: int, b: int) -> GapStream:
    """Encode sorted 0-based outlier positions into gap streams.

    positions: (rows, p) int array, each row strictly increasing, in
               [0, d_in). Runs host-side (pack time), vectorized numpy.
    """
    positions = np.asarray(positions, dtype=np.int64)
    if positions.ndim == 1:
        positions = positions[None, :]
    rows, p = positions.shape
    if p == 0:
        return GapStream(
            symbols=jnp.zeros((rows, 0), dtype=jnp.uint16),
            counts=jnp.zeros((rows,), dtype=jnp.int32),
            b=b,
            d_in=d_in,
        )
    if positions.min() < 0 or positions.max() >= d_in:
        raise ValueError("positions out of range")
    if p > 1 and not (np.diff(positions, axis=1) > 0).all():
        raise ValueError("positions must be strictly increasing per row")

    m = (1 << b) - 1
    flag = m  # stored value of the escape symbol (value-1 encoding)

    gaps = np.empty((rows, p), dtype=np.int64)
    gaps[:, 0] = positions[:, 0] + 1
    if p > 1:
        gaps[:, 1:] = np.diff(positions, axis=1)

    n_flags = (gaps - 1) // m                       # escapes per gap
    remainders = gaps - n_flags * m                 # in [1, m]
    sym_per_gap = n_flags + 1
    counts = sym_per_gap.sum(axis=1)
    s_max = int(counts.max())

    symbols = np.full((rows, s_max), flag, dtype=np.uint16)
    # Vectorized emission: for every gap, its remainder symbol lands at
    # offset cumsum(sym_per_gap) - 1; escape flags occupy the positions
    # before it (and are already the fill value).
    ends = np.cumsum(sym_per_gap, axis=1) - 1       # remainder positions
    row_idx = np.repeat(np.arange(rows), p)
    symbols[row_idx, ends.ravel()] = (remainders - 1).astype(np.uint16).ravel()

    return GapStream(
        symbols=jnp.asarray(symbols, dtype=jnp.uint16),
        counts=jnp.asarray(counts, dtype=jnp.int32),
        b=b,
        d_in=d_in,
    )


def decode_stream(stream: GapStream) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Decode gap streams back to positions via a parallel prefix sum.

    Returns (positions, mask):
      positions: (rows, s_max) int32 — 0-based position per symbol
                 (valid where mask).
      mask:      (rows, s_max) bool — True where the symbol emits an
                 outlier (non-flag, within the row's real count).

    Pure jnp; jit-safe; the only sequential dependency is a cumsum.
    """
    return _decode_symbols(stream.symbols, stream.counts, stream.b)


@jax.jit
def _decode_counts_mask(symbols, counts, flag):
    idx = jnp.arange(symbols.shape[-1], dtype=jnp.int32)
    in_range = idx[None, :] < counts[:, None]
    return in_range & (symbols != flag)


def _decode_symbols(symbols: jnp.ndarray, counts: jnp.ndarray, b: int):
    m = (1 << b) - 1
    flag = m
    sym = symbols.astype(jnp.int32)
    # stored value-1 encoding: non-flag symbol s encodes gap s+1;
    # flag contributes m with no emission.
    increments = jnp.where(sym == flag, m, sym + 1)
    idx = jnp.arange(symbols.shape[-1], dtype=jnp.int32)
    in_range = idx[None, :] < counts[:, None]
    increments = jnp.where(in_range, increments, 0)
    cum = jnp.cumsum(increments, axis=-1)
    positions = (cum - 1).astype(jnp.int32)
    mask = in_range & (sym != flag)
    return positions, mask


def positions_to_mask(positions: jnp.ndarray, mask: jnp.ndarray, d_in: int) -> jnp.ndarray:
    """Scatter decoded (positions, mask) into a dense boolean outlier mask."""
    rows = positions.shape[0]
    dense = jnp.zeros((rows, d_in), dtype=bool)
    safe = jnp.where(mask, positions, 0)
    dense = dense.at[jnp.arange(rows)[:, None], safe].max(mask)
    return dense


def decode_to_dense_mask(stream: GapStream) -> jnp.ndarray:
    positions, mask = decode_stream(stream)
    return positions_to_mask(positions, mask, stream.d_in)


def mask_to_positions(outlier_mask: np.ndarray) -> np.ndarray:
    """Dense boolean mask (rows, d_in) -> (rows, p) sorted positions.

    Requires every row to have the same number of outliers (the codec
    guarantees this: p = floor(gamma * d_in) per row).
    """
    outlier_mask = np.asarray(outlier_mask, dtype=bool)
    per_row = outlier_mask.sum(axis=1)
    if per_row.size and not (per_row == per_row[0]).all():
        raise ValueError("rows have differing outlier counts")
    rows, d_in = outlier_mask.shape
    p = int(per_row[0]) if per_row.size else 0
    positions = np.nonzero(outlier_mask)[1].reshape(rows, p)
    return positions


def _reach(symbols: np.ndarray, counts: np.ndarray, b: int) -> np.ndarray:
    """0-based position consumed by each symbol; +inf past the real count."""
    rows, s_max = symbols.shape
    m = (1 << b) - 1
    sym = symbols.astype(np.int64)
    inc = np.where(sym == m, m, sym + 1)
    idx = np.arange(s_max)
    valid = idx[None, :] < counts[:, None]
    reach = np.cumsum(np.where(valid, inc, 0), axis=1) - 1
    return np.where(valid, reach, np.iinfo(np.int64).max)


def stream_checkpoints(
    symbols: np.ndarray,
    counts: np.ndarray,
    b: int,
    tile: int,
    total_len: int,
) -> Tuple[np.ndarray, np.ndarray]:
    """Per-(row, tile) checkpoints for the v2 runtime format.

    For column tiles [t*tile, (t+1)*tile) covering [0, total_len) returns

      offsets: (rows, T+1) uint16 — index of the first symbol whose decoded
               position reaches tile t; ``offsets[:, T]`` is the per-row
               symbol count (sentinel), so tile t's symbols are exactly
               ``[offsets[t], offsets[t+1])``.
      dbase:   (rows, T) uint8 (uint16 when b > 8) — base-position delta:
               ``t*tile - dbase[t]`` is the absolute position consumed
               before the tile's first symbol. The delta is < 2^b because
               the symbol straddling the boundary advances at most
               2^b - 1 positions, so it packs into b bits.

    A kernel block reconstructs its selector locally: masked cumsum of the
    tile's symbol increments added to the checkpoint base — no row-prefix
    scan, no dense bitmap. Cost: (16*(T+1) + 8*T) / total_len bits/weight.
    Host-side numpy (encode/pack time).
    """
    symbols = np.asarray(symbols)
    counts = np.asarray(counts, dtype=np.int64)
    rows, s_max = symbols.shape
    if total_len % tile:
        raise ValueError(f"total_len {total_len} not a multiple of tile {tile}")
    if counts.size and counts.max() > np.iinfo(np.uint16).max:
        raise ValueError("symbol counts exceed uint16 checkpoint range")
    T = total_len // tile
    reach = _reach(symbols, counts, b) if s_max else \
        np.full((rows, 0), 0, dtype=np.int64)
    d_dtype = np.uint8 if b <= 8 else np.uint16
    d_max = int(np.iinfo(d_dtype).max)
    offsets = np.empty((rows, T + 1), np.uint16)
    dbase = np.zeros((rows, T), d_dtype)
    for t in range(T + 1):
        lo = t * tile
        off = (reach < lo).sum(axis=1) if s_max else np.zeros(rows, np.int64)
        offsets[:, t] = off
        if t < T and s_max:
            prev = np.take_along_axis(
                reach, np.maximum(off - 1, 0)[:, None], axis=1)[:, 0] + 1
            prev = np.where(off > 0, prev, 0)
            # tiles past the last symbol never decode; clamp their delta
            dbase[:, t] = np.clip(lo - prev, 0, d_max).astype(d_dtype)
    return offsets, dbase


def selector_from_checkpoints(
    sym_cols: jnp.ndarray,
    offsets: jnp.ndarray,
    dbase: jnp.ndarray,
    *,
    b: int,
    tile: int,
    out_len: int,
) -> jnp.ndarray:
    """Pure-jnp v2 decode: checkpointed streams -> dense 0/1 selector.

    sym_cols: (rows, S) int — unpacked b-bit symbols (value-1 encoding).
    offsets/dbase: per-tile checkpoints from ``stream_checkpoints``.
    Mirrors the Pallas kernels' per-tile masked-cumsum math exactly (the
    XLA dispatch arm and tests use this), so both arms see bit-identical
    selectors. Returns (rows, out_len) int32.
    """
    rows, S = sym_cols.shape
    T = offsets.shape[-1] - 1
    m = (1 << b) - 1
    sym = sym_cols.astype(jnp.int32)[:, None, :]              # (rows, 1, S)
    off = offsets.astype(jnp.int32)
    o0, o1 = off[:, :-1, None], off[:, 1:, None]              # (rows, T, 1)
    j = jnp.arange(S, dtype=jnp.int32)[None, None, :]
    in_tile = (j >= o0) & (j < o1)                            # (rows, T, S)
    inc = jnp.where(sym == m, m, sym + 1) * in_tile
    lo = (jnp.arange(T, dtype=jnp.int32) * tile)[None, :, None]
    base = lo - dbase.astype(jnp.int32)[:, :, None]
    pos = base + jnp.cumsum(inc, axis=-1) - 1
    emit = in_tile & (sym != m)
    dense = positions_to_mask(pos.reshape(-1, S), emit.reshape(-1, S), out_len)
    return dense.reshape(rows, T, out_len).any(axis=1).astype(jnp.int32)


def selector_from_stream_cols(
    sym_cols: jnp.ndarray,
    counts: jnp.ndarray,
    *,
    b: int,
    out_len: int,
) -> jnp.ndarray:
    """Global-cumsum v2 decode: unpacked symbols + per-row counts ->
    dense 0/1 selector (rows, out_len) int32.

    Bit-identical to ``selector_from_checkpoints`` (the gap stream
    encodes one set of positions; both formulations recover it with
    exact integer math) at 1/T the work — the XLA dispatch arm uses this
    per call, while the per-tile variant validates the checkpoint
    sidecar in tests and mirrors the kernels.
    """
    rows, S = sym_cols.shape
    m = (1 << b) - 1
    sym = sym_cols.astype(jnp.int32)
    j = jnp.arange(S, dtype=jnp.int32)[None, :]
    in_range = j < counts.astype(jnp.int32)[:, None]
    inc = jnp.where(sym == m, m, sym + 1) * in_range
    pos = jnp.cumsum(inc, axis=-1) - 1
    emit = in_range & (sym != m)
    return positions_to_mask(pos, emit, out_len).astype(jnp.int32)


def tile_checkpoints(stream: GapStream, tile: int) -> Tuple[np.ndarray, np.ndarray]:
    """Checkpointed stream (TPU adaptation, DESIGN.md §4.2).

    For each (row, column-tile of width `tile`) returns
      offsets: (rows, n_tiles) int32 — index of the first symbol whose
               decoded position lands in the tile,
      ncount:  (rows, n_tiles) int32 — number of symbols covering the tile
               (including escape flags consumed inside it),
    making every tile independently decodable: a kernel reads
    symbols[offsets[t] : offsets[t] + ncount[t]] and a base position equal
    to tile*t. Cost: 2 * 32 bits per (row, tile) before narrowing; with
    u16 offsets ~= 32/tile bits/weight.
    """
    positions, mask = jax.device_get(decode_stream(stream))
    symbols = np.asarray(jax.device_get(stream.symbols))
    counts = np.asarray(jax.device_get(stream.counts))
    rows, s_max = symbols.shape
    n_tiles = -(-stream.d_in // tile)
    offsets = np.zeros((rows, n_tiles), dtype=np.int32)
    ncount = np.zeros((rows, n_tiles), dtype=np.int32)
    # decoded "reach": position after consuming symbol j (flag or not)
    m = (1 << stream.b) - 1
    sym = symbols.astype(np.int64)
    inc = np.where(sym == m, m, sym + 1)
    idx = np.arange(s_max)
    inc = np.where(idx[None, :] < counts[:, None], inc, 0)
    reach = np.cumsum(inc, axis=1) - 1  # 0-based position touched by sym j
    for t in range(n_tiles):
        lo, hi = t * tile, min((t + 1) * tile, stream.d_in)
        inside = (reach >= lo) & (reach < hi) & (idx[None, :] < counts[:, None])
        any_inside = inside.any(axis=1)
        first = np.where(any_inside, inside.argmax(axis=1), 0)
        last = np.where(
            any_inside, s_max - 1 - inside[:, ::-1].argmax(axis=1), -1
        )
        offsets[:, t] = first
        ncount[:, t] = np.where(any_inside, last - first + 1, 0)
    return offsets, ncount
