"""ICQuant core: the paper's contribution as a composable JAX library."""
from repro.core.bounds import lemma1_bound, optimal_b
from repro.core.icquant import (
    ICQPacked,
    dequant_matmul,
    dequantize,
    quantize,
    quantize_error,
)
from repro.core.index_coding import (
    GapStream,
    decode_stream,
    decode_to_dense_mask,
    encode_positions,
    mask_to_positions,
    tile_checkpoints,
)
from repro.core.partition import num_outliers, outlier_mask, outlier_positions

__all__ = [
    "ICQPacked",
    "GapStream",
    "quantize",
    "dequantize",
    "dequant_matmul",
    "quantize_error",
    "encode_positions",
    "decode_stream",
    "decode_to_dense_mask",
    "mask_to_positions",
    "tile_checkpoints",
    "outlier_mask",
    "outlier_positions",
    "num_outliers",
    "lemma1_bound",
    "optimal_b",
]
