"""Scalar quantizers used by ICQuant and the baselines.

Everything produces *codebooks*: a quantizer maps a masked subset of a row
to (codes, codebook) with reconstruction w_hat = codebook[code]. This
unifies RTN (uniform codebook), signed-tail RTN for outliers (paper
Appendix E.1), and Fisher-weighted K-means (SqueezeLLM / ICQuant^SK).
"""
from __future__ import annotations

from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

_EPS = 1e-12


# ---------------------------------------------------------------------------
# codebook application
# ---------------------------------------------------------------------------

def assign_codes(w: jnp.ndarray, codebook: jnp.ndarray) -> jnp.ndarray:
    """Nearest-centroid code for each element. w: (..., L), codebook:
    (..., C) broadcastable over leading dims."""
    d = jnp.abs(w[..., :, None] - codebook[..., None, :])
    return jnp.argmin(d, axis=-1).astype(jnp.int32)


def lookup(codes: jnp.ndarray, codebook: jnp.ndarray) -> jnp.ndarray:
    return jnp.take_along_axis(codebook, codes, axis=-1)


# ---------------------------------------------------------------------------
# RTN (uniform) codebooks
# ---------------------------------------------------------------------------

def uniform_codebook(lo: jnp.ndarray, hi: jnp.ndarray, n_bits: int) -> jnp.ndarray:
    """Uniform levels covering [lo, hi]; lo/hi: (...,) -> (..., 2^n)."""
    levels = 1 << n_bits
    t = jnp.linspace(0.0, 1.0, levels, dtype=jnp.float32)
    return lo[..., None] + (hi - lo)[..., None] * t


def rtn_inlier_codebook(w: jnp.ndarray, mask: jnp.ndarray, n_bits: int) -> jnp.ndarray:
    """Per-row uniform codebook over the masked (inlier) min/max range."""
    big = jnp.finfo(jnp.float32).max
    lo = jnp.where(mask, w, big).min(axis=-1)
    hi = jnp.where(mask, w, -big).max(axis=-1)
    return uniform_codebook(lo, hi, n_bits)


def rtn_outlier_codebook(
    w: jnp.ndarray, mask: jnp.ndarray, n_bits: int
) -> jnp.ndarray:
    """Signed-tail RTN (Appendix E.1): 1 sign bit + (n-1)-bit RTN per tail.

    The returned 2^n codebook is the concatenation of 2^(n-1) uniform
    levels on the negative tail and 2^(n-1) on the positive tail. Empty
    tails collapse to the available tail so every code stays usable.
    """
    half = 1 << (n_bits - 1)
    big = jnp.finfo(jnp.float32).max
    wneg = jnp.where(mask & (w < 0), w, big)
    wpos = jnp.where(mask & (w >= 0), w, -big)
    neg_lo = wneg.min(axis=-1)
    neg_hi = jnp.where(mask & (w < 0), w, -big).max(axis=-1)
    pos_lo = jnp.where(mask & (w >= 0), w, big).min(axis=-1)
    pos_hi = wpos.max(axis=-1)
    has_neg = (neg_hi > -big) & (neg_lo < big)
    has_pos = (pos_hi > -big) & (pos_lo < big)
    # fall back to the other tail (or zero) when a tail is empty
    neg_lo = jnp.where(has_neg, neg_lo, jnp.where(has_pos, pos_lo, 0.0))
    neg_hi = jnp.where(has_neg, neg_hi, jnp.where(has_pos, pos_hi, 0.0))
    pos_lo = jnp.where(has_pos, pos_lo, neg_lo)
    pos_hi = jnp.where(has_pos, pos_hi, neg_hi)
    t = jnp.linspace(0.0, 1.0, half, dtype=jnp.float32)
    neg = neg_lo[..., None] + (neg_hi - neg_lo)[..., None] * t
    pos = pos_lo[..., None] + (pos_hi - pos_lo)[..., None] * t
    return jnp.concatenate([neg, pos], axis=-1)


# ---------------------------------------------------------------------------
# Fisher-weighted K-means (SqueezeLLM quantizer; ICQuant^SK)
# ---------------------------------------------------------------------------

def _quantile_init(w, weight, n_clusters):
    """Initialize centroids at weighted quantiles of the masked values."""
    order = jnp.argsort(w)
    w_sorted = jnp.take(w, order)
    m_sorted = jnp.take(weight, order)
    cum = jnp.cumsum(m_sorted)
    total = jnp.maximum(cum[-1], _EPS)
    targets = (jnp.arange(n_clusters, dtype=jnp.float32) + 0.5) / n_clusters
    idx = jnp.searchsorted(cum / total, targets)
    idx = jnp.clip(idx, 0, w.shape[0] - 1)
    init = jnp.take(w_sorted, idx)
    # nudge duplicates apart so empty clusters are rare at init
    span = jnp.maximum(w_sorted[-1] - w_sorted[0], _EPS)
    jitter = jnp.linspace(-1e-6, 1e-6, n_clusters) * span
    return init + jitter


@partial(jax.jit, static_argnames=("n_clusters", "iters"))
def weighted_kmeans_1d(
    w: jnp.ndarray,
    weight: jnp.ndarray,
    n_clusters: int,
    iters: int = 25,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Weighted 1-D Lloyd's algorithm on a single row subset.

    w, weight: (L,). weight of 0 excludes a point (mask folded in).
    Returns (codebook (n_clusters,) sorted, codes (L,)).
    """
    centroids = _quantile_init(w, weight, n_clusters)

    def step(c, _):
        d = jnp.abs(w[:, None] - c[None, :])
        a = jnp.argmin(d, axis=-1)
        onehot = jax.nn.one_hot(a, n_clusters, dtype=jnp.float32)
        wsum = (onehot * weight[:, None]).sum(axis=0)
        vsum = (onehot * (weight * w)[:, None]).sum(axis=0)
        new = jnp.where(wsum > _EPS, vsum / jnp.maximum(wsum, _EPS), c)
        return new, None

    centroids, _ = jax.lax.scan(step, centroids, None, length=iters)
    centroids = jnp.sort(centroids)
    codes = jnp.argmin(jnp.abs(w[:, None] - centroids[None, :]), axis=-1)
    return centroids, codes.astype(jnp.int32)


def weighted_kmeans_rows(
    W: jnp.ndarray,
    weight: jnp.ndarray,
    n_clusters: int,
    iters: int = 25,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """vmap of weighted_kmeans_1d over rows. W, weight: (R, L)."""
    f = jax.vmap(lambda w, m: weighted_kmeans_1d(w, m, n_clusters, iters))
    return f(W, weight)


# ---------------------------------------------------------------------------
# plain helpers
# ---------------------------------------------------------------------------

def quantization_mse(
    W: jnp.ndarray, W_hat: jnp.ndarray, fisher: Optional[jnp.ndarray] = None
) -> float:
    err = (W - W_hat) ** 2
    if fisher is not None:
        err = err * fisher
    return float(err.sum())
