"""The ICQuant matrix codec (paper Section 3).

Pipeline (per weight matrix, treated row-wise / per output channel):

  1. partition: top-gamma |w| per row are outliers (exactly p per row);
  2. quantize inliers and outliers with two independent n-bit quantizers
     (RTN or Fisher-weighted K-means), each covering ~half the range;
  3. encode outlier positions with the gap index-coding stream (~0.3 b/w);
  4. pack n-bit codes densely ("two-stream overlay": an outlier position
     holds its code in the *outlier* codebook; the selector bit is implied
     by the decoded stream, never stored per weight).

Storage = n bits/weight + B(stream) + 2 codebooks/row. The packed form is
a pytree, so it shards, jits and checkpoints like any other param.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import packing
from repro.core.bounds import optimal_b
from repro.core.index_coding import (
    GapStream,
    _decode_symbols as _decode,
    decode_stream,
    encode_positions,
    positions_to_mask,
)
from repro.core.partition import num_outliers, outlier_positions
from repro.core.quantizers import (
    assign_codes,
    rtn_inlier_codebook,
    rtn_outlier_codebook,
    weighted_kmeans_rows,
)

CODEBOOK_DTYPE_BITS = 16  # codebooks are stored bf16 on device


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class ICQPacked:
    """Packed ICQuant weight. Reconstruction:

        sel[r, j]  = 1 iff j in decode(stream)[r]
        w_hat[r,j] = codebooks[r, sel[r,j], code[r,j]]
    """

    codes: jnp.ndarray        # (d_out, words) uint32 packed n-bit codes
    symbols: jnp.ndarray      # (d_out, s_max) uint16 gap symbols
    counts: jnp.ndarray       # (d_out,) int32 symbols per row
    codebooks: jnp.ndarray    # (d_out, 2, 2^n) f32 [inlier, outlier]
    n_bits: int = dataclasses.field(metadata=dict(static=True))
    b: int = dataclasses.field(metadata=dict(static=True))
    gamma: float = dataclasses.field(metadata=dict(static=True))
    d_out: int = dataclasses.field(metadata=dict(static=True))
    d_in: int = dataclasses.field(metadata=dict(static=True))
    method: str = dataclasses.field(metadata=dict(static=True))

    # -- pytree protocol ----------------------------------------------------
    def tree_flatten(self):
        children = (self.codes, self.symbols, self.counts, self.codebooks)
        aux = (self.n_bits, self.b, self.gamma, self.d_out, self.d_in, self.method)
        return children, aux

    @classmethod
    def tree_unflatten(cls, aux, children):
        codes, symbols, counts, codebooks = children
        n_bits, b, gamma, d_out, d_in, method = aux
        return cls(codes, symbols, counts, codebooks,
                   n_bits, b, gamma, d_out, d_in, method)

    @property
    def stream(self) -> GapStream:
        return GapStream(self.symbols, self.counts, self.b, self.d_in)

    # -- accounting ----------------------------------------------------------
    def bits_per_weight(self) -> Dict[str, float]:
        total_w = self.d_out * self.d_in
        code_bits = float(self.n_bits)
        stream_bits = float(
            np.asarray(jax.device_get(self.counts), dtype=np.int64).sum()
        ) * self.b / total_w
        codebook_bits = (
            self.codebooks.shape[1] * self.codebooks.shape[2]
            * CODEBOOK_DTYPE_BITS / self.d_in
        )
        count_bits = 32.0 / self.d_in  # per-row symbol count
        total = code_bits + stream_bits + codebook_bits + count_bits
        return dict(
            code=code_bits,
            index=stream_bits,
            codebook=codebook_bits,
            counts=count_bits,
            total=total,
        )


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class ICQRuntime:
    """Load-time-expanded serving format (DESIGN.md §4.3): dense n-bit
    codes + 1-bit selector bitmap + flattened dual codebook. Trades
    ~(1 - 0.31) extra bits/weight of HBM for decode-free dequantization
    (no in-graph gap-stream cumsum/scatter); the Pallas kernels consume
    exactly these tensors."""

    codes: jnp.ndarray        # (..., d_out, ceil(d_in*k/32)) uint32
    bitmap: jnp.ndarray       # (..., d_out, ceil(d_in/32)) uint32
    codebooks: jnp.ndarray    # (..., d_out, 2^(n+1)) f32
    n_bits: int = dataclasses.field(metadata=dict(static=True))
    d_out: int = dataclasses.field(metadata=dict(static=True))
    d_in: int = dataclasses.field(metadata=dict(static=True))

    def tree_flatten(self):
        return ((self.codes, self.bitmap, self.codebooks),
                (self.n_bits, self.d_out, self.d_in))

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, *aux)


def to_runtime_format(packed: ICQPacked) -> ICQRuntime:
    """Expand the storage format into the serving format (load time)."""
    lead = packed.codes.shape[:-2]
    rows = int(np.prod(lead, dtype=np.int64)) * packed.d_out if lead \
        else packed.d_out
    sym2 = packed.symbols.reshape(rows, packed.symbols.shape[-1])
    cnt2 = packed.counts.reshape(rows)
    pos, mask = _decode(sym2, cnt2, packed.b)
    sel = positions_to_mask(pos, mask, packed.d_in).astype(jnp.uint32)
    bitmap = packing.pack_codes(sel, 1)
    bitmap = bitmap.reshape(*lead, packed.d_out, bitmap.shape[-1])
    return ICQRuntime(
        codes=packed.codes,
        bitmap=bitmap,
        codebooks=packed.codebooks.reshape(*lead, packed.d_out, -1),
        n_bits=packed.n_bits,
        d_out=packed.d_out,
        d_in=packed.d_in,
    )


def dequantize_runtime(rt: ICQRuntime) -> jnp.ndarray:
    """Decode-free reconstruction: unpack + select (XLA path; the Pallas
    kernel fuses the same computation into the matmul)."""
    codes = packing.unpack_codes(rt.codes, rt.n_bits, rt.d_in).astype(jnp.int32)
    sel = packing.unpack_codes(rt.bitmap, 1, rt.d_in).astype(jnp.int32)
    idx = sel * (1 << rt.n_bits) + codes
    return jnp.take_along_axis(rt.codebooks, idx, axis=-1)


def quantize(
    W,
    n_bits: int,
    gamma: float = 0.05,
    b: Optional[int] = None,
    fisher: Optional[jnp.ndarray] = None,
    method: str = "rtn",
    kmeans_iters: int = 25,
) -> ICQPacked:
    """Quantize a (d_out, d_in) matrix with ICQuant.

    method: 'rtn' (ICQuant^RTN) or 'kmeans' (ICQuant^SK, Fisher-weighted).
    fisher: optional (d_out, d_in) sensitivity weights (ICQuant^SK).
    """
    W = jnp.asarray(W, dtype=jnp.float32)
    d_out, d_in = W.shape
    if b is None:
        b = optimal_b(gamma)
    p = num_outliers(d_in, gamma)

    positions = outlier_positions(W, gamma)                  # host, exact p/row
    stream = encode_positions(positions, d_in, b)
    mask = jnp.zeros((d_out, d_in), dtype=bool)
    if p:
        mask = mask.at[jnp.arange(d_out)[:, None], jnp.asarray(positions)].set(True)

    if method == "rtn":
        cb_in = rtn_inlier_codebook(W, ~mask, n_bits)
        cb_out = (
            rtn_outlier_codebook(W, mask, n_bits)
            if p
            else jnp.zeros_like(cb_in)
        )
        codes_in = assign_codes(W, cb_in)
        codes_out = assign_codes(W, cb_out) if p else jnp.zeros_like(codes_in)
    elif method == "kmeans":
        fw = jnp.ones_like(W) if fisher is None else jnp.asarray(fisher, jnp.float32)
        cb_in, codes_in = weighted_kmeans_rows(
            W, fw * (~mask), 1 << n_bits, kmeans_iters
        )
        if p:
            cb_out, codes_out = weighted_kmeans_rows(
                W, fw * mask, 1 << n_bits, kmeans_iters
            )
        else:
            cb_out = jnp.zeros_like(cb_in)
            codes_out = jnp.zeros_like(codes_in)
    else:
        raise ValueError(f"unknown method {method!r}")

    dense_codes = jnp.where(mask, codes_out, codes_in).astype(jnp.uint32)
    packed = packing.pack_codes(dense_codes, n_bits)
    codebooks = jnp.stack([cb_in, cb_out], axis=1).astype(jnp.float32)

    return ICQPacked(
        codes=packed,
        symbols=stream.symbols,
        counts=stream.counts,
        codebooks=codebooks,
        n_bits=n_bits,
        b=b,
        gamma=gamma,
        d_out=d_out,
        d_in=d_in,
        method=method,
    )


def dequantize(packed: ICQPacked) -> jnp.ndarray:
    """Pure-jnp reconstruction (the oracle; kernels/ops has the fast path).

    Supports leading batch dims (e.g. layer- or expert-stacked weights):
    codes (..., d_out, words) -> (..., d_out, d_in).
    """
    lead = packed.codes.shape[:-2]
    rows = int(np.prod(lead, dtype=np.int64)) * packed.d_out if lead else packed.d_out
    codes2 = packed.codes.reshape(rows, packed.codes.shape[-1])
    symbols2 = packed.symbols.reshape(rows, packed.symbols.shape[-1])
    counts2 = packed.counts.reshape(rows)
    cb2 = packed.codebooks.reshape(rows, -1)

    codes = packing.unpack_codes(codes2, packed.n_bits, packed.d_in)
    positions, pmask = _decode(symbols2, counts2, packed.b)
    sel = positions_to_mask(positions, pmask, packed.d_in).astype(jnp.int32)
    flat_idx = sel * (1 << packed.n_bits) + codes.astype(jnp.int32)
    out = jnp.take_along_axis(cb2, flat_idx, axis=-1)
    return out.reshape(*lead, packed.d_out, packed.d_in)


def dequant_matmul(x: jnp.ndarray, packed: ICQPacked) -> jnp.ndarray:
    """y = x @ W_hat.T — reference quantized linear application."""
    return x @ dequantize(packed).T


def quantize_error(W, packed: ICQPacked, fisher=None) -> float:
    W_hat = dequantize(packed)
    err = (jnp.asarray(W, jnp.float32) - W_hat) ** 2
    if fisher is not None:
        err = err * fisher
    return float(err.sum())
