"""Outlier statistics (paper Section 2 + Appendix B/C).

- range_taken_by_outliers: Figure 1/6 quantity.
- chi_square_uniformity: Table 1/5 — per-row chi-square goodness-of-fit of
  outlier positions against the uniform distribution, group size 256.
- empirical_index_overhead: Figure 4/8 empirical B(b).
"""
from __future__ import annotations

from typing import Dict, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.index_coding import encode_positions
from repro.core.partition import outlier_mask, outlier_positions


def range_taken_by_outliers(W, gammas: Sequence[float]) -> Dict[float, float]:
    """Mean (over rows) fraction of the value range occupied by the top-
    gamma outliers: 1 - range(inliers)/range(all)."""
    W = jnp.asarray(W, jnp.float32)
    out = {}
    full = W.max(axis=-1) - W.min(axis=-1)
    for g in gammas:
        mask = outlier_mask(W, g)
        big = jnp.float32(3.4e38)
        inl_max = jnp.where(mask, -big, W).max(axis=-1)
        inl_min = jnp.where(mask, big, W).min(axis=-1)
        frac = 1.0 - (inl_max - inl_min) / jnp.maximum(full, 1e-12)
        out[g] = float(frac.mean())
    return out


def chi_square_sf(stat: jnp.ndarray, df: int) -> jnp.ndarray:
    """Survival function of the chi-square distribution, JAX-native."""
    return jax.scipy.special.gammaincc(df / 2.0, stat / 2.0)


def chi_square_uniformity(
    W, gamma: float = 0.0625, group: int = 256, alpha: float = 0.05
) -> float:
    """Rejection rate of per-row uniformity of outlier positions.

    Per row: split columns into groups of `group`, count outliers per
    group, chi-square against the uniform expectation. Returns the
    fraction of rows where uniformity is rejected at level alpha
    (paper Tables 1 and 5 report ~3% for most layers).
    """
    W = jnp.asarray(W, jnp.float32)
    d_in = W.shape[-1]
    n_groups = d_in // group
    if n_groups < 2:
        raise ValueError("need at least 2 groups for the chi-square test")
    usable = n_groups * group
    mask = outlier_mask(W[:, :usable], gamma).astype(jnp.float32)
    counts = mask.reshape(W.shape[0], n_groups, group).sum(axis=-1)
    expected = counts.sum(axis=-1, keepdims=True) / n_groups
    stat = ((counts - expected) ** 2 / jnp.maximum(expected, 1e-9)).sum(axis=-1)
    pvals = chi_square_sf(stat, n_groups - 1)
    return float((pvals < alpha).mean())


def empirical_index_overhead(W, gamma: float, b: int) -> float:
    """Measured bits/weight of the gap stream on real weights."""
    positions = outlier_positions(W, gamma)
    stream = encode_positions(positions, int(W.shape[-1]), b)
    return stream.storage_bits_per_weight()


def synthetic_uniform_overhead(
    d_in: int, rows: int, gamma: float, b: int, seed: int = 0
) -> float:
    """Simulation with exactly-uniform outlier positions (paper Fig 4
    'synthetic' curve)."""
    rng = np.random.default_rng(seed)
    p = int(np.floor(gamma * d_in))
    positions = np.sort(
        np.stack([rng.choice(d_in, size=p, replace=False) for _ in range(rows)]),
        axis=-1,
    )
    stream = encode_positions(positions, d_in, b)
    return stream.storage_bits_per_weight()


def heavy_tailed_weights(
    rows: int, cols: int, seed: int = 0, df: float = 5.0, scale: float = 0.02
) -> np.ndarray:
    """Synthetic LLM-like weights: Student-t tails over a Gaussian bulk.

    df ~ 5 reproduces the paper's headline statistic (top 5% of |w| span
    roughly half the value range) on rows of LLM-typical width.
    """
    rng = np.random.default_rng(seed)
    return (rng.standard_t(df, size=(rows, cols)) * scale).astype(np.float32)
