"""Random input-channel permutations (paper Appendix C.2).

If outlier positions are not uniform, a one-time random permutation of the
input channels of each linear layer enforces uniformity without changing
the model function: W @ x == (W P)(P^T x), and P^T can be folded into the
producing layer's output channels. These helpers build and fold such
permutations; tests assert exact output invariance through an MLP block.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def make_permutation(n: int, seed: int) -> np.ndarray:
    return np.random.default_rng(seed).permutation(n)


def invert(perm: np.ndarray) -> np.ndarray:
    inv = np.empty_like(perm)
    inv[perm] = np.arange(perm.size)
    return inv


def permute_in(W: jnp.ndarray, perm: np.ndarray) -> jnp.ndarray:
    """Permute input channels (columns) of a (d_out, d_in) weight."""
    return W[:, perm]


def permute_out(W: jnp.ndarray, perm: np.ndarray) -> jnp.ndarray:
    """Permute output channels (rows)."""
    return W[perm, :]


def fold_mlp_block(
    w_up: jnp.ndarray,
    w_gate: jnp.ndarray,
    w_down: jnp.ndarray,
    seed: int = 0,
) -> Tuple[Dict[str, jnp.ndarray], Dict[str, np.ndarray]]:
    """Fold permutations through a SwiGLU MLP (paper Figure 7).

    P1 permutes d_model (shared by up/gate inputs and down outputs must
    stay fixed to preserve the residual stream — so we keep the residual
    order and only permute the hidden dim), P2 permutes d_ff.

      up':   P2-rows of up,   gate': P2-rows of gate,
      down': P2-columns of down.

    Output of the block is exactly unchanged because the hidden
    permutation cancels: down' @ act(up' x * gate' x) == down @ act(...).
    """
    d_ff = w_up.shape[0]
    p2 = make_permutation(d_ff, seed)
    folded = dict(
        w_up=permute_out(w_up, p2),
        w_gate=permute_out(w_gate, p2),
        w_down=permute_in(w_down, p2),
    )
    return folded, dict(p2=p2)
