"""Fisher-information sensitivity for ICQuant^SK (paper Appendix E.1).

SqueezeLLM-style: the Hessian of the loss w.r.t. a weight is approximated
by the (empirical, diagonal) Fisher information — the running mean of the
squared gradient over a small calibration set. The quantizer then solves

    min_WQ (W - WQ)^T diag(F) (W - WQ)

via Fisher-weighted K-means (see quantizers.weighted_kmeans_rows).
"""
from __future__ import annotations

from typing import Any, Callable, Iterable

import jax
import jax.numpy as jnp


def fisher_information(
    loss_fn: Callable[[Any, Any], jnp.ndarray],
    params: Any,
    batches: Iterable[Any],
) -> Any:
    """Diagonal Fisher: mean over batches of grad(loss)^2, per parameter.

    loss_fn(params, batch) -> scalar loss. Returns a pytree like params.
    """
    grad_fn = jax.jit(jax.grad(loss_fn))
    acc = jax.tree.map(jnp.zeros_like, params)
    n = 0
    for batch in batches:
        g = grad_fn(params, batch)
        acc = jax.tree.map(lambda a, gi: a + gi.astype(a.dtype) ** 2, acc, g)
        n += 1
    if n == 0:
        raise ValueError("empty calibration set")
    return jax.tree.map(lambda a: a / n, acc)


def normalize_fisher(fisher: jnp.ndarray, floor: float = 1e-8) -> jnp.ndarray:
    """Scale-invariant positive weights (per matrix) for K-means."""
    f = jnp.asarray(fisher, jnp.float32)
    mean = jnp.maximum(f.mean(), floor)
    return jnp.maximum(f / mean, floor)
