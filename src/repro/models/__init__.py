"""Model zoo: 10 assigned architectures on a shared composable stack."""
from repro.models.model import (
    count_params,
    encdec_apply,
    encdec_cache_init,
    encdec_init,
    init_model,
    lm_apply,
    lm_cache_init,
    lm_hidden_and_logits,
    lm_init,
    mtp_logits,
)

__all__ = [
    "init_model",
    "lm_init",
    "lm_apply",
    "lm_cache_init",
    "lm_hidden_and_logits",
    "mtp_logits",
    "encdec_init",
    "encdec_apply",
    "encdec_cache_init",
    "count_params",
]
