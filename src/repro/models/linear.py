"""Linear application that dispatches on the weight representation.

Model params hold either a dense (d_in, d_out) array or an ``ICQPacked``
weight (the paper's codec; packed per *output channel*, i.e. over the
transposed matrix). Every matmul in the model zoo routes through
``linear`` so ICQuant is a first-class, drop-in weight format everywhere.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.icquant import (
    ICQPacked,
    ICQRuntime,
    dequantize,
    dequantize_runtime,
)


def linear(x: jnp.ndarray, w) -> jnp.ndarray:
    """y = x @ w for dense w of shape (d_in, d_out), ICQPacked (storage
    format: gap-stream decode in-graph) or ICQRuntime (serving format:
    decode-free bitmap overlay) — both stored per output channel."""
    if isinstance(w, ICQPacked):
        w_hat = dequantize(w)            # (d_out, d_in)
        return x @ w_hat.T.astype(x.dtype)
    if isinstance(w, ICQRuntime):
        w_hat = dequantize_runtime(w)
        return x @ w_hat.T.astype(x.dtype)
    return x @ w


def as_dense(w, dtype=None) -> jnp.ndarray:
    """Materialize a weight as a dense (d_in, d_out) array."""
    if isinstance(w, (ICQPacked, ICQRuntime)):
        w_hat = (dequantize(w) if isinstance(w, ICQPacked)
                 else dequantize_runtime(w)).T
        return w_hat.astype(dtype) if dtype is not None else w_hat
    return w


def weight_shape(w):
    if isinstance(w, ICQPacked):
        return (w.d_in, w.d_out)
    return w.shape
