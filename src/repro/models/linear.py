"""Linear application that dispatches on the weight representation.

Model params hold a dense (d_in, d_out) array, an ``ICQPacked`` weight
(the paper's codec; packed per *output channel*, i.e. over the
transposed matrix), an ``ICQRuntime`` (decode-free bitmap overlay), or
an ``ICQPrepared`` (pre-padded kernel layout — see kernels/backend.py).
Every matmul in the model zoo routes through ``linear`` so ICQuant is a
first-class, drop-in weight format everywhere; prepared weights flow
through the kernel-backed execution layer instead of a full in-graph
``dequantize()``.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.icquant import (
    ICQPacked,
    ICQRuntime,
    dequantize,
    dequantize_runtime,
)
from repro.kernels.backend import (
    ICQPrepared,
    dequantize_prepared,
    linear_apply,
)


def linear(x: jnp.ndarray, w) -> jnp.ndarray:
    """y = x @ w for dense w of shape (d_in, d_out), ICQPacked (storage
    format: gap-stream decode in-graph), ICQRuntime (serving format:
    decode-free bitmap overlay) or ICQPrepared (kernel execution layer:
    fused Pallas / prepared-XLA dispatch) — all stored per output
    channel."""
    if isinstance(w, ICQPrepared):
        return linear_apply(x, w)
    if isinstance(w, ICQPacked):
        w_hat = dequantize(w)            # (d_out, d_in)
        return x @ w_hat.T.astype(x.dtype)
    if isinstance(w, ICQRuntime):
        w_hat = dequantize_runtime(w)
        return x @ w_hat.T.astype(x.dtype)
    return x @ w


def as_dense(w, dtype=None) -> jnp.ndarray:
    """Materialize a weight as a dense (d_in, d_out) array."""
    if isinstance(w, (ICQPacked, ICQRuntime, ICQPrepared)):
        if isinstance(w, ICQPacked):
            w_hat = dequantize(w)
        elif isinstance(w, ICQRuntime):
            w_hat = dequantize_runtime(w)
        else:
            w_hat = dequantize_prepared(w)
        w_hat = jnp.swapaxes(w_hat, -1, -2)          # (..., d_in, d_out)
        return w_hat.astype(dtype) if dtype is not None else w_hat
    return w


def weight_shape(w):
    """Logical (d_in, d_out) of any weight representation."""
    if isinstance(w, (ICQPacked, ICQRuntime, ICQPrepared)):
        return (w.d_in, w.d_out)
    return w.shape
