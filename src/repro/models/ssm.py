"""Mamba2 SSD (state-space duality) mixer, chunk-parallel in JAX.

Follows the minimal SSD reference (Dao & Gu 2024): intra-chunk "attention"
blocks (quadratic in the chunk) + an inter-chunk scan over compressed
states (b, h, p, n). The chunk matmuls map onto the MXU; the only
sequential dependency is the O(L/Q) inter-chunk scan.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init, rmsnorm, rmsnorm_init
from repro.models.linear import linear

Params = Dict[str, jnp.ndarray]

NEG_INF = -1e30


def _pin_heads(x: jnp.ndarray) -> jnp.ndarray:
    """Constrain a (B, S, h, ...) activation to batch x head sharding when
    running under a (data, model) mesh; no-op otherwise."""
    spec = [None] * x.ndim
    spec[0] = "data"
    spec[2] = "model"
    try:
        return jax.lax.with_sharding_constraint(
            x, jax.sharding.PartitionSpec(*spec)
        )
    except Exception:   # no mesh in context (plain CPU tests)
        return x


def d_inner(cfg) -> int:
    return cfg.ssm_expand * cfg.d_model


def n_ssm_heads(cfg) -> int:
    return d_inner(cfg) // cfg.ssm_head_dim


def segsum(x: jnp.ndarray) -> jnp.ndarray:
    """(..., Q) -> (..., Q, Q) with out[i, j] = sum_{k=j+1..i} x[k] for
    i >= j, -inf above the diagonal."""
    q = x.shape[-1]
    cum = jnp.cumsum(x, axis=-1)
    diff = cum[..., :, None] - cum[..., None, :]
    mask = jnp.tril(jnp.ones((q, q), bool))
    return jnp.where(mask, diff, NEG_INF)


def ssd_chunked(
    x: jnp.ndarray,      # (b, l, h, p)  — already dt-scaled NOT applied here
    dt: jnp.ndarray,     # (b, l, h)     — positive (post-softplus)
    A: jnp.ndarray,      # (h,)          — negative
    B: jnp.ndarray,      # (b, l, h, n)
    C: jnp.ndarray,      # (b, l, h, n)
    chunk: int,
    init_state: Optional[jnp.ndarray] = None,   # (b, h, p, n)
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (y (b, l, h, p), final_state (b, h, p, n))."""
    b, l, h, p = x.shape
    n = B.shape[-1]
    q = min(chunk, l)
    pad = (-l) % q
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0), (0, 0)))
    L = x.shape[1]
    nc = L // q

    xs = (x * dt[..., None]).reshape(b, nc, q, h, p).astype(jnp.float32)
    Bs = B.reshape(b, nc, q, h, n).astype(jnp.float32)
    Cs = C.reshape(b, nc, q, h, n).astype(jnp.float32)
    da = (dt * A).reshape(b, nc, q, h).astype(jnp.float32)
    da_cs = jnp.cumsum(da, axis=2)                         # (b,c,q,h)

    # 1) intra-chunk (diagonal blocks)
    Lmat = jnp.exp(segsum(jnp.moveaxis(da, 2, 3)))         # (b,c,h,q,q)
    scores = jnp.einsum("bcqhn,bckhn->bchqk", Cs, Bs)
    y_diag = jnp.einsum("bchqk,bckhp->bcqhp", scores * Lmat, xs)

    # 2) per-chunk compressed states
    decay_states = jnp.exp(da_cs[:, :, -1:, :] - da_cs)    # (b,c,q,h)
    states = jnp.einsum("bcqhn,bcqh,bcqhp->bchpn", Bs, decay_states, xs)

    # 3) inter-chunk recurrence
    chunk_decay = jnp.exp(da_cs[:, :, -1, :])              # (b,c,h)
    h0 = (
        jnp.zeros((b, h, p, n), jnp.float32)
        if init_state is None
        else init_state.astype(jnp.float32)
    )

    def step(carry, inp):
        st, dec = inp                                       # (b,h,p,n), (b,h)
        prev = carry
        new = prev * dec[:, :, None, None] + st
        return new, prev                                    # emit state *before* chunk

    final, prevs = jax.lax.scan(
        step,
        h0,
        (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)),
    )
    prev_states = jnp.moveaxis(prevs, 0, 1)                 # (b,c,h,p,n)

    # 4) inter-chunk contribution
    state_decay = jnp.exp(da_cs)                            # (b,c,q,h)
    y_off = jnp.einsum(
        "bcqhn,bchpn,bcqh->bcqhp", Cs, prev_states, state_decay
    )

    y = (y_diag + y_off).reshape(b, L, h, p)[:, :l]
    return y, final


def ssd_decode_step(
    x: jnp.ndarray,      # (b, 1, h, p)
    dt: jnp.ndarray,     # (b, 1, h)
    A: jnp.ndarray,      # (h,)
    B: jnp.ndarray,      # (b, 1, h, n)
    C: jnp.ndarray,      # (b, 1, h, n)
    state: jnp.ndarray,  # (b, h, p, n)
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """O(1) single-token state update."""
    dt_ = dt[:, 0].astype(jnp.float32)                      # (b,h)
    decay = jnp.exp(dt_ * A)                                # (b,h)
    xb = jnp.einsum(
        "bhp,bhn->bhpn", (x[:, 0] * dt[:, 0, :, None]).astype(jnp.float32),
        B[:, 0].astype(jnp.float32),
    )
    new_state = state * decay[:, :, None, None] + xb
    y = jnp.einsum("bhpn,bhn->bhp", new_state, C[:, 0].astype(jnp.float32))
    return y[:, None], new_state


# ---------------------------------------------------------------------------
# full Mamba2 block
# ---------------------------------------------------------------------------

def mamba2_init(key, cfg) -> Params:
    dt_ = jnp.dtype(cfg.param_dtype)
    di = d_inner(cfg)
    h = n_ssm_heads(cfg)
    g, n = cfg.ssm_groups, cfg.ssm_state
    conv_dim = di + 2 * g * n
    ks = jax.random.split(key, 4)
    return dict(
        in_proj=dense_init(ks[0], cfg.d_model, 2 * di + 2 * g * n + h, dt_),
        conv_w=(jax.random.normal(ks[1], (cfg.conv_width, conv_dim)) * 0.1).astype(dt_),
        conv_b=jnp.zeros((conv_dim,), dt_),
        dt_bias=jnp.zeros((h,), jnp.float32),
        A_log=jnp.log(
            jnp.linspace(1.0, 16.0, h, dtype=jnp.float32)
        ),
        D=jnp.ones((h,), jnp.float32),
        norm=rmsnorm_init(di, dt_),
        out_proj=dense_init(ks[3], di, cfg.d_model, dt_),
    )


def _causal_conv(xBC: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                 history: Optional[jnp.ndarray] = None):
    """Depthwise causal conv, width K. xBC: (B, L, C); history: (B, K-1, C)."""
    K = w.shape[0]
    if history is None:
        history = jnp.zeros((xBC.shape[0], K - 1, xBC.shape[2]), xBC.dtype)
    xp = jnp.concatenate([history, xBC], axis=1)
    out = sum(xp[:, i : i + xBC.shape[1]] * w[i] for i in range(K))
    new_history = xp[:, -(K - 1):] if K > 1 else history
    return jax.nn.silu(out + b), new_history


def mamba2_apply(
    p: Params,
    x: jnp.ndarray,                 # (B, S, d_model)
    cfg,
    cache: Optional[Params] = None,
    reset: Optional[jnp.ndarray] = None,   # (B,) bool lane-reset mask
) -> Tuple[jnp.ndarray, Optional[Params]]:
    """``reset`` marks lanes admitted into a recycled slot this step:
    their conv history and SSM state slices are zeroed *before* the new
    token is consumed, so a recycled lane starts from exactly the state a
    fresh wave cache would give it — this is what lets the continuous
    engine serve recurrent (positionless) mixers, where there is no
    per-position write index to rewind."""
    B_, S, _ = x.shape
    if reset is not None and cache is not None:
        r = jnp.asarray(reset, bool)
        cache = dict(
            cache,
            conv=jnp.where(r[:, None, None],
                           jnp.zeros_like(cache["conv"]), cache["conv"]),
            ssm=jnp.where(r[:, None, None, None],
                          jnp.zeros_like(cache["ssm"]), cache["ssm"]),
        )
    di = d_inner(cfg)
    h = n_ssm_heads(cfg)
    g, n = cfg.ssm_groups, cfg.ssm_state
    hp = cfg.ssm_head_dim

    zxbcdt = linear(x, p["in_proj"])
    z, xBC, dt = jnp.split(zxbcdt, [di, 2 * di + 2 * g * n], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])     # (B,S,h)

    conv_hist = cache["conv"] if cache is not None else None
    xBC, new_hist = _causal_conv(xBC, p["conv_w"], p["conv_b"], conv_hist)
    xs, Bm, Cm = jnp.split(xBC, [di, di + g * n], axis=-1)
    xs = xs.reshape(B_, S, h, hp)
    # broadcast groups over heads
    Bm = jnp.repeat(Bm.reshape(B_, S, g, n), h // g, axis=2)
    Cm = jnp.repeat(Cm.reshape(B_, S, g, n), h // g, axis=2)
    # pin head sharding through the SSD einsums: without this GSPMD tends
    # to all-gather the (B,S,h,...) activations every layer (§Perf)
    xs = _pin_heads(xs)
    Bm = _pin_heads(Bm)
    Cm = _pin_heads(Cm)
    A = -jnp.exp(p["A_log"])

    if cache is None:
        y, final_state = ssd_chunked(xs, dt, A, Bm, Cm, cfg.ssd_chunk)
        new_cache = None
    elif S == 1:
        y, final_state = ssd_decode_step(xs, dt, A, Bm, Cm, cache["ssm"])
        new_cache = dict(conv=new_hist, ssm=final_state,
                         index=cache["index"] + S)
    else:  # prefill into an existing state
        y, final_state = ssd_chunked(
            xs, dt, A, Bm, Cm, cfg.ssd_chunk, init_state=cache["ssm"]
        )
        new_cache = dict(conv=new_hist, ssm=final_state,
                         index=cache["index"] + S)

    y = y + xs.astype(jnp.float32) * p["D"][:, None]
    y = y.reshape(B_, S, di).astype(x.dtype)
    y = rmsnorm(y, p["norm"], cfg.norm_eps) * jax.nn.silu(z)
    return linear(y, p["out_proj"]), new_cache


def mamba2_cache_init(cfg, batch: int, per_lane: bool = False) -> Params:
    """``per_lane=True`` gives the (bookkeeping-only) index a (B,) batch
    axis so the cache composes with the continuous engine's per-lane
    position sync; conv/ssm state already carries a batch axis — lane
    independence is structural, only the *reset* needs a mask."""
    dt_ = jnp.dtype(cfg.param_dtype)
    di = d_inner(cfg)
    h = n_ssm_heads(cfg)
    g, n = cfg.ssm_groups, cfg.ssm_state
    return dict(
        conv=jnp.zeros((batch, cfg.conv_width - 1, di + 2 * g * n), dt_),
        ssm=jnp.zeros((batch, h, cfg.ssm_head_dim, n), jnp.float32),
        index=jnp.zeros((batch,) if per_lane else (), jnp.int32),
    )
