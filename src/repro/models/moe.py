"""Mixture-of-Experts layer (Mixtral top-2, DeepSeek-V3 shared+routed top-8).

TPU-idiomatic dispatch: tokens are scattered into a per-expert capacity
buffer (E, C, d) with ``.at[e, pos].add`` (GSPMD lowers the data->expert
resharding to an all-to-all on the EP axis), experts run as one batched
einsum, results are gathered back and combined with router weights.
Capacity-dropped tokens fall back to the shared expert / residual path.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.icquant import (
    ICQPacked,
    ICQRuntime,
    dequantize as _icq_dequantize,
    dequantize_runtime as _icq_dequantize_rt,
)
from repro.kernels.backend import ICQPrepared, dequantize_prepared
from repro.models.layers import dense_init, mlp_apply, mlp_init

Params = Dict[str, jnp.ndarray]


def _expert_weight(w, dtype):
    """Materialize stacked expert weights (E, d_in, d_out) from dense or
    ICQuant-packed storage (packed per output channel, transposed).
    Prepared weights go through the kernel execution layer (one dequant
    kernel call over the whole expert stack — rows are independent)."""
    if isinstance(w, ICQPrepared):
        return jnp.swapaxes(dequantize_prepared(w), -1, -2).astype(dtype)
    if isinstance(w, ICQPacked):
        return jnp.swapaxes(_icq_dequantize(w), -1, -2).astype(dtype)
    if isinstance(w, ICQRuntime):
        return jnp.swapaxes(_icq_dequantize_rt(w), -1, -2).astype(dtype)
    return w


def moe_init(key, cfg) -> Params:
    dt = jnp.dtype(cfg.param_dtype)
    E = cfg.n_experts
    d_ff = cfg.moe_d_ff or cfg.d_ff
    ks = jax.random.split(key, 5)

    def expert_stack(k, d_in, d_out):
        return jax.vmap(lambda kk: dense_init(kk, d_in, d_out, dt))(
            jax.random.split(k, E)
        )

    p: Params = dict(
        router=dense_init(ks[0], cfg.d_model, E, jnp.float32),
        w_gate=expert_stack(ks[1], cfg.d_model, d_ff),
        w_up=expert_stack(ks[2], cfg.d_model, d_ff),
        w_down=expert_stack(ks[3], d_ff, cfg.d_model),
    )
    if cfg.n_shared_experts:
        p["shared"] = mlp_init(ks[4], cfg, d_ff * cfg.n_shared_experts)
    return p


def moe_apply(
    p: Params, x: jnp.ndarray, cfg
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (output (B,S,d), aux load-balance loss scalar)."""
    if cfg.moe_grouped_dispatch:
        return moe_apply_grouped(p, x, cfg)
    B, S, d = x.shape
    E, K = cfg.n_experts, cfg.experts_per_token
    N = B * S
    tokens = x.reshape(N, d)

    logits = tokens.astype(jnp.float32) @ p["router"]
    probs = jax.nn.softmax(logits, axis=-1)                      # (N, E)
    gate, idx = jax.lax.top_k(probs, K)                          # (N, K)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    # load-balance auxiliary loss (Switch-style)
    me = probs.mean(axis=0)
    ce = jnp.zeros((E,), jnp.float32).at[idx.reshape(-1)].add(1.0) / (N * K)
    aux = E * jnp.sum(me * ce)

    capacity = int(max(1, round(N * K / E * cfg.capacity_factor)))

    flat_idx = idx.reshape(-1)                                   # (N*K,)
    # position of each (token, k) within its expert queue
    onehot = jax.nn.one_hot(flat_idx, E, dtype=jnp.int32)        # (N*K, E)
    pos = (jnp.cumsum(onehot, axis=0) - 1)[jnp.arange(N * K), flat_idx]
    keep = pos < capacity

    # scatter tokens into expert buffers
    buf = jnp.zeros((E, capacity, d), x.dtype)
    src = jnp.repeat(tokens, K, axis=0)                          # (N*K, d)
    safe_pos = jnp.where(keep, pos, capacity - 1)
    buf = buf.at[flat_idx, safe_pos].add(
        jnp.where(keep[:, None], src, 0).astype(x.dtype)
    )

    # expert FFN as batched einsums
    wg = _expert_weight(p["w_gate"], x.dtype)
    wu = _expert_weight(p["w_up"], x.dtype)
    wd = _expert_weight(p["w_down"], x.dtype)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, wg)) * jnp.einsum(
        "ecd,edf->ecf", buf, wu
    )
    y = jnp.einsum("ecf,efd->ecd", h, wd)                        # (E, C, d)

    # gather back and combine
    out_flat = y[flat_idx, safe_pos]                             # (N*K, d)
    out_flat = jnp.where(keep[:, None], out_flat, 0)
    combined = (
        out_flat.reshape(N, K, d) * gate[..., None].astype(x.dtype)
    ).sum(axis=1)

    if "shared" in p:
        combined = combined + mlp_apply(p["shared"], tokens)

    return combined.reshape(B, S, d), aux


def _int8_reshard(x: jnp.ndarray, spec4) -> jnp.ndarray:
    """Quantize (B, E, Cg, d) to int8 with per-slot scales, force the
    expert resharding (the MoE all-to-all) onto the int8 tensor, then
    dequantize locally — 2x fewer bytes on the wire, straight-through
    gradient (the quantization is a wire format, not a value change the
    optimizer should see)."""
    dtype = x.dtype

    def fwd(v):
        scale = jnp.max(jnp.abs(v.astype(jnp.float32)), axis=-1,
                        keepdims=True) / 127.0
        q = jnp.round(v.astype(jnp.float32)
                      / jnp.maximum(scale, 1e-12)).astype(jnp.int8)
        try:
            q = jax.lax.with_sharding_constraint(
                q, jax.sharding.PartitionSpec(*spec4))
            scale = jax.lax.with_sharding_constraint(
                scale, jax.sharding.PartitionSpec(*spec4[:-1], None))
        except Exception:   # no mesh in context (plain CPU tests)
            pass
        return (q.astype(jnp.float32) * scale).astype(dtype)

    # straight-through estimator: wire quantization is transparent to grads
    zero = jax.lax.stop_gradient
    return x + zero(fwd(x) - x)


def moe_apply_grouped(
    p: Params, x: jnp.ndarray, cfg
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """GShard-style grouped dispatch: expert queues are per batch row.

    The position-in-queue cumsum runs over the (local) sequence axis only,
    so with the batch dim sharded over `data` the dispatch bookkeeping is
    entirely shard-local; the single cross-device exchange is the token
    all-to-all implied by resharding the (B, E, Cg, d) buffer from
    B-sharded to E-sharded at the expert einsum — the information-
    theoretic minimum for MoE. Capacity is per (row, expert):
    Cg = ceil(S*K/E * capacity_factor).
    """
    B, S, d = x.shape
    E, K = cfg.n_experts, cfg.experts_per_token

    logits = x.astype(jnp.float32) @ p["router"]           # (B, S, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, idx = jax.lax.top_k(probs, K)                    # (B, S, K)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    me = probs.mean(axis=(0, 1))
    ce = jnp.zeros((E,), jnp.float32).at[idx.reshape(-1)].add(1.0) / (B * S * K)
    aux = E * jnp.sum(me * ce)

    cap = int(max(1, -(-S * K * cfg.capacity_factor // E)))

    flat_idx = idx.reshape(B, S * K)                       # (B, SK)
    onehot = jax.nn.one_hot(flat_idx, E, dtype=jnp.int32)  # (B, SK, E)
    pos = (jnp.cumsum(onehot, axis=1) - 1)[
        jnp.arange(B)[:, None], jnp.arange(S * K)[None, :], flat_idx
    ]                                                      # (B, SK) local!
    keep = pos < cap
    safe_pos = jnp.where(keep, pos, cap - 1)

    src = jnp.repeat(x.reshape(B, S, d), K, axis=1)        # (B, SK, d)
    buf = jnp.zeros((B, E, cap, d), x.dtype)
    buf = buf.at[
        jnp.arange(B)[:, None], flat_idx, safe_pos
    ].add(jnp.where(keep[..., None], src, 0).astype(x.dtype))

    # expert einsum: reshard (B,E,Cg,d) -> E-major (the clean all-to-all)
    if cfg.moe_int8_dispatch:
        buf = _int8_reshard(buf, (None, "model", None, None))  # int8 wire
    wg = _expert_weight(p["w_gate"], x.dtype)
    wu = _expert_weight(p["w_up"], x.dtype)
    wd = _expert_weight(p["w_down"], x.dtype)
    h = jax.nn.silu(jnp.einsum("becd,edf->becf", buf, wg)) * \
        jnp.einsum("becd,edf->becf", buf, wu)
    y = jnp.einsum("becf,efd->becd", h, wd)                # (B, E, Cg, d)
    if cfg.moe_int8_dispatch:
        y = _int8_reshard(y, ("data", None, None, None))   # combine path

    out_flat = y[jnp.arange(B)[:, None], flat_idx, safe_pos]   # (B, SK, d)
    out_flat = jnp.where(keep[..., None], out_flat, 0)
    combined = (
        out_flat.reshape(B, S, K, d) * gate[..., None].astype(x.dtype)
    ).sum(axis=2)

    if "shared" in p:
        combined = combined + mlp_apply(p["shared"], x.reshape(B * S, d)
                                        ).reshape(B, S, d)

    return combined, aux
