"""Model assembly: blocks, scanned layer stacks, decoder-only LM, enc-dec.

Families (cfg.family):
  dense   — GQA or MLA attention + SwiGLU MLP           (llama/phi/internlm/minicpm3)
  moe     — attention + MoE FFN (optional leading dense layers, DeepSeek)
  ssm     — Mamba2 SSD mixer + no separate FFN           (mamba2)
  hybrid  — parallel GQA + Mamba2 heads, then MLP        (hymba)
  vlm     — dense backbone + precomputed patch-embedding prefix (pixtral)
  encdec  — bidirectional encoder + causal decoder w/ cross-attn (seamless)

Layer stacks are scanned: per-stack params carry a leading layer axis, so
HLO size is depth-independent. KV/SSM caches carry the same leading axis.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import ssm as ssm_mod
from repro.models.layers import (
    dense_init,
    gqa_apply,
    gqa_cache_init,
    gqa_init,
    mla_apply,
    mla_cache_init,
    mla_init,
    mlp_apply,
    mlp_init,
    rmsnorm,
    rmsnorm_init,
)
from repro.models.linear import linear
from repro.models.moe import moe_apply, moe_init

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# blocks
# ---------------------------------------------------------------------------

def _mixer_kind(cfg, use_cross: bool = False) -> str:
    if cfg.family == "ssm":
        return "ssm"
    if cfg.family == "hybrid":
        return "hybrid"
    return cfg.attn_type  # gqa | mla


def block_init(key, cfg, ffn: str = "mlp", cross: bool = False) -> Params:
    """ffn: 'mlp' | 'moe' | 'none'; cross adds cross-attention (decoder)."""
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 6)
    kind = _mixer_kind(cfg)
    p: Params = dict(ln1=rmsnorm_init(cfg.d_model, dt))
    if kind == "gqa":
        p["attn"] = gqa_init(ks[0], cfg)
    elif kind == "mla":
        p["attn"] = mla_init(ks[0], cfg)
    elif kind == "ssm":
        p["ssm"] = ssm_mod.mamba2_init(ks[0], cfg)
    elif kind == "hybrid":
        p["attn"] = gqa_init(ks[0], cfg)
        p["ssm"] = ssm_mod.mamba2_init(ks[1], cfg)
    if cross:
        p["cross"] = gqa_init(ks[2], cfg)
        p["ln_cross"] = rmsnorm_init(cfg.d_model, dt)
    if ffn != "none":
        p["ln2"] = rmsnorm_init(cfg.d_model, dt)
        if ffn == "moe":
            p["moe"] = moe_init(ks[3], cfg)
        else:
            p["mlp"] = mlp_init(ks[3], cfg)
    return p


def block_apply(
    p: Params,
    x: jnp.ndarray,
    cfg,
    positions: jnp.ndarray,
    cache: Optional[Params] = None,
    causal: bool = True,
    enc_out: Optional[jnp.ndarray] = None,
    enc_mask: Optional[jnp.ndarray] = None,
    seq_lens: Optional[jnp.ndarray] = None,
    reset: Optional[jnp.ndarray] = None,
) -> Tuple[jnp.ndarray, Optional[Params], jnp.ndarray]:
    """Returns (x, new_cache, aux_loss).

    ``seq_lens`` (B,) is the chunked-prefill validity mask: number of
    valid tokens this S-chunk per lane (per-lane caches only; GQA/MLA).
    ``reset`` (B,) is the continuous-serving lane-reset mask for
    recurrent mixers: lanes admitted into a recycled slot this step get
    their conv/SSM state zeroed before consuming the new token
    (attention caches need no reset — their per-lane write index is the
    single source of truth).
    """
    aux = jnp.zeros((), jnp.float32)
    kind = _mixer_kind(cfg)
    h = rmsnorm(x, p["ln1"], cfg.norm_eps)

    if seq_lens is not None and kind not in ("gqa", "mla"):
        raise NotImplementedError(
            f"seq_lens (chunked prefill) is not supported for the "
            f"{kind!r} mixer")
    new_cache: Optional[Params] = None
    if kind == "gqa":
        out, new_cache = gqa_apply(
            p["attn"], h, cfg, positions,
            cache=None if cache is None else cache["attn"], causal=causal,
            seq_lens=seq_lens,
        )
        if cache is not None:
            new_cache = dict(attn=new_cache)
    elif kind == "mla":
        out, mc = mla_apply(
            p["attn"], h, cfg, positions,
            cache=None if cache is None else cache["attn"],
            seq_lens=seq_lens,
        )
        if cache is not None:
            new_cache = dict(attn=mc)
    elif kind == "ssm":
        out, sc = ssm_mod.mamba2_apply(
            p["ssm"], h, cfg, cache=None if cache is None else cache["ssm"],
            reset=reset,
        )
        if cache is not None:
            new_cache = dict(ssm=sc)
    elif kind == "hybrid":
        a_out, ac = gqa_apply(
            p["attn"], h, cfg, positions,
            cache=None if cache is None else cache["attn"], causal=causal,
        )
        s_out, sc = ssm_mod.mamba2_apply(
            p["ssm"], h, cfg, cache=None if cache is None else cache["ssm"],
            reset=reset,
        )
        out = 0.5 * (a_out + s_out)
        if cache is not None:
            new_cache = dict(attn=ac, ssm=sc)
    else:
        raise ValueError(kind)
    x = x + out

    if "cross" in p:
        h = rmsnorm(x, p["ln_cross"], cfg.norm_eps)
        out, _ = gqa_apply(
            p["cross"], h, cfg, positions, cross_kv=(enc_out, enc_mask)
        )
        x = x + out

    if "moe" in p:
        h = rmsnorm(x, p["ln2"], cfg.norm_eps)
        out, aux = moe_apply(p["moe"], h, cfg)
        x = x + out
    elif "mlp" in p:
        h = rmsnorm(x, p["ln2"], cfg.norm_eps)
        x = x + mlp_apply(p["mlp"], h)
    return x, new_cache, aux


def block_cache_init(cfg, batch: int, max_len: int,
                     per_lane: bool = False, paged=None) -> Params:
    """``per_lane=True`` builds a continuous-batching slot cache: the KV
    write index carries a (B,) batch axis so every lane advances (and is
    recycled) independently. Recurrent SSM state is per-lane by
    construction (its state already carries a batch axis); recycling it
    is a lane-reset mask (``mamba2_apply(reset=...)``), not a position
    rewind. ``paged=(num_blocks, block_size)`` swaps the attention
    cache's contiguous (B, max_len) rows for a block pool + per-lane
    page tables (serving/kv_pool.py); SSM state has no positions to
    page."""
    kind = _mixer_kind(cfg)
    if paged is not None and kind == "ssm":
        raise NotImplementedError(
            "a paged KV cache needs an attention cache; the 'ssm' mixer "
            "carries recurrent state only")
    c: Params = {}
    if kind in ("gqa", "hybrid"):
        c["attn"] = gqa_cache_init(cfg, batch, max_len, per_lane=per_lane,
                                   paged=paged)
    if kind == "mla":
        c["attn"] = mla_cache_init(cfg, batch, max_len, per_lane=per_lane,
                                   paged=paged)
    if kind in ("ssm", "hybrid"):
        c["ssm"] = ssm_mod.mamba2_cache_init(cfg, batch, per_lane=per_lane)
    return c


# ---------------------------------------------------------------------------
# stacked (scanned) layer groups
# ---------------------------------------------------------------------------

def stack_init(key, cfg, n_layers: int, ffn: str, cross: bool = False) -> Params:
    keys = jax.random.split(key, n_layers)
    return jax.vmap(lambda k: block_init(k, cfg, ffn=ffn, cross=cross))(keys)


def stack_apply(
    stack: Params,
    x: jnp.ndarray,
    cfg,
    positions: jnp.ndarray,
    cache: Optional[Params] = None,
    causal: bool = True,
    enc_out: Optional[jnp.ndarray] = None,
    enc_mask: Optional[jnp.ndarray] = None,
    seq_lens: Optional[jnp.ndarray] = None,
    reset: Optional[jnp.ndarray] = None,
):
    """Scan over the leading layer axis of `stack` (and `cache`)."""

    def body(carry, layer):
        xx, aux_sum = carry
        if cache is None:
            pl, cl = layer, None
        else:
            pl, cl = layer
        xo, co, aux = block_apply(
            pl, xx, cfg, positions, cache=cl, causal=causal,
            enc_out=enc_out, enc_mask=enc_mask, seq_lens=seq_lens,
            reset=reset,
        )
        return (xo, aux_sum + aux), co

    if cfg.remat:
        body = jax.checkpoint(body)

    xs = stack if cache is None else (stack, cache)
    carry0 = (x, jnp.zeros((), jnp.float32))
    if cfg.scan_layers:
        (x, aux), new_cache = jax.lax.scan(body, carry0, xs)
    else:
        # unrolled (dry-run mode): exact XLA cost analysis per layer
        n_layers = jax.tree.leaves(stack)[0].shape[0]
        carry = carry0
        outs = []
        for i in range(n_layers):
            layer_i = jax.tree.map(lambda a: a[i], xs)
            carry, co = body(carry, layer_i)
            outs.append(co)
        (x, aux) = carry
        new_cache = (
            None if cache is None
            else jax.tree.map(lambda *ys: jnp.stack(ys), *outs)
        )
    return x, (None if cache is None else new_cache), aux


# ---------------------------------------------------------------------------
# decoder-only LM (dense / moe / ssm / hybrid / vlm)
# ---------------------------------------------------------------------------

def lm_init(key, cfg) -> Params:
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 6)
    p: Params = dict(
        embed=(jax.random.normal(ks[0], (cfg.vocab_size, cfg.d_model)) * 0.02
               ).astype(dt),
        final_norm=rmsnorm_init(cfg.d_model, dt),
    )
    if not cfg.tie_embeddings:
        p["lm_head"] = dense_init(ks[1], cfg.d_model, cfg.vocab_size, dt)

    ffn = "moe" if cfg.family == "moe" else ("none" if cfg.family == "ssm" else "mlp")
    n_dense = cfg.first_dense_layers if cfg.family == "moe" else 0
    if n_dense:
        p["dense_stack"] = stack_init(ks[2], cfg, n_dense, ffn="mlp")
    p["stack"] = stack_init(ks[3], cfg, cfg.n_layers - n_dense, ffn=ffn)

    if cfg.mtp:  # DeepSeek-V3 multi-token prediction, depth 1
        p["mtp_proj"] = dense_init(ks[4], 2 * cfg.d_model, cfg.d_model, dt)
        p["mtp_block"] = block_init(ks[5], cfg, ffn="mlp")
        p["mtp_norm"] = rmsnorm_init(cfg.d_model, dt)
    return p


def _lm_head(p: Params, cfg, x: jnp.ndarray) -> jnp.ndarray:
    h = rmsnorm(x, p["final_norm"], cfg.norm_eps)
    if cfg.tie_embeddings:
        return h @ p["embed"].T
    return linear(h, p["lm_head"])


def lm_apply(
    p: Params,
    cfg,
    tokens: jnp.ndarray,                   # (B, S_text)
    cache: Optional[Params] = None,
    start_pos: Optional[jnp.ndarray] = None,
    prefix_embeds: Optional[jnp.ndarray] = None,  # (B, P, d) stub frontend
    seq_lens: Optional[jnp.ndarray] = None,       # (B,) chunk validity
    compute_logits: bool = True,
    logits_cols: Optional[jnp.ndarray] = None,    # (B,) per-lane logits column
    reset: Optional[jnp.ndarray] = None,          # (B,) SSM lane-reset mask
) -> Tuple[Optional[jnp.ndarray], Optional[Params], jnp.ndarray]:
    """Returns (logits (B, S, vocab), new_cache, aux_loss).

    S = P + S_text when a frontend prefix is present (VLM/audio stubs).
    ``start_pos`` may be a scalar (wave decoding: one global position) or
    a (B,) vector (continuous batching: per-lane positions — RoPE angles
    and the causal mask are computed lane-wise, and a per-lane cache
    built with ``lm_cache_init(per_lane=True)`` scatters each lane's KV
    at its own index).

    ``seq_lens`` (B,) enables chunked prefill against a per-lane cache:
    only each lane's first ``seq_lens[i]`` chunk tokens are written (and
    attended as new keys); ragged tails and mid-decode lanes pass
    ``seq_lens[i] < S`` and are write-masked, never re-padded.
    ``compute_logits=False`` skips the final norm + lm_head — a prefill
    chunk step only needs the cache side effect, not (B, S, vocab)
    logits (returns None in the logits slot).
    ``logits_cols`` (B,) gathers one hidden column per lane before the
    norm + lm_head, so a fused mixed prefill/decode step bills the
    vocab projection for B rows instead of B*S: returns (B, 1, vocab)
    — lane i's logits are for chunk column ``logits_cols[i]`` (the
    decode token, or a prompt lane's last admitted token).
    """
    x = p["embed"][tokens]
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
    B, S, _ = x.shape
    base = (
        jnp.zeros((B,), jnp.int32) if start_pos is None
        else jnp.broadcast_to(start_pos, (B,))
    )
    positions = base[:, None] + jnp.arange(S, dtype=jnp.int32)[None]

    aux_total = jnp.zeros((), jnp.float32)
    new_cache: Params = {}
    if "dense_stack" in p:
        dc = None if cache is None else cache["dense_stack"]
        x, c, aux = stack_apply(p["dense_stack"], x, cfg, positions, cache=dc,
                                seq_lens=seq_lens, reset=reset)
        aux_total += aux
        if cache is not None:
            new_cache["dense_stack"] = c
    mc = None if cache is None else cache["stack"]
    x, c, aux = stack_apply(p["stack"], x, cfg, positions, cache=mc,
                            seq_lens=seq_lens, reset=reset)
    aux_total += aux
    if cache is not None:
        new_cache["stack"] = c

    if not compute_logits:
        logits = None
    else:
        if logits_cols is not None:
            cols = jnp.broadcast_to(logits_cols, (B,)).astype(jnp.int32)
            x = jnp.take_along_axis(x, cols[:, None, None], axis=1)  # (B,1,d)
        logits = _lm_head(p, cfg, x)
    return logits, (new_cache if cache is not None else None), aux_total


def lm_hidden_and_logits(p, cfg, tokens, prefix_embeds=None):
    """Like lm_apply (no cache) but also returns the final hidden states —
    used by the MTP loss."""
    x = p["embed"][tokens]
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    aux_total = jnp.zeros((), jnp.float32)
    if "dense_stack" in p:
        x, _, aux = stack_apply(p["dense_stack"], x, cfg, positions)
        aux_total += aux
    x, _, aux = stack_apply(p["stack"], x, cfg, positions)
    aux_total += aux
    return x, _lm_head(p, cfg, x), aux_total


def mtp_logits(p: Params, cfg, hidden: jnp.ndarray, tokens: jnp.ndarray):
    """DeepSeek-V3 MTP (depth 1): combine hidden[t] with embed(token[t+1])
    and predict token[t+2] through one extra block."""
    B, S, d = hidden.shape
    nxt = p["embed"][tokens[:, 1:]]                       # (B, S-1, d)
    h = jnp.concatenate([hidden[:, :-1], nxt], axis=-1)   # (B, S-1, 2d)
    h = linear(h, p["mtp_proj"])
    positions = jnp.broadcast_to(
        jnp.arange(S - 1, dtype=jnp.int32)[None], (B, S - 1)
    )
    h, _, _ = block_apply(p["mtp_block"], h, cfg, positions)
    h = rmsnorm(h, p["mtp_norm"], cfg.norm_eps)
    return _lm_head(p, cfg, h)


def lm_cache_init(p: Params, cfg, batch: int, max_len: int,
                  per_lane: bool = False, paged=None) -> Params:
    n_dense = cfg.first_dense_layers if cfg.family == "moe" else 0
    cache: Params = {}

    def stacked(n):
        layer = block_cache_init(cfg, batch, max_len, per_lane=per_lane,
                                 paged=paged)
        return jax.tree.map(
            lambda a: jnp.broadcast_to(a, (n,) + a.shape).copy()
            if a.ndim else jnp.zeros((n,), a.dtype), layer
        )

    if n_dense:
        cache["dense_stack"] = stacked(n_dense)
    cache["stack"] = stacked(cfg.n_layers - n_dense)
    return cache


# ---------------------------------------------------------------------------
# encoder-decoder (Seamless backbone: stub frame frontend)
# ---------------------------------------------------------------------------

def encdec_init(key, cfg) -> Params:
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 5)
    return dict(
        embed=(jax.random.normal(ks[0], (cfg.vocab_size, cfg.d_model)) * 0.02
               ).astype(dt),
        enc_stack=stack_init(ks[1], cfg, cfg.encoder_layers, ffn="mlp"),
        enc_norm=rmsnorm_init(cfg.d_model, dt),
        dec_stack=stack_init(ks[2], cfg, cfg.decoder_layers, ffn="mlp",
                             cross=True),
        final_norm=rmsnorm_init(cfg.d_model, dt),
        lm_head=dense_init(ks[3], cfg.d_model, cfg.vocab_size, dt),
    )


def encode(p: Params, cfg, frames: jnp.ndarray, frame_mask: jnp.ndarray):
    """frames: (B, Tsrc, d_model) precomputed stub embeddings."""
    B, T, _ = frames.shape
    positions = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32)[None], (B, T))
    x, _, _ = stack_apply(
        p["enc_stack"], frames, cfg, positions, causal=False
    )
    return rmsnorm(x, p["enc_norm"], cfg.norm_eps)


def encdec_apply(
    p: Params,
    cfg,
    frames: jnp.ndarray,
    frame_mask: jnp.ndarray,
    tokens: jnp.ndarray,
    cache: Optional[Params] = None,
    enc_out: Optional[jnp.ndarray] = None,
    start_pos: Optional[jnp.ndarray] = None,
):
    """Returns (logits, new_cache, enc_out, aux)."""
    if enc_out is None:
        enc_out = encode(p, cfg, frames, frame_mask)
    x = p["embed"][tokens]
    B, S, _ = x.shape
    base = (
        jnp.zeros((B,), jnp.int32) if start_pos is None
        else jnp.broadcast_to(start_pos, (B,))
    )
    positions = base[:, None] + jnp.arange(S, dtype=jnp.int32)[None]
    dc = None if cache is None else cache["dec_stack"]
    x, c, aux = stack_apply(
        p["dec_stack"], x, cfg, positions, cache=dc,
        enc_out=enc_out, enc_mask=frame_mask,
    )
    h = rmsnorm(x, p["final_norm"], cfg.norm_eps)
    logits = linear(h, p["lm_head"])
    new_cache = None if cache is None else dict(dec_stack=c)
    return logits, new_cache, enc_out, aux


def encdec_cache_init(p: Params, cfg, batch: int, max_len: int,
                      per_lane: bool = False) -> Params:
    if per_lane:
        raise NotImplementedError(
            "per-lane cache positions are not supported for enc-dec "
            "models (encoder output is admitted wave-at-a-time)")
    layer = block_cache_init(cfg, batch, max_len)
    n = cfg.decoder_layers
    stacked = jax.tree.map(
        lambda a: jnp.broadcast_to(a, (n,) + a.shape).copy()
        if a.ndim else jnp.zeros((n,), a.dtype), layer
    )
    return dict(dec_stack=stacked)


# ---------------------------------------------------------------------------
# factory
# ---------------------------------------------------------------------------

def init_model(key, cfg) -> Params:
    if cfg.is_encdec:
        return encdec_init(key, cfg)
    return lm_init(key, cfg)


def count_params(params: Params) -> int:
    return int(
        sum(x.size for x in jax.tree.leaves(params) if hasattr(x, "size"))
    )
