"""Shared neural layers: norms, RoPE, chunked attention (GQA + MLA), MLP.

Pure-functional JAX: params are nested dicts of arrays, every layer is
``init_*(key, cfg) -> params`` plus an apply function. Homogeneous layer
stacks are scanned (params carry a leading layer axis), which keeps HLO
size flat in depth — important when lowering 62-layer models for a
512-device mesh.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

from repro.models.linear import as_dense, linear

import jax
import jax.numpy as jnp

Params = Dict[str, jnp.ndarray]


def _dtype(cfg):
    return jnp.dtype(cfg.param_dtype)


def dense_init(key, d_in: int, d_out: int, dtype, scale: float = 1.0):
    std = scale / (d_in**0.5)
    return (jax.random.normal(key, (d_in, d_out)) * std).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def pin_bshd(x: jnp.ndarray) -> jnp.ndarray:
    """Constrain a (B, S, H, D) activation to batch x head sharding.

    GSPMD otherwise tends to all-gather per-layer attention activations
    (measured: -64% collective bytes on SSD mixers, see EXPERIMENTS §Perf
    A4); no-op outside a mesh context.
    """
    if x.ndim != 4:
        return x
    try:
        return jax.lax.with_sharding_constraint(
            x, jax.sharding.PartitionSpec("data", None, "model", None)
        )
    except Exception:   # no mesh (plain CPU tests)
        return x


def rmsnorm_init(d: int, dtype) -> jnp.ndarray:
    return jnp.ones((d,), dtype=dtype)


def rmsnorm(x: jnp.ndarray, w: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    x32 = x.astype(jnp.float32)
    scale = jax.lax.rsqrt((x32 * x32).mean(-1, keepdims=True) + eps)
    return (x32 * scale).astype(x.dtype) * w


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """Rotary embedding. x: (B, S, H, D) (D even), positions: (B, S)."""
    d = x.shape[-1]
    freqs = 1.0 / (theta ** (jnp.arange(0, d, 2, dtype=jnp.float32) / d))
    angles = positions[..., None].astype(jnp.float32) * freqs  # (B, S, D/2)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# chunked online-softmax attention (flash-style, pure JAX)
# ---------------------------------------------------------------------------

def chunked_attention(
    q: jnp.ndarray,                # (B, S, H, D)
    k: jnp.ndarray,                # (B, T, Hkv, D)
    v: jnp.ndarray,                # (B, T, Hkv, Dv)
    pos_q: jnp.ndarray,            # (B, S) absolute positions
    pos_k: jnp.ndarray,            # (B, T)
    k_valid: Optional[jnp.ndarray] = None,  # (B, T) cache validity
    causal: bool = True,
    window: int = 0,
    chunk: int = 1024,
    scale: Optional[float] = None,
) -> jnp.ndarray:
    """Online-softmax attention scanned over KV chunks: O(S*chunk) memory.

    GQA via head grouping; sliding window folded into the position mask.
    """
    B, S, H, D = q.shape
    T, Hkv = k.shape[1], k.shape[2]
    Dv = v.shape[-1]
    G = H // Hkv
    scale = scale if scale is not None else D**-0.5

    chunk = min(chunk, T)
    n_chunks = -(-T // chunk)
    pad = n_chunks * chunk - T
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        pos_k = jnp.pad(pos_k, ((0, 0), (0, pad)), constant_values=-1)
        valid_pad = jnp.pad(
            jnp.ones((B, T), bool) if k_valid is None else k_valid,
            ((0, 0), (0, pad)),
        )
    else:
        valid_pad = jnp.ones((B, T), bool) if k_valid is None else k_valid

    qg = (q * scale).reshape(B, S, Hkv, G, D)
    kc = k.reshape(B, n_chunks, chunk, Hkv, D)
    vc = v.reshape(B, n_chunks, chunk, Hkv, Dv)
    pkc = pos_k.reshape(B, n_chunks, chunk)
    vmc = valid_pad.reshape(B, n_chunks, chunk)

    neg = jnp.float32(-1e30)

    def body(carry, xs):
        m, l, acc = carry
        k_i, v_i, pk_i, vm_i = xs  # (B, chunk, Hkv, D), ..., (B, chunk)
        s = jnp.einsum(
            "bshgd,bthd->bshgt", qg, k_i, preferred_element_type=jnp.float32
        )
        mask = vm_i[:, None, None, None, :]
        if causal:
            mask = mask & (pk_i[:, None, :] <= pos_q[:, :, None])[:, :, None, None, :]
        if window:
            mask = mask & (
                pk_i[:, None, :] > pos_q[:, :, None] - window
            )[:, :, None, None, :]
        s = jnp.where(mask, s, neg)
        m_i = jnp.maximum(m, s.max(axis=-1))
        alpha = jnp.exp(m - m_i)
        p = jnp.exp(s - m_i[..., None])
        l_i = l * alpha + p.sum(axis=-1)
        acc_i = acc * alpha[..., None] + jnp.einsum(
            "bshgt,bthd->bshgd", p, v_i.astype(jnp.float32)
        )
        return (m_i, l_i, acc_i), None

    m0 = jnp.full((B, S, Hkv, G), neg, jnp.float32)
    l0 = jnp.zeros((B, S, Hkv, G), jnp.float32)
    a0 = jnp.zeros((B, S, Hkv, G, Dv), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        body,
        (m0, l0, a0),
        (
            jnp.moveaxis(kc, 1, 0),
            jnp.moveaxis(vc, 1, 0),
            jnp.moveaxis(pkc, 1, 0),
            jnp.moveaxis(vmc, 1, 0),
        ),
    )
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.reshape(B, S, H, Dv).astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA attention layer (with optional KV cache and cross attention)
# ---------------------------------------------------------------------------

def gqa_init(key, cfg) -> Params:
    dt = _dtype(cfg)
    hd = cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    return dict(
        wq=dense_init(ks[0], cfg.d_model, cfg.n_heads * hd, dt),
        wk=dense_init(ks[1], cfg.d_model, cfg.n_kv_heads * hd, dt),
        wv=dense_init(ks[2], cfg.d_model, cfg.n_kv_heads * hd, dt),
        wo=dense_init(ks[3], cfg.n_heads * hd, cfg.d_model, dt),
    )


def _chunk_write_cols(idx: jnp.ndarray, S: int, T: int,
                      seq_lens: Optional[jnp.ndarray]) -> jnp.ndarray:
    """Per-lane cache write columns for an S-token chunk.

    ``seq_lens`` (B,) masks ragged chunk tails (lanes with fewer than S
    valid tokens this step — mid-decode lanes contribute 0): invalid
    columns are pushed past the cache edge ``T`` so the ``mode='drop'``
    scatter discards them instead of clobbering live rows.
    """
    cols = idx[:, None] + jnp.arange(S, dtype=jnp.int32)[None]
    if seq_lens is None:
        return cols
    valid = jnp.arange(S, dtype=jnp.int32)[None] < seq_lens[:, None]
    return jnp.where(valid, cols, T)


def _check_seq_lens(seq_lens, cache) -> None:
    if seq_lens is None:
        return
    if cache is None or "pos" in cache or not cache["index"].ndim:
        raise NotImplementedError(
            "seq_lens (chunked prefill validity masks) requires a per-lane "
            "slot cache (make_cache(..., per_lane=True))")


def _paged_scatter(pool: jnp.ndarray, pages: jnp.ndarray, cols: jnp.ndarray,
                   values: jnp.ndarray) -> jnp.ndarray:
    """Write ``values`` (B, S, ...) into a block pool through a page table.

    ``pool`` is (num_blocks, block_size, ...); ``pages`` (B, n_pt) maps
    each lane's logical block j to a physical block (-1 = unmapped);
    ``cols`` (B, S) holds logical positions with invalid entries already
    pushed to ``n_pt * block_size`` by ``_chunk_write_cols``. Invalid
    columns and unmapped pages resolve to physical block ``num_blocks``,
    which the ``mode='drop'`` scatter discards — mirroring the
    contiguous path's out-of-range-write semantics exactly.
    """
    nb, bs = pool.shape[0], pool.shape[1]
    n_pt = pages.shape[1]
    blk = jnp.take_along_axis(
        pages, jnp.clip(cols // bs, 0, n_pt - 1), axis=1)       # (B, S)
    ok = (cols < n_pt * bs) & (blk >= 0)
    blk = jnp.where(ok, blk, nb)                                # -> dropped
    off = jnp.where(ok, cols % bs, 0)
    return pool.at[blk, off].set(values.astype(pool.dtype), mode="drop")


def _paged_gather(pool: jnp.ndarray, pages: jnp.ndarray) -> jnp.ndarray:
    """Read each lane's logical KV view (B, n_pt * block_size, ...) out of
    the block pool. Unmapped (-1) page entries clamp to block 0 — the
    gathered garbage sits at logical positions beyond the lane's write
    index, which the per-lane validity mask already excludes (a lane
    maps a block before the first write into it, and position ``p`` is
    written in the same step it first becomes valid)."""
    nb, bs = pool.shape[0], pool.shape[1]
    B, n_pt = pages.shape
    out = pool[jnp.clip(pages, 0, nb - 1)]          # (B, n_pt, bs, ...)
    return out.reshape((B, n_pt * bs) + pool.shape[2:])


def _paged_attn_arm(S: int, window: int, T: int) -> str:
    """Which arm serves a paged-attention call: 'pallas' (the in-kernel
    page-table walk, kernels/paged_attention.py) or 'xla' (gather the
    logical view, the bitwise-authoritative fallback).

    Trace-time decision, mirroring the matmul dispatch: the kernel only
    serves S=1 decode without an active sliding window, and
    ``backend.forced_backend('xla')`` — the fault-tolerance degrade
    context — pins the XLA arm exactly as it does for the matmul
    kernels. Otherwise ``ICQ_PAGED_ATTN`` picks (pallas on TPU, xla
    elsewhere).
    """
    from repro.kernels import backend as _backend
    from repro.kernels.platform import default_paged_attn
    if S != 1 or (window and window < T):
        return "xla"
    if _backend._FORCED_BACKEND == "xla":
        return "xla"
    return default_paged_attn()


def _paged_pages_per_step(*, G: int, d: int, dv: int, bs: int, n_pt: int,
                          d2: int = 0, itemsize: int = 4) -> int:
    """Autotune-cache-aware pages-per-grid-step pick (trace time)."""
    from repro.kernels import autotune
    return autotune.paged_attn_pages_per_step(
        G=G, d=d, dv=dv, bs=bs, n_pt=n_pt, d2=d2, itemsize=itemsize)


def gqa_apply(
    p: Params,
    x: jnp.ndarray,               # (B, S, d_model)
    cfg,
    positions: jnp.ndarray,       # (B, S)
    cache: Optional[Params] = None,
    causal: bool = True,
    cross_kv: Optional[Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]] = None,
    seq_lens: Optional[jnp.ndarray] = None,   # (B,) valid tokens this chunk
) -> Tuple[jnp.ndarray, Optional[Params]]:
    B, S, _ = x.shape
    _check_seq_lens(seq_lens, cache)
    hd = cfg.resolved_head_dim
    q = linear(x, p["wq"]).reshape(B, S, cfg.n_heads, hd)

    if cross_kv is not None:
        enc_out, enc_mask = cross_kv  # (B, Tsrc, d_model), (B, Tsrc)
        Tsrc = enc_out.shape[1]
        k = linear(enc_out, p["wk"]).reshape(B, Tsrc, cfg.n_kv_heads, hd)
        v = linear(enc_out, p["wv"]).reshape(B, Tsrc, cfg.n_kv_heads, hd)
        pos_k = jnp.broadcast_to(
            jnp.arange(Tsrc, dtype=jnp.int32)[None], (B, Tsrc)
        )
        out = chunked_attention(
            q, k, v, positions, pos_k, enc_mask,
            causal=False, chunk=cfg.attn_chunk,
        )
        return linear(out.reshape(B, S, -1), p["wo"]), cache

    k = linear(x, p["wk"]).reshape(B, S, cfg.n_kv_heads, hd)
    v = linear(x, p["wv"]).reshape(B, S, cfg.n_kv_heads, hd)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    # NB: pin_bshd here was measured NET-HARMFUL for attention (unlike the
    # SSD mixer): deepseek train compute 21->344 s. See §Perf B5. Attention
    # activations are left to GSPMD propagation.

    if cache is None:
        out = chunked_attention(
            q, k, v, positions, positions,
            causal=causal, window=cfg.sliding_window, chunk=cfg.attn_chunk,
        )
        new_cache = None
    elif "pos" in cache:
        if cache["index"].ndim:
            raise NotImplementedError(
                "per-lane cache positions are not supported for the "
                "sliding-window ring cache (its pos column is batch-global)"
            )
        # ring-buffer cache of size W (sliding-window attention):
        # attend over [history ring ++ current chunk], then fold the last
        # W tokens back into the ring.
        idx = cache["index"]
        W = cfg.sliding_window
        k_full = jnp.concatenate([cache["k"], k.astype(cache["k"].dtype)], 1)
        v_full = jnp.concatenate([cache["v"], v.astype(cache["v"].dtype)], 1)
        pos_full = jnp.concatenate(
            [cache["pos"], idx + jnp.arange(S, dtype=jnp.int32)]
        )
        valid = jnp.broadcast_to((pos_full >= 0)[None], (B, W + S))
        out = chunked_attention(
            q, k_full, v_full, positions,
            jnp.broadcast_to(pos_full[None], (B, W + S)), valid,
            causal=True, window=W, chunk=cfg.attn_chunk,
        )
        if S >= W:
            kw, vw = k[:, -W:], v[:, -W:]
            write_pos = idx + S - W + jnp.arange(W, dtype=jnp.int32)
        else:
            kw, vw = k, v
            write_pos = idx + jnp.arange(S, dtype=jnp.int32)
        slots = write_pos % W
        ck = cache["k"].at[:, slots].set(kw.astype(cache["k"].dtype))
        cv = cache["v"].at[:, slots].set(vw.astype(cache["v"].dtype))
        cpos = cache["pos"].at[slots].set(write_pos)
        new_cache = dict(k=ck, v=cv, pos=cpos, index=idx + S)
    elif "pages" in cache:
        # paged per-lane cache: a global block pool + per-lane page
        # tables (serving/kv_pool.py). Logical positions are unchanged —
        # only the physical placement of cache rows differs — so the
        # attention math below is the contiguous per-lane branch verbatim
        # over the gathered logical view (bitwise-parity-pinned in
        # tests/test_kv_pool.py).
        idx = cache["index"]                        # (B,) per-lane
        pages = cache["pages"]                      # (B, n_pt), -1 unmapped
        T = pages.shape[1] * cache["k"].shape[1]    # logical capacity
        cols = _chunk_write_cols(idx, S, T, seq_lens)
        ck = _paged_scatter(cache["k"], pages, cols, k)
        cv = _paged_scatter(cache["v"], pages, cols, v)
        adv = S if seq_lens is None else seq_lens
        if _paged_attn_arm(S, cfg.sliding_window, T) == "pallas":
            # stream only live blocks through VMEM; the kernel masks
            # partial tails / unmapped pages in-kernel (same logical
            # semantics as the gather arm below, parity-pinned in
            # tests/test_paged_attention.py)
            from repro.kernels.paged_attention import paged_attention
            Hkv = cfg.n_kv_heads
            G = cfg.n_heads // Hkv
            bs = cache["k"].shape[1]
            qk = (q[:, 0].astype(jnp.float32) * hd**-0.5
                  ).reshape(B, Hkv, G, hd)
            pps = _paged_pages_per_step(
                G=G, d=hd, dv=hd, bs=bs, n_pt=pages.shape[1],
                itemsize=ck.dtype.itemsize)
            out = paged_attention(
                qk, ck, cv, pages, idx + adv, pages_per_step=pps,
            ).reshape(B, 1, cfg.n_heads, hd).astype(q.dtype)
        else:
            pos_k = jnp.broadcast_to(
                jnp.arange(T, dtype=jnp.int32)[None], (B, T))
            k_valid = pos_k < (idx + adv)[:, None]
            out = chunked_attention(
                q, _paged_gather(ck, pages), _paged_gather(cv, pages),
                positions, pos_k, k_valid,
                causal=True, window=cfg.sliding_window, chunk=cfg.attn_chunk,
            )
        new_cache = dict(k=ck, v=cv, index=idx + adv, pages=pages)
    else:
        idx = cache["index"]  # int32 #tokens cached: scalar, or (B,) per-lane
        if idx.ndim:
            # continuous batching: each lane writes at its own position.
            # Out-of-range writes (a recycled lane clamped at max_len, or
            # a ragged chunk tail masked by seq_lens) are dropped, never
            # wrapped.
            rows = jnp.arange(B, dtype=jnp.int32)[:, None]
            cols = _chunk_write_cols(idx, S, cache["k"].shape[1], seq_lens)
            ck = cache["k"].at[rows, cols].set(
                k.astype(cache["k"].dtype), mode="drop")
            cv = cache["v"].at[rows, cols].set(
                v.astype(cache["v"].dtype), mode="drop")
        else:
            ck = jax.lax.dynamic_update_slice(
                cache["k"], k.astype(cache["k"].dtype), (0, idx, 0, 0)
            )
            cv = jax.lax.dynamic_update_slice(
                cache["v"], v.astype(cache["v"].dtype), (0, idx, 0, 0)
            )
        T = ck.shape[1]
        adv = S if seq_lens is None else seq_lens   # per-lane tokens added
        pos_k = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32)[None], (B, T))
        k_valid = pos_k < (
            (idx + adv)[:, None] if idx.ndim else idx + adv)
        out = chunked_attention(
            q, ck, cv, positions, pos_k, k_valid,
            causal=True, window=cfg.sliding_window, chunk=cfg.attn_chunk,
        )
        new_cache = dict(k=ck, v=cv, index=idx + adv)
    return linear(out.reshape(B, S, -1), p["wo"]), new_cache


def _check_paged(paged, per_lane: bool):
    """Validate a ``paged=(num_blocks, block_size)`` cache request; returns
    (num_blocks, block_size) or None."""
    if paged is None:
        return None
    if not per_lane:
        raise NotImplementedError(
            "a paged KV cache requires per-lane positions "
            "(make_cache(..., per_lane=True))")
    num_blocks, block_size = paged
    if num_blocks < 1 or block_size < 1:
        raise ValueError(f"paged cache needs num_blocks >= 1 and "
                         f"block_size >= 1, got {paged}")
    return int(num_blocks), int(block_size)


def gqa_cache_init(cfg, batch: int, max_len: int,
                   per_lane: bool = False, paged=None) -> Params:
    """KV cache. ``per_lane=True`` gives the write index a (B,) batch axis
    (continuous-batching slot cache: every lane tracks its own position).
    ``paged=(num_blocks, block_size)`` replaces the contiguous (B, max_len)
    rows with a global block pool plus per-lane page tables (-1 =
    unmapped); cache HBM becomes num_blocks * block_size rows, decoupled
    from batch * max_len."""
    hd = cfg.resolved_head_dim
    dt = _dtype(cfg)
    paged = _check_paged(paged, per_lane)
    if cfg.sliding_window and cfg.sliding_window < max_len:
        if per_lane:
            raise NotImplementedError(
                "per-lane positions are not supported with a sliding-window "
                "ring cache; serve with max_len <= sliding_window or use "
                "the wave engine")
        W = cfg.sliding_window
        return dict(  # ring buffer
            k=jnp.zeros((batch, W, cfg.n_kv_heads, hd), dt),
            v=jnp.zeros((batch, W, cfg.n_kv_heads, hd), dt),
            pos=jnp.full((W,), -1, jnp.int32),
            index=jnp.zeros((), jnp.int32),
        )
    if paged is not None:
        nb, bs = paged
        n_pt = -(-max_len // bs)
        return dict(
            k=jnp.zeros((nb, bs, cfg.n_kv_heads, hd), dt),
            v=jnp.zeros((nb, bs, cfg.n_kv_heads, hd), dt),
            index=jnp.zeros((batch,), jnp.int32),
            pages=jnp.full((batch, n_pt), -1, jnp.int32),
        )
    return dict(
        k=jnp.zeros((batch, max_len, cfg.n_kv_heads, hd), dt),
        v=jnp.zeros((batch, max_len, cfg.n_kv_heads, hd), dt),
        index=jnp.zeros((batch,) if per_lane else (), jnp.int32),
    )


# ---------------------------------------------------------------------------
# MLA — multi-head latent attention (DeepSeek-V3 / MiniCPM3)
# ---------------------------------------------------------------------------

def mla_init(key, cfg) -> Params:
    dt = _dtype(cfg)
    H = cfg.n_heads
    nd, rd, vd = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    ks = jax.random.split(key, 8)
    p: Params = {}
    if cfg.q_lora_rank:
        p["w_dq"] = dense_init(ks[0], cfg.d_model, cfg.q_lora_rank, dt)
        p["q_norm"] = rmsnorm_init(cfg.q_lora_rank, dt)
        p["w_uq"] = dense_init(ks[1], cfg.q_lora_rank, H * (nd + rd), dt)
    else:
        p["w_q"] = dense_init(ks[1], cfg.d_model, H * (nd + rd), dt)
    p["w_dkv"] = dense_init(ks[2], cfg.d_model, cfg.kv_lora_rank, dt)
    p["kv_norm"] = rmsnorm_init(cfg.kv_lora_rank, dt)
    p["w_kr"] = dense_init(ks[3], cfg.d_model, rd, dt)
    p["w_uk"] = dense_init(ks[4], cfg.kv_lora_rank, H * nd, dt)
    p["w_uv"] = dense_init(ks[5], cfg.kv_lora_rank, H * vd, dt)
    p["wo"] = dense_init(ks[6], H * vd, cfg.d_model, dt)
    return p


def _mla_q(p, x, cfg, positions):
    B, S, _ = x.shape
    H, nd, rd = cfg.n_heads, cfg.qk_nope_head_dim, cfg.qk_rope_head_dim
    if cfg.q_lora_rank:
        q = linear(rmsnorm(linear(x, p["w_dq"]), p["q_norm"], cfg.norm_eps), p["w_uq"])
    else:
        q = linear(x, p["w_q"])
    q = q.reshape(B, S, H, nd + rd)
    q_nope, q_rope = q[..., :nd], q[..., nd:]
    q_rope = rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def mla_apply(
    p: Params,
    x: jnp.ndarray,
    cfg,
    positions: jnp.ndarray,
    cache: Optional[Params] = None,
    seq_lens: Optional[jnp.ndarray] = None,   # (B,) valid tokens this chunk
) -> Tuple[jnp.ndarray, Optional[Params]]:
    """Standard form for train/prefill; latent-absorbed form for decode.

    Cache holds the *compressed* latent (c_kv, k_rope): the MLA memory win.
    """
    B, S, _ = x.shape
    _check_seq_lens(seq_lens, cache)
    H = cfg.n_heads
    nd, rd, vd = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    r = cfg.kv_lora_rank
    scale = (nd + rd) ** -0.5

    q_nope, q_rope = _mla_q(p, x, cfg, positions)
    c_kv = rmsnorm(linear(x, p["w_dkv"]), p["kv_norm"], cfg.norm_eps)   # (B,S,r)
    k_rope = rope(linear(x, p["w_kr"])[:, :, None, :], positions, cfg.rope_theta)

    if cache is None:
        # standard (un-absorbed) attention
        k_nope = linear(c_kv, p["w_uk"]).reshape(B, S, H, nd)
        vv = linear(c_kv, p["w_uv"]).reshape(B, S, H, vd)
        q = jnp.concatenate([q_nope, q_rope], axis=-1)
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope, (B, S, H, rd))], axis=-1
        )
        out = chunked_attention(
            q, k, vv, positions, positions,
            causal=True, chunk=cfg.attn_chunk, scale=scale,
        )
        return linear(out.reshape(B, S, -1), p["wo"]), None

    # decode: absorb W_uk into q, attend directly over the latent cache
    idx = cache["index"]  # int32 #tokens cached: scalar, or (B,) per-lane
    pages = cache.get("pages")      # paged latent cache (see gqa_apply)
    if pages is not None:
        T = pages.shape[1] * cache["c_kv"].shape[1]
        cols = _chunk_write_cols(idx, S, T, seq_lens)
        cc = _paged_scatter(cache["c_kv"], pages, cols, c_kv)
        cr = _paged_scatter(cache["k_rope"], pages, cols, k_rope[:, :, 0, :])
        if _paged_attn_arm(S, 0, T) == "pallas":
            cc_log = cr_log = None      # in-kernel page walk, no gather
        else:
            cc_log = _paged_gather(cc, pages)
            cr_log = _paged_gather(cr, pages)
    elif idx.ndim:
        rows = jnp.arange(B, dtype=jnp.int32)[:, None]
        cols = _chunk_write_cols(idx, S, cache["c_kv"].shape[1], seq_lens)
        cc = cache["c_kv"].at[rows, cols].set(
            c_kv.astype(cache["c_kv"].dtype), mode="drop")
        cr = cache["k_rope"].at[rows, cols].set(
            k_rope[:, :, 0, :].astype(cache["k_rope"].dtype), mode="drop")
        cc_log, cr_log = cc, cr
    else:
        cc = jax.lax.dynamic_update_slice(
            cache["c_kv"], c_kv.astype(cache["c_kv"].dtype), (0, idx, 0)
        )
        cr = jax.lax.dynamic_update_slice(
            cache["k_rope"], k_rope[:, :, 0, :].astype(cache["k_rope"].dtype),
            (0, idx, 0),
        )
        cc_log, cr_log = cc, cr
    if pages is None:
        T = cc_log.shape[1]
    adv = S if seq_lens is None else seq_lens       # per-lane tokens added
    w_uk = as_dense(p["w_uk"]).reshape(r, H, nd)
    q_lat = jnp.einsum("bshn,rhn->bshr", q_nope, w_uk)           # absorbed q
    if pages is not None and cc_log is None:
        # Pallas paged-attention arm over the latent cache: the c_kv
        # pool doubles as K (latent half) and V; the rope side-channel
        # rides the kernel's q2/k2 score pair (Hkv=1, G=H).
        from repro.kernels.paged_attention import paged_attention
        nb_, bs_ = cc.shape[0], cc.shape[1]
        qm = (q_lat[:, 0].astype(jnp.float32) * scale).reshape(B, 1, H, r)
        q2 = (q_rope[:, 0].astype(jnp.float32) * scale).reshape(B, 1, H, rd)
        pps = _paged_pages_per_step(
            G=H, d=r, dv=r, bs=bs_, n_pt=pages.shape[1], d2=rd,
            itemsize=cc.dtype.itemsize)
        ctx = paged_attention(
            qm, cc.reshape(nb_, bs_, 1, r), cc.reshape(nb_, bs_, 1, r),
            pages, idx + adv,
            q2=q2, k2_pool=cr.reshape(nb_, bs_, 1, rd), pages_per_step=pps,
        ).reshape(B, 1, H, r).astype(q_lat.dtype)                # (B,1,H,r)
    else:
        pos_k = jnp.broadcast_to(
            jnp.arange(T, dtype=jnp.int32)[None], (B, T))
        k_valid = pos_k < ((idx + adv)[:, None] if idx.ndim else idx + adv)
        # treat latent dims + rope dims as one concatenated "head dim"
        q_cat = jnp.concatenate([q_lat, q_rope], axis=-1)        # (B,S,H,r+rd)
        k_cat = jnp.concatenate(
            [cc_log, cr_log], axis=-1)[:, :, None, :]            # (B,T,1,r+rd)
        ctx = chunked_attention(
            q_cat, k_cat, cc_log[:, :, None, :], positions, pos_k, k_valid,
            causal=True, chunk=cfg.attn_chunk, scale=scale,
        )                                                        # (B,S,H,r)
    w_uv = as_dense(p["w_uv"]).reshape(r, H, vd)
    out = jnp.einsum("bshr,rhv->bshv", ctx, w_uv)
    new_cache = dict(c_kv=cc, k_rope=cr, index=idx + adv)
    if pages is not None:
        new_cache["pages"] = pages
    return linear(out.reshape(B, S, -1), p["wo"]), new_cache


def mla_cache_init(cfg, batch: int, max_len: int,
                   per_lane: bool = False, paged=None) -> Params:
    dt = _dtype(cfg)
    paged = _check_paged(paged, per_lane)
    if paged is not None:
        nb, bs = paged
        n_pt = -(-max_len // bs)
        return dict(
            c_kv=jnp.zeros((nb, bs, cfg.kv_lora_rank), dt),
            k_rope=jnp.zeros((nb, bs, cfg.qk_rope_head_dim), dt),
            index=jnp.zeros((batch,), jnp.int32),
            pages=jnp.full((batch, n_pt), -1, jnp.int32),
        )
    return dict(
        c_kv=jnp.zeros((batch, max_len, cfg.kv_lora_rank), dt),
        k_rope=jnp.zeros((batch, max_len, cfg.qk_rope_head_dim), dt),
        index=jnp.zeros((batch,) if per_lane else (), jnp.int32),
    )


# ---------------------------------------------------------------------------
# SwiGLU MLP
# ---------------------------------------------------------------------------

def mlp_init(key, cfg, d_ff: Optional[int] = None) -> Params:
    dt = _dtype(cfg)
    d_ff = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    return dict(
        w_gate=dense_init(ks[0], cfg.d_model, d_ff, dt),
        w_up=dense_init(ks[1], cfg.d_model, d_ff, dt),
        w_down=dense_init(ks[2], d_ff, cfg.d_model, dt),
    )


def mlp_apply(p: Params, x: jnp.ndarray) -> jnp.ndarray:
    return linear(jax.nn.silu(linear(x, p["w_gate"])) * linear(x, p["w_up"]), p["w_down"])
