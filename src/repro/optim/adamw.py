"""AdamW with global-norm clipping and cosine schedule, pytree-native.

Optimizer-state dtype is configurable: bf16 moments halve the optimizer
HBM footprint (needed to fit the largest assigned architectures on a
single v5e pod — see EXPERIMENTS.md §Dry-run memory notes).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    state_dtype: str = "float32"    # 'bfloat16' to halve moment storage


def cosine_schedule(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip(
        (step - cfg.warmup_steps)
        / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0, 1.0,
    )
    return cfg.lr * warm * 0.5 * (1.0 + jnp.cos(jnp.pi * t))


def global_norm(tree: Any) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(sum(leaves))


def adamw_init(params: Any, cfg: AdamWConfig) -> Any:
    dt = jnp.dtype(cfg.state_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return dict(
        mu=jax.tree.map(zeros, params),
        nu=jax.tree.map(zeros, params),
        step=jnp.zeros((), jnp.int32),
    )


def adamw_update(
    params: Any, grads: Any, state: Any, cfg: AdamWConfig
) -> Tuple[Any, Any]:
    step = state["step"] + 1
    lr = cosine_schedule(cfg, step.astype(jnp.float32))

    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    grads = jax.tree.map(lambda g: g.astype(jnp.float32) * scale, grads)

    dt = jnp.dtype(cfg.state_dtype)
    bc1 = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        m32 = m.astype(jnp.float32) * cfg.b1 + g * (1 - cfg.b1)
        v32 = v.astype(jnp.float32) * cfg.b2 + g * g * (1 - cfg.b2)
        mhat = m32 / bc1
        vhat = v32 / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:  # decay matrices only (norms/bias exempt)
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (
            (p.astype(jnp.float32) - lr * delta).astype(p.dtype),
            m32.astype(dt),
            v32.astype(dt),
        )

    out = jax.tree.map(upd, params, grads, state["mu"], state["nu"])
    new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_mu = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_nu = jax.tree.map(lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return new_params, dict(mu=new_mu, nu=new_nu, step=step)
