"""Int8 gradient compression with error feedback.

Used on the inter-pod all-reduce path (the thin DCN/ICI link in the
multi-pod mesh): gradients are quantized to int8 with one f32 scale per
block before the cross-pod reduction, and the quantization residual is
carried to the next step (error feedback), which keeps SGD/Adam unbiased
in the long run. This is the paper's own economics applied to training:
a little side information (scales) makes aggressive quantization safe.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

BLOCK = 2048


def compress_int8(g: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Per-block symmetric int8 quantization. Returns (q, scales)."""
    flat = g.astype(jnp.float32).reshape(-1)
    pad = (-flat.size) % BLOCK
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=-1, keepdims=True) / 127.0
    q = jnp.round(blocks / jnp.maximum(scale, 1e-12)).astype(jnp.int8)
    return q, scale


def decompress_int8(
    q: jnp.ndarray, scale: jnp.ndarray, shape, dtype=jnp.float32
) -> jnp.ndarray:
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    size = 1
    for s in shape:
        size *= s
    return flat[:size].reshape(shape).astype(dtype)


def error_feedback_update(
    grads: Any, residuals: Any
) -> Tuple[Any, Any]:
    """Quantize (grad + residual) per leaf; return (dequantized grads to
    feed the reduction, new residuals)."""

    def one(g, r):
        gr = g.astype(jnp.float32) + r
        q, s = compress_int8(gr)
        deq = decompress_int8(q, s, g.shape)
        return deq, gr - deq

    out = jax.tree.map(one, grads, residuals)
    deq = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    res = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    return deq, res


def init_residuals(params: Any) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
