"""Deterministic synthetic data pipeline.

No datasets are available offline, so the corpus is a seeded synthetic
language: a Zipf unigram marginal shaped by an order-2 Markov mixing
process, giving text-like statistics (skewed unigrams, local structure a
small LM can learn, so perplexity deltas between quantization schemes are
meaningful). Batches are a pure function of (seed, step, shard), which
makes the pipeline:
  * restartable — resuming at step k needs no data-state checkpoint;
  * host-shardable — every host materializes only its shard;
  * straggler-free — no global shuffle coordination.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, List

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class SyntheticLM:
    vocab_size: int
    seq_len: int
    seed: int = 0
    zipf_a: float = 1.2
    markov_states: int = 64

    def _rng(self, step: int, shard: int) -> np.random.Generator:
        return np.random.default_rng(
            np.random.SeedSequence([self.seed, step, shard])
        )

    def _unigram(self) -> np.ndarray:
        ranks = np.arange(1, self.vocab_size + 1, dtype=np.float64)
        p = ranks ** (-self.zipf_a)
        return p / p.sum()

    def batch(self, step: int, shard: int, batch_size: int) -> Dict[str, np.ndarray]:
        """(batch_size, seq_len) tokens + next-token labels."""
        rng = self._rng(step, shard)
        p = self._unigram()
        # order-2 structure: token depends on a hidden Markov state that
        # biases a vocab band; keeps entropy below iid-zipf so models learn
        state = rng.integers(0, self.markov_states, size=batch_size)
        toks = np.empty((batch_size, self.seq_len + 1), dtype=np.int64)
        band = self.vocab_size // self.markov_states
        for t in range(self.seq_len + 1):
            base = rng.choice(self.vocab_size, size=batch_size, p=p)
            offset = state * band + rng.integers(0, max(band, 1), size=batch_size)
            use_state = rng.random(batch_size) < 0.5
            toks[:, t] = np.where(use_state, offset % self.vocab_size, base)
            state = (state + toks[:, t]) % self.markov_states
        return dict(
            tokens=toks[:, :-1].astype(np.int32),
            labels=toks[:, 1:].astype(np.int32),
        )


def make_batch_iterator(
    spec: SyntheticLM,
    batch_size: int,
    shard: int = 0,
    start_step: int = 0,
) -> Iterator[Dict[str, np.ndarray]]:
    step = start_step
    while True:
        yield spec.batch(step, shard, batch_size)
        step += 1


@dataclasses.dataclass
class CalibrationSet:
    """Small fixed set of sequences for Fisher-information estimation
    (the paper uses 128 C4 sequences; we use 128 synthetic ones)."""

    spec: SyntheticLM
    n_sequences: int = 128
    batch_size: int = 8

    def batches(self) -> List[Dict[str, jnp.ndarray]]:
        out = []
        for i in range(self.n_sequences // self.batch_size):
            b = self.spec.batch(step=10_000_000 + i, shard=0,
                                batch_size=self.batch_size)
            out.append({k: jnp.asarray(v) for k, v in b.items()})
        return out
