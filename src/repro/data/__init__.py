from repro.data.pipeline import (
    CalibrationSet,
    SyntheticLM,
    make_batch_iterator,
)

__all__ = ["SyntheticLM", "CalibrationSet", "make_batch_iterator"]
