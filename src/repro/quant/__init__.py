"""Baseline outlier-suppression techniques (paper Section 4.1)."""
from repro.quant.baselines import (
    SUPPRESSION_TECHNIQUES,
    grouped_rtn,
    incoherence_rtn,
    mixed_precision_rtn,
    vanilla_rtn,
)

__all__ = [
    "SUPPRESSION_TECHNIQUES",
    "vanilla_rtn",
    "grouped_rtn",
    "mixed_precision_rtn",
    "incoherence_rtn",
]
