"""Outlier-suppression baselines compared against ICQuant (paper §4.1).

Every technique returns ``(W_hat, bits_per_weight)`` so the benchmark
harness can sweep the rate/distortion trade-off of Figure 5:

  - vanilla_rtn:        plain per-row RTN.
  - grouped_rtn:        per-group scales/zeros (GPTQ/OmniQuant grouping).
  - mixed_precision_rtn: FP16 outliers + 16-bit raw indices (SqueezeLLM's
    dense-and-sparse storage model).
  - incoherence_rtn:    QuIP-style two-sided rotation by random orthogonal
    matrices before RTN (weights only).
"""
from __future__ import annotations

from functools import lru_cache
from typing import Callable, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.partition import outlier_mask
from repro.core.quantizers import (
    assign_codes,
    lookup,
    rtn_inlier_codebook,
)

QuantFn = Callable[..., Tuple[jnp.ndarray, float]]


def vanilla_rtn(W, n_bits: int) -> Tuple[jnp.ndarray, float]:
    W = jnp.asarray(W, jnp.float32)
    cb = rtn_inlier_codebook(W, jnp.ones_like(W, dtype=bool), n_bits)
    W_hat = lookup(assign_codes(W, cb), cb)
    # per-row lo/hi in fp16
    bits = n_bits + 2 * 16 / W.shape[-1]
    return W_hat, bits


def grouped_rtn(W, n_bits: int, group: int = 128) -> Tuple[jnp.ndarray, float]:
    W = jnp.asarray(W, jnp.float32)
    d_out, d_in = W.shape
    usable = (d_in // group) * group
    main, tail = W[:, :usable], W[:, usable:]
    g = main.reshape(d_out * (usable // group), group)
    cb = rtn_inlier_codebook(g, jnp.ones_like(g, dtype=bool), n_bits)
    g_hat = lookup(assign_codes(g, cb), cb).reshape(d_out, usable)
    if tail.shape[-1]:
        cb_t = rtn_inlier_codebook(tail, jnp.ones_like(tail, dtype=bool), n_bits)
        tail_hat = lookup(assign_codes(tail, cb_t), cb_t)
        g_hat = jnp.concatenate([g_hat, tail_hat], axis=-1)
    bits = n_bits + 2 * 16 / group  # fp16 scale+zero per group
    return g_hat, bits


def mixed_precision_rtn(
    W, n_bits: int, gamma: float = 0.005
) -> Tuple[jnp.ndarray, float]:
    """Outliers kept exactly (FP16) at 16 value bits + 16 index bits each."""
    W = jnp.asarray(W, jnp.float32)
    mask = outlier_mask(W, gamma)
    cb = rtn_inlier_codebook(W, ~mask, n_bits)
    W_q = lookup(assign_codes(W, cb), cb)
    W_hat = jnp.where(mask, W, W_q)
    bits = n_bits + gamma * (16 + 16) + 2 * 16 / W.shape[-1]
    return W_hat, bits


@lru_cache(maxsize=8)
def _hadamard(n: int) -> np.ndarray:
    """Sylvester Hadamard matrix, n a power of two, normalized."""
    H = np.array([[1.0]])
    while H.shape[0] < n:
        H = np.block([[H, H], [H, -H]])
    return (H / np.sqrt(n)).astype(np.float32)


def random_orthogonal(n: int, seed: int) -> np.ndarray:
    """Randomized Hadamard (H @ diag(signs)) when n is a power of two,
    else QR of a Gaussian. Both are orthogonal."""
    rng = np.random.default_rng(seed)
    if n & (n - 1) == 0:
        signs = rng.choice([-1.0, 1.0], size=n).astype(np.float32)
        return _hadamard(n) * signs[None, :]
    q, r = np.linalg.qr(rng.standard_normal((n, n)).astype(np.float32))
    return q * np.sign(np.diag(r))[None, :]


def incoherence_rtn(W, n_bits: int, seed: int = 0) -> Tuple[jnp.ndarray, float]:
    """Quantize U^T W V with random orthogonal U, V; rotate back.

    Storage for U, V is O(d^2) if random matrices are stored, but both
    sides are seed-reproducible (QuIP uses structured transforms), so the
    bit cost charged is the RTN cost only — matching how the paper plots
    it. The *compute* overhead at inference is the real cost.
    """
    W = jnp.asarray(W, jnp.float32)
    d_out, d_in = W.shape
    U = jnp.asarray(random_orthogonal(d_out, seed))
    V = jnp.asarray(random_orthogonal(d_in, seed + 1))
    Wr = U.T @ W @ V
    cb = rtn_inlier_codebook(Wr, jnp.ones_like(Wr, dtype=bool), n_bits)
    Wr_hat = lookup(assign_codes(Wr, cb), cb)
    W_hat = U @ Wr_hat @ V.T
    bits = n_bits + 2 * 16 / d_in
    return W_hat, bits


SUPPRESSION_TECHNIQUES: Dict[str, QuantFn] = {
    "vanilla_rtn": vanilla_rtn,
    "grouped_rtn": grouped_rtn,
    "mixed_precision_rtn": mixed_precision_rtn,
    "incoherence_rtn": incoherence_rtn,
}
