"""DeepSeek-V3-671B — MoE (1 shared + 256 routed, top-8), MLA, MTP.
d_ff=18432 applies to the 3 leading dense layers; experts are 2048-wide.
[arXiv:2412.19437; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=128,
    n_kv_heads=128,
    d_ff=18432,                 # dense (first 3) layers
    vocab_size=129280,
    attn_type="mla",
    q_lora_rank=1536,
    kv_lora_rank=512,
    qk_nope_head_dim=128,
    qk_rope_head_dim=64,
    v_head_dim=128,
    n_experts=256,
    n_shared_experts=1,
    experts_per_token=8,
    moe_d_ff=2048,
    first_dense_layers=3,
    mtp=True,
    rope_theta=10000.0,
)
