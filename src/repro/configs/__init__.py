"""Architecture registry: ``get_config(arch_id)`` + the shape grid."""
from repro.configs.base import (
    SHAPE_BY_NAME,
    SHAPES,
    ModelConfig,
    ShapeConfig,
    shape_applicable,
    smoke_variant,
)

from repro.configs.minicpm3_4b import CONFIG as _minicpm3
from repro.configs.internlm2_1_8b import CONFIG as _internlm2
from repro.configs.phi3_mini_3_8b import CONFIG as _phi3
from repro.configs.llama3_2_1b import CONFIG as _llama32
from repro.configs.pixtral_12b import CONFIG as _pixtral
from repro.configs.mamba2_130m import CONFIG as _mamba2
from repro.configs.seamless_m4t_large_v2 import CONFIG as _seamless
from repro.configs.hymba_1_5b import CONFIG as _hymba
from repro.configs.deepseek_v3_671b import CONFIG as _deepseek
from repro.configs.mixtral_8x7b import CONFIG as _mixtral
from repro.configs.llama2_7b import CONFIG as _llama2

ARCHITECTURES = {
    c.name: c
    for c in (
        _minicpm3, _internlm2, _phi3, _llama32, _pixtral,
        _mamba2, _seamless, _hymba, _deepseek, _mixtral,
    )
}

EXTRA_CONFIGS = {_llama2.name: _llama2}


def _canon(name: str) -> str:
    """Spelling-insensitive arch key: 'llama3_2_1b' == 'llama3.2-1b'."""
    return "".join(ch for ch in name.lower() if ch.isalnum())


def get_config(name: str) -> ModelConfig:
    if name in ARCHITECTURES:
        return ARCHITECTURES[name]
    if name in EXTRA_CONFIGS:
        return EXTRA_CONFIGS[name]
    aliases = {_canon(k): c for k, c in {**EXTRA_CONFIGS,
                                         **ARCHITECTURES}.items()}
    hit = aliases.get(_canon(name))
    if hit is not None:
        return hit
    raise KeyError(
        f"unknown arch {name!r}; available: {sorted(ARCHITECTURES)}"
    )


__all__ = [
    "ModelConfig", "ShapeConfig", "SHAPES", "SHAPE_BY_NAME",
    "ARCHITECTURES", "get_config", "smoke_variant", "shape_applicable",
]
