"""Hymba-1.5B — hybrid: parallel attention + Mamba heads per layer.
Simplifications (DESIGN.md): the parallel heads are combined with a fixed
0.5/0.5 mean (Hymba learns per-head fusion scalars) and all layers use
the same 1024-token sliding window (Hymba interleaves 3 global layers);
meta-tokens are omitted. [arXiv:2411.13676; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b",
    family="hybrid",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    head_dim=64,
    d_ff=5504,
    vocab_size=32001,
    hybrid_ssm=True,
    sliding_window=1024,
    ssm_state=16,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_groups=1,
)
