"""Model/config schema shared by all architectures and shapes."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0               # 0 -> d_model // n_heads

    # attention
    attn_type: str = "gqa"          # gqa | mla | none
    sliding_window: int = 0         # 0 = full attention
    rope_theta: float = 10000.0

    # MLA (DeepSeek / MiniCPM3 latent attention)
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_head_dim: int = 0
    qk_rope_head_dim: int = 0
    v_head_dim: int = 0

    # MoE
    n_experts: int = 0
    n_shared_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0
    first_dense_layers: int = 0     # leading dense layers (DeepSeek-V3)
    capacity_factor: float = 1.25
    # grouped dispatch: per-batch-row expert queues -> the position cumsum
    # stays shard-local and the only cross-device exchange is the inherent
    # token all-to-all (see EXPERIMENTS.md §Perf, deepseek hillclimb)
    moe_grouped_dispatch: bool = False
    # int8 expert dispatch/combine on the wire: per-slot scales, halves
    # the MoE all-to-all bytes (the dominant collective for DeepSeek-V3
    # training — see §Perf hillclimb B)
    moe_int8_dispatch: bool = False

    # multi-token prediction (DeepSeek-V3 MTP, depth 1)
    mtp: bool = False

    # SSM (Mamba2 SSD)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_groups: int = 1
    conv_width: int = 4
    ssd_chunk: int = 256

    # hybrid (Hymba): parallel attention + SSM heads per layer
    hybrid_ssm: bool = False

    # encoder-decoder (Seamless backbone)
    encoder_layers: int = 0
    decoder_layers: int = 0
    max_source_len: int = 0

    # modality frontend stub: 'none' | 'patch' (VLM) | 'frames' (audio)
    frontend: str = "none"
    frontend_len: int = 0           # prefix length of precomputed embeddings

    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    param_dtype: str = "float32"    # smoke tests f32; dry-run bf16
    remat: bool = True
    # scan layer stacks (compile-time/HLO-size win) or unroll them (exact
    # cost_analysis: XLA counts a scan body once, not x trip-count — the
    # dry-run unrolls so roofline terms are correct)
    scan_layers: bool = True
    attn_chunk: int = 1024          # KV-chunk for online-softmax attention
    # quantization defaults (the paper's technique, first-class)
    quant_bits: int = 0             # 0 = no quantization (FP path)
    quant_gamma: float = 0.05
    quant_method: str = "rtn"

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    @property
    def attention_free(self) -> bool:
        return self.attn_type == "none"

    @property
    def subquadratic(self) -> bool:
        """Can this arch run 500k-token decode without a dense KV cache?"""
        return self.attention_free or self.hybrid_ssm or self.sliding_window > 0


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                       # train | prefill | decode

    @property
    def is_train(self) -> bool:
        return self.kind == "train"


SHAPES: Tuple[ShapeConfig, ...] = (
    ShapeConfig("train_4k", 4096, 256, "train"),
    ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    ShapeConfig("decode_32k", 32768, 128, "decode"),
    ShapeConfig("long_500k", 524288, 1, "decode"),
)

SHAPE_BY_NAME = {s.name: s for s in SHAPES}


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> Optional[str]:
    """None if the (arch, shape) cell runs; else a skip reason (DESIGN.md)."""
    if shape.name == "long_500k" and not cfg.subquadratic:
        return "pure full-attention arch: 500k dense KV decode excluded by design"
    if shape.kind == "decode" and cfg.is_encdec and cfg.decoder_layers == 0:
        return "encoder-only: no decode step"
    return None


def smoke_variant(cfg: ModelConfig) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests."""
    return dataclasses.replace(
        cfg,
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads else 0,
        head_dim=16 if cfg.head_dim else 0,
        d_ff=128,
        vocab_size=256,
        q_lora_rank=32 if cfg.q_lora_rank else 0,
        kv_lora_rank=16 if cfg.kv_lora_rank else 0,
        qk_nope_head_dim=8 if cfg.qk_nope_head_dim else 0,
        qk_rope_head_dim=8 if cfg.qk_rope_head_dim else 0,
        v_head_dim=8 if cfg.v_head_dim else 0,
        n_experts=4 if cfg.n_experts else 0,
        experts_per_token=min(cfg.experts_per_token, 2) if cfg.n_experts else 0,
        moe_d_ff=64 if cfg.moe_d_ff else 0,
        # dropless in smoke tests: capacity >= worst-case routing so that
        # cached decode is bit-identical to the full forward pass
        capacity_factor=float(cfg.n_experts) if cfg.n_experts else 1.25,
        first_dense_layers=min(cfg.first_dense_layers, 1),
        ssm_state=16 if cfg.ssm_state else 0,
        ssm_head_dim=16 if cfg.ssm_state or cfg.hybrid_ssm else 64,
        ssd_chunk=16,
        encoder_layers=2 if cfg.encoder_layers else 0,
        decoder_layers=2 if cfg.decoder_layers else 0,
        max_source_len=32 if cfg.max_source_len else 0,
        frontend_len=8 if cfg.frontend_len else 0,
        sliding_window=min(cfg.sliding_window, 32) if cfg.sliding_window else 0,
        attn_chunk=32,
        remat=False,
        param_dtype="float32",
    )
