"""Pixtral-12B — VLM: Mistral-Nemo-style text backbone; the Pixtral ViT
frontend is a stub supplying precomputed patch embeddings (per the
assignment, frontends are stubs). [hf:mistralai/Pixtral-12B-2409; unverified]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="pixtral-12b",
    family="vlm",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=131072,
    rope_theta=1000000.0,
    frontend="patch",
    frontend_len=256,          # precomputed patch-embedding prefix
)
