"""SeamlessM4T-large-v2 backbone — encoder-decoder, multimodal; the
speech/frame frontend is a stub supplying precomputed frame embeddings.
[arXiv:2308.11596; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2",
    family="encdec",
    n_layers=24,                # per side (24 enc + 24 dec)
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=8192,
    vocab_size=256206,
    encoder_layers=24,
    decoder_layers=24,
    max_source_len=1024,        # stub frame-embedding length
    frontend="frames",
    frontend_len=1024,
)
