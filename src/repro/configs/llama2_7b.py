"""Llama2-7B — the paper's primary analysis model (statistics benchmarks
use this geometry for synthetic weight matrices). Not one of the 10
assigned dry-run architectures. [arXiv:2307.09288]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama2-7b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=32,
    d_ff=11008,
    vocab_size=32000,
)
