"""Production mesh construction.

Defined as functions (never module-level constants) so importing this
module never touches JAX device state — the dry-run must set
XLA_FLAGS before *any* jax initialization.
"""
from __future__ import annotations

import jax


def _auto(n):
    return (jax.sharding.AxisType.Auto,) * n


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 chips) or 2x16x16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, axis_types=_auto(len(axes)))


def make_host_mesh(model_parallel: int = 1):
    """Small mesh over whatever devices exist (tests / CPU)."""
    n = len(jax.devices())
    mp = min(model_parallel, n)
    return jax.make_mesh((n // mp, mp), ("data", "model"),
                         axis_types=_auto(2))
