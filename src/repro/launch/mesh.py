"""Production mesh construction.

Defined as functions (never module-level constants) so importing this
module never touches JAX device state — the dry-run must set
XLA_FLAGS before *any* jax initialization.
"""
from __future__ import annotations

import jax


def make_mesh(shape, axes):
    """jax.make_mesh across jax versions: ``axis_types`` (and
    ``jax.sharding.AxisType`` itself) only exist in newer releases, and
    Auto is the default there anyway — so fall back to plain make_mesh
    on older jax instead of crashing every driver at import-of-use."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(axis_type.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 chips) or 2x16x16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh(model_parallel: int = 1):
    """Small mesh over whatever devices exist (tests / CPU)."""
    n = len(jax.devices())
    mp = min(model_parallel, n)
    return make_mesh((n // mp, mp), ("data", "model"))
