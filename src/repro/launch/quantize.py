"""PTQ driver: calibrate Fisher sensitivity, then ICQuant every linear.

``python -m repro.launch.quantize --arch <id> --bits 2 --gamma 0.05``

Pipeline (mirrors paper Appendix E):
  1. train or load a model (smoke-size by default on this container);
  2. estimate diagonal Fisher information with 128 calibration sequences
     from the synthetic corpus (jax.grad of the LM loss);
  3. for every 2-D linear weight: ICQuant with per-output-channel
     partition, Fisher-weighted K-means (or RTN), gap-coded indices;
  4. emit bits/weight accounting + quantized params ready for serving.
"""
from __future__ import annotations

import argparse
from typing import Any, Dict, Optional, Set, Tuple

import jax
import jax.numpy as jnp

from repro.core import icquant
from repro.core.sensitivity import fisher_information, normalize_fisher
from repro.data import CalibrationSet, SyntheticLM
from repro.launch.steps import loss_fn

# leaves never quantized (norms, scalars, routers, SSD dynamics)
_SKIP_NAMES = {"router", "A_log", "D", "dt_bias", "conv_w", "conv_b",
               "q_norm", "kv_norm", "ln1", "ln2", "ln_cross", "norm",
               "final_norm", "enc_norm", "mtp_norm", "embed"}


def _leaf_name(path) -> str:
    return getattr(path[-1], "key", getattr(path[-1], "name", str(path[-1])))


def quantizable(path, leaf) -> bool:
    return (
        hasattr(leaf, "ndim") and leaf.ndim >= 2
        and _leaf_name(path) not in _SKIP_NAMES
    )


def compute_fisher(params, cfg, n_sequences: int = 128, seq_len: int = 256,
                   batch_size: int = 8):
    spec = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=seq_len)
    cal = CalibrationSet(spec, n_sequences=n_sequences, batch_size=batch_size)
    return fisher_information(
        lambda p, b: loss_fn(p, cfg, b)[0], params, cal.batches()
    )


def quantize_tree(
    params: Any,
    n_bits: int,
    gamma: float = 0.05,
    method: str = "rtn",
    fisher: Optional[Any] = None,
    b: Optional[int] = None,
) -> Tuple[Any, Dict[str, float]]:
    """Replace every quantizable 2-D (or expert/layer-stacked) weight with
    an ICQPacked. Stacked weights (L, d_in, d_out) / (L, E, d, f) are
    quantized per 2-D slice and restacked (the ICQPacked pytree keeps the
    leading axes). Returns (new_params, bits accounting)."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    fisher_flat = None
    if fisher is not None:
        fisher_flat = jax.tree.leaves(fisher)

    out = []
    total_bits = 0.0
    total_weights = 0
    for i, (path, leaf) in enumerate(flat):
        if not quantizable(path, leaf):
            out.append(leaf)
            continue
        fw = fisher_flat[i] if fisher_flat is not None else None
        lead = leaf.shape[:-2]
        d_in, d_out = leaf.shape[-2], leaf.shape[-1]
        # per output channel = rows of W^T
        mats = jnp.moveaxis(leaf, -1, -2).reshape(-1, d_out, d_in)
        fmats = (
            None if fw is None
            else jnp.moveaxis(fw, -1, -2).reshape(-1, d_out, d_in)
        )
        packs = [
            icquant.quantize(
                mats[j], n_bits, gamma=gamma, b=b, method=method,
                fisher=None if fmats is None else normalize_fisher(fmats[j]),
            )
            for j in range(mats.shape[0])
        ]
        # pad gap streams to a common width before stacking slices
        s_max = max(pk.symbols.shape[-1] for pk in packs)
        flag = (1 << packs[0].b) - 1
        packs = [
            pk if pk.symbols.shape[-1] == s_max
            else jax.tree.unflatten(
                jax.tree.structure(pk),
                [
                    jnp.pad(leafx, ((0, 0), (0, s_max - leafx.shape[-1])),
                            constant_values=flag)
                    if name == "symbols" else leafx
                    for name, leafx in zip(
                        ("codes", "symbols", "counts", "codebooks"),
                        jax.tree.leaves(pk),
                    )
                ],
            )
            for pk in packs
        ]
        packed = jax.tree.map(lambda *xs: jnp.stack(xs), *packs)
        if not lead:
            packed = jax.tree.map(lambda x: x[0], packed)
        else:
            # restore leading axes on the array leaves
            packed = jax.tree.map(
                lambda x: x.reshape(lead + x.shape[1:]), packed
            )
        bits = packs[0].bits_per_weight()["total"]
        total_bits += bits * leaf.size
        total_weights += leaf.size
        out.append(packed)

    new_params = jax.tree.unflatten(treedef, out)
    acct = dict(
        mean_bits=total_bits / max(total_weights, 1),
        quantized_weights=total_weights,
    )
    return new_params, acct


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--bits", type=int, default=2)
    ap.add_argument("--gamma", type=float, default=0.05)
    ap.add_argument("--method", choices=["rtn", "kmeans"], default="rtn")
    ap.add_argument("--no-fisher", action="store_true")
    args = ap.parse_args()

    from repro.configs import get_config, smoke_variant
    from repro.models import init_model

    cfg = smoke_variant(get_config(args.arch))
    params = init_model(jax.random.PRNGKey(0), cfg)
    fisher = None
    if args.method == "kmeans" and not args.no_fisher:
        fisher = compute_fisher(params, cfg, n_sequences=32, seq_len=64)
    qparams, acct = quantize_tree(
        params, args.bits, gamma=args.gamma, method=args.method, fisher=fisher
    )
    print(f"[quantize] {cfg.name}: {acct['mean_bits']:.3f} bits/weight over "
          f"{acct['quantized_weights']/1e6:.2f}M weights")


if __name__ == "__main__":
    main()
