"""Serving driver: train briefly, optionally ICQuant the weights, then
serve a queue of requests through the GenerationEngine.

``python -m repro.launch.serve --arch <id> [--bits 3] [--requests 8]``

Request length policy: a request needs ``len(prompt) + max_new_tokens``
cache positions. Requests whose *prompt* cannot fit ``--max-len`` are
rejected up front; requests whose prompt fits but whose token budget
overflows the cache are truncated to the remaining budget with a
warning (``--strict-len`` rejects those too instead of truncating).
"""
from __future__ import annotations

import argparse
import signal

import numpy as np

from repro.configs import get_config, smoke_variant
from repro.launch.quantize import quantize_tree
from repro.launch.train import train
from repro.serving import GenerationEngine, Request, SamplingParams
from repro.serving.faults import FaultInjector, parse_fault_plan


def _install_engine_signals(engine) -> None:
    """Graceful drain on SIGINT/SIGTERM (in-process engine path): the
    first signal refuses new admissions and lets in-flight lanes finish
    with their usual typed statuses; a second signal cancels everything
    still pending (typed 'cancelled'). Either way the final
    status-count ledger prints with exactly one status per rid."""
    state = {"n": 0}

    def handler(signum, frame):
        state["n"] += 1
        if state["n"] == 1:
            print(f"[serve] signal {signum}: draining (no new admissions; "
                  f"in-flight lanes finish)", flush=True)
            engine.request_drain()
        else:
            print(f"[serve] signal {signum}: cancelling pending requests",
                  flush=True)
            for rid in list(engine.metrics.requests):
                if rid not in engine.completed:
                    try:
                        engine.cancel(rid)
                    except KeyError:
                        pass

    signal.signal(signal.SIGINT, handler)
    signal.signal(signal.SIGTERM, handler)


def _install_service_signals(svc) -> None:
    """Same drain contract for the replica service: first signal drains
    (frontend refuses submits, replicas finish in-flight work, WAL
    records go terminal), second cancels everything still pending."""
    state = {"n": 0}

    def handler(signum, frame):
        state["n"] += 1
        if state["n"] == 1:
            print(f"[serve] signal {signum}: draining (no new admissions; "
                  f"in-flight lanes finish)", flush=True)
            svc.begin_drain()
        else:
            print(f"[serve] signal {signum}: cancelling pending requests",
                  flush=True)
            for rid, (status, _) in svc.router.results().items():
                if status is None:
                    try:
                        svc.router.cancel(rid)
                    except KeyError:
                        pass

    signal.signal(signal.SIGINT, handler)
    signal.signal(signal.SIGTERM, handler)


def _serve_replicas(args, params, cfg, sampling):
    """Replica-service path (--replicas N): WAL + N supervised engine
    replicas + router + TCP frontend, driven through the retrying
    client — the full resilient-serving stack end to end."""
    from repro.serving import (FrontendUnavailable, RequestRejected,
                               ServingClient, ServingService)
    from repro.serving.wal import default_wal_path

    if args.sessions:
        raise SystemExit("[serve] the --sessions workload is in-process "
                         "only; drop --replicas")
    if args.mode == "wave":
        raise SystemExit("[serve] --replicas requires the continuous "
                         "engine; drop --mode wave")
    if args.temperature > 0:
        raise SystemExit("[serve] the TCP frontend serves greedy requests; "
                         "drop --temperature")

    def factory():
        faults = None
        if args.fault_plan is not None or args.fault_rate is not None:
            # one injector per engine: a restarted replica gets a fresh
            # (deterministic) schedule, not a half-consumed one
            faults = FaultInjector(
                parse_fault_plan(args.fault_plan) if args.fault_plan
                else None,
                seed=args.fault_seed if args.fault_seed is not None else 0,
                rate=args.fault_rate if args.fault_rate is not None else 0.0)
        return GenerationEngine(
            params, cfg, batch_size=args.batch, max_len=args.max_len,
            weight_cache=args.weight_cache, runtime_fmt=args.runtime_fmt,
            mode="continuous", sampling=sampling, seed=args.seed,
            prefill_chunk=args.prefill_chunk, kv_layout=args.kv_layout,
            kv_block_size=args.kv_block_size, kv_blocks=args.kv_blocks,
            max_queue=args.max_queue, shed_policy=args.shed_policy,
            faults=faults, degrade_steps=args.degrade_steps,
            prefix_cache=args.prefix_cache, session_ttl=args.session_ttl,
            spec_decode=args.spec_decode, spec_k=args.spec_k,
            spec_draft=args.draft)

    wal_path = args.wal if args.wal is not None else default_wal_path()
    svc = ServingService(factory, n_replicas=args.replicas,
                         wal_path=wal_path, max_pending=args.max_pending,
                         supervise_s=0.05)
    host, port = svc.start()
    _install_service_signals(svc)
    print(f"[serve] frontend: {args.replicas} replicas on {host}:{port}"
          + (f", wal={wal_path}" if wal_path else ""))
    if svc.replayed:
        print(f"[serve] WAL replay: {svc.replayed} unfinished request(s) "
              f"resubmitted")

    if args.kill_replica:
        idx, after = args.kill_replica.split(":")
        name, threshold = f"r{int(idx)}", int(after)
        fired = [False]

        def trigger(rid, tok):
            if not fired[0] and svc.metrics.tokens_streamed >= threshold:
                fired[0] = True
                print(f"[serve] KILL {name} after {threshold} streamed "
                      f"tokens (mid-decode)", flush=True)
                svc.router.kill(name)

        svc.router.token_observer = trigger

    cli = ServingClient(host, port)
    cli.metrics = svc.metrics     # client retries land in the ledger
    rng = np.random.default_rng(args.seed)
    rids, prompt_lens = [], {}
    for i in range(args.requests):
        prompt = rng.integers(0, cfg.vocab_size,
                              size=rng.integers(4, 12)).astype(np.int32)
        max_new = args.max_new
        budget = len(prompt) + max_new
        if len(prompt) >= args.max_len:
            print(f"[serve] REJECT req {i}: prompt length {len(prompt)} "
                  f">= max_len {args.max_len}")
            continue
        if budget > args.max_len:
            if args.strict_len:
                print(f"[serve] REJECT req {i}: over budget (--strict-len)")
                continue
            max_new = args.max_len - len(prompt)
        try:
            rid = cli.submit([int(t) for t in prompt],
                             max_new_tokens=max_new,
                             deadline_s=args.deadline,
                             max_queue_wait_s=args.max_queue_wait)
            rids.append(rid)
            prompt_lens[rid] = len(prompt)
        except RequestRejected as e:
            print(f"[serve] REJECT req {i}: {e}")
        except FrontendUnavailable as e:
            print(f"[serve] SHED req {i}: {e}")

    results = {}
    for rid in rids:
        try:
            results[rid] = cli.wait(rid, timeout=600.0)
        except TimeoutError as e:
            results[rid] = ("failed", [])
            print(f"[serve] TIMEOUT waiting on req {rid}: {e}")
    for rid in sorted(results):
        status, tokens = results[rid]
        print(f"[serve] req {rid}: prompt_len={prompt_lens.get(rid)} "
              f"generated={tokens} status={status}")

    svc.begin_drain()
    svc.shutdown()
    svc.check_shutdown_invariants()
    m = svc.metrics.summary()
    print(f"[serve] service: {args.replicas} replicas, "
          f"failovers={int(m['failovers'])}, "
          f"restarts={int(m['replica_restarts'])}, "
          f"kills={int(m['replica_kills'])}, "
          f"retries={int(m['retries'])}, "
          f"sheds={int(m['frontend_sheds'])}, "
          f"duplicate_terminals={int(m['duplicate_terminals'])}, "
          f"wal_replayed={int(m['wal_replayed'])}, "
          f"heartbeat age max {m['heartbeat_age_max']:.2f}s, "
          f"peak pending {int(m['peak_pending'])}")
    counts = {k[len("status_"):]: int(v) for k, v in m.items()
              if k.startswith("status_")}
    statuses = " ".join(f"{k}={v}" for k, v in sorted(counts.items()))
    print(f"[serve] statuses: {statuses or 'none'}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--bits", type=int, default=0,
                    help="ICQuant bits (0 = serve FP weights)")
    ap.add_argument("--gamma", type=float, default=0.05)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--max-len", type=int, default=64,
                    help="KV-cache length: every request must satisfy "
                         "len(prompt) + max_new_tokens <= max_len "
                         "(over-budget requests are truncated with a "
                         "warning, or rejected with --strict-len)")
    ap.add_argument("--strict-len", action="store_true",
                    help="reject over-budget requests instead of "
                         "truncating their token budget")
    ap.add_argument("--mode", default="auto",
                    choices=["auto", "continuous", "wave"],
                    help="'continuous' = slot scheduler with lane "
                         "recycling (default where supported), 'wave' = "
                         "legacy wave-synchronous static batching")
    ap.add_argument("--prefill-chunk", type=int, default=None,
                    help="continuous-mode chunked prefill: drain admitted "
                         "prompts S tokens per launch through a second "
                         "jitted chunk program (routes prompt matmuls "
                         "through the large-M dequant+MXU kernel arm, "
                         "cutting TTFT for long prompts). 1 = walk prompts "
                         "token-by-token inside the decode program (the "
                         "legacy behavior, bit-for-bit); default follows "
                         "ICQ_PREFILL_CHUNK (1). Greedy output is "
                         "token-identical either way")
    ap.add_argument("--kv-layout", default=None,
                    choices=["contiguous", "paged"],
                    help="KV-cache layout (continuous mode): 'contiguous' "
                         "charges batch*max_len rows up front; 'paged' "
                         "serves from a block pool with per-lane page "
                         "tables, decoupling cache HBM from max_len "
                         "(allocator-aware admission + preempt-and-requeue "
                         "under pressure; greedy output is token-identical "
                         "either way). Default follows ICQ_KV_LAYOUT "
                         "(contiguous)")
    ap.add_argument("--kv-block-size", default=None,
                    help="paged KV: cache rows per block, or 'auto' to "
                         "use the block-size sweep winner from the shared "
                         "autotune cache (default ICQ_KV_BLOCK_SIZE / 16)")
    ap.add_argument("--kv-blocks", type=int, default=None,
                    help="paged KV: physical blocks in the pool (default "
                         "batch * ceil(max_len / block_size) = contiguous "
                         "capacity; shrink to oversubscribe and trade "
                         "preemptions for HBM)")
    ap.add_argument("--max-queue", type=int, default=None,
                    help="bounded submit queue: refuse admission beyond "
                         "this many waiting requests (default "
                         "ICQ_MAX_QUEUE / unbounded)")
    ap.add_argument("--shed-policy", default=None,
                    choices=["reject", "shed-oldest"],
                    help="what a full queue sheds: 'reject' the new "
                         "request or 'shed-oldest' waiting one (default "
                         "ICQ_SHED_POLICY / reject)")
    ap.add_argument("--deadline", type=float, default=None,
                    help="per-request deadline in seconds from arrival; "
                         "lanes past it finish with status 'timeout' "
                         "(default: none)")
    ap.add_argument("--max-queue-wait", type=float, default=None,
                    help="per-request bound on queue wait in seconds; "
                         "requests not admitted in time finish with "
                         "status 'expired' (default: none)")
    ap.add_argument("--fault-plan", default=None,
                    help="deterministic fault injection, e.g. "
                         "'3:nan,6:raise' = launch 3 produces NaN "
                         "logits, launch 6 raises (default "
                         "ICQ_FAULT_PLAN)")
    ap.add_argument("--fault-rate", type=float, default=None,
                    help="seeded random fault injection probability per "
                         "launch (default ICQ_FAULT_RATE / 0)")
    ap.add_argument("--fault-seed", type=int, default=None,
                    help="PRNG seed for --fault-rate draws (default "
                         "ICQ_FAULT_SEED / 0)")
    ap.add_argument("--degrade-steps", type=int, default=None,
                    help="after a recovered fault, pin this many launches "
                         "to the bitwise-exact XLA arm before returning "
                         "to the fast path (default ICQ_DEGRADE_STEPS "
                         "/ 8)")
    ap.add_argument("--prefix-cache", action="store_true", default=None,
                    help="share identical prompt prefixes copy-on-write "
                         "across requests and retain finished chains for "
                         "reuse (paged KV only; default ICQ_PREFIX_CACHE "
                         "/ off). Implies --kv-layout paged when the "
                         "layout is unset")
    ap.add_argument("--sessions", type=int, default=0,
                    help="run a multi-turn chat workload instead of the "
                         "independent-request one: this many concurrent "
                         "sessions sharing one system prompt, each turn "
                         "extending its own history (requires/implies "
                         "--prefix-cache; turn 2+ prompts warm-start from "
                         "the previous turn's retained blocks)")
    ap.add_argument("--turns", type=int, default=3,
                    help="turns per session for --sessions (default 3)")
    ap.add_argument("--session-ttl", type=float, default=None,
                    help="idle seconds before a session's retained blocks "
                         "are dropped (default ICQ_SESSION_TTL / 300)")
    ap.add_argument("--shared-prefix", type=int, default=12,
                    help="shared system-prompt length in tokens for the "
                         "--sessions workload (default 12)")
    ap.add_argument("--replicas", type=int, default=0,
                    help="serve through the resilient service layer: this "
                         "many supervised engine replicas behind the TCP "
                         "frontend + router (WAL-journaled, failover on "
                         "replica death), driven by the retrying client. "
                         "0 (default) = the in-process engine path, "
                         "bit-for-bit the pre-service behavior")
    ap.add_argument("--wal", default=None,
                    help="request-journal path for --replicas (default "
                         "ICQ_WAL_PATH / no journal); an existing journal "
                         "is recovered and its unfinished requests "
                         "replayed before new traffic")
    ap.add_argument("--max-pending", type=int, default=None,
                    help="frontend backpressure bound for --replicas: shed "
                         "submits (retryable) beyond this many pending "
                         "requests (default: unbounded)")
    ap.add_argument("--kill-replica", default=None, metavar="I:N",
                    help="chaos drill for --replicas: hard-kill replica I "
                         "once N tokens have streamed service-wide "
                         "(mid-decode); supervision must fail its "
                         "requests over and restart it")
    ap.add_argument("--spec-decode", action="store_true", default=None,
                    help="speculative decoding: draft-and-verify pure-"
                         "decode iterations (greedy lanes only; output "
                         "token-identical to plain decode, only launch "
                         "count changes). Default ICQ_SPEC_DECODE / off")
    ap.add_argument("--spec-k", type=int, default=None,
                    help="draft tokens proposed per lane per speculative "
                         "iteration; the verify launch scores k+1 "
                         "positions per lane (default ICQ_SPEC_K / 4)")
    ap.add_argument("--draft", default=None,
                    choices=["ngram", "self2bit", "tiny", "reject"],
                    help="drafter for --spec-decode: 'ngram' host-side "
                         "prompt lookup (zero extra launches), 'self2bit' "
                         "the serving weights re-quantized at 2 bits, "
                         "'tiny' a dense 1-layer shrunk config, 'reject' "
                         "an adversarial always-wrong drafter (rollback "
                         "stress). Default ICQ_SPEC_DRAFT / ngram")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="0 = greedy; > 0 samples (continuous mode)")
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--top-p", type=float, default=1.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--train-steps", type=int, default=10)
    ap.add_argument("--weight-cache", default="prepared",
                    choices=["prepared", "dense", "none"],
                    help="load-time ICQ weight conversion: 'prepared' = "
                         "kernel dispatch layout, 'dense' = dequant-once "
                         "cache, 'none' = reference in-graph decode")
    ap.add_argument("--runtime-fmt", default=None, choices=["v1", "v2"],
                    help="prepared runtime format: 'v2' checkpointed gap "
                         "stream (~0.3-0.45 b/w outlier overhead, default) "
                         "or 'v1' dense selector bitmap (~1 b/w); default "
                         "follows ICQ_RUNTIME_FMT / platform policy")
    args = ap.parse_args()
    if args.kv_block_size is not None and args.kv_block_size != "auto":
        args.kv_block_size = int(args.kv_block_size)
    if args.sessions and args.prefix_cache is None:
        print("[serve] --sessions implies --prefix-cache; enabling it")
        args.prefix_cache = True
    if args.prefix_cache and args.kv_layout is None:
        args.kv_layout = "paged"

    cfg = smoke_variant(get_config(args.arch))
    if cfg.is_encdec or cfg.frontend != "none":
        import dataclasses
        cfg = dataclasses.replace(cfg, frontend="none", frontend_len=0)

    params, _ = train(args.arch, steps=args.train_steps, batch=4, seq=64,
                      ckpt_dir="/tmp/repro_serve_ckpt")
    if args.bits:
        params, acct = quantize_tree(params, args.bits, gamma=args.gamma)
        print(f"[serve] quantized to {acct['mean_bits']:.2f} bits/weight")

    sampling = SamplingParams(temperature=args.temperature,
                              top_k=args.top_k, top_p=args.top_p)
    if args.replicas:
        return _serve_replicas(args, params, cfg, sampling)
    faults = None
    if args.fault_plan is not None or args.fault_rate is not None:
        faults = FaultInjector(
            parse_fault_plan(args.fault_plan) if args.fault_plan else None,
            seed=args.fault_seed if args.fault_seed is not None else 0,
            rate=args.fault_rate if args.fault_rate is not None else 0.0)
    engine = GenerationEngine(params, cfg, batch_size=args.batch,
                              max_len=args.max_len,
                              weight_cache=args.weight_cache,
                              runtime_fmt=args.runtime_fmt,
                              mode=args.mode, sampling=sampling,
                              seed=args.seed,
                              prefill_chunk=args.prefill_chunk,
                              kv_layout=args.kv_layout,
                              kv_block_size=args.kv_block_size,
                              kv_blocks=args.kv_blocks,
                              max_queue=args.max_queue,
                              shed_policy=args.shed_policy,
                              faults=faults,
                              degrade_steps=args.degrade_steps,
                              prefix_cache=args.prefix_cache,
                              session_ttl=args.session_ttl,
                              spec_decode=args.spec_decode,
                              spec_k=args.spec_k,
                              spec_draft=args.draft)
    kv_desc = engine.kv_layout
    if engine.kv_layout == "paged":
        kv_desc += (f": {engine.kv_blocks} blocks x "
                    f"{engine.kv_block_size} rows")
        if engine.prefix_cache:
            kv_desc += ", prefix-cache on"
    spec_desc = (f", spec_decode=k{engine.spec_k}/{engine.spec_draft}"
                 if engine.spec_decode else "")
    print(f"[serve] engine mode: {engine.mode} (max_len={args.max_len}, "
          f"prefill_chunk={engine.prefill_chunk}, "
          f"fused_step={engine.fused_step}, kv={kv_desc}{spec_desc})")
    _install_engine_signals(engine)

    rng = np.random.default_rng(args.seed)
    if args.sessions:
        # Multi-turn chat workload: every session shares one system
        # prompt; each turn appends fresh user tokens to the session's
        # full history (prior prompt + generated reply). Turn 1 shares
        # the system prompt across sessions through the hash cache;
        # turn 2+ warm-starts from the session's retained chain, so
        # only the delta past the previous turn is prefilled.
        system = rng.integers(0, cfg.vocab_size,
                              size=args.shared_prefix).astype(np.int32)
        history = {sid: system.copy() for sid in range(args.sessions)}
        rid = 0
        for turn in range(args.turns):
            turn_rids = {}
            for sid in range(args.sessions):
                user = rng.integers(
                    0, cfg.vocab_size,
                    size=int(rng.integers(4, 9))).astype(np.int32)
                prompt = np.concatenate([history[sid], user])
                max_new = min(args.max_new, args.max_len - len(prompt))
                if len(prompt) >= args.max_len or max_new < 1:
                    print(f"[serve] session {sid} turn {turn}: history "
                          f"{len(prompt)} tokens overflows max_len "
                          f"{args.max_len}; skipping turn")
                    continue
                req = Request(rid, prompt, max_new_tokens=max_new,
                              deadline_s=args.deadline,
                              max_queue_wait_s=args.max_queue_wait,
                              arrival_time=engine.now())
                try:
                    if engine.submit(req, session=f"s{sid}"):
                        turn_rids[rid] = sid
                    else:
                        print(f"[serve] SHED session {sid} turn {turn}")
                except ValueError as e:
                    print(f"[serve] REJECT session {sid} turn {turn}: {e}")
                rid += 1
            done = engine.run()
            for r_id, sid in sorted(turn_rids.items()):
                r = done[r_id]
                print(f"[serve] session {sid} turn {turn}: "
                      f"prompt_len={len(r.prompt)} "
                      f"generated={r.generated} status={r.status}")
                if r.status == "ok":
                    history[sid] = np.concatenate(
                        [r.prompt, np.asarray(r.generated, np.int32)])
    else:
        for rid in range(args.requests):
            prompt = rng.integers(0, cfg.vocab_size,
                                  size=rng.integers(4, 12))
            prompt = prompt.astype(np.int32)
            max_new = args.max_new
            budget = len(prompt) + max_new
            if len(prompt) >= args.max_len:
                print(f"[serve] REJECT req {rid}: prompt length "
                      f"{len(prompt)} >= max_len {args.max_len}")
                continue
            if budget > args.max_len:
                if args.strict_len:
                    print(f"[serve] REJECT req {rid}: prompt "
                          f"{len(prompt)} + max_new {max_new} = {budget} "
                          f"> max_len {args.max_len} (--strict-len)")
                    continue
                max_new = args.max_len - len(prompt)
                print(f"[serve] WARN req {rid}: prompt {len(prompt)} + "
                      f"max_new {args.max_new} exceeds max_len "
                      f"{args.max_len}; truncating budget to {max_new} "
                      f"new tokens")
            try:
                accepted = engine.submit(
                    Request(rid, prompt, max_new_tokens=max_new,
                            deadline_s=args.deadline,
                            max_queue_wait_s=args.max_queue_wait))
                if not accepted:
                    print(f"[serve] SHED req {rid}: queue full "
                          f"(max_queue={engine.max_queue}, "
                          f"policy={engine.shed_policy})")
            except ValueError as e:
                # e.g. a paged pool too small to ever serve this request:
                # mirror the max_len policy above — reject, don't crash
                print(f"[serve] REJECT req {rid}: {e}")

        done = engine.run()
        for rid in sorted(done):
            r = done[rid]
            print(f"[serve] req {rid}: prompt_len={len(r.prompt)} "
                  f"generated={r.generated} status={r.status}")
    s = engine.metrics.summary()
    print(f"[serve] {int(s['completed'])}/{int(s['requests'])} requests, "
          f"{int(s['generated_tokens'])} tokens in {s['wall_s']:.2f}s "
          f"({s['tokens_per_s']:.1f} tok/s, mean occupancy "
          f"{s['mean_occupancy']:.2f}/{args.batch}, "
          f"ttft p50 {s['ttft_p50']:.3f}s, prompt split "
          f"{int(s['prefill_tokens'])} chunked / "
          f"{int(s['prompt_decode_tokens'])} walked)")
    print(f"[serve] launches: {int(s['launches'])} "
          f"({int(s['prefill_steps'])} chunk / "
          f"{int(s['decode_steps'])} decode / "
          f"{int(s['fused_steps'])} fused / "
          f"{int(s['verify_steps'])} verify / "
          f"{int(s['draft_launches'])} draft)")
    if engine.spec_decode:
        mal = s["mean_accept_len"]
        hist = " ".join(
            f"{a}:{n}" for a, n in
            sorted(engine.metrics.accept_hist.items()))
        print(f"[serve] speculative: spec_proposed="
              f"{int(s['spec_proposed'])} spec_accepted="
              f"{int(s['spec_accepted'])} mean_accept_len="
              f"{mal if mal != mal else round(mal, 2)} "
              f"(accept-len hist {hist or 'none'}, "
              f"{int(s['spec_fallbacks'])} verify fallbacks, "
              f"{int(s['spec_draft_errors'])} draft errors)")
    if s["paged_attn_window_fallbacks"]:
        print(f"[serve] paged-attn window fallbacks: "
              f"{int(s['paged_attn_window_fallbacks'])} decode launches "
              f"on the XLA gather arm (sliding window < page-table span)")
    if engine.kv_layout == "paged":
        print(f"[serve] paged KV: cache {int(s['cache_bytes'])} bytes "
              f"({int(s['kv_blocks'])} x {int(s['kv_block_size'])} rows), "
              f"{int(s['preemptions'])} preemptions, block utilization "
              f"{s['mean_block_utilization']:.2f} mean / "
              f"{int(s['peak_blocks_in_use'])} peak blocks, "
              f"decode attn bytes-read est "
              f"{int(s['attn_live_bytes'])} live / "
              f"{int(s['attn_logical_bytes'])} logical")
    if engine.kv_layout == "paged" and engine.prefix_cache:
        rate = s["prefix_hit_rate"]
        rate_str = f"{rate:.3f}" if rate == rate else "n/a"
        print(f"[serve] prefix cache: {int(s['prefix_hits'])}/"
              f"{int(s['prefix_lookups'])} hits (hit rate {rate_str}), "
              f"{int(s['prefix_tokens_skipped'])} prefill tokens "
              f"skipped, {int(s['cow_forks'])} cow forks, "
              f"{int(s['prefix_inserts'])} chain inserts, "
              f"{int(s['prefix_evictions'])} evictions")
        print(f"[serve] sessions: {int(s['session_hits'])} warm hits, "
              f"{int(s['sessions_active'])} active at exit, "
              f"{int(s['session_expiries'])} expiries, "
              f"{int(s['session_evictions'])} evictions, shared blocks "
              f"mean {s['mean_shared_blocks']:.1f} / peak "
              f"{s['peak_shared_blocks']:.0f}")
    counts = engine.metrics.status_counts()
    statuses = " ".join(f"{k}={v}" for k, v in sorted(counts.items()))
    print(f"[serve] statuses: {statuses or 'none'}")
    if s["faults"] or s["degraded_steps"] or s["replays"]:
        by_kind = " ".join(f"{k}={v}" for k, v in
                           sorted(engine.metrics.faults.items()))
        print(f"[serve] faults: {int(s['faults'])} ({by_kind}), "
              f"{int(s['degraded_steps'])} degraded steps, "
              f"{int(s['replays'])} replays")
    print(f"[serve] watchdog: step time p50 {s['step_time_p50']:.4f}s / "
          f"p95 {s['step_time_p95']:.4f}s, "
          f"{int(s['stalled_steps'])} stalled steps")


if __name__ == "__main__":
    main()
