"""Serving driver: train briefly, optionally ICQuant the weights, then
serve a batch of requests through the GenerationEngine.

``python -m repro.launch.serve --arch <id> [--bits 3] [--requests 8]``
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import get_config, smoke_variant
from repro.launch.quantize import quantize_tree
from repro.launch.train import train
from repro.serving import GenerationEngine, Request


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--bits", type=int, default=0,
                    help="ICQuant bits (0 = serve FP weights)")
    ap.add_argument("--gamma", type=float, default=0.05)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--train-steps", type=int, default=10)
    ap.add_argument("--weight-cache", default="prepared",
                    choices=["prepared", "dense", "none"],
                    help="load-time ICQ weight conversion: 'prepared' = "
                         "kernel dispatch layout, 'dense' = dequant-once "
                         "cache, 'none' = reference in-graph decode")
    ap.add_argument("--runtime-fmt", default=None, choices=["v1", "v2"],
                    help="prepared runtime format: 'v2' checkpointed gap "
                         "stream (~0.3-0.45 b/w outlier overhead, default) "
                         "or 'v1' dense selector bitmap (~1 b/w); default "
                         "follows ICQ_RUNTIME_FMT / platform policy")
    args = ap.parse_args()

    cfg = smoke_variant(get_config(args.arch))
    if cfg.is_encdec or cfg.frontend != "none":
        import dataclasses
        cfg = dataclasses.replace(cfg, frontend="none", frontend_len=0)

    params, _ = train(args.arch, steps=args.train_steps, batch=4, seq=64,
                      ckpt_dir="/tmp/repro_serve_ckpt")
    if args.bits:
        params, acct = quantize_tree(params, args.bits, gamma=args.gamma)
        print(f"[serve] quantized to {acct['mean_bits']:.2f} bits/weight")

    engine = GenerationEngine(params, cfg, batch_size=args.batch, max_len=64,
                              weight_cache=args.weight_cache,
                              runtime_fmt=args.runtime_fmt)
    rng = np.random.default_rng(0)
    for rid in range(args.requests):
        prompt = rng.integers(0, cfg.vocab_size, size=rng.integers(4, 12))
        engine.submit(Request(rid, prompt.astype(np.int32),
                              max_new_tokens=args.max_new))
    done = engine.run()
    for rid in sorted(done):
        r = done[rid]
        print(f"[serve] req {rid}: prompt_len={len(r.prompt)} "
              f"generated={r.generated}")


if __name__ == "__main__":
    main()
