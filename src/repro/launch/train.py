"""Fault-tolerant training driver.

``python -m repro.launch.train --arch <id> [--steps N] [--batch B]
    [--seq S] [--smoke] [--ckpt DIR] [--compress-grads]``

The loop is the production control plane in miniature:
  * mesh + sharding from runtime.sharding (DP x TP, optional FSDP);
  * pure-function data pipeline (seed, step, shard) — restart-safe;
  * CheckpointManager with atomic step dirs; `--resume` restarts from
    the latest step (crash-recovery path, exercised by tests);
  * StragglerMonitor records per-step wall time (per-host on a real
    cluster; per-process here) and logs flagged hosts;
  * optional int8+error-feedback gradient compression (cross-pod path).
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import get_config, smoke_variant
from repro.data import SyntheticLM
from repro.launch.mesh import make_host_mesh
from repro.launch.steps import init_opt_state, make_train_step
from repro.models import count_params, init_model
from repro.optim import AdamWConfig
from repro.runtime import StragglerMonitor
from repro.runtime.sharding import param_specs, batch_specs

from jax.sharding import NamedSharding, PartitionSpec as P


def train(
    arch: str,
    steps: int = 50,
    batch: int = 8,
    seq: int = 128,
    smoke: bool = True,
    ckpt_dir: str = "/tmp/repro_ckpt",
    resume: bool = False,
    compress_grads: bool = False,
    lr: float = 3e-4,
    ckpt_every: int = 25,
    log_every: int = 10,
    seed: int = 0,
):
    cfg = get_config(arch)
    if smoke:
        cfg = smoke_variant(cfg)
    if cfg.frontend != "none" or cfg.is_encdec:
        cfg = dataclasses.replace(cfg, frontend="none", frontend_len=0)

    mesh = make_host_mesh()
    opt_cfg = AdamWConfig(lr=lr, total_steps=steps, warmup_steps=max(steps // 10, 1))

    params = init_model(jax.random.PRNGKey(seed), cfg)
    opt_state = init_opt_state(params, opt_cfg, compress_grads)
    print(f"[train] {cfg.name}: {count_params(params)/1e6:.2f}M params")

    mgr = CheckpointManager(ckpt_dir, keep=3)
    start_step = 0
    if resume and mgr.latest_step() is not None:
        state = mgr.restore(dict(params=params, opt=opt_state))
        params, opt_state = state["params"], state["opt"]
        start_step = int(jax.device_get(opt_state["adam"]["step"]))
        print(f"[train] resumed from step {start_step}")

    p_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), param_specs(params, mesh))
    step_fn = jax.jit(
        make_train_step(cfg, opt_cfg, compress_grads),
        in_shardings=(p_sh, None, None),
        donate_argnums=(0, 1),
    )

    data = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=seq, seed=seed)
    monitor = StragglerMonitor(n_hosts=jax.process_count())
    losses = []
    with mesh:
        for step in range(start_step, steps):
            b = data.batch(step, shard=jax.process_index(), batch_size=batch)
            t0 = time.time()
            params, opt_state, metrics = step_fn(
                params, opt_state, {k: jnp.asarray(v) for k, v in b.items()}
            )
            loss = float(metrics["loss"])
            monitor.record(jax.process_index(), time.time() - t0)
            losses.append(loss)
            if step % log_every == 0 or step == steps - 1:
                flagged = monitor.stragglers()
                print(f"[train] step {step} loss {loss:.4f}"
                      + (f" stragglers={flagged}" if flagged else ""))
            if ckpt_every and (step + 1) % ckpt_every == 0:
                mgr.save(step + 1, dict(params=params, opt=opt_state))
    mgr.save(steps, dict(params=params, opt=opt_state))
    return params, losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--full", action="store_true",
                    help="use the full (non-smoke) config")
    ap.add_argument("--ckpt", default="/tmp/repro_ckpt")
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--lr", type=float, default=3e-4)
    args = ap.parse_args()
    train(
        args.arch, steps=args.steps, batch=args.batch, seq=args.seq,
        smoke=not args.full, ckpt_dir=args.ckpt, resume=args.resume,
        compress_grads=args.compress_grads, lr=args.lr,
    )


if __name__ == "__main__":
    main()
