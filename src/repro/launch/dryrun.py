import os
os.environ.setdefault(
    "XLA_FLAGS", "--xla_force_host_platform_device_count=512"
)

"""Multi-pod dry-run: lower + compile every (architecture x shape) cell on
the production mesh and extract roofline terms from the compiled module.

Usage:
  python -m repro.launch.dryrun --arch llama3.2-1b --shape train_4k
  python -m repro.launch.dryrun --arch all [--multi-pod] [--out DIR]

The XLA_FLAGS line above MUST run before any other import (jax locks the
device count at first initialization); never set it globally.
"""
import argparse      # noqa: E402
import json          # noqa: E402
import re            # noqa: E402
import sys           # noqa: E402
import subprocess    # noqa: E402
import time          # noqa: E402

import jax                                    # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import (                   # noqa: E402
    ARCHITECTURES, SHAPES, SHAPE_BY_NAME, get_config, shape_applicable,
)
from repro.launch import specs as sp          # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.steps import (              # noqa: E402
    make_decode_step, make_prefill_step, make_train_step,
)
from repro.optim import AdamWConfig           # noqa: E402
from repro.runtime.sharding import (          # noqa: E402
    batch_specs, cache_specs, param_specs,
)

COLLECTIVE_RE = re.compile(
    r"^\s*\S+\s*=\s*\S+\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(",
)
SHAPE_RE = re.compile(r"(f32|bf16|f16|s32|u32|s8|u8|pred|s64|u64|f64)\[([\d,]*)\]")
DTYPE_BYTES = dict(f64=8, s64=8, u64=8, f32=4, s32=4, u32=4, bf16=2, f16=2,
                   s8=1, u8=1, pred=1)


def collective_bytes(hlo_text: str) -> dict:
    """Sum operand bytes of collective ops in compiled HLO text."""
    totals = {}
    for line in hlo_text.splitlines():
        m = COLLECTIVE_RE.match(line)
        if not m:
            continue
        op = m.group(1)
        # output-shape convention: bytes of the result tuple/array
        lhs = line.split("=", 1)[1]
        b = 0
        for dt, dims in SHAPE_RE.findall(lhs.split("(", 1)[0]):
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            b += n * DTYPE_BYTES[dt]
        totals[op] = totals.get(op, 0) + b
    totals["total"] = sum(v for k, v in totals.items() if k != "total")
    return totals


def _layer_variants(cfg):
    """Small layer-count variants for linear cost extrapolation.

    XLA counts a scan body once, so the full-config lowering (scanned)
    proves compilation + memory, while two-to-three *unrolled* small
    variants identify the per-layer cost exactly:
        cost(L…) = A + sum_i L_i * B_i   (per homogeneous stack i)
    Returns (variant_cfgs, design_matrix_rows, full_counts).
    """
    import dataclasses as dc

    if cfg.is_encdec:
        pts = [(1, 2), (1, 4), (2, 2)]
        variants = [
            dc.replace(cfg, encoder_layers=e, decoder_layers=d,
                       scan_layers=False)
            for e, d in pts
        ]
        rows = [[1, e, d] for e, d in pts]
        full = [1, cfg.encoder_layers, cfg.decoder_layers]
    elif cfg.family == "moe" and cfg.first_dense_layers:
        pts = [(1, 3), (1, 5), (2, 4)]   # (first_dense, total)
        variants = [
            dc.replace(cfg, first_dense_layers=fd, n_layers=t,
                       scan_layers=False)
            for fd, t in pts
        ]
        rows = [[1, fd, t - fd] for fd, t in pts]
        full = [1, cfg.first_dense_layers,
                cfg.n_layers - cfg.first_dense_layers]
    else:
        pts = [2, 4]
        variants = [
            dc.replace(cfg, n_layers=L, scan_layers=False) for L in pts
        ]
        rows = [[1, L] for L in pts]
        full = [1, cfg.n_layers]
    return variants, rows, full


def extrapolate_costs(arch: str, shape_name: str, multi_pod: bool,
                      fsdp: bool = True, quant_bits: int = 0):
    """Exact roofline terms via per-layer linear fit of unrolled variants."""
    import numpy as np

    cfg0 = sp.dryrun_config(get_config(arch))
    variants, rows, full = _layer_variants(cfg0)
    flops, bts, coll = [], [], []
    for vcfg in variants:
        r = _lower_one(vcfg, shape_name, multi_pod, fsdp,
                       quant_bits=quant_bits)
        flops.append(r["flops"])
        bts.append(r["bytes_accessed"])
        coll.append(r["collective_bytes"]["total"])
    A = np.asarray(rows, dtype=np.float64)
    sol_f, *_ = np.linalg.lstsq(A, np.asarray(flops), rcond=None)
    sol_b, *_ = np.linalg.lstsq(A, np.asarray(bts), rcond=None)
    sol_c, *_ = np.linalg.lstsq(A, np.asarray(coll), rcond=None)
    fv = np.asarray(full, dtype=np.float64)
    return dict(
        flops=float(fv @ sol_f),
        bytes_accessed=float(fv @ sol_b),
        collective_total=float(fv @ sol_c),
        variant_points=dict(rows=rows, flops=flops, bytes=bts,
                            collective=coll),
    )


def lower_cell(arch: str, shape_name: str, multi_pod: bool, fsdp: bool = True,
               extrapolate: bool = True, quant_bits: int = 0):
    cfg = sp.dryrun_config(get_config(arch))
    shape = SHAPE_BY_NAME[shape_name]
    skip = shape_applicable(cfg, shape)
    if skip:
        return dict(arch=arch, shape=shape_name, status="SKIP", reason=skip)

    # full production config, scanned stacks: proves lower+compile on the
    # production mesh and yields the memory analysis
    cfg_scan = __import__("dataclasses").replace(cfg, scan_layers=True)
    result = _lower_one(cfg_scan, shape_name, multi_pod, fsdp,
                        quant_bits=quant_bits)
    result.update(arch=arch, shape=shape_name, status="OK",
                  mesh="2x16x16" if multi_pod else "16x16")
    result["scan_note"] = (
        "flops/bytes/collectives from the scanned module count scan "
        "bodies once; see 'extrapolated' for exact per-layer-scaled terms"
    )
    if quant_bits:
        result["quant_bits"] = quant_bits
    if extrapolate and not multi_pod:
        result["extrapolated"] = extrapolate_costs(
            arch, shape_name, multi_pod, fsdp, quant_bits=quant_bits
        )
    return result


def _lower_one(cfg, shape_name: str, multi_pod: bool, fsdp: bool = True,
               quant_bits: int = 0):
    shape = SHAPE_BY_NAME[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    opt_cfg = AdamWConfig(state_dtype="bfloat16")

    if quant_bits:   # ICQuant-packed serving path (decode/prefill only)
        params = sp.quantized_param_structs(cfg, n_bits=quant_bits)
    else:
        params = sp.param_structs(cfg)
    p_sh = jax.tree.map(
        lambda s: NamedSharding(mesh, s), param_specs(params, mesh, fsdp=fsdp)
    )
    batch = sp.input_specs(cfg, shape)
    b_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), batch_specs(batch, mesh))

    t0 = time.time()
    with mesh:
        if shape.kind == "train":
            opt = sp.opt_structs(cfg, opt_cfg)
            o_sh = jax.tree.map(
                lambda s: NamedSharding(mesh, s),
                param_specs(opt["adam"]["mu"], mesh, fsdp=fsdp),
            )
            o_sh = dict(adam=dict(mu=o_sh, nu=o_sh,
                                  step=NamedSharding(mesh, P())))
            fn = make_train_step(cfg, opt_cfg)
            lowered = jax.jit(
                fn, in_shardings=(p_sh, o_sh, b_sh)
            ).lower(params, opt, batch)
        elif shape.kind == "prefill":
            cache = sp.cache_structs(cfg, shape.global_batch, shape.seq_len)
            c_sh = jax.tree.map(
                lambda s: NamedSharding(mesh, s), cache_specs(cache, mesh)
            )
            fn = make_prefill_step(cfg)
            lowered = jax.jit(
                fn, in_shardings=(p_sh, c_sh, b_sh)
            ).lower(params, cache, batch)
        else:  # decode
            cache = sp.cache_structs(cfg, shape.global_batch, shape.seq_len)
            c_sh = jax.tree.map(
                lambda s: NamedSharding(mesh, s), cache_specs(cache, mesh)
            )
            fn = make_decode_step(cfg)
            tokens = batch["tokens"]
            start = jax.ShapeDtypeStruct((), jax.numpy.int32)
            if cfg.is_encdec:
                enc = jax.ShapeDtypeStruct(
                    (shape.global_batch, cfg.max_source_len, cfg.d_model),
                    jax.numpy.dtype(cfg.param_dtype),
                )
                fm = jax.ShapeDtypeStruct(
                    (shape.global_batch, cfg.max_source_len), jax.numpy.bool_
                )
                lowered = jax.jit(
                    fn,
                    in_shardings=(
                        p_sh, c_sh,
                        NamedSharding(mesh, batch_specs(tokens, mesh)),
                        NamedSharding(mesh, P()),
                        NamedSharding(mesh, batch_specs(enc, mesh)),
                        NamedSharding(mesh, batch_specs(fm, mesh)),
                    ),
                ).lower(params, cache, tokens, start, enc, fm)
            else:
                lowered = jax.jit(
                    fn,
                    in_shardings=(
                        p_sh, c_sh,
                        NamedSharding(mesh, batch_specs(tokens, mesh)),
                        NamedSharding(mesh, P()),
                    ),
                ).lower(params, cache, tokens, start)

        compiled = lowered.compile()
    compile_s = time.time() - t0

    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):   # jax < 0.6: list of per-device dicts
        cost = cost[0] if cost else {}
    mem = compiled.memory_analysis()
    coll = collective_bytes(compiled.as_text())
    result = dict(
        n_chips=int(n_chips),
        compile_seconds=round(compile_s, 1),
        flops=float(cost.get("flops", -1.0)),
        bytes_accessed=float(cost.get("bytes accessed", -1.0)),
        collective_bytes=coll,
        memory=dict(
            argument_bytes=int(getattr(mem, "argument_size_in_bytes", 0)),
            output_bytes=int(getattr(mem, "output_size_in_bytes", 0)),
            temp_bytes=int(getattr(mem, "temp_size_in_bytes", 0)),
            peak_bytes=int(
                getattr(mem, "temp_size_in_bytes", 0)
                + getattr(mem, "argument_size_in_bytes", 0)
            ),
        ),
    )
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True,
                    help="architecture id or 'all'")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--quant-bits", type=int, default=0,
                    help="lower the ICQuant-packed serving path")
    ap.add_argument("--out", default="benchmarks/artifacts/dryrun")
    args = ap.parse_args()

    if args.arch == "all":
        # orchestrate one subprocess per cell (isolates XLA state, allows
        # parallelism at the shell level)
        archs = sorted(ARCHITECTURES)
        shapes = [s.name for s in SHAPES] if args.shape == "all" else [args.shape]
        failures = []
        for a in archs:
            for s in shapes:
                cmd = [sys.executable, "-m", "repro.launch.dryrun",
                       "--arch", a, "--shape", s, "--out", args.out]
                if args.multi_pod:
                    cmd.append("--multi-pod")
                r = subprocess.run(cmd)
                if r.returncode != 0:
                    failures.append((a, s))
        if failures:
            print("FAILED CELLS:", failures)
            sys.exit(1)
        return

    os.makedirs(args.out, exist_ok=True)
    shapes = [s.name for s in SHAPES] if args.shape == "all" else [args.shape]
    for s in shapes:
        res = lower_cell(args.arch, s, args.multi_pod, fsdp=not args.no_fsdp,
                         quant_bits=args.quant_bits)
        tag = "multipod" if args.multi_pod else "pod"
        if args.quant_bits:
            tag += f"_q{args.quant_bits}"
        path = os.path.join(args.out, f"{args.arch}__{s}__{tag}.json")
        with open(path, "w") as f:
            json.dump(res, f, indent=2)
        print(json.dumps(res))


if __name__ == "__main__":
    main()
