"""Jit-able train / prefill / decode step functions for every family.

These are the functions the dry-run lowers on the production mesh and the
drivers (launch/train.py, launch/serve.py) run on real hardware. All of
them are pure: (params, [opt_state | cache], batch) -> outputs.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import (
    encdec_apply,
    encdec_cache_init,
    lm_apply,
    lm_cache_init,
    lm_hidden_and_logits,
    mtp_logits,
)
from repro.optim import adamw_update
from repro.optim.compression import error_feedback_update

AUX_COEF = 0.01
MTP_COEF = 0.3


# ---------------------------------------------------------------------------
# losses
# ---------------------------------------------------------------------------

def _ce(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    logits = logits.astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return (logz - gold).mean()


def loss_fn(params, cfg, batch) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    if cfg.is_encdec:
        logits, _, _, aux = encdec_apply(
            params, cfg, batch["frames"], batch["frame_mask"], batch["tokens"]
        )
        ce = _ce(logits[:, :-1], batch["tokens"][:, 1:])
        loss = ce + AUX_COEF * aux
        return loss, dict(loss=loss, ce=ce, aux=aux)

    prefix = batch.get("prefix_embeds")
    if cfg.mtp:
        hidden, logits, aux = lm_hidden_and_logits(
            params, cfg, batch["tokens"], prefix_embeds=prefix
        )
        P = 0 if prefix is None else prefix.shape[1]
        text_logits = logits[:, P:]
        ce = _ce(text_logits, batch["labels"])
        mtp = mtp_logits(params, cfg, hidden[:, P:], batch["tokens"])
        # mtp predicts token t+2 from hidden t  ->  labels shifted by one
        ce_mtp = _ce(mtp[:, :-1], batch["labels"][:, 2:])
        loss = ce + MTP_COEF * ce_mtp + AUX_COEF * aux
        return loss, dict(loss=loss, ce=ce, ce_mtp=ce_mtp, aux=aux)

    logits, _, aux = lm_apply(params, cfg, batch["tokens"], prefix_embeds=prefix)
    P = 0 if prefix is None else prefix.shape[1]
    ce = _ce(logits[:, P:], batch["labels"])
    loss = ce + AUX_COEF * aux
    return loss, dict(loss=loss, ce=ce, aux=aux)


# ---------------------------------------------------------------------------
# train step
# ---------------------------------------------------------------------------

def make_train_step(cfg, opt_cfg, compress_grads: bool = False):
    """Returns train_step(params, opt_state, batch) -> (params, opt_state,
    metrics). If compress_grads, opt_state carries 'residuals' and the
    gradient passes through int8 + error feedback before the update
    (modeling the cross-pod reduction; see optim.compression)."""

    def train_step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: loss_fn(p, cfg, batch), has_aux=True
        )(params)
        if compress_grads:
            grads, new_res = error_feedback_update(
                grads, opt_state["residuals"]
            )
        new_params, new_adam = adamw_update(
            params, grads, opt_state["adam"], opt_cfg
        )
        new_state = dict(adam=new_adam)
        if compress_grads:
            new_state["residuals"] = new_res
        metrics = dict(metrics, grad_norm=_safe_norm(grads))
        return new_params, new_state, metrics

    return train_step


def _safe_norm(tree):
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
            for x in jax.tree.leaves(tree))
    )


def init_opt_state(params, opt_cfg, compress_grads: bool = False):
    from repro.optim import adamw_init
    from repro.optim.compression import init_residuals

    st = dict(adam=adamw_init(params, opt_cfg))
    if compress_grads:
        st["residuals"] = init_residuals(params)
    return st


# ---------------------------------------------------------------------------
# serving steps
# ---------------------------------------------------------------------------

def make_prefill_step(cfg):
    def prefill_step(params, cache, batch):
        if cfg.is_encdec:
            logits, cache, enc_out, _ = encdec_apply(
                params, cfg, batch["frames"], batch["frame_mask"],
                batch["tokens"], cache=cache,
                start_pos=jnp.zeros((), jnp.int32),
            )
            return logits[:, -1], cache, enc_out
        logits, cache, _ = lm_apply(
            params, cfg, batch["tokens"], cache=cache,
            start_pos=jnp.zeros((), jnp.int32),
            prefix_embeds=batch.get("prefix_embeds"),
        )
        return logits[:, -1], cache

    return prefill_step


def sync_cache_positions(cache, start_pos):
    """Overwrite every ``index`` leaf of a (stacked) cache with ``start_pos``.

    With per-lane positions (``start_pos`` of shape (B,)) the serving
    engine owns the position vector: recycling a slot is a host-side
    ``pos[slot] = 0`` and the next step's cache writes land at the new
    lane origin — no device-side per-slot cache surgery. ``index`` leaves
    carry a leading layer axis ((L,) scalar caches, (L, B) per-lane
    caches); ``start_pos`` broadcasts across it.
    """
    if isinstance(cache, dict):
        return {
            k: (jnp.broadcast_to(start_pos, v.shape).astype(v.dtype)
                if k == "index" else sync_cache_positions(v, start_pos))
            for k, v in cache.items()
        }
    return cache


def sync_cache_pages(cache, pages):
    """Overwrite every ``pages`` leaf of a (stacked) paged cache.

    The serving engine's host-side block allocator
    (serving/kv_pool.py) owns the page tables; like the position
    vector, the device copy is just a mirror shipped in with each
    launch. ``pages`` is (B, max_blocks); stacked leaves carry a
    leading layer axis it broadcasts across (every layer maps logical
    positions through the same table — blocks are allocated per
    logical position, and each layer has its own physical pool).
    """
    if isinstance(cache, dict):
        return {
            k: (jnp.broadcast_to(pages, v.shape).astype(v.dtype)
                if k == "pages" else sync_cache_pages(v, pages))
            for k, v in cache.items()
        }
    return cache


def fork_cache_block(cache, src, dst):
    """Copy one physical KV block's rows, pool block ``src`` -> ``dst``
    (copy-on-write fork; see serving/prefix_cache.py).

    A paged cache subtree is recognized by its ``pages`` leaf; its
    sibling pool leaves — ``k``/``v`` (GQA) or ``c_kv``/``k_rope``
    (MLA), with any leading layer-stack axes — get row ``dst`` on the
    block axis overwritten with row ``src``. ``index``/``pages`` leaves
    are per-lane bookkeeping, not pool storage, and pass through
    untouched. ``src``/``dst`` may be traced scalars, so the engine can
    jit this once and fork arbitrary block pairs without retracing.
    """
    if isinstance(cache, dict):
        if "pages" in cache:
            # pages is (..., B, n_pt); the pool leaves share its leading
            # layer-stack axes, then (num_blocks, block_size, ...)
            lead = (slice(None),) * (cache["pages"].ndim - 2)
            return {
                k: (v if k in ("index", "pages")
                    else v.at[lead + (dst,)].set(v[lead + (src,)]))
                for k, v in cache.items()
            }
        return {k: fork_cache_block(v, src, dst) for k, v in cache.items()}
    return cache


def make_prefill_chunk_step(cfg):
    """S-token prompt-chunk admission step for the continuous engine.

    ``(params, cache, tokens (B, S), start_pos (B,), seq_lens (B,)) ->
    cache``: writes each lane's first ``seq_lens[i]`` chunk tokens into
    the per-lane cache at positions ``start_pos[i] + j`` and returns the
    updated cache. Lanes with ragged tails (fewer than S prompt tokens
    left) or lanes currently decoding pass ``seq_lens[i] < S`` and are
    write-masked — one traced program serves every chunk shape. No
    logits come back: chunk matmuls carry M = B*S tokens, which routes
    them through the large-M dequant+MXU dispatch arm, and the final
    norm + lm_head are skipped entirely (the first *generated* token's
    logits always come from the decode step consuming the last prompt
    token, so chunking never changes what that token sees).

    ``pages`` (B, max_blocks) is the paged-KV page table (None for the
    contiguous layout): chunk rows then land at physical block offsets
    via the same table the decode step reads through.
    """

    def prefill_chunk_step(params, cache, tokens, start_pos, seq_lens,
                           pages=None):
        if pages is not None:
            cache = sync_cache_pages(cache, pages)
        cache = sync_cache_positions(cache, start_pos)
        _, cache, _ = lm_apply(
            params, cfg, tokens, cache=cache, start_pos=start_pos,
            seq_lens=seq_lens, compute_logits=False,
        )
        return cache

    return prefill_chunk_step


def make_fused_step(cfg):
    """One launch for a mixed prefill+decode continuous-batching iteration.

    ``(params, cache, tokens (B, S), start_pos (B,), seq_lens (B,),
    pages) -> (logits (B, vocab), cache)``: each lane consumes its first
    ``seq_lens[i]`` tokens of the (B, S) chunk — ``seq_lens[i] > 1`` for
    lanes still admitting prompt, ``seq_lens[i] == 1`` for decoding
    lanes whose next token sits in column 0, ``seq_lens[i] == 0`` for
    idle lanes (fully write-masked). This folds what used to be two
    device launches per mixed iteration (an S-token chunk pass plus a
    1-token decode pass) into ONE program: the decode token rides the
    chunk program's token axis, and the chunk matmuls keep their large
    M = B*S dispatch arm.

    Logits come back for every lane at its own last valid column
    (``max(seq_lens - 1, 0)``) via ``lm_apply(logits_cols=...)``, so the
    vocab projection bills B rows, not B*S. For a decode lane that is
    exactly the new token's logits; for a prompt lane it is the logits
    after its last admitted token — meaningful (and consumed by the
    engine) only on the chunk that admits the final prompt token.
    Lanes with ``seq_lens[i] == 0`` return garbage logits the engine
    ignores. ``pages`` mirrors the paged-KV page table exactly as in
    the chunk/decode steps (None for contiguous per-lane caches).
    """

    def fused_step(params, cache, tokens, start_pos, seq_lens, pages=None):
        if pages is not None:
            cache = sync_cache_pages(cache, pages)
        cache = sync_cache_positions(cache, start_pos)
        cols = jnp.maximum(seq_lens - 1, 0).astype(jnp.int32)
        logits, cache, _ = lm_apply(
            params, cfg, tokens, cache=cache, start_pos=start_pos,
            seq_lens=seq_lens, logits_cols=cols,
        )
        return logits[:, 0], cache

    return fused_step


def make_verify_step(cfg):
    """Speculative-decode verifier: score every draft position at once.

    ``(params, cache, tokens (B, S), start_pos (B,), seq_lens (B,),
    pages) -> (logits (B, S, vocab), cache)``: lane ``i`` consumes its
    current feed token in column 0 followed by ``seq_lens[i] - 1`` draft
    tokens, all written into the cache at ``start_pos[i] + j``. The
    full per-column logits come back: column ``j`` is the greedy
    verdict after consuming token ``j``, so acceptance is a host-side
    longest-matching-prefix scan (serving/spec_decode.py). ``S`` is
    traced-static (``spec_k + 1``); ragged lanes ride the chunked-
    prefill per-lane validity masks (``seq_lens``), exactly like
    ``make_fused_step`` — but unlike the fused step the lm_head bills
    all B*S rows, since every column's argmax is consulted. M = B*S
    routes the matmuls down the large-M dequant+MXU arm.

    Cache rows written past the accepted prefix are *stale, not wrong*:
    the engine rewinds its host ``pos`` vector (and trims paged tail
    blocks) and the write-discipline invariant — a lane writes position
    ``p`` the step ``p`` re-enters its valid range — guarantees they
    are overwritten before any gather can see them as valid.
    """

    def verify_step(params, cache, tokens, start_pos, seq_lens, pages=None):
        if pages is not None:
            cache = sync_cache_pages(cache, pages)
        cache = sync_cache_positions(cache, start_pos)
        logits, cache, _ = lm_apply(
            params, cfg, tokens, cache=cache, start_pos=start_pos,
            seq_lens=seq_lens,
        )
        return logits, cache

    return verify_step


def make_decode_step(cfg):
    """One new token against an existing cache (the ``decode_*`` shapes).

    ``start_pos`` is a scalar (wave decoding) or a (B,) per-lane position
    vector (continuous batching). In the per-lane case the cache's own
    ``index`` leaves are overridden from ``start_pos`` before the forward
    pass, so the caller's position vector is the single source of truth
    (admitting a request into a recycled slot resets only host state).

    ``pages`` (B, max_blocks) mirrors the host block allocator's page
    tables into a paged cache's ``pages`` leaves (kv_layout='paged');
    ``reset`` (B,) zeroes recycled lanes' recurrent SSM state before the
    token is consumed (continuous serving of ssm/hybrid mixers). Both
    default to None and change nothing for contiguous attention caches.
    """

    def decode_step(params, cache, tokens, start_pos, enc_out=None,
                    frame_mask=None, pages=None, reset=None):
        if pages is not None:
            cache = sync_cache_pages(cache, pages)
        if jnp.ndim(start_pos):
            cache = sync_cache_positions(cache, start_pos)
        if cfg.is_encdec:
            logits, cache, _, _ = encdec_apply(
                params, cfg, None, frame_mask, tokens, cache=cache,
                enc_out=enc_out, start_pos=start_pos,
            )
            return logits[:, -1], cache
        logits, cache, _ = lm_apply(
            params, cfg, tokens, cache=cache, start_pos=start_pos,
            reset=reset,
        )
        return logits[:, -1], cache

    return decode_step


def make_cache(params, cfg, batch: int, max_len: int,
               per_lane: bool = False, paged=None):
    """``paged=(num_blocks, block_size)`` builds the block-pool KV layout
    (requires ``per_lane=True``; see serving/kv_pool.py)."""
    if cfg.is_encdec:
        if paged is not None:
            raise NotImplementedError(
                "paged KV caches are not supported for enc-dec models")
        return encdec_cache_init(params, cfg, batch, max_len,
                                 per_lane=per_lane)
    return lm_cache_init(params, cfg, batch, max_len, per_lane=per_lane,
                         paged=paged)


def prepare_serving_params(params, mode: str = "prepared", **prepare_kw):
    """One-time load-step weight conversion for the serving hot path.

    mode:
      'prepared' — ICQPacked/ICQRuntime leaves -> ICQPrepared (kernel
                   execution layer; padding + checkpoint/bitmap build
                   happen exactly once, never inside the jitted step).
                   Extra ``prepare_kw`` reach ``backend.prepare`` —
                   notably ``fmt='v1'|'v2'`` (runtime format; default is
                   the platform's, normally the v2 checkpointed gap
                   stream at ~0.3-0.45 b/w outlier overhead) and
                   ``codebook_dtype='f32'|'bf16'``.
      'dense'    — dequantize-once weight cache: leaves materialize to
                   dense (d_in, d_out) arrays at load time, so
                   prefill-heavy waves never redecode per step (costs
                   full bf16 HBM; right call only when HBM is plentiful).
      'none'     — leave params untouched (reference path).
    """
    from repro.core.icquant import ICQPacked, ICQRuntime
    from repro.kernels import backend as _backend

    if mode in (None, "none"):
        return params
    if mode == "prepared":
        return _backend.prepare_tree(params, **prepare_kw)
    if mode == "dense":
        from repro.models.linear import as_dense

        return jax.tree.map(
            lambda w: as_dense(w)
            if isinstance(w, (ICQPacked, ICQRuntime, _backend.ICQPrepared))
            else w,
            params,
            is_leaf=lambda w: isinstance(
                w, (ICQPacked, ICQRuntime, _backend.ICQPrepared)),
        )
    raise ValueError(f"unknown serving weight mode {mode!r}")
