import os
os.environ.setdefault(
    "XLA_FLAGS", "--xla_force_host_platform_device_count=512"
)

"""Perf hillclimbing driver (EXPERIMENTS.md §Perf).

Lowers one (arch x shape) cell with config/mesh overrides and reports the
same roofline terms as the dry-run, so each hypothesis -> change ->
re-lower -> measure iteration is one CLI call:

  python -m repro.launch.hillclimb --arch mamba2-130m --shape train_4k \
      --tp 1 --set ssd_chunk=64
  python -m repro.launch.hillclimb --arch deepseek-v3-671b --shape train_4k \
      --set moe_grouped_dispatch=True
  python -m repro.launch.hillclimb --arch mixtral-8x7b --shape long_500k \
      --quant-bits 2
"""
import argparse      # noqa: E402
import dataclasses   # noqa: E402
import json          # noqa: E402
import time          # noqa: E402

import jax           # noqa: E402
import numpy as np   # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import SHAPE_BY_NAME, get_config          # noqa: E402
from repro.launch import specs as sp                         # noqa: E402
from repro.launch.dryrun import collective_bytes, _layer_variants  # noqa: E402
from repro.launch.steps import (                             # noqa: E402
    make_decode_step, make_prefill_step, make_train_step,
)
from repro.optim import AdamWConfig                          # noqa: E402
from repro.runtime.sharding import (                         # noqa: E402
    batch_specs, cache_specs, param_specs,
)


def parse_overrides(pairs):
    out = {}
    for kv in pairs or []:
        k, v = kv.split("=", 1)
        for cast in (int, float):
            try:
                out[k] = cast(v)
                break
            except ValueError:
                continue
        else:
            out[k] = {"True": True, "False": False}.get(v, v)
    return out


def make_mesh(tp: int, n_chips: int = 256):
    from repro.launch.mesh import make_mesh as _compat_mesh

    return _compat_mesh((n_chips // tp, tp), ("data", "model"))


def lower_with(cfg, shape, mesh, fsdp=True, quant_bits=0, runtime=False):
    opt_cfg = AdamWConfig(state_dtype="bfloat16")
    params = (
        sp.quantized_param_structs(cfg, n_bits=quant_bits, runtime=runtime)
        if quant_bits else sp.param_structs(cfg)
    )
    p_sh = jax.tree.map(lambda s: NamedSharding(mesh, s),
                        param_specs(params, mesh, fsdp=fsdp))
    batch = sp.input_specs(cfg, shape)
    b_sh = jax.tree.map(lambda s: NamedSharding(mesh, s),
                        batch_specs(batch, mesh))
    t0 = time.time()
    with mesh:
        if shape.kind == "train":
            opt = sp.opt_structs(cfg, opt_cfg)
            o_mu = jax.tree.map(
                lambda s: NamedSharding(mesh, s),
                param_specs(opt["adam"]["mu"], mesh, fsdp=fsdp),
            )
            o_sh = dict(adam=dict(mu=o_mu, nu=o_mu,
                                  step=NamedSharding(mesh, P())))
            compiled = jax.jit(
                make_train_step(cfg, opt_cfg),
                in_shardings=(p_sh, o_sh, b_sh),
            ).lower(params, opt, batch).compile()
        elif shape.kind == "prefill":
            cache = sp.cache_structs(cfg, shape.global_batch, shape.seq_len)
            c_sh = jax.tree.map(lambda s: NamedSharding(mesh, s),
                                cache_specs(cache, mesh))
            compiled = jax.jit(
                make_prefill_step(cfg), in_shardings=(p_sh, c_sh, b_sh)
            ).lower(params, cache, batch).compile()
        else:
            cache = sp.cache_structs(cfg, shape.global_batch, shape.seq_len)
            c_sh = jax.tree.map(lambda s: NamedSharding(mesh, s),
                                cache_specs(cache, mesh))
            tokens = batch["tokens"]
            start = jax.ShapeDtypeStruct((), jax.numpy.int32)
            args = [params, cache, tokens, start]
            shards = [p_sh, c_sh,
                      NamedSharding(mesh, batch_specs(tokens, mesh)),
                      NamedSharding(mesh, P())]
            if cfg.is_encdec:
                enc = jax.ShapeDtypeStruct(
                    (shape.global_batch, cfg.max_source_len, cfg.d_model),
                    jax.numpy.dtype(cfg.param_dtype))
                fm = jax.ShapeDtypeStruct(
                    (shape.global_batch, cfg.max_source_len), jax.numpy.bool_)
                args += [enc, fm]
                shards += [NamedSharding(mesh, batch_specs(enc, mesh)),
                           NamedSharding(mesh, batch_specs(fm, mesh))]
            compiled = jax.jit(
                make_decode_step(cfg), in_shardings=tuple(shards)
            ).lower(*args).compile()
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):   # jax < 0.6: list of per-device dicts
        cost = cost[0] if cost else {}
    mem = compiled.memory_analysis()
    return dict(
        compile_seconds=round(time.time() - t0, 1),
        flops=float(cost.get("flops", -1.0)),
        bytes_accessed=float(cost.get("bytes accessed", -1.0)),
        collective_bytes=collective_bytes(compiled.as_text()),
        peak_bytes=int(getattr(mem, "temp_size_in_bytes", 0)
                       + getattr(mem, "argument_size_in_bytes", 0)),
    )


def run_cell(arch, shape_name, tp=16, fsdp=True, quant_bits=0,
             runtime=False, overrides=None, extrapolate=True):
    cfg = sp.dryrun_config(get_config(arch))
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    shape = SHAPE_BY_NAME[shape_name]
    mesh = make_mesh(tp)

    # per-layer-exact costs via small unrolled variants (see dryrun)
    variants, rows, full = _layer_variants(cfg)
    fl, bt, cl = [], [], []
    r0 = None
    for vcfg in variants:
        r = lower_with(vcfg, shape, mesh, fsdp, quant_bits, runtime)
        r0 = r0 or r
        fl.append(r["flops"])
        bt.append(r["bytes_accessed"])
        cl.append(r["collective_bytes"]["total"])
    A = np.asarray(rows, np.float64)
    fv = np.asarray(full, np.float64)
    sol = lambda y: float(fv @ np.linalg.lstsq(A, np.asarray(y), rcond=None)[0])
    n_chips = mesh.devices.size
    flops, bts, coll = sol(fl), sol(bt), sol(cl)
    return dict(
        arch=arch, shape=shape_name, tp=tp, fsdp=fsdp,
        quant_bits=quant_bits, overrides=overrides or {},
        n_chips=int(n_chips),
        flops=flops, bytes_accessed=bts, collective_total=coll,
        compute_s=flops / 197e12,
        memory_xla_s=bts / 819e9,
        collective_s=coll / 150e9,
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--tp", type=int, default=16)
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--quant-bits", type=int, default=0)
    ap.add_argument("--runtime-format", action="store_true",
                    help="serve from the bitmap runtime format")
    ap.add_argument("--set", nargs="*", default=[],
                    help="config overrides, e.g. ssd_chunk=64")
    args = ap.parse_args()
    res = run_cell(args.arch, args.shape, tp=args.tp, fsdp=not args.no_fsdp,
                   quant_bits=args.quant_bits, runtime=args.runtime_format,
                   overrides=parse_overrides(args.set))
    print(json.dumps(res))


if __name__ == "__main__":
    main()
