"""Launchers: mesh, dry-run, train, serve, quantize.

NOTE: ``repro.launch.dryrun`` must be imported/executed as the entry
point (it sets XLA_FLAGS before jax init); don't import it from library
code.
"""
from repro.launch.mesh import make_host_mesh, make_production_mesh

__all__ = ["make_production_mesh", "make_host_mesh"]
