"""ShapeDtypeStruct stand-ins for params / optimizer / caches / batches.

Everything the dry-run lowers is abstract: parameter trees come from
``jax.eval_shape`` over the real initializers (no 671B allocation), and
inputs are ShapeDtypeStructs — weak-type-correct and shardable.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.launch.steps import init_opt_state, make_cache
from repro.models import init_model


def dryrun_config(cfg: ModelConfig) -> ModelConfig:
    """Production numerics for lowering: bf16 params, remat on, layers
    unrolled so cost_analysis counts every layer (scan bodies are counted
    once by XLA)."""
    return dataclasses.replace(
        cfg, param_dtype="bfloat16", remat=True, scan_layers=False
    )


def param_structs(cfg: ModelConfig):
    return jax.eval_shape(lambda: init_model(jax.random.PRNGKey(0), cfg))


def opt_structs(cfg: ModelConfig, opt_cfg, compress_grads: bool = False):
    params = param_structs(cfg)
    return jax.eval_shape(
        lambda: init_opt_state(params, opt_cfg, compress_grads)
    )


def cache_structs(cfg: ModelConfig, batch: int, max_len: int):
    params = param_structs(cfg)
    return jax.eval_shape(lambda: make_cache(params, cfg, batch, max_len))


def quantized_param_structs(cfg: ModelConfig, n_bits: int = 2,
                            gamma: float = 0.05, b: int = 6,
                            runtime: bool = False):
    """Abstract ICQPacked weights for lowering the quantized serving path.

    Every quantizable 2-D (or stacked) weight becomes an ICQPacked struct
    with the exact packed shapes the codec would produce: n-bit code
    words, a gap stream sized to p + E[flags] (+3σ slack, uniform
    positions), per-row dual codebooks.
    """
    import math

    from repro.core.icquant import ICQPacked, ICQRuntime
    from repro.core.packing import packed_width
    from repro.launch.quantize import quantizable

    params = param_structs(cfg)
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    out = []
    for path, leaf in flat:
        if not quantizable(path, leaf):
            out.append(leaf)
            continue
        lead = leaf.shape[:-2]
        d_in, d_out = leaf.shape[-2], leaf.shape[-1]
        p = int(gamma * d_in)
        flags = p / max(math.expm1(gamma * (2**b - 1)), 1e-9)
        s_max = int(p + flags + 3 * math.sqrt(max(p, 1)))
        rows = lead + (d_out,)
        if runtime:
            out.append(
                ICQRuntime(
                    codes=_sds(rows + (packed_width(d_in, n_bits),),
                               jnp.uint32),
                    bitmap=_sds(rows + (packed_width(d_in, 1),), jnp.uint32),
                    codebooks=_sds(rows + (2 << n_bits,), jnp.float32),
                    n_bits=n_bits, d_out=d_out, d_in=d_in,
                )
            )
            continue
        out.append(
            ICQPacked(
                codes=_sds(rows + (packed_width(d_in, n_bits),), jnp.uint32),
                symbols=_sds(rows + (s_max,), jnp.uint16),
                counts=_sds(rows, jnp.int32),
                codebooks=_sds(rows + (2, 1 << n_bits), jnp.float32),
                n_bits=n_bits, b=b, gamma=gamma,
                d_out=d_out, d_in=d_in, method="kmeans",
            )
        )
    return jax.tree.unflatten(treedef, out)


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """Batch ShapeDtypeStructs for a (arch, shape) cell.

    train/prefill: full-sequence inputs. decode: one new token per
    sequence against a cache of size seq_len (built by cache_structs).
    """
    B, S = shape.global_batch, shape.seq_len
    if cfg.is_encdec:
        Ts = cfg.max_source_len
        batch = dict(
            frames=_sds((B, Ts, cfg.d_model), jnp.dtype(cfg.param_dtype)),
            frame_mask=_sds((B, Ts), jnp.bool_),
        )
        if shape.kind == "decode":
            batch["tokens"] = _sds((B, 1), jnp.int32)
        else:
            batch["tokens"] = _sds((B, S), jnp.int32)
        return batch

    prefix = cfg.frontend_len if cfg.frontend != "none" else 0
    if shape.kind == "decode":
        return dict(tokens=_sds((B, 1), jnp.int32))
    s_text = S - prefix
    batch: Dict[str, Any] = dict(tokens=_sds((B, s_text), jnp.int32))
    if shape.kind == "train":
        batch["labels"] = _sds((B, s_text), jnp.int32)
    if prefix:
        batch["prefix_embeds"] = _sds(
            (B, prefix, cfg.d_model), jnp.dtype(cfg.param_dtype)
        )
    return batch
