"""Asyncio serving frontend + retrying client (stdlib only).

``ServingFrontend`` puts a network surface in front of a
``ReplicaRouter``: a TCP server speaking newline-delimited JSON, one
request object per line, exposing

  ``submit``  {"op":"submit","prompt":[...],"max_new_tokens":N,
               "eos_id":E,"deadline_s":D,"max_queue_wait_s":W,
               "session":S}            -> {"ok":true,"rid":R}
  ``poll``    {"op":"poll","rid":R}    -> {"ok":true,"done":...,
                                           "status":...,"tokens":[...]}
  ``stream``  {"op":"stream","rid":R}  -> history + {"tokens_delta":
                                           [...]} lines, then a final
                                           {"done":true,...} line
  ``cancel``  {"op":"cancel","rid":R}  -> {"ok":true,"cancelled":...}
  ``health``  {"op":"health"}          -> replica states, loads,
                                           heartbeat ages, pending
  ``metrics`` {"op":"metrics"}         -> the ServiceMetrics summary
  ``drain``   {"op":"drain"}           -> refuse new admissions;
                                           in-flight work finishes

Error responses are ``{"ok":false,"error":...,"retryable":...}``:
**retryable** errors are load/liveness conditions (``shed`` from the
bounded frontend queue, ``unavailable`` when every replica is down) —
the client backs off and retries; **terminal** errors are decisions
(``rejected`` validation failures, ``draining``, ``unknown-rid``) — the
client surfaces them immediately. Deadlines propagate: ``deadline_s``
rides the Request into the engine (and, minus wall time already spent,
through router failover).

The event loop runs in a dedicated thread (``start()`` returns the
bound address) and never blocks on engine work: submits are queue
handoffs, streaming polls router snapshots, and the built-in
supervision task runs ``router.supervise()`` in an executor so replica
restarts (engine rebuilds) cannot stall the loop.

``ServingClient`` is the matching synchronous client with capped
exponential backoff (``ICQ_RETRY_MAX`` attempts, ``ICQ_RETRY_BASE_S``
doubling up to ``ICQ_RETRY_CAP_S``) on retryable errors and connection
failures. ``ServingService`` bundles WAL + replicas + router + frontend
into the one object ``launch/serve.py`` and the chaos drills drive.
"""
from __future__ import annotations

import asyncio
import json
import os
import socket
import threading
import time
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.serving.metrics import ServiceMetrics
from repro.serving.replica import EngineReplica
from repro.serving.router import NoReplicaAvailable, ReplicaRouter
from repro.serving.scheduler import Request
from repro.serving.wal import RequestWAL


def default_retry_max() -> int:
    """``ICQ_RETRY_MAX`` env knob: client retry attempts after the
    first try (default 5)."""
    v = os.environ.get("ICQ_RETRY_MAX", "")
    if not v:
        return 5
    out = int(v)
    if out < 0:
        raise ValueError(f"ICQ_RETRY_MAX must be >= 0, got {v!r}")
    return out


def default_retry_base_s() -> float:
    """``ICQ_RETRY_BASE_S`` env knob: first retry backoff in seconds,
    doubled per attempt (default 0.05)."""
    v = os.environ.get("ICQ_RETRY_BASE_S", "")
    if not v:
        return 0.05
    out = float(v)
    if out <= 0:
        raise ValueError(f"ICQ_RETRY_BASE_S must be > 0, got {v!r}")
    return out


def default_retry_cap_s() -> float:
    """``ICQ_RETRY_CAP_S`` env knob: backoff ceiling in seconds
    (default 2.0)."""
    v = os.environ.get("ICQ_RETRY_CAP_S", "")
    if not v:
        return 2.0
    out = float(v)
    if out <= 0:
        raise ValueError(f"ICQ_RETRY_CAP_S must be > 0, got {v!r}")
    return out


def backoff_s(attempt: int, base: float, cap: float) -> float:
    """Capped exponential backoff: ``min(cap, base * 2**attempt)``."""
    return min(cap, base * (2.0 ** attempt))


class ServingFrontend:
    """TCP frontend over one router (see module doc)."""

    def __init__(self, router: ReplicaRouter,
                 max_pending: Optional[int] = None,
                 host: str = "127.0.0.1", port: int = 0,
                 supervise_s: float = 0.1,
                 stream_poll_s: float = 0.02):
        if max_pending is not None and max_pending < 1:
            raise ValueError(f"max_pending must be >= 1, got {max_pending}")
        self.router = router
        self.metrics = router.metrics
        self.max_pending = max_pending
        self.host = host
        self.port = port
        self.supervise_s = supervise_s
        self.stream_poll_s = stream_poll_s
        self.draining = False
        self._loop: Optional[asyncio.AbstractEventLoop] = None
        self._stop: Optional[asyncio.Event] = None
        self._thread: Optional[threading.Thread] = None
        self._started = threading.Event()
        self._start_err: Optional[BaseException] = None

    # -- lifecycle ------------------------------------------------------
    def start(self) -> Tuple[str, int]:
        """Run the event loop in a dedicated thread; returns the bound
        (host, port) once the server is accepting connections."""
        if self._thread is not None:
            raise RuntimeError("frontend already started")
        self._thread = threading.Thread(
            target=lambda: asyncio.run(self._amain()),
            name="serving-frontend", daemon=True)
        self._thread.start()
        self._started.wait(timeout=30.0)
        if self._start_err is not None:
            raise RuntimeError("frontend failed to start") \
                from self._start_err
        if not self._started.is_set():
            raise RuntimeError("frontend did not start within 30s")
        return self.host, self.port

    def stop(self) -> None:
        if self._loop is not None and self._stop is not None:
            self._loop.call_soon_threadsafe(self._stop.set)
        if self._thread is not None:
            self._thread.join(timeout=10.0)

    def begin_drain(self) -> None:
        """Refuse new submissions here and on every replica; queued and
        running requests finish with their usual typed statuses."""
        self.draining = True
        self.router.drain()

    async def _amain(self) -> None:
        self._loop = asyncio.get_running_loop()
        self._stop = asyncio.Event()
        try:
            server = await asyncio.start_server(
                self._handle_conn, self.host, self.port)
        except BaseException as e:
            self._start_err = e
            self._started.set()
            return
        self.host, self.port = server.sockets[0].getsockname()[:2]
        self._started.set()
        sup = asyncio.ensure_future(self._supervisor())
        try:
            await self._stop.wait()
        finally:
            sup.cancel()
            server.close()
            await server.wait_closed()

    async def _supervisor(self) -> None:
        """Periodic supervision: hung/dead replica detection + restart.
        Runs in an executor thread — a restart rebuilds an engine (jit
        setup), which must never block the accept loop."""
        loop = asyncio.get_running_loop()
        while True:
            await asyncio.sleep(self.supervise_s)
            try:
                await loop.run_in_executor(None, self.router.supervise)
            except Exception:
                pass   # supervision must never kill the frontend

    # -- protocol -------------------------------------------------------
    @staticmethod
    def _send(writer: asyncio.StreamWriter, obj: dict) -> None:
        writer.write((json.dumps(obj) + "\n").encode("utf-8"))

    async def _handle_conn(self, reader: asyncio.StreamReader,
                           writer: asyncio.StreamWriter) -> None:
        try:
            while True:
                line = await reader.readline()
                if not line:
                    break
                try:
                    msg = json.loads(line)
                    op = msg.get("op")
                except (json.JSONDecodeError, UnicodeDecodeError,
                        AttributeError):
                    msg, op = {}, None
                if op == "stream":
                    await self._op_stream(msg, writer)
                else:
                    self._send(writer, self._dispatch(op, msg))
                await writer.drain()
        except (ConnectionResetError, BrokenPipeError):
            pass
        finally:
            writer.close()
            try:
                await writer.wait_closed()
            except (ConnectionResetError, BrokenPipeError):
                pass

    def _dispatch(self, op: Optional[str], msg: dict) -> dict:
        try:
            if op == "submit":
                return self._op_submit(msg)
            if op == "poll":
                return self._op_poll(msg)
            if op == "cancel":
                return self._op_cancel(msg)
            if op == "health":
                h = self.router.health()
                h.update(ok=True, draining=self.draining)
                return h
            if op == "metrics":
                return dict(ok=True, metrics=self.metrics.summary())
            if op == "drain":
                self.begin_drain()
                return dict(ok=True, pending=self.router.pending)
            return dict(ok=False, error=f"unknown-op:{op}",
                        retryable=False)
        except KeyError:
            return dict(ok=False, error="unknown-rid", retryable=False)
        except Exception as e:
            return dict(ok=False, error=f"internal:{e}", retryable=False)

    def _op_submit(self, msg: dict) -> dict:
        if self.draining:
            return dict(ok=False, error="draining", retryable=False)
        if (self.max_pending is not None
                and self.router.pending >= self.max_pending):
            # bounded-queue backpressure at the service edge: shed now,
            # before the request is journaled or routed — the client
            # backs off and retries
            self.metrics.on_shed()
            return dict(ok=False, error="shed", retryable=True)
        prompt = msg.get("prompt")
        if not isinstance(prompt, list) or not prompt:
            return dict(ok=False, error="rejected:empty-prompt",
                        retryable=False)
        req = Request(
            rid=self.router.allocate_rid(),
            prompt=np.asarray(prompt, np.int32),
            max_new_tokens=int(msg.get("max_new_tokens", 16)),
            eos_id=msg.get("eos_id"),
            deadline_s=msg.get("deadline_s"),
            max_queue_wait_s=msg.get("max_queue_wait_s"),
        )
        try:
            rid = self.router.submit(req, session=msg.get("session"))
        except NoReplicaAvailable:
            return dict(ok=False, error="unavailable", retryable=True)
        except ValueError as e:
            return dict(ok=False, error=f"rejected:{e}", retryable=False)
        return dict(ok=True, rid=rid)

    def _op_poll(self, msg: dict) -> dict:
        done, status, tokens = self.router.result(int(msg["rid"]))
        return dict(ok=True, rid=int(msg["rid"]), done=done,
                    status=status, tokens=tokens)

    def _op_cancel(self, msg: dict) -> dict:
        return dict(ok=True,
                    cancelled=self.router.cancel(int(msg["rid"])))

    async def _op_stream(self, msg: dict,
                         writer: asyncio.StreamWriter) -> None:
        try:
            rid = int(msg["rid"])
            done, status, tokens = self.router.result(rid)
        except (KeyError, ValueError, TypeError):
            self._send(writer, dict(ok=False, error="unknown-rid",
                                    retryable=False))
            return
        self._send(writer, dict(ok=True, rid=rid))
        sent = 0
        while True:
            done, status, tokens = self.router.result(rid)
            if len(tokens) > sent:
                self._send(writer, dict(tokens_delta=tokens[sent:]))
                sent = len(tokens)
                await writer.drain()
            if done:
                self._send(writer, dict(done=True, status=status,
                                        tokens=tokens))
                await writer.drain()
                return
            await asyncio.sleep(self.stream_poll_s)


# ----------------------------------------------------------------------
class ClientError(RuntimeError):
    """Base class for client-side failures."""


class RequestRejected(ClientError):
    """The frontend returned a terminal (non-retryable) error."""


class FrontendUnavailable(ClientError):
    """Retries exhausted against a retryable condition."""


class ServingClient:
    """Synchronous client with capped exponential retry/backoff.

    Connection failures and retryable responses (``shed``,
    ``unavailable``) back off ``base * 2**attempt`` seconds (capped)
    for up to ``retry_max`` retries, then raise
    ``FrontendUnavailable``. Terminal responses (``rejected``,
    ``draining``, ``unknown-rid``) raise ``RequestRejected``
    immediately — retrying a decision would never change it.
    ``self.retries`` counts retry attempts (the serve ledger reports
    it).
    """

    def __init__(self, host: str, port: int,
                 retry_max: Optional[int] = None,
                 retry_base_s: Optional[float] = None,
                 retry_cap_s: Optional[float] = None,
                 timeout_s: float = 30.0,
                 sleep: Callable[[float], None] = time.sleep):
        self.host = host
        self.port = port
        self.retry_max = (default_retry_max() if retry_max is None
                          else int(retry_max))
        self.retry_base_s = (default_retry_base_s()
                             if retry_base_s is None else float(retry_base_s))
        self.retry_cap_s = (default_retry_cap_s()
                            if retry_cap_s is None else float(retry_cap_s))
        self.timeout_s = timeout_s
        self._sleep = sleep
        self.retries = 0
        self.metrics: Optional[ServiceMetrics] = None  # optional mirror

    # -- transport ------------------------------------------------------
    def _rpc(self, payload: dict) -> dict:
        with socket.create_connection((self.host, self.port),
                                      timeout=self.timeout_s) as s:
            f = s.makefile("rwb")
            f.write((json.dumps(payload) + "\n").encode("utf-8"))
            f.flush()
            line = f.readline()
        if not line:
            raise ConnectionError("frontend closed the connection")
        return json.loads(line)

    def _rpc_retry(self, payload: dict) -> dict:
        attempt = 0
        while True:
            try:
                resp = self._rpc(payload)
            except (OSError, json.JSONDecodeError) as e:
                resp = dict(ok=False, error=f"transport:{e}",
                            retryable=True)
            if resp.get("ok"):
                return resp
            if not resp.get("retryable"):
                raise RequestRejected(str(resp.get("error")))
            if attempt >= self.retry_max:
                raise FrontendUnavailable(
                    f"retries exhausted ({self.retry_max}): "
                    f"{resp.get('error')}")
            self.retries += 1
            if self.metrics is not None:
                self.metrics.on_retry()
            self._sleep(backoff_s(attempt, self.retry_base_s,
                                  self.retry_cap_s))
            attempt += 1

    # -- API ------------------------------------------------------------
    def submit(self, prompt: List[int], max_new_tokens: int = 16,
               eos_id: Optional[int] = None,
               deadline_s: Optional[float] = None,
               max_queue_wait_s: Optional[float] = None,
               session: Optional[str] = None) -> int:
        resp = self._rpc_retry(dict(
            op="submit", prompt=[int(t) for t in prompt],
            max_new_tokens=int(max_new_tokens), eos_id=eos_id,
            deadline_s=deadline_s, max_queue_wait_s=max_queue_wait_s,
            session=session))
        return int(resp["rid"])

    def poll(self, rid: int) -> dict:
        return self._rpc_retry(dict(op="poll", rid=rid))

    def wait(self, rid: int, timeout: float = 120.0,
             poll_s: float = 0.02) -> Tuple[str, List[int]]:
        """Poll until terminal; returns (status, tokens)."""
        deadline = time.monotonic() + timeout
        while True:
            resp = self.poll(rid)
            if resp["done"]:
                return resp["status"], resp["tokens"]
            if time.monotonic() >= deadline:
                raise TimeoutError(f"rid {rid} not terminal in {timeout}s")
            self._sleep(poll_s)

    def stream(self, rid: int):
        """Yield tokens as the server streams them (one dedicated
        connection); raises ``RequestRejected`` on a terminal error."""
        with socket.create_connection((self.host, self.port),
                                      timeout=self.timeout_s) as s:
            f = s.makefile("rwb")
            f.write((json.dumps(dict(op="stream", rid=rid)) + "\n")
                    .encode("utf-8"))
            f.flush()
            head = json.loads(f.readline())
            if not head.get("ok"):
                raise RequestRejected(str(head.get("error")))
            for line in f:
                msg = json.loads(line)
                for t in msg.get("tokens_delta", []):
                    yield int(t)
                if msg.get("done"):
                    return

    def cancel(self, rid: int) -> bool:
        return bool(self._rpc_retry(dict(op="cancel", rid=rid))["cancelled"])

    def health(self) -> dict:
        return self._rpc_retry(dict(op="health"))

    def service_metrics(self) -> dict:
        return self._rpc_retry(dict(op="metrics"))["metrics"]

    def drain(self) -> dict:
        return self._rpc_retry(dict(op="drain"))


# ----------------------------------------------------------------------
class ServingService:
    """WAL + N supervised replicas + router + TCP frontend in one box.

    ``engine_factory`` must build a fresh continuous-mode engine per
    call (each replica gets its own; restarts get fresh ones). Share
    the *prepared* weight tree across factory calls — preparation is
    the expensive part and is read-only at serve time.
    """

    def __init__(self, engine_factory: Callable[[], "object"],
                 n_replicas: int = 1,
                 wal_path: Optional[str] = None,
                 max_pending: Optional[int] = None,
                 heartbeat_s: Optional[float] = None,
                 stall_steps: Optional[int] = None,
                 hang_after_s: Optional[float] = None,
                 supervise_s: float = 0.1,
                 host: str = "127.0.0.1", port: int = 0):
        if n_replicas < 1:
            raise ValueError(f"n_replicas must be >= 1, got {n_replicas}")
        self.metrics = ServiceMetrics()
        self.wal = RequestWAL(wal_path) if wal_path else None
        self.replicas = [
            EngineReplica(f"r{i}", engine_factory,
                          heartbeat_s=heartbeat_s, stall_steps=stall_steps)
            for i in range(n_replicas)]
        self.router = ReplicaRouter(self.replicas, wal=self.wal,
                                    metrics=self.metrics,
                                    hang_after_s=hang_after_s)
        self.frontend = ServingFrontend(self.router,
                                        max_pending=max_pending,
                                        host=host, port=port,
                                        supervise_s=supervise_s)
        self.replayed = 0

    def start(self) -> Tuple[str, int]:
        self.router.start()
        self.replayed = self.router.recover()
        return self.frontend.start()

    def begin_drain(self) -> None:
        self.frontend.begin_drain()

    def shutdown(self, timeout: float = 30.0) -> None:
        self.frontend.stop()
        self.router.stop(timeout)
        if self.wal is not None:
            self.wal.close()

    def check_shutdown_invariants(self) -> None:
        self.router.check_shutdown_invariants()
        if self.wal is not None:
            assert not self.wal.pending, (
                f"WAL still pending after shutdown: "
                f"{sorted(self.wal.pending)}")


__all__ = ["ServingFrontend", "ServingClient", "ServingService",
           "ClientError", "RequestRejected", "FrontendUnavailable",
           "backoff_s", "default_retry_max", "default_retry_base_s",
           "default_retry_cap_s"]
