"""Serving engine: continuous batching over a slot-based KV cache.

The engine runs a **single persistent jitted step** — decode one token
for every lane, then sample — over a per-lane-position KV cache
(``make_cache(..., per_lane=True)``). A slot scheduler
(serving/scheduler.py) owns admission: requests queue with arrival
times, a free slot is filled the same step the previous occupant emits
EOS (lane recycling), and dead slots are masked so their logits are
never sampled. Per-lane positions mean one lane can be at position 3 of
its prompt while its neighbor is 40 tokens into generation — there is
no wave barrier, which is what converts the ICQ kernels' bandwidth win
into aggregate served tokens/s under mixed-length traffic.

Prompt handling has two gears. With ``prefill_chunk=1`` (the default)
prompts are walked one token per step in the same jitted program as
generation (teacher forcing: lanes inside their prompt feed the next
prompt token and ignore the sampled one) — no second program runs.
With ``prefill_chunk=S > 1`` a second persistent jitted program
(``launch/steps.make_prefill_chunk_step``) drains newly admitted
prompts S tokens at a time: every lane with bulk prompt left consumes
``min(S, remaining)`` tokens per launch (ragged tails and mid-decode
lanes are write-masked via per-lane ``seq_lens``, never re-padded or
re-traced), which routes the prompt matmuls through the large-M
dequant+MXU dispatch arm instead of paying one full decode step per
prompt token. The chunk program never samples: the first generated
token's logits always come from the decode step consuming the last
prompt token, so chunking changes *when* cache rows are written but
never what any sampled token sees — greedy continuous output stays
token-identical to the wave engine (and to ``prefill_chunk=1``).
Exactness caveat: that identity is bitwise when chunk and decode
matmuls execute the same math (the pure-XLA arm, or any same-arm
configuration — what CI pins); on the Pallas backend the chunk step's
M = B*S lands on the dequant+MXU arm while the 1-token walk's M = B
rides the fused kernel, whose different K-reduction order can differ in
the last ulp — the compiled-TPU validation pass (ROADMAP) owns
re-checking greedy stability there. ``ICQ_PREFILL_CHUNK`` sets the
default chunk.

On top of chunking, the **fused step** (``fused_step=True`` whenever
chunking is active; ``ICQ_FUSED_STEP=0`` restores the split structure)
folds the decode token into the chunk program's token axis: a mixed
prefill+decode iteration — some lanes admitting bulk prompt, others
generating — runs as ONE device launch
(``launch/steps.make_fused_step``) instead of a chunk pass followed by
a decode pass. Each lane consumes ``min(S, prompt remaining)`` tokens
(including its final prompt token) or exactly its decode token, and
sampling happens in the same launch from each lane's own last valid
column. Once every live lane is a decode lane the engine falls back to
the plain 1-token decode program, so pure-decode steady state is
untouched. Greedy fused output is token-identical to the split
structure (same same-arm caveat as chunking; CI pins it); sampled
streams differ because the fused engine draws one PRNG subkey per
iteration where the split engine draws none on chunk-only iterations.
Sampling
(serving/sampling.py) is fused into the decode step: greedy by
default, per-request temperature / top-k / top-p overrides, PRNG key
threaded from the engine seed.

``kv_layout`` selects how the continuous engine's KV cache charges HBM:

  * 'contiguous' (default; ``ICQ_KV_LAYOUT`` overrides) — every lane
    owns ``max_len`` cache rows up front: bit-for-bit the pre-paging
    engine. Cache HBM = ``batch * max_len`` rows regardless of traffic.
  * 'paged' — vLLM-style block pool (serving/kv_pool.py): cache rows
    live in ``kv_blocks`` physical blocks of ``kv_block_size`` rows;
    lanes map logical positions through per-lane page tables, appending
    a block only when their position crosses a block boundary and
    giving every block back the step they finish. Admission becomes
    allocator-aware (a request is only admitted when free blocks cover
    its prompt plus a minimum decode budget) and pool exhaustion
    preempts the youngest lane — its request requeues at the queue
    head with generated tokens folded into the prompt, so a greedy
    stream is *recomputed identically* after preemption. Greedy output
    is token-identical to 'contiguous' (CI-pinned); only HBM footprint
    and scheduling change. Cache HBM = ``kv_blocks * kv_block_size``
    rows — decoupled from ``batch * max_len``, which is what converts
    ICQuant's weight savings into concurrent-lane headroom.

``mode`` selects the runtime:

  * 'continuous' — the slot engine above. Dense / moe / vlm families
    run it natively; SSM and hybrid mixers run it via per-lane *state
    reset* (a (B,) reset mask threads into ``mamba2_apply`` and zeroes
    a recycled lane's conv/ssm state slices the step it is admitted —
    recurrent state has no positions to rewind, but zeroing on admit is
    exactly the fresh-cache semantics the wave engine provides).
    Enc-dec models and sliding-window ring caches stay wave-only.
  * 'wave'       — the legacy wave-synchronous static batcher kept as
    the parity baseline: admit up to ``batch_size`` requests, step every
    lane from position 0 until the *slowest* lane finishes, then admit
    the next wave with a fresh cache. Greedy only.
  * 'auto' (default) — 'continuous' when the config supports it, else
    'wave'.

With greedy sampling both modes emit token-identical streams for the
same request set (lanes are batch-independent; the parity test in CI
pins this), so 'auto' never changes results — only scheduling.

Quantized weights are converted ONCE at engine construction
(``weight_cache='prepared'``, the default): ICQPacked storage weights
become pre-padded ICQPrepared layouts, so the per-step jitted program
routes every matmul through the kernel-backed dispatch layer
(kernels/backend.py). ``runtime_fmt`` picks the prepared runtime format
(None = platform default, normally 'v2' — the checkpointed gap-stream
layout serving at ~0.3-0.45 b/w outlier overhead); ``'dense'``
materializes dense weights once; ``'none'`` keeps the reference
in-graph decode. A MetricsCollector (serving/metrics.py) records TTFT,
queue wait, tokens/s, slot occupancy and queue depth for every run.

Fault tolerance (this layer's additions; every default preserves the
pre-fault-tolerance engine bit-for-bit):

  * **Request lifecycle** — ``Request.deadline_s`` (end-to-end, from
    arrival on the engine clock) and ``max_queue_wait_s`` (queue wait
    alone) are enforced once per engine iteration: an expired running
    lane finishes with status ``'timeout'`` (partial output kept), an
    expired queued request with ``'expired'``. ``cancel(rid)`` is safe
    from ``on_token`` callbacks: a queued request leaves the queue, a
    running lane is torn down (slot + paged blocks freed) at the next
    iteration boundary, both with status ``'cancelled'``. Every request
    handed back by ``run()`` carries exactly one terminal
    ``Request.status`` from ``scheduler.STATUSES``.
  * **Backpressure** — ``max_queue`` bounds the submit queue
    (``ICQ_MAX_QUEUE``; None = unbounded, the historical behavior).
    ``submit`` returns False for a request the ``shed_policy``
    (``ICQ_SHED_POLICY``) turned away: ``'reject'`` sheds the *new*
    request, ``'shed-oldest'`` sheds the longest-queued one and admits
    the new. Shed requests terminate with status ``'rejected'``.
  * **Fault injection + recovery** — a seeded ``FaultInjector``
    (serving/faults.py; ``ICQ_FAULT_PLAN`` / ``ICQ_FAULT_RATE`` /
    ``ICQ_FAULT_SEED``) can fail chosen launches. Injected or not,
    every step launch is *checked*: launches that raise, and decode
    launches whose logits come back NaN/inf on a live lane (the
    signature of a corrupted v2 gap stream), are retried **once, on the
    bitwise-exact pure-XLA arm** (``kernels/backend.forced_backend``) —
    degraded mode, which then stays sticky for ``degrade_steps`` clean
    launches (``ICQ_DEGRADE_STEPS``, default 8) before dispatch returns
    to the kernel arms. If the degraded retry also fails, the engine
    falls back to the paged engine's preempt-and-requeue machinery:
    every live lane is preempted and replayed (greedy streams recompute
    identically). A request that needs more than two replays — a
    genuinely poisoned weight would otherwise loop forever — finishes
    as ``'failed'``, as does a sampled (temperature > 0) preemption
    victim, whose replay would silently diverge. The metrics ledger
    (faults / degraded_steps / replays / timeouts / cancellations /
    sheds) makes every recovery visible.
"""
from __future__ import annotations

import contextlib
import os
import time
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.backend import forced_backend
from repro.launch.steps import fork_cache_block, make_cache, \
    make_decode_step, make_fused_step, make_prefill_chunk_step, \
    prepare_serving_params
from repro.serving.faults import FaultInjected, FaultInjector
from repro.serving.kv_pool import KVBlockPool
from repro.serving.metrics import MetricsCollector
from repro.serving.spec_decode import DRAFTERS, Drafter, make_drafter, \
    make_spec_verify
from repro.serving.prefix_cache import PrefixCache, SessionStore, \
    block_hashes
from repro.serving.sampling import GREEDY, SamplingParams, sample_tokens
from repro.serving.scheduler import Request, SlotScheduler

__all__ = ["GenerationEngine", "Request", "make_serving_step",
           "make_fused_serving_step"]


class _BadLogits(RuntimeError):
    """A decode launch returned NaN/inf logits on a live lane (detected
    by the checked step, or reported by an injected ``'nan'`` fault)."""


class _ReplayNeeded(RuntimeError):
    """Both the normal launch and its degraded XLA retry failed: the
    engine must preempt the live lanes and replay them."""


def make_serving_step(cfg, sample: bool = True, check: bool = False):
    """decode-one-token + select-next, as a single jit-able program.

    ``sample=True``: (params, cache, tokens (B,1), pos (B,), live (B,),
    temperature (B,), top_k (B,), top_p (B,), key) -> (next (B,), cache).
    ``sample=False`` is the greedy fast path — same contract minus the
    sampling arrays and key (argmax only, measurably cheaper per step on
    CPU than the full sampler; the engine uses it whenever no live lane
    has temperature > 0, which keeps greedy serving at wave step cost).

    ``check=True`` appends a third output ``bad`` (B,) bool: True where
    a *live* lane's logits contain NaN/inf — the health probe the
    fault-recovery path keys on (a corrupted v2 gap stream poisons
    logits silently; this converts that into a typed, retryable
    failure). The token outputs are computed identically, so checked
    and unchecked variants emit the same streams.

    Both variants take two trailing optional arrays: ``pages`` (B,
    max_blocks) mirrors the paged-KV page tables into the cache
    (kv_layout='paged'), ``reset`` (B,) zeroes recycled lanes' recurrent
    state (continuous ssm/hybrid serving). None (the default) keeps the
    contiguous-attention contract bit-for-bit.
    """
    decode = make_decode_step(cfg)

    def step(params, cache, tokens, pos, live, temperature, top_k, top_p,
             key, pages=None, reset=None):
        logits, cache = decode(params, cache, tokens, pos, pages=pages,
                               reset=reset)
        toks = sample_tokens(logits, key, temperature, top_k, top_p,
                             live=live)
        if check:
            bad = live & ~jnp.isfinite(logits).all(axis=-1)
            return toks, cache, bad
        return toks, cache

    def greedy_step(params, cache, tokens, pos, live, pages=None,
                    reset=None):
        logits, cache = decode(params, cache, tokens, pos, pages=pages,
                               reset=reset)
        toks = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        if check:
            bad = live & ~jnp.isfinite(logits).all(axis=-1)
            return jnp.where(live, toks, 0), cache, bad
        return jnp.where(live, toks, 0), cache

    return step if sample else greedy_step


def make_fused_serving_step(cfg, sample: bool = True, check: bool = False):
    """Fused mixed prefill/decode iteration as a single jit-able program.

    Same contract family as ``make_serving_step``, but over an S-token
    chunk: (params, cache, tokens (B, S), pos (B,), seq_lens (B,), live
    (B,), [temperature, top_k, top_p, key,] pages) -> (next (B,), cache
    [, bad (B,)]). Each lane consumes its first ``seq_lens[i]`` chunk
    tokens (``> 1``: bulk prompt admission, ``== 1``: the decode token
    in column 0, ``== 0``: idle, fully write-masked) and the returned
    token is sampled from that lane's logits at its own last valid
    column — so one launch replaces the chunk-pass + decode-pass pair
    of a mixed continuous-batching iteration. The engine ignores the
    sampled token for lanes still inside their prompt (their logits are
    real but mid-prompt); ``sample=False`` / ``check=True`` mirror the
    decode program's greedy fast path and NaN health probe.
    """
    fused = make_fused_step(cfg)

    def step(params, cache, tokens, pos, lens, live, temperature, top_k,
             top_p, key, pages=None):
        logits, cache = fused(params, cache, tokens, pos, lens, pages=pages)
        toks = sample_tokens(logits, key, temperature, top_k, top_p,
                             live=live)
        if check:
            bad = live & ~jnp.isfinite(logits).all(axis=-1)
            return toks, cache, bad
        return toks, cache

    def greedy_step(params, cache, tokens, pos, lens, live, pages=None):
        logits, cache = fused(params, cache, tokens, pos, lens, pages=pages)
        toks = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        if check:
            bad = live & ~jnp.isfinite(logits).all(axis=-1)
            return jnp.where(live, toks, 0), cache, bad
        return jnp.where(live, toks, 0), cache

    return step if sample else greedy_step


def default_fused_step() -> bool:
    """Engine default for ``fused_step`` (ICQ_FUSED_STEP, default on):
    whether a chunked-prefill continuous engine folds the decode token
    into the chunk program and runs mixed prefill+decode iterations as
    ONE launch. Only consulted when chunked prefill is active
    (``prefill_chunk > 1`` on the continuous engine); off = the split
    two-launch chunk + decode structure."""
    env = os.environ.get("ICQ_FUSED_STEP")
    if not env:  # unset or set-but-empty
        return True
    low = env.lower()
    if low in ("1", "true", "yes", "on"):
        return True
    if low in ("0", "false", "no", "off"):
        return False
    raise ValueError(f"ICQ_FUSED_STEP must be a boolean flag, got {env!r}")


def default_prefill_chunk() -> int:
    """Engine default for ``prefill_chunk`` (ICQ_PREFILL_CHUNK, default 1 =
    walk prompts token-by-token inside the decode program, the pre-chunking
    behavior)."""
    env = os.environ.get("ICQ_PREFILL_CHUNK")
    if not env:  # unset or set-but-empty
        return 1
    try:
        chunk = int(env)
    except ValueError:
        raise ValueError(
            f"ICQ_PREFILL_CHUNK must be an integer, got {env!r}")
    if chunk < 1:
        raise ValueError(
            f"ICQ_PREFILL_CHUNK must be >= 1, got {chunk}")
    return chunk


def default_kv_layout() -> str:
    """Engine default for ``kv_layout`` (ICQ_KV_LAYOUT, default
    'contiguous' — the pre-paging slot cache, bit-for-bit)."""
    env = os.environ.get("ICQ_KV_LAYOUT")
    if not env:
        return "contiguous"
    if env not in ("contiguous", "paged"):
        raise ValueError(
            f"ICQ_KV_LAYOUT must be 'contiguous' or 'paged', got {env!r}")
    return env


def default_kv_block_size():
    """Paged-KV block size default (ICQ_KV_BLOCK_SIZE, default 16 rows).
    ``'auto'`` consults the shared JSON autotune cache for a block size
    recorded by ``kernels.autotune.autotune_kv_block_size`` (the
    fragmentation-vs-table-overhead sweep), falling back to 16 on a
    cache miss — the engine resolves it against its ``max_len``."""
    env = os.environ.get("ICQ_KV_BLOCK_SIZE")
    if not env:
        return 16
    if env == "auto":
        return "auto"
    try:
        bs = int(env)
    except ValueError:
        raise ValueError(
            f"ICQ_KV_BLOCK_SIZE must be an integer or 'auto', got {env!r}")
    if bs < 1:
        raise ValueError(f"ICQ_KV_BLOCK_SIZE must be >= 1, got {bs}")
    return bs


def default_prefix_cache() -> bool:
    """Engine default for ``prefix_cache`` (ICQ_PREFIX_CACHE, default
    off — the PR-7 engine bit-for-bit). On, the paged continuous engine
    shares identical prompt prefixes copy-on-write and retains session
    chains (serving/prefix_cache.py); requires ``kv_layout='paged'``."""
    env = os.environ.get("ICQ_PREFIX_CACHE")
    if not env:  # unset or set-but-empty
        return False
    low = env.lower()
    if low in ("1", "true", "yes", "on"):
        return True
    if low in ("0", "false", "no", "off"):
        return False
    raise ValueError(f"ICQ_PREFIX_CACHE must be a boolean flag, got {env!r}")


def default_session_ttl() -> float:
    """Session idle TTL default in seconds on the engine clock
    (ICQ_SESSION_TTL, default 300): a session whose last turn finished
    longer ago than this is dropped by the lifecycle pass and its
    retained blocks unpinned. 0 expires sessions at the next sweep —
    the deterministic testing hook, mirroring ``max_queue_wait_s=0``."""
    env = os.environ.get("ICQ_SESSION_TTL")
    if not env:
        return 300.0
    try:
        ttl = float(env)
    except ValueError:
        raise ValueError(f"ICQ_SESSION_TTL must be a number, got {env!r}")
    if ttl < 0:
        raise ValueError(f"ICQ_SESSION_TTL must be >= 0, got {ttl}")
    return ttl


def default_max_queue() -> Optional[int]:
    """Bounded-submit-queue default (ICQ_MAX_QUEUE; unset = None =
    unbounded, the pre-backpressure behavior)."""
    env = os.environ.get("ICQ_MAX_QUEUE")
    if not env:
        return None
    try:
        mq = int(env)
    except ValueError:
        raise ValueError(f"ICQ_MAX_QUEUE must be an integer, got {env!r}")
    if mq < 0:
        raise ValueError(f"ICQ_MAX_QUEUE must be >= 0, got {mq}")
    return mq


def default_shed_policy() -> str:
    """Backpressure shed policy default (ICQ_SHED_POLICY, default
    'reject' — turn away the *new* request; 'shed-oldest' drops the
    longest-queued request instead)."""
    env = os.environ.get("ICQ_SHED_POLICY")
    if not env:
        return "reject"
    if env not in ("reject", "shed-oldest"):
        raise ValueError(
            f"ICQ_SHED_POLICY must be 'reject' or 'shed-oldest', got {env!r}")
    return env


def default_degrade_steps() -> int:
    """Degraded-mode stickiness default (ICQ_DEGRADE_STEPS, default 8):
    clean launches on the XLA fallback arm before dispatch returns to
    the kernel arms after a recovered fault."""
    env = os.environ.get("ICQ_DEGRADE_STEPS")
    if not env:
        return 8
    try:
        n = int(env)
    except ValueError:
        raise ValueError(
            f"ICQ_DEGRADE_STEPS must be an integer, got {env!r}")
    if n < 1:
        raise ValueError(f"ICQ_DEGRADE_STEPS must be >= 1, got {n}")
    return n


def default_spec_decode() -> bool:
    """Engine default for ``spec_decode`` (ICQ_SPEC_DECODE, default off
    — the pre-speculation engine bit-for-bit). On, the continuous engine
    runs draft-and-verify iterations whenever every live lane is
    greedily decoding (serving/spec_decode.py); greedy output is
    token-identical either way, only the launch count changes."""
    env = os.environ.get("ICQ_SPEC_DECODE")
    if not env:  # unset or set-but-empty
        return False
    low = env.lower()
    if low in ("1", "true", "yes", "on"):
        return True
    if low in ("0", "false", "no", "off"):
        return False
    raise ValueError(f"ICQ_SPEC_DECODE must be a boolean flag, got {env!r}")


def default_spec_k() -> int:
    """Draft length default (ICQ_SPEC_K, default 4): tokens proposed per
    lane per speculative iteration; the verify launch scores k+1
    positions per lane."""
    env = os.environ.get("ICQ_SPEC_K")
    if not env:
        return 4
    try:
        k = int(env)
    except ValueError:
        raise ValueError(f"ICQ_SPEC_K must be an integer, got {env!r}")
    if k < 1:
        raise ValueError(f"ICQ_SPEC_K must be >= 1, got {k}")
    return k


def default_spec_draft() -> str:
    """Drafter default (ICQ_SPEC_DRAFT, default 'ngram' — host-side
    prompt-lookup, zero extra launches). See serving/spec_decode.py for
    the registry: ngram | self2bit | tiny | reject."""
    env = os.environ.get("ICQ_SPEC_DRAFT")
    if not env:
        return "ngram"
    if env not in DRAFTERS:
        raise ValueError(
            f"ICQ_SPEC_DRAFT must be one of {'|'.join(DRAFTERS)}, "
            f"got {env!r}")
    return env


def _continuous_supported(cfg, max_len: int) -> Optional[str]:
    """None if the config can run the continuous engine, else the reason."""
    if cfg.is_encdec:
        return "enc-dec models admit encoder output wave-at-a-time"
    if cfg.sliding_window and cfg.sliding_window < max_len:
        return "sliding-window ring cache has a batch-global position column"
    return None


class GenerationEngine:
    def __init__(self, params, cfg, batch_size: int, max_len: int,
                 weight_cache: str = "prepared",
                 runtime_fmt: Optional[str] = None,
                 mode: str = "auto",
                 sampling: Optional[SamplingParams] = None,
                 seed: int = 0,
                 prefill_chunk: Optional[int] = None,
                 kv_layout: Optional[str] = None,
                 kv_block_size: Optional[int] = None,
                 kv_blocks: Optional[int] = None,
                 clock: Optional[Callable[[], float]] = None,
                 max_queue: Optional[int] = None,
                 shed_policy: Optional[str] = None,
                 faults: Optional[FaultInjector] = None,
                 degrade_steps: Optional[int] = None,
                 fused_step: Optional[bool] = None,
                 prefix_cache: Optional[bool] = None,
                 session_ttl: Optional[float] = None,
                 spec_decode: Optional[bool] = None,
                 spec_k: Optional[int] = None,
                 spec_draft=None,
                 draft_params=None):
        kw = {"fmt": runtime_fmt} if runtime_fmt is not None else {}
        raw_params = params   # the self2bit drafter re-quantizes these
        self.params = prepare_serving_params(params, mode=weight_cache, **kw)
        self.cfg = cfg
        self.batch_size = batch_size
        self.max_len = max_len
        self.sampling = sampling if sampling is not None else GREEDY
        if prefill_chunk is None:
            prefill_chunk = default_prefill_chunk()
        if prefill_chunk < 1:
            raise ValueError(
                f"prefill_chunk must be >= 1, got {prefill_chunk}")
        self.prefill_chunk = int(prefill_chunk)
        if self.prefill_chunk > 1 and cfg.family in ("ssm", "hybrid"):
            import warnings

            warnings.warn(
                f"chunked prefill is not supported for the {cfg.family!r} "
                f"mixer (no per-position validity masking for recurrent "
                f"state); falling back to prefill_chunk=1", stacklevel=2)
            self.prefill_chunk = 1

        why_not = _continuous_supported(cfg, max_len)
        if mode == "auto":
            mode = "wave" if why_not else "continuous"
        elif mode == "continuous" and why_not:
            raise NotImplementedError(
                f"mode='continuous' unsupported for this config: {why_not}; "
                f"use mode='wave'")
        elif mode not in ("continuous", "wave"):
            raise ValueError(f"mode must be 'auto'|'continuous'|'wave', "
                             f"got {mode!r}")
        self.mode = mode
        if self.mode == "wave" and self.sampling != GREEDY:
            import warnings

            warnings.warn(
                "the wave engine is greedy-only: the engine-level "
                "sampling parameters are ignored in mode='wave'",
                stacklevel=2)

        # ---- KV-cache layout (contiguous slot rows vs paged block pool)
        if kv_layout is None:
            kv_layout = default_kv_layout()
        if kv_layout not in ("contiguous", "paged"):
            raise ValueError(f"kv_layout must be 'contiguous' or 'paged', "
                             f"got {kv_layout!r}")
        if kv_layout == "paged":
            if self.mode != "continuous":
                raise NotImplementedError(
                    "kv_layout='paged' requires the continuous engine "
                    "(the wave engine rebuilds a contiguous cache per wave)")
            if cfg.family == "ssm":
                raise NotImplementedError(
                    "kv_layout='paged' needs an attention KV cache; the "
                    "'ssm' mixer carries recurrent state only")
        self.kv_layout = kv_layout
        if kv_block_size is None:
            kv_block_size = default_kv_block_size()
        if kv_block_size == "auto":
            # block-size sweep winner for this cache cap (the shared
            # JSON autotune cache); static default on a miss
            from repro.kernels import autotune

            kv_block_size = autotune.kv_block_size_for(max_len) or 16
        self.kv_block_size = int(kv_block_size)
        if self.kv_block_size < 1:
            raise ValueError(
                f"kv_block_size must be >= 1, got {self.kv_block_size}")
        # page-table width: a lane never maps more than the cache cap
        self._n_pt = -(-max_len // self.kv_block_size)
        if kv_blocks is None:
            # default pool = contiguous capacity (batch * max_len rows):
            # same worst-case footprint, but blocks only charge HBM-rows
            # that are actually mapped to a lane. Shrink to oversubscribe.
            kv_blocks = batch_size * self._n_pt
        self.kv_blocks = int(kv_blocks)
        if self.kv_layout == "paged" and self.kv_blocks < 1:
            raise ValueError(f"kv_blocks must be >= 1, got {self.kv_blocks}")

        # ---- prefix cache + sessions (serving/prefix_cache.py)
        if prefix_cache is None:
            prefix_cache = default_prefix_cache()
        self.prefix_cache = bool(prefix_cache)
        if self.prefix_cache:
            if self.kv_layout != "paged":
                raise ValueError(
                    "prefix_cache=True requires kv_layout='paged' (prefix "
                    "sharing maps physical blocks through page tables)")
            if cfg.family in ("ssm", "hybrid"):
                raise NotImplementedError(
                    f"prefix_cache is not supported for the {cfg.family!r} "
                    f"mixer: recurrent state has no per-position rows to "
                    f"share, so a warm start past position 0 cannot be "
                    f"reconstructed from cached blocks")
        self.session_ttl = (default_session_ttl() if session_ttl is None
                            else float(session_ttl))
        if self.session_ttl < 0:
            raise ValueError(
                f"session_ttl must be >= 0, got {self.session_ttl}")
        self._prefix = PrefixCache() if self.prefix_cache else None
        self._sessions = SessionStore() if self.prefix_cache else None
        self._session_rid: Dict[str, int] = {}   # in-flight request per sid
        self._pending_match: Dict[int, tuple] = {}  # rid -> pinned match
        self._cache = None   # persistent device cache (prefix-cache runs)
        # COW tail fork as ONE jitted program (src/dst are traced scalars,
        # so every fork reuses the same trace); built lazily on first use
        self._fork_block = None

        self._decode = jax.jit(make_decode_step(cfg))       # wave path
        # continuous path: checked variants (tokens identical to the
        # unchecked programs; the extra `bad` output is the NaN probe the
        # recovery path keys on)
        self._step = jax.jit(make_serving_step(cfg, check=True))
        self._step_greedy = jax.jit(
            make_serving_step(cfg, sample=False, check=True))
        # degraded twins: the *same* programs, but their (lazy) first
        # trace happens under forced_backend('xla') — every matmul is
        # pinned to the bitwise-exact pure-XLA arm. Distinct jit objects
        # so the two arms never share a compilation cache entry.
        self._step_xla = jax.jit(make_serving_step(cfg, check=True))
        self._step_greedy_xla = jax.jit(
            make_serving_step(cfg, sample=False, check=True))
        # recurrent mixers need the lane-reset mask on every decode launch
        self._needs_reset = cfg.family in ("ssm", "hybrid")
        # chunked prefill's device programs. With fused_step (the default
        # whenever chunking is active) the chunk and decode programs of a
        # mixed iteration collapse into ONE fused program — the decode
        # token rides the chunk's token axis and sampling happens in the
        # same launch; the split chunk program is then never built. With
        # fused_step=False (ICQ_FUSED_STEP=0) the PR-4 two-launch
        # structure is kept bit-for-bit. chunk=1 keeps the PR-3
        # single-program engine: neither program is built.
        chunking = self.prefill_chunk > 1 and self.mode == "continuous"
        if fused_step is None:
            fused_step = default_fused_step()
        self.fused_step = bool(fused_step) and chunking
        self._fused = self._fused_greedy = None
        self._fused_xla = self._fused_greedy_xla = None
        if self.fused_step:
            self._fused = jax.jit(make_fused_serving_step(cfg, check=True))
            self._fused_greedy = jax.jit(
                make_fused_serving_step(cfg, sample=False, check=True))
            # degraded twins (same pattern as the decode programs above)
            self._fused_xla = jax.jit(
                make_fused_serving_step(cfg, check=True))
            self._fused_greedy_xla = jax.jit(
                make_fused_serving_step(cfg, sample=False, check=True))
        # second persistent jitted program: S-token prompt-chunk admission
        self._chunk_step = (
            jax.jit(make_prefill_chunk_step(cfg))
            if chunking and not self.fused_step else None)
        self._chunk_step_xla = (
            jax.jit(make_prefill_chunk_step(cfg))
            if self._chunk_step is not None else None)
        if chunking:
            from repro.kernels import autotune

            # chunk matmuls carry M = batch * chunk tokens: give the
            # autotuner (and backend.arm_blocks at call time) a bucket at
            # that M so the large-M arm can block for the chunk shape.
            autotune.register_prefill_m(batch_size * self.prefill_chunk)
        self._sched = SlotScheduler(batch_size)
        self._pool: Optional[KVBlockPool] = None    # built per run (paged)
        self._pages_dev = None    # device mirror of the pool's page table
        self._pages_ver = -1
        self._row_bytes: Optional[float] = None  # KV bytes per cache row
        self._folded: Dict[int, int] = {}   # rid -> generated tokens already
        #                                     folded into the prompt (preempt)
        self._key = jax.random.PRNGKey(seed)
        self._clock = clock
        self._real_clock = clock is None
        self._t0: Optional[float] = None
        self._skew = 0.0
        self.completed: Dict[int, Request] = {}
        self.metrics = MetricsCollector()

        # ---- fault tolerance (see module doc)
        self.max_queue = default_max_queue() if max_queue is None \
            else int(max_queue)
        if self.max_queue is not None and self.max_queue < 0:
            raise ValueError(f"max_queue must be >= 0, got {self.max_queue}")
        self.shed_policy = (default_shed_policy() if shed_policy is None
                            else shed_policy)
        if self.shed_policy not in ("reject", "shed-oldest"):
            raise ValueError(f"shed_policy must be 'reject' or "
                             f"'shed-oldest', got {self.shed_policy!r}")
        # faults=None reads the ICQ_FAULT_* env knobs (normally unset ->
        # no injector at all; pass an explicit FaultInjector to drive a
        # storm programmatically)
        self.faults = FaultInjector.from_env() if faults is None else faults
        self.degrade_steps = (default_degrade_steps() if degrade_steps is None
                              else int(degrade_steps))
        if self.degrade_steps < 1:
            raise ValueError(
                f"degrade_steps must be >= 1, got {self.degrade_steps}")
        # ---- speculative decoding (serving/spec_decode.py)
        if spec_decode is None:
            spec_decode = default_spec_decode()
        self.spec_decode = bool(spec_decode)
        self.spec_k = default_spec_k() if spec_k is None else int(spec_k)
        if self.spec_k < 1:
            raise ValueError(f"spec_k must be >= 1, got {self.spec_k}")
        if spec_draft is None:
            spec_draft = default_spec_draft()
        self.spec_draft = (spec_draft.name if isinstance(spec_draft, Drafter)
                           else spec_draft)
        self._drafter: Optional[Drafter] = None
        self._verify = None
        if self.spec_decode:
            if self.mode != "continuous":
                raise NotImplementedError(
                    "spec_decode=True requires the continuous engine "
                    "(the wave engine has no per-lane positions to rewind)")
            if cfg.family in ("ssm", "hybrid"):
                raise NotImplementedError(
                    f"spec_decode needs positional KV rollback; the "
                    f"{cfg.family!r} mixer carries recurrent state that "
                    f"cannot rewind past a rejected draft")
            if isinstance(spec_draft, Drafter):
                self._drafter = spec_draft   # injected (tests, custom)
            else:
                self._drafter = make_drafter(
                    spec_draft, raw_params, cfg, batch_size, max_len,
                    weight_cache=weight_cache, prepare_kw=kw,
                    draft_params=draft_params, seed=seed)
            self._verify = jax.jit(make_spec_verify(cfg))
            from repro.kernels import autotune

            # the verify launch carries M = batch * (spec_k + 1) tokens:
            # give the autotuner a bucket at that M so the large-M
            # dequant+MXU arm can block for the verify shape
            autotune.register_prefill_m(batch_size * (self.spec_k + 1))
        self._draft_mark = 0     # drafter.launches already ledgered

        self._launch_no = 0           # global launch counter (decode+chunk)
        self._degraded_left = 0       # sticky degraded-mode countdown
        self._cancel_pending: set = set()   # rids awaiting cancellation
        self._replayed: Dict[int, int] = {}  # rid -> replay count (cap 2)
        self._replay_cap = 2

        # ---- service-layer hooks (serving/replica.py) — both inert by
        # default, so an engine used directly is bit-for-bit the PR-8
        # engine. ``on_iteration`` is called at the top of every
        # continuous-mode iteration (before the lifecycle pass): the
        # replica supervisor uses it to drain its inbox mid-run, beat
        # its heartbeat and raise to simulate a hard crash. A hook that
        # submits or cancels takes effect the same iteration.
        self.on_iteration: Optional[Callable[[], None]] = None
        self._draining = False

    # ------------------------------------------------------------------
    def submit(self, req: Request, session: Optional[str] = None) -> bool:
        """Enqueue a request; returns False when backpressure shed it.

        Invalid requests (empty prompt, prompt that cannot fit,
        duplicate rid, paged-unservable) still raise — those are caller
        bugs, not load. A shed request terminates immediately with
        status ``'rejected'`` and appears in ``run()``'s results like
        every other submission, so callers never lose track of a rid.

        ``session`` (or ``req.session``) names a multi-turn session on a
        prefix-cache engine: the finished turn's KV blocks stay pinned
        under that id (TTL/LRU-bounded) and the next turn's prompt
        warm-starts past the longest shared prefix — only the delta is
        prefilled. One request per session may be in flight at a time.
        """
        if session is not None:
            req.session = session
        if req.session is not None:
            if self._sessions is None:
                raise ValueError(
                    f"request {req.rid}: session={req.session!r} requires "
                    f"an engine built with prefix_cache=True "
                    f"(kv_layout='paged')")
            other = self._session_rid.get(req.session)
            if other is not None:
                raise ValueError(
                    f"request {req.rid}: session {req.session!r} already "
                    f"has request {other} in flight (one turn at a time)")
        n = len(req.prompt)
        if n == 0:
            raise ValueError(f"request {req.rid}: empty prompt")
        if n >= self.max_len:
            raise ValueError(
                f"request {req.rid}: prompt length {n} does not fit "
                f"max_len={self.max_len} (needs at most max_len - 1 prompt "
                f"positions to generate anything); raise max_len or "
                f"truncate the prompt")
        if req.rid in self.metrics.requests:
            raise ValueError(f"duplicate request id {req.rid}")
        if self.kv_layout == "paged":
            # a request must be servable by the pool *alone* (this is
            # also what guarantees preemption always makes progress: a
            # lane with the whole pool to itself can always finish)
            need = -(-min(n + req.max_new_tokens, self.max_len)
                     // self.kv_block_size)
            if need > self.kv_blocks:
                raise ValueError(
                    f"request {req.rid}: needs {need} KV blocks "
                    f"(prompt {n} + budget {req.max_new_tokens} tokens at "
                    f"block_size={self.kv_block_size}) but the pool only "
                    f"has {self.kv_blocks}; raise kv_blocks or shrink the "
                    f"request")
        if (self.mode == "wave" and req.sampling is not None
                and req.sampling != GREEDY):
            import warnings

            warnings.warn(
                f"request {req.rid}: per-request sampling parameters are "
                f"ignored by the greedy-only wave engine", stacklevel=2)
        if self._draining:
            # drain refuses new admissions exactly like a backpressure
            # shed: the request still terminates (status 'rejected'),
            # preserving the exactly-once typed-status guarantee while
            # in-flight and already-queued work runs to completion
            self.metrics.on_submit(req.rid, req.arrival_time, n)
            self._terminal_queued(req, req.arrival_time, "rejected")
            return False
        if (self.max_queue is not None
                and self._sched.queue_depth >= self.max_queue):
            if self.shed_policy == "reject":
                # the new request is the victim: record it (metrics +
                # results) and turn it away
                self.metrics.on_submit(req.rid, req.arrival_time, n)
                self._terminal_queued(req, req.arrival_time, "rejected")
                return False
            victim = self._sched.shed_oldest()   # 'shed-oldest'
            if victim is not None:
                self._terminal_queued(victim, req.arrival_time, "rejected")
        self.metrics.on_submit(req.rid, req.arrival_time, n)
        self._sched.submit(req)
        if req.session is not None:
            self._session_rid[req.session] = req.rid
        return True

    def cancel(self, rid: int) -> bool:
        """Request cancellation of ``rid``; safe from ``on_token``.

        Returns True when the cancellation is pending (it takes effect
        at the next iteration boundary: a queued request leaves the
        queue, a running lane frees its slot and paged blocks — both
        with status ``'cancelled'`` and partial output kept), False when
        the request already finished. Unknown rids raise KeyError.
        """
        if rid not in self.metrics.requests:
            raise KeyError(f"unknown request id {rid}")
        if rid in self.completed:
            return False
        self._cancel_pending.add(rid)
        return True

    def request_drain(self) -> None:
        """Refuse new submissions from now on (graceful drain).

        Already-queued and running requests finish normally; subsequent
        ``submit()`` calls terminate immediately with status
        ``'rejected'``. Sticky for the engine's lifetime — a drained
        replica is replaced by a fresh engine, never re-opened.
        """
        self._draining = True

    @property
    def draining(self) -> bool:
        return self._draining

    def has_work(self) -> bool:
        """True while any request is queued or running (scheduler view)."""
        return self._sched.has_work()

    def _now(self) -> float:
        raw = time.monotonic() if self._real_clock else self._clock()
        if self._t0 is None:
            self._t0 = raw
        return raw - self._t0 + self._skew

    def _idle_until(self, t: float) -> None:
        """Nothing admissible: wait out the gap to the next arrival."""
        now = self._now()
        if t <= now:
            return
        if self._real_clock:
            time.sleep(t - now)
        else:
            self._skew += t - now  # virtual clock: fast-forward

    # ------------------------------------------------------------------
    # continuous mode
    # ------------------------------------------------------------------

    def _finish(self, slot: int, t: float, live: np.ndarray,
                pos: np.ndarray, tokens: np.ndarray,
                status: str = "ok") -> None:
        req = self._sched.release(slot)
        if self._pool is not None:
            if status == "ok" and self.prefix_cache:
                # index the finished chain / retain the session chain
                # BEFORE the lane's references drop, so shared blocks
                # never transit refcount 0 on their way into the cache
                self._retain_prefix(slot, req, int(pos[slot]), t)
            self._pool.release(slot)   # lane references dropped same step
        if req.session is not None:
            self._session_rid.pop(req.session, None)
        self._folded.pop(req.rid, None)
        self._replayed.pop(req.rid, None)
        self._cancel_pending.discard(req.rid)
        req.status = status
        self.metrics.on_finish(req.rid, t, len(req.generated), status=status)
        self.completed[req.rid] = req
        live[slot] = False
        pos[slot] = 0
        tokens[slot, 0] = 0

    def _terminal_queued(self, req: Request, t: float, status: str) -> None:
        """Terminal path for a request that never occupies a slot again
        (queued expiry/cancellation, backpressure shed). Partial output
        from a pre-preemption life is kept on the request."""
        if req.session is not None:
            self._session_rid.pop(req.session, None)
        self._folded.pop(req.rid, None)
        self._replayed.pop(req.rid, None)
        self._cancel_pending.discard(req.rid)
        req.status = status
        self.metrics.on_finish(req.rid, t, len(req.generated), status=status)
        self.completed[req.rid] = req

    def _lifecycle_pass(self, now: float, live: np.ndarray, pos: np.ndarray,
                        tokens: np.ndarray) -> bool:
        """Once-per-iteration deadline/cancellation enforcement.

        Queued requests past ``max_queue_wait_s`` or ``deadline_s`` (or
        cancelled) leave the queue without ever occupying a slot; live
        lanes past ``deadline_s`` finish as ``'timeout'`` with whatever
        they generated, cancelled lanes as ``'cancelled'``. Returns True
        when anything changed (the caller must refresh the device ctrl
        mirror). Comparisons are ``>=`` so a zero deadline/wait expires
        deterministically under the virtual clock (which only advances
        across idle gaps) — ``max_queue_wait_s=0`` is the deterministic
        'never admitted' testing hook.
        """
        sched = self._sched
        changed = False

        def queued_verdict(req: Request) -> Optional[str]:
            if req.rid in self._cancel_pending:
                return "cancelled"
            waited = now - req.arrival_time
            if req.deadline_s is not None and waited >= req.deadline_s:
                return "expired"
            if (req.max_queue_wait_s is not None
                    and waited >= req.max_queue_wait_s):
                return "expired"
            return None

        for req in sched.drop_queued(lambda r: queued_verdict(r) is not None):
            self._terminal_queued(req, now, queued_verdict(req))
            changed = True
        for i in range(self.batch_size):
            if not live[i]:
                continue
            req = sched.slot(i).request
            if req.rid in self._cancel_pending:
                self._finish(i, now, live, pos, tokens, status="cancelled")
                changed = True
            elif (req.deadline_s is not None
                  and now - req.arrival_time >= req.deadline_s):
                self._finish(i, now, live, pos, tokens, status="timeout")
                changed = True
        if self._sessions is not None and len(self._sessions):
            # TTL sweep: idle sessions past ICQ_SESSION_TTL drop their
            # retained chains (in-flight sessions are exempt — their
            # next retention refreshes the stamp anyway)
            expired = self._sessions.expire(
                now, self.session_ttl, self._pool,
                protect=self._session_rid.keys())
            if expired:
                self.metrics.on_session_expired(len(expired))
        return changed

    # -- paged-KV admission / preemption -------------------------------

    def _admit_tokens(self, req: Request) -> int:
        """Positions an admission must be able to back: the whole prompt
        plus a minimum decode budget (one block's worth of generated
        tokens, or the remaining budget if smaller — a preempted request
        already folded its generated tokens into the prompt, so only the
        *unspent* budget counts), capped at the cache cap."""
        remaining = max(0, req.max_new_tokens - len(req.generated))
        return min(len(req.prompt) + min(remaining, self.kv_block_size),
                   self.max_len)

    def _admit_gate(self, req: Request) -> bool:
        pool = self._pool
        if not self.prefix_cache:
            return pool.free_blocks >= pool.blocks_for(
                self._admit_tokens(req))
        # prefix-aware gate: only the blocks NOT covered by the matched
        # prefix must come from the free list. The match is pinned
        # (temporary increfs) before any eviction runs, so LRU pressure
        # can never free the very blocks this admission is about to
        # share — and the pinned ids stay valid even if their cache
        # entries are evicted between gate and attach.
        m, shared, fork_src, via_session = self._match_for(req)
        need = pool.blocks_for(self._admit_tokens(req)) - len(shared)
        if pool.free_blocks < need and not self._evict_for(need):
            for b in shared:
                pool.decref(b)
            if fork_src is not None:
                pool.decref(fork_src)
            return False
        self._pending_match[req.rid] = (m, shared, fork_src, via_session)
        return True

    def _match_for(self, req: Request):
        """Longest warm prefix available for ``req``: the session chain
        (exact tokens, can warm-start mid-block) vs the hash cache
        (full blocks only), whichever matches more. Matched blocks are
        pinned with temporary increfs; the caller owns dropping them
        (after ``share`` re-references them lane-side, or on gate
        failure). Returns (m, shared_full_blocks, fork_src, via_session)
        where ``fork_src`` is the partially-matched block to COW-fork
        (None on a block-aligned match)."""
        pool = self._pool
        bs = pool.block_size
        L = len(req.prompt)
        now = self._now()
        m, chain, via_session = 0, [], False
        if req.session is not None:
            m, chain = self._sessions.match(req.session, req.prompt, now)
            m = min(m, L - 1)   # the decode step must consume >= 1 token
            via_session = m > 0
        hits = self._prefix.match(
            block_hashes(req.prompt, bs, n_blocks=(L - 1) // bs), now)
        if len(hits) * bs > m:
            m, chain, via_session = len(hits) * bs, hits, False
        nfull = m // bs
        shared = chain[:nfull]
        fork_src = chain[nfull] if m % bs else None
        for b in shared:
            pool.incref(b)
        if fork_src is not None:
            pool.incref(fork_src)
        return m, shared, fork_src, via_session

    def _evict_for(self, min_free: int) -> bool:
        """Pool-pressure gate for the caches: evict hash-cache entries
        (LRU leaves first), then idle sessions (LRU first), until the
        free list covers ``min_free`` blocks. True iff the target is met.

        Only sessions whose turn currently occupies a *slot* are
        protected. Protecting every submitted session would deadlock:
        with more queued sessions than the pool can pin, admission could
        never free enough blocks for anyone. A merely-queued session
        losing its chain costs a cold prefill, nothing more — and a
        running session's chain is mostly lane-shared anyway, so
        evicting it would barely free blocks while its retain-at-finish
        is imminent."""
        pool = self._pool
        if pool.free_blocks >= min_free:
            return True
        if self._prefix is not None:
            n = self._prefix.evict_until(pool, min_free)
            if n:
                self.metrics.on_prefix_evictions(n)
        if pool.free_blocks < min_free and self._sessions is not None:
            running = {s.request.rid
                       for s in self._sched.occupied().values()}
            n = self._sessions.evict_until(
                pool, min_free,
                protect=(sid for sid, rid in self._session_rid.items()
                         if rid in running))
            if n:
                self.metrics.on_session_evicted(n)
        return pool.free_blocks >= min_free

    def _attach_prefix(self, slot: int, req: Request, cache,
                       pos: np.ndarray, tokens: np.ndarray):
        """Admission-time warm start: map the matched blocks into the
        lane's page table, COW-fork the partially-matched tail block (if
        any), and advance the lane's position past the matched prefix —
        the existing chunked-prefill / teacher-forcing path then walks
        only the delta. Returns the (possibly fork-copied) cache."""
        pool = self._pool
        m, shared, fork_src, via_session = self._pending_match.pop(req.rid)
        forked = False
        if fork_src is not None:
            pool.share(slot, [*shared, fork_src])
            dst = pool.fork(slot, len(shared))
            if dst is None:
                # pool dry (cannot happen after a passed gate, but stay
                # safe): degrade to the block-aligned prefix
                pool.pop_last(slot)
                m = len(shared) * pool.block_size
            else:
                if self._fork_block is None:
                    self._fork_block = jax.jit(fork_cache_block)
                cache = self._fork_block(cache, jnp.int32(fork_src),
                                         jnp.int32(dst))
                forked = True
        elif shared:
            pool.share(slot, shared)
        # drop the temporary match pins: the lane now holds its own refs
        for b in shared:
            pool.decref(b)
        if fork_src is not None:
            pool.decref(fork_src)
        if m > 0:
            pos[slot] = m
            tokens[slot, 0] = int(req.prompt[m])
            self._sched.slot(slot).pos = m
        self.metrics.on_prefix_attach(m, forked=forked,
                                      via_session=via_session)
        return cache

    def _retain_prefix(self, slot: int, req: Request, nrows: int,
                       t: float) -> None:
        """Finish-time retention: index the lane's full blocks in the
        hash cache and (for session requests) pin the exact consumed
        chain under the session id. ``nrows`` is the lane's final
        position = tokens consumed; the last generated token was emitted
        but never consumed, so it is not part of the chain."""
        pool = self._pool
        if nrows < 1:
            return
        # tokens the lane consumed this life: the (possibly replay-
        # folded) prompt, then the generated tokens fed back after it
        folded = self._folded.get(req.rid, 0)
        seq = np.concatenate([
            np.asarray(req.prompt, np.int32),
            np.asarray(req.generated[folded:], np.int32),
        ])[:nrows]
        chain = pool.lane_chain(slot)[: pool.blocks_for(len(seq))]
        hashes = block_hashes(seq, pool.block_size)
        created = self._prefix.insert(hashes, chain[: len(hashes)], pool, t)
        if created:
            self.metrics.on_prefix_insert(created)
        if req.session is not None:
            self._sessions.retain(req.session, seq, chain, pool, t)

    def _grow_evicting(self, lane: int, n_tokens: int) -> int:
        """``pool.grow`` that spends cached chains before letting a lane
        clip its chunk: under pool pressure, LRU cache entries and idle
        sessions give their pinned blocks back first."""
        pool = self._pool
        if self.prefix_cache:
            cap = pool.max_blocks_per_lane * pool.block_size
            need = (pool.blocks_for(min(n_tokens, cap))
                    - pool.lane_blocks(lane))
            if need > pool.free_blocks:
                self._evict_for(need)
        return pool.grow(lane, n_tokens)

    def _preempt(self, slot: int, t: float, live: np.ndarray,
                 pos: np.ndarray, tokens: np.ndarray) -> None:
        """Pool exhausted: evict a lane and requeue its request.

        Preempt-and-recompute, vLLM-style: generated tokens fold into
        the prompt, the request goes back to the *head* of the queue
        (keeps FIFO), and on re-admission the lane replays the extended
        prompt through teacher forcing. Greedy decoding makes the replay
        reproduce the identical continuation, so preemption never
        changes a greedy stream — only its timing.

        A **sampled** lane (temperature > 0) has no such guarantee: its
        replay draws fresh PRNG and silently diverges from the stream
        already handed to ``on_token``. Rather than return a stream no
        run can reproduce, the lane force-finishes with status
        ``'failed'`` (partial output kept) — the caller sees a typed
        loss, not quiet divergence.
        """
        st = self._sched.slot(slot)
        sp = (st.request.sampling if st.request.sampling is not None
              else self.sampling)
        if sp.temperature > 0.0:
            self._finish(slot, t, live, pos, tokens, status="failed")
            return
        req = self._sched.release(slot)
        if self._pool is not None:    # contiguous replay has no pool
            self._pool.release(slot)
        # fold only the not-yet-folded suffix: a request preempted a
        # second time must not duplicate tokens already in the prompt
        folded = self._folded.get(req.rid, 0)
        fresh = req.generated[folded:]
        if fresh:
            req.prompt = np.concatenate(
                [np.asarray(req.prompt, np.int32),
                 np.asarray(fresh, np.int32)])
            self._folded[req.rid] = len(req.generated)
        self._sched.requeue_front(req)
        self.metrics.on_preempt(req.rid, t)
        live[slot] = False
        pos[slot] = 0
        tokens[slot, 0] = 0

    def _ensure_decode_blocks(self, live: np.ndarray, pos: np.ndarray,
                              tokens: np.ndarray) -> None:
        """Back every live lane's next write position, preempting the
        youngest live lane when the pool runs dry. Oldest-first order
        gives long-running lanes (closest to finishing, holding the
        most blocks) priority; the victim is always the youngest live
        lane — the requester itself when it *is* the youngest (or the
        only lane), and then its requeued request later gets the pool
        to itself, which ``submit`` guaranteed is enough (progress is
        total).
        """
        pool, sched = self._pool, self._sched
        order = sorted((i for i in range(self.batch_size) if live[i]),
                       key=lambda i: sched.slot(i).seq)
        for i in order:
            while live[i] and not pool.ensure(i, int(pos[i]) + 1):
                # cached chains give their blocks back before any lane
                # is preempted: cache pressure must never cost running
                # work (the caches only hold HBM nobody else wanted)
                if self.prefix_cache and self._evict_for(1):
                    continue
                # youngest live lane overall — possibly the requester
                # itself (then the loop exits via live[i] going False and
                # the requeued request later gets the pool to itself)
                victim = max((j for j in range(self.batch_size) if live[j]),
                             key=lambda j: sched.slot(j).seq)
                self._preempt(victim, self._now(), live, pos, tokens)

    # -- fault recovery -------------------------------------------------

    def _decode_launch(self, cache, tokens, pos, ctrl, greedy_only, sub,
                       extra, live, fault):
        """One checked decode launch under the recovery policy.

        Returns (toks, cache). A launch that raises (injected or genuine
        RuntimeError) or whose logits come back non-finite on a live
        lane is retried **once** on the degraded XLA arm with identical
        inputs — including the PRNG subkey, so a recovered sampled
        launch draws the very tokens the failed one would have. Failure
        of the retry raises ``_ReplayNeeded``; the caller preempts and
        replays the live lanes. The failed launch's cache output is
        discarded (jitted steps are functional), so a retry never sees
        half-written state.
        """
        d_live, d_temp, d_topk, d_topp = ctrl
        t_dev, p_dev = jnp.asarray(tokens), jnp.asarray(pos)

        def run(degraded: bool):
            if greedy_only:
                prog = (self._step_greedy_xla if degraded
                        else self._step_greedy)
                args = (self.params, cache, t_dev, p_dev, d_live)
            else:
                prog = self._step_xla if degraded else self._step
                args = (self.params, cache, t_dev, p_dev, d_live,
                        d_temp, d_topk, d_topp, sub)
            ctx = (forced_backend("xla") if degraded
                   else contextlib.nullcontext())
            with ctx:
                toks, cache2, bad = prog(*args, **extra)
            if bool((np.asarray(bad) & live).any()):
                raise _BadLogits("non-finite logits on a live lane")
            return toks, cache2

        degraded = self._degraded_left > 0
        try:
            if fault == "raise":
                raise FaultInjected(
                    f"injected 'raise' at launch {self._launch_no - 1}")
            out = run(degraded)
            if fault == "nan":
                # the launch ran; its logits are reported poisoned
                raise _BadLogits(
                    f"injected 'nan' at launch {self._launch_no - 1}")
        except RuntimeError as e:   # FaultInjected / _BadLogits / XLA
            if fault is not None:
                self.metrics.on_fault(fault)
            else:
                self.metrics.on_fault(
                    "nan" if isinstance(e, _BadLogits) else "error")
            self._degraded_left = self.degrade_steps
            try:
                out = run(True)   # retry once, bitwise-exact XLA arm
            except RuntimeError:
                raise _ReplayNeeded("decode launch failed twice")
        if self._degraded_left > 0:
            self._degraded_left -= 1
            self.metrics.on_degraded_step()
        return out

    def _replay_live_lanes(self, t: float, live: np.ndarray,
                           pos: np.ndarray, tokens: np.ndarray) -> None:
        """Both launch attempts failed: preempt every live lane through
        the standing preempt-and-requeue machinery, so the whole batch
        replays from requeued prompts (greedy streams recompute
        identically; sampled lanes force-finish as 'failed' inside
        ``_preempt``). A request that needs more than ``_replay_cap``
        replays — a genuinely poisoned weight or model would otherwise
        loop forever — finishes as ``'failed'`` with partial output.
        """
        self.metrics.on_replay()
        for i in range(self.batch_size):
            if not live[i]:
                continue
            rid = self._sched.slot(i).request.rid
            n = self._replayed.get(rid, 0) + 1
            self._replayed[rid] = n
            if n > self._replay_cap:
                self._finish(i, t, live, pos, tokens, status="failed")
            else:
                self._preempt(i, t, live, pos, tokens)

    def _prefill_chunk_pass(self, cache, pos: np.ndarray, live: np.ndarray,
                            tokens: np.ndarray):
        """Drain bulk prompt through the chunk program, one launch.

        A lane's *bulk* is every prompt token except the last (the decode
        step must consume the last one so the first generated token's
        logits are unchanged). Returns (cache, True) after a launch, or
        (cache, False) when no live lane has bulk left — the caller then
        runs a decode step as usual. Lanes mid-decode (or ragged tails
        shorter than the chunk) ride along write-masked via seq_lens.
        """
        B = self.batch_size
        sched = self._sched
        S = self.prefill_chunk
        lens = np.zeros((B,), np.int32)
        for i in range(B):
            if live[i]:
                r = sched.slot(i).request
                lens[i] = min(S, max(0, len(r.prompt) - 1 - pos[i]))
                if lens[i] and self._pool is not None:
                    # paged: clip the chunk to what the pool can back
                    # right now (never preempt for prefill — a clipped
                    # lane just chunks less this launch, and the decode
                    # pass owns last-resort preemption)
                    backed = self._grow_evicting(
                        i, int(pos[i]) + int(lens[i]))
                    lens[i] = min(lens[i], max(0, backed - int(pos[i])))
        if not lens.any():
            return cache, False
        ctoks = np.zeros((B, S), np.int32)
        for i in range(B):
            if lens[i]:
                r = sched.slot(i).request
                ctoks[i, : lens[i]] = r.prompt[pos[i]: pos[i] + lens[i]]
        # .copy(): argument transfers are async and pos mutates below —
        # the chunk step has no host-side output read to fence on.
        args = (self.params, cache, jnp.asarray(ctoks),
                jnp.asarray(pos.copy()), jnp.asarray(lens))
        fault = (self.faults.draw(self._launch_no)
                 if self.faults is not None else None)
        self._launch_no += 1

        def run(degraded: bool):
            prog = self._chunk_step_xla if degraded else self._chunk_step
            ctx = (forced_backend("xla") if degraded
                   else contextlib.nullcontext())
            with ctx:
                return prog(*args, pages=self._pages_mirror())

        degraded = self._degraded_left > 0
        try:
            if fault is not None:
                # the chunk step returns no logits and never touches the
                # allocator, so 'nan'/'alloc' draws degrade to 'raise'
                raise FaultInjected(
                    f"injected {fault!r} at chunk launch {self._launch_no - 1}")
            cache = run(degraded)
        except (FaultInjected, RuntimeError):
            self.metrics.on_fault(fault if fault is not None else "error")
            self._degraded_left = self.degrade_steps
            try:
                cache = run(True)   # retry once, bitwise-exact XLA arm
            except (FaultInjected, RuntimeError):
                raise _ReplayNeeded("chunk launch failed twice")
        if self._degraded_left > 0:
            self._degraded_left -= 1
            self.metrics.on_degraded_step()
        t_now = self._now()
        self.metrics.on_step(
            int(live.sum()), sched.queue_depth, t_now, kind="prefill",
            blocks_in_use=(None if self._pool is None
                           else self._pool.used_blocks),
            shared_blocks=(self._pool.shared_blocks()
                           if self.prefix_cache else None))
        self.metrics.on_prompt_tokens(int(lens.sum()), kind="prefill")
        for i in range(B):
            if lens[i]:
                pos[i] += int(lens[i])
                st = sched.slot(i)
                st.pos = int(pos[i])
                # next token to feed (the decode step consumes it when
                # every lane's bulk is drained)
                tokens[i, 0] = int(st.request.prompt[pos[i]])
        return cache, True

    def _fused_lens(self, live: np.ndarray, pos: np.ndarray) -> np.ndarray:
        """Per-lane token counts for one fused iteration: ``min(S,
        prompt remaining)`` for lanes still admitting prompt (INCLUDING
        the final prompt token — the fused program samples right after
        it, exactly like the decode step would), 1 for decoding lanes,
        0 for idle lanes. Paged lanes clip bulk to what the pool can
        back right now; ``_ensure_decode_blocks`` already guaranteed
        every live lane at least one backed position, so a clipped lane
        still consumes >= 1 token (never preempt for prefill)."""
        B = self.batch_size
        S = self.prefill_chunk
        lens = np.zeros((B,), np.int32)
        for i in range(B):
            if not live[i]:
                continue
            r = self._sched.slot(i).request
            lens[i] = max(1, min(S, len(r.prompt) - int(pos[i])))
            if lens[i] > 1 and self._pool is not None:
                backed = self._grow_evicting(i, int(pos[i]) + int(lens[i]))
                lens[i] = min(int(lens[i]), max(1, backed - int(pos[i])))
        return lens

    def _fused_pass(self, cache, pos: np.ndarray, live: np.ndarray,
                    tokens: np.ndarray, lens: np.ndarray, ctrl,
                    greedy_only, sub, fault):
        """One fused mixed prefill+decode launch + its bookkeeping.

        The single-launch counterpart of the chunk-pass + decode-pass
        pair: every live lane consumes its ``lens[i]`` chunk tokens and
        the lanes whose consumption reaches past their prompt (decoding
        lanes, and prompt lanes admitting their final token) emit one
        generated token, sampled inside the launch. Fault handling is
        identical to ``_decode_launch``: retry once on the bitwise-exact
        XLA arm with the same inputs (and PRNG subkey), then
        ``_ReplayNeeded``. Returns (cache, ctrl_dirty).
        """
        B = self.batch_size
        sched = self._sched
        ctoks = np.zeros((B, self.prefill_chunk), np.int32)
        n_prompt = 0
        for i in range(B):
            if not live[i]:
                continue
            r = sched.slot(i).request
            if pos[i] < len(r.prompt):   # prompt lane: feed prompt slice
                ctoks[i, : lens[i]] = r.prompt[pos[i]: pos[i] + lens[i]]
                n_prompt += int(lens[i])
            else:                        # decode lane: last emitted token
                ctoks[i, 0] = tokens[i, 0]
        d_live, d_temp, d_topk, d_topp = ctrl
        # .copy(): argument transfers are async and pos mutates below
        t_dev = jnp.asarray(ctoks)
        p_dev = jnp.asarray(pos.copy())
        l_dev = jnp.asarray(lens)

        def run(degraded: bool):
            if greedy_only:
                prog = (self._fused_greedy_xla if degraded
                        else self._fused_greedy)
                args = (self.params, cache, t_dev, p_dev, l_dev, d_live)
            else:
                prog = self._fused_xla if degraded else self._fused
                args = (self.params, cache, t_dev, p_dev, l_dev, d_live,
                        d_temp, d_topk, d_topp, sub)
            ctx = (forced_backend("xla") if degraded
                   else contextlib.nullcontext())
            with ctx:
                toks, cache2, bad = prog(*args, pages=self._pages_mirror())
            if bool((np.asarray(bad) & live).any()):
                raise _BadLogits("non-finite logits on a live lane")
            return toks, cache2

        degraded = self._degraded_left > 0
        try:
            if fault == "raise":
                raise FaultInjected(
                    f"injected 'raise' at launch {self._launch_no - 1}")
            out = run(degraded)
            if fault == "nan":
                raise _BadLogits(
                    f"injected 'nan' at launch {self._launch_no - 1}")
        except RuntimeError as e:   # FaultInjected / _BadLogits / XLA
            if fault is not None:
                self.metrics.on_fault(fault)
            else:
                self.metrics.on_fault(
                    "nan" if isinstance(e, _BadLogits) else "error")
            self._degraded_left = self.degrade_steps
            try:
                out = run(True)   # retry once, bitwise-exact XLA arm
            except RuntimeError:
                raise _ReplayNeeded("fused launch failed twice")
        if self._degraded_left > 0:
            self._degraded_left -= 1
            self.metrics.on_degraded_step()
        toks, cache = out
        nxt_tok = np.asarray(toks)
        t_now = self._now()
        self.metrics.on_step(
            int(live.sum()), sched.queue_depth, t_now, kind="fused",
            blocks_in_use=(None if self._pool is None
                           else self._pool.used_blocks),
            shared_blocks=(self._pool.shared_blocks()
                           if self.prefix_cache else None))
        self._note_attn_bytes(live, pos + lens)
        if n_prompt:
            self.metrics.on_prompt_tokens(n_prompt, kind="prefill")

        dirty = False
        for i in range(B):
            if not live[i]:
                continue
            st = sched.slot(i)
            r = st.request
            pos[i] += int(lens[i])
            st.pos = int(pos[i])
            if pos[i] < len(r.prompt):   # still admitting bulk prompt;
                # keep the next-token slot current in case the next
                # iteration falls through to the plain decode program
                tokens[i, 0] = int(r.prompt[pos[i]])
                continue
            tok = int(nxt_tok[i])
            if not r.generated:
                self.metrics.on_first_token(r.rid, t_now)
            r.generated.append(tok)
            if r.on_token is not None:
                r.on_token(r.rid, tok)
            tokens[i, 0] = tok
            if (
                len(r.generated) >= r.max_new_tokens
                or (r.eos_id is not None and tok == r.eos_id)
                or pos[i] >= self.max_len - 1   # cache cap
            ):
                self._finish(i, t_now, live, pos, tokens)
                dirty = True
        return cache, dirty

    def _spec_pass(self, cache, pos: np.ndarray, live: np.ndarray,
                   tokens: np.ndarray, ctrl, fault):
        """One speculative draft-and-verify iteration (pure-decode,
        greedy-only — the caller gates on both).

        The drafter proposes up to ``spec_k`` tokens per lane; ONE
        verify launch (M = batch * (spec_k + 1), the large-M arm) scores
        every column; greedy acceptance emits the longest matching draft
        prefix plus the verifier's own corrected/next token. Column j's
        logits are exactly what the plain 1-token walk would compute
        after consuming the same j+1 tokens (the chunked-prefill parity
        argument), so by induction over the accepted prefix the emitted
        stream is token-identical to plain decode — only launch count
        changes. Rejection rewinds the host ``pos`` vector and (paged)
        trims the lane's tail blocks; stale cache rows past the rewound
        position are harmless under the write-discipline invariant.

        Returns ``(cache, handled, fault, ctrl_dirty)``. ``handled``
        False means the caller must fall through to the plain decode
        program: either nothing could be drafted (``fault`` is handed
        back unspent) or the verify launch failed (``fault`` comes back
        None — consumed; degraded mode is set, so the plain decode
        retraces this iteration on the bitwise-exact XLA arm from the
        same cache, and its own retry/replay machinery takes over from
        there — a greedy replay recomputes the identical stream).
        """
        B = self.batch_size
        sched = self._sched
        S = self.spec_k + 1
        # per-lane draft budget: stay inside the cache cap and the
        # request's remaining token budget, and (paged) what the pool
        # can back right now — clip, never preempt (drafts must never
        # cost running work its blocks, mirroring the chunk pass)
        ks = np.zeros((B,), np.int32)
        hists: Dict[int, np.ndarray] = {}
        for i in range(B):
            if not live[i]:
                continue
            r = sched.slot(i).request
            k = min(self.spec_k,
                    self.max_len - 1 - int(pos[i]),
                    max(0, r.max_new_tokens - len(r.generated) - 1))
            if k > 0 and self._pool is not None:
                backed = self._grow_evicting(i, int(pos[i]) + k + 1)
                k = min(k, max(0, backed - int(pos[i]) - 1))
            ks[i] = k
            # the lane's consumed tokens + the pending feed token: the
            # (possibly replay-folded) prompt, then fresh generations
            folded = self._folded.get(r.rid, 0)
            seq = np.concatenate([
                np.asarray(r.prompt, np.int32),
                np.asarray(r.generated[folded:], np.int32)])
            hists[i] = seq[: int(pos[i]) + 1]
        slots = [i for i in range(B) if live[i] and ks[i] > 0]
        if not slots:
            return cache, False, fault, False
        d0 = self._drafter.launches
        try:
            drafts = self._drafter.propose(
                slots, [hists[i] for i in slots],
                [int(ks[i]) for i in slots])
        except Exception:
            self.metrics.on_spec_draft_error()
            drafts = None
        n_draft = self._drafter.launches - d0
        if n_draft:
            self.metrics.on_draft_launches(n_draft)
        if drafts is None:
            return cache, False, fault, False
        toks = np.zeros((B, S), np.int32)
        lens = np.zeros((B,), np.int32)
        for i in range(B):
            if not live[i]:
                continue
            toks[i, 0] = tokens[i, 0]
            d = np.asarray(drafts.get(i, ()), np.int32).ravel()[: int(ks[i])]
            ks[i] = len(d)
            toks[i, 1: 1 + len(d)] = d
            lens[i] = 1 + len(d)   # k == 0 lanes ride along as plain decode
        if not (ks > 0).any():
            return cache, False, fault, False

        d_live = ctrl[0]
        # .copy(): argument transfers are async and pos mutates below
        t_dev = jnp.asarray(toks)
        p_dev = jnp.asarray(pos.copy())
        l_dev = jnp.asarray(lens)
        try:
            if fault == "raise":
                raise FaultInjected(
                    f"injected 'raise' at verify launch {self._launch_no - 1}")
            tgt, cache2, bad = self._verify(
                self.params, cache, t_dev, p_dev, l_dev, d_live,
                pages=self._pages_mirror())
            if bool((np.asarray(bad) & live).any()):
                raise _BadLogits("non-finite logits on a live lane")
            if fault == "nan":
                raise _BadLogits(
                    f"injected 'nan' at verify launch {self._launch_no - 1}")
        except RuntimeError as e:   # FaultInjected / _BadLogits / XLA
            if fault is not None:
                self.metrics.on_fault(fault)
            else:
                self.metrics.on_fault(
                    "nan" if isinstance(e, _BadLogits) else "error")
            self._degraded_left = self.degrade_steps
            self.metrics.on_spec_fallback()
            # the failed launch's cache output is discarded (jitted
            # steps are functional): the plain decode below this pass
            # sees the pre-verify cache, bit-for-bit
            return cache, False, None, False

        tgt = np.asarray(tgt)
        t_now = self._now()
        self.metrics.on_step(
            int(live.sum()), sched.queue_depth, t_now, kind="verify",
            blocks_in_use=(None if self._pool is None
                           else self._pool.used_blocks),
            shared_blocks=(self._pool.shared_blocks()
                           if self.prefix_cache else None))
        self._note_attn_bytes(live, pos + lens)

        dirty = False
        for i in range(B):
            if not live[i]:
                continue
            st = sched.slot(i)
            r = st.request
            k = int(ks[i])
            # longest draft prefix the verifier agrees with: column j's
            # argmax must equal the token fed at column j+1
            a = 0
            while a < k and tgt[i, a] == toks[i, a + 1]:
                a += 1
            if k:
                self.metrics.on_spec(k, a)
            # emit the accepted drafts plus the corrected/next token
            # sequentially, with the plain decode path's exact per-token
            # finish checks — the stream (and where it stops) is the one
            # plain decode would produce
            emitted = 0
            finished = False
            for j in range(a + 1):
                tok = int(tgt[i, j])
                if not r.generated:
                    self.metrics.on_first_token(r.rid, t_now)
                r.generated.append(tok)
                if r.on_token is not None:
                    r.on_token(r.rid, tok)
                emitted = j + 1
                if (
                    len(r.generated) >= r.max_new_tokens
                    or (r.eos_id is not None and tok == r.eos_id)
                    or int(pos[i]) + emitted >= self.max_len - 1  # cache cap
                ):
                    finished = True
                    break
            new_pos = int(pos[i]) + emitted
            pos[i] = new_pos
            st.pos = new_pos
            tokens[i, 0] = int(r.generated[-1])
            if finished:
                self._finish(i, t_now, live, pos, tokens)
                dirty = True
            elif self._pool is not None and emitted <= k:
                # rollback: the rewound host pos is authoritative; unmap
                # the lane's tail blocks past its next write row. Never
                # trims below pos+1 rows, so blocks shared at admission
                # (all within the consumed prefix) are structurally out
                # of reach — COW safety by construction, not by check.
                self._pool.trim(i, new_pos + 1)
        return cache2, True, None, dirty

    def _note_attn_bytes(self, live: np.ndarray,
                         kv_lens: np.ndarray) -> None:
        """Accumulate the paged decode-attention bytes-read estimate for
        one launch. ``kv_lens[i]`` is lane i's KV length after the
        launch; 'logical' bills the full page-table span for every live
        lane (what a contiguous gather streams through HBM), 'live'
        only the blocks actually mapped (what the paged Pallas kernel
        streams through VMEM). No-op for contiguous caches."""
        if self._pool is None or self._row_bytes is None:
            return
        bs = self.kv_block_size
        logical = live_rows = 0
        for i in range(self.batch_size):
            if live[i]:
                logical += self._n_pt * bs
                live_rows += -(-int(kv_lens[i]) // bs) * bs
        self.metrics.on_attn_bytes(int(logical * self._row_bytes),
                                   int(live_rows * self._row_bytes))

    def _pages_mirror(self):
        """Device mirror of the pool's page table, refreshed only when the
        allocator mutated it (same pattern as the ctrl arrays)."""
        if self._pool is None:
            return None
        if self._pages_dev is None or self._pages_ver != self._pool.version:
            # .copy(): transfers are async and the host table mutates on
            # the very next alloc/release.
            self._pages_dev = jnp.asarray(self._pool.table.copy())
            self._pages_ver = self._pool.version
        return self._pages_dev

    def _run_continuous(self) -> Dict[int, Request]:
        B = self.batch_size
        sched = self._sched
        paged = self.kv_layout == "paged"
        # prefix-cache runs keep pool + device cache alive across run()
        # calls: retained session chains and hash-cache entries point
        # into them, which is what makes the next turn's submit->run
        # warm. Every other configuration rebuilds per run, exactly as
        # before.
        if not (self.prefix_cache and self._pool is not None):
            self._pool = (KVBlockPool(self.kv_blocks, self.kv_block_size, B,
                                      self._n_pt) if paged else None)
            self._pages_dev = None
            self._pages_ver = -1
            self._cache = make_cache(
                self.params, self.cfg, B, self.max_len, per_lane=True,
                paged=(self.kv_blocks, self.kv_block_size) if paged else None)
        cache = self._cache
        cache_bytes = sum(int(x.size) * x.dtype.itemsize
                          for x in jax.tree.leaves(cache))
        self.metrics.set_kv_stats(
            cache_bytes,
            kv_blocks=self.kv_blocks if paged else None,
            kv_block_size=self.kv_block_size if paged else None)
        # per-row KV bytes for the attention bytes-read estimate (coarse:
        # the small index/pages leaves amortize over the pool rows)
        self._row_bytes = (cache_bytes / (self.kv_blocks * self.kv_block_size)
                           if paged else None)
        # sliding-window + paged attention: the per-call arm gate
        # (models/layers._paged_attn_arm) routes any decode whose window
        # is shorter than the page-table span down the XLA gather arm —
        # silently, until now. The continuous engine only admits configs
        # with window >= max_len, so the gate can only fire in the
        # max_len <= window < n_pt * block_size rounding band; count it
        # per decode launch so the ledger makes the lost kernel visible.
        window_xla = bool(
            paged and self.cfg.sliding_window
            and self.cfg.sliding_window < self._n_pt * self.kv_block_size)
        tokens = np.zeros((B, 1), np.int32)
        pos = np.zeros((B,), np.int32)
        live = np.zeros((B,), bool)
        reset = np.zeros((B,), bool)   # lanes admitted since the last step
        temp = np.zeros((B,), np.float32)
        topk = np.zeros((B,), np.int32)
        topp = np.ones((B,), np.float32)
        ctrl = None        # device mirror of (live, temp, topk, topp):
        ctrl_dirty = True  # refreshed only on admit/finish, not per step
        greedy_only = True  # no live lane samples; refreshed with ctrl

        while sched.has_work():
            if self.on_iteration is not None:
                # service-layer hook (replica inbox drain / heartbeat /
                # kill). It may submit, cancel or raise; a raise
                # abandons the run — the supervisor discards the engine.
                self.on_iteration()
                if not sched.has_work():
                    break
            now = self._now()
            if self._lifecycle_pass(now, live, pos, tokens):
                ctrl_dirty = True
                if not sched.has_work():
                    break
            while True:
                # paged: admit one at a time so the allocator-aware gate
                # sees each admission's block reservation before judging
                # the next queued request (no overcommit inside a batch).
                admitted = sched.admit(
                    now, gate=self._admit_gate if paged else None,
                    limit=1 if paged else None)
                if not admitted:
                    break
                for slot, req in admitted:
                    live[slot] = True
                    pos[slot] = 0
                    tokens[slot, 0] = int(req.prompt[0])
                    reset[slot] = True
                    sp = (req.sampling if req.sampling is not None
                          else self.sampling)
                    temp[slot], topk[slot], topp[slot] = (
                        sp.temperature, sp.top_k, sp.top_p)
                    ctrl_dirty = True
                    self.metrics.on_admit(req.rid, now)
                    if paged:
                        if self.prefix_cache:
                            # warm start: map matched blocks, COW-fork
                            # the tail, advance pos past the prefix
                            cache = self._attach_prefix(
                                slot, req, cache, pos, tokens)
                        # reserve prompt + minimum decode budget
                        self._grow_evicting(slot, self._admit_tokens(req))
                if not paged:
                    break
            if not live.any():
                nxt = sched.next_arrival()
                if nxt is None:       # nothing queued, nothing running
                    break
                self._idle_until(nxt)
                continue
            if self._chunk_step is not None:
                try:
                    cache, launched = self._prefill_chunk_pass(
                        cache, pos, live, tokens)
                except _ReplayNeeded:
                    self._replay_live_lanes(self._now(), live, pos, tokens)
                    ctrl_dirty = True
                    continue
                if launched and not any(
                    live[i] and pos[i] >= len(sched.slot(i).request.prompt) - 1
                    for i in range(B)
                ):           # pure prefill phase: every live lane still has
                    continue  # bulk, so there is nothing to decode yet.
                # Otherwise fall through and decode in the same iteration:
                # drained lanes generate while their neighbors keep
                # chunking (the decode step teacher-forces mid-bulk lanes
                # one extra prompt token — order-free per lane, so token
                # streams are unchanged; only TTFT timing improves).
            if paged:
                # back every lane's next write position; exhaustion
                # preempts the youngest lane(s) into the queue.
                before = self.metrics.preemptions
                self._ensure_decode_blocks(live, pos, tokens)
                if self.metrics.preemptions != before:
                    ctrl_dirty = True
                    if not live.any():
                        continue
            # once-per-launch fault draw. An 'alloc' drill mutates the
            # live set through the standing preemption machinery, so it
            # runs *before* the ctrl refresh below; 'raise'/'nan' ride
            # into the launch helper.
            fault = (self.faults.draw(self._launch_no)
                     if self.faults is not None else None)
            self._launch_no += 1
            if fault == "alloc":
                if paged and live.any():
                    self.metrics.on_fault("alloc")
                    victim = max(
                        (j for j in range(B) if live[j]),
                        key=lambda j: sched.slot(j).seq)
                    self._preempt(victim, self._now(), live, pos, tokens)
                    ctrl_dirty = True
                    fault = None
                    if not live.any():
                        continue
                else:
                    fault = "raise"  # contiguous: no allocator to exhaust
            if ctrl_dirty:
                ctrl = tuple(jnp.asarray(a)
                             for a in (live, temp, topk, topp))
                # greedy fast path predicate: folded into the ctrl refresh
                # (live/temp only change on admit/finish), so steady-state
                # steps skip the host-array scan.
                greedy_only = not (temp[live] > 0.0).any()
                ctrl_dirty = False

            sub = None
            if not greedy_only:   # greedy fast path: no sampler, no PRNG
                # one split per iteration, shared by every retry of this
                # launch — a degraded retry redraws identical samples
                self._key, sub = jax.random.split(self._key)
            if (self._drafter is not None and greedy_only
                    and self._degraded_left == 0
                    and not any(
                        live[i]
                        and pos[i] < len(sched.slot(i).request.prompt) - 1
                        for i in range(B))):
                # speculative iteration: greedy-only (a sampled stream
                # has no acceptance identity), pure-decode steady state
                # only (drafts never preempt prefill — a lane still
                # admitting bulk prompt sends the whole batch down the
                # plain path), and never while degraded (the XLA
                # fallback arm should drain its countdown on the plain
                # 1-token program the recovery path reasons about)
                cache, handled, fault, dirty = self._spec_pass(
                    cache, pos, live, tokens, ctrl, fault)
                if handled:
                    ctrl_dirty |= dirty
                    continue
            if self.fused_step:
                lens = self._fused_lens(live, pos)
                if (lens > 1).any():
                    # at least one lane still has bulk prompt: this whole
                    # mixed iteration is ONE fused launch (chunk admission
                    # + the decode token + sampling in the same program)
                    try:
                        cache, dirty = self._fused_pass(
                            cache, pos, live, tokens, lens, ctrl,
                            greedy_only, sub, fault)
                    except _ReplayNeeded:
                        self._replay_live_lanes(
                            self._now(), live, pos, tokens)
                        ctrl_dirty = True
                        continue
                    ctrl_dirty |= dirty
                    continue
                # every live lane is one token from emitting: fall through
                # to the plain decode program (identical S=1 math)
            # trailing step args shared by both step variants: page-table
            # mirror (paged) and recurrent lane-reset mask (ssm/hybrid)
            extra = dict(pages=self._pages_mirror())
            if self._needs_reset:
                extra["reset"] = jnp.asarray(reset.copy())
            try:
                toks, cache = self._decode_launch(
                    cache, tokens, pos, ctrl, greedy_only, sub, extra,
                    live, fault)
            except _ReplayNeeded:
                self._replay_live_lanes(self._now(), live, pos, tokens)
                ctrl_dirty = True
                continue
            reset[:] = False    # consumed by this launch
            nxt_tok = np.asarray(toks)
            t_now = self._now()
            self.metrics.on_step(
                int(live.sum()), sched.queue_depth, t_now,
                blocks_in_use=(None if self._pool is None
                               else self._pool.used_blocks),
                shared_blocks=(self._pool.shared_blocks()
                               if self.prefix_cache else None))
            self._note_attn_bytes(live, pos + 1)
            if window_xla:
                self.metrics.on_window_fallback()

            n_prompt = 0
            for i in range(B):
                if not live[i]:
                    continue
                st = sched.slot(i)
                r = st.request
                pos[i] += 1
                st.pos = int(pos[i])
                if pos[i] < len(r.prompt):      # still teacher-forcing; an
                    tokens[i, 0] = int(r.prompt[pos[i]])  # eos_id inside the
                    n_prompt += 1               # prompt never ends the lane
                    continue
                tok = int(nxt_tok[i])
                if not r.generated:
                    self.metrics.on_first_token(r.rid, t_now)
                r.generated.append(tok)
                if r.on_token is not None:
                    r.on_token(r.rid, tok)
                tokens[i, 0] = tok
                if (
                    len(r.generated) >= r.max_new_tokens
                    or (r.eos_id is not None and tok == r.eos_id)
                    or pos[i] >= self.max_len - 1   # cache cap
                ):
                    self._finish(i, t_now, live, pos, tokens)
                    ctrl_dirty = True
            if n_prompt:
                self.metrics.on_prompt_tokens(n_prompt)
        if self.prefix_cache:
            self._cache = cache   # retained chains point into it
            self.metrics.set_session_stats(len(self._sessions))
        else:
            self._cache = None    # per-run cache, freed as before
        return self.completed

    # ------------------------------------------------------------------
    # legacy wave mode (parity baseline)
    # ------------------------------------------------------------------

    def _run_wave_batch(self, wave: List[Request]) -> None:
        B = self.batch_size
        cache = make_cache(self.params, self.cfg, B, self.max_len)
        pos = 0
        done = [False] * len(wave)
        emitted_first = [False] * len(wave)
        # lane i consumes prompt[pos] while pos < len(prompt)-1, then its
        # generated stream. First fed token is prompt[0].
        tokens = np.zeros((B, 1), np.int32)
        for i, r in enumerate(wave):
            tokens[i, 0] = int(r.prompt[0])

        while not all(done) and pos < self.max_len - 1:
            logits, cache = self._decode(
                self.params, cache, jnp.asarray(tokens),
                jnp.asarray(pos, jnp.int32),
            )
            nxt = np.asarray(jnp.argmax(logits, axis=-1))
            pos += 1
            t_now = self._now()
            self.metrics.on_step(
                sum(not d for d in done), self._sched.queue_depth, t_now)
            n_prompt = 0
            for i, r in enumerate(wave):
                if done[i]:
                    continue
                if pos < len(r.prompt):            # still teacher-forcing
                    tokens[i, 0] = int(r.prompt[pos])
                    n_prompt += 1
                else:                               # generating
                    tok = int(nxt[i])
                    if not emitted_first[i]:
                        emitted_first[i] = True
                        self.metrics.on_first_token(r.rid, t_now)
                    r.generated.append(tok)
                    if r.on_token is not None:
                        r.on_token(r.rid, tok)
                    tokens[i, 0] = tok
                    if (
                        len(r.generated) >= r.max_new_tokens
                        or (r.eos_id is not None and tok == r.eos_id)
                    ):
                        done[i] = True
                        r.status = "ok"
                        self.metrics.on_finish(r.rid, t_now, len(r.generated))
                        self.completed[r.rid] = r
            if n_prompt:
                self.metrics.on_prompt_tokens(n_prompt)
        for i, r in enumerate(wave):                # max_len cutoff
            if not done[i]:
                r.status = "ok"
                self.metrics.on_finish(r.rid, self._now(), len(r.generated))
                self.completed[r.rid] = r

    def _run_wave(self) -> Dict[int, Request]:
        while True:
            admitted = self._sched.admit()   # legacy: ignores arrival times
            if not admitted:
                break
            now = self._now()
            for _, req in admitted:
                self.metrics.on_admit(req.rid, now)
            self._run_wave_batch([req for _, req in admitted])
            for slot, _ in admitted:
                self._sched.release(slot)
        return self.completed

    # ------------------------------------------------------------------
    def check_shutdown_invariants(self) -> None:
        """Post-``run()`` leak check (tests and benches call this after
        every run, fault storms included). Asserts that:

          * the scheduler is fully drained — no occupied slots, no
            queued requests;
          * the paged block pool (if any) has refcounts exactly
            explained by the page tables plus the prefix-cache /
            session holdings, refcount==0 ⇔ on the free list, and
            conservation holds (``KVBlockPool.check_invariants``);
          * with no prefix cache, every block is back on the free list;
            with one, every used block is accounted to a cached chain
            or retained session (no leaked shared blocks) and no
            session still claims an in-flight request;
          * every submitted rid is in ``completed`` exactly once, each
            with a typed terminal status.

        Raises AssertionError on the first violated invariant.
        """
        sched = self._sched
        assert sched.occupancy == 0, (
            f"{sched.occupancy} slot(s) still occupied after run()")
        assert sched.queue_depth == 0, (
            f"{sched.queue_depth} request(s) still queued after run()")
        if self._pool is not None:
            ext: Dict[int, int] = {}
            for holder in (self._prefix, self._sessions):
                if holder is not None:
                    for b, n in holder.holdings().items():
                        ext[b] = ext.get(b, 0) + n
            self._pool.check_invariants(external=ext)
            assert self._pool.used_blocks == len(ext), (
                f"{self._pool.used_blocks} KV block(s) in use after run() "
                f"but only {len(ext)} accounted to cached chains / "
                f"retained sessions")
            assert not self._pending_match, (
                f"pinned prefix matches never attached: "
                f"{sorted(self._pending_match)}")
            assert not self._session_rid, (
                f"sessions still claim in-flight requests: "
                f"{sorted(self._session_rid)}")
        submitted = set(self.metrics.requests)
        finished = set(self.completed)
        assert submitted == finished, (
            f"submitted/completed rid mismatch: "
            f"missing={sorted(submitted - finished)} "
            f"extra={sorted(finished - submitted)}")
        from repro.serving.scheduler import STATUSES
        for r in self.completed.values():
            assert r.status in STATUSES, (
                f"request {r.rid} finished without a typed status "
                f"({r.status!r})")
        assert not self._cancel_pending, (
            f"cancellations never resolved: {sorted(self._cancel_pending)}")

    def now(self) -> float:
        """Current time on the engine clock (what ``arrival_time``,
        deadlines and session TTLs are measured against). Multi-turn
        drivers stamp follow-up submissions with this so queue-wait and
        TTFT stay meaningful across run() calls."""
        return self._now()

    def clear_prefix_cache(self) -> int:
        """Drop every cached chain and retained session, returning their
        pinned blocks to the pool. Returns the number of blocks freed.
        After this (and outside a run), a prefix-cache engine's pool is
        fully free — the teardown counterpart of
        ``check_shutdown_invariants``."""
        if self._pool is None:
            return 0
        before = self._pool.free_blocks
        if self._prefix is not None:
            self._prefix.clear(self._pool)
        if self._sessions is not None:
            self._sessions.clear(self._pool)
        return self._pool.free_blocks - before

    def run(self) -> Dict[int, Request]:
        if self.mode == "continuous":
            return self._run_continuous()
        return self._run_wave()
