"""Batched serving engine over the shared-position KV cache.

The cache design (one global write index per layer, batch-wide) matches
TPU serving practice: a decode wave advances all batch lanes by one token
per step. The engine therefore runs *wave-synchronous static batching*:

  1. admit up to `batch_size` requests from the queue;
  2. step the whole batch from position 0: lanes still inside their
     prompt are teacher-forced with the next prompt token, lanes past
     their prompt consume their previously generated token (this fuses
     "prefill" and "decode" into one jitted program — prompts amortize
     across the batch);
  3. lanes finish on EOS / max_new_tokens; when every lane is done the
     wave closes and the next wave is admitted with a fresh cache.

Works with dense bf16 weights or ICQuant-packed weights (the `linear`
dispatch inside the model handles both) — the quantized-serving example
and benchmarks drive this engine.

Quantized weights are converted ONCE at engine construction
(``weight_cache='prepared'``, the default): ICQPacked storage weights
become pre-padded ICQPrepared layouts, so the per-step jitted program
routes every matmul through the kernel-backed dispatch layer
(kernels/backend.py). ``runtime_fmt`` picks the prepared runtime format
(None = platform default, normally 'v2' — the checkpointed gap-stream
layout serving at ~0.3-0.45 b/w outlier overhead, with kernels decoding
selector tiles in VMEM; 'v1' = dense-bitmap fallback at ~1 b/w).
``weight_cache='dense'`` instead materializes dense weights once
(dequant-once cache for prefill-heavy waves on HBM-rich hosts);
``weight_cache='none'`` keeps the reference in-graph decode.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.steps import make_cache, make_decode_step, \
    prepare_serving_params


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                 # (S,) int32
    max_new_tokens: int = 16
    eos_id: Optional[int] = None
    generated: List[int] = dataclasses.field(default_factory=list)


class GenerationEngine:
    def __init__(self, params, cfg, batch_size: int, max_len: int,
                 weight_cache: str = "prepared",
                 runtime_fmt: Optional[str] = None):
        kw = {"fmt": runtime_fmt} if runtime_fmt is not None else {}
        self.params = prepare_serving_params(params, mode=weight_cache, **kw)
        self.cfg = cfg
        self.batch_size = batch_size
        self.max_len = max_len
        self._decode = jax.jit(make_decode_step(cfg))
        self._queue: Deque[Request] = deque()
        self.completed: Dict[int, Request] = {}

    def submit(self, req: Request) -> None:
        self._queue.append(req)

    # ------------------------------------------------------------------
    def _run_wave(self, wave: List[Request]) -> None:
        B = self.batch_size
        cache = make_cache(self.params, self.cfg, B, self.max_len)
        pos = 0
        done = [False] * len(wave)
        # lane i consumes prompt[pos] while pos < len(prompt)-1, then its
        # generated stream. First fed token is prompt[0].
        tokens = np.zeros((B, 1), np.int32)
        for i, r in enumerate(wave):
            tokens[i, 0] = int(r.prompt[0])

        while not all(done) and pos < self.max_len - 1:
            logits, cache = self._decode(
                self.params, cache, jnp.asarray(tokens),
                jnp.asarray(pos, jnp.int32),
            )
            nxt = np.asarray(jnp.argmax(logits, axis=-1))
            pos += 1
            for i, r in enumerate(wave):
                if done[i]:
                    continue
                if pos < len(r.prompt):            # still teacher-forcing
                    tokens[i, 0] = int(r.prompt[pos])
                else:                               # generating
                    tok = int(nxt[i])
                    r.generated.append(tok)
                    tokens[i, 0] = tok
                    if (
                        len(r.generated) >= r.max_new_tokens
                        or (r.eos_id is not None and tok == r.eos_id)
                    ):
                        done[i] = True
                        self.completed[r.rid] = r
        for i, r in enumerate(wave):                # max_len cutoff
            if not done[i]:
                self.completed[r.rid] = r

    def run(self) -> Dict[int, Request]:
        while self._queue:
            wave = [
                self._queue.popleft()
                for _ in range(min(self.batch_size, len(self._queue)))
            ]
            self._run_wave(wave)
        return self.completed
