from repro.serving.engine import GenerationEngine, make_serving_step
from repro.serving.kv_pool import KVBlockPool
from repro.serving.metrics import MetricsCollector, RequestMetrics
from repro.serving.sampling import GREEDY, SamplingParams, sample_tokens
from repro.serving.scheduler import Request, Slot, SlotScheduler

__all__ = [
    "GenerationEngine",
    "GREEDY",
    "KVBlockPool",
    "MetricsCollector",
    "Request",
    "RequestMetrics",
    "SamplingParams",
    "Slot",
    "SlotScheduler",
    "make_serving_step",
    "sample_tokens",
]
