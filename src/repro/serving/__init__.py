"""Serving subsystem: continuous batching, paged KV, fault tolerance.

Fault-tolerance contract
------------------------

Every request submitted to ``GenerationEngine`` terminates with exactly
one **typed status** (``Request.status``, one of
``scheduler.STATUSES``):

  * ``ok``        — ran to completion (budget or EOS);
  * ``timeout``   — a running lane crossed its ``deadline_s`` (seconds
    from ``arrival_time`` on the engine clock) and was finished with
    whatever it had generated;
  * ``expired``   — a queued request exceeded ``max_queue_wait_s``
    before ever being admitted (``max_queue_wait_s=0`` deterministically
    expires: the lifecycle pass runs before admission);
  * ``cancelled`` — ``engine.cancel(rid)`` took effect (queued or
    mid-decode; a live lane's slot and paged blocks are reclaimed);
  * ``rejected``  — shed by the bounded submit queue (see below);
  * ``failed``    — the recovery path gave up: the launch failed on the
    degraded arm too and the request exhausted its replay budget, or a
    sampled (temperature > 0) lane had to be preempted, whose stream
    cannot be replayed bit-identically.

**Backpressure**: ``max_queue`` bounds the submit queue (default
``ICQ_MAX_QUEUE``, unbounded when unset). A full queue applies
``shed_policy`` (default ``ICQ_SHED_POLICY`` / ``reject``):

  * ``reject``     — the *new* request is refused (``submit`` returns
    ``False``) and recorded with status ``rejected``;
  * ``shed-oldest`` — the oldest *waiting* request is shed with status
    ``rejected`` and the new one admitted in its place.

**Fault injection and degraded mode**: ``serving.faults.FaultInjector``
injects deterministic launch faults — a planned schedule
(``ICQ_FAULT_PLAN``, e.g. ``"3:nan,6:raise"``: kinds ``raise`` /
``nan`` / ``alloc``) and/or a seeded random rate (``ICQ_FAULT_RATE``,
``ICQ_FAULT_SEED``). The engine also *detects* genuine faults: NaN/inf
logits on a live lane and runtime errors from a launch. Either way the
step is retried once on the bitwise-exact pure-XLA dispatch arm
(``kernels.backend.forced_backend('xla')``) with identical inputs —
including the same PRNG subkey, so sampled streams stay reproducible —
and the engine stays pinned to that arm for ``degrade_steps`` clean
launches (``ICQ_DEGRADE_STEPS``, default 8) before returning to the
fast path. If the retry fails too, the live lanes are preempted and
requeued (the paged engine's replay machinery), each request at most
twice before it is finished as ``failed``. The
``MetricsCollector`` ledger (``faults`` by kind, ``degraded_steps``,
``replays``, per-status counters) and the ``StepTimeWatchdog``
(EWMA step-time p50/p95 + ``stalled`` flag) make every recovery
visible in ``metrics.summary()``.

With injection disabled (the default) greedy continuous serving is
token-identical to the pre-fault-tolerance engine, contiguous and
paged alike.

Prefix cache + sessions
-----------------------

``prefix_cache=True`` (``ICQ_PREFIX_CACHE``; paged layout only) shares
identical prompt prefixes **copy-on-write** across requests: finished
chains are indexed by rolling per-block chain hashes
(``prefix_cache.block_hashes``), matched blocks are mapped — never
copied, never written — into the new lane's page table with a pool
reference each, and only the delta past the match is prefilled. A
divergence inside a block COW-forks it (one device row-copy). Cached
chains are LRU-evicted **only under pool pressure** and always before
any running lane is preempted. ``engine.submit(req, session=sid)``
additionally retains the finished turn's exact chain (partial tail
block included) under ``sid`` — TTL-bounded via ``ICQ_SESSION_TTL`` —
so the next turn of a chat warm-starts mid-block. Warm greedy output is
token-identical to cold-prefill serving (same same-arm caveat as
chunked prefill; CI pins it, preemption and fault storms included):
cached rows are bitwise the rows cold prefill would have written.

Speculative decoding
--------------------

``spec_decode=True`` (``ICQ_SPEC_DECODE``; continuous engine, greedy
lanes only) runs pure-decode iterations as draft-and-verify: a
``spec_decode.Drafter`` (``ICQ_SPEC_DRAFT``: host-side ``ngram``
prompt-lookup by default, or a real low-bit ``self2bit`` /
``tiny``-config model) proposes up to ``spec_k`` tokens per lane
(``ICQ_SPEC_K``, default 4) and ONE verify launch scores all k+1
positions per lane at M = batch*(k+1) — the same large-M dequant+MXU
arm chunked prefill rides. Greedy acceptance (longest matching draft
prefix + the verifier's corrected token) makes the output
**token-identical to plain decode**; rejection rewinds the host
position vector and trims paged tail blocks (``KVBlockPool.trim``,
COW-aware — shared/pinned blocks only lose the lane's mapping). A
faulted verify launch degrades to the plain decode program in the same
iteration, so the fault-tolerance contract above is unchanged. The
metrics ledger (``spec_proposed`` / ``spec_accepted`` /
``mean_accept_len`` / accepted-length histogram, draft-vs-verify launch
split) accounts tokens accepted-only — rejected drafts never touch
tokens/s. See docs/SPECULATIVE.md.

Service layer (frontend -> router -> replicas)
----------------------------------------------

Above the engine sits a crash-survivable service (``docs/SERVING.md``):
``wal.RequestWAL`` journals every accepted submit and terminal
transition (JSONL + per-record crc32, torn-tail tolerant) so a cold
restart replays exactly the unfinished requests; ``replica.
EngineReplica`` runs each engine in a supervised worker thread with
heartbeats and watchdog-driven hang detection, and can be hard-killed
and restarted with a fresh engine; ``router.ReplicaRouter`` routes
least-loaded with session affinity and **fails over** a dead replica's
in-flight requests by folding their streamed tokens into the prompt
(greedy continuation token-identical, same guarantee as
preempt-and-requeue), keeping the exactly-once typed-status contract
service-wide; ``frontend.ServingFrontend`` is an asyncio TCP surface
(submit/poll/stream/cancel/health/drain, newline-delimited JSON) with
bounded-queue backpressure and deadline propagation, and
``frontend.ServingClient`` retries retryable conditions (shed, replica
down) with capped exponential backoff while surfacing terminal ones
(rejected, draining) immediately. ``ServiceMetrics`` ledgers
failovers/restarts/retries/sheds/heartbeat age. The hooks the service
uses (``engine.on_iteration``, ``engine.request_drain()``) are inert by
default: an engine used directly behaves bit-for-bit as before.
"""
from repro.serving.engine import GenerationEngine, make_serving_step
from repro.serving.faults import FaultInjected, FaultInjector, parse_fault_plan
from repro.serving.frontend import (ClientError, FrontendUnavailable,
                                    RequestRejected, ServingClient,
                                    ServingFrontend, ServingService)
from repro.serving.kv_pool import KVBlockPool
from repro.serving.metrics import (MetricsCollector, RequestMetrics,
                                   ServiceMetrics, StepTimeWatchdog)
from repro.serving.prefix_cache import (PrefixCache, SessionStore,
                                        block_hashes)
from repro.serving.replica import EngineReplica, ReplicaDead, ReplicaKilled
from repro.serving.router import NoReplicaAvailable, ReplicaRouter
from repro.serving.sampling import GREEDY, SamplingParams, sample_tokens
from repro.serving.scheduler import STATUSES, Request, Slot, SlotScheduler
from repro.serving.spec_decode import (DRAFTERS, Drafter, ModelDrafter,
                                       NgramDrafter, RejectDrafter,
                                       make_drafter, make_spec_verify)
from repro.serving.wal import RequestWAL

__all__ = [
    "GenerationEngine",
    "GREEDY",
    "ClientError",
    "DRAFTERS",
    "Drafter",
    "EngineReplica",
    "FaultInjected",
    "FaultInjector",
    "FrontendUnavailable",
    "KVBlockPool",
    "MetricsCollector",
    "ModelDrafter",
    "NgramDrafter",
    "NoReplicaAvailable",
    "PrefixCache",
    "ReplicaDead",
    "ReplicaKilled",
    "ReplicaRouter",
    "RejectDrafter",
    "Request",
    "RequestMetrics",
    "RequestRejected",
    "RequestWAL",
    "STATUSES",
    "ServiceMetrics",
    "ServingClient",
    "ServingFrontend",
    "ServingService",
    "SessionStore",
    "SamplingParams",
    "Slot",
    "SlotScheduler",
    "StepTimeWatchdog",
    "block_hashes",
    "make_drafter",
    "make_serving_step",
    "make_spec_verify",
    "parse_fault_plan",
    "sample_tokens",
]
