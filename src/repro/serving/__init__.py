from repro.serving.engine import GenerationEngine, Request

__all__ = ["GenerationEngine", "Request"]
