"""Speculative decoding: draft-and-verify through the dual ICQ kernel arms.

Decode is small-M and bandwidth-bound — exactly where ICQuant's
compressed weights shine — yet the tuned dequant+MXU large-M arm sits
idle during pure-decode iterations. Speculative decoding puts it to
work: a cheap **drafter** proposes ``k`` tokens per lane, and ONE
verifier launch (``launch/steps.make_verify_step``) scores all ``k+1``
positions at M = batch*(k+1), which routes the matmuls down the same
large-M arm chunked prefill uses. Greedy acceptance — the longest
prefix of drafts matching the verifier's own argmax, plus the
verifier's one corrected/next token — makes the output **token-
identical to plain greedy decode**: column ``j`` of the verify launch
sees exactly the tokens the plain walk would have consumed (induction
over the accepted prefix), so only the launch count changes, never a
token. That keeps the repo's token-parity CI discipline intact (same
same-arm ulp caveat as chunked prefill: the verify M lands on the
dequant arm where the 1-token walk rides the fused kernel; CI pins
parity on the XLA arms, the compiled-TPU pass owns cross-arm greedy
stability).

Rejection costs nothing but stale cache rows: the engine rewinds its
host position vector and (paged layout) calls
``KVBlockPool.trim(lane, new_len)`` to unmap tail blocks — rows past
the rewound position are harmless under the write-discipline invariant
(a lane writes position ``p`` the step ``p`` re-enters its valid
range), the exact argument that already covers preempt-and-requeue.
Speculation is **greedy-gated** (temperature > 0 lanes bypass it — a
sampled stream has no acceptance identity), never preempts prefill
(the engine speculates only when every live lane is decoding), and is
unavailable for recurrent mixers (ssm/hybrid state cannot rewind).

Drafters (``make_drafter``):

  * ``'ngram'``   (default) — prompt-lookup drafting: match the lane's
    trailing n-gram against its own consumed history and propose the
    historical continuation; repeats the last token when nothing
    matches. ZERO model launches, so an iteration costs exactly one
    verify launch — worst case ~plain-decode throughput, and greedy
    streams (which love loops) often accept most of ``k``.
  * ``'self2bit'`` — self-speculation: the *serving weights themselves*
    re-quantized at n_bits=2 via a second ``quantize_tree`` +
    ``prepare_serving_params`` sharing the engine's ``weight_cache``
    mode. OWQ-style outlier handling makes the 2-bit twin nearly free
    in HBM; alignment comes from being the same model.
  * ``'tiny'``   — a dense 1-layer shrunk config of the target
    architecture (same vocab), randomly initialized unless
    ``draft_params`` is supplied. A real deployment plugs a distilled
    drafter in here; an *undistilled* one is rejection-heavy, which is
    exactly what the CI chaos path wants.
  * ``'reject'`` — adversarial test drafter: proposes tokens chosen to
    be wrong (last token + 1 mod vocab), forcing the rejection/rollback
    path every iteration while still emitting one correct token per
    verify (the corrected column). Parity must survive it.

Model drafters keep their own per-lane contiguous KV cache and a host
mirror of each lane's consumed tokens; every ``propose`` first
computes the longest common prefix of its mirror with the engine's
authoritative history (so rejected drafts, preemptions, lane recycling
and warm starts all reduce to "re-consume the delta"), catches up
chunk-wise through a fused-step program, then rolls ``k`` greedy
1-token proposals. Rollback on the drafter side is the same
position-rewind trick — stale rows in its private cache are equally
harmless.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.steps import make_cache, make_fused_step, make_verify_step

__all__ = ["Drafter", "NgramDrafter", "RejectDrafter", "ModelDrafter",
           "make_drafter", "make_spec_verify", "DRAFTERS"]


def make_spec_verify(cfg):
    """The engine's verify program: ``make_verify_step`` + greedy argmax
    + the NaN health probe, as one jit-able program.

    ``(params, cache, tokens (B, S), start_pos (B,), seq_lens (B,),
    live (B,), pages) -> (tgt (B, S) int32, cache, bad (B,))``: ``tgt``
    is the per-column greedy verdict, ``bad`` is True where a live
    lane's logits are non-finite in any *valid* column (columns past
    ``seq_lens[i]`` are write-masked garbage — a fully-masked softmax
    row may be legitimately NaN — so they never trip the probe).
    """
    verify = make_verify_step(cfg)

    def prog(params, cache, tokens, start_pos, seq_lens, live, pages=None):
        logits, cache = verify(params, cache, tokens, start_pos, seq_lens,
                               pages=pages)
        tgt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        finite = jnp.isfinite(logits).all(axis=-1)          # (B, S)
        valid = (jnp.arange(tokens.shape[1])[None, :]
                 < seq_lens[:, None])                       # (B, S)
        bad = live & (valid & ~finite).any(axis=-1)
        return tgt, cache, bad

    return prog


class Drafter:
    """Base drafter: propose up to ``k`` greedy continuation tokens per
    lane. ``hists[j]`` is lane ``slots[j]``'s full consumed history
    *including* the pending feed token (``(prompt ++ fresh generated)
    [:pos+1]``) — the proposal is the drafter's greedy continuation
    after consuming all of it. ``launches`` counts device launches the
    drafter spent (0 for host-only drafters); the engine ledgers the
    delta per iteration."""

    name = "base"

    def __init__(self):
        self.launches = 0

    def propose(self, slots: Sequence[int], hists: Sequence[np.ndarray],
                ks: Sequence[int]) -> Dict[int, np.ndarray]:
        raise NotImplementedError


class NgramDrafter(Drafter):
    """Prompt-lookup drafting (zero launches): match the longest
    trailing n-gram (``max_n`` down to 1) of the lane's history against
    an earlier occurrence and propose the ``k`` tokens that followed
    it; fill with the last token when history offers nothing (greedy
    streams repeat — a run IS a 1-gram hit one step later)."""

    name = "ngram"

    def __init__(self, max_n: int = 3):
        super().__init__()
        if max_n < 1:
            raise ValueError(f"max_n must be >= 1, got {max_n}")
        self.max_n = max_n

    def propose(self, slots, hists, ks):
        out = {}
        for slot, hist, k in zip(slots, hists, ks):
            h = np.asarray(hist, np.int64)
            L = len(h)
            drafts = None
            for n in range(min(self.max_n, L - 1), 0, -1):
                pat = h[L - n:]
                # most recent earlier occurrence of the trailing n-gram
                for s in range(L - n - 1, -1, -1):
                    if np.array_equal(h[s: s + n], pat):
                        cont = h[s + n: s + n + k]
                        if len(cont):
                            drafts = np.resize(
                                cont, k) if len(cont) < k else cont[:k]
                        break
                if drafts is not None:
                    break
            if drafts is None:
                drafts = np.full(k, h[-1], np.int64)
            out[slot] = np.asarray(drafts[:k], np.int32)
        return out


class RejectDrafter(Drafter):
    """Adversarial test drafter: every proposal is ``last + 1 + j`` mod
    vocab — engineered to disagree with any self-consistent greedy
    stream almost always, so every iteration exercises the rejection /
    KV-rollback path while the verify's corrected column keeps the
    stream advancing one token. Output parity must be unaffected."""

    name = "reject"

    def __init__(self, vocab_size: int):
        super().__init__()
        self.vocab_size = int(vocab_size)

    def propose(self, slots, hists, ks):
        return {
            slot: ((int(hist[-1]) + 1 + np.arange(k, dtype=np.int64))
                   % self.vocab_size).astype(np.int32)
            for slot, hist, k in zip(slots, hists, ks)
        }


class ModelDrafter(Drafter):
    """A real (cheap) model proposes: its own per-lane contiguous KV
    cache, a host mirror of each lane's consumed tokens, and one fused
    chunk program for both catch-up and 1-token proposal rolls. See the
    module doc for the common-prefix resync that makes rejections,
    preemptions and lane recycling all collapse to "consume the delta".
    """

    name = "model"

    def __init__(self, params, cfg, batch_size: int, max_len: int,
                 chunk: int = 8):
        super().__init__()
        if chunk < 1:
            raise ValueError(f"chunk must be >= 1, got {chunk}")
        self.params = params
        self.cfg = cfg
        self.batch_size = int(batch_size)
        self.max_len = int(max_len)
        self.chunk = int(chunk)
        self._cache = make_cache(params, cfg, self.batch_size, self.max_len,
                                 per_lane=True)
        self._seqs: List[List[int]] = [[] for _ in range(self.batch_size)]
        fused = make_fused_step(cfg)

        def prog(params, cache, tokens, start_pos, seq_lens):
            logits, cache = fused(params, cache, tokens, start_pos, seq_lens)
            return jnp.argmax(logits, axis=-1).astype(jnp.int32), cache

        self._prog = jax.jit(prog)

    def _launch(self, toks: np.ndarray, lens: np.ndarray) -> np.ndarray:
        start = np.asarray([len(s) for s in self._seqs], np.int32)
        nxt, self._cache = self._prog(
            self.params, self._cache, jnp.asarray(toks),
            jnp.asarray(start), jnp.asarray(lens))
        self.launches += 1
        return np.asarray(nxt)

    def propose(self, slots, hists, ks):
        B, S = self.batch_size, self.chunk
        # resync: roll each lane's mirror back to its agreement with the
        # engine's authoritative history, then consume the delta
        pend: Dict[int, List[int]] = {}
        for slot, hist in zip(slots, hists):
            h = [int(t) for t in hist]
            seq = self._seqs[slot]
            m = 0
            lim = min(len(seq), len(h))
            while m < lim and seq[m] == h[m]:
                m += 1
            if m == len(h):          # defensive: always re-consume >= 1
                m = len(h) - 1       # token so last-column logits exist
            self._seqs[slot] = seq[:m]
            pend[slot] = h[m:]
        first: Dict[int, int] = {}
        while any(pend.values()):
            toks = np.zeros((B, S), np.int32)
            lens = np.zeros((B,), np.int32)
            for slot in slots:
                rem = pend[slot]
                n = min(S, len(rem))
                if n:
                    toks[slot, :n] = rem[:n]
                    lens[slot] = n
            nxt = self._launch(toks, lens)
            for slot in slots:
                n = int(lens[slot])
                if n:
                    self._seqs[slot].extend(pend[slot][:n])
                    pend[slot] = pend[slot][n:]
                    if not pend[slot]:
                        first[slot] = int(nxt[slot])
        drafts = {slot: [first[slot]] for slot in slots}
        # greedy 1-token rolls for the remaining k-1 proposals per lane
        while True:
            roll = [slot for slot, k in zip(slots, ks)
                    if len(drafts[slot]) < k]
            if not roll:
                break
            toks = np.zeros((B, S), np.int32)
            lens = np.zeros((B,), np.int32)
            for slot in roll:
                toks[slot, 0] = drafts[slot][-1]
                lens[slot] = 1
            nxt = self._launch(toks, lens)
            for slot in roll:
                self._seqs[slot].append(int(toks[slot, 0]))
                drafts[slot].append(int(nxt[slot]))
        return {slot: np.asarray(d[:k], np.int32)
                for slot, d, k in ((s, drafts[s], k)
                                   for s, k in zip(slots, ks))}


def _dense_tree(params):
    """Materialize any quantized leaves (ICQPacked / ICQRuntime /
    ICQPrepared) to dense arrays so ``quantize_tree`` can re-quantize
    them at a different bit width."""
    from repro.core.icquant import ICQPacked, ICQRuntime
    from repro.kernels import backend as _backend
    from repro.models.linear import as_dense

    def is_q(w):
        return isinstance(
            w, (ICQPacked, ICQRuntime, _backend.ICQPrepared))

    return jax.tree.map(lambda w: as_dense(w) if is_q(w) else w, params,
                        is_leaf=is_q)


def tiny_draft_config(cfg):
    """The 'tiny' drafter's architecture: the target config shrunk to a
    single layer (every width already validated by construction, same
    vocab — the only dimension acceptance cares about)."""
    return dataclasses.replace(cfg, name=f"{cfg.name}-draft", n_layers=1)


DRAFTERS = ("ngram", "self2bit", "tiny", "reject")


def make_drafter(kind: str, params, cfg, batch_size: int, max_len: int,
                 weight_cache: str = "prepared",
                 prepare_kw: Optional[dict] = None,
                 draft_params=None, seed: int = 0, n_bits: int = 2,
                 chunk: int = 8) -> Drafter:
    """Drafter factory for the engine. ``params`` are the engine's RAW
    constructor params (captured before ``prepare_serving_params``
    consumed them) — 'self2bit' dequantizes and re-quantizes them at
    ``n_bits`` and shares the engine's ``weight_cache`` mode /
    ``prepare_kw``; 'tiny' initializes (or accepts via ``draft_params``)
    a dense 1-layer config; 'ngram' / 'reject' are host-only."""
    if kind == "ngram":
        return NgramDrafter()
    if kind == "reject":
        return RejectDrafter(cfg.vocab_size)
    if kind == "tiny":
        dcfg = tiny_draft_config(cfg)
        if draft_params is None:
            from repro.models import init_model

            draft_params = init_model(jax.random.PRNGKey(seed), dcfg)
        return ModelDrafter(draft_params, dcfg, batch_size, max_len,
                            chunk=chunk)
    if kind == "self2bit":
        from repro.launch.quantize import quantize_tree
        from repro.launch.steps import prepare_serving_params

        qparams, _ = quantize_tree(_dense_tree(params), n_bits,
                                   gamma=cfg.quant_gamma)
        qparams = prepare_serving_params(qparams, mode=weight_cache,
                                         **(prepare_kw or {}))
        return ModelDrafter(qparams, cfg, batch_size, max_len, chunk=chunk)
    raise ValueError(
        f"unknown drafter {kind!r}; available: {', '.join(DRAFTERS)}")
