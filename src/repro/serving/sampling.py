"""Token sampling for the serving engine: greedy + temperature/top-k/top-p.

Everything here is jit-safe and vectorized over the batch so the engine
can fuse sampling into its single persistent decode step. Per-lane
sampling parameters arrive as (B,) arrays — each request may override
the engine default (``Request.sampling``), and lanes holding different
requests sample with different temperatures in the same step.

The PRNG key is threaded: the engine splits its key once per step and
passes the subkey in, so a run is reproducible from (seed, admission
schedule). ``temperature <= 0`` selects greedy decoding for that lane —
no randomness is consumed by the lane's decision (the vectorized draw
still happens, but the argmax result is emitted), which is what makes a
fully-greedy continuous run token-identical to the legacy wave engine.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Per-request sampling knobs.

    temperature: 0 (default) = greedy argmax; > 0 = softmax sampling at
        that temperature.
    top_k: keep only the k highest-logit tokens (0 = off).
    top_p: nucleus sampling — keep the smallest prefix of the sorted
        distribution with cumulative probability >= top_p (1.0 = off).
    """

    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 1.0

    def __post_init__(self):
        if self.temperature < 0:
            raise ValueError(f"temperature must be >= 0, got {self.temperature}")
        if self.top_k < 0:
            raise ValueError(f"top_k must be >= 0, got {self.top_k}")
        if not 0 < self.top_p <= 1.0:
            raise ValueError(f"top_p must be in (0, 1], got {self.top_p}")


GREEDY = SamplingParams()


def lane_arrays(params_list) -> dict:
    """Stack per-lane SamplingParams into the (B,) arrays the jitted
    sampler consumes. ``None`` entries fall back to GREEDY."""
    ps = [p if p is not None else GREEDY for p in params_list]
    return dict(
        temperature=np.asarray([p.temperature for p in ps], np.float32),
        top_k=np.asarray([p.top_k for p in ps], np.int32),
        top_p=np.asarray([p.top_p for p in ps], np.float32),
    )


def sample_tokens(
    logits: jnp.ndarray,            # (B, V) last-position logits
    key: jax.Array,                 # threaded PRNG key (one split per step)
    temperature: jnp.ndarray,       # (B,) f32; <= 0 means greedy
    top_k: jnp.ndarray,             # (B,) int32; 0 means off
    top_p: jnp.ndarray,             # (B,) f32; 1.0 means off
    live: Optional[jnp.ndarray] = None,  # (B,) bool slot-occupancy mask
) -> jnp.ndarray:
    """Sample one token per lane; returns (B,) int32.

    Dead slots (``live == False``) are masked to token 0 — their logits
    are never sampled into an output stream, and because lanes draw
    independent noise they cannot perturb live lanes' draws either.
    """
    logits = logits.astype(jnp.float32)
    B, V = logits.shape
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)

    # work in sorted-descending space: top-k is a rank cut, top-p a
    # cumulative-probability cut; both map back through the sort order.
    order = jnp.argsort(-logits, axis=-1)                     # (B, V)
    sorted_logits = jnp.take_along_axis(logits, order, axis=-1)
    t = jnp.maximum(temperature, 1e-6)[:, None]
    scaled = sorted_logits / t

    ranks = jnp.arange(V, dtype=jnp.int32)[None]
    k_eff = jnp.where(top_k > 0, top_k, V)[:, None]
    keep = ranks < k_eff
    probs = jax.nn.softmax(scaled, axis=-1)
    cum_excl = jnp.cumsum(probs, axis=-1) - probs             # exclusive
    keep &= cum_excl < top_p[:, None]
    keep = keep.at[:, 0].set(True)                            # never empty

    masked = jnp.where(keep, scaled, -jnp.inf)
    choice = jax.random.categorical(key, masked, axis=-1)     # (B,)
    sampled = jnp.take_along_axis(order, choice[:, None], axis=-1)[:, 0]

    out = jnp.where(temperature > 0.0, sampled.astype(jnp.int32), greedy)
    if live is not None:
        out = jnp.where(live, out, 0)
    return out


__all__ = ["SamplingParams", "GREEDY", "lane_arrays", "sample_tokens"]
