"""Write-ahead request journal (WAL) for the serving service layer.

Every request accepted by the service is journaled *before* it reaches
an engine, and journaled again when it reaches a terminal status — so a
process that dies mid-storm can be restarted and replay exactly the
requests that never finished. Greedy decoding makes the replay
token-identical by construction (same prompt -> same stream), the same
guarantee the engine's preempt-and-requeue path relies on.

Format: JSON Lines, one record per line, append-only. Each record
carries a ``crc`` field — crc32 (same discipline as the PR-6 checkpoint
sidecars, ``kernels/backend``) over the canonical JSON encoding of the
record *without* the crc field (``sort_keys=True``, compact
separators). Two record kinds:

  ``{"ev": "submit", "rid": ..., "prompt": [...], "max_new": ...,
     "eos": ..., "deadline_s": ..., "max_queue_wait_s": ...,
     "session": ..., "sampled": ..., "replica": ..., "crc": ...}``
  ``{"ev": "terminal", "rid": ..., "status": ..., "n_generated": ...,
     "crc": ...}``

Recovery scan (run once, at open):

  * a record that fails to parse or fails its crc **at the tail of the
    file** is a *torn tail* — the write the crash interrupted. It is
    truncated away so appends continue on a clean line boundary.
  * a bad record **mid-file** is a *corrupt record* — it is skipped and
    counted, and the scan continues, so a later ``terminal`` record
    still marks its request completed. A completed request is therefore
    never replayed (never double-completed), even across corruption.
  * an empty or missing journal round-trips to an empty state.

``pending`` after the scan maps rid -> the *latest* submit record with
no later terminal (failover re-submits journal the same rid again —
last submit wins). ``replay_requests()`` turns pending into fresh
``Request`` objects; requests journaled with ``sampled=True`` are *not*
replayable (a fresh PRNG draw could not reproduce the tokens the dead
process already streamed) — the recovery path terminates them with
status ``'failed'`` instead, mirroring ``engine._preempt``.

The journal object is thread-safe for appends (the router logs from
frontend, supervisor and replica-worker threads) and flushes every
record; ``fsync=True`` additionally fsyncs per append for crash
durability at the cost of append latency.

``ICQ_WAL_PATH`` (empty/unset = no WAL) supplies the default journal
path for ``launch/serve.py`` and ``ServingService``.
"""
from __future__ import annotations

import json
import os
import threading
import zlib
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.serving.scheduler import Request


def default_wal_path() -> Optional[str]:
    """``ICQ_WAL_PATH`` env knob: journal path (empty/unset = no WAL)."""
    v = os.environ.get("ICQ_WAL_PATH", "")
    return v if v else None


def _canonical(record: dict) -> bytes:
    """Canonical JSON bytes of ``record`` without its crc field."""
    body = {k: v for k, v in record.items() if k != "crc"}
    return json.dumps(body, sort_keys=True,
                      separators=(",", ":")).encode("utf-8")


def _crc(record: dict) -> int:
    return zlib.crc32(_canonical(record)) & 0xFFFFFFFF


def encode_record(record: dict) -> bytes:
    """Serialize one record with its crc; returns the journal line."""
    rec = dict(record)
    rec["crc"] = _crc(rec)
    return (json.dumps(rec, sort_keys=True, separators=(",", ":"))
            + "\n").encode("utf-8")


def decode_record(line: bytes) -> dict:
    """Parse + crc-verify one journal line; raises ValueError when bad."""
    try:
        rec = json.loads(line.decode("utf-8"))
    except (UnicodeDecodeError, json.JSONDecodeError) as e:
        raise ValueError(f"unparseable WAL record: {e}") from e
    if not isinstance(rec, dict) or "crc" not in rec:
        raise ValueError("WAL record missing crc")
    want = rec["crc"]
    got = _crc(rec)
    if want != got:
        raise ValueError(f"WAL crc mismatch: stored {want}, computed {got}")
    if rec.get("ev") not in ("submit", "terminal"):
        raise ValueError(f"unknown WAL event {rec.get('ev')!r}")
    return rec


class RequestWAL:
    """Append-only request journal with crash recovery (see module doc)."""

    def __init__(self, path: str, fsync: bool = False):
        self.path = path
        self.fsync = fsync
        self.pending: Dict[int, dict] = {}     # rid -> latest submit record
        self.completed: Dict[int, str] = {}    # rid -> terminal status
        self.corrupt_records = 0               # bad mid-file records skipped
        self.torn_tail = False                 # a torn tail was truncated
        self.records_recovered = 0             # good records scanned at open
        self._lock = threading.Lock()
        self._recover()
        self._f = open(path, "ab")

    # -- recovery -------------------------------------------------------
    def _recover(self) -> None:
        if not os.path.exists(self.path):
            return
        with open(self.path, "rb") as f:
            data = f.read()
        if not data:
            return
        # line offsets: (start, line) for every non-empty line
        lines: List[Tuple[int, bytes]] = []
        off = 0
        for raw in data.split(b"\n"):
            if raw:
                lines.append((off, raw))
            off += len(raw) + 1
        keep_until = len(data)
        for i, (start, raw) in enumerate(lines):
            try:
                rec = decode_record(raw)
            except ValueError:
                if i == len(lines) - 1:
                    # bad final record = the write the crash tore;
                    # truncate so appends continue on a clean boundary
                    self.torn_tail = True
                    keep_until = start
                else:
                    self.corrupt_records += 1
                continue
            self._apply(rec)
            self.records_recovered += 1
        if self.torn_tail:
            with open(self.path, "r+b") as f:
                f.truncate(keep_until)

    def _apply(self, rec: dict) -> None:
        rid = int(rec["rid"])
        if rec["ev"] == "submit":
            # a submit after a terminal would be a new life for the rid;
            # service rids are unique, but failover re-submits the same
            # rid — latest submit wins while the request is unfinished
            self.pending[rid] = rec
            self.completed.pop(rid, None)
        else:  # terminal
            self.pending.pop(rid, None)
            self.completed[rid] = str(rec["status"])

    # -- append ---------------------------------------------------------
    def _append(self, rec: dict) -> None:
        line = encode_record(rec)
        with self._lock:
            self._f.write(line)
            self._f.flush()
            if self.fsync:
                os.fsync(self._f.fileno())
            self._apply(rec)

    def log_submit(self, req: Request, replica: Optional[str] = None) -> None:
        """Journal a submit; call *before* handing ``req`` to a replica."""
        sampled = (req.sampling is not None
                   and getattr(req.sampling, "temperature", 0.0) > 0.0)
        self._append({
            "ev": "submit",
            "rid": int(req.rid),
            "prompt": [int(t) for t in np.asarray(req.prompt).ravel()],
            "max_new": int(req.max_new_tokens),
            "eos": None if req.eos_id is None else int(req.eos_id),
            "deadline_s": req.deadline_s,
            "max_queue_wait_s": req.max_queue_wait_s,
            "session": req.session,
            "sampled": bool(sampled),
            "replica": replica,
        })

    def log_terminal(self, rid: int, status: str, n_generated: int = 0) -> None:
        """Journal a terminal transition (exactly one per finished rid)."""
        self._append({
            "ev": "terminal",
            "rid": int(rid),
            "status": str(status),
            "n_generated": int(n_generated),
        })

    # -- replay ---------------------------------------------------------
    def replay_requests(self) -> List[Request]:
        """Fresh ``Request`` objects for every replayable pending record
        (rid order). Sampled pending records are excluded — see
        ``unreplayable()``. Deadlines restart from the new submission
        (the dead process's clock did not survive it)."""
        out: List[Request] = []
        for rid in sorted(self.pending):
            rec = self.pending[rid]
            if rec.get("sampled"):
                continue
            out.append(Request(
                rid=rid,
                prompt=np.asarray(rec["prompt"], np.int32),
                max_new_tokens=int(rec["max_new"]),
                eos_id=rec.get("eos"),
                deadline_s=rec.get("deadline_s"),
                max_queue_wait_s=rec.get("max_queue_wait_s"),
                session=rec.get("session"),
            ))
        return out

    def unreplayable(self) -> List[int]:
        """Pending rids that cannot be replayed (sampled streams: a fresh
        PRNG draw would diverge from tokens already handed out). The
        recovery path terminates these with status ``'failed'``."""
        return [rid for rid in sorted(self.pending)
                if self.pending[rid].get("sampled")]

    def close(self) -> None:
        with self._lock:
            if not self._f.closed:
                self._f.flush()
                self._f.close()

    def __enter__(self) -> "RequestWAL":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


__all__ = ["RequestWAL", "default_wal_path", "encode_record",
           "decode_record"]
