"""Slot scheduler for continuous batching.

The scheduler owns two structures:

  * a FIFO **request queue** (submit order; each request carries an
    ``arrival_time`` so benchmarks can replay Poisson traces — a request
    is only admittable once the engine clock passes its arrival), and
  * a **slot table** of ``batch_size`` lanes. ``admit()`` moves queued
    requests into free slots; ``release()`` recycles a slot the moment
    its lane finishes (EOS / token budget), so the very next decode step
    can run a new request in that lane instead of idling it until the
    slowest lane of a wave drains.

The scheduler is pure host-side bookkeeping: it never touches device
state. Lane recycling works because the decode step derives every
lane's cache write index from the engine's position vector
(``launch/steps.sync_cache_positions``) — resetting a slot is just
``pos[slot] = 0``.
"""
from __future__ import annotations

import dataclasses
import heapq
from collections import deque
from typing import Callable, Deque, Dict, List, Optional, Tuple

import numpy as np

from repro.serving.sampling import SamplingParams


#: Terminal request statuses. Every request handed back by the engine
#: carries exactly one of these in ``Request.status``:
#:   ok        — finished normally (EOS / token budget / cache cap)
#:   timeout   — a *running* lane crossed its ``deadline_s``
#:   expired   — a *queued* request crossed ``max_queue_wait_s`` (or its
#:               deadline) before ever being admitted
#:   cancelled — ``GenerationEngine.cancel(rid)`` took effect
#:   rejected  — shed by the bounded submit queue (``max_queue``)
#:   failed    — terminated by the fault-recovery path (e.g. a sampled
#:               lane that cannot be replayed, or replay retries ran out)
STATUSES = ("ok", "timeout", "expired", "cancelled", "rejected", "failed")


@dataclasses.dataclass
class Request:
    """One generation request.

    ``sampling=None`` uses the engine default (greedy unless the engine
    was built with another default). ``arrival_time`` is seconds on the
    engine clock (0.0 = already arrived); the wave engine ignores it.
    ``on_token(rid, token)`` streams tokens as they are emitted.

    ``deadline_s`` is an end-to-end deadline in seconds *from
    arrival_time* on the engine clock: a running lane that crosses it
    finishes with status ``'timeout'`` (partial output kept); a queued
    request that crosses it expires. ``max_queue_wait_s`` bounds queue
    wait alone — a request still queued that long after arrival
    finishes with status ``'expired'``. ``status`` is None while the
    request is pending and one of ``STATUSES`` once terminal.

    ``session`` names a multi-turn session (``engine.submit(req,
    session=sid)`` sets it): on a prefix-cache engine the finished
    turn's KV blocks stay pinned under that id so the next turn only
    prefills its delta (serving/prefix_cache.py). At most one request
    per session may be in flight.
    """

    rid: int
    prompt: np.ndarray                 # (S,) int32
    max_new_tokens: int = 16
    eos_id: Optional[int] = None
    sampling: Optional[SamplingParams] = None
    arrival_time: float = 0.0
    on_token: Optional[Callable[[int, int], None]] = None
    deadline_s: Optional[float] = None
    max_queue_wait_s: Optional[float] = None
    session: Optional[str] = None      # multi-turn session id (or None)
    generated: List[int] = dataclasses.field(default_factory=list)
    status: Optional[str] = None       # terminal status (see STATUSES)


@dataclasses.dataclass
class Slot:
    """Host-side lane state for one occupied slot."""

    request: Request
    pos: int = 0            # tokens already fed to the model for this lane
    admitted_at: float = 0.0
    seq: int = 0            # admission sequence number (strict total order;
                            # the paged engine preempts the youngest lane)


class SlotScheduler:
    def __init__(self, n_slots: int):
        if n_slots < 1:
            raise ValueError(f"n_slots must be >= 1, got {n_slots}")
        self.n_slots = n_slots
        self._queue: Deque[Request] = deque()
        self._slots: List[Optional[Slot]] = [None] * n_slots
        self._free: List[int] = list(range(n_slots))  # min-heap: low slot first
        heapq.heapify(self._free)
        self._seq = 0

    # -- queue ----------------------------------------------------------
    def submit(self, req: Request) -> None:
        self._queue.append(req)

    def requeue_front(self, req: Request) -> None:
        """Put a preempted request back at the head of the queue: it keeps
        its FIFO position and is re-admitted (into any free slot) as soon
        as capacity allows. The caller has already folded any generated
        tokens into the prompt (preempt-and-recompute)."""
        self._queue.appendleft(req)

    def drop_queued(self, pred: Callable[[Request], bool]) -> List[Request]:
        """Remove (and return) every queued request matching ``pred``,
        preserving the FIFO order of the survivors. The lifecycle pass
        uses this for queue-wait expiry, deadline expiry and queued
        cancellation — requests that must leave the queue *without*
        ever occupying a slot."""
        dropped: List[Request] = []
        kept: Deque[Request] = deque()
        for req in self._queue:
            if pred(req):
                dropped.append(req)
            else:
                kept.append(req)
        self._queue = kept
        return dropped

    def shed_oldest(self) -> Optional[Request]:
        """Pop the queue head (the request that has waited longest) —
        the ``shed-oldest`` backpressure policy's victim. None when the
        queue is empty."""
        return self._queue.popleft() if self._queue else None

    def queued(self) -> Tuple[Request, ...]:
        """Snapshot of the queued requests in FIFO order (read-only view
        for service-layer introspection — health endpoints and drain
        accounting; mutation goes through submit/admit/drop_queued)."""
        return tuple(self._queue)

    @property
    def queue_depth(self) -> int:
        return len(self._queue)

    @property
    def occupancy(self) -> int:
        return self.n_slots - len(self._free)

    def has_work(self) -> bool:
        return bool(self._queue) or self.occupancy > 0

    def next_arrival(self) -> Optional[float]:
        """Arrival time of the queue head (None if queue empty). Admission
        is FIFO and therefore head-blocked: this is the earliest instant
        at which ``admit`` can make progress, even if later requests in
        the queue have already arrived."""
        if not self._queue:
            return None
        return self._queue[0].arrival_time

    # -- slot table -----------------------------------------------------
    def admit(self, now: Optional[float] = None,
              gate: Optional[Callable[[Request], bool]] = None,
              limit: Optional[int] = None) -> List[Tuple[int, Request]]:
        """Fill free slots from the queue head; returns [(slot, request)].

        FIFO order is preserved: admission stops at the first queued
        request that has not arrived yet (``arrival_time > now``), even
        if later requests already arrived — no reordering. ``gate`` is
        an extra admission predicate consulted on the queue head (the
        paged engine's allocator-aware check: free blocks must cover the
        prompt plus a minimum decode budget); a False stops admission
        the same head-blocked way. ``limit`` caps admissions per call so
        a caller doing per-admission resource accounting can interleave
        (admit one, allocate, repeat).
        """
        out: List[Tuple[int, Request]] = []
        while self._free and self._queue:
            if limit is not None and len(out) >= limit:
                break
            req = self._queue[0]
            if now is not None and req.arrival_time > now:
                break
            if gate is not None and not gate(req):
                break
            self._queue.popleft()
            slot = heapq.heappop(self._free)
            self._slots[slot] = Slot(
                request=req, pos=0,
                admitted_at=0.0 if now is None else now,
                seq=self._seq,
            )
            self._seq += 1
            out.append((slot, req))
        return out

    def release(self, slot: int) -> Request:
        """Recycle a finished lane; its slot is admittable immediately."""
        st = self._slots[slot]
        if st is None:
            raise ValueError(f"slot {slot} is already free")
        self._slots[slot] = None
        heapq.heappush(self._free, slot)
        return st.request

    def slot(self, i: int) -> Optional[Slot]:
        return self._slots[i]

    def occupied(self) -> Dict[int, Slot]:
        return {i: s for i, s in enumerate(self._slots) if s is not None}


__all__ = ["Request", "STATUSES", "Slot", "SlotScheduler"]
