"""Paged KV-cache block pool: host-side free-list allocator + page tables.

The paged cache layout (``kv_layout='paged'``) replaces the contiguous
per-lane ``(B, max_len, ...)`` KV regions with one global **block pool**
per layer — ``(num_blocks, block_size, n_kv_heads, hd)`` — plus a
per-lane **page table** ``(B, max_blocks)`` mapping each lane's logical
position range ``[j*block_size, (j+1)*block_size)`` to a physical block.
Cache HBM is then ``num_blocks * block_size`` rows, decoupled from
``batch * max_len``: a pool sized for the *expected* footprint serves
traffic whose per-request ``max_len`` would otherwise reserve the
worst case for every lane.

This module is the host side of that design, mirroring the slot
scheduler's philosophy: pure bookkeeping, no device state. The pool
owns the free list, the per-block refcounts and the page table (an
int32 numpy array the engine ships to the device whenever ``version``
changes — exactly how the engine's position vector is the single source
of truth for cache write indices). Blocks are appended on demand as a
lane's position crosses a block boundary (``ensure``/``grow`` before
every launch) and dereferenced the step the lane finishes or is
preempted (``release``).

Prefix sharing (serving/prefix_cache.py) turns single ownership into
**refcounted, copy-on-write sharing**: a block may be mapped by several
lanes at once (identical prompt prefixes) and pinned by the prefix /
session caches after its writer finished. The safety argument is
write-discipline, not hardware protection:

  * a block enters sharing only through ``share``/``incref`` *after*
    its writer finished — every row it will ever expose is already
    written;
  * a lane only ever writes rows at its own ``pos``, and ``pos`` for a
    lane that attached a shared prefix of ``m`` tokens starts at ``m``
    — so writes land exclusively in blocks allocated fresh for that
    lane (``grow``/``fork``), never in a shared block;
  * a divergence *inside* a block (``m % block_size != 0``) is handled
    by ``fork``: allocate a fresh block, remap the lane's page-table
    entry, and let the engine device-copy the rows — classic COW.

Invariants (property-tested in tests/test_kv_pool.py):

  * ``refcount[b] == (#page-table references to b) + external pins``
    where external pins are the prefix-cache / session holdings;
  * ``refcount[b] == 0``  ⇔  ``b`` is on the free list;
  * ``free_blocks + used_blocks == num_blocks`` always (conservation);
  * ``release`` unmaps every block the lane mapped, same call, and a
    block is recycled the moment its last reference drops;
  * page-table rows list a lane's blocks in logical order, ``-1`` padded.

The device side never sees the allocator: the jitted step receives the
page table as a plain array, computes physical write indices
``(table[lane, pos // bs], pos % bs)`` and gathers K/V through the
table (models/layers.py). Unmapped entries are ``-1``: writes through
them are pushed out of range so the ``mode='drop'`` scatter discards
them, gathers clamp and are masked by the existing per-lane validity
masks — stale block contents can never become valid, because a lane
writes position ``p`` in the same step ``p`` first enters its valid
range.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence

import numpy as np

__all__ = ["KVBlockPool"]


class KVBlockPool:
    """Refcounted free-list allocator over ``num_blocks`` physical KV blocks.

    ``max_blocks_per_lane`` is the page-table width (ceil(max_len /
    block_size)): a lane can never map more logical positions than the
    engine's cache cap.
    """

    def __init__(self, num_blocks: int, block_size: int, n_lanes: int,
                 max_blocks_per_lane: int):
        if num_blocks < 1:
            raise ValueError(f"num_blocks must be >= 1, got {num_blocks}")
        if block_size < 1:
            raise ValueError(f"block_size must be >= 1, got {block_size}")
        if n_lanes < 1:
            raise ValueError(f"n_lanes must be >= 1, got {n_lanes}")
        if max_blocks_per_lane < 1:
            raise ValueError(
                f"max_blocks_per_lane must be >= 1, got {max_blocks_per_lane}")
        self.num_blocks = num_blocks
        self.block_size = block_size
        self.n_lanes = n_lanes
        self.max_blocks_per_lane = max_blocks_per_lane
        # LIFO free list: recycled blocks are reused first (hot in cache)
        self._free: List[int] = list(range(num_blocks - 1, -1, -1))
        self._ref = np.zeros(num_blocks, np.int32)
        self._owned: List[List[int]] = [[] for _ in range(n_lanes)]
        self.table = np.full((n_lanes, max_blocks_per_lane), -1, np.int32)
        # bumped on every table mutation: the engine re-ships the table
        # to the device only when this changed since the last launch
        self.version = 0

    # -- accounting -----------------------------------------------------
    @property
    def free_blocks(self) -> int:
        return len(self._free)

    @property
    def used_blocks(self) -> int:
        return self.num_blocks - len(self._free)

    def lane_blocks(self, lane: int) -> int:
        return len(self._owned[lane])

    def lane_chain(self, lane: int) -> List[int]:
        """The lane's mapped blocks in logical order (a copy)."""
        return list(self._owned[lane])

    def refcount(self, block: int) -> int:
        return int(self._ref[block])

    def shared_blocks(self) -> int:
        """Blocks referenced more than once (mapped by several lanes
        and/or pinned by the prefix / session caches)."""
        return int((self._ref > 1).sum())

    def blocks_for(self, n_tokens: int) -> int:
        """Blocks needed to back ``n_tokens`` logical positions."""
        return -(-max(0, n_tokens) // self.block_size)

    # -- refcounting ----------------------------------------------------
    def incref(self, block: int) -> None:
        """Add an external pin (prefix-cache / session holding). The
        block must already be live — pinning a free block would resurrect
        garbage."""
        if not (0 <= block < self.num_blocks):
            raise ValueError(f"bad block id {block}")
        if self._ref[block] <= 0:
            raise ValueError(f"incref on free block {block}")
        self._ref[block] += 1

    def decref(self, block: int) -> bool:
        """Drop one reference; recycle the block when the last drops.
        Returns True iff the block was freed by this call."""
        if not (0 <= block < self.num_blocks):
            raise ValueError(f"bad block id {block}")
        if self._ref[block] <= 0:
            raise ValueError(f"decref on free block {block}")
        self._ref[block] -= 1
        if self._ref[block] == 0:
            self._free.append(block)
            return True
        return False

    # -- allocation -----------------------------------------------------
    def grow(self, lane: int, n_tokens: int) -> int:
        """Append fresh blocks until ``lane`` backs ``n_tokens`` positions
        (or the pool / page table runs out). Returns the number of
        positions actually backed — callers clip their chunk to it; a
        return below ``n_tokens`` means the pool is exhausted (preempt
        or retry)."""
        want = min(self.blocks_for(n_tokens), self.max_blocks_per_lane)
        owned = self._owned[lane]
        while len(owned) < want and self._free:
            blk = self._free.pop()
            self._ref[blk] = 1
            self.table[lane, len(owned)] = blk
            owned.append(blk)
            self.version += 1
        return min(len(owned) * self.block_size,
                   self.max_blocks_per_lane * self.block_size)

    def ensure(self, lane: int, n_tokens: int) -> bool:
        """True iff ``lane`` backs ``n_tokens`` positions after growing."""
        return self.grow(lane, n_tokens) >= min(
            n_tokens, self.max_blocks_per_lane * self.block_size)

    def share(self, lane: int, blocks: Sequence[int]) -> None:
        """Map an already-live prefix chain into an *empty* lane
        (prefix-cache hit at admission). Each block gains a reference;
        none is ever written by this lane — its ``pos`` starts past
        them."""
        owned = self._owned[lane]
        if owned:
            raise ValueError(f"share into non-empty lane {lane}")
        if len(blocks) > self.max_blocks_per_lane:
            raise ValueError("shared chain longer than page table")
        for j, blk in enumerate(blocks):
            if self._ref[blk] <= 0:
                raise ValueError(f"share of free block {blk}")
            self._ref[blk] += 1
            self.table[lane, j] = blk
            owned.append(blk)
        if blocks:
            self.version += 1

    def pop_last(self, lane: int) -> int:
        """Unmap the lane's last mapped block (dropping one reference).
        Degrade path for a COW fork that found the pool dry: the
        partially-matched tail block leaves the lane again. Returns the
        block id unmapped."""
        owned = self._owned[lane]
        if not owned:
            raise ValueError(f"pop_last on empty lane {lane}")
        blk = owned.pop()
        self.table[lane, len(owned)] = -1
        self.version += 1
        self.decref(blk)
        return blk

    def trim(self, lane: int, new_len: int) -> int:
        """Rollback primitive for speculative decoding: unmap the lane's
        tail blocks so it backs only ``new_len`` logical positions,
        dropping one reference per unmapped block. COW-aware by
        construction — a shared or cache-pinned block merely loses this
        lane's mapping (it is recycled only when its last reference
        drops) and its contents are never touched; stale rows past
        ``new_len`` in blocks the lane keeps are harmless under the
        write-discipline invariant (a lane writes position ``p`` the
        step ``p`` re-enters its valid range). Returns how many blocks
        were unmapped."""
        if new_len < 0:
            raise ValueError(f"trim to negative length {new_len}")
        keep = self.blocks_for(new_len)
        owned = self._owned[lane]
        n = 0
        while len(owned) > keep:
            blk = owned.pop()
            self.table[lane, len(owned)] = -1
            self.decref(blk)
            n += 1
        if n:
            self.version += 1
        return n

    def fork(self, lane: int, index: int) -> Optional[int]:
        """Copy-on-write fork of the lane's ``index``-th mapped block:
        allocate a fresh block, remap the page-table entry to it, drop
        the lane's reference to the shared original. Returns the new
        physical block id (the engine device-copies the rows), or None
        if the pool is dry — the caller degrades to re-prefilling the
        partial block."""
        owned = self._owned[lane]
        if not (0 <= index < len(owned)):
            raise ValueError(f"fork index {index} out of range")
        if not self._free:
            return None
        src = owned[index]
        dst = self._free.pop()
        self._ref[dst] = 1
        self.table[lane, index] = dst
        owned[index] = dst
        self.version += 1
        self.decref(src)
        return dst

    def release(self, lane: int) -> int:
        """Unmap every block the lane references (EOS / recycle /
        preempt) and drop one reference per mapping — a block is only
        recycled when no other lane and no cache pin still holds it.
        Returns how many blocks were unmapped from the lane."""
        owned = self._owned[lane]
        n = len(owned)
        if n:
            # LIFO: blocks freed here sit on top of the free list
            for blk in reversed(owned):
                self.decref(blk)
            self.table[lane, :n] = -1
            owned.clear()
            self.version += 1
        return n

    def check_invariants(
            self, external: Optional[Dict[int, int]] = None) -> None:
        """Raise AssertionError on any broken allocator invariant
        (test/debug hook — the engine never calls this on the hot path).

        ``external`` maps block id -> number of pins held outside the
        page tables (prefix-cache entries + session chains). With the
        default None, refcounts must be fully explained by the page
        tables alone."""
        ext = external or {}
        want_ref = np.zeros(self.num_blocks, np.int64)
        for lane, owned in enumerate(self._owned):
            row = self.table[lane]
            assert list(row[: len(owned)]) == owned, (
                f"lane {lane}: table row disagrees with owned list")
            assert (row[len(owned):] == -1).all(), (
                f"lane {lane}: table row not -1 beyond owned blocks")
            for b in owned:
                assert 0 <= b < self.num_blocks, f"bad block id {b}"
                want_ref[b] += 1
        for b, n in ext.items():
            assert 0 <= b < self.num_blocks, f"bad external block id {b}"
            assert n >= 0, f"negative external pin count on block {b}"
            want_ref[b] += n
        bad = [(b, int(self._ref[b]), int(want_ref[b]))
               for b in range(self.num_blocks) if self._ref[b] != want_ref[b]]
        assert not bad, (
            "refcounts disagree with page tables + external pins "
            f"(block, have, want): {bad}")
        free = set(self._free)
        assert len(free) == len(self._free), "duplicate block on free list"
        live = {b for b in range(self.num_blocks) if self._ref[b] > 0}
        assert not (live & free), "block both referenced and free"
        assert len(live) + len(free) == self.num_blocks, (
            "free-list conservation violated")
