"""Paged KV-cache block pool: host-side free-list allocator + page tables.

The paged cache layout (``kv_layout='paged'``) replaces the contiguous
per-lane ``(B, max_len, ...)`` KV regions with one global **block pool**
per layer — ``(num_blocks, block_size, n_kv_heads, hd)`` — plus a
per-lane **page table** ``(B, max_blocks)`` mapping each lane's logical
position range ``[j*block_size, (j+1)*block_size)`` to a physical block.
Cache HBM is then ``num_blocks * block_size`` rows, decoupled from
``batch * max_len``: a pool sized for the *expected* footprint serves
traffic whose per-request ``max_len`` would otherwise reserve the
worst case for every lane.

This module is the host side of that design, mirroring the slot
scheduler's philosophy: pure bookkeeping, no device state. The pool
owns the free list and the page table (an int32 numpy array the engine
ships to the device whenever ``version`` changes — exactly how the
engine's position vector is the single source of truth for cache write
indices). Blocks are appended on demand as a lane's position crosses a
block boundary (``ensure``/``grow`` before every launch) and reclaimed
the step the lane finishes or is preempted (``release``).

Invariants (property-tested in tests/test_kv_pool.py):

  * a physical block is owned by at most one lane at a time;
  * ``free_blocks + used_blocks == num_blocks`` always (conservation);
  * ``release`` returns every block the lane owned, same call;
  * page-table rows list a lane's blocks in logical order, ``-1`` padded.

The device side never sees the allocator: the jitted step receives the
page table as a plain array, computes physical write indices
``(table[lane, pos // bs], pos % bs)`` and gathers K/V through the
table (models/layers.py). Unmapped entries are ``-1``: writes through
them are pushed out of range so the ``mode='drop'`` scatter discards
them, gathers clamp and are masked by the existing per-lane validity
masks — stale block contents can never become valid, because a lane
writes position ``p`` in the same step ``p`` first enters its valid
range.
"""
from __future__ import annotations

from typing import List

import numpy as np

__all__ = ["KVBlockPool"]


class KVBlockPool:
    """Free-list allocator over ``num_blocks`` physical KV blocks.

    ``max_blocks_per_lane`` is the page-table width (ceil(max_len /
    block_size)): a lane can never map more logical positions than the
    engine's cache cap.
    """

    def __init__(self, num_blocks: int, block_size: int, n_lanes: int,
                 max_blocks_per_lane: int):
        if num_blocks < 1:
            raise ValueError(f"num_blocks must be >= 1, got {num_blocks}")
        if block_size < 1:
            raise ValueError(f"block_size must be >= 1, got {block_size}")
        if n_lanes < 1:
            raise ValueError(f"n_lanes must be >= 1, got {n_lanes}")
        if max_blocks_per_lane < 1:
            raise ValueError(
                f"max_blocks_per_lane must be >= 1, got {max_blocks_per_lane}")
        self.num_blocks = num_blocks
        self.block_size = block_size
        self.n_lanes = n_lanes
        self.max_blocks_per_lane = max_blocks_per_lane
        # LIFO free list: recycled blocks are reused first (hot in cache)
        self._free: List[int] = list(range(num_blocks - 1, -1, -1))
        self._owned: List[List[int]] = [[] for _ in range(n_lanes)]
        self.table = np.full((n_lanes, max_blocks_per_lane), -1, np.int32)
        # bumped on every table mutation: the engine re-ships the table
        # to the device only when this changed since the last launch
        self.version = 0

    # -- accounting -----------------------------------------------------
    @property
    def free_blocks(self) -> int:
        return len(self._free)

    @property
    def used_blocks(self) -> int:
        return self.num_blocks - len(self._free)

    def lane_blocks(self, lane: int) -> int:
        return len(self._owned[lane])

    def blocks_for(self, n_tokens: int) -> int:
        """Blocks needed to back ``n_tokens`` logical positions."""
        return -(-max(0, n_tokens) // self.block_size)

    # -- allocation -----------------------------------------------------
    def grow(self, lane: int, n_tokens: int) -> int:
        """Append blocks until ``lane`` backs ``n_tokens`` positions (or
        the pool / page table runs out). Returns the number of positions
        actually backed — callers clip their chunk to it; a return below
        ``n_tokens`` means the pool is exhausted (preempt or retry)."""
        want = min(self.blocks_for(n_tokens), self.max_blocks_per_lane)
        owned = self._owned[lane]
        while len(owned) < want and self._free:
            blk = self._free.pop()
            self.table[lane, len(owned)] = blk
            owned.append(blk)
            self.version += 1
        return min(len(owned) * self.block_size,
                   self.max_blocks_per_lane * self.block_size)

    def ensure(self, lane: int, n_tokens: int) -> bool:
        """True iff ``lane`` backs ``n_tokens`` positions after growing."""
        return self.grow(lane, n_tokens) >= min(
            n_tokens, self.max_blocks_per_lane * self.block_size)

    def release(self, lane: int) -> int:
        """Reclaim every block the lane owns (EOS / recycle / preempt).
        Returns how many blocks were freed."""
        owned = self._owned[lane]
        n = len(owned)
        if n:
            # LIFO: freed blocks sit on top of the free list
            self._free.extend(reversed(owned))
            self.table[lane, :n] = -1
            owned.clear()
            self.version += 1
        return n

    def check_invariants(self) -> None:
        """Raise AssertionError on any broken allocator invariant
        (test/debug hook — the engine never calls this on the hot path)."""
        seen: set = set()
        for lane, owned in enumerate(self._owned):
            row = self.table[lane]
            assert list(row[: len(owned)]) == owned, (
                f"lane {lane}: table row disagrees with owned list")
            assert (row[len(owned):] == -1).all(), (
                f"lane {lane}: table row not -1 beyond owned blocks")
            for b in owned:
                assert 0 <= b < self.num_blocks, f"bad block id {b}"
                assert b not in seen, f"block {b} owned by two lanes"
                seen.add(b)
        assert not (seen & set(self._free)), "block both owned and free"
        assert len(seen) + len(self._free) == self.num_blocks, (
            "free-list conservation violated")
