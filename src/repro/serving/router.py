"""Multi-replica request router with failover and session affinity.

``ReplicaRouter`` owns the *service-level* request table: every request
submitted through it is tracked from acceptance to exactly-once typed
terminal status, no matter how many replicas die along the way.

Routing: least-loaded (fewest in-flight requests, ties by replica
order) with **session affinity** — a session's turns stick to the
replica that served turn 1, so the PR-8 prefix-cache chains stay warm
(a session moved to another replica would re-prefill from scratch).

Failover (``supervise()``): a replica found dead is restarted with a
fresh engine, and every request it had in flight is re-routed:

  * tokens already streamed are **folded into the prompt** — the new
    replica continues from where the dead one stopped, exactly like the
    engine's own preempt-and-requeue. Greedy continuation is
    token-identical to a no-failure run by construction.
  * a request whose folded stream already ends the generation (EOS
    emitted, or token budget spent) is completed ``'ok'`` locally — the
    dead replica finished it but died before publishing.
  * a *sampled* request that already streamed tokens cannot be replayed
    (a fresh PRNG draw would diverge) — it terminates ``'failed'``,
    mirroring ``engine._preempt``.
  * remaining ``deadline_s`` is propagated (wall time already spent is
    deducted); an exhausted deadline terminates ``'timeout'``.

Exactly-once: dead replicas never publish (the worker thread is gone),
the survivor table keeps the first terminal per rid and counts any
second one in ``ServiceMetrics.duplicate_terminals`` (asserted zero by
the invariant check). With a WAL attached, every accepted submit and
every terminal transition is journaled; ``recover()`` re-submits the
journal's unfinished requests on a cold start.
"""
from __future__ import annotations

import itertools
import threading
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.serving.metrics import ServiceMetrics
from repro.serving.replica import EngineReplica, ReplicaDead
from repro.serving.scheduler import Request, STATUSES
from repro.serving.wal import RequestWAL


class NoReplicaAvailable(RuntimeError):
    """No alive replica could accept the request (retryable)."""


class _Tracked:
    """Service-level state for one rid (router-internal)."""

    __slots__ = ("rid", "prompt", "max_new", "eos_id", "sampling",
                 "deadline_s", "max_queue_wait_s", "session", "cb",
                 "current", "prior", "replica", "status", "done",
                 "t_submit", "failovers")

    def __init__(self, req: Request, cb, replica: str, t_submit: float):
        self.rid = req.rid
        self.prompt = np.asarray(req.prompt, np.int32)
        self.max_new = int(req.max_new_tokens)
        self.eos_id = req.eos_id
        self.sampling = req.sampling
        self.deadline_s = req.deadline_s
        self.max_queue_wait_s = req.max_queue_wait_s
        self.session = req.session
        self.cb = cb                  # wrapped on_token, reused on failover
        self.current = req            # the live Request incarnation
        self.prior: List[int] = []    # tokens from dead incarnations
        self.replica = replica
        self.status: Optional[str] = None
        self.done = threading.Event()
        self.t_submit = t_submit      # wall clock, for deadline deduction
        self.failovers = 0

    def tokens(self) -> List[int]:
        return self.prior + list(self.current.generated)


class ReplicaRouter:
    """Route requests across supervised replicas (see module doc).

    ``hang_after_s`` (None = disabled): a replica whose heartbeat is
    older than this is killed by ``supervise()`` and handled like any
    other death — the recovery drill for a worker wedged inside a
    launch. Keep it well above worst-case compile time when enabled.
    """

    def __init__(self, replicas: Sequence[EngineReplica],
                 wal: Optional[RequestWAL] = None,
                 metrics: Optional[ServiceMetrics] = None,
                 hang_after_s: Optional[float] = None,
                 clock: Callable[[], float] = time.monotonic):
        if not replicas:
            raise ValueError("need at least one replica")
        names = [r.name for r in replicas]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate replica names: {names}")
        self.replicas = list(replicas)
        self.wal = wal
        self.metrics = metrics if metrics is not None else ServiceMetrics()
        self.hang_after_s = hang_after_s
        self._clock = clock
        self._lock = threading.RLock()
        self._table: Dict[int, _Tracked] = {}
        self._affinity: Dict[str, str] = {}    # session -> replica name
        #: observers (set by the frontend / chaos triggers); called from
        #: replica worker threads — keep them cheap and non-blocking
        self.token_observer: Optional[Callable[[int, int], None]] = None
        self.done_observer: Optional[Callable[[int, str, List[int]],
                                              None]] = None
        start = 0
        if wal is not None:
            known = list(wal.pending) + list(wal.completed)
            start = (max(known) + 1) if known else 0
        self._rids = itertools.count(start)
        for r in self.replicas:
            r.on_terminal = self._on_terminal

    # -- lifecycle ------------------------------------------------------
    def start(self) -> None:
        for r in self.replicas:
            if r.state == "new":
                r.start()

    def stop(self, timeout: float = 10.0) -> None:
        for r in self.replicas:
            r.stop(timeout)

    def allocate_rid(self) -> int:
        """Next service-unique rid (starts above anything in the WAL, so
        a recovered journal never collides with new traffic)."""
        return next(self._rids)

    def recover(self) -> int:
        """Cold-start WAL replay: re-submit every replayable unfinished
        request from the journal; terminate unreplayable (sampled) ones
        ``'failed'``. Returns the number re-submitted."""
        if self.wal is None:
            return 0
        for rid in self.wal.unreplayable():
            tr = _Tracked(Request(rid=rid, prompt=np.zeros(1, np.int32)),
                          cb=None, replica="", t_submit=self._clock())
            with self._lock:
                self._table[rid] = tr
            self._terminal_local(tr, "failed")
        reqs = self.wal.replay_requests()
        for req in reqs:
            self.submit(req)
        self.metrics.on_wal_replayed(len(reqs))
        return len(reqs)

    # -- submission -----------------------------------------------------
    def _pick(self, session: Optional[str],
              exclude: Optional[str] = None) -> EngineReplica:
        cands = [r for r in self.replicas
                 if r.alive and r.name != exclude]
        if not cands:
            raise NoReplicaAvailable("no alive replica")
        if session is not None:
            aff = self._affinity.get(session)
            for r in cands:
                if r.name == aff:
                    return r
        best = min(enumerate(cands), key=lambda ir: (ir[1].load, ir[0]))[1]
        if session is not None:
            self._affinity[session] = best.name
        return best

    def submit(self, req: Request, session: Optional[str] = None) -> int:
        """Accept, journal and route one request; returns the rid.

        Raises ``NoReplicaAvailable`` (retryable) when every replica is
        down, ``ValueError`` on a duplicate rid (caller bug). Per-rid
        terminal statuses arrive via ``wait()``/``result()`` and the
        ``done_observer``.
        """
        if session is not None:
            req.session = session
        with self._lock:
            if req.rid in self._table:
                raise ValueError(f"duplicate request id {req.rid}")
            user_cb = req.on_token
            rid = req.rid

            def cb(r, tok, _user=user_cb):
                self.metrics.on_token()
                obs = self.token_observer
                if obs is not None:
                    obs(r, tok)
                if _user is not None:
                    _user(r, tok)

            req.on_token = cb
            tr = _Tracked(req, cb=cb, replica="", t_submit=self._clock())
            self._route(tr, req)
            self._table[rid] = tr
            if self.wal is not None:
                self.wal.log_submit(req, replica=tr.replica)
            self.metrics.on_submit()
        return rid

    def _route(self, tr: _Tracked, req: Request,
               exclude: Optional[str] = None) -> None:
        """Hand ``req`` to a live replica (retrying through deaths)."""
        while True:
            target = self._pick(tr.session, exclude=exclude)
            try:
                target.submit(req, session=tr.session)
            except ReplicaDead:
                exclude = None   # alive-set changed; re-pick freely
                continue
            tr.replica = target.name
            return

    # -- terminal path --------------------------------------------------
    def _on_terminal(self, replica: EngineReplica, req: Request) -> None:
        """Replica worker callback: exactly one per rid survives."""
        notify = None
        with self._lock:
            tr = self._table.get(req.rid)
            if tr is None:
                return                      # never tracked here
            if tr.status is not None:
                self.metrics.on_duplicate_terminal()
                return
            tr.status = req.status
            tokens = tr.prior + list(req.generated)
            if self.wal is not None:
                self.wal.log_terminal(req.rid, req.status, len(tokens))
            self.metrics.on_terminal(req.status)
            notify = (req.rid, req.status, tokens)
            tr.done.set()
        obs = self.done_observer
        if obs is not None and notify is not None:
            obs(*notify)

    def _terminal_local(self, tr: _Tracked, status: str) -> None:
        """Terminal decided by the router itself (failover edge cases)."""
        notify = None
        with self._lock:
            if tr.status is not None:
                return
            tr.status = status
            tokens = tr.tokens()
            if self.wal is not None:
                self.wal.log_terminal(tr.rid, status, len(tokens))
            self.metrics.on_terminal(status)
            notify = (tr.rid, status, tokens)
            tr.done.set()
        obs = self.done_observer
        if obs is not None and notify is not None:
            obs(*notify)

    # -- supervision ----------------------------------------------------
    def kill(self, name: str) -> None:
        """Chaos hook: hard-kill a replica by name (handled by the next
        ``supervise()`` pass like any other death)."""
        for r in self.replicas:
            if r.name == name:
                r.kill()
                self.metrics.on_replica_kill()
                return
        raise KeyError(f"unknown replica {name!r}")

    def supervise(self) -> None:
        """One supervision pass: detect hung workers, restart dead
        replicas, fail their in-flight requests over. Safe to call from
        any thread, any number of times."""
        if self.hang_after_s is not None:
            for r in self.replicas:
                if r.alive and r.heartbeat_age() > self.hang_after_s:
                    r.kill()
                    self.metrics.on_replica_kill()
        for r in self.replicas:
            if r.kill_requested and r.state != "dead":
                r.join(timeout=10.0)
            if r.state != "dead":
                continue
            victims = r.in_flight()
            # restart first so failover always has a live target (and a
            # single-replica service still recovers)
            r.restart()
            self.metrics.on_replica_restart()
            self._failover(victims, dead_incarnation=r.name)
        ages = [r.heartbeat_age() for r in self.replicas if r.alive]
        self.metrics.sample(self.pending, max(ages) if ages else 0.0)

    def _failover(self, victims: Sequence[Request],
                  dead_incarnation: str) -> None:
        with self._lock:
            for req in victims:
                tr = self._table.get(req.rid)
                if tr is None or tr.status is not None:
                    continue                 # already terminal elsewhere
                self.metrics.on_failover()
                tr.failovers += 1
                # fold the tokens the dead incarnation streamed into the
                # prompt (preempt-and-requeue discipline) and retire its
                # Request: ``tokens()`` must not count the folded stream
                # twice on the local-terminal paths below
                tr.prior = tr.tokens()
                tr.current = Request(rid=tr.rid, prompt=tr.prompt,
                                     max_new_tokens=0)
                sampled = (tr.sampling is not None
                           and tr.sampling.temperature > 0.0)
                if sampled and tr.prior:
                    self._terminal_local(tr, "failed")
                    continue
                remaining = tr.max_new - len(tr.prior)
                finished = (remaining <= 0
                            or (tr.eos_id is not None and tr.prior
                                and tr.prior[-1] == tr.eos_id))
                if finished:
                    # the dead replica completed it but died before
                    # publishing — the stream is whole; complete locally
                    self._terminal_local(tr, "ok")
                    continue
                deadline = tr.deadline_s
                if deadline is not None:
                    deadline -= self._clock() - tr.t_submit
                    if deadline <= 0:
                        self._terminal_local(tr, "timeout")
                        continue
                prompt = (np.concatenate(
                    [tr.prompt, np.asarray(tr.prior, np.int32)])
                    if tr.prior else tr.prompt)
                nreq = Request(
                    rid=tr.rid, prompt=prompt, max_new_tokens=remaining,
                    eos_id=tr.eos_id, sampling=tr.sampling,
                    deadline_s=deadline,
                    max_queue_wait_s=tr.max_queue_wait_s,
                    session=tr.session, on_token=tr.cb)
                tr.current = nreq
                if (tr.session is not None
                        and self._affinity.get(tr.session)
                        == dead_incarnation):
                    # the warm chain died with the replica; re-pin
                    self._affinity.pop(tr.session, None)
                try:
                    self._route(tr, nreq)
                except NoReplicaAvailable:
                    self._terminal_local(tr, "failed")
                if self.wal is not None and tr.status is None:
                    self.wal.log_submit(nreq, replica=tr.replica)

    # -- results / control ---------------------------------------------
    @property
    def pending(self) -> int:
        with self._lock:
            return sum(1 for tr in self._table.values()
                       if tr.status is None)

    def result(self, rid: int) -> Tuple[bool, Optional[str], List[int]]:
        """(done, status, tokens-so-far) snapshot for one rid."""
        with self._lock:
            tr = self._table[rid]
            return tr.status is not None, tr.status, tr.tokens()

    def wait(self, rid: int, timeout: Optional[float] = None) -> bool:
        with self._lock:
            tr = self._table[rid]
        return tr.done.wait(timeout)

    def wait_all(self, timeout: Optional[float] = None) -> bool:
        """Wait until every tracked request is terminal; False on
        timeout (deadline shared across requests)."""
        end = None if timeout is None else self._clock() + timeout
        with self._lock:
            trs = list(self._table.values())
        for tr in trs:
            left = None if end is None else max(0.0, end - self._clock())
            if not tr.done.wait(left):
                return False
        return True

    def results(self) -> Dict[int, Tuple[Optional[str], List[int]]]:
        with self._lock:
            return {rid: (tr.status, tr.tokens())
                    for rid, tr in self._table.items()}

    def cancel(self, rid: int) -> bool:
        """Cancel a tracked request; False when already terminal."""
        with self._lock:
            tr = self._table.get(rid)
            if tr is None:
                raise KeyError(f"unknown request id {rid}")
            if tr.status is not None:
                return False
            target = next((r for r in self.replicas
                           if r.name == tr.replica), None)
        if target is not None and target.alive:
            try:
                target.cancel(rid)
                return True
            except ReplicaDead:
                pass
        # owner is down: the request cannot make progress — honor the
        # cancel locally (failover skips entries that are terminal)
        self._terminal_local(tr, "cancelled")
        return True

    def drain(self) -> None:
        """Stop admitting new work on every replica; in-flight and
        queued requests run to completion."""
        for r in self.replicas:
            r.drain()

    def health(self) -> Dict[str, object]:
        reps = [dict(name=r.name, state=r.state, load=r.load,
                     restarts=r.restarts,
                     heartbeat_age=round(r.heartbeat_age(), 3))
                for r in self.replicas]
        return dict(replicas=reps, pending=self.pending,
                    sessions=len(self._affinity))

    def check_shutdown_invariants(self) -> None:
        """Service-level invariants after a drain: every tracked rid is
        terminal with exactly one typed status, no duplicate terminals
        were ever observed, and each live replica's engine passes its
        own shutdown invariants."""
        with self._lock:
            for rid, tr in self._table.items():
                assert tr.status in STATUSES, (
                    f"request {rid}: untyped terminal status {tr.status!r}")
                assert tr.done.is_set(), f"request {rid}: done event unset"
        assert self.metrics.duplicate_terminals == 0, (
            f"{self.metrics.duplicate_terminals} duplicate terminal(s)")
        for r in self.replicas:
            if r.state in ("idle", "stopped"):
                r.engine.check_shutdown_invariants()


__all__ = ["ReplicaRouter", "NoReplicaAvailable"]
