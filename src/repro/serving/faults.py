"""Deterministic fault injection for the serving engine.

The serving fault-tolerance layer (serving/engine.py) survives three
failure shapes: a step launch that *raises* (compiler/runtime error,
device loss), a launch that returns *NaN/inf logits* (a flipped bit in
a v2 gap stream reassigns an outlier index across quantization groups —
the uniquely dangerous ICQ failure mode, which poisons output silently
unless checked), and an *allocator* that reports exhaustion early. The
``FaultInjector`` here manufactures all three on demand so every
recovery path is exercised in CI instead of being discovered in
production.

Faults are **seeded and deterministic**: a run with the same plan (or
the same seed + rate) injects the same faults at the same launches, so
the fault-storm benchmark can assert that the *surviving* greedy output
matches a no-fault run token for token.

Two knobs, combinable:

  * ``plan`` — explicit ``(launch_index, kind)`` entries; each fires
    exactly once when the engine's global launch counter (decode and
    prefill-chunk launches share it) reaches that index. Env form
    ``ICQ_FAULT_PLAN="3:nan,6:raise,9:alloc"``.
  * ``rate`` + ``seed`` — every launch draws Bernoulli(rate) from a
    ``numpy`` generator seeded with ``seed`` and picks uniformly among
    ``kinds``. Env form ``ICQ_FAULT_RATE=0.05`` / ``ICQ_FAULT_SEED=7``.

Kinds:

  * ``'raise'`` — the launch raises ``FaultInjected`` before running.
  * ``'nan'``   — the launch runs, but its logits are reported
    non-finite for every live lane (the engine discards the result and
    retries, exactly as for genuinely corrupted logits). On launches
    with no logits to poison (prefill chunk), the engine downgrades
    this to ``'raise'``.
  * ``'alloc'`` — the paged-KV allocator reports exhaustion: the
    engine preempts the youngest live lane through the standing
    preempt-and-requeue machinery. Downgraded to ``'raise'`` when the
    engine runs the contiguous layout (no allocator to exhaust).

``fired`` records every injected ``(launch_index, kind)`` so tests and
benchmarks can assert the storm actually happened.
"""
from __future__ import annotations

import os
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

__all__ = ["Fault", "FaultInjected", "FaultInjector", "parse_fault_plan"]

KINDS = ("raise", "nan", "alloc")


class FaultInjected(RuntimeError):
    """Raised by the engine in place of a step launch the injector failed."""


Fault = Tuple[int, str]   # (launch_index, kind)


def parse_fault_plan(text: str) -> Tuple[Fault, ...]:
    """``"3:nan,6:raise"`` -> ((3, 'nan'), (6, 'raise')).

    Whitespace is ignored; duplicate launch indices are an error (one
    launch cannot fail two ways).
    """
    plan: List[Fault] = []
    seen = set()
    for part in text.split(","):
        part = part.strip()
        if not part:
            continue
        try:
            step_s, kind = part.split(":")
            step = int(step_s)
        except ValueError:
            raise ValueError(
                f"fault plan entry {part!r} is not '<launch_index>:<kind>'")
        kind = kind.strip()
        if kind not in KINDS:
            raise ValueError(
                f"fault plan entry {part!r}: kind must be one of {KINDS}")
        if step < 0:
            raise ValueError(
                f"fault plan entry {part!r}: launch index must be >= 0")
        if step in seen:
            raise ValueError(
                f"fault plan has two entries for launch {step}")
        seen.add(step)
        plan.append((step, kind))
    return tuple(plan)


class FaultInjector:
    """Seeded, deterministic launch-fault source (see module doc)."""

    def __init__(self, plan: Sequence[Fault] = (), *, seed: int = 0,
                 rate: float = 0.0, kinds: Sequence[str] = ("raise", "nan")):
        if not 0.0 <= rate <= 1.0:
            raise ValueError(f"rate must be in [0, 1], got {rate}")
        for _, kind in plan:
            if kind not in KINDS:
                raise ValueError(f"unknown fault kind {kind!r}; one of {KINDS}")
        for kind in kinds:
            if kind not in KINDS:
                raise ValueError(f"unknown fault kind {kind!r}; one of {KINDS}")
        self._plan: Dict[int, str] = {int(s): k for s, k in plan}
        self._rate = float(rate)
        self._kinds = tuple(kinds)
        self._rng = np.random.default_rng(seed)
        self.fired: List[Fault] = []

    @classmethod
    def from_env(cls) -> Optional["FaultInjector"]:
        """Build from ``ICQ_FAULT_PLAN`` / ``ICQ_FAULT_RATE`` /
        ``ICQ_FAULT_SEED``; None when no fault knob is set (the default —
        the engine then skips the injector entirely)."""
        plan_s = os.environ.get("ICQ_FAULT_PLAN", "")
        rate_s = os.environ.get("ICQ_FAULT_RATE", "")
        if not plan_s and not rate_s:
            return None
        seed = int(os.environ.get("ICQ_FAULT_SEED", "0") or "0")
        rate = float(rate_s) if rate_s else 0.0
        return cls(parse_fault_plan(plan_s), seed=seed, rate=rate)

    def draw(self, launch_index: int) -> Optional[str]:
        """Fault kind to inject at this launch, or None.

        Plan entries are one-shot: a consumed entry never fires again
        (the degraded retry of a failed launch re-runs *clean*, which is
        what lets recovery converge). The rate path draws once per call,
        so a fixed seed yields the same fault sequence for the same
        sequence of launches.
        """
        kind = self._plan.pop(launch_index, None)
        if kind is None and self._rate > 0.0:
            if self._rng.random() < self._rate:
                kind = self._kinds[int(self._rng.integers(len(self._kinds)))]
        if kind is not None:
            self.fired.append((launch_index, kind))
        return kind

    @property
    def pending(self) -> int:
        """Plan entries that have not fired yet."""
        return len(self._plan)
