"""Serving metrics: per-request latency plus engine-level utilization.

The collector is clock-agnostic — the engine stamps every event with its
own clock (wall time by default, a virtual clock in simulation) so the
numbers stay meaningful either way:

  * per request: queue wait (arrival -> admit), TTFT (arrival -> first
    *generated* token, i.e. prompt walk included), decode tokens/s, the
    terminal ``status`` (ok|timeout|expired|cancelled|rejected|failed),
    and how many times the request was preempted and requeued;
  * per engine run: aggregate generated tokens/s over the active window,
    mean slot occupancy and queue depth sampled once per step, the
    prefill-vs-decode token split (plus fused prefill+decode launches
    and the total launch count), the paged-KV footprint (cache bytes,
    pool geometry, preemptions, blocks-in-use), the decode-attention
    bytes-read estimate (logical full-table span vs live mapped
    blocks), and the
    **fault-tolerance ledger**: timeouts / cancellations / expired /
    sheds / failed terminal counts, injected-or-detected fault count by
    kind, degraded-mode steps (launches retried or pinned to the
    bitwise-exact XLA arm) and replay events (lanes preempted and
    requeued by the recovery path);
  * a **step-time watchdog** (``StepTimeWatchdog``): per-iteration wall
    time fed through the EWMA logic of ``runtime/straggler.py``,
    exposing p50/p95 step time and a ``stalled`` flag whenever an
    iteration exceeds ``threshold x`` the EWMA of its predecessors.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Dict, List, Optional

from repro.runtime.straggler import StragglerMonitor


@dataclasses.dataclass
class RequestMetrics:
    rid: int
    arrival_time: float = 0.0
    prompt_len: int = 0
    admit_time: Optional[float] = None
    first_token_time: Optional[float] = None
    finish_time: Optional[float] = None
    n_generated: int = 0
    n_preempted: int = 0    # times this request was preempted + requeued
    status: Optional[str] = None   # terminal status (scheduler.STATUSES)

    @property
    def queue_wait(self) -> Optional[float]:
        if self.admit_time is None:
            return None
        return self.admit_time - self.arrival_time

    @property
    def ttft(self) -> Optional[float]:
        """Arrival to first generated token (prompt processing included)."""
        if self.first_token_time is None:
            return None
        return self.first_token_time - self.arrival_time

    @property
    def decode_tokens_per_s(self) -> Optional[float]:
        if self.finish_time is None or self.first_token_time is None:
            return None
        span = self.finish_time - self.first_token_time
        if span <= 0:  # single-token request: no measurable decode span
            return None
        return (self.n_generated - 1) / span


def _percentile(xs: List[float], q: float) -> float:
    ys = sorted(xs)
    if not ys:
        return float("nan")
    i = min(len(ys) - 1, max(0, round(q * (len(ys) - 1))))
    return ys[i]


class StepTimeWatchdog:
    """EWMA step-time monitor for one engine run.

    Reuses the smoothing from ``runtime.straggler.StragglerMonitor``
    (one 'host' = this engine): each recorded iteration time updates the
    EWMA, and an iteration is flagged **stalled** when it exceeds
    ``threshold x`` the EWMA of the iterations before it (after
    ``warmup`` samples — the first steps include compilation). A
    virtual-clock run records dt = 0 everywhere and never flags.
    """

    def __init__(self, alpha: float = 0.2, threshold: float = 3.0,
                 warmup: int = 3):
        self._mon = StragglerMonitor(1, alpha=alpha, threshold=threshold,
                                     warmup=warmup)
        self.threshold = threshold
        self.warmup = warmup
        self.samples: List[float] = []
        self.stalled = False          # the most recent iteration stalled
        self.stalled_steps = 0        # iterations flagged over the run

    def record(self, dt: float) -> bool:
        """Feed one iteration wall time; returns the stalled flag."""
        prev = self._mon.ewma(0)
        self.stalled = bool(
            self._mon.count(0) >= self.warmup
            and prev is not None and prev > 0.0
            and dt > self.threshold * prev
        )
        if self.stalled:
            self.stalled_steps += 1
        self._mon.record(0, dt)
        self.samples.append(dt)
        return self.stalled

    @property
    def ewma(self) -> Optional[float]:
        return self._mon.ewma(0)

    def p(self, q: float) -> float:
        return _percentile(self.samples, q)


#: terminal-status -> collector counter attribute
_STATUS_COUNTERS = {
    "timeout": "timeouts",
    "expired": "expired",
    "cancelled": "cancellations",
    "rejected": "sheds",
    "failed": "failed",
}


class MetricsCollector:
    """Event sink for one engine run."""

    def __init__(self, watchdog: Optional[StepTimeWatchdog] = None):
        self.requests: Dict[int, RequestMetrics] = {}
        self.occupancy_samples: List[int] = []
        self.queue_depth_samples: List[int] = []
        self.start_time: Optional[float] = None
        self.end_time: Optional[float] = None
        # prefill-vs-decode split (chunked prefill observability)
        self.prefill_steps: int = 0          # chunk-program launches
        self.decode_steps: int = 0           # decode-program launches
        self.fused_steps: int = 0            # fused prefill+decode launches
        self.prefill_tokens: int = 0         # prompt tokens via chunk program
        self.prompt_decode_tokens: int = 0   # prompt tokens walked 1/step
        # speculative-decode ledger (serving/spec_decode.py). Tokens/s
        # and per-request counts stay accepted-only under speculation by
        # construction: rejected drafts never enter ``generated``, so
        # ``n_generated`` (and every rate derived from it) never double-
        # counts a proposed-but-refused token.
        self.verify_steps: int = 0           # verify-program launches
        self.draft_launches: int = 0         # drafter device launches
        self.spec_proposed: int = 0          # draft tokens proposed
        self.spec_accepted: int = 0          # draft tokens accepted
        self.spec_lanes: int = 0             # lane-iterations speculated
        self.accept_hist: Dict[int, int] = {}   # accepted-length histogram
        self.spec_draft_errors: int = 0      # drafter raised; plain decode
        self.spec_fallbacks: int = 0         # verify faulted; plain decode
        # sliding-window + paged attention: decode launches the per-call
        # arm gate routed down the XLA gather arm because the window is
        # shorter than the page-table span (docs/ENV.md, ICQ_PAGED_ATTN)
        self.window_fallbacks: int = 0
        # paged-attention bytes-read estimate, accumulated per launch:
        # 'logical' bills the full page-table span every lane (what a
        # contiguous gather streams), 'live' only the blocks actually
        # mapped to each live lane (what the paged kernel streams)
        self.attn_logical_bytes: int = 0
        self.attn_live_bytes: int = 0
        # paged-KV observability (kv_layout='paged')
        self.preemptions: int = 0            # preempt-and-requeue events
        self.blocks_in_use_samples: List[int] = []   # sampled once per step
        self.cache_bytes: Optional[int] = None       # device KV cache bytes
        self.kv_blocks: Optional[int] = None         # pool size (blocks)
        self.kv_block_size: Optional[int] = None     # rows per block
        # prefix-cache / session observability (serving/prefix_cache.py)
        self.prefix_lookups: int = 0         # admissions that consulted it
        self.prefix_hits: int = 0            # admissions with matched > 0
        self.prefix_tokens_skipped: int = 0  # prompt tokens never prefilled
        self.prefix_inserts: int = 0         # new hash-cache entries
        self.prefix_evictions: int = 0       # hash-cache entries evicted
        self.cow_forks: int = 0              # partial tail blocks COW-forked
        self.session_hits: int = 0           # hits matched via a session chain
        self.session_expiries: int = 0       # sessions dropped by TTL
        self.session_evictions: int = 0      # sessions dropped by pool pressure
        self.sessions_active: int = 0        # retained sessions at run end
        self.shared_blocks_samples: List[int] = []  # sampled once per step
        # fault-tolerance ledger
        self.timeouts: int = 0               # running lanes past deadline_s
        self.expired: int = 0                # queued requests past their wait
        self.cancellations: int = 0          # cancel(rid) taking effect
        self.sheds: int = 0                  # bounded-queue rejections
        self.failed: int = 0                 # recovery gave up on the request
        self.faults: Dict[str, int] = {}     # injected/detected, by kind
        self.degraded_steps: int = 0         # launches on the XLA fallback arm
        self.replays: int = 0                # whole-batch replay events
        self.watchdog = watchdog if watchdog is not None else StepTimeWatchdog()

    # -- events ---------------------------------------------------------
    def on_submit(self, rid: int, arrival_time: float, prompt_len: int):
        self.requests[rid] = RequestMetrics(
            rid=rid, arrival_time=arrival_time, prompt_len=prompt_len)

    def on_admit(self, rid: int, t: float):
        self.requests[rid].admit_time = t

    def on_first_token(self, rid: int, t: float):
        self.requests[rid].first_token_time = t

    def on_finish(self, rid: int, t: float, n_generated: int,
                  status: str = "ok"):
        r = self.requests[rid]
        r.finish_time = t
        r.n_generated = n_generated
        r.status = status
        counter = _STATUS_COUNTERS.get(status)
        if counter is not None:
            setattr(self, counter, getattr(self, counter) + 1)

    def on_step(self, occupancy: int, queue_depth: int, t: float,
                kind: str = "decode", blocks_in_use: Optional[int] = None,
                shared_blocks: Optional[int] = None):
        if self.start_time is None:
            self.start_time = t
        elif self.end_time is not None:
            self.watchdog.record(max(0.0, t - self.end_time))
        self.end_time = t
        self.occupancy_samples.append(occupancy)
        self.queue_depth_samples.append(queue_depth)
        if blocks_in_use is not None:
            self.blocks_in_use_samples.append(blocks_in_use)
        if shared_blocks is not None:
            self.shared_blocks_samples.append(shared_blocks)
        if kind == "prefill":
            self.prefill_steps += 1
        elif kind == "fused":
            self.fused_steps += 1
        elif kind == "verify":
            self.verify_steps += 1
        else:
            self.decode_steps += 1

    def on_preempt(self, rid: int, t: float):
        """Lane preempted (pool exhausted / replay) + request requeued."""
        self.preemptions += 1
        self.requests[rid].n_preempted += 1

    def on_fault(self, kind: str):
        """A launch fault was injected or detected (kind: 'raise' | 'nan'
        | 'alloc' | 'error')."""
        self.faults[kind] = self.faults.get(kind, 0) + 1

    def on_degraded_step(self):
        """One launch executed on the degraded (bitwise-exact XLA) arm."""
        self.degraded_steps += 1

    def on_replay(self):
        """Recovery preempted the live lanes and requeued them for replay."""
        self.replays += 1

    def on_spec(self, proposed: int, accepted: int):
        """One lane's draft-and-verify outcome this iteration:
        ``proposed`` draft tokens went into the verify launch, the first
        ``accepted`` of them matched the verifier's greedy verdict (the
        lane then also emitted the verifier's corrected/next token, so
        it advanced ``accepted + 1`` tokens for one verify launch)."""
        self.spec_lanes += 1
        self.spec_proposed += int(proposed)
        self.spec_accepted += int(accepted)
        self.accept_hist[int(accepted)] = \
            self.accept_hist.get(int(accepted), 0) + 1

    def on_draft_launches(self, n: int):
        """Device launches the drafter spent this iteration (0 for
        host-only drafters like 'ngram'/'reject')."""
        self.draft_launches += int(n)

    def on_spec_draft_error(self):
        """The drafter raised; the iteration fell back to plain decode."""
        self.spec_draft_errors += 1

    def on_spec_fallback(self):
        """The verify launch faulted (injected or genuine); the iteration
        degraded to the plain decode program, which re-emits this step's
        token(s) identically on the XLA arm."""
        self.spec_fallbacks += 1

    def on_window_fallback(self):
        """One paged decode launch ran on the XLA gather arm because the
        config's sliding window is shorter than the page-table span
        (models/layers._paged_attn_arm gate)."""
        self.window_fallbacks += 1

    def on_prefix_attach(self, matched_tokens: int, forked: bool = False,
                         via_session: bool = False):
        """One prefix-cache consultation at admission: ``matched_tokens``
        prompt tokens were warm-started from shared blocks (0 = miss),
        ``forked`` when the partial tail block was COW-forked,
        ``via_session`` when the winning match came from a session chain
        rather than the hash cache."""
        self.prefix_lookups += 1
        if matched_tokens > 0:
            self.prefix_hits += 1
            self.prefix_tokens_skipped += int(matched_tokens)
            if via_session:
                self.session_hits += 1
        if forked:
            self.cow_forks += 1

    def on_prefix_insert(self, n_entries: int):
        """New hash-cache entries indexed from a finished chain."""
        self.prefix_inserts += int(n_entries)

    def on_prefix_evictions(self, n_entries: int):
        """Hash-cache entries evicted under pool pressure."""
        self.prefix_evictions += int(n_entries)

    def on_session_expired(self, n: int):
        """Sessions dropped by the TTL sweep (``ICQ_SESSION_TTL``)."""
        self.session_expiries += int(n)

    def on_session_evicted(self, n: int):
        """Sessions dropped LRU-first under pool pressure."""
        self.session_evictions += int(n)

    def set_session_stats(self, active: int):
        """Retained sessions at run end (set by the engine per run)."""
        self.sessions_active = int(active)

    def set_kv_stats(self, cache_bytes: int,
                     kv_blocks: Optional[int] = None,
                     kv_block_size: Optional[int] = None):
        """Device KV-cache footprint for this run (set once, at cache
        build time; kv_blocks/kv_block_size only for the paged layout)."""
        self.cache_bytes = int(cache_bytes)
        self.kv_blocks = kv_blocks
        self.kv_block_size = kv_block_size

    def on_attn_bytes(self, logical: int, live: int):
        """One launch's decode-attention KV bytes-read estimate:
        ``logical`` = full page-table span per live lane (the contiguous
        gather's streaming cost), ``live`` = only the blocks each lane
        actually maps (what the paged Pallas kernel streams through
        VMEM). The gap between the two running totals is the bandwidth
        the paged kernel saves."""
        self.attn_logical_bytes += int(logical)
        self.attn_live_bytes += int(live)

    def on_prompt_tokens(self, n: int, kind: str = "decode"):
        """Prompt tokens consumed this step: ``kind='prefill'`` via the
        S-token chunk program, ``'decode'`` teacher-forced 1/step."""
        if kind == "prefill":
            self.prefill_tokens += n
        else:
            self.prompt_decode_tokens += n

    # -- report ---------------------------------------------------------
    def status_counts(self) -> Dict[str, int]:
        """Terminal-status histogram over all finished requests."""
        out: Dict[str, int] = {}
        for r in self.requests.values():
            if r.status is not None:
                out[r.status] = out.get(r.status, 0) + 1
        return out

    def summary(self) -> Dict[str, float]:
        done = [r for r in self.requests.values() if r.finish_time is not None]
        served = [r for r in done if r.status in (None, "ok", "timeout")]
        total_tokens = sum(r.n_generated for r in done)
        wall = (
            (self.end_time - self.start_time)
            if self.start_time is not None and self.end_time is not None
            else 0.0
        )
        ttfts = [r.ttft for r in served if r.ttft is not None]
        waits = [r.queue_wait for r in served if r.queue_wait is not None]
        occ = self.occupancy_samples
        qd = self.queue_depth_samples
        bu = self.blocks_in_use_samples
        sb = self.shared_blocks_samples
        wd = self.watchdog
        return dict(
            requests=float(len(self.requests)),
            completed=float(len(done)),
            generated_tokens=float(total_tokens),
            wall_s=wall,
            tokens_per_s=(total_tokens / wall) if wall > 0 else float("nan"),
            steps=float(len(occ)),
            mean_occupancy=(sum(occ) / len(occ)) if occ else 0.0,
            mean_queue_depth=(sum(qd) / len(qd)) if qd else 0.0,
            ttft_mean=(sum(ttfts) / len(ttfts)) if ttfts else float("nan"),
            ttft_p50=_percentile(ttfts, 0.50),
            ttft_p95=_percentile(ttfts, 0.95),
            queue_wait_mean=(sum(waits) / len(waits)) if waits else 0.0,
            prefill_steps=float(self.prefill_steps),
            decode_steps=float(self.decode_steps),
            fused_steps=float(self.fused_steps),
            verify_steps=float(self.verify_steps),
            draft_launches=float(self.draft_launches),
            launches=float(self.prefill_steps + self.decode_steps
                           + self.fused_steps + self.verify_steps
                           + self.draft_launches),
            # speculative-decode ledger (accepted-only token accounting)
            spec_proposed=float(self.spec_proposed),
            spec_accepted=float(self.spec_accepted),
            spec_accept_rate=(self.spec_accepted / self.spec_proposed
                              if self.spec_proposed else float("nan")),
            mean_accept_len=(self.spec_accepted / self.spec_lanes
                             if self.spec_lanes else float("nan")),
            spec_draft_errors=float(self.spec_draft_errors),
            spec_fallbacks=float(self.spec_fallbacks),
            paged_attn_window_fallbacks=float(self.window_fallbacks),
            prefill_tokens=float(self.prefill_tokens),
            prompt_decode_tokens=float(self.prompt_decode_tokens),
            attn_logical_bytes=float(self.attn_logical_bytes),
            attn_live_bytes=float(self.attn_live_bytes),
            preemptions=float(self.preemptions),
            cache_bytes=(float(self.cache_bytes)
                         if self.cache_bytes is not None else float("nan")),
            kv_blocks=(float(self.kv_blocks)
                       if self.kv_blocks is not None else float("nan")),
            kv_block_size=(float(self.kv_block_size)
                           if self.kv_block_size is not None
                           else float("nan")),
            mean_blocks_in_use=((sum(bu) / len(bu)) if bu else float("nan")),
            peak_blocks_in_use=(float(max(bu)) if bu else float("nan")),
            mean_block_utilization=(
                (sum(bu) / len(bu)) / self.kv_blocks
                if bu and self.kv_blocks else float("nan")),
            # prefix-cache / session ledger
            prefix_lookups=float(self.prefix_lookups),
            prefix_hits=float(self.prefix_hits),
            prefix_hit_rate=(self.prefix_hits / self.prefix_lookups
                             if self.prefix_lookups else float("nan")),
            prefix_tokens_skipped=float(self.prefix_tokens_skipped),
            prefix_inserts=float(self.prefix_inserts),
            prefix_evictions=float(self.prefix_evictions),
            cow_forks=float(self.cow_forks),
            session_hits=float(self.session_hits),
            session_expiries=float(self.session_expiries),
            session_evictions=float(self.session_evictions),
            sessions_active=float(self.sessions_active),
            mean_shared_blocks=((sum(sb) / len(sb)) if sb else float("nan")),
            peak_shared_blocks=(float(max(sb)) if sb else float("nan")),
            # fault-tolerance ledger
            timeouts=float(self.timeouts),
            expired=float(self.expired),
            cancellations=float(self.cancellations),
            sheds=float(self.sheds),
            failed=float(self.failed),
            faults=float(sum(self.faults.values())),
            degraded_steps=float(self.degraded_steps),
            replays=float(self.replays),
            # step-time watchdog
            step_time_p50=wd.p(0.50),
            step_time_p95=wd.p(0.95),
            step_time_ewma=(wd.ewma if wd.ewma is not None else float("nan")),
            stalled_steps=float(wd.stalled_steps),
            stalled=float(wd.stalled),
        )


class ServiceMetrics:
    """Counters for the service layer above the engine (frontend ->
    router -> replicas): submissions and terminal statuses as the router
    sees them, failovers and replica restarts, frontend backpressure
    sheds, client retries, and gauges for the frontend queue depth and
    the worst replica heartbeat age. One instance is shared by every
    component of a ``ServingService``; all methods are thread-safe (the
    frontend event loop, the supervisor and N replica workers all
    report into it)."""

    def __init__(self):
        self._lock = threading.Lock()
        self.submits = 0              # requests the router accepted
        self.status_counts: Dict[str, int] = {}   # terminal statuses
        self.tokens_streamed = 0      # tokens forwarded to callers
        self.failovers = 0            # in-flight requests moved off a
        #                               dead replica (or force-failed)
        self.replica_restarts = 0     # dead replicas rebuilt + restarted
        self.replica_kills = 0        # hard kills (chaos or watchdog)
        self.frontend_sheds = 0       # submits refused by backpressure
        self.retries = 0              # client-side retry attempts
        self.duplicate_terminals = 0  # MUST stay 0: a second terminal
        #                               for an already-finished rid
        self.wal_replayed = 0         # requests re-submitted from WAL
        self.peak_pending = 0         # frontend queue-depth high water
        self.heartbeat_age_max = 0.0  # worst replica heartbeat age seen

    def _bump(self, attr: str, n: int = 1):
        with self._lock:
            setattr(self, attr, getattr(self, attr) + n)

    def on_submit(self):
        self._bump("submits")

    def on_terminal(self, status: str):
        with self._lock:
            self.status_counts[status] = self.status_counts.get(status, 0) + 1

    def on_token(self):
        self._bump("tokens_streamed")

    def on_failover(self):
        self._bump("failovers")

    def on_replica_restart(self):
        self._bump("replica_restarts")

    def on_replica_kill(self):
        self._bump("replica_kills")

    def on_shed(self):
        self._bump("frontend_sheds")

    def on_retry(self, n: int = 1):
        self._bump("retries", n)

    def on_duplicate_terminal(self):
        self._bump("duplicate_terminals")

    def on_wal_replayed(self, n: int):
        self._bump("wal_replayed", n)

    def sample(self, pending: int, heartbeat_age: float):
        """Gauge sample: current frontend queue depth + worst replica
        heartbeat age (taken by the supervisor each pass)."""
        with self._lock:
            self.peak_pending = max(self.peak_pending, int(pending))
            self.heartbeat_age_max = max(self.heartbeat_age_max,
                                         float(heartbeat_age))

    def completed(self) -> int:
        with self._lock:
            return sum(self.status_counts.values())

    def summary(self) -> Dict[str, float]:
        with self._lock:
            out = dict(
                submits=float(self.submits),
                completed=float(sum(self.status_counts.values())),
                tokens_streamed=float(self.tokens_streamed),
                failovers=float(self.failovers),
                replica_restarts=float(self.replica_restarts),
                replica_kills=float(self.replica_kills),
                frontend_sheds=float(self.frontend_sheds),
                retries=float(self.retries),
                duplicate_terminals=float(self.duplicate_terminals),
                wal_replayed=float(self.wal_replayed),
                peak_pending=float(self.peak_pending),
                heartbeat_age_max=float(self.heartbeat_age_max),
            )
            for s, n in self.status_counts.items():
                out[f"status_{s}"] = float(n)
            return out


__all__ = ["RequestMetrics", "MetricsCollector", "StepTimeWatchdog",
           "ServiceMetrics"]
