"""Prefix cache + multi-turn sessions over the paged KV block pool.

Production traffic is dominated by shared system prompts and multi-turn
chats; with the paged layout (serving/kv_pool.py) the KV rows for a
repeated prompt prefix already exist in the pool when the next request
arrives — re-prefilling them is pure waste. This module is the host-side
bookkeeping that turns the refcounted pool into a **prefix cache**:

``block_hashes``
    Rolling per-block chain hash of a token sequence at ``block_size``
    granularity. Block ``j``'s digest commits to every token in blocks
    ``0..j`` (each digest hashes the parent digest + the block's
    tokens), so a single digest identifies an entire prefix — two
    prompts share block ``j`` iff their first ``(j+1)*block_size``
    tokens agree. Only *full* blocks are hashed: a partial tail block
    can still be receiving writes and is never shared through the hash
    index (sessions share it via COW fork instead).

``PrefixCache``
    Digest -> physical-block index over *finished* chains. Insertion
    happens only at request finish (``engine._finish``), so every
    indexed block is fully written and read-only forever after — the
    write-discipline half of the COW safety argument (see
    kv_pool.py's module docstring for the other half). Each entry pins
    its block with one pool reference; eviction is leaf-first LRU
    (children hold their parents reachable) and only runs under pool
    pressure — a cached block costs nothing until someone needs the
    HBM back.

``SessionStore``
    Session id -> the exact token chain (including the partial tail
    block) of the session's last finished turn. The next turn matches
    by token comparison, not hashes, so it can warm-start mid-block:
    the engine maps the shared full blocks, COW-forks the partial tail
    block, and starts prefill at the first divergent token. Sessions
    are TTL-expired on the engine clock (``ICQ_SESSION_TTL``) and
    LRU-evicted under pool pressure, idle sessions first.

Both structures are pure host bookkeeping: they hold block *ids* and
pool references, never device arrays. Correctness does not depend on
them — evicting everything merely makes the next request prefill cold.
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

import numpy as np

__all__ = ["block_hashes", "PrefixCache", "SessionStore"]


def block_hashes(tokens: Sequence[int], block_size: int,
                 n_blocks: Optional[int] = None) -> List[bytes]:
    """Chain digests for the full ``block_size``-token blocks of
    ``tokens`` (optionally only the first ``n_blocks``). Digest ``j``
    commits to tokens ``[0, (j+1)*block_size)`` — equality of digests
    is equality of whole prefixes (modulo hash collisions; blake2b-16
    makes that negligible)."""
    if block_size < 1:
        raise ValueError(f"block_size must be >= 1, got {block_size}")
    toks = np.asarray(tokens, np.int32)
    total = len(toks) // block_size
    if n_blocks is not None:
        total = min(total, max(0, n_blocks))
    out: List[bytes] = []
    parent = b""
    for j in range(total):
        h = hashlib.blake2b(digest_size=16)
        h.update(parent)
        h.update(toks[j * block_size:(j + 1) * block_size].tobytes())
        parent = h.digest()
        out.append(parent)
    return out


@dataclass
class _Entry:
    block: int
    parent: Optional[bytes]
    last_used: float
    children: int = 0


class PrefixCache:
    """LRU cache of finished prefix chains: digest -> pinned block."""

    def __init__(self) -> None:
        self._entries: Dict[bytes, _Entry] = {}

    def __len__(self) -> int:
        return len(self._entries)

    @property
    def blocks_pinned(self) -> int:
        return len(self._entries)

    def match(self, hashes: Sequence[bytes], now: float) -> List[int]:
        """Longest cached prefix of ``hashes``: block ids for the
        leading run of digests present in the cache. Touches every
        matched entry's LRU stamp."""
        out: List[int] = []
        for h in hashes:
            e = self._entries.get(h)
            if e is None:
                break
            e.last_used = now
            out.append(e.block)
        return out

    def insert(self, hashes: Sequence[bytes], blocks: Sequence[int],
               pool, now: float) -> int:
        """Index a finished chain. Digests already present are refreshed
        (their existing block stays — same content by construction);
        new digests pin their block with one pool reference. Returns
        how many new entries were created."""
        if len(hashes) != len(blocks):
            raise ValueError("hashes and blocks length mismatch")
        created = 0
        parent: Optional[bytes] = None
        for h, b in zip(hashes, blocks):
            e = self._entries.get(h)
            if e is not None:
                e.last_used = now
            else:
                self._entries[h] = _Entry(b, parent, now)
                pool.incref(b)
                if parent is not None:
                    self._entries[parent].children += 1
                created += 1
            parent = h
        return created

    def _evict_one(self, pool, protect: Set[int]) -> Optional[int]:
        """Evict the least-recently-used *leaf* entry whose block is not
        protected. Returns the block id dereferenced, or None if nothing
        is evictable."""
        victim: Optional[bytes] = None
        best = float("inf")
        for h, e in self._entries.items():
            if e.children == 0 and e.block not in protect and \
                    e.last_used < best:
                best = e.last_used
                victim = h
        if victim is None:
            return None
        e = self._entries.pop(victim)
        if e.parent is not None and e.parent in self._entries:
            self._entries[e.parent].children -= 1
        pool.decref(e.block)
        return e.block

    def evict_until(self, pool, min_free: int,
                    protect: Iterable[int] = ()) -> int:
        """Evict LRU leaves until ``pool.free_blocks >= min_free`` or
        nothing more can be evicted. Returns the number of entries
        evicted (pool pressure gate: callers only invoke this when an
        allocation would otherwise fail)."""
        prot = set(protect)
        evicted = 0
        while pool.free_blocks < min_free:
            if self._evict_one(pool, prot) is None:
                break
            evicted += 1
        return evicted

    def clear(self, pool) -> int:
        """Drop every entry (engine teardown). Returns entries dropped."""
        n = len(self._entries)
        for e in self._entries.values():
            pool.decref(e.block)
        self._entries.clear()
        return n

    def holdings(self) -> Dict[int, int]:
        """block id -> number of pins held by this cache (for
        ``KVBlockPool.check_invariants(external=...)``)."""
        out: Dict[int, int] = {}
        for e in self._entries.values():
            out[e.block] = out.get(e.block, 0) + 1
        return out


@dataclass
class _Session:
    tokens: np.ndarray          # exact consumed token chain, int32
    blocks: List[int] = field(default_factory=list)
    last_used: float = 0.0


class SessionStore:
    """Per-session retained chains for multi-turn warm starts."""

    def __init__(self) -> None:
        self._sessions: Dict[str, _Session] = {}

    def __len__(self) -> int:
        return len(self._sessions)

    def __contains__(self, sid: str) -> bool:
        return sid in self._sessions

    def ids(self) -> List[str]:
        return list(self._sessions)

    def retain(self, sid: str, tokens: np.ndarray, blocks: Sequence[int],
               pool, now: float) -> None:
        """Replace the session's retained chain with the just-finished
        turn's. New blocks are pinned before old pins drop so a block
        shared between consecutive turns never transits refcount 0."""
        for b in blocks:
            pool.incref(b)
        old = self._sessions.get(sid)
        if old is not None:
            for b in old.blocks:
                pool.decref(b)
        self._sessions[sid] = _Session(
            np.asarray(tokens, np.int32).copy(), list(blocks), now)

    def match(self, sid: str, prompt: Sequence[int],
              now: float) -> Tuple[int, List[int]]:
        """Longest common prefix (in tokens) between ``prompt`` and the
        session's retained chain, with the retained blocks backing it.
        Returns ``(0, [])`` for an unknown session."""
        s = self._sessions.get(sid)
        if s is None:
            return 0, []
        p = np.asarray(prompt, np.int32)
        n = min(len(p), len(s.tokens))
        neq = np.nonzero(p[:n] != s.tokens[:n])[0]
        m = int(neq[0]) if len(neq) else n
        s.last_used = now
        return m, list(s.blocks)

    def drop(self, sid: str, pool) -> int:
        """Forget a session, dropping its pins. Returns blocks unpinned."""
        s = self._sessions.pop(sid, None)
        if s is None:
            return 0
        for b in s.blocks:
            pool.decref(b)
        return len(s.blocks)

    def expire(self, now: float, ttl: float, pool,
               protect: Iterable[str] = ()) -> List[str]:
        """Drop every session idle longer than ``ttl`` seconds (engine
        clock), except protected (in-flight) ones."""
        prot = set(protect)
        stale = [sid for sid, s in self._sessions.items()
                 if sid not in prot and now - s.last_used >= ttl]
        for sid in stale:
            self.drop(sid, pool)
        return stale

    def evict_until(self, pool, min_free: int,
                    protect: Iterable[str] = ()) -> int:
        """Evict idle sessions, LRU first, until ``pool.free_blocks >=
        min_free`` or none remain. Returns sessions evicted."""
        prot = set(protect)
        evicted = 0
        while pool.free_blocks < min_free:
            victim, best = None, float("inf")
            for sid, s in self._sessions.items():
                if sid not in prot and s.last_used < best:
                    best = s.last_used
                    victim = sid
            if victim is None:
                break
            self.drop(victim, pool)
            evicted += 1
        return evicted

    def clear(self, pool) -> int:
        n = len(self._sessions)
        for sid in list(self._sessions):
            self.drop(sid, pool)
        return n

    def holdings(self) -> Dict[int, int]:
        """block id -> pins held by retained session chains."""
        out: Dict[int, int] = {}
        for s in self._sessions.values():
            for b in s.blocks:
                out[b] = out.get(b, 0) + 1
        return out
