"""Engine replica supervisor: the engine loop in a worker thread.

``EngineReplica`` wraps one ``GenerationEngine`` (continuous mode) in a
worker thread so the service layer can treat it like a remote process:
submit/cancel go through a thread-safe inbox, terminal statuses come
back through an ``on_terminal`` callback, and the replica can *die* —
either killed deliberately (the in-process analog of ``kill -9``, used
by the chaos drills) or declared hung by the step-time watchdog — and
be **restarted with a fresh engine** while the router fails its
in-flight requests over to a healthy replica.

Threading contract: the engine stays single-threaded. Only the worker
thread ever touches it — submits and cancels are enqueued and applied
by the worker, either between runs or *mid-run* through the engine's
``on_iteration`` hook (which also beats the heartbeat every iteration).
Everything the supervisor exposes cross-thread is a plain
counter/flag/queue.

Failure detection:

  * **crash** — any exception escaping the worker loop (including the
    deliberate ``ReplicaKilled``) marks the replica ``dead``. The
    engine object is abandoned where it stood: no terminal statuses are
    published for its in-flight requests (a dead process cannot
    publish), which is exactly what lets the router's failover keep the
    exactly-once guarantee.
  * **hang** — the ``on_iteration`` hook watches the engine's
    ``StepTimeWatchdog``: ``stall_steps`` *consecutive* stalled
    iterations (default off; ``ICQ_STALL_STEPS``) raises
    ``ReplicaKilled`` from inside the loop, turning a live-but-crawling
    replica into a clean death the supervisor can restart. A worker
    that stops beating entirely (stuck inside a launch) is caught by
    the router's heartbeat check instead.

``restart()`` discards the dead engine, clears the inbox (the router
re-owns anything that was in flight) and starts a fresh worker over a
fresh engine from the factory. Greedy replay of the lost requests is
token-identical by construction — same discipline as the engine's own
preempt-and-requeue.

``ICQ_HEARTBEAT_S`` sets the default heartbeat/inbox-poll interval.
"""
from __future__ import annotations

import os
import queue
import threading
import time
from typing import Callable, Dict, Optional, Tuple

from repro.serving.scheduler import Request


class ReplicaDead(RuntimeError):
    """Raised by ``submit``/``cancel`` on a replica that is not alive.

    Retryable from the caller's point of view: the router catches it
    and re-routes to a healthy replica."""


class ReplicaKilled(RuntimeError):
    """Raised inside the worker loop to crash the replica on purpose
    (chaos kill or watchdog-detected stall). The engine run is
    abandoned mid-flight — nothing is published after it."""


def default_heartbeat_s() -> float:
    """``ICQ_HEARTBEAT_S`` env knob: heartbeat/inbox-poll interval in
    seconds (default 0.5)."""
    v = os.environ.get("ICQ_HEARTBEAT_S", "")
    if not v:
        return 0.5
    out = float(v)
    if out <= 0:
        raise ValueError(f"ICQ_HEARTBEAT_S must be > 0, got {v!r}")
    return out


def default_stall_steps() -> int:
    """``ICQ_STALL_STEPS`` env knob: consecutive watchdog-stalled
    iterations before the worker declares itself hung and dies for
    restart (0 = disabled, the default — CI runners stall spuriously)."""
    v = os.environ.get("ICQ_STALL_STEPS", "")
    if not v:
        return 0
    out = int(v)
    if out < 0:
        raise ValueError(f"ICQ_STALL_STEPS must be >= 0, got {v!r}")
    return out


class EngineReplica:
    """One supervised engine worker (see module doc).

    ``engine_factory`` must build a *fresh* continuous-mode
    ``GenerationEngine`` per call — restart discards the old engine
    (and its jitted programs) entirely. ``on_terminal(replica, req)``
    is invoked from the worker thread exactly once per request that
    reaches a terminal status on a *live* replica.
    """

    def __init__(self, name: str,
                 engine_factory: Callable[[], "object"],
                 heartbeat_s: Optional[float] = None,
                 stall_steps: Optional[int] = None):
        self.name = name
        self._factory = engine_factory
        self.heartbeat_s = (default_heartbeat_s() if heartbeat_s is None
                            else float(heartbeat_s))
        self.stall_steps = (default_stall_steps() if stall_steps is None
                            else int(stall_steps))
        self.on_terminal: Optional[Callable[["EngineReplica", Request],
                                            None]] = None
        self.restarts = 0
        self.last_error: Optional[BaseException] = None
        self.state = "new"          # new|idle|running|dead|stopped
        self._lock = threading.Lock()
        self._inbox: "queue.Queue[Tuple[str, object, object]]" = queue.Queue()
        self._accepted: Dict[int, Request] = {}   # rid -> in-flight here
        self._published: set = set()
        self._kill = threading.Event()
        self._stop = threading.Event()
        self._hb = time.monotonic()
        self._consec_stalled = 0
        self._thread: Optional[threading.Thread] = None
        self.engine = self._build_engine()

    def _build_engine(self):
        eng = self._factory()
        if getattr(eng, "mode", "continuous") != "continuous":
            raise ValueError(
                f"replica {self.name}: engine_factory must build a "
                f"continuous-mode engine, got mode={eng.mode!r}")
        eng.on_iteration = self._hook
        return eng

    # -- cross-thread surface ------------------------------------------
    @property
    def alive(self) -> bool:
        """Accepting work: worker running and no kill pending."""
        return (self.state in ("idle", "running")
                and self._thread is not None and self._thread.is_alive()
                and not self._kill.is_set())

    @property
    def kill_requested(self) -> bool:
        return self._kill.is_set()

    @property
    def load(self) -> int:
        """In-flight requests accepted by this replica (routing weight)."""
        with self._lock:
            return len(self._accepted)

    def heartbeat_age(self, now: Optional[float] = None) -> float:
        """Seconds since the worker last proved liveness."""
        t = time.monotonic() if now is None else now
        return max(0.0, t - self._hb)

    def in_flight(self) -> Tuple[Request, ...]:
        """Snapshot of the requests this replica owns (router failover
        reads this off a *dead* replica — the worker is gone, nothing
        mutates it concurrently)."""
        with self._lock:
            return tuple(self._accepted.values())

    def start(self) -> None:
        if self._thread is not None and self._thread.is_alive():
            raise RuntimeError(f"replica {self.name} already running")
        self.state = "idle"
        self._thread = threading.Thread(
            target=self._main, name=f"replica-{self.name}", daemon=True)
        self._thread.start()

    def submit(self, req: Request, session: Optional[str] = None) -> None:
        """Hand a request to the worker (applied in inbox order)."""
        if not self.alive:
            raise ReplicaDead(f"replica {self.name} is {self.state}")
        with self._lock:
            self._accepted[req.rid] = req
        self._inbox.put(("submit", req, session))

    def cancel(self, rid: int) -> None:
        if not self.alive:
            raise ReplicaDead(f"replica {self.name} is {self.state}")
        self._inbox.put(("cancel", rid, None))

    def drain(self) -> None:
        """Refuse new engine admissions; in-flight work finishes."""
        if self.alive:
            self._inbox.put(("drain", None, None))

    def kill(self) -> None:
        """Hard-kill the worker (chaos / hung-replica recovery): the
        loop raises ``ReplicaKilled`` at its next heartbeat and the
        engine is abandoned mid-run."""
        self._kill.set()
        self._inbox.put(("nop", None, None))   # wake an idle worker

    def stop(self, timeout: Optional[float] = 10.0) -> None:
        """Graceful stop: finish queued + running work, then exit."""
        self._stop.set()
        self._inbox.put(("nop", None, None))
        self.join(timeout)

    def join(self, timeout: Optional[float] = None) -> None:
        if self._thread is not None:
            self._thread.join(timeout)

    def restart(self) -> None:
        """Replace a dead (or stopped) replica with a fresh engine and
        a fresh worker. The old engine and anything in the inbox are
        discarded — the router owns re-submission of lost requests."""
        if self.state in ("idle", "running") and not self._kill.is_set():
            raise RuntimeError(
                f"replica {self.name} is {self.state}; kill/stop it first")
        self.join(timeout=10.0)
        with self._lock:
            self._accepted.clear()
        self._published = set()
        self._kill.clear()
        self._stop.clear()
        self._consec_stalled = 0
        self.last_error = None
        while True:   # discard anything queued at the dead worker
            try:
                self._inbox.get_nowait()
            except queue.Empty:
                break
        self.engine = self._build_engine()
        self.restarts += 1
        self._hb = time.monotonic()
        self.start()

    # -- worker thread --------------------------------------------------
    def _beat(self) -> None:
        self._hb = time.monotonic()

    def _hook(self) -> None:
        """Engine ``on_iteration`` hook (worker thread, mid-run)."""
        self._beat()
        if self._kill.is_set():
            raise ReplicaKilled(f"replica {self.name}: killed")
        if self.stall_steps:
            wd = self.engine.metrics.watchdog
            self._consec_stalled = (self._consec_stalled + 1 if wd.stalled
                                    else 0)
            if self._consec_stalled >= self.stall_steps:
                raise ReplicaKilled(
                    f"replica {self.name}: watchdog stalled "
                    f"{self._consec_stalled} consecutive iterations")
        self._drain_inbox()
        self._publish()

    def _drain_inbox(self) -> None:
        while True:
            try:
                item = self._inbox.get_nowait()
            except queue.Empty:
                return
            self._handle(item)

    def _handle(self, item: Tuple[str, object, object]) -> None:
        op, a, b = item
        if op == "submit":
            req: Request = a  # type: ignore[assignment]
            req.arrival_time = self.engine.now()
            try:
                self.engine.submit(req, session=b)
                # a False return (shed/draining) already recorded the
                # terminal in engine.completed; _publish picks it up
            except ValueError:
                # caller-bug class rejection (empty prompt, too long,
                # duplicate rid, unservable): the engine never saw it,
                # so publish the typed terminal ourselves
                req.status = "rejected"
                self._publish_one(req)
        elif op == "cancel":
            try:
                self.engine.cancel(a)
            except KeyError:
                pass      # not (or no longer) on this engine
        elif op == "drain":
            self.engine.request_drain()
        # 'nop': wake-up only

    def _publish_one(self, req: Request) -> None:
        with self._lock:
            if req.rid in self._published:
                return
            self._published.add(req.rid)
            self._accepted.pop(req.rid, None)
        cb = self.on_terminal
        if cb is not None:
            cb(self, req)

    def _publish(self) -> None:
        """Forward newly-terminal requests (engine.completed accumulates
        across runs; the published-set makes each rid fire once)."""
        for rid, req in list(self.engine.completed.items()):
            if rid not in self._published:
                self._publish_one(req)

    def _main(self) -> None:
        try:
            while True:
                self._beat()
                if self._kill.is_set():
                    raise ReplicaKilled(f"replica {self.name}: killed")
                try:
                    item = self._inbox.get(timeout=self.heartbeat_s)
                except queue.Empty:
                    item = None
                if item is not None:
                    self._handle(item)
                    self._drain_inbox()
                self._publish()
                if self.engine.has_work():
                    self.state = "running"
                    try:
                        self.engine.run()
                    finally:
                        if not self._kill.is_set():
                            self._publish()
                    self.state = "idle"
                elif self._stop.is_set() and self._inbox.empty():
                    self.state = "stopped"
                    return
        except BaseException as e:   # ReplicaKilled or a real crash
            self.last_error = e
            self.state = "dead"


__all__ = ["EngineReplica", "ReplicaDead", "ReplicaKilled",
           "default_heartbeat_s", "default_stall_steps"]
