"""Serve a small model with batched requests from ICQuant-packed weights.

    PYTHONPATH=src python examples/serve_quantized.py [--bits 3]

Trains briefly, quantizes, then pushes a queue of requests through the
continuous-batching GenerationEngine and compares greedy outputs against
the FP-weight engine. The quantized engine streams tokens as they are
emitted via the per-request ``on_token`` callback (lanes interleave —
that's the slot scheduler recycling lanes mid-flight).
"""
import argparse

import numpy as np

from repro.configs import get_config, smoke_variant
from repro.launch.quantize import quantize_tree
from repro.launch.train import train
from repro.serving import GenerationEngine, Request


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--bits", type=int, default=3)
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--mode", default="continuous",
                    choices=["auto", "continuous", "wave"])
    args = ap.parse_args()

    cfg = smoke_variant(get_config(args.arch))
    params, _ = train(args.arch, steps=30, batch=8, seq=64,
                      ckpt_dir="/tmp/repro_serve_example", log_every=10)
    qparams, acct = quantize_tree(params, args.bits, gamma=0.05)
    print(f"quantized: {acct['mean_bits']:.2f} bits/weight")

    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, size=6).astype(np.int32)
               for _ in range(args.requests)]

    def stream(rid: int, tok: int) -> None:
        print(f"  [stream] req {rid} -> {tok}")

    results = {}
    for tag, p in (("fp", params), ("icq", qparams)):
        engine = GenerationEngine(p, cfg, batch_size=4, max_len=48,
                                  mode=args.mode)
        for rid, prompt in enumerate(prompts):
            engine.submit(Request(
                rid, prompt, max_new_tokens=8,
                on_token=stream if tag == "icq" else None))
        results[tag] = engine.run()
        s = engine.metrics.summary()
        print(f"{tag}: {s['tokens_per_s']:.1f} tok/s over "
              f"{int(s['steps'])} steps ({engine.mode} mode, mean "
              f"occupancy {s['mean_occupancy']:.2f})")

    agree = 0
    total = 0
    for rid in range(args.requests):
        g_fp = results["fp"][rid].generated
        g_q = results["icq"][rid].generated
        agree += sum(a == b for a, b in zip(g_fp, g_q))
        total += len(g_fp)
        print(f"req {rid}: fp={g_fp}\n        icq={g_q}")
    print(f"\ngreedy-token agreement at {args.bits} bits: {agree}/{total}")


if __name__ == "__main__":
    main()
