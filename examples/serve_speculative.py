"""Speculative decoding parity demo: spec vs plain, token for token.

    PYTHONPATH=src python examples/serve_speculative.py [--draft ngram]

One request set runs through two paged continuous engines fed identical
prompts:

  * **spec**  — ``spec_decode=True``: a drafter proposes up to
    ``--spec-k`` tokens per lane each pure-decode iteration and ONE
    verify launch (M = batch * (k+1), the large-M dequant+MXU arm)
    scores every position; the longest draft prefix matching the
    verifier's own greedy verdict is accepted, plus the verifier's
    corrected token. Rejection rewinds the lane's position and trims
    its paged tail blocks (``KVBlockPool.trim``).
  * **plain** — the same engine with speculation off, one token per
    decode launch.

Greedy acceptance makes the streams **token-identical** — speculation
changes how many launches the tokens cost, never which tokens come out.
The ledger shows the trade: verify launches replace decode launches at
a rate of one per ``accepted + 1`` tokens.
"""
import argparse

import numpy as np

from repro.configs import get_config, smoke_variant
from repro.launch.train import train
from repro.serving import GenerationEngine, Request


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--draft", default="ngram",
                    choices=["ngram", "self2bit", "tiny", "reject"])
    ap.add_argument("--spec-k", type=int, default=4)
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=16)
    args = ap.parse_args()

    cfg = smoke_variant(get_config(args.arch))
    params, _ = train(args.arch, steps=30, batch=8, seq=64,
                      ckpt_dir="/tmp/repro_serve_spec", log_every=10)

    kw = dict(batch_size=4, max_len=48, mode="continuous",
              kv_layout="paged", kv_block_size=4)
    spec = GenerationEngine(params, cfg, spec_decode=True,
                            spec_k=args.spec_k, spec_draft=args.draft, **kw)
    plain = GenerationEngine(params, cfg, **kw)

    rng = np.random.default_rng(0)
    reqs = []
    for rid in range(args.requests):
        prompt = rng.integers(0, cfg.vocab_size,
                              int(rng.integers(4, 12))).astype(np.int32)
        reqs.append((rid, prompt))
    for eng in (spec, plain):
        for rid, prompt in reqs:
            eng.submit(Request(rid, prompt.copy(),
                               max_new_tokens=args.max_new,
                               arrival_time=0.0))
    done_s = spec.run()
    done_p = plain.run()
    spec.check_shutdown_invariants()
    plain.check_shutdown_invariants()

    for rid, prompt in reqs:
        match = ("ok" if done_s[rid].generated == done_p[rid].generated
                 else "DIVERGED")
        print(f"req {rid} ({len(prompt)} prompt): "
              f"spec={done_s[rid].generated}  [{match}]")
        assert done_s[rid].generated == done_p[rid].generated, \
            f"req {rid}: spec diverged from plain decode"
    print("parity: every stream token-identical, spec vs plain")

    ss, sp = spec.metrics.summary(), plain.metrics.summary()
    hist = " ".join(f"{a}:{n}" for a, n in
                    sorted(spec.metrics.accept_hist.items()))
    print(f"\nspec ledger ({args.draft}, k={args.spec_k}): "
          f"{int(ss['verify_steps'])} verify + {int(ss['decode_steps'])} "
          f"decode + {int(ss['draft_launches'])} draft launches for "
          f"{int(ss['generated_tokens'])} tokens; "
          f"proposed {int(ss['spec_proposed'])}, accepted "
          f"{int(ss['spec_accepted'])} (mean accept len "
          f"{ss['mean_accept_len']:.2f}, hist {hist or 'none'})")
    print(f"plain ledger: {int(sp['decode_steps'])} decode launches for "
          f"{int(sp['generated_tokens'])} tokens")


if __name__ == "__main__":
    main()
