"""Multi-turn chat serving with the prefix cache: warm vs cold.

    PYTHONPATH=src python examples/serve_multiturn.py [--sessions 2]

Two chat sessions share one system prompt and run three turns each,
through two engines fed identical prompts:

  * **warm** — ``prefix_cache=True`` + ``submit(..., session=sid)``:
    turn 1 shares the system-prompt blocks across sessions through the
    hash cache; every later turn warm-starts from the session's retained
    chain (copy-on-write fork of the partial tail block) and prefills
    only the new user tokens;
  * **cold** — plain paged serving: every turn re-prefills the whole
    conversation history.

Greedy outputs are token-identical — the cache changes how many prompt
tokens get (re)computed, never what any token sees. The per-turn ledger
shows the skipped prefill work growing with the history.
"""
import argparse

import numpy as np

from repro.configs import get_config, smoke_variant
from repro.launch.train import train
from repro.serving import GenerationEngine, Request


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--sessions", type=int, default=2)
    ap.add_argument("--turns", type=int, default=3)
    ap.add_argument("--max-new", type=int, default=4)
    args = ap.parse_args()

    cfg = smoke_variant(get_config(args.arch))
    params, _ = train(args.arch, steps=30, batch=8, seq=64,
                      ckpt_dir="/tmp/repro_serve_multiturn", log_every=10)

    kw = dict(batch_size=args.sessions, max_len=64, mode="continuous",
              kv_layout="paged", kv_block_size=4, prefill_chunk=8)
    warm = GenerationEngine(params, cfg, prefix_cache=True, **kw)
    cold = GenerationEngine(params, cfg, prefix_cache=False, **kw)

    rng = np.random.default_rng(0)
    system = rng.integers(0, cfg.vocab_size, 12).astype(np.int32)
    history = {sid: system.copy() for sid in range(args.sessions)}
    print(f"system prompt: {system.tolist()}")

    rid = 0
    for turn in range(args.turns):
        reqs = []
        for sid in range(args.sessions):
            user = rng.integers(0, cfg.vocab_size,
                                int(rng.integers(4, 9))).astype(np.int32)
            prompt = np.concatenate([history[sid], user])
            reqs.append((rid, sid, prompt))
            rid += 1
        skipped_before = warm.metrics.prefix_tokens_skipped
        for r, sid, prompt in reqs:
            warm.submit(Request(r, prompt.copy(),
                                max_new_tokens=args.max_new,
                                arrival_time=warm.now()),
                        session=f"chat-{sid}")
        done_w = warm.run()
        for r, sid, prompt in reqs:
            cold.submit(Request(r, prompt.copy(),
                                max_new_tokens=args.max_new,
                                arrival_time=cold.now()))
        done_c = cold.run()
        skipped = warm.metrics.prefix_tokens_skipped - skipped_before
        print(f"\nturn {turn}: {skipped} prompt tokens never re-prefilled")
        for r, sid, prompt in reqs:
            match = "ok" if done_w[r].generated == done_c[r].generated \
                else "DIVERGED"
            print(f"  chat-{sid} ({len(prompt)} ctx): "
                  f"warm={done_w[r].generated} "
                  f"cold={done_c[r].generated}  [{match}]")
            assert done_w[r].generated == done_c[r].generated
            history[sid] = np.concatenate(
                [prompt, np.asarray(done_w[r].generated, np.int32)])

    s = warm.metrics.summary()
    print(f"\nwarm ledger: hit rate {s['prefix_hit_rate']:.2f}, "
          f"{int(s['prefix_tokens_skipped'])} prefill tokens skipped, "
          f"{int(s['cow_forks'])} cow forks, "
          f"{int(s['session_hits'])} session warm starts; "
          f"cold prefilled {int(cold.metrics.summary()['prefill_tokens'])} "
          f"tokens vs warm {int(s['prefill_tokens'])}")


if __name__ == "__main__":
    main()
