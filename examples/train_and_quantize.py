"""End-to-end driver: train a ~100M-param LM for a few hundred steps on
the synthetic corpus, checkpoint it, then post-training-quantize with
ICQuant^RTN and ICQuant^SK and report held-out NLL at each bit width.

    PYTHONPATH=src python examples/train_and_quantize.py \
        [--steps 300] [--width small|100m]

``--width 100m`` uses a ~100M-parameter config (slow on CPU but the real
deal); default 'small' finishes in minutes.
"""
import argparse
import dataclasses

import jax
import jax.numpy as jnp

from repro.configs import get_config, smoke_variant
from repro.data import SyntheticLM
from repro.launch.quantize import compute_fisher, quantize_tree
from repro.launch.steps import loss_fn
from repro.launch.train import train
from repro.models import count_params


def heldout_nll(params, cfg, seq=64, batches=4):
    data = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=seq, seed=0)
    tot = 0.0
    for i in range(batches):
        b = data.batch(step=90_000 + i, shard=1, batch_size=8)
        loss, _ = loss_fn(params, cfg, {k: jnp.asarray(v) for k, v in b.items()})
        tot += float(loss)
    return tot / batches


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--width", choices=["small", "100m"], default="small")
    args = ap.parse_args()

    cfg = smoke_variant(get_config(args.arch))
    if args.width == "100m":
        cfg = dataclasses.replace(
            cfg, n_layers=8, d_model=640, n_heads=10, n_kv_heads=5,
            head_dim=64, d_ff=2560, vocab_size=32064,
        )
        # monkeypatch-free: train() re-derives the smoke config, so for the
        # 100m width we drive the loop inline
        from repro.launch.steps import init_opt_state, make_train_step
        from repro.models import init_model
        from repro.optim import AdamWConfig

        params = init_model(jax.random.PRNGKey(0), cfg)
        print(f"params: {count_params(params)/1e6:.1f}M")
        opt_cfg = AdamWConfig(lr=3e-4, total_steps=args.steps,
                              warmup_steps=20)
        opt = init_opt_state(params, opt_cfg)
        step = jax.jit(make_train_step(cfg, opt_cfg), donate_argnums=(0, 1))
        data = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=128, seed=0)
        for s in range(args.steps):
            b = data.batch(s, 0, 8)
            params, opt, m = step(params, opt,
                                  {k: jnp.asarray(v) for k, v in b.items()})
            if s % 20 == 0:
                print(f"step {s} loss {float(m['loss']):.4f}")
    else:
        params, _ = train(args.arch, steps=args.steps, batch=8, seq=64,
                          ckpt_dir="/tmp/repro_example_ckpt", log_every=25)

    nll_fp = heldout_nll(params, cfg)
    print(f"\nFP32 held-out NLL: {nll_fp:.4f}")
    fisher = compute_fisher(params, cfg, n_sequences=32, seq_len=64)

    print(f"{'bits':>6} {'ICQuant_RTN':>12} {'ICQuant_SK':>12} {'vanillaRTN':>12}")
    for n_bits in (4, 3, 2):
        qr, _ = quantize_tree(params, n_bits, gamma=0.05)
        qs, _ = quantize_tree(params, n_bits, gamma=0.05, method="kmeans",
                              fisher=fisher)
        qv, _ = quantize_tree(params, n_bits, gamma=1e-9)
        print(f"{n_bits:>6} {heldout_nll(qr, cfg):>12.4f} "
              f"{heldout_nll(qs, cfg):>12.4f} {heldout_nll(qv, cfg):>12.4f}")


if __name__ == "__main__":
    main()
