"""Reproduce the paper's Section 2 statistics (Figures 1-2, Table 1).

    PYTHONPATH=src python examples/outlier_statistics.py
"""
import numpy as np

from benchmarks.common import LLAMA2_7B_LAYERS, layer_weights
from repro.core import lemma1_bound, optimal_b
from repro.core.stats import (
    chi_square_uniformity,
    empirical_index_overhead,
    range_taken_by_outliers,
)

print("== range taken by top-gamma outliers (Fig 1a) ==")
print(f"{'layer':<12}" + "".join(f"{g:>8.0%}" for g in (0.01, 0.05, 0.10)))
for name in LLAMA2_7B_LAYERS:
    W = layer_weights(name)
    fr = range_taken_by_outliers(W, (0.01, 0.05, 0.10))
    print(f"{name:<12}" + "".join(f"{fr[g]:>8.2f}" for g in (0.01, 0.05, 0.10)))

print("\n== chi-square uniformity rejection @0.05 (Table 1) ==")
for name in LLAMA2_7B_LAYERS:
    rej = chi_square_uniformity(layer_weights(name), gamma=0.0625)
    print(f"{name:<12}{rej:>8.2%}")

print("\n== index-coding overhead B(b) at gamma=5% (Fig 4) ==")
W = layer_weights("q_proj")
print(f"{'b':>3}{'Lemma1':>10}{'empirical':>11}")
for b in range(3, 11):
    print(f"{b:>3}{lemma1_bound(0.05, b):>10.4f}"
          f"{empirical_index_overhead(W, 0.05, b):>11.4f}")
print(f"optimal b = {optimal_b(0.05)} "
      f"(paper: b=6, B~0.31 bits/weight)")
