"""Quickstart: ICQuant a weight matrix and use it.

    PYTHONPATH=src python examples/quickstart.py

Shows the whole codec surface in ~40 lines: partition -> index-code ->
quantize -> pack -> (kernel) matmul, with bits/weight accounting.
"""
import numpy as np
import jax.numpy as jnp

from repro import core
from repro.core.stats import heavy_tailed_weights
from repro.kernels import ops

# 1. a heavy-tailed weight matrix (statistically like an LLM layer)
W = heavy_tailed_weights(rows=256, cols=4096, seed=0)

# 2. ICQuant at 2 bits, 5% outliers (the paper's headline setting)
packed = core.quantize(jnp.asarray(W), n_bits=2, gamma=0.05)
bits = packed.bits_per_weight()
print(f"storage: {bits['total']:.3f} bits/weight "
      f"(codes {bits['code']:.2f} + index {bits['index']:.3f} "
      f"+ codebooks {bits['codebook']:.3f})")
print(f"Lemma-1 bound for the index stream: "
      f"{core.lemma1_bound(0.05, packed.b):.3f} bits/weight (b={packed.b})")

# 3. reconstruction error vs vanilla RTN at the same and +1 bits
from repro.quant import vanilla_rtn

W_hat = np.asarray(core.dequantize(packed))
mse_icq = float(((W - W_hat) ** 2).mean())
for n in (2, 3):
    Wv, _ = vanilla_rtn(W, n)
    print(f"MSE vanilla RTN {n}-bit: {float(((W - np.asarray(Wv))**2).mean()):.3e}")
print(f"MSE ICQuant 2-bit:     {mse_icq:.3e}  <- ~RTN-3bit quality at ~2.4 bits")

# 4. serve from the packed format through the fused Pallas kernel
rt = ops.to_runtime(packed)
x = jnp.asarray(np.random.default_rng(1).standard_normal((8, 4096)), jnp.float32)
y = ops.matmul(x, rt)            # interpret auto: compiled on TPU, else interp
y_ref = x @ jnp.asarray(W_hat).T
print(f"kernel vs reference max err: {float(abs(y - y_ref).max()):.2e}")

# 5. ...or the way the serving engine does it: prepare once (pad/block at
#    load time), then every model matmul dispatches per-call between the
#    fused kernel, dequant+MXU matmul, and the pure-XLA arm. The default
#    v2 runtime serves the checkpointed gap stream directly (~0.3-0.45
#    b/w outlier overhead); fmt="v1" expands the dense 1-bit bitmap
#    (~1 b/w) the kernels decode for free.
prep = ops.prepare(packed)                    # fmt='v2' by default
y2 = ops.linear_apply(x, prep)
prep_v1 = ops.prepare(packed, fmt="v1")
print(f"dispatch [{prep.backend}/{prep.fmt}] vs reference max err: "
      f"{float(abs(y2 - y_ref).max()):.2e}; "
      f"runtime HBM: v2 {prep.bits_per_weight():.2f} vs "
      f"v1 {prep_v1.bits_per_weight():.2f} bits/weight "
      f"(outlier overhead {prep.outlier_bits_per_weight():.2f} vs "
      f"{prep_v1.outlier_bits_per_weight():.2f})")
