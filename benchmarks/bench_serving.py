"""Serving-subsystem benchmark: continuous batching vs the legacy wave
engine under a Poisson arrival trace with mixed prompt/generation lengths.

One workload (requests, arrival times, prompt lengths, token budgets) is
replayed through both engine modes for each weight configuration —
``weight_cache='prepared'`` at runtime format v1 and v2, plus the
dequant-once ``'dense'`` cache. The wave engine idles finished lanes
until the slowest lane of each wave drains; the continuous engine
recycles a lane the step it finishes, so under mixed lengths it takes
fewer steps for the same tokens and aggregate tokens/s rises. Greedy
parity (continuous == wave token streams) is asserted per config.

Structured result lands in BENCH_serving.json via ``benchmarks/run.py``.
"""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import emit
from repro.configs import get_config, smoke_variant
from repro.launch.quantize import quantize_tree
from repro.models import init_model
from repro.serving import GenerationEngine, Request

ARCH = "llama3.2-1b"
BATCH = 4
MAX_LEN = 64
N_REQUESTS = 16
# Offered load must exceed service rate for continuous batching to have
# anything to win (a drained queue idles both engines equally): 200 Hz
# puts every arrival inside the first few decode steps on this host.
POISSON_RATE_HZ = 200.0
BITS = 3


def _workload(cfg, seed: int = 0):
    """Poisson arrivals, mixed prompt lengths (2-12) and budgets (2-32).

    The wide budget spread is the point: it is what makes the wave
    engine idle short lanes behind the longest lane of each wave (and
    what real traffic looks like). Sized so the step-count gap between
    the engines dwarfs per-step wall-clock noise on a shared host.
    """
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(1.0 / POISSON_RATE_HZ, N_REQUESTS))
    specs = []
    for rid in range(N_REQUESTS):
        n_prompt = int(rng.integers(2, 13))
        specs.append(dict(
            rid=rid,
            prompt=rng.integers(0, cfg.vocab_size, n_prompt).astype(np.int32),
            max_new_tokens=int(rng.integers(2, 33)),
            arrival_time=float(arrivals[rid]),
        ))
    return specs


def _run_engine(params, cfg, mode, weight_cache, fmt, specs):
    engine = GenerationEngine(
        params, cfg, batch_size=BATCH, max_len=MAX_LEN,
        weight_cache=weight_cache, runtime_fmt=fmt, mode=mode,
    )
    for s in specs:   # fresh Request objects: generated streams are mutable
        engine.submit(Request(**s))
    done = engine.run()
    summary = engine.metrics.summary()
    tokens = {rid: r.generated for rid, r in done.items()}
    return tokens, summary


def run() -> dict:
    cfg = smoke_variant(get_config(ARCH))
    params = init_model(jax.random.PRNGKey(0), cfg)
    qparams, acct = quantize_tree(params, BITS, gamma=0.05)
    specs = _workload(cfg)

    out = dict(
        arch=ARCH, batch=BATCH, max_len=MAX_LEN, requests=N_REQUESTS,
        poisson_rate_hz=POISSON_RATE_HZ, bits=BITS,
        mean_bits=round(acct["mean_bits"], 3),
        by_config={},
    )
    configs = (
        ("prepared_v1", qparams, "prepared", "v1"),
        ("prepared_v2", qparams, "prepared", "v2"),
        ("dense", qparams, "dense", None),
    )
    for tag, p, wc, fmt in configs:
        row = {}
        tokens = {}
        for mode in ("wave", "continuous"):
            tokens[mode], summary = _run_engine(p, cfg, mode, wc, fmt, specs)
            row[mode] = {
                k: (round(v, 4) if v == v else None)  # NaN -> null
                for k, v in summary.items()
            }
        row["speedup_tokens_per_s"] = round(
            row["continuous"]["tokens_per_s"] / row["wave"]["tokens_per_s"], 3)
        row["greedy_parity"] = tokens["continuous"] == tokens["wave"]
        if not row["greedy_parity"]:   # a speedup over diverging token
            raise AssertionError(      # streams is not a speedup
                f"{tag}: continuous vs wave greedy token streams diverge")
        out["by_config"][tag] = row
        emit(
            f"serving/{tag}_continuous",
            row["continuous"]["wall_s"] * 1e6,
            f"tok_s={row['continuous']['tokens_per_s']};"
            f"wave_tok_s={row['wave']['tokens_per_s']};"
            f"speedup={row['speedup_tokens_per_s']}x;"
            f"parity={row['greedy_parity']};"
            f"occupancy={row['continuous']['mean_occupancy']}"
            f"vs{row['wave']['mean_occupancy']}",
        )
    return out


if __name__ == "__main__":
    import json

    print(json.dumps(run(), indent=1))
