"""Serving-subsystem benchmark: continuous batching vs the legacy wave
engine under a Poisson arrival trace with mixed prompt/generation lengths.

One workload (requests, arrival times, prompt lengths, token budgets) is
replayed through both engine modes for each weight configuration —
``weight_cache='prepared'`` at runtime format v1 and v2, plus the
dequant-once ``'dense'`` cache. The wave engine idles finished lanes
until the slowest lane of each wave drains; the continuous engine
recycles a lane the step it finishes, so under mixed lengths it takes
fewer steps for the same tokens and aggregate tokens/s rises. Greedy
parity (continuous == wave token streams) is asserted per config.

A second **long-prompt trace** (prompts 64-256 tokens) replays the same
requests through the continuous engine at ``prefill_chunk=1`` (walk
every prompt token through the decode program, the pre-chunking
behavior) and ``prefill_chunk=PREFILL_CHUNK`` (drain prompt bulk
S-at-a-time through the chunk program / large-M kernel arm), asserting
greedy parity between both and against the wave engine, and reporting
the TTFT p50/p95 and aggregate tokens/s deltas chunking buys.

A third **paged-KV trace** (skewed lengths: a few long requests among
many short ones) replays one workload through ``kv_layout='contiguous'``
and ``kv_layout='paged'`` with the block pool sized *below* contiguous
capacity. It asserts greedy parity (preempt-and-requeue recomputes
identical streams), a strictly smaller cache footprint, sustained lane
occupancy, and that pool pressure actually exercised preemption —
reporting cache bytes, block utilization, preemption count and tokens/s
for both layouts. The paged workload additionally replays with the
fused mixed prefill/decode step disabled (``fused_step=False``, the
split chunk+decode structure), asserting greedy parity fused-vs-split,
strictly fewer device launches with fusion, and that the decode
attention bytes-read estimate shows the paged arm streaming strictly
fewer live-block bytes than the logical full-table span.

A fifth **multi-turn trace** (shared system prompt + 3-turn chats)
replays identical per-turn prompts through a warm engine
(``prefix_cache=True`` + sessions) and a cold one (plain paged prefill),
asserting per-turn greedy parity, that the prefix cache actually hit
(hit rate > 0, prefill tokens skipped > 0), and that warm turn-2+ TTFT
p50 improves by at least 2x — reporting the TTFT delta, tokens skipped
and the pool's cache-HBM ratio vs contiguous capacity.

A **speculative-decoding trace** (short prompts, big budgets: pure
decode-bound) replays one workload through the plain paged engine and
the speculative one (``spec_decode=True``, ngram drafter) plus the
adversarial always-wrong ``reject`` drafter. Token parity is asserted
for both spec runs **before** any speedup is reported; the row then
reports the decode tokens/s speedup (≥ 1.2x asserted), mean accepted
length, launch reduction and the reject worst case.

A fourth **fault-storm trace** replays the skewed workload through the
paged engine under a deterministic fault plan (NaN logits, a raised
launch, and an allocator-exhaustion drill) plus one request with
``max_queue_wait_s=0`` (deterministically ``expired``) and one cancelled
mid-decode from its own ``on_token`` callback. It asserts the
fault-tolerance contract: every submitted request terminates with a
typed status, every ``ok`` request's greedy stream is token-identical to
a no-fault run of the same workload, recovery actually engaged
(``degraded_steps >= 1``) and the engine shuts down with its pool and
scheduler invariants intact.

A sixth **replica-failover trace** runs the service layer itself: two
supervised ``EngineReplica`` workers behind a ``ReplicaRouter`` with a
WAL attached, one replica hard-killed mid-decode by a token-stream
chaos trigger. It asserts the service contract: every request
terminates exactly once with a typed status, failover actually engaged
(``failovers >= 1``, ``replica_restarts >= 1``,
``duplicate_terminals == 0``), every surviving greedy stream is
token-identical to a single-engine no-failure run, and the reopened
journal shows no pending requests.

Structured result lands in BENCH_serving.json via ``benchmarks/run.py``.
"""
from __future__ import annotations

import os
import tempfile
import time

import jax
import numpy as np

from benchmarks.common import emit
from repro.configs import get_config, smoke_variant
from repro.launch.quantize import quantize_tree
from repro.models import init_model
from repro.serving import (EngineReplica, GenerationEngine, ReplicaRouter,
                           Request, RequestWAL, ServiceMetrics)
from repro.serving.faults import FaultInjector, parse_fault_plan
from repro.serving.scheduler import STATUSES

ARCH = "llama3.2-1b"
BATCH = 4
MAX_LEN = 64
N_REQUESTS = 16
# Offered load must exceed service rate for continuous batching to have
# anything to win (a drained queue idles both engines equally): 200 Hz
# puts every arrival inside the first few decode steps on this host.
POISSON_RATE_HZ = 200.0
BITS = 3

# long-prompt trace: prompts of 64-256 tokens, where prefill dominates
# and the 1-token-per-step walk is the bottleneck chunking removes
PREFILL_CHUNK = 32
LONG_N_REQUESTS = 6
LONG_MAX_LEN = 288
LONG_MAX_NEW = (2, 9)
LONG_PROMPT = (64, 257)
# long-prompt chunking is benched on the configs where it matters most:
# prepared_v2 pays the per-launch XLA-arm overhead, so amortizing S
# tokens per launch is the headline win; dense is the
# weight-bandwidth-free control.
LONG_CONFIGS = ("prepared_v2", "dense")

# paged-KV trace: skewed lengths (a few long requests among many short
# ones) — the regime where reserving max_len contiguous rows for every
# lane wastes the most cache HBM. The paged pool is sized *below*
# contiguous capacity (PAGED_BLOCKS * PAGED_BLOCK_SIZE rows vs
# BATCH * PAGED_MAX_LEN), so the benchmark demonstrates the headline
# property: same lane occupancy and identical greedy streams at a
# strictly smaller cache footprint, with pool pressure absorbed by
# preempt-and-requeue instead of rejected admissions.
PAGED_BLOCK_SIZE = 8
PAGED_MAX_LEN = 96
PAGED_BLOCKS = 30          # 240 pooled rows < 4 * 96 = 384 contiguous
PAGED_N_REQUESTS = 12
PAGED_LONG_RIDS = (1, 3, 5)     # three long requests among the shorts
PAGED_PROMPT_LONG = (40, 57)
PAGED_NEW_LONG = (24, 33)
PAGED_PROMPT_SHORT = (2, 9)
PAGED_NEW_SHORT = (2, 9)
PAGED_PREFILL_CHUNK = 8         # exercise the paged chunk-write path
PAGED_CONFIGS = ("prepared_v2", "dense")

# multi-turn trace: MT_SESSIONS concurrent chats sharing one system
# prompt, MT_TURNS turns each. The warm engine retains each finished
# turn's chain under its session id (plus the hash cache for the
# cross-session system prompt), so turn 2+ only prefills the new user
# tokens; the cold engine re-prefills the whole history every turn.
# The pool is sized below contiguous capacity so the cache-HBM ratio
# is a real saving, with headroom for the retained session chains.
MT_SESSIONS = 3
MT_TURNS = 3
MT_SHARED = 32                  # shared system-prompt tokens: sized so
                                # cold re-pays several whole chunk
                                # launches per turn that warm skips —
                                # the 2x TTFT assertion must clear even
                                # on a noisy 2-core CI runner
MT_USER = (4, 9)                # fresh user tokens per turn
MT_MAX_NEW = (4, 7)
MT_MAX_LEN = 96
MT_BLOCK_SIZE = 4
MT_BLOCKS = 60                  # 240 pooled rows < 3 * 96 = 288 contiguous

# fault-storm trace: the skewed paged workload with one of each fault
# kind injected at fixed launch indices (all comfortably below the
# trace's launch count, so the whole plan fires), one request that can
# never be admitted in time, and one cancelled from its token stream.
FAULT_STORM_PLAN = "3:nan,7:raise,15:alloc"
FAULT_CANCEL_RID = 3            # a long request: cancelled mid-decode
FAULT_CANCEL_AFTER = 3          # ...after it has streamed this many tokens

# speculative-decoding trace: short prompts, big budgets — the pure
# decode-bound regime speculation targets. The weights are a briefly
# TRAINED checkpoint (not random init): speculation's payoff is
# acceptance, and acceptance needs a model whose greedy continuations
# are predictable — the serving regime (a converged LM on real text),
# not the wandering streams of random weights. One workload replays
# through the plain paged engine and the speculative one (ngram
# drafter: zero draft launches, so the speedup is purely
# verify-for-decode launch substitution); token parity is asserted
# BEFORE any speedup is reported. A third run with the adversarial
# always-wrong drafter pins the worst case: pure rejection overhead,
# parity still exact.
SPEC_TRAIN_STEPS = 120
SPEC_K = 4
SPEC_DRAFT = "ngram"
SPEC_N_REQUESTS = 8
SPEC_PROMPT = (2, 7)
SPEC_MAX_NEW = 40
SPEC_MAX_LEN = 64
SPEC_BLOCK_SIZE = 8
SPEC_BLOCKS = BATCH * SPEC_MAX_LEN // SPEC_BLOCK_SIZE
SPEC_MIN_SPEEDUP = 1.2

# replica-failover trace: the service layer (router + supervised
# replica workers + WAL) with one replica hard-killed mid-decode. Small
# on purpose — every replica engine (and each restart) pays a fresh
# jit compile, so the trace demonstrates the failover contract rather
# than throughput.
FAILOVER_REPLICAS = 2
FAILOVER_BATCH = 2
FAILOVER_N_REQUESTS = 6
FAILOVER_MAX_NEW = 6
FAILOVER_KILL_AFTER = 5         # streamed tokens before r0 is killed


def _workload(cfg, seed: int = 0):
    """Poisson arrivals, mixed prompt lengths (2-12) and budgets (2-32).

    The wide budget spread is the point: it is what makes the wave
    engine idle short lanes behind the longest lane of each wave (and
    what real traffic looks like). Sized so the step-count gap between
    the engines dwarfs per-step wall-clock noise on a shared host.
    """
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(1.0 / POISSON_RATE_HZ, N_REQUESTS))
    specs = []
    for rid in range(N_REQUESTS):
        n_prompt = int(rng.integers(2, 13))
        specs.append(dict(
            rid=rid,
            prompt=rng.integers(0, cfg.vocab_size, n_prompt).astype(np.int32),
            max_new_tokens=int(rng.integers(2, 33)),
            arrival_time=float(arrivals[rid]),
        ))
    return specs


def _long_workload(cfg, seed: int = 1):
    """Poisson arrivals, long prompts (64-256), small budgets: TTFT is
    dominated by the prompt walk, the regime chunked prefill targets."""
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(
        rng.exponential(1.0 / POISSON_RATE_HZ, LONG_N_REQUESTS))
    return [dict(
        rid=rid,
        prompt=rng.integers(
            0, cfg.vocab_size, int(rng.integers(*LONG_PROMPT))
        ).astype(np.int32),
        max_new_tokens=int(rng.integers(*LONG_MAX_NEW)),
        arrival_time=float(arrivals[rid]),
    ) for rid in range(LONG_N_REQUESTS)]


def _skewed_workload(cfg, seed: int = 2):
    """Poisson arrivals, skewed lengths: a few long prompts with big
    budgets among many short ones — what makes per-lane max_len rows
    wasteful and a shared block pool dense."""
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(
        rng.exponential(1.0 / POISSON_RATE_HZ, PAGED_N_REQUESTS))
    specs = []
    for rid in range(PAGED_N_REQUESTS):
        long = rid in PAGED_LONG_RIDS
        p_lo, p_hi = PAGED_PROMPT_LONG if long else PAGED_PROMPT_SHORT
        n_lo, n_hi = PAGED_NEW_LONG if long else PAGED_NEW_SHORT
        specs.append(dict(
            rid=rid,
            prompt=rng.integers(
                0, cfg.vocab_size, int(rng.integers(p_lo, p_hi))
            ).astype(np.int32),
            max_new_tokens=int(rng.integers(n_lo, n_hi)),
            arrival_time=float(arrivals[rid]),
        ))
    return specs


def _run_engine(params, cfg, mode, weight_cache, fmt, specs,
                max_len=MAX_LEN, prefill_chunk=1, **engine_kw):
    engine = GenerationEngine(
        params, cfg, batch_size=BATCH, max_len=max_len,
        weight_cache=weight_cache, runtime_fmt=fmt, mode=mode,
        prefill_chunk=prefill_chunk, **engine_kw,
    )
    for s in specs:   # fresh Request objects: generated streams are mutable
        engine.submit(Request(**s))
    done = engine.run()
    summary = engine.metrics.summary()
    tokens = {rid: r.generated for rid, r in done.items()}
    return tokens, summary


def _run_fault_storm(params, cfg) -> dict:
    """No-fault baseline, then the storm: same workload + fault plan +
    an expired request + a mid-decode cancellation. Returns the bench
    row; raises AssertionError if the fault-tolerance contract breaks."""
    engine_kw = dict(
        batch_size=BATCH, max_len=PAGED_MAX_LEN, weight_cache="prepared",
        runtime_fmt="v2", mode="continuous",
        prefill_chunk=PAGED_PREFILL_CHUNK, kv_layout="paged",
        kv_block_size=PAGED_BLOCK_SIZE, kv_blocks=PAGED_BLOCKS,
    )
    specs = _skewed_workload(cfg)

    base_eng = GenerationEngine(params, cfg, **engine_kw)
    for s in specs:
        base_eng.submit(Request(**s))
    base = base_eng.run()
    base_eng.check_shutdown_invariants()
    base_tokens = {rid: r.generated for rid, r in base.items()}

    eng = GenerationEngine(
        params, cfg,
        faults=FaultInjector(parse_fault_plan(FAULT_STORM_PLAN)),
        **engine_kw)
    streamed = {"n": 0}

    def cancel_mid(rid, tok):
        streamed["n"] += 1
        if streamed["n"] == FAULT_CANCEL_AFTER:
            eng.cancel(rid)

    expired_rid = PAGED_N_REQUESTS
    last_arrival = specs[-1]["arrival_time"]
    for s in specs:
        kw = dict(s)
        if kw["rid"] == FAULT_CANCEL_RID:
            kw["on_token"] = cancel_mid
        eng.submit(Request(**kw))
    eng.submit(Request(
        expired_rid,
        np.arange(4, dtype=np.int32) % cfg.vocab_size,
        max_new_tokens=4, arrival_time=last_arrival,
        max_queue_wait_s=0.0))
    done = eng.run()
    eng.check_shutdown_invariants()
    summary = eng.metrics.summary()

    all_rids = {s["rid"] for s in specs} | {expired_rid}
    if set(done) != all_rids:
        raise AssertionError(
            f"fault_storm: requests lost ({sorted(all_rids - set(done))}) "
            f"or invented ({sorted(set(done) - all_rids)})")
    for rid, r in done.items():
        if r.status not in STATUSES:
            raise AssertionError(
                f"fault_storm: req {rid} ended without a typed status "
                f"({r.status!r})")
    if done[expired_rid].status != "expired":
        raise AssertionError(
            f"fault_storm: max_queue_wait_s=0 request ended "
            f"{done[expired_rid].status!r}, expected 'expired'")
    if done[FAULT_CANCEL_RID].status != "cancelled":
        raise AssertionError(
            f"fault_storm: cancelled request ended "
            f"{done[FAULT_CANCEL_RID].status!r}, expected 'cancelled'")
    # survivors must be bit-identical to the no-fault run: recovery that
    # changes tokens is corruption with extra steps
    mismatched = [
        rid for rid, r in done.items()
        if r.status == "ok" and r.generated != base_tokens[rid]
    ]
    if mismatched:
        raise AssertionError(
            f"fault_storm: ok-status streams diverged from the no-fault "
            f"run for rids {mismatched}")
    if summary["degraded_steps"] < 1:
        raise AssertionError(
            "fault_storm: recovery never engaged the degraded XLA arm")
    if eng.faults.pending:
        raise AssertionError(
            f"fault_storm: plan faults never drawn: {eng.faults.pending}")

    row = {k: (round(v, 4) if v == v else None) for k, v in summary.items()}
    row["status_counts"] = eng.metrics.status_counts()
    row["fault_kinds"] = dict(eng.metrics.faults)
    row["ok_parity"] = True
    return row


def _run_replica_failover(params, cfg) -> dict:
    """Single-engine no-failure baseline, then the same workload through
    two supervised replicas with r0 hard-killed mid-decode. Returns the
    bench row; raises AssertionError if the service contract breaks."""
    rng = np.random.default_rng(5)
    specs = [dict(
        rid=rid,
        prompt=rng.integers(
            0, cfg.vocab_size, int(rng.integers(4, 9))).astype(np.int32),
        max_new_tokens=FAILOVER_MAX_NEW,
    ) for rid in range(FAILOVER_N_REQUESTS)]

    def factory():
        return GenerationEngine(
            params, cfg, batch_size=FAILOVER_BATCH, max_len=MAX_LEN,
            weight_cache="prepared", runtime_fmt="v2", mode="continuous")

    base_eng = factory()
    for s in specs:
        base_eng.submit(Request(arrival_time=0.0, **s))
    base = base_eng.run()
    base_eng.check_shutdown_invariants()
    base_tokens = {rid: r.generated for rid, r in base.items()}

    metrics = ServiceMetrics()
    wal_path = os.path.join(
        tempfile.mkdtemp(prefix="icq-bench-wal-"), "requests.wal")
    wal = RequestWAL(wal_path)
    replicas = [EngineReplica(f"r{i}", factory, heartbeat_s=0.05)
                for i in range(FAILOVER_REPLICAS)]
    router = ReplicaRouter(replicas, wal=wal, metrics=metrics)
    chaos = {"streamed": 0, "killed": False}

    def kill_mid_decode(rid, tok):
        chaos["streamed"] += 1
        if chaos["streamed"] == FAILOVER_KILL_AFTER and not chaos["killed"]:
            chaos["killed"] = True
            router.kill("r0")

    router.token_observer = kill_mid_decode
    t0 = time.perf_counter()
    router.start()
    for s in specs:
        router.submit(Request(arrival_time=0.0, **s))
    give_up = time.monotonic() + 600.0
    while router.pending and time.monotonic() < give_up:
        router.supervise()
        time.sleep(0.02)
    router.supervise()
    wall = time.perf_counter() - t0
    done = router.results()
    router.stop()
    router.check_shutdown_invariants()
    wal.close()

    all_rids = {s["rid"] for s in specs}
    if set(done) != all_rids:
        raise AssertionError(
            f"replica_failover: requests lost "
            f"({sorted(all_rids - set(done))}) or invented "
            f"({sorted(set(done) - all_rids)})")
    if not chaos["killed"]:
        raise AssertionError(
            "replica_failover: chaos trigger never fired — the trace "
            "is not exercising the kill path")
    bad = {rid: st for rid, (st, _) in done.items() if st != "ok"}
    if bad:
        raise AssertionError(
            f"replica_failover: non-ok terminal statuses {bad}")
    # failover replays must continue the greedy streams token-exactly:
    # fold-into-prompt recovery that changes tokens is corruption
    mismatched = [rid for rid, (st, toks) in done.items()
                  if toks != base_tokens[rid]]
    if mismatched:
        raise AssertionError(
            f"replica_failover: ok-status streams diverged from the "
            f"no-failure run for rids {mismatched}")
    if metrics.failovers < 1 or metrics.replica_restarts < 1:
        raise AssertionError(
            f"replica_failover: kill did not engage recovery "
            f"(failovers={metrics.failovers}, "
            f"restarts={metrics.replica_restarts})")
    if metrics.duplicate_terminals:
        raise AssertionError(
            f"replica_failover: {metrics.duplicate_terminals} duplicate "
            f"terminal(s) — exactly-once broken")
    reopened = RequestWAL(wal_path)
    wal_pending_after = len(reopened.pending)
    wal_completed = len(reopened.completed)
    reopened.close()
    if wal_pending_after:
        raise AssertionError(
            f"replica_failover: reopened WAL still has "
            f"{wal_pending_after} pending request(s)")
    if set(reopened.completed) != all_rids:
        raise AssertionError(
            "replica_failover: WAL terminal records do not cover the "
            "workload")

    row = {k: (round(v, 4) if v == v else None)
           for k, v in metrics.summary().items()}
    row.update(
        wall_s=round(wall, 4), requests=FAILOVER_N_REQUESTS,
        replicas=FAILOVER_REPLICAS, kill_after=FAILOVER_KILL_AFTER,
        status_counts=dict(metrics.status_counts),
        ok_parity=True, wal_pending_after=wal_pending_after,
        wal_completed=wal_completed,
    )
    return row


def _run_spec_decode(cfg) -> dict:
    """Plain vs speculative paged serving on one decode-bound workload.
    Token parity is asserted before any number is reported — a speedup
    over diverging streams is not a speedup. Returns the bench row;
    raises AssertionError on parity loss or a sub-threshold speedup."""
    from repro.launch.train import train

    tparams, _ = train(ARCH, steps=SPEC_TRAIN_STEPS, batch=8, seq=64,
                       ckpt_dir=tempfile.mkdtemp(prefix="icq-bench-spec-"),
                       log_every=SPEC_TRAIN_STEPS)
    params, _ = quantize_tree(tparams, BITS, gamma=0.05)
    rng = np.random.default_rng(11)
    specs = [dict(
        rid=rid,
        prompt=rng.integers(
            0, cfg.vocab_size, int(rng.integers(*SPEC_PROMPT))
        ).astype(np.int32),
        max_new_tokens=SPEC_MAX_NEW,
        arrival_time=0.0,
    ) for rid in range(SPEC_N_REQUESTS)]
    engine_kw = dict(
        batch_size=BATCH, max_len=SPEC_MAX_LEN, weight_cache="prepared",
        runtime_fmt="v2", mode="continuous", kv_layout="paged",
        kv_block_size=SPEC_BLOCK_SIZE, kv_blocks=SPEC_BLOCKS,
    )

    def one(label, **extra):
        # jit caches are per-engine (each engine closes over its own
        # step programs), so steady state is measured by a warm-up run
        # of the SAME workload through the SAME engine first — the
        # measured pass then pays launches, not compiles
        eng = GenerationEngine(params, cfg, **engine_kw, **extra)
        for s in specs:
            eng.submit(Request(**s))
        eng.run()
        before = eng.metrics.summary()
        for s in specs:
            eng.submit(Request(rid=s["rid"] + 100, prompt=s["prompt"].copy(),
                               max_new_tokens=s["max_new_tokens"],
                               arrival_time=0.0))
        t0 = time.perf_counter()
        done = eng.run()
        wall = time.perf_counter() - t0
        eng.check_shutdown_invariants()
        tokens = {rid - 100: r.generated for rid, r in done.items()
                  if rid >= 100}
        summary = eng.metrics.summary()
        n_tok = sum(len(g) for g in tokens.values())
        summary["wall_s"] = wall
        summary["tokens_per_s"] = n_tok / wall
        summary["launches"] -= before["launches"]
        return tokens, summary

    tok_p, sum_p = one("plain")
    tok_s, sum_s = one("spec", spec_decode=True, spec_k=SPEC_K,
                       spec_draft=SPEC_DRAFT)
    tok_r, sum_r = one("reject", spec_decode=True, spec_k=SPEC_K,
                       spec_draft="reject")

    # parity gate: no speedup is reported unless every stream matches
    if tok_s != tok_p:
        raise AssertionError(
            "spec_decode: speculative streams diverged from plain decode "
            f"on rids {[r for r in tok_p if tok_s.get(r) != tok_p[r]]}")
    if tok_r != tok_p:
        raise AssertionError(
            "spec_decode: reject-drafter streams diverged from plain "
            "decode — the rejection/rollback path corrupts state")

    speedup = sum_s["tokens_per_s"] / sum_p["tokens_per_s"]
    if speedup < SPEC_MIN_SPEEDUP:
        raise AssertionError(
            f"spec_decode: {speedup:.2f}x below the {SPEC_MIN_SPEEDUP}x "
            f"decode tokens/s target (mean accept "
            f"{sum_s['mean_accept_len']:.2f} of k={SPEC_K})")

    def _round(s):
        return {k: (round(v, 4) if v == v else None) for k, v in s.items()}

    return dict(
        requests=SPEC_N_REQUESTS, max_new=SPEC_MAX_NEW, spec_k=SPEC_K,
        draft=SPEC_DRAFT, max_len=SPEC_MAX_LEN,
        train_steps=SPEC_TRAIN_STEPS,
        plain=_round(sum_p), spec=_round(sum_s), reject=_round(sum_r),
        token_parity=True,
        speedup_tokens_per_s=round(speedup, 3),
        reject_slowdown_tokens_per_s=round(
            sum_r["tokens_per_s"] / sum_p["tokens_per_s"], 3),
        mean_accept_len=round(sum_s["mean_accept_len"], 3),
        accept_rate=round(sum_s["spec_accept_rate"], 3),
        launch_reduction=round(sum_p["launches"] / sum_s["launches"], 3),
    )


def _run_multi_turn(params, cfg) -> dict:
    """Warm (prefix cache + sessions) vs cold multi-turn serving on
    identical per-turn prompts. Returns the bench row; raises
    AssertionError if parity breaks or the cache fails to pay off."""
    engine_kw = dict(
        batch_size=MT_SESSIONS, max_len=MT_MAX_LEN,
        weight_cache="prepared", runtime_fmt="v2", mode="continuous",
        prefill_chunk=PAGED_PREFILL_CHUNK, kv_layout="paged",
        kv_block_size=MT_BLOCK_SIZE, kv_blocks=MT_BLOCKS,
    )
    warm = GenerationEngine(params, cfg, prefix_cache=True, **engine_kw)
    cold = GenerationEngine(params, cfg, prefix_cache=False, **engine_kw)

    rng = np.random.default_rng(7)
    # compile warm-up: one throwaway 2-turn session through each engine
    # so the jit compiles (chunk / decode / fused programs plus the COW
    # fork row-copy, which only triggers on a mid-block warm start) land
    # outside the measured TTFTs. The warm cache is cleared afterwards;
    # only the counter ledger keeps the warm-up's few lookups.
    wh = rng.integers(0, cfg.vocab_size, 8).astype(np.int32)
    for wturn in range(2):
        wuser = rng.integers(0, cfg.vocab_size, 4).astype(np.int32)
        wprompt = np.concatenate([wh, wuser])
        wrid = 9000 + wturn
        warm.submit(Request(wrid, wprompt.copy(), max_new_tokens=2,
                            arrival_time=warm.now()), session="warmup")
        dw = warm.run()
        cold.submit(Request(wrid, wprompt.copy(), max_new_tokens=2,
                            arrival_time=cold.now()))
        cold.run()
        wh = np.concatenate(
            [wprompt, np.asarray(dw[wrid].generated, np.int32)])
    warm.clear_prefix_cache()

    system = rng.integers(0, cfg.vocab_size, MT_SHARED).astype(np.int32)
    history = {sid: system.copy() for sid in range(MT_SESSIONS)}
    ttfts = {"warm": [[] for _ in range(MT_TURNS)],
             "cold": [[] for _ in range(MT_TURNS)]}
    rid = 0
    for turn in range(MT_TURNS):
        turn_reqs = []
        for sid in range(MT_SESSIONS):
            user = rng.integers(
                0, cfg.vocab_size, int(rng.integers(*MT_USER))
            ).astype(np.int32)
            prompt = np.concatenate([history[sid], user])
            max_new = int(rng.integers(*MT_MAX_NEW))
            turn_reqs.append((rid, sid, prompt, max_new))
            rid += 1
        # each engine is submitted-then-run by itself: arrival stamps
        # come from its own clock right before its run, so neither
        # engine's TTFT absorbs the other's wall time
        for r, sid, prompt, max_new in turn_reqs:
            warm.submit(Request(r, prompt.copy(), max_new_tokens=max_new,
                                arrival_time=warm.now()),
                        session=f"s{sid}")
        done_w = warm.run()
        for r, sid, prompt, max_new in turn_reqs:
            cold.submit(Request(r, prompt.copy(), max_new_tokens=max_new,
                                arrival_time=cold.now()))
        done_c = cold.run()
        for r, sid, prompt, _ in turn_reqs:
            if done_w[r].generated != done_c[r].generated:
                raise AssertionError(
                    f"multi_turn: warm vs cold greedy streams diverge on "
                    f"session {sid} turn {turn} "
                    f"({done_w[r].generated} vs {done_c[r].generated})")
            history[sid] = np.concatenate(
                [prompt, np.asarray(done_w[r].generated, np.int32)])
            ttfts["warm"][turn].append(warm.metrics.requests[r].ttft)
            ttfts["cold"][turn].append(cold.metrics.requests[r].ttft)
    warm.check_shutdown_invariants()
    cold.check_shutdown_invariants()

    sw = warm.metrics.summary()
    sc = cold.metrics.summary()
    if not sw["prefix_hit_rate"] > 0:
        raise AssertionError("multi_turn: warm engine never hit the "
                             "prefix cache")
    if not sw["prefix_tokens_skipped"] > 0:
        raise AssertionError("multi_turn: warm engine skipped no prefill "
                             "tokens")
    if sw["session_hits"] < 1:
        raise AssertionError("multi_turn: no turn warm-started from a "
                             "retained session chain")
    # the headline claim: once a session's history is resident, TTFT is
    # the delta prefill, not the whole history — p50 over turn-2+
    # requests must improve at least 2x
    late_w = sorted(t for turn in ttfts["warm"][1:] for t in turn)
    late_c = sorted(t for turn in ttfts["cold"][1:] for t in turn)
    warm_p50 = late_w[len(late_w) // 2]
    cold_p50 = late_c[len(late_c) // 2]
    if not warm_p50 * 2 <= cold_p50:
        raise AssertionError(
            f"multi_turn: warm turn-2+ TTFT p50 {warm_p50:.4f}s not 2x "
            f"better than cold {cold_p50:.4f}s")

    contiguous_rows = MT_SESSIONS * MT_MAX_LEN
    paged_rows = MT_BLOCKS * MT_BLOCK_SIZE
    row = dict(
        sessions=MT_SESSIONS, turns=MT_TURNS, shared_prefix=MT_SHARED,
        block_size=MT_BLOCK_SIZE, kv_blocks=MT_BLOCKS,
        warm={k: (round(v, 4) if v == v else None) for k, v in sw.items()},
        cold={k: (round(v, 4) if v == v else None) for k, v in sc.items()},
        greedy_parity=True,
        ttft_p50_turn2plus_warm_s=round(warm_p50, 4),
        ttft_p50_turn2plus_cold_s=round(cold_p50, 4),
        ttft_speedup_turn2plus=round(cold_p50 / warm_p50, 3),
        prefill_tokens_skipped=int(sw["prefix_tokens_skipped"]),
        cache_hbm_ratio=round(paged_rows / contiguous_rows, 3),
    )
    return row


# trace names accepted by ``run(traces=...)`` and the ``--trace`` CLI flag
TRACES = ("short", "long_prompt", "paged_kv", "multi_turn",
          "spec_decode", "fault_storm", "replica_failover")


def run(traces=None) -> dict:
    """Run the serving benchmark traces; ``traces`` optionally restricts
    the run to a subset of the names in ``TRACES`` (default: all)."""
    want = set(TRACES if traces is None else traces)
    unknown = want - set(TRACES)
    if unknown:
        raise ValueError(
            f"unknown traces {sorted(unknown)}; available: {list(TRACES)}")
    cfg = smoke_variant(get_config(ARCH))
    params = init_model(jax.random.PRNGKey(0), cfg)
    qparams, acct = quantize_tree(params, BITS, gamma=0.05)
    specs = _workload(cfg)

    out = dict(
        arch=ARCH, batch=BATCH, max_len=MAX_LEN, requests=N_REQUESTS,
        poisson_rate_hz=POISSON_RATE_HZ, bits=BITS,
        mean_bits=round(acct["mean_bits"], 3),
        by_config={},
    )
    configs = (
        ("prepared_v1", qparams, "prepared", "v1"),
        ("prepared_v2", qparams, "prepared", "v2"),
        ("dense", qparams, "dense", None),
    )
    if "short" in want:
        for tag, p, wc, fmt in configs:
            row = {}
            tokens = {}
            for mode in ("wave", "continuous"):
                tokens[mode], summary = _run_engine(p, cfg, mode, wc, fmt, specs)
                row[mode] = {
                    k: (round(v, 4) if v == v else None)  # NaN -> null
                    for k, v in summary.items()
                }
            row["speedup_tokens_per_s"] = round(
                row["continuous"]["tokens_per_s"] / row["wave"]["tokens_per_s"], 3)
            row["greedy_parity"] = tokens["continuous"] == tokens["wave"]
            if not row["greedy_parity"]:   # a speedup over diverging token
                raise AssertionError(      # streams is not a speedup
                    f"{tag}: continuous vs wave greedy token streams diverge")
            out["by_config"][tag] = row
            emit(
                f"serving/{tag}_continuous",
                row["continuous"]["wall_s"] * 1e6,
                f"tok_s={row['continuous']['tokens_per_s']};"
                f"wave_tok_s={row['wave']['tokens_per_s']};"
                f"speedup={row['speedup_tokens_per_s']}x;"
                f"parity={row['greedy_parity']};"
                f"occupancy={row['continuous']['mean_occupancy']}"
                f"vs{row['wave']['mean_occupancy']}",
            )

    if "long_prompt" in want:
        # ---- long-prompt trace: chunked vs unchunked prefill --------------
        long_specs = _long_workload(cfg)
        out["long_prompt"] = dict(
            requests=LONG_N_REQUESTS, max_len=LONG_MAX_LEN,
            prompt_range=list(LONG_PROMPT), prefill_chunk=PREFILL_CHUNK,
            by_config={},
        )
        for tag, p, wc, fmt in configs:
            if tag not in LONG_CONFIGS:
                continue
            tokens = {}
            row = {}
            runs = (
                ("wave", dict(mode="wave")),
                ("chunk1", dict(mode="continuous", prefill_chunk=1)),
                ("chunked", dict(mode="continuous",
                                 prefill_chunk=PREFILL_CHUNK)),
            )
            for label, kw in runs:
                tokens[label], summary = _run_engine(
                    p, cfg, weight_cache=wc, fmt=fmt, specs=long_specs,
                    max_len=LONG_MAX_LEN, **kw)
                row[label] = {
                    k: (round(v, 4) if v == v else None)  # NaN -> null
                    for k, v in summary.items()
                }
            # greedy continuous output must stay token-identical to wave per
            # request with chunking enabled — a TTFT win over diverging
            # streams is not a win.
            row["greedy_parity"] = (
                tokens["chunked"] == tokens["chunk1"] == tokens["wave"])
            if not row["greedy_parity"]:
                raise AssertionError(
                    f"{tag}: chunked prefill token streams diverge "
                    f"(chunked vs chunk1 vs wave)")
            row["speedup_tokens_per_s"] = round(
                row["chunked"]["tokens_per_s"] / row["chunk1"]["tokens_per_s"],
                3)
            row["ttft_p50_delta_s"] = round(
                row["chunk1"]["ttft_p50"] - row["chunked"]["ttft_p50"], 4)
            row["ttft_p95_delta_s"] = round(
                row["chunk1"]["ttft_p95"] - row["chunked"]["ttft_p95"], 4)
            out["long_prompt"]["by_config"][tag] = row
            emit(
                f"serving/long_prompt_{tag}_chunk{PREFILL_CHUNK}",
                row["chunked"]["wall_s"] * 1e6,
                f"tok_s={row['chunked']['tokens_per_s']};"
                f"chunk1_tok_s={row['chunk1']['tokens_per_s']};"
                f"speedup={row['speedup_tokens_per_s']}x;"
                f"ttft_p95={row['chunked']['ttft_p95']}"
                f"vs{row['chunk1']['ttft_p95']};"
                f"parity={row['greedy_parity']};"
                f"prefill_tokens={row['chunked']['prefill_tokens']}",
            )

    if "paged_kv" in want:
        # ---- paged-KV trace: block pool vs contiguous rows ----------------
        paged_specs = _skewed_workload(cfg)
        out["paged_kv"] = dict(
            requests=PAGED_N_REQUESTS, max_len=PAGED_MAX_LEN,
            block_size=PAGED_BLOCK_SIZE, kv_blocks=PAGED_BLOCKS,
            prefill_chunk=PAGED_PREFILL_CHUNK,
            contiguous_rows=BATCH * PAGED_MAX_LEN,
            paged_rows=PAGED_BLOCKS * PAGED_BLOCK_SIZE,
            by_config={},
        )
        for tag, p, wc, fmt in configs:
            if tag not in PAGED_CONFIGS:
                continue
            tokens = {}
            row = {}
            runs = (
                ("contiguous", dict(kv_layout="contiguous")),
                ("paged", dict(kv_layout="paged",
                               kv_block_size=PAGED_BLOCK_SIZE,
                               kv_blocks=PAGED_BLOCKS)),
                # split two-launch structure: the fused-step control
                ("paged_split", dict(kv_layout="paged",
                                     kv_block_size=PAGED_BLOCK_SIZE,
                                     kv_blocks=PAGED_BLOCKS,
                                     fused_step=False)),
            )
            for label, kw in runs:
                tokens[label], summary = _run_engine(
                    p, cfg, mode="continuous", weight_cache=wc, fmt=fmt,
                    specs=paged_specs, max_len=PAGED_MAX_LEN,
                    prefill_chunk=PAGED_PREFILL_CHUNK, **kw)
                row[label] = {
                    k: (round(v, 4) if v == v else None)  # NaN -> null
                    for k, v in summary.items()
                }
            # identical greedy streams at a strictly smaller footprint is the
            # whole claim — preemption replays must recompute exact tokens,
            # and folding mixed iterations into one fused launch must not
            # change a single token either.
            row["greedy_parity"] = (tokens["paged"] == tokens["contiguous"]
                                    == tokens["paged_split"])
            if not row["greedy_parity"]:
                raise AssertionError(
                    f"{tag}: paged / contiguous / split-step greedy token "
                    f"streams diverge")
            # fused mixed iterations are ONE launch: strictly fewer device
            # launches than the split chunk+decode structure for the same
            # tokens
            fused_l = row["paged"]["launches"]
            split_l = row["paged_split"]["launches"]
            row["launch_reduction"] = round(split_l / fused_l, 3)
            if not (row["paged"]["fused_steps"] >= 1 and fused_l < split_l):
                raise AssertionError(
                    f"{tag}: fused step did not reduce launches "
                    f"({fused_l} fused vs {split_l} split)")
            # the paged decode attention streams only live blocks: its
            # bytes-read estimate must sit strictly below the logical
            # full-table span a contiguous gather would stream
            attn_log = row["paged"]["attn_logical_bytes"]
            attn_live = row["paged"]["attn_live_bytes"]
            row["attn_bytes_ratio"] = round(attn_live / attn_log, 3)
            if not 0 < attn_live < attn_log:
                raise AssertionError(
                    f"{tag}: paged attention bytes-read estimate did not "
                    f"shrink (live {attn_live} vs logical {attn_log})")
            c_bytes = row["contiguous"]["cache_bytes"]
            p_bytes = row["paged"]["cache_bytes"]
            row["cache_bytes_ratio"] = round(p_bytes / c_bytes, 3)
            if not p_bytes < c_bytes:
                raise AssertionError(
                    f"{tag}: paged cache ({p_bytes} B) not smaller than "
                    f"contiguous ({c_bytes} B)")
            occ_c = row["contiguous"]["mean_occupancy"]
            occ_p = row["paged"]["mean_occupancy"]
            row["occupancy_ratio"] = round(occ_p / occ_c, 3)
            # the smaller pool must not cost served concurrency: paged lanes
            # stay as full as contiguous ones (measured ratio 0.98-1.00 on
            # this host; 5% slack absorbs step-count jitter from
            # wall-clock-dependent admission timing on shared CI runners)
            if not occ_p >= 0.95 * occ_c:
                raise AssertionError(
                    f"{tag}: paged occupancy {occ_p} fell below contiguous "
                    f"{occ_c}")
            if row["paged"]["preemptions"] < 1:
                raise AssertionError(
                    f"{tag}: pool pressure never triggered a preemption — "
                    f"the trace is not exercising the requeue path")
            out["paged_kv"]["by_config"][tag] = row
            emit(
                f"serving/paged_kv_{tag}",
                row["paged"]["wall_s"] * 1e6,
                f"tok_s={row['paged']['tokens_per_s']}"
                f"vs{row['contiguous']['tokens_per_s']};"
                f"cache_bytes={int(p_bytes)}vs{int(c_bytes)};"
                f"occupancy={occ_p}vs{occ_c};"
                f"preemptions={int(row['paged']['preemptions'])};"
                f"block_util={row['paged']['mean_block_utilization']};"
                f"attn_bytes={int(attn_live)}vs{int(attn_log)};"
                f"launches={int(fused_l)}vs{int(split_l)};"
                f"parity={row['greedy_parity']}",
            )

    if "multi_turn" in want:
        # ---- multi-turn trace: warm sessions vs cold re-prefill -----------
        mt = _run_multi_turn(qparams, cfg)
        out["multi_turn"] = mt
        emit(
            "serving/multi_turn_warm",
            mt["warm"]["wall_s"] * 1e6,
            f"ttft_p50_turn2plus={mt['ttft_p50_turn2plus_warm_s']}"
            f"vs{mt['ttft_p50_turn2plus_cold_s']};"
            f"speedup={mt['ttft_speedup_turn2plus']}x;"
            f"hit_rate={mt['warm']['prefix_hit_rate']};"
            f"tokens_skipped={mt['prefill_tokens_skipped']};"
            f"cow_forks={int(mt['warm']['cow_forks'])};"
            f"cache_hbm_ratio={mt['cache_hbm_ratio']};"
            f"parity={mt['greedy_parity']}",
        )

    if "spec_decode" in want:
        # ---- speculative-decoding trace: draft-and-verify vs plain --------
        sd = _run_spec_decode(cfg)
        out["spec_decode"] = sd
        emit(
            "serving/spec_decode",
            sd["spec"]["wall_s"] * 1e6,
            f"tok_s={sd['spec']['tokens_per_s']}"
            f"vs{sd['plain']['tokens_per_s']};"
            f"speedup={sd['speedup_tokens_per_s']}x;"
            f"mean_accept_len={sd['mean_accept_len']}of{SPEC_K};"
            f"accept_rate={sd['accept_rate']};"
            f"launches={int(sd['spec']['launches'])}"
            f"vs{int(sd['plain']['launches'])};"
            f"reject_worst_case={sd['reject_slowdown_tokens_per_s']}x;"
            f"parity={sd['token_parity']}",
        )

    if "fault_storm" in want:
        # ---- fault-storm trace: typed termination + recovery parity -------
        storm = _run_fault_storm(qparams, cfg)
        out["fault_storm"] = dict(
            plan=FAULT_STORM_PLAN, cancel_rid=FAULT_CANCEL_RID,
            expired_rid=PAGED_N_REQUESTS, row=storm,
        )
        emit(
            "serving/fault_storm",
            storm["wall_s"] * 1e6,
            f"statuses={storm['status_counts']};"
            f"faults={storm['fault_kinds']};"
            f"degraded_steps={int(storm['degraded_steps'])};"
            f"replays={int(storm['replays'])};"
            f"ok_parity={storm['ok_parity']}",
        )

    if "replica_failover" in want:
        # ---- replica-failover trace: router + supervised replicas ---------
        fo = _run_replica_failover(qparams, cfg)
        out["replica_failover"] = dict(
            replicas=FAILOVER_REPLICAS, requests=FAILOVER_N_REQUESTS,
            kill_after=FAILOVER_KILL_AFTER, row=fo,
        )
        emit(
            "serving/replica_failover",
            fo["wall_s"] * 1e6,
            f"failovers={int(fo['failovers'])};"
            f"restarts={int(fo['replica_restarts'])};"
            f"kills={int(fo['replica_kills'])};"
            f"dup_terminals={int(fo['duplicate_terminals'])};"
            f"statuses={fo['status_counts']};"
            f"ok_parity={fo['ok_parity']};"
            f"wal_pending_after={fo['wal_pending_after']}",
        )
    return out


if __name__ == "__main__":
    import argparse
    import json

    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--trace", action="append", choices=list(TRACES), default=None,
        help="run only the named trace(s) (repeatable); default: all. "
        "The selected subset still lands in BENCH_serving.json.")
    args = ap.parse_args()
    result = run(args.trace)
    with open("BENCH_serving.json", "w") as f:
        json.dump(result, f, indent=1)
    print(json.dumps(result, indent=1))
