"""Kernel execution-layer benchmark: reference vs kernel-backed dispatch.

Reference = today's model path for storage-format weights: full in-graph
``dequantize()`` (gap-stream decode + gather) then a dense matmul, every
call. Fused = the kernels/backend.py dispatch layer over a prepared
layout (decode/pad once at load): on TPU the fused Pallas kernel for
decode and dequant-kernel+MXU-matmul for prefill, off-TPU the prepared
pure-XLA arm (interpret-free — the Pallas interpreter never sits on the
measured path).

``benchmarks/run.py`` serializes the returned dict to BENCH_kernels.json
so the tokens/s + bits/weight trajectory is tracked across PRs.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import emit, timeit
from repro import core
from repro.core.stats import heavy_tailed_weights
from repro.kernels import autotune, backend, ops, ref
from repro.kernels.platform import default_backend, default_interpret, \
    detected_platform
from repro.models.linear import linear

R, C = 512, 2048
DECODE_M, PREFILL_M = 1, 256


def _bench_linear(params_w, x) -> float:
    f = jax.jit(lambda xx, w: linear(xx, w))
    return timeit(f, x, params_w)


def run() -> dict:
    out = dict(
        platform=detected_platform(),
        dispatch_backend=default_backend(),
        interpret_default=default_interpret(),
        shape=[R, C],
        by_bits={},
    )

    for n_bits in (2, 3, 4):
        W = heavy_tailed_weights(R, C, seed=n_bits)
        pk = core.quantize(jnp.asarray(W), n_bits, gamma=0.05)
        prep = backend.prepare(pk)
        rt_bits = prep.bits_per_weight()
        st_bits = pk.bits_per_weight()["total"]

        row = dict(storage_bits=round(st_bits, 3),
                   runtime_bits=round(rt_bits, 3),
                   hbm_reduction_vs_bf16=round(16.0 / rt_bits, 2))
        for phase, M in (("decode", DECODE_M), ("prefill", PREFILL_M)):
            x = jnp.asarray(
                np.random.default_rng(M).standard_normal((M, C)), jnp.float32)
            us_ref = _bench_linear(pk, x)
            us_fused = _bench_linear(prep, x)
            row[phase] = dict(
                ref_us=round(us_ref, 1),
                fused_us=round(us_fused, 1),
                ref_tok_s=round(M / us_ref * 1e6, 1),
                fused_tok_s=round(M / us_fused * 1e6, 1),
                speedup=round(us_ref / us_fused, 2),
                path=backend.choose_path(M, prep),
            )
            emit(
                f"kernels/dispatch_n{n_bits}_{phase}", us_fused,
                f"ref_us={us_ref:.0f};speedup={us_ref / us_fused:.2f}x;"
                f"runtime_bits={rt_bits:.2f};path={row[phase]['path']}",
            )
        out["by_bits"][n_bits] = row

    # Pallas kernel micro (small shape: interpret mode off-TPU is slow) +
    # autotuned blocks, recorded to the shared JSON cache for reuse.
    r2, c2 = 64, 512
    tuned = autotune.autotune_matmul(DECODE_M, r2, c2, 4, iters=1)
    out["autotune"] = dict(
        key=autotune.matmul_key(DECODE_M, r2, c2, 4, "pallas",
                                default_interpret()),
        blocks=list(tuned["blocks"]),
        cached=tuned["cached"],
        cache_file=autotune.cache_path(),
    )
    W2 = heavy_tailed_weights(r2, c2, seed=11)
    pk2 = core.quantize(jnp.asarray(W2), 4, gamma=0.05)
    prep2 = backend.prepare(pk2, backend="pallas",
                            blocks=tuple(tuned["blocks"]))
    x2 = jnp.asarray(
        np.random.default_rng(5).standard_normal((DECODE_M, c2)), jnp.float32)
    us_pallas = _bench_linear(prep2, x2)
    out["pallas_micro"] = dict(
        shape=[r2, c2], n_bits=4, M=DECODE_M, us=round(us_pallas, 1),
        interpret=default_interpret(),
    )
    emit("kernels/pallas_fused_micro", us_pallas,
         f"blocks={tuned['blocks']};interpret={default_interpret()}")

    # kmeans assignment (the ICQuant^SK calibration hot loop)
    w = jnp.asarray(heavy_tailed_weights(256, 4096, seed=9))
    wt = jnp.abs(w) + 0.1
    cnt = jnp.asarray(
        np.sort(np.random.default_rng(1).standard_normal((256, 16)), -1),
        jnp.float32,
    )
    us_ref = timeit(jax.jit(ref.kmeans_assign_ref), w, wt, cnt)
    us_kern = timeit(lambda: ops.kmeans_assign(w, wt, cnt))
    emit("kernels/kmeans_assign", us_kern, f"ref_us={us_ref:.0f};C=16")
    out["kmeans_assign"] = dict(ref_us=round(us_ref, 1),
                                kernel_us=round(us_kern, 1))
    return out


if __name__ == "__main__":
    import json

    print(json.dumps(run(), indent=1))
