"""Kernel-level benchmark: Pallas (interpret) vs pure-jnp oracle, plus the
deployment-relevant derived quantity — HBM bytes per weight each format
moves (the real TPU win; wall-times here are CPU-interpret and only
meaningful relative to each other)."""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import emit, timeit
from repro import core
from repro.core.stats import heavy_tailed_weights
from repro.kernels import ops, ref


def run() -> dict:
    out = {}
    R, C = 512, 2048
    x = jnp.asarray(np.random.default_rng(0).standard_normal((64, C)),
                    jnp.float32)
    dense_bytes = R * C * 2  # bf16 baseline

    for n_bits in (2, 3, 4):
        W = heavy_tailed_weights(R, C, seed=n_bits)
        pk = core.quantize(jnp.asarray(W), n_bits, gamma=0.05)
        rt = ops.to_runtime(pk)

        us_ref = timeit(
            jax.jit(lambda c, b, k: ref.matmul_ref(x, c, b, k, n_bits, C)),
            rt["codes"], rt["bitmap"], rt["codebooks"],
        )
        us_kern = timeit(
            lambda: ops.matmul(x, rt, block_m=64, block_n=128, block_k=512),
        )
        rt_bits = ops.runtime_bits_per_weight(rt)
        st_bits = pk.bits_per_weight()["total"]
        weight_bytes = rt_bits / 8 * R * C
        out[n_bits] = dict(rt_bits=rt_bits, st_bits=st_bits)
        emit(
            f"kernels/icq_matmul_n{n_bits}", us_kern,
            f"ref_us={us_ref:.0f};storage_bits={st_bits:.2f};"
            f"runtime_bits={rt_bits:.2f};"
            f"hbm_reduction_vs_bf16={dense_bytes / weight_bytes:.2f}x",
        )

    # kmeans assignment (the ICQuant^SK calibration hot loop)
    w = jnp.asarray(heavy_tailed_weights(256, 4096, seed=9))
    wt = jnp.abs(w) + 0.1
    cnt = jnp.asarray(
        np.sort(np.random.default_rng(1).standard_normal((256, 16)), -1),
        jnp.float32,
    )
    us_ref = timeit(jax.jit(ref.kmeans_assign_ref), w, wt, cnt)
    us_kern = timeit(lambda: ops.kmeans_assign(w, wt, cnt))
    emit("kernels/kmeans_assign", us_kern, f"ref_us={us_ref:.0f};C=16")
    return out


if __name__ == "__main__":
    run()
