"""Kernel execution-layer benchmark: reference vs kernel-backed dispatch,
v1 (dense-bitmap) vs v2 (checkpointed gap-stream) runtime formats.

Reference = today's model path for storage-format weights: full in-graph
``dequantize()`` (gap-stream decode + gather) then a dense matmul, every
call. Fused = the kernels/backend.py dispatch layer over a prepared
layout (decode/pad once at load): on TPU the fused Pallas kernel for
decode and dequant-kernel+MXU-matmul for prefill, off-TPU the prepared
pure-XLA arm (interpret-free — the Pallas interpreter never sits on the
measured path).

Per (n_bits, fmt) the table records the honest HBM accounting
(``runtime_bits_per_weight`` + the outlier-selection share) next to
tokens/s, so the v1->v2 trade — ~0.55 b/w of HBM back for the decode
work moving in-kernel — is tracked across PRs in BENCH_kernels.json.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import emit, timeit
from repro import core
from repro.core.stats import heavy_tailed_weights
from repro.kernels import autotune, backend, ops, ref
from repro.kernels.platform import default_backend, default_interpret, \
    detected_platform
from repro.models.linear import linear

R, C = 512, 2048
DECODE_M, PREFILL_M = 1, 256


def _bench_linear(params_w, x) -> float:
    f = jax.jit(lambda xx, w: linear(xx, w))
    return timeit(f, x, params_w)


def run() -> dict:
    out = dict(
        platform=detected_platform(),
        dispatch_backend=default_backend(),
        interpret_default=default_interpret(),
        shape=[R, C],
        by_bits={},
    )

    for n_bits in (2, 3, 4):
        W = heavy_tailed_weights(R, C, seed=n_bits)
        pk = core.quantize(jnp.asarray(W), n_bits, gamma=0.05)
        row = dict(storage_bits=round(pk.bits_per_weight()["total"], 3),
                   storage_stream_bits=round(pk.bits_per_weight()["index"], 3))

        for fmt in ("v1", "v2"):
            rt = ops.to_runtime(pk, fmt=fmt)
            prep = backend.prepare(pk, fmt=fmt)
            frow = dict(
                runtime_bits=round(ops.runtime_bits_per_weight(rt), 3),
                outlier_bits=round(
                    ops.runtime_outlier_bits_per_weight(rt), 3),
                prepared_bits=round(prep.bits_per_weight(), 3),
                hbm_reduction_vs_bf16=round(
                    16.0 / prep.bits_per_weight(), 2),
                block_k=prep.block_k,
            )
            for phase, M in (("decode", DECODE_M), ("prefill", PREFILL_M)):
                x = jnp.asarray(
                    np.random.default_rng(M).standard_normal((M, C)),
                    jnp.float32)
                us_ref = _bench_linear(pk, x)
                us_fused = _bench_linear(prep, x)
                frow[phase] = dict(
                    ref_us=round(us_ref, 1),
                    fused_us=round(us_fused, 1),
                    ref_tok_s=round(M / us_ref * 1e6, 1),
                    fused_tok_s=round(M / us_fused * 1e6, 1),
                    speedup=round(us_ref / us_fused, 2),
                    path=backend.choose_path(M, prep),
                )
                # v1 keeps the legacy un-suffixed metric name so the
                # cross-PR time series stays continuous (mirrors the
                # autotune cache-key spelling)
                sfx = "" if fmt == "v1" else f"_{fmt}"
                emit(
                    f"kernels/dispatch_n{n_bits}{sfx}_{phase}", us_fused,
                    f"ref_us={us_ref:.0f};speedup={us_ref / us_fused:.2f}x;"
                    f"runtime_bits={frow['runtime_bits']};"
                    f"outlier_bits={frow['outlier_bits']};"
                    f"path={frow[phase]['path']}",
                )
            row[fmt] = frow
        row["v2_outlier_saving_bits"] = round(
            row["v1"]["outlier_bits"] - row["v2"]["outlier_bits"], 3)
        out["by_bits"][n_bits] = row

    # Pallas kernel micro (small shape: interpret mode off-TPU is slow) +
    # autotuned blocks per format, recorded to the shared JSON cache.
    r2, c2 = 64, 512
    W2 = heavy_tailed_weights(r2, c2, seed=11)
    pk2 = core.quantize(jnp.asarray(W2), 4, gamma=0.05)
    x2 = jnp.asarray(
        np.random.default_rng(5).standard_normal((DECODE_M, c2)), jnp.float32)
    out["pallas_micro"] = {}
    out["autotune"] = {}
    for fmt in ("v1", "v2"):
        # full per-arm table: decode M=1 + prefill-M buckets (fused arm)
        # + the M-free dequant arm, all consulted by backend.arm_blocks.
        # Interpret-mode sweeps are slow, so the bench tunes only the
        # first prefill bucket; on real TPU drop prefill_ms to tune all.
        arms = autotune.autotune_arms(
            r2, c2, 4, iters=1, fmt=fmt,
            prefill_ms=autotune.PREFILL_MS[:1] if default_interpret()
            else autotune.PREFILL_MS)
        tuned = arms["decode"]
        out["autotune"][fmt] = dict(
            key=autotune.matmul_key(DECODE_M, r2, c2, 4, "pallas",
                                    default_interpret(), fmt=fmt),
            blocks=list(tuned["blocks"]),
            cached=tuned["cached"],
            prefill_blocks={m: list(t["blocks"])
                            for m, t in arms["prefill"].items()},
            dequant_blocks=list(arms["dequant"]["blocks"]),
            cache_file=autotune.cache_path(),
        )
        prep2 = backend.prepare(pk2, backend="pallas", fmt=fmt,
                                blocks=tuple(tuned["blocks"]))
        us_pallas = _bench_linear(prep2, x2)
        out["pallas_micro"][fmt] = dict(
            shape=[r2, c2], n_bits=4, M=DECODE_M, us=round(us_pallas, 1),
            interpret=default_interpret(),
        )
        micro_name = "kernels/pallas_fused_micro" + (
            "" if fmt == "v1" else f"_{fmt}")
        emit(micro_name, us_pallas,
             f"blocks={tuned['blocks']};interpret={default_interpret()}")

    # kmeans assignment (the ICQuant^SK calibration hot loop)
    w = jnp.asarray(heavy_tailed_weights(256, 4096, seed=9))
    wt = jnp.abs(w) + 0.1
    cnt = jnp.asarray(
        np.sort(np.random.default_rng(1).standard_normal((256, 16)), -1),
        jnp.float32,
    )
    us_ref = timeit(jax.jit(ref.kmeans_assign_ref), w, wt, cnt)
    us_kern = timeit(lambda: ops.kmeans_assign(w, wt, cnt))
    emit("kernels/kmeans_assign", us_kern, f"ref_us={us_ref:.0f};C=16")
    out["kmeans_assign"] = dict(ref_us=round(us_ref, 1),
                                kernel_us=round(us_kern, 1))
    return out


if __name__ == "__main__":
    import json

    print(json.dumps(run(), indent=1))
