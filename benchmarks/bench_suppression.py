"""Paper Figure 5: outlier-suppression comparison at matched storage.

(b)-analog: per-layer quantization MSE of 3-bit RTN under grouping /
mixed-precision / incoherence / ICQuant at ~comparable bits/weight.
Claim: ICQuant gives the lowest error (~1/4 of vanilla)."""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from benchmarks.common import LLAMA2_7B_LAYERS, emit, layer_weights, timeit
from repro import core
from repro.quant import (
    grouped_rtn,
    incoherence_rtn,
    mixed_precision_rtn,
    vanilla_rtn,
)

N_BITS = 3


def run() -> dict:
    out = {}
    for name in ("q_proj", "o_proj", "up_proj", "down_proj"):
        W = layer_weights(name)
        results = {}

        Wv, bits = vanilla_rtn(W, N_BITS)
        results["vanilla"] = (bits, float(((W - np.asarray(Wv)) ** 2).sum()))

        Wg, bits = grouped_rtn(W, N_BITS, group=128)
        results["grouped_g128"] = (bits, float(((W - np.asarray(Wg)) ** 2).sum()))

        Wm, bits = mixed_precision_rtn(W, N_BITS, gamma=0.01)
        results["mixed_fp16_1pct"] = (bits, float(((W - np.asarray(Wm)) ** 2).sum()))

        Wi, bits = incoherence_rtn(W, N_BITS, seed=0)
        results["incoherence"] = (bits, float(((W - np.asarray(Wi)) ** 2).sum()))

        us = timeit(lambda: core.quantize(jnp.asarray(W), N_BITS, 0.05), iters=1)
        pk = core.quantize(jnp.asarray(W), N_BITS, gamma=0.05)
        mse = float(((W - np.asarray(core.dequantize(pk))) ** 2).sum())
        results["icquant_rtn_5pct"] = (pk.bits_per_weight()["total"], mse)

        out[name] = results
        base = results["vanilla"][1]
        for tech, (bits, mse) in results.items():
            emit(
                f"suppression/{name}/{tech}",
                us if tech.startswith("icquant") else 0.0,
                f"bits={bits:.3f};mse={mse:.4e};rel={mse / base:.3f}",
            )
        icq_rel = results["icquant_rtn_5pct"][1] / base
        emit(f"suppression/{name}/summary", 0.0,
             f"icquant_rel_mse={icq_rel:.3f};paper_claim~0.25")
    return out


if __name__ == "__main__":
    run()
