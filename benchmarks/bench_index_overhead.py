"""Paper Figure 4/8 + Lemma 1: index-coding overhead B(b).

Three curves per gamma: Lemma-1 bound, synthetic uniform simulation, and
empirical heavy-tailed weights. The paper's claims: the curves coincide,
the minimum is ~0.31 b/w at (gamma=5%, b=6), and B is convex in b."""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, layer_weights, timeit
from repro.core import lemma1_bound, optimal_b
from repro.core.stats import (
    empirical_index_overhead,
    synthetic_uniform_overhead,
)

BS = range(3, 11)
GAMMAS = (0.05, 0.0825)


def run() -> dict:
    out = {}
    W = layer_weights("q_proj")
    for gamma in GAMMAS:
        rows = []
        for b in BS:
            bound = lemma1_bound(gamma, b)
            syn = synthetic_uniform_overhead(4096, 128, gamma, b, seed=b)
            us = timeit(empirical_index_overhead, W, gamma, b, iters=1)
            emp = empirical_index_overhead(W, gamma, b)
            rows.append((b, bound, syn, emp))
            emit(
                f"index_overhead/g{gamma:.4f}/b{b}", us,
                f"bound={bound:.4f};synthetic={syn:.4f};empirical={emp:.4f}",
            )
        out[gamma] = rows
        bstar = optimal_b(gamma)
        emit(f"index_overhead/g{gamma:.4f}/optimal", 0.0,
             f"b*={bstar};B*={lemma1_bound(gamma, bstar):.4f}")
    return out


if __name__ == "__main__":
    run()
