"""Benchmark harness: one module per paper table/figure.

``PYTHONPATH=src python -m benchmarks.run [--only NAME]``

Output contract: ``name,us_per_call,derived`` CSV lines. The kernels and
serving modules additionally dump structured results to
``BENCH_kernels.json`` (tokens/s + bits/weight, reference vs fused
dispatch path) and ``BENCH_serving.json`` (continuous-batching vs legacy
wave engine throughput) so the perf trajectory is tracked across PRs;
block-autotuner winners land in the shared JSON cache
(``ICQ_AUTOTUNE_CACHE``) and are reused on re-runs.
"""
from __future__ import annotations

import argparse
import json
import sys
import traceback

# modules whose run() result is archived as BENCH_<name>.json
JSON_MODULES = {"kernels", "serving"}

MODULES = [
    ("outlier_range", "benchmarks.bench_outlier_range"),    # Fig 1/6
    ("uniformity", "benchmarks.bench_uniformity"),          # Tab 1/5
    ("index_overhead", "benchmarks.bench_index_overhead"),  # Fig 4/8, Lemma 1
    ("suppression", "benchmarks.bench_suppression"),        # Fig 5
    ("e2e_quality", "benchmarks.bench_e2e_quality"),        # Tab 2-4 proxy
    ("kernels", "benchmarks.bench_kernels"),                # deployment
    ("serving", "benchmarks.bench_serving"),                # continuous vs wave
    ("roofline", "benchmarks.bench_roofline"),              # §Roofline
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    args = ap.parse_args()

    failed = []
    for name, module in MODULES:
        if args.only and args.only != name:
            continue
        print(f"# === {name} ({module}) ===", flush=True)
        try:
            mod = __import__(module, fromlist=["run"])
            result = mod.run()
            if name in JSON_MODULES and isinstance(result, dict):
                path = f"BENCH_{name}.json"
                with open(path, "w") as f:
                    json.dump(result, f, indent=1)
                print(f"# wrote {path}", flush=True)
        except Exception:
            traceback.print_exc()
            failed.append(name)
    if failed:
        print(f"# FAILED: {failed}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
