"""Benchmark harness: one module per paper table/figure.

``PYTHONPATH=src python -m benchmarks.run [--only NAME]``

Output contract: ``name,us_per_call,derived`` CSV lines.
"""
from __future__ import annotations

import argparse
import sys
import traceback

MODULES = [
    ("outlier_range", "benchmarks.bench_outlier_range"),    # Fig 1/6
    ("uniformity", "benchmarks.bench_uniformity"),          # Tab 1/5
    ("index_overhead", "benchmarks.bench_index_overhead"),  # Fig 4/8, Lemma 1
    ("suppression", "benchmarks.bench_suppression"),        # Fig 5
    ("e2e_quality", "benchmarks.bench_e2e_quality"),        # Tab 2-4 proxy
    ("kernels", "benchmarks.bench_kernels"),                # deployment
    ("roofline", "benchmarks.bench_roofline"),              # §Roofline
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    args = ap.parse_args()

    failed = []
    for name, module in MODULES:
        if args.only and args.only != name:
            continue
        print(f"# === {name} ({module}) ===", flush=True)
        try:
            mod = __import__(module, fromlist=["run"])
            mod.run()
        except Exception:
            traceback.print_exc()
            failed.append(name)
    if failed:
        print(f"# FAILED: {failed}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
