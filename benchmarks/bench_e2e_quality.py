"""Paper Tables 2-4 (proxy): end-to-end quality of quantized LMs.

No pretrained Llama weights exist offline, so the protocol is: train a
small LM on the synthetic corpus, then PTQ it with each scheme and
measure held-out NLL deltas vs the model's own FP baseline. The paper's
*orderings* are the claims under test:
  NLL(FP) <= NLL(ICQuant^SK n-bit) <= NLL(ICQuant^RTN n-bit)
           <= NLL(vanilla RTN n-bit),
and ICQuant at n bits ~ vanilla at n+1 bits."""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from benchmarks.common import emit, timeit
from repro.configs import get_config, smoke_variant
from repro.data import SyntheticLM
from repro.launch.quantize import compute_fisher, quantize_tree
from repro.launch.steps import loss_fn
from repro.launch.train import train

ARCH = "internlm2-1.8b"
STEPS = 60


def _heldout_nll(params, cfg, n_batches: int = 4) -> float:
    data = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=64, seed=0)
    tot = 0.0
    for i in range(n_batches):
        b = data.batch(step=50_000 + i, shard=9, batch_size=8)
        loss, _ = loss_fn(params, cfg,
                          {k: jnp.asarray(v) for k, v in b.items()})
        tot += float(loss)
    return tot / n_batches


def run() -> dict:
    cfg = smoke_variant(get_config(ARCH))
    params, _ = train(ARCH, steps=STEPS, batch=8, seq=64,
                      ckpt_dir="/tmp/repro_bench_ckpt", log_every=1000)
    nll_fp = _heldout_nll(params, cfg)
    emit("e2e_quality/fp32", 0.0, f"nll={nll_fp:.4f}")

    fisher = compute_fisher(params, cfg, n_sequences=32, seq_len=64)

    out = {"fp": nll_fp}
    for n_bits in (2, 3, 4):
        # vanilla RTN = ICQuant with gamma -> 0 (no outlier separation)
        qv, _ = quantize_tree(params, n_bits, gamma=1e-9)
        nll_v = _heldout_nll(qv, cfg)

        us = timeit(
            lambda: quantize_tree(params, n_bits, gamma=0.05), iters=1
        )
        qr, acct_r = quantize_tree(params, n_bits, gamma=0.05)
        nll_r = _heldout_nll(qr, cfg)

        qs, acct_s = quantize_tree(params, n_bits, gamma=0.05,
                                   method="kmeans", fisher=fisher)
        nll_s = _heldout_nll(qs, cfg)

        out[n_bits] = dict(vanilla=nll_v, icq_rtn=nll_r, icq_sk=nll_s)
        emit(
            f"e2e_quality/{n_bits}bit", us,
            f"nll_vanilla={nll_v:.4f};nll_icq_rtn={nll_r:.4f};"
            f"nll_icq_sk={nll_s:.4f};fp={nll_fp:.4f};"
            f"bits_icq={acct_r['mean_bits']:.2f}",
        )
    # the paper's "n-bit ICQuant ~ (n+1)-bit vanilla" claim
    q2, _ = quantize_tree(params, 2, gamma=0.05)
    q3v, _ = quantize_tree(params, 3, gamma=1e-9)
    emit(
        "e2e_quality/range_halving", 0.0,
        f"icq2={_heldout_nll(q2, cfg):.4f};vanilla3={_heldout_nll(q3v, cfg):.4f}",
    )
    return out


if __name__ == "__main__":
    run()
