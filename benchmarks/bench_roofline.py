"""Roofline analysis from dry-run artifacts (EXPERIMENTS.md §Roofline).

Reads benchmarks/artifacts/dryrun/*.json (written by repro.launch.dryrun)
and derives, per (arch x shape x mesh):

  compute_s    = HLO_FLOPs / (chips x 197e12)          [bf16 peak, v5e]
  memory_s     = HLO_bytes  / (chips x 819e9)           [HBM bw]
  collective_s = collective_bytes / (chips x 3 x 50e9)  [3 usable ICI links]

plus the dominant term, MODEL_FLOPS, and the usefulness ratio
MODEL_FLOPS / HLO_FLOPs. HLO numbers from cost_analysis() are per-device
(XLA reports the partitioned module), so terms are computed per device
and NOT divided by chips again.
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, Optional

from benchmarks.common import emit
from repro.configs import SHAPE_BY_NAME, get_config

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
ICI_BW = 3 * 50e9            # bytes/s / chip (3 concurrently-usable links)

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "artifacts", "dryrun")


def model_params(cfg) -> Dict[str, float]:
    """Analytic parameter counts (total and active) for MODEL_FLOPS."""
    d, V = cfg.d_model, cfg.vocab_size
    embed = V * d * (1 if cfg.tie_embeddings else 2)

    def attn_params():
        if cfg.attn_type == "none":
            return 0
        hd = cfg.resolved_head_dim
        if cfg.attn_type == "mla":
            nd, rd, vd = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
            q = (cfg.q_lora_rank * (d + cfg.n_heads * (nd + rd))
                 if cfg.q_lora_rank else d * cfg.n_heads * (nd + rd))
            kv = d * cfg.kv_lora_rank + d * rd + cfg.kv_lora_rank * cfg.n_heads * (nd + vd)
            return q + kv + cfg.n_heads * vd * d
        if cfg.attn_type == "none":
            return 0
        return d * hd * (cfg.n_heads * 2 + cfg.n_kv_heads * 2)

    def ssm_params():
        if cfg.family not in ("ssm", "hybrid"):
            return 0
        di = cfg.ssm_expand * d
        g, n = cfg.ssm_groups, cfg.ssm_state
        h = di // cfg.ssm_head_dim
        return d * (2 * di + 2 * g * n + h) + di * d

    def ffn_params(width):
        return 3 * d * width

    per_layer_dense = attn_params() + ssm_params() + ffn_params(cfg.d_ff if cfg.family != "ssm" else 0)
    total = embed
    active = embed
    if cfg.family == "moe":
        moe_layers = cfg.n_layers - cfg.first_dense_layers
        dense_layers = cfg.first_dense_layers
        per_moe = (
            attn_params()
            + cfg.n_experts * ffn_params(cfg.moe_d_ff)
            + cfg.n_shared_experts * ffn_params(cfg.moe_d_ff)
            + d * cfg.n_experts
        )
        per_moe_active = (
            attn_params()
            + cfg.experts_per_token * ffn_params(cfg.moe_d_ff)
            + cfg.n_shared_experts * ffn_params(cfg.moe_d_ff)
        )
        total += dense_layers * per_layer_dense + moe_layers * per_moe
        active += dense_layers * per_layer_dense + moe_layers * per_moe_active
    elif cfg.is_encdec:
        total += (cfg.encoder_layers + cfg.decoder_layers) * per_layer_dense
        # decoder cross-attn extra
        total += cfg.decoder_layers * attn_params()
        active = total
    else:
        total += cfg.n_layers * per_layer_dense
        active = total
    return dict(total=float(total), active=float(active))


def kv_cache_bytes_per_seq(cfg, seq_len: int) -> float:
    """Bytes of decode state per sequence (bf16)."""
    if cfg.attn_type == "mla":
        per_tok = cfg.kv_lora_rank + cfg.qk_rope_head_dim
        L = cfg.n_layers
        return 2.0 * L * seq_len * per_tok
    ssm = 0.0
    if cfg.family in ("ssm", "hybrid"):
        di = cfg.ssm_expand * cfg.d_model
        h = di // cfg.ssm_head_dim
        ssm = 4.0 * cfg.n_layers * h * cfg.ssm_head_dim * cfg.ssm_state
        if cfg.family == "ssm":
            return ssm
    T = min(seq_len, cfg.sliding_window) if cfg.sliding_window else seq_len
    L = cfg.n_layers + (cfg.decoder_layers if cfg.is_encdec else 0)
    attn = 2.0 * L * T * 2 * cfg.n_kv_heads * cfg.resolved_head_dim
    return attn + ssm


def memory_floor_bytes(cfg, shape, n_chips: int,
                       weight_bits: float = 16.0) -> float:
    """Analytic lower bound on HBM traffic per chip per step.

    XLA's per-op 'bytes accessed' ignores fusion (upper bound); this floor
    counts only unavoidable traffic: weights (at `weight_bits`), optimizer
    state (train), remat-checkpointed layer boundaries, and KV/SSM state
    (decode/prefill). The achievable step time lies between floor and the
    XLA bound; roofline fractions are reported against the floor.
    """
    p = model_params(cfg)
    B, S = shape.global_batch, shape.seq_len
    d = cfg.d_model
    L = cfg.n_layers + (cfg.encoder_layers if cfg.is_encdec else 0)
    wbytes = p["total"] * weight_bits / 8.0

    if shape.kind == "train":
        # fwd+bwd weight reads + grad write/read + AdamW moments rw + write
        weight_traffic = wbytes * 3 + p["total"] * (4 + 8)
        # remat boundaries: one activation per layer, written + read twice
        act = 3.0 * L * B * S * d * 2
        return (weight_traffic + act) / n_chips
    if shape.kind == "prefill":
        act = 2.0 * L * B * S * d * 2
        kv = B * kv_cache_bytes_per_seq(cfg, S)
        return (wbytes + act + kv) / n_chips
    # decode: read all (active) weights once + read the whole cache
    active_bytes = p["active"] * weight_bits / 8.0
    kv = B * kv_cache_bytes_per_seq(cfg, S)
    return (active_bytes + kv) / n_chips


def model_flops(cfg, shape) -> float:
    """6*N_active*D for train; 2*N_active per generated token for decode;
    2*N_active*D for prefill."""
    p = model_params(cfg)["active"]
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        return 6.0 * p * B * S
    if shape.kind == "prefill":
        return 2.0 * p * B * S
    return 2.0 * p * B  # decode: one token per sequence


def analyze(rec: dict) -> Optional[dict]:
    if rec.get("status") != "OK":
        return None
    cfg = get_config(rec["arch"])
    shape = SHAPE_BY_NAME[rec["shape"]]
    # prefer per-layer-exact extrapolated terms (scan bodies are counted
    # once by XLA cost analysis; see dryrun.extrapolate_costs)
    ext = rec.get("extrapolated")
    flops = ext["flops"] if ext else rec["flops"]
    bytes_acc = ext["bytes_accessed"] if ext else rec["bytes_accessed"]
    coll_total = (ext["collective_total"] if ext
                  else rec["collective_bytes"].get("total", 0))
    wb = 16.0
    if rec.get("quant_bits"):
        # ICQuant storage: n code bits + ~0.31 index + codebooks
        wb = rec["quant_bits"] + 0.31 + 0.1
    compute_s = flops / PEAK_FLOPS
    memory_hi_s = bytes_acc / HBM_BW                   # XLA per-op bound
    memory_lo_s = memory_floor_bytes(
        cfg, shape, rec["n_chips"], weight_bits=wb
    ) / HBM_BW                                         # analytic floor
    coll_s = coll_total / ICI_BW
    terms = dict(compute=compute_s, memory=memory_lo_s, collective=coll_s)
    dominant = max(terms, key=terms.get)
    mf = model_flops(cfg, shape) / rec["n_chips"]     # per-device
    useful = mf / flops if flops > 0 else 0.0
    bound_s = max(terms.values())
    # roofline fraction: useful work at peak / achievable step time
    frac = (mf / PEAK_FLOPS) / bound_s if bound_s > 0 else 0.0
    return dict(
        arch=rec["arch"], shape=rec["shape"], mesh=rec["mesh"],
        compute_s=compute_s, memory_s=memory_lo_s,
        memory_xla_s=memory_hi_s, collective_s=coll_s,
        dominant=dominant, model_flops_per_chip=mf,
        usefulness=useful, roofline_fraction=frac,
        peak_hbm_bytes=rec["memory"]["peak_bytes"],
    )


def run() -> list:
    rows = []
    for path in sorted(glob.glob(os.path.join(ARTIFACT_DIR, "*.json"))):
        with open(path) as f:
            rec = json.load(f)
        if rec.get("status") == "SKIP":
            emit(f"roofline/{rec['arch']}/{rec['shape']}", 0.0,
                 f"SKIP:{rec['reason']}")
            continue
        if rec.get("mesh") == "2x16x16":
            # multi-pod lowerings prove the pod axis shards; their scanned
            # cost numbers are not roofline-grade (scan body counted once)
            emit(
                f"dryrun/{rec['arch']}/{rec['shape']}/multipod", 0.0,
                f"status=OK;compile_s={rec['compile_seconds']};"
                f"collective_bytes={rec['collective_bytes'].get('total', 0):.3e}",
            )
            continue
        a = analyze(rec)
        if a is None:
            emit(f"roofline/{rec['arch']}/{rec['shape']}", 0.0, "FAILED")
            continue
        rows.append(a)
        tag = f"/q{rec['quant_bits']}" if rec.get("quant_bits") else ""
        emit(
            f"roofline/{a['arch']}/{a['shape']}/{a['mesh']}{tag}", 0.0,
            f"compute_s={a['compute_s']:.3e};memory_s={a['memory_s']:.3e};"
            f"memory_xla_s={a['memory_xla_s']:.3e};"
            f"collective_s={a['collective_s']:.3e};dom={a['dominant']};"
            f"useful={a['usefulness']:.3f};roofline={a['roofline_fraction']:.3f}",
        )
    if not rows:
        emit("roofline/none", 0.0,
             "no dry-run artifacts: run python -m repro.launch.dryrun --arch all")
    return rows


if __name__ == "__main__":
    run()
