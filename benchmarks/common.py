"""Shared helpers for the benchmark harness."""
from __future__ import annotations

import time
from typing import Callable, Dict, Tuple

import numpy as np

# Llama2-7B linear-layer geometries (paper's analysis model): the
# statistics figures sweep these shapes with synthetic heavy-tailed
# weights calibrated to the paper's §2 measurements.
LLAMA2_7B_LAYERS: Dict[str, Tuple[int, int]] = {
    "q_proj": (4096, 4096),
    "k_proj": (4096, 4096),
    "v_proj": (4096, 4096),
    "o_proj": (4096, 4096),
    "up_proj": (11008, 4096),
    "gate_proj": (11008, 4096),
    "down_proj": (4096, 11008),
}

# statistics benches subsample rows to keep the suite fast
BENCH_ROWS = 256


def layer_weights(name: str, seed: int = 0, rows: int = BENCH_ROWS,
                  df: float = 5.0) -> np.ndarray:
    """Synthetic weights with the named layer's row geometry (d_in kept,
    rows subsampled)."""
    d_out, d_in = LLAMA2_7B_LAYERS[name]
    rng = np.random.default_rng(abs(hash((name, seed))) % 2**31)
    r = min(rows, d_out)
    return (rng.standard_t(df, size=(r, d_in)) * 0.02).astype(np.float32)


def timeit(fn: Callable, *args, warmup: int = 1, iters: int = 3) -> float:
    """Median wall-time in microseconds (values are block_until_ready'd
    when jax arrays)."""
    def run():
        out = fn(*args)
        for leaf in (out if isinstance(out, (tuple, list)) else (out,)):
            if hasattr(leaf, "block_until_ready"):
                leaf.block_until_ready()
        return out

    for _ in range(warmup):
        run()
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        run()
        times.append((time.perf_counter() - t0) * 1e6)
    return float(np.median(times))


def emit(name: str, us_per_call: float, derived: str) -> None:
    """The harness output contract: ``name,us_per_call,derived`` CSV."""
    print(f"{name},{us_per_call:.1f},{derived}")
