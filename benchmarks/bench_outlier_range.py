"""Paper Figure 1(a)/6: normalized range occupied by top-gamma outliers,
per layer type. Claim: ~5% of weights take ~50% of the value range."""
from __future__ import annotations

import numpy as np

from benchmarks.common import LLAMA2_7B_LAYERS, emit, layer_weights, timeit
from repro.core.stats import range_taken_by_outliers

GAMMAS = (0.01, 0.03, 0.05, 0.08, 0.10)


def run() -> dict:
    out = {}
    for name in LLAMA2_7B_LAYERS:
        W = layer_weights(name)
        us = timeit(range_taken_by_outliers, W, GAMMAS, iters=1)
        fr = range_taken_by_outliers(W, GAMMAS)
        out[name] = fr
        emit(
            f"outlier_range/{name}", us,
            ";".join(f"g={g:.2f}:frac={fr[g]:.3f}" for g in GAMMAS),
        )
    mean5 = float(np.mean([v[0.05] for v in out.values()]))
    emit("outlier_range/mean_top5pct", 0.0,
         f"frac={mean5:.3f};paper_claim~0.5")
    return out


if __name__ == "__main__":
    run()
