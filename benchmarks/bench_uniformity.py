"""Paper Tables 1/5: chi-square uniformity of outlier positions.

iid-initialized (and trained-equivalent) weights give rejection rates
around the significance level (~3-5%); a synthetically clustered layer
(our stand-in for the paper's anomalous o_proj) is overwhelmingly
rejected; a random permutation repairs it (Appendix C.2)."""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from benchmarks.common import LLAMA2_7B_LAYERS, emit, layer_weights, timeit
from repro.core.permute import make_permutation, permute_in
from repro.core.stats import chi_square_uniformity


def run() -> dict:
    out = {}
    for name in LLAMA2_7B_LAYERS:
        W = layer_weights(name)
        us = timeit(chi_square_uniformity, W, 0.0625, 256, iters=1)
        rej = chi_square_uniformity(W, gamma=0.0625, group=256)
        out[name] = rej
        emit(f"uniformity/{name}", us, f"rejection={rej:.4f};alpha=0.05")

    # clustered stand-in for the paper's o_proj anomaly + permutation fix
    rng = np.random.default_rng(0)
    W = rng.standard_normal((256, 4096)).astype(np.float32) * 0.01
    W[:, :512] *= 30.0
    rej_bad = chi_square_uniformity(W, gamma=0.0625)
    perm = make_permutation(4096, seed=1)
    rej_fixed = chi_square_uniformity(
        np.asarray(permute_in(jnp.asarray(W), perm)), gamma=0.0625
    )
    emit("uniformity/clustered", 0.0, f"rejection={rej_bad:.3f}")
    emit("uniformity/clustered_permuted", 0.0,
         f"rejection={rej_fixed:.3f};appendix_C2_fix")
    out["clustered"] = rej_bad
    out["clustered_permuted"] = rej_fixed
    return out


if __name__ == "__main__":
    run()
