"""Dry-run machinery on a small placeholder mesh (subprocess: the device
count must be forced before jax init, exactly like the real dry-run)."""
import json
import os
import subprocess
import sys

import pytest

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json, dataclasses
import jax
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import get_config, smoke_variant
from repro.configs.base import ShapeConfig
from repro.launch import specs as sp
from repro.launch.dryrun import collective_bytes
from repro.launch.steps import make_train_step
from repro.optim import AdamWConfig
from repro.runtime.sharding import param_specs, batch_specs

from repro.launch.mesh import make_mesh
mesh = make_mesh((2, 4), ("data", "model"))
cfg = dataclasses.replace(smoke_variant(get_config("internlm2-1.8b")),
                          param_dtype="bfloat16", remat=True,
                          d_model=128, d_ff=256, n_heads=8, n_kv_heads=4)
opt_cfg = AdamWConfig(state_dtype="bfloat16")
shape = ShapeConfig("t", 64, 8, "train")
params = sp.param_structs(cfg)
p_sh = jax.tree.map(lambda s: NamedSharding(mesh, s),
                    param_specs(params, mesh, fsdp=True))
batch = sp.input_specs(cfg, shape)
b_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), batch_specs(batch, mesh))
opt = sp.opt_structs(cfg, opt_cfg)
o_mu = jax.tree.map(lambda s: NamedSharding(mesh, s),
                    param_specs(opt["adam"]["mu"], mesh, fsdp=True))
o_sh = dict(adam=dict(mu=o_mu, nu=o_mu, step=NamedSharding(mesh, P())))
with mesh:
    lowered = jax.jit(make_train_step(cfg, opt_cfg),
                      in_shardings=(p_sh, o_sh, b_sh)).lower(params, opt, batch)
    compiled = lowered.compile()
cost = compiled.cost_analysis()
if isinstance(cost, (list, tuple)):   # jax < 0.6: list of per-device dicts
    cost = cost[0] if cost else {}
coll = collective_bytes(compiled.as_text())
print(json.dumps(dict(
    n_devices=len(jax.devices()),
    flops=float(cost.get("flops", -1)),
    collective_total=coll.get("total", 0),
    has_all_reduce=coll.get("all-reduce", 0) > 0 or coll.get("all-gather", 0) > 0,
)))
"""


@pytest.mark.slow
def test_dryrun_lowering_on_8_device_mesh():
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run(
        [sys.executable, "-c", _SCRIPT], capture_output=True, text=True,
        env=env, cwd=os.path.dirname(os.path.dirname(__file__)), timeout=600,
    )
    assert out.returncode == 0, out.stderr[-2000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["n_devices"] == 8
    assert res["flops"] > 0
    # FSDP + TP sharding must produce collectives in the compiled module
    assert res["collective_total"] > 0
    assert res["has_all_reduce"]


def test_collective_bytes_parser():
    from repro.launch.dryrun import collective_bytes

    hlo = """
  %ag = bf16[8,128]{1,0} all-gather(bf16[1,128]{1,0} %x), replica_groups={}
  %ar = f32[256]{0} all-reduce(f32[256]{0} %y), to_apply=%add
  %rs.1 = f32[32]{0} reduce-scatter(f32[256]{0} %z), dimensions={0}
  %cp = u32[16]{0} collective-permute(u32[16]{0} %w)
  %not_a_collective = f32[999]{0} add(f32[999]{0} %a, f32[999]{0} %b)
"""
    got = collective_bytes(hlo)
    assert got["all-gather"] == 8 * 128 * 2
    assert got["all-reduce"] == 256 * 4
    assert got["reduce-scatter"] == 32 * 4
    assert got["collective-permute"] == 16 * 4
    assert got["total"] == sum(
        v for k, v in got.items() if k != "total"
    )
