"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import core
from repro.core.stats import heavy_tailed_weights
from repro.kernels import backend, ops, ref


@pytest.mark.parametrize("n_bits", [2, 3, 4, 8])
@pytest.mark.parametrize("shape", [(16, 96), (64, 512), (48, 330), (128, 1024)])
def test_dequant_kernel_matches_ref(n_bits, shape):
    R, C = shape
    W = heavy_tailed_weights(R, C, seed=n_bits * 100 + R)
    pk = core.quantize(jnp.asarray(W), n_bits, gamma=0.05)
    rt = ops.to_runtime(pk)
    w_ref = ref.dequant_ref(rt["codes"], rt["bitmap"], rt["codebooks"],
                            n_bits, C)
    # oracle chain: ref equals the core library reconstruction
    np.testing.assert_allclose(
        np.asarray(w_ref), np.asarray(core.dequantize(pk)), rtol=1e-6
    )
    w_k = ops.dequant(rt, block_r=32, block_c=320)
    np.testing.assert_allclose(np.asarray(w_k), np.asarray(w_ref), rtol=1e-6)


@pytest.mark.parametrize("n_bits", [2, 3, 4])
@pytest.mark.parametrize("M", [1, 8, 33])
@pytest.mark.parametrize("x_dtype", [jnp.float32, jnp.bfloat16])
def test_matmul_kernel_matches_ref(n_bits, M, x_dtype):
    R, C = 64, 512
    W = heavy_tailed_weights(R, C, seed=7)
    pk = core.quantize(jnp.asarray(W), n_bits, gamma=0.05)
    rt = ops.to_runtime(pk)
    x = jnp.asarray(
        np.random.default_rng(M).standard_normal((M, C)), x_dtype
    )
    y_ref = ref.matmul_ref(x.astype(jnp.float32), rt["codes"], rt["bitmap"],
                           rt["codebooks"], n_bits, C)
    y_k = ops.matmul(x, rt, block_m=16, block_n=32, block_k=256)
    np.testing.assert_allclose(
        np.asarray(y_k), np.asarray(y_ref), rtol=5e-2 if x_dtype == jnp.bfloat16 else 2e-5,
        atol=5e-2 if x_dtype == jnp.bfloat16 else 2e-5,
    )


@pytest.mark.parametrize("shape", [(4, 100), (20, 700), (64, 2048)])
@pytest.mark.parametrize("C", [4, 16])
def test_kmeans_assign_matches_ref(shape, C):
    R, L = shape
    rng = np.random.default_rng(R * L)
    w = jnp.asarray(rng.standard_normal((R, L)), jnp.float32)
    wt = jnp.asarray(np.abs(rng.standard_normal((R, L))), jnp.float32)
    c = jnp.asarray(np.sort(rng.standard_normal((R, C)), axis=-1), jnp.float32)
    ws_r, vs_r = ref.kmeans_assign_ref(w, wt, c)
    ws_k, vs_k = ops.kmeans_assign(w, wt, c, block_r=16, block_l=256)
    np.testing.assert_allclose(np.asarray(ws_k), np.asarray(ws_r),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(vs_k), np.asarray(vs_r),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("n_bits", [2, 3, 4])
@pytest.mark.parametrize("shape", [(64, 512), (48, 330)])  # aligned + ragged
@pytest.mark.parametrize("M", [1, 8, 300])                 # both dispatch arms
def test_dispatch_parity_fused_vs_dequantize(n_bits, shape, M):
    """backend.linear_apply (pallas arms) ≍ dequantize()-then-matmul.

    M ∈ {1, 8} rides the fused icq_matmul kernel, M = 300 the
    icq_dequant-then-dense-matmul arm; (48, 330) is ragged w.r.t. the
    block lcm for every n_bits."""
    R, C = shape
    W = heavy_tailed_weights(R, C, seed=n_bits * 10 + R)
    pk = core.quantize(jnp.asarray(W), n_bits, gamma=0.05)
    from repro.kernels.platform import decode_m_threshold

    prep = backend.prepare(pk, backend="pallas")
    want_path = "fused" if M <= decode_m_threshold() else "dequant"
    assert backend.choose_path(M, prep) == want_path
    x = jnp.asarray(
        np.random.default_rng(M).standard_normal((M, C)), jnp.float32)
    y_ref = np.asarray(x @ core.dequantize(pk).T)
    y = np.asarray(backend.linear_apply(x, prep))
    np.testing.assert_allclose(y, y_ref, rtol=2e-5, atol=2e-5)


def test_dispatch_xla_arm_bitwise_equals_reference():
    """The pure-XLA arm (CPU default) must reproduce the reference
    dequantize path bit-for-bit (token-parity guarantee for serving)."""
    W = heavy_tailed_weights(48, 330, seed=3)
    pk = core.quantize(jnp.asarray(W), 3, gamma=0.05)
    prep = backend.prepare(pk, backend="xla")
    x = jnp.asarray(
        np.random.default_rng(0).standard_normal((2, 5, 330)), jnp.float32)
    y_ref = np.asarray(x @ core.dequantize(pk).T)
    np.testing.assert_array_equal(
        np.asarray(backend.linear_apply(x, prep)), y_ref)


@pytest.mark.parametrize("n_bits", [2, 3, 4, 8])
@pytest.mark.parametrize("shape", [(16, 96), (48, 330), (64, 512)])
def test_dequant_kernel_v2_matches_ref(n_bits, shape):
    """v2 in-kernel gap->selector decode ≍ the core reconstruction."""
    R, C = shape
    W = heavy_tailed_weights(R, C, seed=n_bits * 100 + R)
    pk = core.quantize(jnp.asarray(W), n_bits, gamma=0.05)
    rt = ops.to_runtime(pk, fmt="v2", tile=128)
    w_k = ops.dequant(rt, block_r=32)
    np.testing.assert_array_equal(
        np.asarray(w_k), np.asarray(core.dequantize(pk)))
    # v2's column block is the checkpoint tile: a block_c request is a
    # caller error, not something to silently ignore
    with pytest.raises(TypeError):
        ops.dequant(rt, block_c=64)


@pytest.mark.parametrize("n_bits", [2, 3, 4])
@pytest.mark.parametrize("M", [1, 8, 33])
def test_matmul_kernel_v2_matches_ref(n_bits, M):
    R, C = 64, 512
    W = heavy_tailed_weights(R, C, seed=7)
    pk = core.quantize(jnp.asarray(W), n_bits, gamma=0.05)
    rt = ops.to_runtime(pk, fmt="v2", tile=256)
    x = jnp.asarray(
        np.random.default_rng(M).standard_normal((M, C)), jnp.float32)
    y_ref = x.astype(jnp.float32) @ core.dequantize(pk).T
    y_k = ops.matmul(x, rt, block_m=16, block_n=32)
    np.testing.assert_allclose(
        np.asarray(y_k), np.asarray(y_ref), rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("n_bits", [2, 4])
@pytest.mark.parametrize("M", [1, 300])     # fused arm, dequant arm
def test_v1_v2_bitwise_parity_both_arms(n_bits, M):
    """Acceptance: with identical blocking geometry the v2 stream decode
    must be bit-identical to the v1 bitmap path on BOTH dispatch arms
    (same selector -> same gathered weights -> same f32 accumulation)."""
    R, C = 64, 512                           # aligned: v1/v2 snap equally
    pk = core.quantize(
        jnp.asarray(heavy_tailed_weights(R, C, seed=n_bits)), n_bits,
        gamma=0.05)
    blocks = (16, 32, 256)
    p1 = backend.prepare(pk, backend="pallas", fmt="v1", blocks=blocks)
    p2 = backend.prepare(pk, backend="pallas", fmt="v2", blocks=blocks)
    assert (p1.block_n, p1.block_k) == (p2.block_n, p2.block_k)
    np.testing.assert_array_equal(
        np.asarray(backend.dequantize_prepared(p1)),
        np.asarray(backend.dequantize_prepared(p2)))
    from repro.kernels.platform import decode_m_threshold
    want = "fused" if M <= decode_m_threshold() else "dequant"
    assert backend.choose_path(M, p1) == want
    x = jnp.asarray(
        np.random.default_rng(M).standard_normal((M, C)), jnp.float32)
    np.testing.assert_array_equal(
        np.asarray(backend.linear_apply(x, p1)),
        np.asarray(backend.linear_apply(x, p2)))


@pytest.mark.parametrize("fmt", ["v1", "v2"])
def test_dispatch_xla_arm_bitwise_equals_reference_both_fmts(fmt):
    """The pure-XLA arm must reproduce the reference dequantize path
    bit-for-bit in either runtime format (token-parity guarantee): the
    v2 checkpoint decode yields the exact selector the stream encodes."""
    W = heavy_tailed_weights(48, 330, seed=3)
    pk = core.quantize(jnp.asarray(W), 3, gamma=0.05)
    prep = backend.prepare(pk, backend="xla", fmt=fmt)
    assert prep.fmt == fmt
    x = jnp.asarray(
        np.random.default_rng(0).standard_normal((2, 5, 330)), jnp.float32)
    y_ref = np.asarray(x @ core.dequantize(pk).T)
    np.testing.assert_array_equal(
        np.asarray(backend.linear_apply(x, prep)), y_ref)


@pytest.mark.parametrize("n_bits", [2, 3])
@pytest.mark.parametrize("M", [1, 300])
def test_dispatch_parity_v2_ragged(n_bits, M):
    """v2 pallas arms on a ragged shape (block lcm does not divide d_in)
    still match the reference to f32-accumulation tolerance."""
    R, C = 48, 330
    pk = core.quantize(
        jnp.asarray(heavy_tailed_weights(R, C, seed=n_bits * 10 + R)),
        n_bits, gamma=0.05)
    prep = backend.prepare(pk, backend="pallas", fmt="v2")
    x = jnp.asarray(
        np.random.default_rng(M).standard_normal((M, C)), jnp.float32)
    y_ref = np.asarray(x @ core.dequantize(pk).T)
    np.testing.assert_allclose(
        np.asarray(backend.linear_apply(x, prep)), y_ref,
        rtol=2e-5, atol=2e-5)


def test_runtime_format_bits():
    """Runtime overlay = n + 1 + codebooks bits; storage = n + ~0.31."""
    W = heavy_tailed_weights(256, 4096, seed=9)
    pk = core.quantize(jnp.asarray(W), 2, gamma=0.05)
    rt = ops.to_runtime(pk)
    rt_bits = ops.runtime_bits_per_weight(rt)
    st_bits = pk.bits_per_weight()["total"]
    assert st_bits < rt_bits < st_bits + 0.85   # bitmap costs ~0.7 extra
    assert rt_bits < 16 / 4                     # still ~4x under bf16


def test_matmul_kernel_lowers_for_tpu():
    """The kernel must *lower* (not execute) for a TPU-like target: build
    the ClosedJaxpr via abstract eval without interpret mode to catch
    Python-level BlockSpec errors."""
    W = heavy_tailed_weights(64, 512, seed=10)
    pk = core.quantize(jnp.asarray(W), 4, gamma=0.05)
    rt = ops.to_runtime(pk)
    x = jnp.zeros((8, 512), jnp.float32)
    jax.eval_shape(
        lambda xx, cc, bb, kk: ops.matmul(xx, dict(rt, codes=cc, bitmap=bb,
                                                   codebooks=kk)),
        x, rt["codes"], rt["bitmap"], rt["codebooks"],
    )


def test_v2_kernels_lower_for_tpu():
    """Same Python-level lowering check for the v2 stream-decode kernels
    (dynamic checkpoint slices, chunked selector compare)."""
    W = heavy_tailed_weights(64, 512, seed=10)
    pk = core.quantize(jnp.asarray(W), 4, gamma=0.05)
    rt = ops.to_runtime(pk, fmt="v2")
    x = jnp.zeros((8, 512), jnp.float32)
    jax.eval_shape(
        lambda xx, cc, ss, oo, dd, kk: ops.matmul(
            xx, dict(rt, codes=cc, syms=ss, offs=oo, dbase=dd,
                     codebooks=kk)),
        x, rt["codes"], rt["syms"], rt["offs"], rt["dbase"],
        rt["codebooks"],
    )
    jax.eval_shape(
        lambda cc, ss, oo, dd, kk: ops.dequant(
            dict(rt, codes=cc, syms=ss, offs=oo, dbase=dd, codebooks=kk)),
        rt["codes"], rt["syms"], rt["offs"], rt["dbase"], rt["codebooks"],
    )


# ---------------------------------------------------------------------------
# bf16 one-hot codebook-select option (ICQ_ONEHOT_DTYPE)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("fmt", ["v1", "v2"])
@pytest.mark.parametrize("n_bits", [2, 3])
def test_onehot_bf16_parity_tolerance_both_kernels(fmt, n_bits):
    """onehot='bf16' halves the (BR, BC, C) select temporary; the result
    is each codebook level rounded to bf16 — matmul and dequant must
    agree with the f32 one-hot to bf16 mantissa tolerance, and the f32
    path must stay bitwise-exact against the reference."""
    R, C = 64, 512
    W = heavy_tailed_weights(R, C, seed=n_bits * 11)
    pk = core.quantize(jnp.asarray(W), n_bits, gamma=0.05)
    rt = ops.to_runtime(pk, fmt=fmt, **(dict(tile=256) if fmt == "v2" else {}))

    kw = dict(block_r=32) if fmt == "v2" else dict(block_r=32, block_c=256)
    w32 = np.asarray(ops.dequant(rt, onehot="f32", **kw))
    wbf = np.asarray(ops.dequant(rt, onehot="bf16", **kw))
    np.testing.assert_array_equal(w32, np.asarray(core.dequantize(pk)))
    np.testing.assert_allclose(wbf, w32, rtol=8e-3, atol=8e-3)
    assert not np.array_equal(wbf, w32)   # bf16 rounding is real

    x = jnp.asarray(
        np.random.default_rng(1).standard_normal((8, C)), jnp.float32)
    mkw = dict(block_m=8, block_n=32)
    if fmt == "v1":
        mkw["block_k"] = 256
    y32 = np.asarray(ops.matmul(x, rt, onehot="f32", **mkw))
    ybf = np.asarray(ops.matmul(x, rt, onehot="bf16", **mkw))
    np.testing.assert_allclose(ybf, y32, rtol=2e-2, atol=2e-2)


def test_onehot_env_default_and_vmem_estimate(monkeypatch):
    from repro.kernels.icq_dequant import onehot_itemsize
    from repro.kernels.platform import default_onehot_dtype

    monkeypatch.delenv("ICQ_ONEHOT_DTYPE", raising=False)
    assert default_onehot_dtype() == "f32" and onehot_itemsize() == 4
    monkeypatch.setenv("ICQ_ONEHOT_DTYPE", "bf16")
    assert default_onehot_dtype() == "bf16" and onehot_itemsize() == 2
    monkeypatch.setenv("ICQ_ONEHOT_DTYPE", "fp8")
    with pytest.raises(ValueError):
        default_onehot_dtype()

    # the bf16 one-hot halves the dominant VMEM term, so the same block
    # candidate bills roughly half the budget for large C
    e32 = backend.vmem_bytes_estimate(128, 128, 512, n_bits=3, C=16,
                                      onehot="f32")
    ebf = backend.vmem_bytes_estimate(128, 128, 512, n_bits=3, C=16,
                                      onehot="bf16")
    assert ebf < e32


def test_onehot_qualifies_autotune_keys_and_rejects_bad_values(monkeypatch):
    """VMEM admission depends on the one-hot width, so block winners
    tuned under bf16 must never be replayed by an f32 run (and vice
    versa): the dtype is part of the cache key. Bad explicit kwargs are
    a ValueError at the kernel entry, not a KeyError mid-trace."""
    from repro.kernels import autotune

    monkeypatch.delenv("ICQ_ONEHOT_DTYPE", raising=False)
    k_f32 = autotune.matmul_key(1, 16, 96, 4, "pallas", True)
    k_bf16 = autotune.matmul_key(1, 16, 96, 4, "pallas", True,
                                 onehot="bf16")
    assert k_f32 != k_bf16 and k_bf16.endswith("_oh-bf16")
    # the un-suffixed f32 spelling keeps existing cache files valid
    assert "oh-" not in k_f32
    # env default flows into un-pinned keys
    monkeypatch.setenv("ICQ_ONEHOT_DTYPE", "bf16")
    assert autotune.matmul_key(1, 16, 96, 4, "pallas", True) == k_bf16
    assert autotune.dequant_key(16, 96, 4, "pallas", True,
                                fmt="v2").endswith("_v2_oh-bf16")
    monkeypatch.delenv("ICQ_ONEHOT_DTYPE", raising=False)

    W = heavy_tailed_weights(16, 96, seed=0)
    pk = core.quantize(jnp.asarray(W), 4, gamma=0.05)
    rt = ops.to_runtime(pk)
    with pytest.raises(ValueError, match="onehot"):
        ops.dequant(rt, onehot="fp8")
    with pytest.raises(ValueError, match="onehot"):
        ops.matmul(jnp.zeros((2, 96), jnp.float32), rt, onehot="f16")


# ---------------------------------------------------------------------------
# bf16 accumulator option (ICQ_ACCUM_DTYPE)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("fmt", ["v1", "v2"])
@pytest.mark.parametrize("n_bits", [2, 4])
def test_accum_bf16_parity_tolerance_both_formats(fmt, n_bits):
    """accum='bf16' halves the fused matmul's VMEM accumulator scratch;
    partial sums round to bf16 at every K step, so the result must agree
    with the f32 accumulator to bf16 mantissa tolerance — and the f32
    accumulator path must stay bitwise what it was (the default)."""
    R, C = 48, 512
    W = heavy_tailed_weights(R, C, seed=n_bits * 7)
    pk = core.quantize(jnp.asarray(W), n_bits, gamma=0.05)
    rt = ops.to_runtime(pk, fmt=fmt, **(dict(tile=256) if fmt == "v2" else {}))

    x = jnp.asarray(
        np.random.default_rng(5).standard_normal((8, C)), jnp.float32)
    mkw = dict(block_m=8, block_n=16)
    if fmt == "v1":
        mkw["block_k"] = 256
    y32 = np.asarray(ops.matmul(x, rt, accum="f32", **mkw))
    ydef = np.asarray(ops.matmul(x, rt, **mkw))
    np.testing.assert_array_equal(ydef, y32)    # f32 is the default
    ybf = np.asarray(ops.matmul(x, rt, accum="bf16", **mkw))
    np.testing.assert_allclose(ybf, y32, rtol=2e-2, atol=2e-2)
    assert not np.array_equal(ybf, y32)         # bf16 rounding is real


def test_accum_env_default_vmem_estimate_and_keys(monkeypatch):
    from repro.kernels import autotune
    from repro.kernels.platform import default_accum_dtype

    monkeypatch.delenv("ICQ_ACCUM_DTYPE", raising=False)
    assert default_accum_dtype() == "f32"
    monkeypatch.setenv("ICQ_ACCUM_DTYPE", "bf16")
    assert default_accum_dtype() == "bf16"
    monkeypatch.setenv("ICQ_ACCUM_DTYPE", "fp8")
    with pytest.raises(ValueError):
        default_accum_dtype()
    monkeypatch.delenv("ICQ_ACCUM_DTYPE", raising=False)

    # the bf16 accumulator shaves the acc-scratch VMEM term
    e32 = backend.vmem_bytes_estimate(128, 128, 512, n_bits=3, C=16,
                                      accum="f32")
    ebf = backend.vmem_bytes_estimate(128, 128, 512, n_bits=3, C=16,
                                      accum="bf16")
    assert ebf == e32 - 128 * 128 * 2

    # accumulator width is part of the autotune key (block winners tuned
    # under bf16 must not be replayed by f32 runs); f32 keeps the
    # un-suffixed spelling so existing cache files stay valid
    k_f32 = autotune.matmul_key(1, 16, 96, 4, "pallas", True)
    k_bf16 = autotune.matmul_key(1, 16, 96, 4, "pallas", True,
                                 accum="bf16")
    assert k_f32 != k_bf16 and k_bf16.endswith("_acc-bf16")
    assert "acc-" not in k_f32
    monkeypatch.setenv("ICQ_ACCUM_DTYPE", "bf16")
    assert autotune.matmul_key(1, 16, 96, 4, "pallas", True) == k_bf16
    monkeypatch.delenv("ICQ_ACCUM_DTYPE", raising=False)

    W = heavy_tailed_weights(16, 96, seed=0)
    pk = core.quantize(jnp.asarray(W), 4, gamma=0.05)
    rt = ops.to_runtime(pk)
    with pytest.raises(ValueError, match="accum"):
        ops.matmul(jnp.zeros((2, 96), jnp.float32), rt, accum="f16")
