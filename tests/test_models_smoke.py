"""Per-architecture smoke tests: reduced config, one forward + one train
step on CPU; output shapes + no NaNs; decode parity with full forward."""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHITECTURES, get_config, smoke_variant
from repro.launch.steps import (
    init_opt_state,
    loss_fn,
    make_cache,
    make_decode_step,
    make_train_step,
)
from repro.models import encdec_apply, init_model, lm_apply
from repro.optim import AdamWConfig

ARCH_IDS = sorted(ARCHITECTURES)


def _smoke_batch(cfg, B=2, S=16, seed=0):
    rng = np.random.default_rng(seed)
    if cfg.is_encdec:
        return dict(
            frames=jnp.asarray(
                rng.standard_normal((B, 8, cfg.d_model)), jnp.float32
            ),
            frame_mask=jnp.ones((B, 8), bool),
            tokens=jnp.asarray(
                rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32
            ),
        )
    batch = dict(
        tokens=jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32),
        labels=jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32),
    )
    if cfg.frontend != "none":
        batch["prefix_embeds"] = jnp.asarray(
            rng.standard_normal((B, cfg.frontend_len, cfg.d_model)),
            jnp.float32,
        )
    return batch


def _smoke_cfg(arch):
    return smoke_variant(get_config(arch))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_and_finite(arch):
    cfg = _smoke_cfg(arch)
    params = init_model(jax.random.PRNGKey(0), cfg)
    batch = _smoke_batch(cfg)
    B, S = batch["tokens"].shape
    if cfg.is_encdec:
        logits, _, _, _ = encdec_apply(
            params, cfg, batch["frames"], batch["frame_mask"], batch["tokens"]
        )
        assert logits.shape == (B, S, cfg.vocab_size)
    else:
        logits, _, _ = lm_apply(
            params, cfg, batch["tokens"],
            prefix_embeds=batch.get("prefix_embeds"),
        )
        prefix = cfg.frontend_len if cfg.frontend != "none" else 0
        assert logits.shape == (B, S + prefix, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all()), f"{arch}: non-finite logits"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_one_train_step(arch):
    cfg = _smoke_cfg(arch)
    opt_cfg = AdamWConfig(lr=1e-3, total_steps=10, warmup_steps=1)
    params = init_model(jax.random.PRNGKey(0), cfg)
    opt_state = init_opt_state(params, opt_cfg)
    batch = _smoke_batch(cfg)
    step = jax.jit(make_train_step(cfg, opt_cfg))
    new_params, new_state, metrics = step(params, opt_state, batch)
    assert np.isfinite(float(metrics["loss"])), f"{arch}: non-finite loss"
    assert int(new_state["adam"]["step"]) == 1
    # params actually changed
    delta = sum(
        float(jnp.abs(a - b).sum())
        for a, b in zip(jax.tree.leaves(new_params), jax.tree.leaves(params))
    )
    assert delta > 0.0


@pytest.mark.parametrize("arch", ["internlm2-1.8b", "mixtral-8x7b",
                                  "mamba2-130m", "hymba-1.5b",
                                  "minicpm3-4b"])
def test_decode_matches_forward(arch):
    """Step-by-step cached decode reproduces the full forward logits."""
    cfg = _smoke_cfg(arch)
    params = init_model(jax.random.PRNGKey(0), cfg)
    toks = jnp.asarray(
        np.random.default_rng(1).integers(0, cfg.vocab_size, (2, 12)),
        jnp.int32,
    )
    full, _, _ = lm_apply(params, cfg, toks)
    cache = make_cache(params, cfg, 2, 16)
    decode = make_decode_step(cfg)
    outs = []
    for t in range(12):
        lg, cache = decode(params, cache, toks[:, t : t + 1],
                           jnp.asarray(t, jnp.int32))
        outs.append(lg)
    got = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(full), rtol=3e-4, atol=3e-4
    )


def test_all_ten_architectures_registered():
    assert len(ARCHITECTURES) == 10
    expected = {
        "minicpm3-4b", "internlm2-1.8b", "phi3-mini-3.8b", "llama3.2-1b",
        "pixtral-12b", "mamba2-130m", "seamless-m4t-large-v2", "hymba-1.5b",
        "deepseek-v3-671b", "mixtral-8x7b",
    }
    assert set(ARCHITECTURES) == expected


def test_full_configs_match_assignment():
    c = get_config("deepseek-v3-671b")
    assert (c.n_layers, c.d_model, c.n_heads) == (61, 7168, 128)
    assert (c.n_experts, c.experts_per_token, c.moe_d_ff) == (256, 8, 2048)
    c = get_config("mixtral-8x7b")
    assert (c.n_experts, c.experts_per_token, c.d_ff) == (8, 2, 14336)
    c = get_config("minicpm3-4b")
    assert (c.n_layers, c.d_model, c.vocab_size) == (62, 2560, 73448)
    c = get_config("mamba2-130m")
    assert (c.ssm_state, c.attention_free) == (128, True)
    c = get_config("hymba-1.5b")
    assert (c.d_model, c.n_heads, c.n_kv_heads, c.ssm_state) == (1600, 25, 5, 16)
