"""serving/sampling.py (vectorized per-lane sampler) + serving/metrics.py."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.serving.metrics import MetricsCollector
from repro.serving.sampling import (
    GREEDY,
    SamplingParams,
    lane_arrays,
    sample_tokens,
)


def _call(logits, key=0, **lanes):
    B = logits.shape[0]
    defaults = dict(
        temperature=np.zeros(B, np.float32),
        top_k=np.zeros(B, np.int32),
        top_p=np.ones(B, np.float32),
    )
    defaults.update({k: np.asarray(v) for k, v in lanes.items()})
    return np.asarray(sample_tokens(
        jnp.asarray(logits), jax.random.PRNGKey(key),
        jnp.asarray(defaults["temperature"]),
        jnp.asarray(defaults["top_k"]),
        jnp.asarray(defaults["top_p"]),
        live=defaults.get("live"),
    ))


def test_zero_temperature_is_argmax():
    rng = np.random.default_rng(0)
    logits = rng.standard_normal((4, 37)).astype(np.float32)
    assert (_call(logits) == logits.argmax(-1)).all()


def test_top_k_one_is_argmax_at_any_temperature():
    rng = np.random.default_rng(1)
    logits = rng.standard_normal((3, 50)).astype(np.float32)
    out = _call(logits, temperature=np.full(3, 2.0, np.float32),
                top_k=np.full(3, 1, np.int32))
    assert (out == logits.argmax(-1)).all()


def test_top_k_restricts_support():
    rng = np.random.default_rng(2)
    logits = rng.standard_normal((1, 64)).astype(np.float32)
    top4 = set(np.argsort(-logits[0])[:4].tolist())
    draws = {
        int(_call(logits, key=k, temperature=np.full(1, 1.5, np.float32),
                  top_k=np.full(1, 4, np.int32))[0])
        for k in range(50)
    }
    assert draws <= top4
    assert len(draws) > 1           # actually samples, not just argmax


def test_top_p_tiny_collapses_to_argmax():
    rng = np.random.default_rng(3)
    logits = rng.standard_normal((2, 40)).astype(np.float32)
    out = _call(logits, temperature=np.full(2, 1.0, np.float32),
                top_p=np.full(2, 1e-6, np.float32))
    assert (out == logits.argmax(-1)).all()


def test_per_lane_overrides_mix_greedy_and_sampled():
    rng = np.random.default_rng(4)
    logits = np.tile(rng.standard_normal((1, 100)), (2, 1)).astype(np.float32)
    for k in range(30):
        out = _call(logits, key=k,
                    temperature=np.asarray([0.0, 5.0], np.float32))
        assert out[0] == logits[0].argmax()     # greedy lane pinned
    # hot lane must eventually disagree with argmax at temperature 5
    hot = {int(_call(logits, key=k,
                     temperature=np.asarray([0.0, 5.0], np.float32))[1])
           for k in range(30)}
    assert len(hot) > 1


def test_dead_lanes_masked_to_zero():
    rng = np.random.default_rng(5)
    logits = rng.standard_normal((3, 16)).astype(np.float32) + 3.0
    out = _call(logits, live=np.asarray([True, False, True]))
    assert out[1] == 0
    assert (out[[0, 2]] == logits[[0, 2]].argmax(-1)).all()


def test_same_key_same_tokens():
    rng = np.random.default_rng(6)
    logits = rng.standard_normal((4, 60)).astype(np.float32)
    t = np.full(4, 1.0, np.float32)
    a = _call(logits, key=9, temperature=t)
    b = _call(logits, key=9, temperature=t)
    assert (a == b).all()


def test_sampling_params_validation_and_lane_arrays():
    with pytest.raises(ValueError):
        SamplingParams(temperature=-1.0)
    with pytest.raises(ValueError):
        SamplingParams(top_p=0.0)
    with pytest.raises(ValueError):
        SamplingParams(top_k=-2)
    arrs = lane_arrays([None, SamplingParams(temperature=0.7, top_k=5)])
    assert arrs["temperature"].tolist() == pytest.approx(
        [GREEDY.temperature, 0.7])      # float32 storage
    assert arrs["top_k"].tolist() == [0, 5]


# ---------------------------------------------------------------------------
# metrics
# ---------------------------------------------------------------------------

def test_metrics_collector_summary():
    m = MetricsCollector()
    m.on_submit(0, arrival_time=0.0, prompt_len=4)
    m.on_submit(1, arrival_time=1.0, prompt_len=2)
    m.on_admit(0, 0.5)
    m.on_admit(1, 1.5)
    m.on_step(2, 0, 1.0)
    m.on_first_token(0, 2.0)
    m.on_first_token(1, 3.0)
    m.on_step(2, 0, 3.0)
    m.on_finish(0, 5.0, 7)
    m.on_step(1, 0, 5.0)
    m.on_finish(1, 5.0, 3)
    s = m.summary()
    assert s["requests"] == 2 and s["completed"] == 2
    assert s["generated_tokens"] == 10
    assert s["wall_s"] == 4.0
    assert s["tokens_per_s"] == pytest.approx(10 / 4.0)
    assert s["ttft_mean"] == pytest.approx(2.0)     # (2.0-0.0, 3.0-1.0)
    assert s["queue_wait_mean"] == pytest.approx(0.5)
    assert s["mean_occupancy"] == pytest.approx(5 / 3)
    r0 = m.requests[0]
    assert r0.decode_tokens_per_s == pytest.approx(6 / 3.0)


def test_metrics_unfinished_requests_not_counted():
    m = MetricsCollector()
    m.on_submit(0, 0.0, 3)
    m.on_step(1, 0, 0.0)
    s = m.summary()
    assert s["completed"] == 0 and s["generated_tokens"] == 0
