"""Checkpoint manager: atomicity, restart, retention, elastic restore."""
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.checkpoint import CheckpointError, CheckpointManager


def _tree(seed):
    rng = np.random.default_rng(seed)
    return dict(
        w=jnp.asarray(rng.standard_normal((8, 16)), jnp.float32),
        nested=dict(b=jnp.asarray(rng.standard_normal(4), jnp.bfloat16)),
        step=jnp.asarray(seed, jnp.int32),
    )


def test_save_restore_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    t = _tree(3)
    mgr.save(3, t)
    out = mgr.restore(jax.tree.map(jnp.zeros_like, t))
    for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(t)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_latest_step_and_retention(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, _tree(s))
    assert mgr.latest_step() == 4
    kept = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert len(kept) == 2
    out = mgr.restore(jax.tree.map(jnp.zeros_like, _tree(0)))
    assert int(out["step"]) == 4


def test_no_partial_checkpoint_visible(tmp_path):
    """A .tmp dir must never be listed as a restorable step."""
    mgr = CheckpointManager(str(tmp_path))
    os.makedirs(os.path.join(tmp_path, "step_00000007.tmp"))
    assert mgr.latest_step() is None


def test_restore_missing_raises(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    with pytest.raises(FileNotFoundError):    # CheckpointError subclasses it
        mgr.restore(dict(x=jnp.zeros(1)))


def test_save_writes_terminal_complete_marker(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    d = mgr.save(5, _tree(5))
    assert os.path.exists(os.path.join(d, "MANIFEST-complete"))


def test_partial_save_skipped_and_refused(tmp_path):
    """A step dir without the terminal marker (torn copy / interrupted
    save) must never be selected by latest_step() nor loaded."""
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(2, _tree(2))
    # simulate a torn copy of a newer step: leaf files but no marker
    torn = os.path.join(tmp_path, "step_00000009")
    os.makedirs(torn)
    np.save(os.path.join(torn, "0.npy"), np.zeros(3))
    assert mgr.latest_step() == 2                 # partial never selected
    with pytest.raises(CheckpointError, match="partial"):
        mgr.restore(dict(x=jnp.zeros(1)), step=9)
    out = mgr.restore(jax.tree.map(jnp.zeros_like, _tree(0)))
    assert int(out["step"]) == 2                  # falls back to complete


def test_restore_names_missing_step_and_leaf(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    t = _tree(4)
    d = mgr.save(4, t)
    with pytest.raises(CheckpointError, match="no directory"):
        mgr.restore(jax.tree.map(jnp.zeros_like, t), step=8)
    os.remove(os.path.join(d, "1.npy"))           # lost one leaf file
    with pytest.raises(CheckpointError, match="1.npy"):
        mgr.restore(jax.tree.map(jnp.zeros_like, t))


def test_partial_dir_does_not_consume_retention(tmp_path):
    """Retention must count complete saves only — and never delete a
    markerless dir (it may be the subject of an investigation)."""
    mgr = CheckpointManager(str(tmp_path), keep=2)
    torn = os.path.join(tmp_path, "step_00000001")
    os.makedirs(torn)
    for s in (2, 3, 4):
        mgr.save(s, _tree(s))
    kept = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert "step_00000001" in kept                # untouched
    assert len(kept) == 3                         # 2 complete + 1 partial


def test_elastic_restore_with_sharding_fn(tmp_path):
    """Restore onto a different 'mesh' via sharding_fn (single-device
    NamedSharding here; the code path is identical at scale)."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    mgr = CheckpointManager(str(tmp_path))
    t = _tree(9)
    mgr.save(9, t)
    from repro.launch.mesh import make_mesh
    mesh = make_mesh((1,), ("data",))
    sh = NamedSharding(mesh, P())
    out = mgr.restore(jax.tree.map(jnp.zeros_like, t),
                      sharding_fn=lambda i: sh)
    for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(t)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert all(
        x.sharding == sh for x in jax.tree.leaves(out) if hasattr(x, "sharding")
    )
