"""Continuous SSM/hybrid serving (ISSUE-5 satellite): the lane-reset mask
threaded into ``mamba2_apply`` must make slot recycling equivalent to a
fresh wave cache, so the continuous engine's greedy streams are
token-identical to the wave engine for recurrent mixers too.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, smoke_variant
from repro.models import init_model
from repro.models.ssm import mamba2_apply, mamba2_cache_init, mamba2_init
from repro.serving import GenerationEngine, Request


def _setup(arch):
    cfg = smoke_variant(get_config(arch))
    params = init_model(jax.random.PRNGKey(0), cfg)
    return cfg, params


# ---------------------------------------------------------------------------
# layer-level: reset mask == fresh state, other lanes untouched
# ---------------------------------------------------------------------------

def test_reset_mask_zeroes_only_masked_lanes():
    cfg = smoke_variant(get_config("mamba2-130m"))
    p = mamba2_init(jax.random.PRNGKey(0), cfg)
    B = 3
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(B, 1, cfg.d_model)).astype(np.float32))

    # warm every lane's state with a few tokens
    cache = mamba2_cache_init(cfg, B, per_lane=True)
    for _ in range(4):
        xt = jnp.asarray(
            rng.normal(size=(B, 1, cfg.d_model)).astype(np.float32))
        _, cache = mamba2_apply(p, xt, cfg, cache=cache)

    reset = jnp.asarray(np.array([False, True, False]))
    y_reset, c_reset = mamba2_apply(p, x, cfg, cache=cache, reset=reset)

    # lane 1 must behave exactly like a fresh cache fed the same token
    fresh = mamba2_cache_init(cfg, B, per_lane=True)
    y_fresh, c_fresh = mamba2_apply(p, x, cfg, cache=fresh)
    assert np.array_equal(np.asarray(y_reset[1]).view(np.uint8),
                          np.asarray(y_fresh[1]).view(np.uint8))
    for k in ("conv", "ssm"):
        assert np.array_equal(
            np.asarray(c_reset[k][1]).view(np.uint8),
            np.asarray(c_fresh[k][1]).view(np.uint8))

    # unmasked lanes must be bit-identical to the no-reset step
    y_none, c_none = mamba2_apply(p, x, cfg, cache=cache)
    for i in (0, 2):
        assert np.array_equal(np.asarray(y_reset[i]).view(np.uint8),
                              np.asarray(y_none[i]).view(np.uint8))
        for k in ("conv", "ssm"):
            assert np.array_equal(
                np.asarray(c_reset[k][i]).view(np.uint8),
                np.asarray(c_none[k][i]).view(np.uint8))


def test_mamba2_cache_per_lane_index_shape():
    cfg = smoke_variant(get_config("mamba2-130m"))
    assert mamba2_cache_init(cfg, 2)["index"].shape == ()
    assert mamba2_cache_init(cfg, 2, per_lane=True)["index"].shape == (2,)


# ---------------------------------------------------------------------------
# engine-level: continuous == wave for recurrent mixers
# ---------------------------------------------------------------------------

def _mixed_specs(cfg, n, seed=0):
    rng = np.random.default_rng(seed)
    return [dict(rid=rid,
                 prompt=rng.integers(0, cfg.vocab_size,
                                     int(rng.integers(2, 9))
                                     ).astype(np.int32),
                 max_new_tokens=int(rng.integers(2, 8)))
            for rid in range(n)]


@pytest.mark.parametrize("arch", ["mamba2-130m", "hymba-1.5b"])
def test_ssm_continuous_greedy_token_identical_to_wave(arch):
    """More requests than slots: recycled lanes must restart from zeroed
    conv/ssm state (and, for hybrid, a rewound attention position) and
    reproduce the wave engine's streams exactly."""
    cfg, params = _setup(arch)
    specs = _mixed_specs(cfg, 5)
    out = {}
    for mode in ("wave", "continuous"):
        eng = GenerationEngine(params, cfg, batch_size=2, max_len=32,
                               mode=mode)
        for s in specs:
            eng.submit(Request(**s))
        out[mode] = {rid: r.generated for rid, r in eng.run().items()}
    assert out["continuous"] == out["wave"]


@pytest.mark.parametrize("arch", ["mamba2-130m", "hymba-1.5b"])
def test_ssm_auto_mode_picks_continuous(arch):
    """The ssm/hybrid wave-only gate is lifted: 'auto' now selects the
    continuous engine (no ring cache in the smoke configs)."""
    cfg, params = _setup(arch)
    eng = GenerationEngine(params, cfg, batch_size=2, max_len=16,
                           mode="auto")
    assert eng.mode == "continuous"


def test_ssm_chunked_prefill_falls_back_to_walk():
    """Recurrent state has no per-position validity masking: a chunked
    prefill request degrades to the 1-token walk with a warning."""
    cfg, params = _setup("mamba2-130m")
    with pytest.warns(UserWarning, match="chunked prefill"):
        eng = GenerationEngine(params, cfg, batch_size=2, max_len=16,
                               mode="continuous", prefill_chunk=4)
    assert eng.prefill_chunk == 1 and eng._chunk_step is None


def test_ssm_continuous_fewer_steps_than_wave():
    """The point of lifting the gate: mixed lengths recycle lanes."""
    cfg, params = _setup("mamba2-130m")
    rng = np.random.default_rng(1)
    specs = [
        dict(rid=rid,
             prompt=rng.integers(0, cfg.vocab_size, 3 + 5 * (rid % 2))
             .astype(np.int32),
             max_new_tokens=2 + 10 * (rid % 2))
        for rid in range(6)
    ]
    steps = {}
    for mode in ("wave", "continuous"):
        eng = GenerationEngine(params, cfg, batch_size=2, max_len=32,
                               mode=mode)
        for s in specs:
            eng.submit(Request(**s))
        eng.run()
        steps[mode] = eng.metrics.summary()["steps"]
    assert steps["continuous"] < steps["wave"], steps
