"""Random-permutation folding (paper Appendix C.2): exact invariance."""
import numpy as np
import jax
import jax.numpy as jnp

from repro.core.permute import (
    fold_mlp_block,
    invert,
    make_permutation,
    permute_in,
    permute_out,
)


def test_permutation_inverse():
    p = make_permutation(64, seed=0)
    inv = invert(p)
    np.testing.assert_array_equal(p[inv], np.arange(64))


def test_single_layer_invariance():
    rng = np.random.default_rng(1)
    W = jnp.asarray(rng.standard_normal((32, 64)), jnp.float32)
    x = jnp.asarray(rng.standard_normal((64,)), jnp.float32)
    p = make_permutation(64, seed=2)
    # W x == (W P)(P^T x):   (WP)[:, j] = W[:, p[j]],  (P^T x)[j] = x[p[j]]
    y = W @ x
    y2 = permute_in(W, p) @ x[p]
    np.testing.assert_allclose(np.asarray(y), np.asarray(y2), rtol=1e-5,
                               atol=1e-5)


def test_mlp_block_invariance():
    """SwiGLU block output unchanged after hidden-dim permutation."""
    rng = np.random.default_rng(3)
    d, f = 32, 96
    w_up = jnp.asarray(rng.standard_normal((f, d)), jnp.float32)
    w_gate = jnp.asarray(rng.standard_normal((f, d)), jnp.float32)
    w_down = jnp.asarray(rng.standard_normal((d, f)), jnp.float32)
    x = jnp.asarray(rng.standard_normal((5, d)), jnp.float32)

    def mlp(up, gate, down):
        h = jax.nn.silu(x @ gate.T) * (x @ up.T)
        return h @ down.T

    y0 = mlp(w_up, w_gate, w_down)
    folded, _ = fold_mlp_block(w_up, w_gate, w_down, seed=4)
    y1 = mlp(folded["w_up"], folded["w_gate"], folded["w_down"])
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1), rtol=2e-5,
                               atol=2e-5)


def test_permutation_uniformizes_outliers():
    """Clustered outliers become uniform after a random permutation."""
    from repro.core.stats import chi_square_uniformity

    rng = np.random.default_rng(5)
    W = rng.standard_normal((64, 2048)).astype(np.float32) * 0.01
    W[:, :256] *= 50.0
    assert chi_square_uniformity(W, gamma=0.0625) > 0.9
    p = make_permutation(2048, seed=6)
    Wp = np.asarray(permute_in(jnp.asarray(W), p))
    assert chi_square_uniformity(Wp, gamma=0.0625) < 0.12
