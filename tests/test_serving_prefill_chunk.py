"""Chunked prefill (ISSUE-4): S>1 per-lane scatter parity + engine
token-identity + autotune bucket registration.

The contract under test: chunking only changes *when* cache rows are
written, never what any sampled token sees — so a chunked prompt walk
must produce a bitwise-identical KV cache and identical next-token
logits to the token-by-token walk (including ragged chunk tails and a
recycled slot admitted mid-chunk), and greedy engine output must be
token-identical across wave / chunk=1 / chunk>1.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, smoke_variant
from repro.launch.steps import (
    make_cache,
    make_prefill_chunk_step,
    sync_cache_positions,
)
from repro.models import init_model
from repro.models.model import lm_apply
from repro.serving import GenerationEngine, Request


def _setup(arch):
    cfg = smoke_variant(get_config(arch))
    params = init_model(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _attn_leaves(cache):
    return {k: np.asarray(v) for k, v in cache["stack"]["attn"].items()
            if k != "index"}


# ---------------------------------------------------------------------------
# layer-level: chunked walk == token-by-token walk, bitwise
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["llama3.2-1b", "minicpm3-4b"])
def test_chunked_prompt_walk_bitwise_cache_and_logits(arch):
    """gqa_apply (llama) and mla_apply (minicpm3) S>1 per-lane scatter:
    ragged tails (prompt lengths not multiples of the chunk), one lane
    admitted a chunk late into a recycled position, write-masked
    mid-chunk — cache and next-token logits must match the 1-token walk
    bitwise."""
    cfg, params = _setup(arch)
    B, L, S = 3, 16, 4
    rng = np.random.default_rng(0)
    plens = [8, 5, 6]              # 8 = 2 full chunks, 5/6 = ragged tails
    starts = [0, 0, 4]             # lane 2 admitted mid-run (recycled slot)
    prompts = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
               for n in plens]

    # oracle: token-by-token walk (the PR-3 admission path)
    cache1 = make_cache(params, cfg, B, L, per_lane=True)
    for t in range(max(s + n for s, n in zip(starts, plens))):
        lens = np.zeros(B, np.int32)
        toks = np.zeros((B, 1), np.int32)
        pos = np.zeros(B, np.int32)
        for i in range(B):
            j = t - starts[i]
            if 0 <= j < plens[i]:
                lens[i], toks[i, 0], pos[i] = 1, prompts[i][j], j
        if not lens.any():
            continue
        c = sync_cache_positions(cache1, jnp.asarray(pos.copy()))
        _, cache1, _ = lm_apply(params, cfg, jnp.asarray(toks), cache=c,
                                start_pos=jnp.asarray(pos.copy()),
                                seq_lens=jnp.asarray(lens))
        jax.block_until_ready(cache1)

    # chunked walk through the jitted second program
    chunk_step = jax.jit(make_prefill_chunk_step(cfg))
    cache2 = make_cache(params, cfg, B, L, per_lane=True)
    consumed = np.zeros(B, np.int32)
    for c in range(3):
        lens = np.zeros(B, np.int32)
        toks = np.zeros((B, S), np.int32)
        for i in range(B):
            if i == 2 and c == 0:        # not yet admitted
                continue
            n = min(S, plens[i] - consumed[i])
            if n > 0:
                toks[i, :n] = prompts[i][consumed[i]: consumed[i] + n]
                lens[i] = n
        cache2 = chunk_step(params, cache2, jnp.asarray(toks),
                            jnp.asarray(consumed.copy()), jnp.asarray(lens))
        consumed += lens
    assert list(consumed) == plens

    for name, a in _attn_leaves(cache1).items():
        b = _attn_leaves(cache2)[name]
        assert np.array_equal(a.view(np.uint8), b.view(np.uint8)), (
            f"{name} cache diverges between chunked and 1-token walks")

    # next-token logits (what the first generated token would see)
    nxt = rng.integers(0, cfg.vocab_size, (B, 1)).astype(np.int32)
    pos = np.asarray(plens, np.int32)

    def decode_logits(cache):
        c = sync_cache_positions(cache, jnp.asarray(pos))
        return np.asarray(lm_apply(params, cfg, jnp.asarray(nxt), cache=c,
                                   start_pos=jnp.asarray(pos))[0])

    l1, l2 = decode_logits(cache1), decode_logits(cache2)
    assert np.array_equal(l1.view(np.uint8), l2.view(np.uint8))


def test_seq_lens_requires_per_lane_cache():
    cfg, params = _setup("llama3.2-1b")
    cache = make_cache(params, cfg, 2, 8, per_lane=False)
    with pytest.raises(NotImplementedError):
        lm_apply(params, cfg, jnp.zeros((2, 2), jnp.int32), cache=cache,
                 start_pos=jnp.zeros((), jnp.int32),
                 seq_lens=jnp.ones((2,), jnp.int32))


# ---------------------------------------------------------------------------
# engine-level: greedy token identity + metrics split
# ---------------------------------------------------------------------------

def _mixed_specs(cfg, n, seed=2, prompt_hi=25):
    rng = np.random.default_rng(seed)
    return [dict(rid=rid,
                 prompt=rng.integers(0, cfg.vocab_size,
                                     int(rng.integers(2, prompt_hi))
                                     ).astype(np.int32),
                 max_new_tokens=int(rng.integers(2, 8)))
            for rid in range(n)]


@pytest.mark.parametrize("arch", ["llama3.2-1b", "minicpm3-4b"])
def test_engine_chunked_greedy_token_identical(arch):
    """wave == continuous chunk=1 == continuous chunk=4 (split two-launch
    structure) == continuous chunk=4 fused (one launch per mixed
    iteration), per request, with more requests than slots so lanes
    recycle while neighbors are still mid-chunk."""
    cfg, params = _setup(arch)
    specs = _mixed_specs(cfg, 5)
    out, engines = {}, {}
    for label, kw in (("wave", dict(mode="wave")),
                      ("chunk1", dict(mode="continuous", prefill_chunk=1)),
                      ("chunk4", dict(mode="continuous", prefill_chunk=4,
                                      fused_step=False)),
                      ("fused4", dict(mode="continuous", prefill_chunk=4))):
        eng = GenerationEngine(params, cfg, batch_size=2, max_len=40, **kw)
        for s in specs:
            eng.submit(Request(**s))
        out[label] = {rid: r.generated for rid, r in eng.run().items()}
        engines[label] = eng
    assert out["fused4"] == out["chunk4"] == out["chunk1"] == out["wave"]

    m1 = engines["chunk1"].metrics.summary()
    m4 = engines["chunk4"].metrics.summary()
    mf = engines["fused4"].metrics.summary()
    assert m1["prefill_tokens"] == 0 and m1["prefill_steps"] == 0
    assert m4["prefill_tokens"] > 0 and m4["prefill_steps"] > 0
    # every bulk prompt token is accounted to exactly one program (the
    # interleaved decode step may teacher-force a few bulk tokens while
    # a neighbor lane is still chunking — the chunk program carries the
    # rest)
    total_bulk = sum(len(s["prompt"]) - 1 for s in specs)
    assert (m4["prefill_tokens"] + m4["prompt_decode_tokens"]
            == total_bulk)
    assert m1["prompt_decode_tokens"] == total_bulk
    # draining bulk S-at-a-time must launch fewer programs overall
    assert m4["prefill_steps"] + m4["decode_steps"] < m1["decode_steps"]
    # the fused engine never runs the split chunk program, consumes the
    # whole prompt (final token included) through fused launches, and a
    # mixed iteration costs ONE launch — strictly fewer than the split
    # structure's chunk + decode pairs
    assert mf["fused_steps"] > 0 and mf["prefill_steps"] == 0
    # every prompt token flows through fused launches except final
    # prompt tokens the plain-decode fallthrough happens to consume
    # (at most one per request)
    total_prompt = sum(len(s["prompt"]) for s in specs)
    assert (total_prompt - len(specs) <= mf["prefill_tokens"]
            <= total_prompt)
    assert mf["prompt_decode_tokens"] == 0
    assert mf["launches"] < m4["launches"] < m1["launches"]


def test_chunk1_never_builds_the_chunk_program():
    """prefill_chunk=1 must be the PR-3 engine bit-for-bit: neither the
    chunk program nor the fused program is built, let alone launched.
    With chunking, the default builds the fused program (one launch per
    mixed iteration); fused_step=False restores the split chunk+decode
    pair."""
    cfg, params = _setup("llama3.2-1b")
    eng = GenerationEngine(params, cfg, batch_size=2, max_len=16,
                           mode="continuous", prefill_chunk=1)
    assert eng._chunk_step is None and eng._fused is None
    assert not eng.fused_step
    eng2 = GenerationEngine(params, cfg, batch_size=2, max_len=16,
                            mode="continuous", prefill_chunk=4)
    assert eng2.fused_step
    assert eng2._fused is not None and eng2._chunk_step is None
    eng3 = GenerationEngine(params, cfg, batch_size=2, max_len=16,
                            mode="continuous", prefill_chunk=4,
                            fused_step=False)
    assert not eng3.fused_step
    assert eng3._chunk_step is not None and eng3._fused is None


def test_fused_step_env_default(monkeypatch):
    from repro.serving.engine import default_fused_step

    monkeypatch.delenv("ICQ_FUSED_STEP", raising=False)
    assert default_fused_step() is True
    monkeypatch.setenv("ICQ_FUSED_STEP", "0")
    assert default_fused_step() is False
    monkeypatch.setenv("ICQ_FUSED_STEP", "on")
    assert default_fused_step() is True
    monkeypatch.setenv("ICQ_FUSED_STEP", "banana")
    with pytest.raises(ValueError):
        default_fused_step()


def test_prefill_chunk_env_default(monkeypatch):
    from repro.serving.engine import default_prefill_chunk

    monkeypatch.delenv("ICQ_PREFILL_CHUNK", raising=False)
    assert default_prefill_chunk() == 1
    monkeypatch.setenv("ICQ_PREFILL_CHUNK", "8")
    assert default_prefill_chunk() == 8
    monkeypatch.setenv("ICQ_PREFILL_CHUNK", "0")
    with pytest.raises(ValueError):
        default_prefill_chunk()
    monkeypatch.setenv("ICQ_PREFILL_CHUNK", "banana")
    with pytest.raises(ValueError):
        default_prefill_chunk()


def test_engine_rejects_bad_prefill_chunk():
    cfg, params = _setup("llama3.2-1b")
    with pytest.raises(ValueError):
        GenerationEngine(params, cfg, batch_size=2, max_len=16,
                         mode="continuous", prefill_chunk=0)


# ---------------------------------------------------------------------------
# autotune: the chunk-M bucket reaches the per-arm block table
# ---------------------------------------------------------------------------

def test_register_prefill_m_extends_bucket_table():
    from repro.kernels import autotune, backend

    orig = autotune.PREFILL_MS
    try:
        autotune.register_prefill_m(48)
        assert 48 in autotune.PREFILL_MS
        assert autotune.PREFILL_MS == tuple(sorted(autotune.PREFILL_MS))
        # idempotent; decode M never becomes a bucket
        autotune.register_prefill_m(48)
        assert autotune.PREFILL_MS.count(48) == 1
        autotune.register_prefill_m(1)
        assert 1 not in autotune.PREFILL_MS
        # bucket_m now resolves chunk-sized calls to the new bucket
        assert backend.bucket_m(48) == 48
        below = [m for m in autotune.PREFILL_MS if m <= 47]
        assert backend.bucket_m(47) == (max(below) if below else 1)
    finally:
        autotune.PREFILL_MS = orig


def test_engine_registers_chunk_bucket():
    from repro.kernels import autotune

    cfg, params = _setup("llama3.2-1b")
    orig = autotune.PREFILL_MS
    try:
        GenerationEngine(params, cfg, batch_size=3, max_len=16,
                         mode="continuous", prefill_chunk=16)
        assert 48 in autotune.PREFILL_MS   # batch * chunk
    finally:
        autotune.PREFILL_MS = orig
