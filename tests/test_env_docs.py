"""docs/ENV.md vs the source tree: every ICQ_* environment variable the
code reads must be documented, and every documented variable must still
be read somewhere (no stale docs). Pure-text test — no jax import."""
import pathlib
import re

REPO = pathlib.Path(__file__).resolve().parent.parent
ENV_DOC = REPO / "docs" / "ENV.md"

# matches os.environ.get("ICQ_X") / os.environ["ICQ_X"] / getenv("ICQ_X"),
# including reads split across lines by black-style wrapping
_READ = re.compile(
    r'(?:environ(?:\.get)?|getenv)\s*[\(\[]\s*"(ICQ_[A-Z0-9_]+)"')


def _vars_read_in_src():
    found = set()
    for path in sorted((REPO / "src").rglob("*.py")):
        found |= set(_READ.findall(path.read_text()))
    return found


def _vars_documented():
    return set(re.findall(r"`(ICQ_[A-Z0-9_]+)`", ENV_DOC.read_text()))


def test_every_env_read_is_documented():
    read, doc = _vars_read_in_src(), _vars_documented()
    assert read, "no ICQ_* reads found — the regex rotted"
    missing = read - doc
    assert not missing, (
        f"ICQ_* variables read in src/ but missing from docs/ENV.md: "
        f"{sorted(missing)}")


def test_every_documented_var_is_still_read():
    read, doc = _vars_read_in_src(), _vars_documented()
    stale = doc - read
    assert not stale, (
        f"docs/ENV.md documents variables nothing reads anymore: "
        f"{sorted(stale)}")


def test_known_knobs_present():
    """Spot-pin the knobs this PR added so a doc rewrite can't quietly
    drop them while keeping the greps symmetric."""
    doc = _vars_documented()
    for var in ("ICQ_PAGED_ATTN", "ICQ_ACCUM_DTYPE", "ICQ_FUSED_STEP",
                "ICQ_PREFILL_CHUNK", "ICQ_KV_LAYOUT", "ICQ_FAULT_PLAN",
                "ICQ_PREFIX_CACHE", "ICQ_SESSION_TTL",
                "ICQ_SPEC_DECODE", "ICQ_SPEC_K", "ICQ_SPEC_DRAFT",
                "ICQ_WAL_PATH", "ICQ_HEARTBEAT_S", "ICQ_STALL_STEPS",
                "ICQ_RETRY_MAX", "ICQ_RETRY_BASE_S", "ICQ_RETRY_CAP_S"):
        assert var in doc
