"""Bit-packing round-trip properties."""
import numpy as np
import jax.numpy as jnp
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.packing import (
    codes_per_word,
    pack_codes,
    pack_codes_np,
    packed_width,
    unpack_codes,
)


@settings(max_examples=100, deadline=None)
@given(
    st.integers(min_value=1, max_value=16),
    st.integers(min_value=1, max_value=300),
    st.integers(min_value=0, max_value=2**31),
)
def test_pack_unpack_roundtrip(n_bits, length, seed):
    rng = np.random.default_rng(seed)
    codes = rng.integers(0, 1 << n_bits, size=(3, length), dtype=np.uint32)
    words = pack_codes(jnp.asarray(codes), n_bits)
    assert words.shape == (3, packed_width(length, n_bits))
    out = unpack_codes(words, n_bits, length)
    np.testing.assert_array_equal(np.asarray(out), codes)


def test_numpy_and_jax_packers_agree():
    rng = np.random.default_rng(0)
    for n in (1, 2, 3, 4, 6, 8):
        codes = rng.integers(0, 1 << n, size=(5, 97), dtype=np.uint32)
        a = np.asarray(pack_codes(jnp.asarray(codes), n))
        b = pack_codes_np(codes, n)
        np.testing.assert_array_equal(a, b)


def test_codes_per_word():
    assert codes_per_word(2) == 16
    assert codes_per_word(3) == 10
    assert codes_per_word(4) == 8
