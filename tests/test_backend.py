"""Kernel-backed execution layer: prepare, dispatch, autotune, serving.

(Names mention "kernel" so ``pytest -k kernel`` smoke-sweeps this file
together with tests/test_kernels.py.)
"""
import json

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import core
from repro.core.icquant import to_runtime_format
from repro.core.stats import heavy_tailed_weights
from repro.kernels import autotune, backend, ops
from repro.kernels.platform import (
    decode_m_threshold,
    default_backend,
    default_interpret,
)
from repro.launch.quantize import quantize_tree
from repro.launch.steps import prepare_serving_params
from repro.models.linear import as_dense, linear, weight_shape


def _pack(R=48, C=330, n_bits=3, seed=1):
    W = heavy_tailed_weights(R, C, seed=seed)
    return core.quantize(jnp.asarray(W), n_bits, gamma=0.05)


# ---------------------------------------------------------------------------
# satellite regressions
# ---------------------------------------------------------------------------

def test_kernel_weight_shape_all_representations():
    """Regression: weight_shape(ICQRuntime) used to fall through to
    w.shape and raise AttributeError."""
    pk = _pack()
    rt = to_runtime_format(pk)
    prep = backend.prepare(pk)
    assert weight_shape(pk) == (330, 48)
    assert weight_shape(rt) == (330, 48)          # <- the old crash
    assert weight_shape(prep) == (330, 48)
    assert weight_shape(jnp.zeros((7, 9))) == (7, 9)


def test_kernel_runtime_bits_counts_f32_codebooks():
    """runtime_bits_per_weight must charge codebooks at their stored f32
    width: total ≈ n (codes) + 1 (bitmap) + 32·2^(n+1)/d_in (codebooks),
    exactly when d_in divides the packing words."""
    d_out, d_in = 64, 4096
    for n_bits in (2, 4):
        pk = _pack(d_out, d_in, n_bits, seed=n_bits)
        rt = ops.to_runtime(pk)
        assert rt["codebooks"].dtype == jnp.float32
        got = ops.runtime_bits_per_weight(rt)
        want = n_bits + 1 + 32 * (2 << n_bits) / d_in
        assert got == pytest.approx(want, rel=1e-6), (n_bits, got, want)


def test_kernel_interpret_default_platform_and_env(monkeypatch):
    monkeypatch.delenv("ICQ_INTERPRET", raising=False)
    monkeypatch.delenv("ICQ_BACKEND", raising=False)
    monkeypatch.setenv("ICQ_PLATFORM", "tpu")
    assert default_interpret() is False
    assert default_backend() == "pallas"
    monkeypatch.setenv("ICQ_PLATFORM", "cpu")
    assert default_interpret() is True
    assert default_backend() == "xla"
    monkeypatch.setenv("ICQ_INTERPRET", "0")
    assert default_interpret() is False
    monkeypatch.setenv("ICQ_BACKEND", "pallas")
    assert default_backend() == "pallas"


# ---------------------------------------------------------------------------
# prepared layout
# ---------------------------------------------------------------------------

def test_kernel_prepared_layout_blocked_and_padded():
    pk = _pack(48, 330, 3)
    prep = backend.prepare(pk, backend="pallas")
    k = 32 // 3
    assert prep.codes.shape[-2] % prep.block_n == 0
    assert prep.codes.shape[-1] * k % prep.block_k == 0
    assert prep.bitmap.shape[-1] * 32 == prep.codes.shape[-1] * k
    assert prep.codes.shape[-2] >= prep.d_out
    # padding accounted in the HBM bits (and still far under bf16)
    assert prep.bits_per_weight() < 16


def test_kernel_prepare_accepts_runtime_and_dict():
    pk = _pack()
    w_ref = np.asarray(core.dequantize(pk))
    for src in (to_runtime_format(pk), ops.to_runtime(pk)):
        prep = backend.prepare(src)
        np.testing.assert_array_equal(
            np.asarray(backend.dequantize_prepared(prep)), w_ref)


def test_kernel_prepare_tree_and_dense_cache_modes():
    leaf = jnp.asarray(heavy_tailed_weights(96, 64, seed=5)).T  # (64, 96)
    params = dict(a=dict(w=leaf), ln=jnp.ones((4,)))
    qparams, _ = quantize_tree(params, 4)
    prepped = prepare_serving_params(qparams, mode="prepared")
    assert isinstance(prepped["a"]["w"], backend.ICQPrepared)
    assert prepped["ln"] is qparams["ln"]
    dense = prepare_serving_params(qparams, mode="dense")
    assert dense["a"]["w"].shape == leaf.shape      # (d_in, d_out) restored
    np.testing.assert_array_equal(
        np.asarray(dense["a"]["w"]),
        np.asarray(as_dense(qparams["a"]["w"])))
    assert prepare_serving_params(qparams, mode="none") is qparams
    with pytest.raises(ValueError):
        prepare_serving_params(qparams, mode="bogus")


def test_kernel_prepared_slices_under_scan_like_indexing():
    """Layer-stacked prepared weights must survive the scan leaf slicing
    stack_apply performs (children lose the lead axis, statics persist)."""
    stacked = jnp.stack([
        jnp.asarray(heavy_tailed_weights(40, 64, seed=s)).T for s in (1, 2)
    ])                                               # (2, 64, 40) leaf
    qp, _ = quantize_tree(dict(w=stacked), 4)
    prep = backend.prepare_tree(qp)["w"]
    assert prep.codes.ndim == 3
    layer0 = jax.tree.map(lambda a: a[0], prep)
    assert isinstance(layer0, backend.ICQPrepared)
    assert layer0.codes.ndim == 2
    w0 = np.asarray(backend.dequantize_prepared(layer0))
    w_ref = np.asarray(core.dequantize(qp["w"]))[0]
    np.testing.assert_array_equal(w0, w_ref)


def test_kernel_moe_stacked_prepared_dequant_matches_reference():
    stacked = jnp.stack([
        jnp.asarray(heavy_tailed_weights(48, 96, seed=s)).T for s in range(3)
    ])                                               # (3, 96, 48)
    qp, _ = quantize_tree(dict(w=stacked), 3)
    w_ref = np.asarray(core.dequantize(qp["w"]))     # (3, 48, 96)
    for be in ("xla", "pallas"):
        prep = backend.prepare(qp["w"], backend=be)
        got = np.asarray(backend.dequantize_prepared(prep))
        np.testing.assert_allclose(got, w_ref, rtol=1e-6)

    from repro.models.moe import _expert_weight
    ew = _expert_weight(backend.prepare(qp["w"]), jnp.float32)
    assert ew.shape == (3, 96, 48)
    np.testing.assert_allclose(
        np.asarray(ew), np.swapaxes(w_ref, -1, -2), rtol=1e-6)


def test_kernel_dispatch_threshold_env(monkeypatch):
    pk = _pack()
    prep = backend.prepare(pk, backend="pallas")
    assert backend.choose_path(decode_m_threshold(), prep) == "fused"
    assert backend.choose_path(decode_m_threshold() + 1, prep) == "dequant"
    monkeypatch.setenv("ICQ_DECODE_M", "4")
    assert backend.choose_path(8, prep) == "dequant"
    # xla backend always takes the xla arm
    assert backend.choose_path(1, backend.prepare(pk, backend="xla")) == "xla"


# ---------------------------------------------------------------------------
# autotuner
# ---------------------------------------------------------------------------

def test_kernel_autotune_cache_roundtrip(tmp_path, monkeypatch):
    cache = tmp_path / "tune.json"
    monkeypatch.setenv("ICQ_AUTOTUNE_CACHE", str(cache))
    autotune.reset()
    got = autotune.autotune_matmul(
        1, 16, 96, 4, interpret=True,
        candidates=[(8, 16, 96), (8, 8, 96)], iters=1)
    assert not got["cached"] and got["blocks"] in ((8, 16, 96), (8, 8, 96))
    assert cache.exists()
    key = autotune.matmul_key(1, 16, 96, 4, "pallas", True)
    assert json.loads(cache.read_text())[key] == list(got["blocks"])
    # second call: in-memory hit
    again = autotune.autotune_matmul(1, 16, 96, 4, interpret=True)
    assert again["cached"] and again["blocks"] == got["blocks"]
    # fresh process simulation: disk hit
    autotune.reset()
    assert autotune.lookup(key) == list(got["blocks"])
    autotune.reset()


def test_kernel_prepare_consults_autotune_cache(tmp_path, monkeypatch):
    monkeypatch.setenv("ICQ_AUTOTUNE_CACHE", str(tmp_path / "tune.json"))
    autotune.reset()
    pk = _pack(48, 330, 3)
    key = autotune.matmul_key(1, 48, 330, 3, "pallas", default_interpret())
    # n=3 -> lcm(k=10, 32)=160, padded d_in=480: block_k=480 survives the
    # padding-minimizing snap (snap_block_k) unchanged
    autotune.record(key, [64, 32, 480])
    prep = backend.prepare(pk, backend="pallas")
    assert (prep.block_m, prep.block_n, prep.block_k) == (64, 32, 480)
    # a cached block_k that would inflate padding gets snapped down
    autotune.record(key, [64, 32, 320])
    prep2 = backend.prepare(pk, backend="pallas")
    assert prep2.block_k == 160 and prep2.codes.shape[-1] * 10 == 480
    autotune.reset()


# ---------------------------------------------------------------------------
# serving engine routes through the dispatch layer
# ---------------------------------------------------------------------------

def test_kernel_engine_prepared_token_parity():
    """GenerationEngine decode with ICQ weights goes through the prepared
    dispatch layer (no full dequantize() in the per-step hot path) and
    generates IDENTICAL tokens to the reference in-graph-decode path."""
    from repro.configs import get_config, smoke_variant
    from repro.models import init_model
    from repro.serving import GenerationEngine, Request

    cfg = smoke_variant(get_config("llama3.2-1b"))
    params = init_model(jax.random.PRNGKey(0), cfg)
    qparams, _ = quantize_tree(params, 4, gamma=0.05)
    prompt = np.random.default_rng(2).integers(
        0, cfg.vocab_size, 5).astype(np.int32)

    e_ref = GenerationEngine(qparams, cfg, batch_size=1, max_len=24,
                             weight_cache="none")
    e_prep = GenerationEngine(qparams, cfg, batch_size=1, max_len=24)
    assert any(
        isinstance(w, backend.ICQPrepared)
        for w in jax.tree.leaves(
            e_prep.params,
            is_leaf=lambda x: isinstance(x, backend.ICQPrepared))
    ), "engine did not prepare ICQ weights"
    for e in (e_ref, e_prep):
        e.submit(Request(0, prompt, max_new_tokens=4))
    assert e_prep.run()[0].generated == e_ref.run()[0].generated
