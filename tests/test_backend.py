"""Kernel-backed execution layer: prepare, dispatch, autotune, serving.

(Names mention "kernel" so ``pytest -k kernel`` smoke-sweeps this file
together with tests/test_kernels.py.)
"""
import json

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import core
from repro.core.icquant import to_runtime_format
from repro.core.stats import heavy_tailed_weights
from repro.kernels import autotune, backend, ops
from repro.kernels.platform import (
    decode_m_threshold,
    default_backend,
    default_interpret,
)
from repro.launch.quantize import quantize_tree
from repro.launch.steps import prepare_serving_params
from repro.models.linear import as_dense, linear, weight_shape


def _pack(R=48, C=330, n_bits=3, seed=1):
    W = heavy_tailed_weights(R, C, seed=seed)
    return core.quantize(jnp.asarray(W), n_bits, gamma=0.05)


# ---------------------------------------------------------------------------
# satellite regressions
# ---------------------------------------------------------------------------

def test_kernel_weight_shape_all_representations():
    """Regression: weight_shape(ICQRuntime) used to fall through to
    w.shape and raise AttributeError."""
    pk = _pack()
    rt = to_runtime_format(pk)
    prep = backend.prepare(pk)
    assert weight_shape(pk) == (330, 48)
    assert weight_shape(rt) == (330, 48)          # <- the old crash
    assert weight_shape(prep) == (330, 48)
    assert weight_shape(jnp.zeros((7, 9))) == (7, 9)


def test_kernel_runtime_bits_counts_f32_codebooks():
    """runtime_bits_per_weight must charge codebooks at their stored f32
    width: total ≈ n (codes) + 1 (bitmap) + 32·2^(n+1)/d_in (codebooks),
    exactly when d_in divides the packing words."""
    d_out, d_in = 64, 4096
    for n_bits in (2, 4):
        pk = _pack(d_out, d_in, n_bits, seed=n_bits)
        rt = ops.to_runtime(pk)
        assert rt["codebooks"].dtype == jnp.float32
        got = ops.runtime_bits_per_weight(rt)
        want = n_bits + 1 + 32 * (2 << n_bits) / d_in
        assert got == pytest.approx(want, rel=1e-6), (n_bits, got, want)


def test_kernel_interpret_default_platform_and_env(monkeypatch):
    monkeypatch.delenv("ICQ_INTERPRET", raising=False)
    monkeypatch.delenv("ICQ_BACKEND", raising=False)
    monkeypatch.setenv("ICQ_PLATFORM", "tpu")
    assert default_interpret() is False
    assert default_backend() == "pallas"
    monkeypatch.setenv("ICQ_PLATFORM", "cpu")
    assert default_interpret() is True
    assert default_backend() == "xla"
    monkeypatch.setenv("ICQ_INTERPRET", "0")
    assert default_interpret() is False
    monkeypatch.setenv("ICQ_BACKEND", "pallas")
    assert default_backend() == "pallas"


# ---------------------------------------------------------------------------
# prepared layout
# ---------------------------------------------------------------------------

def test_kernel_prepared_layout_blocked_and_padded():
    pk = _pack(48, 330, 3)
    k = 32 // 3

    prep = backend.prepare(pk, backend="pallas", fmt="v1")
    assert prep.fmt == "v1" and prep.syms is None
    assert prep.codes.shape[-2] % prep.block_n == 0
    assert prep.codes.shape[-1] * k % prep.block_k == 0
    assert prep.bitmap.shape[-1] * 32 == prep.codes.shape[-1] * k
    assert prep.codes.shape[-2] >= prep.d_out
    # padding accounted in the HBM bits (and still far under bf16)
    assert prep.bits_per_weight() < 16

    prep2 = backend.prepare(pk, backend="pallas", fmt="v2")
    assert prep2.fmt == "v2" and prep2.bitmap is None
    assert prep2.b == pk.b
    pk_cols = prep2.codes.shape[-1] * k
    assert pk_cols % prep2.block_k == 0
    # checkpoint sidecar blocked to block_k: one offset per tile + sentinel
    T = pk_cols // prep2.block_k
    assert prep2.offs.shape == (prep2.codes.shape[-2], T + 1)
    assert prep2.dbase.shape == (prep2.codes.shape[-2], T)
    assert prep2.offs.dtype == jnp.uint16
    assert prep2.dbase.dtype == jnp.uint8          # b = 6 <= 8
    # v2 serves cheaper than the dense bitmap for the same weight
    assert prep2.bits_per_weight() < prep.bits_per_weight()


def test_kernel_prepare_accepts_runtime_and_dict():
    pk = _pack()
    w_ref = np.asarray(core.dequantize(pk))
    for src in (to_runtime_format(pk), ops.to_runtime(pk)):
        prep = backend.prepare(src)
        np.testing.assert_array_equal(
            np.asarray(backend.dequantize_prepared(prep)), w_ref)


def test_kernel_prepare_tree_and_dense_cache_modes():
    leaf = jnp.asarray(heavy_tailed_weights(96, 64, seed=5)).T  # (64, 96)
    params = dict(a=dict(w=leaf), ln=jnp.ones((4,)))
    qparams, _ = quantize_tree(params, 4)
    prepped = prepare_serving_params(qparams, mode="prepared")
    assert isinstance(prepped["a"]["w"], backend.ICQPrepared)
    assert prepped["ln"] is qparams["ln"]
    dense = prepare_serving_params(qparams, mode="dense")
    assert dense["a"]["w"].shape == leaf.shape      # (d_in, d_out) restored
    np.testing.assert_array_equal(
        np.asarray(dense["a"]["w"]),
        np.asarray(as_dense(qparams["a"]["w"])))
    assert prepare_serving_params(qparams, mode="none") is qparams
    with pytest.raises(ValueError):
        prepare_serving_params(qparams, mode="bogus")


def test_kernel_prepared_slices_under_scan_like_indexing():
    """Layer-stacked prepared weights must survive the scan leaf slicing
    stack_apply performs (children lose the lead axis, statics persist)."""
    stacked = jnp.stack([
        jnp.asarray(heavy_tailed_weights(40, 64, seed=s)).T for s in (1, 2)
    ])                                               # (2, 64, 40) leaf
    qp, _ = quantize_tree(dict(w=stacked), 4)
    prep = backend.prepare_tree(qp)["w"]
    assert prep.codes.ndim == 3
    layer0 = jax.tree.map(lambda a: a[0], prep)
    assert isinstance(layer0, backend.ICQPrepared)
    assert layer0.codes.ndim == 2
    w0 = np.asarray(backend.dequantize_prepared(layer0))
    w_ref = np.asarray(core.dequantize(qp["w"]))[0]
    np.testing.assert_array_equal(w0, w_ref)


def test_kernel_moe_stacked_prepared_dequant_matches_reference():
    stacked = jnp.stack([
        jnp.asarray(heavy_tailed_weights(48, 96, seed=s)).T for s in range(3)
    ])                                               # (3, 96, 48)
    qp, _ = quantize_tree(dict(w=stacked), 3)
    w_ref = np.asarray(core.dequantize(qp["w"]))     # (3, 48, 96)
    for be in ("xla", "pallas"):
        prep = backend.prepare(qp["w"], backend=be)
        got = np.asarray(backend.dequantize_prepared(prep))
        np.testing.assert_allclose(got, w_ref, rtol=1e-6)

    from repro.models.moe import _expert_weight
    ew = _expert_weight(backend.prepare(qp["w"]), jnp.float32)
    assert ew.shape == (3, 96, 48)
    np.testing.assert_allclose(
        np.asarray(ew), np.swapaxes(w_ref, -1, -2), rtol=1e-6)


def test_kernel_dispatch_threshold_env(monkeypatch):
    pk = _pack()
    prep = backend.prepare(pk, backend="pallas")
    assert backend.choose_path(decode_m_threshold(), prep) == "fused"
    assert backend.choose_path(decode_m_threshold() + 1, prep) == "dequant"
    monkeypatch.setenv("ICQ_DECODE_M", "4")
    assert backend.choose_path(8, prep) == "dequant"
    # xla backend always takes the xla arm
    assert backend.choose_path(1, backend.prepare(pk, backend="xla")) == "xla"


# ---------------------------------------------------------------------------
# autotuner
# ---------------------------------------------------------------------------

def test_kernel_autotune_cache_roundtrip(tmp_path, monkeypatch):
    cache = tmp_path / "tune.json"
    monkeypatch.setenv("ICQ_AUTOTUNE_CACHE", str(cache))
    autotune.reset()
    got = autotune.autotune_matmul(
        1, 16, 96, 4, interpret=True,
        candidates=[(8, 16, 96), (8, 8, 96)], iters=1)
    assert not got["cached"] and got["blocks"] in ((8, 16, 96), (8, 8, 96))
    assert cache.exists()
    key = autotune.matmul_key(1, 16, 96, 4, "pallas", True)
    assert json.loads(cache.read_text())[key] == list(got["blocks"])
    # second call: in-memory hit
    again = autotune.autotune_matmul(1, 16, 96, 4, interpret=True)
    assert again["cached"] and again["blocks"] == got["blocks"]
    # fresh process simulation: disk hit
    autotune.reset()
    assert autotune.lookup(key) == list(got["blocks"])
    autotune.reset()


def test_kernel_prepare_consults_autotune_cache(tmp_path, monkeypatch):
    monkeypatch.setenv("ICQ_AUTOTUNE_CACHE", str(tmp_path / "tune.json"))
    autotune.reset()
    pk = _pack(48, 330, 3)
    key = autotune.matmul_key(1, 48, 330, 3, "pallas", default_interpret())
    # n=3 -> lcm(k=10, 32)=160, padded d_in=480: block_k=480 survives the
    # padding-minimizing snap (snap_block_k) unchanged
    autotune.record(key, [64, 32, 480])
    prep = backend.prepare(pk, backend="pallas", fmt="v1")
    assert (prep.block_m, prep.block_n, prep.block_k) == (64, 32, 480)
    # a cached block_k that would inflate padding gets snapped down
    autotune.record(key, [64, 32, 320])
    prep2 = backend.prepare(pk, backend="pallas", fmt="v1")
    assert prep2.block_k == 160 and prep2.codes.shape[-1] * 10 == 480

    # v2 tunes under its own key (bitmap-free column granularity = k):
    # requesting 320 snaps to the largest divisor of 330/10=33 tiles -> 110
    key2 = autotune.matmul_key(1, 48, 330, 3, "pallas", default_interpret(),
                               fmt="v2")
    assert key2 != key and key2.endswith("_v2")
    autotune.record(key2, [64, 32, 320])
    prep3 = backend.prepare(pk, backend="pallas", fmt="v2")
    assert prep3.block_k == 110 and prep3.offs.shape[-1] == 330 // 110 + 1
    autotune.reset()


def test_kernel_autotune_corrupted_cache_falls_back(tmp_path, monkeypatch):
    """A corrupted / partial cache file must mean 'sweep', never a crash."""
    cache = tmp_path / "tune.json"
    monkeypatch.setenv("ICQ_AUTOTUNE_CACHE", str(cache))
    for garbage in ('{"matmul/m1_o16_i96_n4_pallas-int": [8, 16', "not json",
                    ""):
        cache.write_text(garbage)
        autotune.reset()
        assert autotune.lookup("matmul/m1_o16_i96_n4_pallas-int") is None
        got = autotune.autotune_matmul(
            1, 16, 96, 4, interpret=True,
            candidates=[(8, 16, 96)], iters=1)
        assert not got["cached"] and got["blocks"] == (8, 16, 96)
        # the sweep rewrote a valid cache file over the garbage
        assert json.loads(cache.read_text())
    autotune.reset()


def test_kernel_autotune_v2_sweep_and_key(tmp_path, monkeypatch):
    monkeypatch.setenv("ICQ_AUTOTUNE_CACHE", str(tmp_path / "tune.json"))
    autotune.reset()
    got = autotune.autotune_matmul(
        1, 16, 96, 4, interpret=True, fmt="v2",
        candidates=[(8, 16, 96), (8, 8, 96)], iters=1)
    assert not got["cached"]
    key = autotune.matmul_key(1, 16, 96, 4, "pallas", True, fmt="v2")
    assert autotune.lookup(key) == list(got["blocks"])
    # the v1 spelling of the same shape is a distinct cache entry
    assert autotune.lookup(
        autotune.matmul_key(1, 16, 96, 4, "pallas", True)) is None
    autotune.reset()


# ---------------------------------------------------------------------------
# v2 checkpointed-stream runtime format
# ---------------------------------------------------------------------------

def test_kernel_runtime_fmt_env_override(monkeypatch):
    from repro.kernels.platform import default_runtime_fmt

    monkeypatch.delenv("ICQ_RUNTIME_FMT", raising=False)
    assert default_runtime_fmt() == "v2"
    pk = _pack()
    assert backend.prepare(pk).fmt == "v2"
    monkeypatch.setenv("ICQ_RUNTIME_FMT", "v1")
    assert default_runtime_fmt() == "v1"
    assert backend.prepare(pk).fmt == "v1"
    monkeypatch.setenv("ICQ_RUNTIME_FMT", "v3")
    with pytest.raises(ValueError):
        default_runtime_fmt()


def test_kernel_prepare_v2_falls_back_for_bitmap_sources():
    """ICQRuntime / v1 dicts carry no gap stream: prepare(fmt='v2') keeps
    serving them as v1 instead of failing."""
    pk = _pack()
    for src in (to_runtime_format(pk), ops.to_runtime(pk, fmt="v1")):
        prep = backend.prepare(src, fmt="v2")
        assert prep.fmt == "v1" and prep.bitmap is not None


def test_kernel_prepare_accepts_v2_dict():
    pk = _pack()
    rt = ops.to_runtime(pk, fmt="v2", tile=128)
    prep = backend.prepare(rt)
    assert prep.fmt == "v2"
    assert prep.block_k == rt["tile"]       # checkpoint tile is binding
    np.testing.assert_array_equal(
        np.asarray(backend.dequantize_prepared(prep)),
        np.asarray(core.dequantize(pk)))
    with pytest.raises(ValueError):
        backend.prepare(rt, fmt="v1")       # bitmap never materialized


def test_kernel_codebook_dtype_bf16():
    """Satellite: bf16 codebook option halves the codebook HBM charge;
    dequant error stays within bf16 rounding of the f32 levels."""
    pk = _pack(64, 512, 4)
    w32 = np.asarray(core.dequantize(pk))
    for fmt in ("v1", "v2"):
        p32 = backend.prepare(pk, fmt=fmt, codebook_dtype="f32")
        p16 = backend.prepare(pk, fmt=fmt, codebook_dtype="bf16")
        assert p16.codebooks.dtype == jnp.bfloat16
        cb_elems = p32.codebooks.size
        want_saving = cb_elems * 16 / (64 * 512)
        got_saving = p32.bits_per_weight() - p16.bits_per_weight()
        assert got_saving == pytest.approx(want_saving, rel=1e-6)
        w16 = np.asarray(backend.dequantize_prepared(p16), np.float32)
        np.testing.assert_allclose(w16, w32, rtol=8e-3, atol=8e-3)
    with pytest.raises(ValueError):
        backend.prepare(pk, codebook_dtype="f64")


def test_kernel_vmem_budget_clamps_blocks(monkeypatch):
    """Satellite: block candidates whose one-hot temp + accumulator bust
    the VMEM budget are clamped in prepare() before any compiler sees
    them (n_bits=8 -> C=512 makes the default blocks cost >100 MB)."""
    pk = _pack(64, 512, 8)
    prep = backend.prepare(pk, backend="pallas", fmt="v1")
    C = prep.codebooks.shape[-1]
    assert C == 512
    est = backend.vmem_bytes_estimate(
        prep.block_m, prep.block_n, prep.block_k, n_bits=8, C=C, fmt="v1")
    assert est <= backend.vmem_budget_bytes()
    assert (prep.block_n, prep.block_k) != backend.DEFAULT_BLOCKS[1:]
    # a tighter explicit budget clamps harder
    monkeypatch.setenv("ICQ_VMEM_BUDGET_MB", "2")
    tight = backend.prepare(pk, backend="pallas", fmt="v1")
    est2 = backend.vmem_bytes_estimate(
        tight.block_m, tight.block_n, tight.block_k, n_bits=8, C=C, fmt="v1")
    assert est2 <= 2 * 2**20 or (tight.block_n == 8 and tight.block_m == 8)
    # parity survives clamping
    x = jnp.asarray(
        np.random.default_rng(1).standard_normal((3, 512)), jnp.float32)
    np.testing.assert_allclose(
        np.asarray(backend.linear_apply(x, tight)),
        np.asarray(x @ core.dequantize(pk).T), rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("n_bits", [2, 3, 4])
def test_kernel_v2_outlier_overhead_bench_configs(n_bits):
    """Acceptance: on the bench geometry the v2 runtime pays <= 0.45 b/w
    for outlier selection (stream + checkpoints + padding) where the v1
    bitmap pays ~1.0 — measured by runtime_bits_per_weight accounting."""
    pk = _pack(512, 2048, n_bits, seed=n_bits)
    rt1 = ops.to_runtime(pk, fmt="v1")
    rt2 = ops.to_runtime(pk, fmt="v2")
    over1 = ops.runtime_outlier_bits_per_weight(rt1)
    over2 = ops.runtime_outlier_bits_per_weight(rt2)
    assert over1 >= 1.0                       # dense 1-bit selector
    assert over2 <= 0.45, (n_bits, over2)     # checkpointed stream
    # total runtime bits drop by the same margin
    assert ops.runtime_bits_per_weight(rt1) - ops.runtime_bits_per_weight(
        rt2) == pytest.approx(over1 - over2, rel=1e-6)
    # and stay within ~0.15 b/w of the storage stream itself
    assert over2 <= pk.bits_per_weight()["index"] + 0.15


def test_kernel_runtime_bits_itemsize_derived():
    """Satellite: accounting derives widths from itemsize — the uint16
    offsets and uint8 deltas of the v2 sidecar bill at 16/8 bits, not a
    hardcoded 32."""
    pk = _pack(64, 512, 4)
    rt = ops.to_runtime(pk, fmt="v2")
    total_w = 64 * 512
    want = (
        rt["codes"].size * 32 + rt["syms"].size * 32
        + rt["offs"].size * 16 + rt["dbase"].size * 8
        + rt["codebooks"].size * 32
    ) / total_w
    assert ops.runtime_bits_per_weight(rt) == pytest.approx(want, rel=1e-9)
    rt16 = ops.to_runtime(pk, fmt="v2", codebook_dtype="bf16")
    assert ops.runtime_bits_per_weight(rt) - ops.runtime_bits_per_weight(
        rt16) == pytest.approx(rt["codebooks"].size * 16 / total_w, rel=1e-6)


# ---------------------------------------------------------------------------
# serving engine routes through the dispatch layer
# ---------------------------------------------------------------------------

def test_kernel_engine_prepared_token_parity():
    """GenerationEngine decode with ICQ weights goes through the prepared
    dispatch layer (no full dequantize() in the per-step hot path) and
    generates IDENTICAL tokens to the reference in-graph-decode path —
    for both the v1 bitmap and the v2 checkpointed-stream formats."""
    from repro.configs import get_config, smoke_variant
    from repro.models import init_model
    from repro.serving import GenerationEngine, Request

    cfg = smoke_variant(get_config("llama3.2-1b"))
    params = init_model(jax.random.PRNGKey(0), cfg)
    qparams, _ = quantize_tree(params, 4, gamma=0.05)
    prompt = np.random.default_rng(2).integers(
        0, cfg.vocab_size, 5).astype(np.int32)

    e_ref = GenerationEngine(qparams, cfg, batch_size=1, max_len=24,
                             weight_cache="none")
    e_ref.submit(Request(0, prompt, max_new_tokens=4))
    ref_tokens = e_ref.run()[0].generated

    for fmt in ("v1", "v2"):
        e_prep = GenerationEngine(qparams, cfg, batch_size=1, max_len=24,
                                  runtime_fmt=fmt)
        leaves = [
            w for w in jax.tree.leaves(
                e_prep.params,
                is_leaf=lambda x: isinstance(x, backend.ICQPrepared))
            if isinstance(w, backend.ICQPrepared)
        ]
        assert leaves, "engine did not prepare ICQ weights"
        assert all(w.fmt == fmt for w in leaves)
        e_prep.submit(Request(0, prompt, max_new_tokens=4))
        assert e_prep.run()[0].generated == ref_tokens, fmt


# ---------------------------------------------------------------------------
# batched-M autotune entries + per-arm block tables (ISSUE-3 satellite)
# ---------------------------------------------------------------------------

def test_kernel_bucket_m_largest_not_exceeding():
    assert backend.bucket_m(1) == 1
    assert backend.bucket_m(8) == 1       # decode batches reuse the M=1 key
    assert backend.bucket_m(63) == 1
    assert backend.bucket_m(64) == 64
    assert backend.bucket_m(255) == 64
    assert backend.bucket_m(256) == 256
    assert backend.bucket_m(4096) == 256  # saturates at the largest bucket


def test_kernel_arm_blocks_consults_per_arm_winners(tmp_path, monkeypatch):
    monkeypatch.setenv("ICQ_AUTOTUNE_CACHE", str(tmp_path / "tune.json"))
    autotune.reset()
    pk = _pack()
    prep = backend.prepare(pk, backend="pallas", interpret=True)
    pn = prep.codes.shape[-2]
    pk_cols = prep.codes.shape[-1] * (32 // prep.n_bits)

    # no cache entries: every arm falls back to the prepare-time table
    base = (prep.block_m, prep.block_n, prep.block_k)
    assert backend.arm_blocks(1, prep) == base
    assert backend.arm_blocks(200, prep) == base

    # fused arm: decode (M=1) and prefill (M=64 bucket) key independently
    autotune.record(autotune.matmul_key(
        1, prep.d_out, prep.d_in, prep.n_bits, "pallas", True,
        fmt=prep.fmt), (8, pn, prep.block_k))
    assert backend.arm_blocks(1, prep) == (8, pn, prep.block_k)

    # dequant arm (M past the decode threshold) uses the M-free dequant key
    autotune.record(autotune.dequant_key(
        prep.d_out, prep.d_in, prep.n_bits, "pallas", True,
        fmt=prep.fmt), (pn, prep.block_k))
    bm, bn, bk = backend.arm_blocks(200, prep)
    assert (bn, bk) == (pn, prep.block_k)

    # a winner that does not tile the prepared padding is rejected
    autotune.record(autotune.matmul_key(
        1, prep.d_out, prep.d_in, prep.n_bits, "pallas", True,
        fmt=prep.fmt), (8, pn + 8, pk_cols + 64))
    assert backend.arm_blocks(1, prep) == base
    autotune.reset()


def test_kernel_arm_blocks_v2_pins_checkpoint_tile(tmp_path, monkeypatch):
    """v2 block_k is baked into the checkpoint sidecar: an arm winner may
    re-block M/N but its K tile must be overridden to the prepared one."""
    monkeypatch.setenv("ICQ_AUTOTUNE_CACHE", str(tmp_path / "tune.json"))
    autotune.reset()
    pk = _pack()
    prep = backend.prepare(pk, backend="pallas", interpret=True, fmt="v2")
    assert prep.fmt == "v2"
    pn = prep.codes.shape[-2]
    autotune.record(autotune.matmul_key(
        1, prep.d_out, prep.d_in, prep.n_bits, "pallas", True,
        fmt="v2"), (16, pn, 99999))
    assert backend.arm_blocks(1, prep) == (16, pn, prep.block_k)
    autotune.reset()


def test_kernel_autotune_arms_populates_all_keys(tmp_path, monkeypatch):
    monkeypatch.setenv("ICQ_AUTOTUNE_CACHE", str(tmp_path / "tune.json"))
    autotune.reset()
    table = autotune.autotune_arms(16, 96, 4, interpret=True, iters=1,
                                   prefill_ms=(64,))
    assert autotune.lookup(
        autotune.matmul_key(1, 16, 96, 4, "pallas", True)) is not None
    assert autotune.lookup(
        autotune.matmul_key(64, 16, 96, 4, "pallas", True)) is not None
    assert autotune.lookup(
        autotune.dequant_key(16, 96, 4, "pallas", True)) is not None
    assert set(table) == {"decode", "prefill", "dequant"}
    assert list(table["prefill"]) == [64]
    autotune.reset()


# ---------------------------------------------------------------------------
# XLA-arm decoded-selector memo (ISSUE-5 satellite)
# ---------------------------------------------------------------------------

def test_kernel_xla_sel_memo_built_only_for_xla_v2(monkeypatch):
    monkeypatch.delenv("ICQ_XLA_SEL_MEMO", raising=False)
    pk = _pack()
    assert backend.prepare(pk, backend="xla", fmt="v2").sel_memo is not None
    assert backend.prepare(pk, backend="xla", fmt="v1").sel_memo is None
    assert backend.prepare(pk, backend="pallas", fmt="v2").sel_memo is None
    monkeypatch.setenv("ICQ_XLA_SEL_MEMO", "0")
    assert backend.prepare(pk, backend="xla", fmt="v2").sel_memo is None


def test_kernel_xla_sel_memo_bitwise_parity(monkeypatch):
    """The memo replaces the per-call in-graph gap-stream decode: outputs
    must be bit-identical with and without it (and to the v1 bitmap)."""
    pk = _pack()
    x = jnp.asarray(
        np.random.default_rng(0).normal(size=(3, 330)).astype(np.float32))
    monkeypatch.setenv("ICQ_XLA_SEL_MEMO", "0")
    p_plain = backend.prepare(pk, backend="xla", fmt="v2")
    monkeypatch.setenv("ICQ_XLA_SEL_MEMO", "1")
    p_memo = backend.prepare(pk, backend="xla", fmt="v2")
    assert p_memo.sel_memo is not None and p_plain.sel_memo is None
    y_plain = np.asarray(backend.linear_apply(x, p_plain))
    y_memo = np.asarray(backend.linear_apply(x, p_memo))
    assert np.array_equal(y_plain.view(np.uint8), y_memo.view(np.uint8))
    w_plain = np.asarray(backend.dequantize_prepared(p_plain))
    w_memo = np.asarray(backend.dequantize_prepared(p_memo))
    assert np.array_equal(w_plain.view(np.uint8), w_memo.view(np.uint8))


def test_kernel_xla_sel_memo_excluded_from_bits_accounting(monkeypatch):
    """The memo is an off-TPU fallback compute cache, not part of the
    runtime format: the v2 bits/weight story must not change with it."""
    pk = _pack()
    monkeypatch.setenv("ICQ_XLA_SEL_MEMO", "0")
    p_plain = backend.prepare(pk, backend="xla", fmt="v2")
    monkeypatch.setenv("ICQ_XLA_SEL_MEMO", "1")
    p_memo = backend.prepare(pk, backend="xla", fmt="v2")
    assert p_memo.bits_per_weight() == p_plain.bits_per_weight()
    assert (p_memo.outlier_bits_per_weight()
            == p_plain.outlier_bits_per_weight())


def test_kernel_xla_sel_memo_slices_under_stacked_lead_axes():
    """Stacked (layer-scanned) prepared weights slice the memo child with
    the other children; the sliced layer must still decode bitwise."""
    pk = _pack()
    # fake a 2-layer stack by stacking the packed children
    stacked = jax.tree.map(lambda a: jnp.stack([a, a]), pk)
    prep = backend.prepare(stacked, backend="xla", fmt="v2")
    assert prep.sel_memo is not None and prep.sel_memo.ndim == 3
    layer0 = jax.tree.map(lambda a: a[0], prep)
    flat = backend.prepare(pk, backend="xla", fmt="v2")
    a = np.asarray(backend.dequantize_prepared(layer0))
    b = np.asarray(backend.dequantize_prepared(flat))
    assert np.array_equal(a.view(np.uint8), b.view(np.uint8))
