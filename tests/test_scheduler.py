"""Slot scheduler: admission/recycling invariants + continuous-vs-wave
engine parity (the ISSUE-3 acceptance tests)."""
import numpy as np
import jax
import pytest

from repro.configs import get_config, smoke_variant
from repro.models import init_model
from repro.serving import GenerationEngine, Request, SlotScheduler


# ---------------------------------------------------------------------------
# host-side scheduler unit tests (no model)
# ---------------------------------------------------------------------------

def _req(rid, arrival=0.0, n=4):
    return Request(rid, np.zeros(n, np.int32), arrival_time=arrival)


def test_fifo_admission_and_lane_recycling():
    s = SlotScheduler(2)
    for rid in range(5):
        s.submit(_req(rid))
    got = s.admit(now=0.0)
    assert [(slot, r.rid) for slot, r in got] == [(0, 0), (1, 1)]
    assert s.occupancy == 2 and s.queue_depth == 3
    assert s.admit(now=0.0) == []          # full: nothing admitted
    assert s.release(0).rid == 0
    got = s.admit(now=0.0)                 # freed slot refills immediately
    assert [(slot, r.rid) for slot, r in got] == [(0, 2)]
    assert s.occupancy == 2


def test_arrival_time_gating_preserves_fifo():
    s = SlotScheduler(4)
    s.submit(_req(0, arrival=5.0))
    s.submit(_req(1, arrival=0.0))         # arrived, but behind the head
    assert s.admit(now=1.0) == []          # head not arrived: no reorder
    assert s.next_arrival() == 5.0
    got = s.admit(now=6.0)
    assert [r.rid for _, r in got] == [0, 1]


def test_release_free_slot_raises():
    s = SlotScheduler(1)
    with pytest.raises(ValueError):
        s.release(0)


def test_occupancy_never_exceeds_slots_under_random_schedule():
    rng = np.random.default_rng(0)
    s = SlotScheduler(3)
    submitted, admitted, released = 0, [], 0
    for step in range(200):
        if rng.random() < 0.4:
            s.submit(_req(submitted, arrival=float(rng.uniform(0, 5))))
            submitted += 1
        got = s.admit(now=float(step) * 0.1)
        admitted.extend(r.rid for _, r in got)
        assert 0 <= s.occupancy <= 3
        occ = s.occupied()
        if occ and rng.random() < 0.5:
            slot = int(rng.choice(list(occ)))
            s.release(slot)
            released += 1
    # drain: everything submitted is admitted exactly once
    while s.has_work():
        for slot in list(s.occupied()):
            s.release(slot)
        admitted.extend(r.rid for _, r in s.admit(now=1e9))
    assert sorted(admitted) == list(range(submitted))
    assert len(set(admitted)) == len(admitted)


# ---------------------------------------------------------------------------
# engine-level acceptance: parity + completion/occupancy invariants
# ---------------------------------------------------------------------------

def _setup(arch="llama3.2-1b"):
    cfg = smoke_variant(get_config(arch))
    params = init_model(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _mixed_requests(cfg, n, seed=0):
    rng = np.random.default_rng(seed)
    return [
        dict(rid=rid,
             prompt=rng.integers(0, cfg.vocab_size,
                                 int(rng.integers(2, 9))).astype(np.int32),
             max_new_tokens=int(rng.integers(2, 8)))
        for rid in range(n)
    ]


@pytest.mark.parametrize("arch", ["llama3.2-1b", "minicpm3-4b"])
def test_continuous_greedy_token_identical_to_wave(arch):
    """More requests than slots, mixed prompt/generation lengths: the
    continuous engine (per-lane positions, lane recycling, gqa + mla
    cache paths) must emit exactly the wave engine's greedy streams."""
    cfg, params = _setup(arch)
    specs = _mixed_requests(cfg, 5)
    out = {}
    for mode in ("wave", "continuous"):
        eng = GenerationEngine(params, cfg, batch_size=2, max_len=32,
                               mode=mode)
        for s in specs:
            eng.submit(Request(**s))
        out[mode] = {rid: r.generated for rid, r in eng.run().items()}
    assert out["continuous"] == out["wave"]


def test_every_request_completes_exactly_once_and_occupancy_bounded():
    cfg, params = _setup()
    specs = _mixed_requests(cfg, 9, seed=3)
    eng = GenerationEngine(params, cfg, batch_size=3, max_len=32,
                           mode="continuous")
    for s in specs:
        eng.submit(Request(**s))
    done = eng.run()
    assert sorted(done) == [s["rid"] for s in specs]
    for s in specs:
        r = done[s["rid"]]
        assert 1 <= len(r.generated) <= s["max_new_tokens"]
    occ = eng.metrics.occupancy_samples
    assert occ and max(occ) <= 3 and min(occ) >= 1
    summ = eng.metrics.summary()
    assert summ["completed"] == len(specs)
    assert summ["generated_tokens"] == sum(
        len(r.generated) for r in done.values())


def test_continuous_recycles_lanes_fewer_steps_than_wave():
    """The whole point: mixed lengths make the wave engine idle finished
    lanes; the continuous engine must finish the same work in fewer
    decode steps."""
    cfg, params = _setup()
    rng = np.random.default_rng(1)
    specs = [
        dict(rid=rid,
             prompt=rng.integers(0, cfg.vocab_size, 3 + 5 * (rid % 2))
             .astype(np.int32),
             max_new_tokens=2 + 10 * (rid % 2))   # short/long alternating
        for rid in range(6)
    ]
    steps = {}
    for mode in ("wave", "continuous"):
        eng = GenerationEngine(params, cfg, batch_size=2, max_len=32,
                               mode=mode)
        for s in specs:
            eng.submit(Request(**s))
        eng.run()
        steps[mode] = eng.metrics.summary()["steps"]
    assert steps["continuous"] < steps["wave"], steps


def test_poisson_arrivals_admit_in_order_and_complete():
    cfg, params = _setup()
    rng = np.random.default_rng(5)
    arrivals = np.cumsum(rng.exponential(0.002, 6))
    eng = GenerationEngine(params, cfg, batch_size=2, max_len=32,
                           mode="continuous")
    for rid in range(6):
        eng.submit(Request(
            rid, rng.integers(0, cfg.vocab_size, 4).astype(np.int32),
            max_new_tokens=3, arrival_time=float(arrivals[rid])))
    done = eng.run()
    assert sorted(done) == list(range(6))
    m = eng.metrics.requests
    for rid in range(6):
        assert m[rid].admit_time >= m[rid].arrival_time
    admits = [m[rid].admit_time for rid in range(6)]
    assert admits == sorted(admits)        # FIFO admission
