"""Service layer above the engine: WAL-journaled frontend, replica
supervision, failover with token-parity replay, and the retrying client.

Two kinds of tests share this file. Real-engine tests pin the headline
guarantee — greedy token streams identical through kills, failovers and
WAL cold restarts — against an actual ``GenerationEngine``. Host-engine
tests drive the supervision machinery (stall watchdog, backpressure,
affinity, chaos schedules) against ``_HostEngine``, a deterministic
stand-in implementing the same protocol surface the replica uses, fast
enough for property schedules that would be unaffordable with jit
compiles per restart."""
import threading
import time
import types

import numpy as np
import jax
import pytest

from repro.configs import get_config, smoke_variant
from repro.models import init_model
from repro.serving import (EngineReplica, FrontendUnavailable,
                           GenerationEngine, NoReplicaAvailable,
                           ReplicaRouter, Request, RequestRejected,
                           RequestWAL, ServiceMetrics, ServingClient,
                           ServingFrontend, ServingService)
from repro.serving.frontend import (backoff_s, default_retry_base_s,
                                    default_retry_cap_s, default_retry_max)
from repro.serving.replica import default_heartbeat_s, default_stall_steps
from repro.serving.sampling import SamplingParams
from repro.serving.scheduler import STATUSES

try:
    from hypothesis import HealthCheck, given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:          # property schedules need hypothesis; the
    HAVE_HYPOTHESIS = False  # deterministic chaos cases below run anyway


# ---------------------------------------------------------------------------
# shared real-engine setup (one baseline run per module)
# ---------------------------------------------------------------------------

def _prompts(cfg, n, length=5, seed=3):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab_size, length).astype(np.int32)
            for _ in range(n)]


@pytest.fixture(scope="module")
def env():
    cfg = smoke_variant(get_config("llama3.2-1b"))
    params = init_model(jax.random.PRNGKey(0), cfg)
    prompts = _prompts(cfg, 6)

    def factory():
        return GenerationEngine(params, cfg, batch_size=2, max_len=32,
                                mode="continuous")

    eng = factory()
    for i, p in enumerate(prompts):
        eng.submit(Request(i, p, max_new_tokens=4))
    base = eng.run()
    eng.check_shutdown_invariants()
    baseline = {i: list(r.generated) for i, r in base.items()}
    return cfg, prompts, factory, baseline


def _drive(router, timeout=120.0):
    """Pump supervision until every tracked request is terminal."""
    end = time.monotonic() + timeout
    while router.pending and time.monotonic() < end:
        router.supervise()
        time.sleep(0.01)
    router.supervise()
    assert not router.pending, "requests never reached a terminal status"


# ---------------------------------------------------------------------------
# host-side engine: the replica protocol without the jit bill
# ---------------------------------------------------------------------------

def _next_token(seq):
    """Deterministic 'greedy decode': a pure function of the whole token
    sequence, so fold-into-prompt failover replays are token-identical
    exactly when the service preserves the sequence."""
    return (int(seq[-1]) * 31 + len(seq) * 7) % 101


def _expected(prompt, max_new, eos_id=None):
    seq = [int(t) for t in prompt]
    out = []
    for _ in range(max_new):
        tok = _next_token(seq)
        out.append(tok)
        seq.append(tok)
        if eos_id is not None and tok == eos_id:
            break
    return out


class _HostEngine:
    """Minimal continuous-mode engine: the exact surface EngineReplica
    touches (submit/cancel/run/completed/has_work/request_drain/now/
    on_iteration/metrics.watchdog), one token per live request per
    iteration."""

    mode = "continuous"

    def __init__(self, step_s=0.0, stall_after=None):
        self.on_iteration = None
        self.completed = {}
        self.metrics = types.SimpleNamespace(
            watchdog=types.SimpleNamespace(stalled=False))
        self._queue = []
        self._draining = False
        self._step_s = step_s
        self._stall_after = stall_after   # iterations until stalled=True
        self._iters = 0
        self._t0 = time.monotonic()

    def now(self):
        return time.monotonic() - self._t0

    @property
    def draining(self):
        return self._draining

    def request_drain(self):
        self._draining = True

    def has_work(self):
        return bool(self._queue)

    def submit(self, req, session=None):
        if len(np.asarray(req.prompt).ravel()) == 0:
            raise ValueError("empty prompt")
        if self._draining:
            req.status = "rejected"
            self.completed[req.rid] = req
            return False
        self._queue.append(req)
        return True

    def cancel(self, rid):
        for r in self._queue:
            if r.rid == rid:
                r.status = "cancelled"
                self.completed[rid] = r
                self._queue.remove(r)
                return
        raise KeyError(rid)

    def run(self):
        while self._queue:
            self._iters += 1
            if self._stall_after is not None and self._iters >= self._stall_after:
                self.metrics.watchdog.stalled = True
            if self.on_iteration is not None:
                self.on_iteration()     # may raise ReplicaKilled
                if not self._queue:
                    break
            for r in list(self._queue):
                seq = [int(t) for t in np.asarray(r.prompt).ravel()]
                seq += r.generated
                tok = _next_token(seq)
                r.generated.append(tok)
                if r.on_token is not None:
                    r.on_token(r.rid, tok)
                if (len(r.generated) >= r.max_new_tokens
                        or (r.eos_id is not None and tok == r.eos_id)):
                    r.status = "ok"
                    self.completed[r.rid] = r
                    self._queue.remove(r)
            if self._step_s:
                time.sleep(self._step_s)
        return dict(self.completed)

    def check_shutdown_invariants(self):
        assert not self._queue, "host engine stopped with live requests"


def _host_router(n=2, step_s=0.001, stall_after=None, stall_steps=None,
                 **router_kw):
    reps = [EngineReplica(f"r{i}",
                          lambda: _HostEngine(step_s=step_s,
                                              stall_after=stall_after),
                          heartbeat_s=0.01, stall_steps=stall_steps)
            for i in range(n)]
    return ReplicaRouter(reps, **router_kw)


# ---------------------------------------------------------------------------
# unit: backoff + env knobs
# ---------------------------------------------------------------------------

def test_backoff_is_capped_exponential():
    assert backoff_s(0, 0.05, 2.0) == 0.05
    assert backoff_s(1, 0.05, 2.0) == 0.1
    assert backoff_s(2, 0.05, 2.0) == 0.2
    assert backoff_s(10, 0.05, 2.0) == 2.0      # cap wins
    assert backoff_s(0, 3.0, 2.0) == 2.0        # cap wins immediately


def test_env_knob_defaults_and_validation(monkeypatch):
    for var in ("ICQ_RETRY_MAX", "ICQ_RETRY_BASE_S", "ICQ_RETRY_CAP_S",
                "ICQ_HEARTBEAT_S", "ICQ_STALL_STEPS"):
        monkeypatch.setenv(var, "")
    assert default_retry_max() == 5
    assert default_retry_base_s() == 0.05
    assert default_retry_cap_s() == 2.0
    assert default_heartbeat_s() == 0.5
    assert default_stall_steps() == 0
    monkeypatch.setenv("ICQ_RETRY_MAX", "2")
    monkeypatch.setenv("ICQ_HEARTBEAT_S", "0.25")
    monkeypatch.setenv("ICQ_STALL_STEPS", "4")
    assert default_retry_max() == 2
    assert default_heartbeat_s() == 0.25
    assert default_stall_steps() == 4
    monkeypatch.setenv("ICQ_RETRY_MAX", "-1")
    with pytest.raises(ValueError, match="ICQ_RETRY_MAX"):
        default_retry_max()
    monkeypatch.setenv("ICQ_HEARTBEAT_S", "0")
    with pytest.raises(ValueError, match="ICQ_HEARTBEAT_S"):
        default_heartbeat_s()
    monkeypatch.setenv("ICQ_STALL_STEPS", "-2")
    with pytest.raises(ValueError, match="ICQ_STALL_STEPS"):
        default_stall_steps()


# ---------------------------------------------------------------------------
# engine hooks: inert by default, drain refuses new admissions
# ---------------------------------------------------------------------------

def test_engine_drain_rejects_new_admissions(env):
    cfg, prompts, factory, baseline = env
    eng = factory()
    # the service hooks must be inert on a fresh engine: direct engine
    # use is bit-for-bit the pre-service behavior
    assert eng.on_iteration is None and not eng.draining
    eng.submit(Request(0, prompts[0], max_new_tokens=4))
    eng.request_drain()
    assert eng.draining
    assert eng.submit(Request(1, prompts[1], max_new_tokens=4)) is False
    assert eng.completed[1].status == "rejected"
    done = eng.run()
    # work admitted before the drain still finishes, identically
    assert done[0].status == "ok"
    assert list(done[0].generated) == baseline[0]
    eng.check_shutdown_invariants()


# ---------------------------------------------------------------------------
# real engine: kill -> failover -> parity, WAL cold restart, TCP e2e
# ---------------------------------------------------------------------------

def test_kill_midrun_failover_keeps_parity_and_exactly_once(env):
    cfg, prompts, factory, baseline = env
    metrics = ServiceMetrics()
    reps = [EngineReplica(f"r{i}", factory, heartbeat_s=0.05)
            for i in range(2)]
    router = ReplicaRouter(reps, metrics=metrics)
    terminals = {}
    router.done_observer = (
        lambda rid, st, toks: terminals.__setitem__(
            rid, terminals.get(rid, 0) + 1))
    chaos = {"streamed": 0, "killed": False}

    def kill_mid_decode(rid, tok):
        chaos["streamed"] += 1
        if chaos["streamed"] == 5 and not chaos["killed"]:
            chaos["killed"] = True
            router.kill("r0")

    router.token_observer = kill_mid_decode
    router.start()
    for i, p in enumerate(prompts):
        router.submit(Request(rid=i, prompt=p, max_new_tokens=4))
    _drive(router)
    res = router.results()
    router.stop()
    router.check_shutdown_invariants()

    assert chaos["killed"], "kill trigger never fired"
    assert set(res) == set(range(6))
    assert all(st == "ok" for st, _ in res.values())
    # failover folds streamed tokens into the prompt: greedy streams
    # must be token-identical to the no-failure baseline
    assert {rid: toks for rid, (st, toks) in res.items()} == baseline
    assert all(n == 1 for n in terminals.values())
    assert metrics.failovers >= 1 and metrics.replica_restarts >= 1
    assert metrics.replica_kills == 1
    assert metrics.duplicate_terminals == 0


def test_wal_cold_restart_replays_unfinished_only(env, tmp_path):
    cfg, prompts, factory, baseline = env
    path = str(tmp_path / "requests.wal")
    # forge the journal a crashed process would leave behind: rid 0
    # finished, rids 1-2 unfinished greedy, rid 3 unfinished *sampled*
    w = RequestWAL(path)
    for i in range(3):
        w.log_submit(Request(rid=i, prompt=prompts[i], max_new_tokens=4),
                     replica="r0")
    w.log_terminal(0, "ok", 4)
    w.log_submit(Request(rid=3, prompt=prompts[3], max_new_tokens=4,
                         sampling=SamplingParams(temperature=0.7)))
    w.close()

    wal = RequestWAL(path)
    metrics = ServiceMetrics()
    router = ReplicaRouter([EngineReplica("r0", factory, heartbeat_s=0.05)],
                           wal=wal, metrics=metrics)
    assert router.allocate_rid() == 4     # above everything journaled
    router.start()
    assert router.recover() == 2          # rids 1 and 2, not 0, not 3
    assert metrics.wal_replayed == 2
    assert router.wait_all(timeout=120.0)
    res = router.results()
    router.stop()
    router.check_shutdown_invariants()
    wal.close()

    assert res[3][0] == "failed"          # sampled: unreplayable
    for rid in (1, 2):
        assert res[rid] == ("ok", baseline[rid])
    reopened = RequestWAL(path)
    assert not reopened.pending           # every rid reached a terminal
    assert reopened.completed[0] == "ok"  # ...and was never re-run
    reopened.close()


def test_frontend_tcp_end_to_end(env):
    cfg, prompts, factory, baseline = env
    svc = ServingService(factory, n_replicas=1, supervise_s=0.05)
    host, port = svc.start()
    try:
        cli = ServingClient(host, port, retry_base_s=0.01)
        rid = cli.submit([int(t) for t in prompts[0]], max_new_tokens=4)
        status, tokens = cli.wait(rid, timeout=120.0)
        assert status == "ok" and tokens == baseline[0]

        rid2 = cli.submit([int(t) for t in prompts[1]], max_new_tokens=4)
        assert list(cli.stream(rid2)) == baseline[1]

        h = cli.health()
        assert h["ok"] and not h["draining"]
        assert h["replicas"][0]["state"] in ("idle", "running")
        m = cli.service_metrics()
        assert m["submits"] >= 2 and m["duplicate_terminals"] == 0

        with pytest.raises(RequestRejected, match="unknown-rid"):
            cli.poll(99999)
        with pytest.raises(RequestRejected, match="rejected"):
            cli.submit([], max_new_tokens=4)

        cli.drain()
        with pytest.raises(RequestRejected, match="draining"):
            cli.submit([1, 2], max_new_tokens=2)
    finally:
        svc.shutdown()
    svc.check_shutdown_invariants()


# ---------------------------------------------------------------------------
# host engine: supervision machinery
# ---------------------------------------------------------------------------

def test_host_engine_parity_oracle():
    router = _host_router(n=1)
    router.start()
    router.submit(Request(rid=0, prompt=np.asarray([3], np.int32),
                          max_new_tokens=5))
    assert router.wait_all(timeout=10.0)
    st, toks = router.results()[0]
    router.stop()
    assert st == "ok" and toks == _expected([3], 5)


def test_stall_watchdog_kills_replica_and_request_fails_over():
    metrics = ServiceMetrics()
    # the engine flags stalled from iteration 3 on; two consecutive
    # stalled iterations kill the worker mid-run
    router = _host_router(n=1, step_s=0.001, stall_after=3, stall_steps=2,
                          metrics=metrics)
    router.start()
    router.submit(Request(rid=0, prompt=np.asarray([3], np.int32),
                          max_new_tokens=5))
    _drive(router, timeout=30.0)
    st, toks = router.results()[0]
    router.stop()
    router.check_shutdown_invariants()
    assert st == "ok" and toks == _expected([3], 5)
    assert metrics.replica_restarts >= 1 and metrics.failovers >= 1
    assert metrics.duplicate_terminals == 0


def test_finished_but_unpublished_victim_completes_without_doubling():
    # the nastiest failover edge: the victim generated its whole budget
    # on the dead replica but the kill landed before the publish. The
    # router must complete it 'ok' locally with the stream exactly once
    # — not refold it into a doubled stream, not regenerate past budget.
    metrics = ServiceMetrics()
    router = _host_router(n=2, step_s=0.001, metrics=metrics)

    def kill_at_last_token(rid, tok):
        if rid == 0 and len(router._table[0].current.generated) >= 3:
            router.kill("r0")   # kill lands before the worker publishes

    router.token_observer = kill_at_last_token
    router.start()
    router.submit(Request(rid=0, prompt=np.asarray([3], np.int32),
                          max_new_tokens=3))
    _drive(router, timeout=30.0)
    st, toks = router.results()[0]
    router.stop()
    router.check_shutdown_invariants()
    assert st == "ok"
    assert toks == _expected([3], 3)      # exactly once, exactly 3
    assert metrics.failovers == 1 and metrics.duplicate_terminals == 0


def test_session_affinity_sticks_to_one_replica():
    router = _host_router(n=2)
    router.start()
    rids = []
    for _ in range(3):
        rid = router.allocate_rid()
        router.submit(Request(rid=rid, prompt=np.asarray([7], np.int32),
                              max_new_tokens=2), session="chat")
        rids.append(rid)
        assert router.wait(rid, timeout=10.0)
    owners = {router._table[rid].replica for rid in rids}
    router.stop()
    router.check_shutdown_invariants()
    assert len(owners) == 1               # turns never moved replicas
    assert router.health()["sessions"] == 1


def test_cancel_on_dead_owner_and_no_replica_available():
    router = _host_router(n=1, step_s=0.005)
    router.start()
    rid = router.submit(Request(rid=0, prompt=np.asarray([2], np.int32),
                                max_new_tokens=100000))
    r0 = router.replicas[0]
    r0.kill()
    deadline = time.monotonic() + 10.0
    while r0.state != "dead" and time.monotonic() < deadline:
        time.sleep(0.005)
    assert r0.state == "dead"
    # every replica down: new submissions are retryable-refused
    with pytest.raises(NoReplicaAvailable):
        router.submit(Request(rid=1, prompt=np.asarray([2], np.int32),
                              max_new_tokens=2))
    # the dead owner cannot make progress — cancel is honored locally
    assert router.cancel(rid) is True
    assert router.results()[rid][0] == "cancelled"
    router.supervise()                    # restart brings capacity back
    rid2 = router.submit(Request(rid=2, prompt=np.asarray([2], np.int32),
                                 max_new_tokens=3))
    assert router.wait(rid2, timeout=10.0)
    _drive(router, timeout=10.0)
    router.stop()
    router.check_shutdown_invariants()


def test_frontend_shed_backpressure_and_client_retry_exhaustion():
    router = _host_router(n=1, step_s=0.002)
    frontend = ServingFrontend(router, max_pending=1, supervise_s=0.05)
    router.start()
    host, port = frontend.start()
    sleeps = []
    cli = ServingClient(host, port, retry_max=3, retry_base_s=0.01,
                        retry_cap_s=0.02, sleep=sleeps.append)
    try:
        rid = cli.submit([5], max_new_tokens=100000)
        with pytest.raises(FrontendUnavailable, match="shed"):
            cli.submit([6], max_new_tokens=2)
        assert cli.retries == 3
        # capped exponential backoff between the retry attempts
        assert sleeps[:3] == [0.01, 0.02, 0.02]
        assert router.metrics.frontend_sheds >= 4   # first try + retries
        assert cli.cancel(rid) is True
        status, _ = cli.wait(rid, timeout=30.0)
        assert status in ("cancelled", "ok")
    finally:
        frontend.stop()
        router.stop()
    router.check_shutdown_invariants()


def test_duplicate_rid_rejected():
    router = _host_router(n=1)
    router.start()
    router.submit(Request(rid=0, prompt=np.asarray([1], np.int32),
                          max_new_tokens=2))
    with pytest.raises(ValueError, match="duplicate"):
        router.submit(Request(rid=0, prompt=np.asarray([1], np.int32),
                              max_new_tokens=2))
    assert router.wait_all(timeout=10.0)
    router.stop()
    router.check_shutdown_invariants()


# ---------------------------------------------------------------------------
# chaos schedules: random submit/cancel/kill against the host engine
# ---------------------------------------------------------------------------

def _chaos_run(reqs, kills, cancels):
    """One chaos schedule: submit ``reqs`` (prompt, max_new) pairs,
    cancel the given indices immediately, kill replicas when the global
    streamed-token count crosses each (threshold, replica_idx) entry.
    Asserts the service contract regardless of interleaving."""
    metrics = ServiceMetrics()
    router = _host_router(n=2, step_s=0.001, metrics=metrics)
    terminals = {}
    router.done_observer = (
        lambda rid, st, toks: terminals.__setitem__(
            rid, terminals.get(rid, 0) + 1))
    pending_kills = sorted(kills)
    streamed = {"n": 0}

    def tok_obs(rid, tok):
        streamed["n"] += 1
        while pending_kills and streamed["n"] >= pending_kills[0][0]:
            _, idx = pending_kills.pop(0)
            router.kill(f"r{idx}")

    router.token_observer = tok_obs
    router.start()
    rids = []
    for prompt, max_new in reqs:
        req = Request(rid=router.allocate_rid(),
                      prompt=np.asarray(prompt, np.int32),
                      max_new_tokens=max_new)
        while True:
            try:
                rids.append(router.submit(req))
                break
            except NoReplicaAvailable:
                router.supervise()        # restart, then re-route
    for i in cancels:
        router.cancel(rids[i])
    _drive(router, timeout=60.0)
    res = router.results()
    router.stop()
    router.check_shutdown_invariants()

    assert set(res) == set(rids)
    for rid in rids:
        st, _ = res[rid]
        assert st in STATUSES
        assert terminals.get(rid) == 1    # exactly one terminal, ever
    assert metrics.duplicate_terminals == 0
    # any request that ended 'ok' must carry the exact deterministic
    # stream, no matter how many times it moved replicas
    for (prompt, max_new), rid in zip(reqs, rids):
        st, toks = res[rid]
        if st == "ok":
            assert toks == _expected(prompt, max_new)
    return res


def test_chaos_deterministic_cases():
    # both replicas killed mid-storm
    _chaos_run(reqs=[([3], 5), ([4, 9], 4), ([11], 6), ([2, 2, 2], 3)],
               kills=[(3, 0), (8, 1)], cancels=[1])
    # kill storm with every request cancelled up front
    _chaos_run(reqs=[([1], 8), ([2], 8)], kills=[(1, 0)], cancels=[0, 1])
    # no failures at all: plain multi-replica serving
    _chaos_run(reqs=[([5], 3), ([6], 3), ([7], 3)], kills=[], cancels=[])


if HAVE_HYPOTHESIS:
    @settings(max_examples=10, deadline=None,
              suppress_health_check=[HealthCheck.too_slow,
                                     HealthCheck.data_too_large])
    @given(data=st.data())
    def test_chaos_schedule_property(data):
        n = data.draw(st.integers(1, 5), label="n_requests")
        reqs = [
            (data.draw(st.lists(st.integers(0, 99), min_size=1,
                                max_size=4), label=f"prompt{i}"),
             data.draw(st.integers(1, 6), label=f"max_new{i}"))
            for i in range(n)
        ]
        kills = data.draw(
            st.lists(st.tuples(st.integers(1, 15), st.integers(0, 1)),
                     max_size=2), label="kills")
        cancels = data.draw(
            st.lists(st.integers(0, n - 1), max_size=2, unique=True),
            label="cancels")
        _chaos_run(reqs, kills, cancels)
