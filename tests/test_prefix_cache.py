"""Prefix-cache + multi-turn session subsystem (ISSUE-8): chain-hash
properties, PrefixCache / SessionStore bookkeeping against a real block
pool, and the engine-level contract — warm (prefix-cache / session)
greedy serving is token-identical to cold prefill, through COW forks,
LRU eviction under pool pressure, preemption, fault recovery, the
split-step arm and both paged-attention arms.

The contract under test: the caches change *which rows get written*
(matched prefixes are mapped, never recomputed), but every row a lane
reads is bitwise the row cold prefill would have produced — so no
sampled token can tell warm from cold.
"""
import numpy as np
import jax
import pytest

from repro.configs import get_config, smoke_variant
from repro.models import init_model
from repro.serving import (GenerationEngine, KVBlockPool, PrefixCache,
                           Request, SessionStore, block_hashes)

ARCH = "llama3.2-1b"


def _setup(arch=ARCH):
    cfg = smoke_variant(get_config(arch))
    params = init_model(jax.random.PRNGKey(0), cfg)
    return cfg, params


# ---------------------------------------------------------------------------
# chain hashes (no model)
# ---------------------------------------------------------------------------

def test_block_hashes_commit_to_whole_prefix():
    rng = np.random.default_rng(0)
    a = rng.integers(0, 1000, 20).astype(np.int32)
    b = a.copy()
    ha, hb = block_hashes(a, 4), block_hashes(b, 4)
    assert ha == hb and len(ha) == 5          # partial tails never hashed
    # diverge inside block 2: digests 0-1 unchanged, 2+ all differ (each
    # digest chains its parent, so one flipped token poisons the suffix)
    b[9] += 1
    hb = block_hashes(b, 4)
    assert hb[:2] == ha[:2]
    assert all(x != y for x, y in zip(hb[2:], ha[2:]))
    # same tokens at a different block size share nothing
    assert set(block_hashes(a, 5)).isdisjoint(ha)
    # a longer sequence's chain extends its prefix's chain exactly
    assert block_hashes(a[:12], 4) == ha[:3]
    assert block_hashes(a, 4, n_blocks=2) == ha[:2]
    assert block_hashes(a[:3], 4) == []       # no full block yet
    with pytest.raises(ValueError):
        block_hashes(a, 0)


# ---------------------------------------------------------------------------
# PrefixCache / SessionStore bookkeeping (real pool, no model)
# ---------------------------------------------------------------------------

def _grown_chain(pool, lane, n_tokens):
    pool.grow(lane, n_tokens)
    chain = pool.lane_chain(lane)
    for b in chain:                 # retain-at-finish: pin THEN release
        pool.incref(b)
    pool.release(lane)
    return chain


def test_prefix_cache_insert_match_dedupe_evict():
    pool = KVBlockPool(num_blocks=12, block_size=4, n_lanes=2,
                       max_blocks_per_lane=4)
    cache = PrefixCache()
    toks = np.arange(16, dtype=np.int32)
    hashes = block_hashes(toks, 4)
    chain = _grown_chain(pool, 0, 16)
    assert cache.insert(hashes, chain, pool, now=1.0) == 4
    for b in chain:                 # hand-off pins drop; cache pins stay
        pool.decref(b)
    pool.check_invariants(external=cache.holdings())
    # re-inserting the same chain (another request, same prefix) is a
    # refresh, not a double-pin
    chain2 = _grown_chain(pool, 1, 16)
    assert cache.insert(hashes, chain2, pool, now=2.0) == 0
    for b in chain2:                # second copy's pins drop; first stays
        pool.decref(b)
    assert cache.match(hashes, now=3.0) == chain
    assert cache.match(block_hashes(toks[:9], 4), now=3.0) == chain[:2]
    miss = block_hashes(toks + 1, 4)
    assert cache.match(miss, now=3.0) == []
    # leaf-first LRU: eviction removes from the tail inward, and a
    # protected block is skipped
    freed_before = pool.free_blocks
    assert cache.evict_until(pool, min_free=freed_before + 2,
                             protect=(chain[0],)) == 2
    assert len(cache) == 2
    assert cache.match(hashes, now=4.0) == chain[:2]
    assert cache.clear(pool) == 2
    pool.check_invariants()
    assert pool.free_blocks == 12


def test_session_store_retain_match_expire_evict():
    pool = KVBlockPool(num_blocks=12, block_size=4, n_lanes=2,
                       max_blocks_per_lane=4)
    store = SessionStore()
    t1 = np.arange(10, dtype=np.int32)
    c1 = _grown_chain(pool, 0, 10)
    store.retain("a", t1, c1, pool, now=1.0)
    for b in c1:                    # the engine's temporary pins drop
        pool.decref(b)
    pool.check_invariants(external=store.holdings())
    # next turn extends the history: common prefix is the whole chain
    prompt = np.concatenate([t1, np.array([7, 8, 9], np.int32)])
    m, blocks = store.match("a", prompt, now=2.0)
    assert m == 10 and blocks == c1
    # a diverging prompt matches only up to the first different token
    bad = prompt.copy()
    bad[4] = 999
    m, _ = store.match("a", bad, now=2.0)
    assert m == 4
    assert store.match("ghost", prompt, now=2.0) == (0, [])
    # re-retaining an overlapping chain never lets shared blocks transit
    # refcount 0 (pin-new-before-unpin-old)
    c2 = list(c1)                   # same physical blocks, turn 2
    for b in c2:
        pool.incref(b)
    store.retain("a", prompt, c2, pool, now=3.0)
    for b in c2:
        pool.decref(b)
    pool.check_invariants(external=store.holdings())
    # TTL expiry honors protection (in-flight sessions)
    c3 = _grown_chain(pool, 1, 8)
    store.retain("b", np.arange(8, dtype=np.int32), c3, pool, now=4.0)
    for b in c3:
        pool.decref(b)
    assert store.expire(now=100.0, ttl=50.0, pool=pool,
                        protect=("a",)) == ["b"]
    assert "a" in store and "b" not in store
    # LRU eviction under pressure
    assert store.evict_until(pool, min_free=pool.num_blocks) == 1
    assert len(store) == 0
    pool.check_invariants()
    assert pool.free_blocks == 12


# ---------------------------------------------------------------------------
# engine-level: warm == cold, token for token
# ---------------------------------------------------------------------------

def _mixed_after_system(cfg, n, system_len=12, seed=0):
    """n requests sharing one system prompt, each with a distinct tail."""
    rng = np.random.default_rng(seed)
    system = rng.integers(0, cfg.vocab_size, system_len).astype(np.int32)
    specs = []
    for rid in range(n):
        tail = rng.integers(0, cfg.vocab_size,
                            int(rng.integers(2, 7))).astype(np.int32)
        specs.append(dict(rid=rid,
                          prompt=np.concatenate([system, tail]),
                          max_new_tokens=int(rng.integers(2, 6))))
    return specs


def _run(params, cfg, specs, **kw):
    kw.setdefault("batch_size", 2)
    kw.setdefault("max_len", 32)
    kw.setdefault("kv_layout", "paged")
    kw.setdefault("kv_block_size", 4)
    eng = GenerationEngine(params, cfg, mode="continuous", **kw)
    for s in specs:
        eng.submit(Request(**{k: (v.copy() if k == "prompt" else v)
                              for k, v in s.items()}))
    out = {rid: r.generated for rid, r in eng.run().items()}
    return out, eng


def test_engine_cross_request_prefix_parity():
    """More requests than lanes, all sharing a system prompt: later
    admissions must hit the chains earlier finishers inserted — and emit
    exactly the tokens a cold engine emits."""
    cfg, params = _setup()
    specs = _mixed_after_system(cfg, 6)
    out_cold, _ = _run(params, cfg, specs, prefix_cache=False)
    out_warm, eng = _run(params, cfg, specs, prefix_cache=True)
    assert out_warm == out_cold
    s = eng.metrics.summary()
    assert s["prefix_hits"] >= 1 and s["prefix_hit_rate"] > 0
    assert s["prefix_tokens_skipped"] > 0
    eng.check_shutdown_invariants()
    # cached chains still pin blocks after the run; clearing returns the
    # pool to fully free
    assert eng._pool.free_blocks < eng._pool.num_blocks
    assert eng.clear_prefix_cache() > 0
    assert eng._pool.free_blocks == eng._pool.num_blocks
    eng._pool.check_invariants()


def test_engine_session_multiturn_parity_and_cow_fork():
    """2 sessions x 3 turns: turn 2+ warm-starts mid-block from the
    retained chain (a COW fork), and every turn's greedy output matches
    a cold engine fed the identical prompt."""
    cfg, params = _setup()
    kw = dict(batch_size=2, max_len=64, mode="continuous",
              kv_layout="paged", kv_block_size=4, prefill_chunk=8)
    warm = GenerationEngine(params, cfg, prefix_cache=True, **kw)
    cold = GenerationEngine(params, cfg, prefix_cache=False, **kw)
    rng = np.random.default_rng(5)
    system = rng.integers(0, cfg.vocab_size, 12).astype(np.int32)
    history = {sid: system.copy() for sid in range(2)}
    rid = 0
    for turn in range(3):
        reqs = []
        for sid in range(2):
            user = rng.integers(0, cfg.vocab_size,
                                int(rng.integers(3, 7))).astype(np.int32)
            prompt = np.concatenate([history[sid], user])
            reqs.append((rid, sid, prompt))
            rid += 1
        for r, sid, prompt in reqs:
            warm.submit(Request(r, prompt.copy(), max_new_tokens=4,
                                arrival_time=warm.now()),
                        session=f"s{sid}")
            cold.submit(Request(r, prompt.copy(), max_new_tokens=4,
                                arrival_time=cold.now()))
        dw, dc = warm.run(), cold.run()
        for r, sid, prompt in reqs:
            assert dw[r].generated == dc[r].generated, (
                f"warm vs cold diverged: session {sid} turn {turn}")
            history[sid] = np.concatenate(
                [prompt, np.asarray(dw[r].generated, np.int32)])
    s = warm.metrics.summary()
    assert s["session_hits"] >= 2          # every turn-2+ warm-started
    assert s["cow_forks"] >= 1             # histories are not block-aligned
    assert s["prefix_tokens_skipped"] > 0
    assert s["sessions_active"] == 2
    warm.check_shutdown_invariants()
    assert warm.clear_prefix_cache() > 0
    assert warm._pool.free_blocks == warm._pool.num_blocks


def test_engine_prefix_parity_under_preemption():
    """A pool too small for both long-running lanes: preemption +
    prefix reuse together must still reproduce the contiguous stream."""
    cfg, params = _setup()
    rng = np.random.default_rng(1)
    specs = [dict(rid=r,
                  prompt=rng.integers(0, cfg.vocab_size, 4).astype(np.int32),
                  max_new_tokens=16) for r in range(2)]
    out_c, _ = _run(params, cfg, specs, kv_layout="contiguous")
    out_p, eng = _run(params, cfg, specs, prefix_cache=True, kv_blocks=6)
    assert eng.metrics.preemptions >= 1, \
        "pool was large enough that nothing was preempted — bad fixture"
    assert out_p == out_c
    eng.check_shutdown_invariants()


def test_engine_prefix_eviction_under_pool_pressure():
    """Retained chains fill the pool; admitting fresh distinct prompts
    must evict cached chains (never preempt a running lane for them) and
    still serve every request with cold-identical tokens."""
    cfg, params = _setup()
    rng = np.random.default_rng(9)
    specs = [dict(rid=r,
                  prompt=rng.integers(0, cfg.vocab_size,
                                      int(rng.integers(8, 13))
                                      ).astype(np.int32),
                  max_new_tokens=4) for r in range(6)]
    out_cold, _ = _run(params, cfg, specs, prefix_cache=False,
                       kv_blocks=10)
    out_warm, eng = _run(params, cfg, specs, prefix_cache=True,
                         kv_blocks=10)
    assert out_warm == out_cold
    s = eng.metrics.summary()
    assert s["prefix_evictions"] >= 1, \
        "pool pressure never evicted a cached chain — bad fixture"
    eng.check_shutdown_invariants()


def test_engine_prefix_parity_split_step_and_faults():
    """Warm serving with the split (unfused) step under a fault plan:
    ok-status streams still match the cold no-fault run."""
    from repro.serving.faults import FaultInjector, parse_fault_plan

    cfg, params = _setup()
    specs = _mixed_after_system(cfg, 4, seed=2)
    out_cold, _ = _run(params, cfg, specs, prefix_cache=False,
                       prefill_chunk=4)
    out_warm, eng = _run(params, cfg, specs, prefix_cache=True,
                         prefill_chunk=4, fused_step=False,
                         faults=FaultInjector(parse_fault_plan("2:nan")))
    done = {rid: r for rid, r in eng.completed.items()}
    for rid, r in done.items():
        if r.status == "ok":
            assert out_warm[rid] == out_cold[rid]
    assert eng.metrics.summary()["degraded_steps"] >= 1
    eng.check_shutdown_invariants()


@pytest.mark.parametrize("arm", ["xla", "pallas"])
def test_engine_prefix_parity_both_paged_attn_arms(arm, monkeypatch):
    """Warm == cold on each paged-attention arm (the Pallas in-kernel
    page-table walk runs in interpret mode on CPU)."""
    monkeypatch.setenv("ICQ_PAGED_ATTN", arm)
    cfg, params = _setup()
    specs = _mixed_after_system(cfg, 3, system_len=8, seed=3)
    out_cold, _ = _run(params, cfg, specs, prefix_cache=False)
    out_warm, eng = _run(params, cfg, specs, prefix_cache=True)
    assert out_warm == out_cold
    assert eng.metrics.summary()["prefix_hits"] >= 1
    eng.check_shutdown_invariants()


def test_engine_session_ttl_expiry():
    """session_ttl=0 deterministically expires an idle session at the
    next lifecycle pass; its blocks return to the pool."""
    cfg, params = _setup()
    eng = GenerationEngine(params, cfg, batch_size=2, max_len=32,
                           mode="continuous", kv_layout="paged",
                           kv_block_size=4, prefix_cache=True,
                           session_ttl=0.0)
    rng = np.random.default_rng(4)
    p1 = rng.integers(0, cfg.vocab_size, 8).astype(np.int32)
    eng.submit(Request(0, p1, max_new_tokens=3), session="chat")
    eng.run()
    assert len(eng._sessions) == 1
    # any later run's lifecycle pass sweeps the now-idle session
    p2 = rng.integers(0, cfg.vocab_size, 4).astype(np.int32)
    eng.submit(Request(1, p2, max_new_tokens=3))
    eng.run()
    assert len(eng._sessions) == 0
    assert eng.metrics.summary()["session_expiries"] >= 1
    eng.check_shutdown_invariants()
    eng.clear_prefix_cache()
    assert eng._pool.free_blocks == eng._pool.num_blocks


def test_engine_session_api_validation():
    cfg, params = _setup()
    eng = GenerationEngine(params, cfg, batch_size=2, max_len=32,
                           mode="continuous", kv_layout="paged",
                           kv_block_size=4, prefix_cache=False)
    with pytest.raises(ValueError, match="prefix_cache"):
        eng.submit(Request(0, np.zeros(4, np.int32), max_new_tokens=2),
                   session="chat")
    warm = GenerationEngine(params, cfg, batch_size=2, max_len=32,
                            mode="continuous", kv_layout="paged",
                            kv_block_size=4, prefix_cache=True)
    warm.submit(Request(0, np.zeros(4, np.int32), max_new_tokens=2),
                session="chat")
    with pytest.raises(ValueError, match="in flight"):
        warm.submit(Request(1, np.zeros(4, np.int32), max_new_tokens=2),
                    session="chat")


def test_engine_prefix_cache_gating():
    cfg, params = _setup()
    with pytest.raises(ValueError, match="paged"):
        GenerationEngine(params, cfg, batch_size=2, max_len=16,
                         mode="continuous", kv_layout="contiguous",
                         prefix_cache=True)
    ssm_cfg, ssm_params = _setup("mamba2-130m")
    with pytest.raises((NotImplementedError, ValueError)):
        GenerationEngine(ssm_params, ssm_cfg, batch_size=2, max_len=16,
                         mode="continuous", kv_layout="paged",
                         prefix_cache=True)


# ---------------------------------------------------------------------------
# env knobs + block-size autotune
# ---------------------------------------------------------------------------

def test_prefix_env_knob_parsing(monkeypatch):
    from repro.serving.engine import (default_kv_block_size,
                                      default_prefix_cache,
                                      default_session_ttl)

    monkeypatch.delenv("ICQ_PREFIX_CACHE", raising=False)
    monkeypatch.delenv("ICQ_SESSION_TTL", raising=False)
    assert default_prefix_cache() is False
    assert default_session_ttl() == 300.0
    monkeypatch.setenv("ICQ_PREFIX_CACHE", "1")
    assert default_prefix_cache() is True
    monkeypatch.setenv("ICQ_PREFIX_CACHE", "off")
    assert default_prefix_cache() is False
    monkeypatch.setenv("ICQ_PREFIX_CACHE", "")    # empty = unset
    assert default_prefix_cache() is False
    monkeypatch.setenv("ICQ_PREFIX_CACHE", "banana")
    with pytest.raises(ValueError):
        default_prefix_cache()
    monkeypatch.setenv("ICQ_SESSION_TTL", "0")
    assert default_session_ttl() == 0.0
    monkeypatch.setenv("ICQ_SESSION_TTL", "2.5")
    assert default_session_ttl() == 2.5
    monkeypatch.setenv("ICQ_SESSION_TTL", "-1")
    with pytest.raises(ValueError):
        default_session_ttl()
    monkeypatch.setenv("ICQ_SESSION_TTL", "soon")
    with pytest.raises(ValueError):
        default_session_ttl()
    monkeypatch.setenv("ICQ_KV_BLOCK_SIZE", "auto")
    assert default_kv_block_size() == "auto"


def test_kv_block_size_autotune_roundtrip(tmp_path, monkeypatch):
    from repro.kernels.autotune import (autotune_kv_block_size,
                                        kv_block_size_for)

    monkeypatch.setenv("ICQ_AUTOTUNE_CACHE",
                       str(tmp_path / "autotune.json"))
    assert kv_block_size_for(64) is None        # cold cache
    # block-multiple lengths at a long cap: large blocks win (table
    # overhead dominates, zero fragmentation)
    res = autotune_kv_block_size([512] * 8, 512)
    assert res["block_size"] in (32, 64) and res["cached"] is False
    assert kv_block_size_for(512) == res["block_size"]
    again = autotune_kv_block_size([512] * 8, 512)
    assert again["cached"] is True
    assert again["block_size"] == res["block_size"]
    # short ragged lengths at the same cap would fragment big blocks;
    # a different cap gets its own cache key
    res16 = autotune_kv_block_size([3, 5, 2, 7], 64)
    assert res16["block_size"] <= 8
    assert kv_block_size_for(64) == res16["block_size"]
    with pytest.raises(ValueError):
        autotune_kv_block_size([], 64)
    with pytest.raises(ValueError):
        autotune_kv_block_size([4], 0)


def test_engine_resolves_auto_block_size(tmp_path, monkeypatch):
    from repro.kernels.autotune import autotune_kv_block_size

    monkeypatch.setenv("ICQ_AUTOTUNE_CACHE",
                       str(tmp_path / "autotune.json"))
    cfg, params = _setup()
    # a miss falls back to the static default
    eng = GenerationEngine(params, cfg, batch_size=2, max_len=32,
                           mode="continuous", kv_layout="paged",
                           kv_block_size="auto")
    assert eng.kv_block_size == 16
    res = autotune_kv_block_size([8, 12, 6, 9], 32)
    eng2 = GenerationEngine(params, cfg, batch_size=2, max_len=32,
                            mode="continuous", kv_layout="paged",
                            kv_block_size="auto")
    assert eng2.kv_block_size == res["block_size"]
