"""End-to-end behaviour: train -> checkpoint/restart -> quantize -> serve.

These are the paper's workflow on a reduced scale: a small LM is trained
on the synthetic corpus, ICQuant-quantized post-training with/without
outlier separation, and the quality ordering of the paper's Figure 5
must hold on held-out NLL.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, smoke_variant
from repro.data import SyntheticLM
from repro.launch.quantize import quantize_tree
from repro.launch.steps import loss_fn
from repro.launch.train import train


@pytest.fixture(scope="module")
def trained(tmp_path_factory):
    ckpt = str(tmp_path_factory.mktemp("ckpt"))
    params, losses = train(
        "internlm2-1.8b", steps=40, batch=8, seq=64, ckpt_dir=ckpt,
        ckpt_every=20, log_every=100,
    )
    return params, losses, ckpt


def test_training_reduces_loss(trained):
    _, losses, _ = trained
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.1


def test_restart_from_checkpoint_continues(trained, tmp_path):
    _, _, ckpt = trained
    params2, losses2 = train(
        "internlm2-1.8b", steps=42, batch=8, seq=64, ckpt_dir=ckpt,
        resume=True, ckpt_every=0, log_every=100,
    )
    # resumed at step 40 -> only 2 steps run
    assert len(losses2) == 2


def _heldout_nll(params, cfg, seed=999):
    data = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=64, seed=0)
    b = data.batch(step=10_000 + seed, shard=3, batch_size=8)
    loss, _ = loss_fn(params, cfg, {k: jnp.asarray(v) for k, v in b.items()})
    return float(loss)


def test_quantization_quality_ordering(trained):
    """ICQuant 3-bit must sit between FP and a crude 3-bit no-outlier RTN
    (the paper's range-halving effect)."""
    params, _, _ = trained
    cfg = smoke_variant(get_config("internlm2-1.8b"))
    nll_fp = _heldout_nll(params, cfg)

    q3, _ = quantize_tree(params, 3, gamma=0.05)
    nll_q3 = _heldout_nll(q3, cfg)

    q3_no_outlier, _ = quantize_tree(params, 3, gamma=1e-9)
    nll_q3_no = _heldout_nll(q3_no_outlier, cfg)

    assert nll_fp <= nll_q3 + 1e-6
    assert nll_q3 <= nll_q3_no + 1e-6, (
        f"outlier separation should not hurt: {nll_q3} vs {nll_q3_no}"
    )
    assert nll_q3 - nll_fp < 1.0, "3-bit ICQuant should stay close to FP"


def test_quantized_params_bits(trained):
    params, _, _ = trained
    _, acct = quantize_tree(params, 2, gamma=0.05)
    # smoke dims are tiny (d_in=64) so codebook overhead dominates; the
    # accounting must still be internally consistent
    assert acct["mean_bits"] > 2.3
    assert acct["quantized_weights"] > 0
