"""Outlier-suppression baselines (paper §4.1) sanity + comparison."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro import core
from repro.core.stats import heavy_tailed_weights
from repro.quant import (
    SUPPRESSION_TECHNIQUES,
    grouped_rtn,
    incoherence_rtn,
    mixed_precision_rtn,
    vanilla_rtn,
)


@pytest.fixture(scope="module")
def W():
    return heavy_tailed_weights(32, 2048, seed=0)


@pytest.mark.parametrize("name", sorted(SUPPRESSION_TECHNIQUES))
def test_technique_runs_and_reduces_error_vs_more_bits(name, W):
    fn = SUPPRESSION_TECHNIQUES[name]
    W3, bits3 = fn(W, 3)
    W4, bits4 = fn(W, 4)
    mse3 = float(((W - np.asarray(W3)) ** 2).mean())
    mse4 = float(((W - np.asarray(W4)) ** 2).mean())
    assert mse4 < mse3
    assert bits4 > bits3


def test_grouping_beats_vanilla(W):
    Wg, _ = grouped_rtn(W, 3, group=128)
    Wv, _ = vanilla_rtn(W, 3)
    assert ((W - np.asarray(Wg)) ** 2).mean() < ((W - np.asarray(Wv)) ** 2).mean()


def test_mixed_precision_exact_on_outliers(W):
    Wm, _ = mixed_precision_rtn(W, 3, gamma=0.01)
    mask = np.asarray(core.outlier_mask(jnp.asarray(W), 0.01))
    np.testing.assert_array_equal(np.asarray(Wm)[mask], W[mask])


def test_incoherence_orthogonality():
    from repro.quant.baselines import random_orthogonal

    for n in (64, 100):
        Q = random_orthogonal(n, seed=1)
        np.testing.assert_allclose(Q @ Q.T, np.eye(n), atol=1e-4)


def test_icquant_best_tradeoff(W):
    """Fig 5(b): at comparable storage, ICQuant has the lowest MSE among
    suppression techniques on heavy-tailed weights."""
    results = {}
    Wg, bits_g = grouped_rtn(W, 3, group=128)          # ~3.25 b/w
    results["grouped"] = (bits_g, float(((W - np.asarray(Wg)) ** 2).mean()))
    Wm, bits_m = mixed_precision_rtn(W, 3, gamma=0.01)  # ~3.3 b/w
    results["mixed"] = (bits_m, float(((W - np.asarray(Wm)) ** 2).mean()))
    pk = core.quantize(jnp.asarray(W), 3, gamma=0.05)   # ~3.4 b/w
    results["icquant"] = (
        pk.bits_per_weight()["total"],
        float(((W - np.asarray(core.dequantize(pk))) ** 2).mean()),
    )
    assert results["icquant"][1] < results["grouped"][1]
    assert results["icquant"][1] < results["mixed"][1]
