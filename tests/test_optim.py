"""Optimizer + gradient compression."""
import numpy as np
import jax
import jax.numpy as jnp

from repro.optim import (
    AdamWConfig,
    adamw_init,
    adamw_update,
    compress_int8,
    cosine_schedule,
    decompress_int8,
    error_feedback_update,
    global_norm,
)
from repro.optim.compression import init_residuals


def test_adamw_minimizes_quadratic():
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, total_steps=200,
                      warmup_steps=1)
    target = jnp.asarray([1.0, -2.0, 3.0])
    params = dict(w=jnp.zeros(3))
    state = adamw_init(params, cfg)
    for _ in range(200):
        grads = jax.grad(lambda p: ((p["w"] - target) ** 2).sum())(params)
        params, state = adamw_update(params, grads, state, cfg)
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target),
                               atol=1e-2)


def test_cosine_schedule_shape():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100)
    assert float(cosine_schedule(cfg, jnp.asarray(0.0))) == 0.0
    assert abs(float(cosine_schedule(cfg, jnp.asarray(10.0))) - 1.0) < 1e-6
    assert float(cosine_schedule(cfg, jnp.asarray(100.0))) < 1e-6


def test_grad_clipping():
    cfg = AdamWConfig(lr=0.0, clip_norm=1.0)
    params = dict(w=jnp.zeros(4))
    state = adamw_init(params, cfg)
    big = dict(w=jnp.full(4, 1e6))
    # lr=0 -> no movement, but the update must not produce NaN/inf
    p2, _ = adamw_update(params, big, state, cfg)
    assert bool(jnp.isfinite(p2["w"]).all())


def test_int8_roundtrip_accuracy():
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.standard_normal((333, 77)), jnp.float32)
    q, s = compress_int8(g)
    out = decompress_int8(q, s, g.shape)
    err = float(jnp.abs(out - g).max())
    scale = float(jnp.abs(g).max()) / 127
    assert err <= scale * 1.01


def test_error_feedback_unbiased_over_time():
    """Sum of EF-compressed grads converges to the sum of true grads."""
    rng = np.random.default_rng(1)
    grads_seq = [dict(g=jnp.asarray(rng.standard_normal(512) * 1e-3,
                                    jnp.float32)) for _ in range(50)]
    res = init_residuals(grads_seq[0])
    acc_true = jnp.zeros(512)
    acc_comp = jnp.zeros(512)
    for g in grads_seq:
        deq, res = error_feedback_update(g, res)
        acc_true += g["g"]
        acc_comp += deq["g"]
    # residual carries what's missing
    np.testing.assert_allclose(
        np.asarray(acc_comp + res["g"]), np.asarray(acc_true),
        rtol=1e-4, atol=1e-6,
    )


def test_global_norm():
    t = dict(a=jnp.asarray([3.0]), b=jnp.asarray([4.0]))
    assert abs(float(global_norm(t)) - 5.0) < 1e-6
