"""Pallas paged-attention decode kernel (ISSUE-7) vs the XLA gather arm.

The contract under test: walking the page table *inside* the kernel
(streaming only live blocks through VMEM) computes the same masked
softmax the XLA arm computes over the gathered logical view — to ulp
tolerance at the kernel boundary, and greedy token-identically at the
engine boundary (``ICQ_PAGED_ATTN=pallas|xla``). Fragmented / shuffled
page tables, ragged per-lane lengths, partially-filled tail blocks,
unmapped (-1) tail entries and recycled (kv_len == 0) lanes must all be
invisible to the output, and garbage parked in block 0 (the clamp
target for -1 entries) must never leak into any lane's context.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.kernels.paged_attention import (
    PAGES_PER_STEP_CANDIDATES,
    attn_vmem_bytes,
    fallback_pages_per_step,
    paged_attention,
)

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                   # pragma: no cover
    HAVE_HYPOTHESIS = False


# ---------------------------------------------------------------------------
# oracle: the XLA arm's math — clamped gather + masked softmax, f64
# ---------------------------------------------------------------------------

def _oracle(q, k_pool, v_pool, pages, kv_len, q2=None, k2_pool=None):
    """f64 plain-softmax attention over the clamped logical gather: the
    same semantics as layers._paged_gather + chunked_attention, computed
    the straightforward way so the kernel's online-softmax reassociation
    is the only difference."""
    B, Hkv, G, d = q.shape
    nb, bs = k_pool.shape[0], k_pool.shape[1]
    T = pages.shape[1] * bs
    pg = np.clip(pages, 0, nb - 1)

    def gather(pool):
        return pool[pg].reshape(B, T, Hkv, pool.shape[-1]).astype(np.float64)

    s = np.einsum("bhgd,bthd->bhgt", q.astype(np.float64), gather(k_pool))
    if q2 is not None:
        s += np.einsum("bhgd,bthd->bhgt", q2.astype(np.float64),
                       gather(k2_pool))
    valid = np.arange(T)[None, :] < kv_len[:, None]            # (B, T)
    s = np.where(valid[:, None, None, :], s, -np.inf)
    m = np.where(kv_len[:, None, None] > 0, s.max(-1), 0.0)[..., None]
    p = np.where(valid[:, None, None, :], np.exp(s - m), 0.0)
    l = p.sum(-1, keepdims=True)
    ctx = np.einsum("bhgt,bthd->bhgd", p, gather(v_pool))
    return (ctx / np.maximum(l, 1e-30)).astype(np.float32)


def _case(rng, B, Hkv, G, d, dv, bs, n_pt, nb, kv_len, *, d2=0,
          avoid_block0=False):
    """Random operands with a shuffled, fragmented page table: lanes
    interleave through a block permutation, unmapped tail entries are
    -1, and ``avoid_block0`` keeps every live page >= 1 so block 0 can
    be scrambled as the clamp-garbage probe."""
    q = rng.standard_normal((B, Hkv, G, d)).astype(np.float32)
    k_pool = rng.standard_normal((nb, bs, Hkv, d)).astype(np.float32)
    v_pool = rng.standard_normal((nb, bs, Hkv, dv)).astype(np.float32)
    q2 = k2_pool = None
    if d2:
        q2 = rng.standard_normal((B, Hkv, G, d2)).astype(np.float32)
        k2_pool = rng.standard_normal((nb, bs, Hkv, d2)).astype(np.float32)
    kv_len = np.asarray(kv_len, np.int32)
    blocks = np.arange(1, nb) if avoid_block0 else np.arange(nb)
    perm = rng.permutation(blocks)
    pages = np.full((B, n_pt), -1, np.int32)
    take = 0
    for i in range(B):
        need = -(-int(kv_len[i]) // bs)
        pages[i, :need] = perm[take: take + need]
        take += need
    assert take <= len(perm), "test case maps more blocks than the pool"
    return q, k_pool, v_pool, pages, kv_len, q2, k2_pool


def _kernel_out(q, k_pool, v_pool, pages, kv_len, q2, k2_pool, pps):
    return np.asarray(paged_attention(
        jnp.asarray(q), jnp.asarray(k_pool), jnp.asarray(v_pool),
        jnp.asarray(pages), jnp.asarray(kv_len),
        q2=None if q2 is None else jnp.asarray(q2),
        k2_pool=None if k2_pool is None else jnp.asarray(k2_pool),
        pages_per_step=pps))


# ---------------------------------------------------------------------------
# deterministic parity sweeps (interpret mode — run everywhere)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("pps", [1, 2, 8])
def test_gqa_parity_ragged_lanes(pps):
    """GQA flavor: ragged kv_len (full blocks, partial tail, single row,
    recycled kv_len=0 lane) x every pages-per-step shape, vs the f64
    oracle to f32-ulp-scale tolerance."""
    rng = np.random.default_rng(pps)
    case = _case(rng, B=4, Hkv=2, G=2, d=8, dv=8, bs=4, n_pt=4, nb=20,
                 kv_len=[16, 7, 1, 0])
    out = _kernel_out(*case, pps)
    ref = _oracle(*case[:5], q2=case[5], k2_pool=case[6])
    np.testing.assert_allclose(out, ref, rtol=2e-5, atol=2e-5)
    assert np.all(out[3] == 0.0)            # recycled lane -> exact zeros


@pytest.mark.parametrize("pps", [1, 2])
def test_mla_rope_sidechannel_parity(pps):
    """MLA flavor: Hkv=1, the latent pool doubles as K and V, rope
    halves ride the q2/k2 score pair."""
    rng = np.random.default_rng(10 + pps)
    q, c_pool, _, pages, kv_len, q2, r_pool = _case(
        rng, B=3, Hkv=1, G=4, d=8, dv=8, bs=4, n_pt=3, nb=12,
        kv_len=[10, 4, 3], d2=4)
    out = _kernel_out(q, c_pool, c_pool, pages, kv_len, q2, r_pool, pps)
    ref = _oracle(q, c_pool, c_pool, pages, kv_len, q2=q2, k2_pool=r_pool)
    np.testing.assert_allclose(out, ref, rtol=2e-5, atol=2e-5)


def test_block0_garbage_is_invisible():
    """-1 page entries clamp to block 0, so block 0 is the one block a
    fragmented pool can hand any lane uninvited: scrambling it (huge
    finite values) must leave every output bitwise unchanged when no
    live page maps it."""
    rng = np.random.default_rng(3)
    q, k_pool, v_pool, pages, kv_len, _, _ = _case(
        rng, B=3, Hkv=2, G=2, d=8, dv=8, bs=4, n_pt=3, nb=12,
        kv_len=[9, 4, 0], avoid_block0=True)
    base = _kernel_out(q, k_pool, v_pool, pages, kv_len, None, None, 2)
    k_pool[0] = 1e9
    v_pool[0] = -1e9
    poisoned = _kernel_out(q, k_pool, v_pool, pages, kv_len, None, None, 2)
    assert np.array_equal(base.view(np.uint8), poisoned.view(np.uint8))


def test_rejects_lone_rope_operand():
    rng = np.random.default_rng(0)
    q, k_pool, v_pool, pages, kv_len, q2, _ = _case(
        rng, B=1, Hkv=1, G=1, d=4, dv=4, bs=2, n_pt=2, nb=4,
        kv_len=[3], d2=4)
    with pytest.raises(ValueError):
        paged_attention(jnp.asarray(q), jnp.asarray(k_pool),
                        jnp.asarray(v_pool), jnp.asarray(pages),
                        jnp.asarray(kv_len), q2=jnp.asarray(q2))


# ---------------------------------------------------------------------------
# property test: fragmented tables / ragged lengths / recycled lanes
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:
    @settings(max_examples=20, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1),
           B=st.integers(1, 3),
           hkv_g=st.sampled_from([(1, 4), (2, 2), (2, 1)]),
           bs=st.sampled_from([2, 4]),
           n_pt=st.integers(1, 4),
           pps=st.sampled_from(PAGES_PER_STEP_CANDIDATES),
           mla=st.booleans())
    def test_property_kernel_matches_oracle(seed, B, hkv_g, bs, n_pt, pps,
                                            mla):
        """Any shuffled/fragmented table, any ragged kv_len mix (partial
        tails, unmapped -1 tails, recycled lanes), any pages-per-step:
        kernel == oracle to f32-ulp-scale tolerance."""
        Hkv, G = (1, 4) if mla else hkv_g
        rng = np.random.default_rng(seed)
        kv_len = rng.integers(0, n_pt * bs + 1, B)
        nb = int(sum(-(-int(n) // bs) for n in kv_len)) + 2
        case = _case(rng, B=B, Hkv=Hkv, G=G, d=8, dv=8, bs=bs, n_pt=n_pt,
                     nb=nb, kv_len=kv_len, d2=4 if mla else 0)
        if mla:
            q, c_pool, _, pages, kv_len, q2, r_pool = case
            out = _kernel_out(q, c_pool, c_pool, pages, kv_len, q2,
                              r_pool, pps)
            ref = _oracle(q, c_pool, c_pool, pages, kv_len, q2=q2,
                          k2_pool=r_pool)
        else:
            out = _kernel_out(*case, pps)
            ref = _oracle(*case[:5])
        np.testing.assert_allclose(out, ref, rtol=2e-5, atol=2e-5)
        assert np.all(out[np.asarray(kv_len) == 0] == 0.0)


# ---------------------------------------------------------------------------
# TPU lowering + VMEM accounting (no execution)
# ---------------------------------------------------------------------------

def test_paged_attention_lowers_for_tpu():
    """Build the ClosedJaxpr via abstract eval without interpret mode to
    catch Python-level BlockSpec/index-map errors (same idiom as the
    matmul lowering checks)."""
    rng = np.random.default_rng(0)
    q, k_pool, v_pool, pages, kv_len, q2, k2_pool = _case(
        rng, B=2, Hkv=2, G=2, d=8, dv=8, bs=4, n_pt=3, nb=8,
        kv_len=[9, 4], d2=4)
    jax.eval_shape(
        lambda *a: paged_attention(*a[:5], pages_per_step=2,
                                   interpret=False),
        q, k_pool, v_pool, pages, kv_len)
    jax.eval_shape(
        lambda qq, kk, pg, ln, q2_, k2_: paged_attention(
            qq, kk, kk, pg, ln, q2=q2_, k2_pool=k2_,
            pages_per_step=2, interpret=False),
        q, k_pool, pages, kv_len, q2, k2_pool)


def test_vmem_fallback_respects_budget():
    """fallback_pages_per_step picks the largest sweep candidate that
    fits, never exceeds n_pt, and floors at 1 under absurd budgets."""
    kw = dict(G=4, d=64, dv=64, bs=16, d2=0, itemsize=4)
    per_page = 2 * kw["bs"] * (kw["d"] + kw["dv"]) * 4   # double-buffered
    assert (attn_vmem_bytes(2, **{k: v for k, v in kw.items()
                                  if k != "itemsize"})
            - attn_vmem_bytes(1, **{k: v for k, v in kw.items()
                                    if k != "itemsize"})) == per_page
    roomy = attn_vmem_bytes(8, **{k: v for k, v in kw.items()
                                  if k != "itemsize"})
    assert fallback_pages_per_step(n_pt=32, budget=roomy, **kw) == 8
    assert fallback_pages_per_step(n_pt=3, budget=roomy, **kw) == 2
    assert fallback_pages_per_step(n_pt=32, budget=1, **kw) == 1


def test_autotune_key_and_cache_roundtrip(tmp_path, monkeypatch):
    """The pages-per-step pick flows through the same JSON autotune cache
    as the matmul blocks: a pinned entry wins over the VMEM fallback."""
    from repro.kernels import autotune

    from repro.kernels.platform import default_interpret

    monkeypatch.setenv("ICQ_AUTOTUNE_CACHE", str(tmp_path / "tune.json"))
    autotune.reset()
    kw = dict(G=4, d=8, dv=8, bs=4, n_pt=4, d2=0, itemsize=4)
    key = autotune.paged_attn_key(4, 8, 8, 4, 4, d2=0,
                                  interpret=default_interpret())
    assert key.startswith("paged_attn/")
    assert autotune.paged_attn_pages_per_step(**kw) == \
        fallback_pages_per_step(**kw)
    autotune.record(key, [1])
    assert autotune.paged_attn_pages_per_step(**kw) == 1
    autotune.reset()


def test_autotune_sweep_records_winner(tmp_path, monkeypatch):
    from repro.kernels import autotune

    monkeypatch.setenv("ICQ_AUTOTUNE_CACHE", str(tmp_path / "tune.json"))
    autotune.reset()
    got = autotune.autotune_paged_attn(2, 1, 4, 8, 8, 4, 2,
                                       interpret=True,
                                       candidates=[2, 1], iters=1)
    assert not got["cached"] and got["pages_per_step"] in (1, 2)
    again = autotune.autotune_paged_attn(2, 1, 4, 8, 8, 4, 2,
                                         interpret=True)
    assert again["cached"] and again["pages_per_step"] == \
        got["pages_per_step"]
    autotune.reset()


# ---------------------------------------------------------------------------
# dispatch + engine-level token identity across arms
# ---------------------------------------------------------------------------

def test_arm_dispatch(monkeypatch):
    from repro.kernels import backend
    from repro.models.layers import _paged_attn_arm

    monkeypatch.setenv("ICQ_PAGED_ATTN", "pallas")
    assert _paged_attn_arm(1, 0, 16) == "pallas"
    assert _paged_attn_arm(1, 32, 16) == "pallas"   # window >= T: inactive
    assert _paged_attn_arm(4, 0, 16) == "xla"       # chunk steps: gather arm
    assert _paged_attn_arm(1, 8, 16) == "xla"       # active sliding window
    with backend.forced_backend("xla"):             # fault degrade pin
        assert _paged_attn_arm(1, 0, 16) == "xla"
    monkeypatch.setenv("ICQ_PAGED_ATTN", "xla")
    assert _paged_attn_arm(1, 0, 16) == "xla"
    monkeypatch.setenv("ICQ_PAGED_ATTN", "mosaic")
    with pytest.raises(ValueError):
        _paged_attn_arm(1, 0, 16)


@pytest.mark.parametrize("arch", ["llama3.2-1b", "minicpm3-4b"])
def test_engine_greedy_token_identical_across_arms(arch, monkeypatch):
    """Greedy paged serving must emit identical token streams whichever
    arm computes decode attention (pallas in interpret mode here), under
    both the fused one-launch structure and the split chunk+decode
    structure."""
    from repro.configs import get_config, smoke_variant
    from repro.models import init_model
    from repro.serving import GenerationEngine, Request

    cfg = smoke_variant(get_config(arch))
    params = init_model(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(5)
    specs = [dict(rid=rid,
                  prompt=rng.integers(0, cfg.vocab_size,
                                      int(rng.integers(2, 9))
                                      ).astype(np.int32),
                  max_new_tokens=int(rng.integers(2, 6)))
             for rid in range(3)]
    out = {}
    for arm in ("xla", "pallas"):
        monkeypatch.setenv("ICQ_PAGED_ATTN", arm)
        for label, kw in ((arm, {}), (f"{arm}_split",
                                      dict(fused_step=False))):
            eng = GenerationEngine(params, cfg, batch_size=2, max_len=24,
                                   mode="continuous", kv_layout="paged",
                                   kv_block_size=4, prefill_chunk=4, **kw)
            for s in specs:
                eng.submit(Request(**s))
            out[label] = {rid: r.generated
                          for rid, r in eng.run().items()}
    assert (out["pallas"] == out["pallas_split"] == out["xla"]
            == out["xla_split"])
    assert all(len(v) > 0 for v in out["pallas"].values())
