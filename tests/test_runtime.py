"""Runtime: sharding rules, straggler monitor, elastic re-mesh."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.runtime import StragglerMonitor, fit_spec
from repro.runtime.elastic import rebuild_mesh, shrink_mesh_shape
from repro.runtime.sharding import batch_specs, param_specs


def _mesh_1x1():
    from repro.launch.mesh import make_mesh
    return make_mesh((1, 1), ("data", "model"))


class _FakeMesh:
    """Shape-only stand-in so rules can be tested for a 16x16 grid
    without 256 devices."""

    def __init__(self, shape, names):
        self.axis_names = names
        self.devices = np.empty(shape, dtype=object)


def test_fit_spec_divisibility():
    mesh = _FakeMesh((16, 16), ("data", "model"))
    # divisible -> kept
    assert fit_spec((4096, 8192), ("data", "model"), mesh) == P("data", "model")
    # odd vocab -> dropped on that dim only
    assert fit_spec((73448, 512), ("model", None), mesh) == P(None, None)
    # tuple axes
    mesh3 = _FakeMesh((2, 16, 16), ("pod", "data", "model"))
    assert fit_spec((64, 10), (("pod", "data"), None), mesh3) == \
        P(("pod", "data"), None)
    assert fit_spec((33, 10), (("pod", "data"), None), mesh3) == P(None, None)


def test_param_specs_tp_rules():
    mesh = _FakeMesh((16, 16), ("data", "model"))
    sds = lambda *sh: jax.ShapeDtypeStruct(sh, jnp.float32)
    params = dict(
        layers=dict(
            attn=dict(wq=sds(4, 2048, 4096),           # layer-stacked
                      wo=sds(4, 4096, 2048)),
            mlp=dict(w_up=sds(4, 2048, 8192),
                     w_down=sds(4, 8192, 2048)),
            ln1=sds(4, 2048),
        )
    )
    specs = param_specs(params, mesh, fsdp=False)
    assert specs["layers"]["attn"]["wq"] == P(None, None, "model")
    assert specs["layers"]["attn"]["wo"] == P(None, "model", None)
    assert specs["layers"]["mlp"]["w_down"] == P(None, "model", None)
    assert specs["layers"]["ln1"] == P()        # norms replicated
    # FSDP adds the data axis on the other dim
    specs_f = param_specs(params, mesh, fsdp=True)
    assert specs_f["layers"]["attn"]["wq"] == P(None, "data", "model")


def test_param_specs_moe_expert_parallel():
    mesh = _FakeMesh((16, 16), ("data", "model"))
    # ShapeDtypeStructs: rule evaluation needs shapes only (a full-size
    # deepseek expert stack would be 870 GB)
    sds = lambda *sh: jax.ShapeDtypeStruct(sh, jnp.float32)
    params = dict(moe=dict(
        w_gate=sds(58, 256, 7168, 2048),
        w_down=sds(58, 256, 2048, 7168),
        router=sds(7168, 256),
    ))
    specs = param_specs(params, mesh, fsdp=True)
    assert specs["moe"]["w_gate"] == P(None, "model", "data", None)
    assert specs["moe"]["w_down"] == P(None, "model", None, "data")
    assert specs["moe"]["router"] == P(None, None)
    # mixtral: 8 experts don't divide 16 -> EP dropped, TP on d_ff kept
    params8 = dict(moe=dict(w_gate=sds(32, 8, 4096, 14336)))
    specs8 = param_specs(params8, mesh, fsdp=False)
    assert specs8["moe"]["w_gate"] == P(None, None, None, None)


def test_batch_specs():
    mesh = _FakeMesh((2, 16, 16), ("pod", "data", "model"))
    batch = dict(tokens=jnp.zeros((256, 4096), jnp.int32))
    specs = batch_specs(batch, mesh)
    assert specs["tokens"] == P(("pod", "data"), None)
    odd = dict(tokens=jnp.zeros((1, 64), jnp.int32))
    assert batch_specs(odd, mesh)["tokens"] == P(None, None)


def test_straggler_monitor_flags_slow_host():
    mon = StragglerMonitor(n_hosts=4, threshold=2.0, warmup=3)
    for step in range(6):
        for h in range(4):
            mon.record(h, 1.0 if h != 2 else 5.0)
    assert mon.stragglers() == [2]


def test_straggler_monitor_needs_warmup():
    mon = StragglerMonitor(n_hosts=2, warmup=5)
    mon.record(0, 1.0)
    mon.record(1, 100.0)
    assert mon.stragglers() == []


def test_shrink_mesh_preserves_tp():
    assert shrink_mesh_shape(240, 16) == (15, 16)
    with pytest.raises(ValueError):
        shrink_mesh_shape(8, 16)


def test_rebuild_mesh_single_device():
    mesh = rebuild_mesh(jax.devices(), model_parallel=1)
    assert mesh.devices.size == len(jax.devices())


def test_compressed_cross_pod_mean_subprocess():
    """int8 cross-pod gradient reduction on a (2,2,2) pod mesh."""
    import os
    import subprocess
    import sys

    script = r'''
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.runtime.collectives import compressed_cross_pod_mean
from repro.launch.mesh import make_mesh
mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
x = jnp.asarray(np.random.default_rng(0).standard_normal((64, 32)), jnp.float32)
tree = dict(g=x)
with mesh:
    out = jax.jit(lambda t: compressed_cross_pod_mean(t, mesh))(tree)
# all pods hold the same tree -> mean == original, up to int8 error
err = float(jnp.abs(out["g"] - x).max())
scale = float(jnp.abs(x).max()) / 127
assert err <= scale * 1.05, (err, scale)
print("OK", err)
'''
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run(
        [sys.executable, "-c", script], capture_output=True, text=True,
        env=env, cwd=os.path.dirname(os.path.dirname(__file__)), timeout=300,
    )
    assert out.returncode == 0, out.stderr[-2000:]
    assert out.stdout.startswith("OK")
