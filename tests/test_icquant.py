"""ICQuant codec: the paper's central claims as tests."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import core
from repro.core.quantizers import (
    assign_codes,
    lookup,
    rtn_inlier_codebook,
    rtn_outlier_codebook,
    weighted_kmeans_rows,
)
from repro.core.stats import heavy_tailed_weights


def _vanilla_rtn_mse(W, n_bits):
    Wj = jnp.asarray(W)
    cb = rtn_inlier_codebook(Wj, jnp.ones_like(Wj, dtype=bool), n_bits)
    return float(((Wj - lookup(assign_codes(Wj, cb), cb)) ** 2).mean())


@pytest.mark.parametrize("n_bits", [2, 3])
def test_icq_n_bits_beats_vanilla_n_plus_1(n_bits):
    """Paper Fig 3/5: halving the range is worth ~one bit."""
    W = heavy_tailed_weights(32, 2048, seed=0)
    pk = core.quantize(jnp.asarray(W), n_bits, gamma=0.05)
    mse_icq = float(((W - np.asarray(core.dequantize(pk))) ** 2).mean())
    assert mse_icq < _vanilla_rtn_mse(W, n_bits) / 2.5   # >= ~4x claimed
    assert mse_icq < _vanilla_rtn_mse(W, n_bits + 1) * 1.05


def test_bits_accounting_matches_paper():
    """gamma=5%, b=6 -> ~n + 0.31 + small codebook overhead."""
    W = heavy_tailed_weights(64, 4096, seed=1)
    pk = core.quantize(jnp.asarray(W), 2, gamma=0.05)
    bits = pk.bits_per_weight()
    assert pk.b == 6
    assert 0.29 <= bits["index"] <= 0.33
    assert bits["total"] < 2.4


def test_outlier_partition_exact_count():
    W = heavy_tailed_weights(16, 1000, seed=2)
    mask = np.asarray(core.outlier_mask(jnp.asarray(W), 0.05))
    assert (mask.sum(axis=1) == 50).all()
    # outliers are the largest-|w| elements per row
    for r in range(16):
        thr = np.abs(W[r])[mask[r]].min()
        assert (np.abs(W[r])[~mask[r]] <= thr + 1e-7).all()


def test_exact_reconstruction_when_few_levels():
    """A row with <= 2^n distinct inlier values and <= 2^n outlier values
    must be reconstructed exactly (codebook can represent it)."""
    rng = np.random.default_rng(3)
    inl = rng.choice([-0.1, 0.0, 0.05, 0.1], size=(4, 100))
    W = inl.copy()
    W[:, :5] = rng.choice([1.0, -1.0, 2.0, -2.0], size=(4, 5))  # outliers
    pk = core.quantize(jnp.asarray(W, dtype=jnp.float32), 2, gamma=0.05,
                       method="kmeans", kmeans_iters=50)
    W_hat = np.asarray(core.dequantize(pk))
    np.testing.assert_allclose(W_hat, W, atol=5e-3)


def test_kmeans_beats_rtn():
    W = heavy_tailed_weights(8, 1024, seed=4)
    mse = {}
    for m in ("rtn", "kmeans"):
        pk = core.quantize(jnp.asarray(W), 3, gamma=0.05, method=m)
        mse[m] = float(((W - np.asarray(core.dequantize(pk))) ** 2).mean())
    assert mse["kmeans"] <= mse["rtn"]


def test_fisher_weighted_kmeans_prioritizes_sensitive_weights():
    rng = np.random.default_rng(5)
    W = rng.standard_normal((4, 512)).astype(np.float32)
    fisher = np.ones_like(W)
    fisher[:, :64] = 100.0                      # sensitive region
    cb, codes = weighted_kmeans_rows(
        jnp.asarray(W), jnp.asarray(fisher), 8, iters=30
    )
    W_hat = np.asarray(lookup(np.asarray(codes), cb))
    err_sens = ((W - W_hat)[:, :64] ** 2).mean()
    err_rest = ((W - W_hat)[:, 64:] ** 2).mean()
    assert err_sens < err_rest


def test_signed_tail_outlier_codebook():
    W = jnp.asarray([[-5.0, -4.0, 0.1, -0.1, 4.0, 5.0, 0.0, 0.2]])
    mask = jnp.asarray([[True, True, False, False, True, True, False, False]])
    cb = rtn_outlier_codebook(W, mask, 2)       # 2 levels per tail
    cb = np.asarray(cb)[0]
    assert cb[0] == -5.0 and cb[1] == -4.0      # negative tail
    assert cb[2] == 4.0 and cb[3] == 5.0        # positive tail


def test_stacked_dequantize():
    """Layer-stacked ICQPacked (leading axes) dequantizes per slice."""
    from repro.launch.quantize import quantize_tree

    rng = np.random.default_rng(6)
    params = dict(w=jnp.asarray(rng.standard_normal((3, 64, 48)), jnp.float32))
    qp, acct = quantize_tree(params, 4, gamma=0.05)
    W_hat = core.dequantize(qp["w"])            # (3, 48, 64)
    assert W_hat.shape == (3, 48, 64)
    for i in range(3):
        pk_i = core.quantize(params["w"][i].T, 4, gamma=0.05)
        np.testing.assert_allclose(
            np.asarray(W_hat[i]), np.asarray(core.dequantize(pk_i)), atol=1e-6
        )


def test_dequant_matmul_linear_dispatch():
    from repro.models.linear import linear

    rng = np.random.default_rng(7)
    W = jnp.asarray(rng.standard_normal((128, 96)), jnp.float32)  # (d_in, d_out)
    x = jnp.asarray(rng.standard_normal((4, 128)), jnp.float32)
    pk = core.quantize(W.T, 8, gamma=0.05)      # near-lossless at 8 bits
    y_q = linear(x, pk)
    y = x @ W
    # 8-bit RTN elementwise error ~ range/2^9 accumulates ~sqrt(d_in) in a
    # matmul: tolerance scaled accordingly
    np.testing.assert_allclose(np.asarray(y_q), np.asarray(y), atol=0.35)
