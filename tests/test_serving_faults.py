"""Serving fault-tolerance layer: deadlines, cancellation, backpressure,
fault injection + degrade-to-XLA recovery, replay caps, weight-integrity
checksums, step-time watchdog, and post-run shutdown invariants."""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import core
from repro.configs import get_config, smoke_variant
from repro.core.stats import heavy_tailed_weights
from repro.kernels import backend, ops
from repro.models import init_model
from repro.serving import GenerationEngine, Request, SamplingParams
from repro.serving.faults import (
    FaultInjected,
    FaultInjector,
    parse_fault_plan,
)
from repro.serving.metrics import StepTimeWatchdog
from repro.serving.scheduler import STATUSES


def _setup(arch="llama3.2-1b"):
    cfg = smoke_variant(get_config(arch))
    params = init_model(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _prompts(cfg, n, length=5, seed=3):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab_size, length).astype(np.int32)
            for _ in range(n)]


def _run(params, cfg, reqs, **kw):
    eng = GenerationEngine(params, cfg, batch_size=kw.pop("batch_size", 2),
                           max_len=kw.pop("max_len", 32), mode="continuous",
                           **kw)
    for r in reqs:
        eng.submit(r)
    done = eng.run()
    eng.check_shutdown_invariants()
    return eng, done


# ---------------------------------------------------------------------------
# injector unit behavior
# ---------------------------------------------------------------------------

def test_parse_fault_plan():
    assert parse_fault_plan("3:nan, 6:raise") == ((3, "nan"), (6, "raise"))
    assert parse_fault_plan("") == ()
    with pytest.raises(ValueError, match="kind"):
        parse_fault_plan("2:explode")
    with pytest.raises(ValueError, match="two entries"):
        parse_fault_plan("2:nan,2:raise")


def test_injector_plan_is_one_shot_and_rate_deterministic():
    inj = FaultInjector(((2, "nan"),))
    assert [inj.draw(i) for i in range(4)] == [None, None, "nan", None]
    assert inj.draw(2) is None          # consumed: never fires again
    assert inj.fired == [(2, "nan")]
    a = FaultInjector(seed=7, rate=0.5)
    b = FaultInjector(seed=7, rate=0.5)
    assert [a.draw(i) for i in range(20)] == [b.draw(i) for i in range(20)]


# ---------------------------------------------------------------------------
# lifecycle: typed statuses, deadlines, cancellation
# ---------------------------------------------------------------------------

def test_all_statuses_ok_on_clean_run():
    cfg, params = _setup()
    reqs = [Request(i, p, max_new_tokens=3)
            for i, p in enumerate(_prompts(cfg, 3))]
    eng, done = _run(params, cfg, reqs)
    assert all(r.status == "ok" for r in done.values())
    assert eng.metrics.status_counts() == {"ok": 3}
    s = eng.metrics.summary()
    assert s["timeouts"] == s["cancellations"] == s["sheds"] == 0
    assert s["faults"] == s["degraded_steps"] == s["replays"] == 0


def test_wave_mode_statuses_ok():
    cfg, params = _setup()
    eng = GenerationEngine(params, cfg, batch_size=2, max_len=16, mode="wave")
    for i, p in enumerate(_prompts(cfg, 3)):
        eng.submit(Request(i, p, max_new_tokens=2))
    done = eng.run()
    assert all(r.status == "ok" for r in done.values())


def test_deadline_timeout_keeps_partial_output():
    cfg, params = _setup()
    clock = [0.0]

    def tick(rid, tok):       # each generated token costs 1s of clock
        clock[0] += 1.0

    [p] = _prompts(cfg, 1)
    req = Request(0, p, max_new_tokens=20, deadline_s=float(len(p) + 3),
                  on_token=tick)
    eng, done = _run(params, cfg, [req], batch_size=1,
                     clock=lambda: clock[0])
    assert done[0].status == "timeout"
    assert 0 < len(done[0].generated) < 20      # partial output kept
    assert eng.metrics.timeouts == 1
    assert eng.metrics.requests[0].status == "timeout"


def test_zero_queue_wait_expires_deterministically():
    cfg, params = _setup()
    p1, p2 = _prompts(cfg, 2)
    reqs = [Request(0, p1, max_new_tokens=3),
            Request(1, p2, max_new_tokens=3, max_queue_wait_s=0.0)]
    eng, done = _run(params, cfg, reqs, batch_size=1,
                     clock=lambda: 0.0)
    assert done[0].status == "ok"
    assert done[1].status == "expired"
    assert done[1].generated == []
    assert eng.metrics.expired == 1


def test_cancel_queued_and_live():
    cfg, params = _setup()
    p = _prompts(cfg, 3)
    eng = GenerationEngine(params, cfg, batch_size=1, max_len=32,
                           mode="continuous")
    seen = []

    def maybe_cancel(rid, tok):
        seen.append((rid, tok))
        if rid == 0 and len([x for x in seen if x[0] == 0]) == 2:
            assert eng.cancel(0) is True         # live lane, mid-decode
    eng.submit(Request(0, p[0], max_new_tokens=10, on_token=maybe_cancel))
    eng.submit(Request(1, p[1], max_new_tokens=3))
    eng.submit(Request(2, p[2], max_new_tokens=3))
    assert eng.cancel(2) is True                 # still queued
    with pytest.raises(KeyError):
        eng.cancel(99)
    done = eng.run()
    eng.check_shutdown_invariants()
    assert done[0].status == "cancelled"
    assert 2 <= len(done[0].generated) < 10      # partial output kept
    assert done[2].status == "cancelled" and done[2].generated == []
    assert done[1].status == "ok"
    assert eng.metrics.cancellations == 2
    assert eng.cancel(1) is False                # already finished


# ---------------------------------------------------------------------------
# backpressure
# ---------------------------------------------------------------------------

def test_bounded_queue_rejects_new_requests():
    cfg, params = _setup()
    p = _prompts(cfg, 4)
    eng = GenerationEngine(params, cfg, batch_size=1, max_len=32,
                           mode="continuous", max_queue=2)
    accepted = [eng.submit(Request(i, p[i], max_new_tokens=2))
                for i in range(4)]
    assert accepted == [True, True, False, False]
    done = eng.run()
    eng.check_shutdown_invariants()
    assert done[0].status == done[1].status == "ok"
    assert done[2].status == done[3].status == "rejected"
    assert done[2].generated == []
    assert eng.metrics.sheds == 2


def test_shed_oldest_drops_longest_queued():
    cfg, params = _setup()
    p = _prompts(cfg, 3)
    eng = GenerationEngine(params, cfg, batch_size=1, max_len=32,
                           mode="continuous", max_queue=2,
                           shed_policy="shed-oldest")
    assert eng.submit(Request(0, p[0], max_new_tokens=2)) is True
    assert eng.submit(Request(1, p[1], max_new_tokens=2)) is True
    assert eng.submit(Request(2, p[2], max_new_tokens=2)) is True  # kept
    done = eng.run()
    eng.check_shutdown_invariants()
    assert done[0].status == "rejected"          # the oldest was shed
    assert done[1].status == done[2].status == "ok"


def test_engine_rejects_bad_fault_tolerance_config():
    cfg, params = _setup()
    with pytest.raises(ValueError, match="shed_policy"):
        GenerationEngine(params, cfg, 1, 16, shed_policy="drop-newest")
    with pytest.raises(ValueError, match="degrade_steps"):
        GenerationEngine(params, cfg, 1, 16, degrade_steps=0)


# ---------------------------------------------------------------------------
# fault injection + degrade-to-XLA recovery
# ---------------------------------------------------------------------------

def _greedy_tokens(params, cfg, reqs_fn, **kw):
    eng, done = _run(params, cfg, reqs_fn(), **kw)
    return eng, {rid: r.generated for rid, r in done.items()}


@pytest.mark.parametrize("kind", ["nan", "raise"])
def test_injected_fault_recovers_token_identically(kind):
    """A faulted launch retries on the bitwise-exact XLA arm: greedy
    output must match the no-fault run token for token, with the
    recovery visible in the metrics ledger."""
    cfg, params = _setup()

    def reqs():
        return [Request(i, p, max_new_tokens=6)
                for i, p in enumerate(_prompts(cfg, 2))]

    _, want = _greedy_tokens(params, cfg, reqs)
    inj = FaultInjector(((2, kind),))
    eng, got = _greedy_tokens(params, cfg, reqs, faults=inj)
    assert got == want
    assert inj.fired == [(2, kind)]
    assert eng.metrics.faults.get(kind) == 1
    assert eng.metrics.degraded_steps >= 1
    assert all(r.status == "ok" for r in eng.completed.values())


def test_degraded_mode_sticky_then_clears():
    cfg, params = _setup()

    def reqs():
        return [Request(0, _prompts(cfg, 1, length=4)[0],
                        max_new_tokens=12)]

    _, want = _greedy_tokens(params, cfg, reqs)
    inj = FaultInjector(((1, "raise"),))
    eng, got = _greedy_tokens(params, cfg, reqs, faults=inj,
                              degrade_steps=3)
    assert got == want
    # the retry plus the next clean launches, capped by stickiness
    assert eng.metrics.degraded_steps == 3


def test_alloc_fault_preempts_and_replays_paged():
    cfg, params = _setup()

    def reqs():
        return [Request(i, p, max_new_tokens=6)
                for i, p in enumerate(_prompts(cfg, 2, length=4))]

    base_kw = dict(kv_layout="paged", kv_block_size=4)
    _, want = _greedy_tokens(params, cfg, reqs, **base_kw)
    inj = FaultInjector(((4, "alloc"),))
    eng, got = _greedy_tokens(params, cfg, reqs, faults=inj, **base_kw)
    assert got == want                       # greedy replay is identical
    assert eng.metrics.faults.get("alloc") == 1
    assert eng.metrics.preemptions >= 1


def test_alloc_fault_downgrades_to_raise_on_contiguous():
    cfg, params = _setup()

    def reqs():
        return [Request(0, _prompts(cfg, 1)[0], max_new_tokens=5)]

    _, want = _greedy_tokens(params, cfg, reqs)
    inj = FaultInjector(((1, "alloc"),))
    eng, got = _greedy_tokens(params, cfg, reqs, faults=inj)
    assert got == want
    assert eng.metrics.faults.get("raise") == 1   # no allocator to exhaust


def test_chunk_launch_fault_recovers():
    cfg, params = _setup()

    def reqs():
        return [Request(i, p, max_new_tokens=4)
                for i, p in enumerate(_prompts(cfg, 2, length=9))]

    kw = dict(prefill_chunk=4)
    _, want = _greedy_tokens(params, cfg, reqs, **kw)
    inj = FaultInjector(((0, "raise"),))    # launch 0 is a chunk launch
    eng, got = _greedy_tokens(params, cfg, reqs, faults=inj, **kw)
    assert got == want
    assert eng.metrics.degraded_steps >= 1


def test_sampled_fault_recovery_reuses_subkey():
    """A recovered sampled launch must draw the same tokens the failed
    one would have: the per-iteration PRNG subkey is shared by retries."""
    cfg, params = _setup()
    hot = SamplingParams(temperature=1.2)

    def reqs():
        return [Request(0, _prompts(cfg, 1)[0], max_new_tokens=8,
                        sampling=hot)]

    _, want = _greedy_tokens(params, cfg, reqs, seed=5)
    inj = FaultInjector(((3, "nan"),))
    eng, got = _greedy_tokens(params, cfg, reqs, seed=5, faults=inj)
    assert got == want
    assert eng.metrics.degraded_steps >= 1


def test_persistent_failure_fails_requests_with_replay_cap():
    """When every launch fails on both arms (a genuinely poisoned model),
    the engine must not loop: requests replay up to the cap, then
    force-finish as 'failed', and the run terminates cleanly."""
    cfg, params = _setup()
    eng = GenerationEngine(params, cfg, batch_size=1, max_len=16,
                           mode="continuous")

    def boom(*a, **k):
        raise RuntimeError("synthetic persistent launch failure")
    eng._step_greedy = boom
    eng._step_greedy_xla = boom
    eng.submit(Request(0, _prompts(cfg, 1)[0], max_new_tokens=4))
    done = eng.run()
    eng.check_shutdown_invariants()
    assert done[0].status == "failed"
    assert eng.metrics.replays >= 1
    assert eng.metrics.failed == 1


def test_sampled_preemption_victim_force_fails():
    """A temperature>0 lane cannot be replayed reproducibly: preemption
    force-finishes it as 'failed' instead of silently diverging."""
    cfg, params = _setup()
    p = _prompts(cfg, 2, length=4)
    inj = FaultInjector(((4, "alloc"),))
    eng = GenerationEngine(params, cfg, batch_size=2, max_len=32,
                           mode="continuous", kv_layout="paged",
                           kv_block_size=4, faults=inj)
    eng.submit(Request(0, p[0], max_new_tokens=8))
    eng.submit(Request(1, p[1], max_new_tokens=8,
                       sampling=SamplingParams(temperature=1.0)))
    done = eng.run()
    eng.check_shutdown_invariants()
    # the youngest live lane (rid 1, admitted second) was the victim
    assert done[1].status == "failed"
    assert done[0].status == "ok"
    assert eng.metrics.failed == 1


def test_fault_env_knobs(monkeypatch):
    monkeypatch.delenv("ICQ_FAULT_PLAN", raising=False)
    monkeypatch.delenv("ICQ_FAULT_RATE", raising=False)
    assert FaultInjector.from_env() is None
    monkeypatch.setenv("ICQ_FAULT_PLAN", "5:nan")
    inj = FaultInjector.from_env()
    assert inj is not None and inj.pending == 1
    cfg, params = _setup()
    eng = GenerationEngine(params, cfg, 1, 16)
    assert eng.faults is not None and eng.faults.pending == 1
    monkeypatch.setenv("ICQ_MAX_QUEUE", "3")
    monkeypatch.setenv("ICQ_SHED_POLICY", "shed-oldest")
    monkeypatch.setenv("ICQ_DEGRADE_STEPS", "5")
    eng2 = GenerationEngine(params, cfg, 1, 16)
    assert (eng2.max_queue, eng2.shed_policy, eng2.degrade_steps) == \
        (3, "shed-oldest", 5)


# ---------------------------------------------------------------------------
# step-time watchdog
# ---------------------------------------------------------------------------

def test_watchdog_flags_stall_after_warmup():
    wd = StepTimeWatchdog(threshold=3.0, warmup=3)
    for _ in range(5):
        assert wd.record(0.1) is False
    assert wd.record(1.0) is True          # 10x the EWMA: stalled
    assert wd.stalled and wd.stalled_steps == 1
    assert wd.record(0.1) is False         # recovers
    assert wd.p(0.50) == pytest.approx(0.1)


def test_watchdog_never_flags_virtual_clock_or_warmup():
    wd = StepTimeWatchdog(warmup=3)
    assert wd.record(5.0) is False         # first samples: warming up
    assert wd.record(0.0) is False
    vd = StepTimeWatchdog()
    for _ in range(10):
        assert vd.record(0.0) is False     # virtual clock: dt == 0 always
    assert vd.stalled_steps == 0


def test_engine_run_feeds_watchdog():
    cfg, params = _setup()
    reqs = [Request(0, _prompts(cfg, 1)[0], max_new_tokens=4)]
    eng, _ = _run(params, cfg, reqs, batch_size=1)
    s = eng.metrics.summary()
    assert s["step_time_p50"] >= 0.0
    assert np.isfinite(s["step_time_ewma"])


# ---------------------------------------------------------------------------
# weight integrity (v2 sidecar crc32)
# ---------------------------------------------------------------------------

def _packed(R=40, C=256, n_bits=3, seed=2):
    W = heavy_tailed_weights(R, C, seed=seed)
    return core.quantize(jnp.asarray(W), n_bits, gamma=0.05)


def test_v2_runtime_dict_carries_and_verifies_crc():
    rt = ops.to_runtime(_packed(), fmt="v2")
    assert set(rt["crc"]) == {"syms", "offs", "dbase"}
    ops.verify_runtime_integrity(rt)                      # clean: no raise
    bad = dict(rt)
    syms = np.asarray(jax.device_get(rt["syms"])).copy()
    syms.flat[0] ^= 1                                     # one flipped bit
    bad["syms"] = jnp.asarray(syms)
    with pytest.raises(ops.WeightIntegrityError, match="syms"):
        ops.verify_runtime_integrity(bad)
    with pytest.raises(ops.WeightIntegrityError):
        backend.prepare(bad, fmt="v2")      # load boundary refuses it


def test_prepared_verify_integrity_detects_mutation():
    prep = backend.prepare(_packed(), fmt="v2")
    assert prep.crc is not None
    prep.verify_integrity()                               # clean: no raise
    offs = np.asarray(jax.device_get(prep.offs)).copy()
    offs.flat[3] ^= 1
    tampered = dataclasses.replace(prep, offs=jnp.asarray(offs))
    with pytest.raises(backend.WeightIntegrityError, match="offs"):
        tampered.verify_integrity()


def test_v1_and_crcless_layouts_are_exempt():
    pk = _packed()
    rt1 = ops.to_runtime(pk, fmt="v1")
    assert "crc" not in rt1
    ops.verify_runtime_integrity(rt1)                     # no-op for v1
    prep1 = backend.prepare(pk, fmt="v1")
    assert prep1.crc is None
    prep1.verify_integrity()                              # no-op
