"""Serving engine: greedy parity with direct decoding, quantized path,
and the queue/length edge cases (eos-in-prompt, oversized prompts,
empty/single-request/batch-of-one paths)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, smoke_variant
from repro.launch.quantize import quantize_tree
from repro.launch.steps import make_cache, make_decode_step
from repro.models import init_model
from repro.serving import GenerationEngine, Request, SamplingParams


def _setup(arch="llama3.2-1b"):
    cfg = smoke_variant(get_config(arch))
    params = init_model(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _greedy_direct(params, cfg, prompt, n_new, max_len=64):
    cache = make_cache(params, cfg, 1, max_len)
    decode = make_decode_step(cfg)
    toks = list(prompt)
    out = []
    logits = None
    for pos, t in enumerate(toks):
        logits, cache = decode(
            params, cache, jnp.asarray([[t]], jnp.int32),
            jnp.asarray(pos, jnp.int32),
        )
    cur = int(jnp.argmax(logits[0]))
    for i in range(n_new):
        out.append(cur)
        logits, cache = decode(
            params, cache, jnp.asarray([[cur]], jnp.int32),
            jnp.asarray(len(toks) + i, jnp.int32),
        )
        cur = int(jnp.argmax(logits[0]))
    return out


def test_engine_matches_direct_greedy():
    cfg, params = _setup()
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, size=6).astype(np.int32)
               for _ in range(2)]
    engine = GenerationEngine(params, cfg, batch_size=2, max_len=64)
    for rid, p in enumerate(prompts):
        engine.submit(Request(rid, p, max_new_tokens=5))
    done = engine.run()
    for rid, p in enumerate(prompts):
        want = _greedy_direct(params, cfg, p.tolist(), 5)
        assert done[rid].generated == want, (rid, done[rid].generated, want)


def test_engine_queue_overflow_waves():
    cfg, params = _setup()
    rng = np.random.default_rng(1)
    engine = GenerationEngine(params, cfg, batch_size=2, max_len=32)
    for rid in range(5):   # 3 waves of batch 2
        engine.submit(Request(rid, rng.integers(0, cfg.vocab_size, 4)
                              .astype(np.int32), max_new_tokens=3))
    done = engine.run()
    assert sorted(done) == [0, 1, 2, 3, 4]
    assert all(len(r.generated) == 3 for r in done.values())


def test_quantized_engine_runs_and_degrades_gracefully():
    cfg, params = _setup()
    qparams, acct = quantize_tree(params, 8, gamma=0.05)  # near-lossless
    rng = np.random.default_rng(2)
    prompt = rng.integers(0, cfg.vocab_size, 6).astype(np.int32)
    e1 = GenerationEngine(params, cfg, batch_size=1, max_len=32)
    e2 = GenerationEngine(qparams, cfg, batch_size=1, max_len=32)
    e1.submit(Request(0, prompt, max_new_tokens=4))
    e2.submit(Request(0, prompt, max_new_tokens=4))
    g1 = e1.run()[0].generated
    g2 = e2.run()[0].generated
    # 8-bit ICQuant is near-lossless: greedy tokens should mostly agree
    agree = sum(a == b for a, b in zip(g1, g2))
    assert agree >= 3, (g1, g2)


# ---------------------------------------------------------------------------
# edge cases (both engine modes)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", ["wave", "continuous"])
def test_eos_inside_prompt_does_not_terminate_lane(mode):
    """An eos_id occurring in the teacher-forced prompt region must not
    end the request — only a *generated* eos token may."""
    cfg, params = _setup()
    rng = np.random.default_rng(7)
    prompt = rng.integers(0, cfg.vocab_size, 6).astype(np.int32)

    ref = GenerationEngine(params, cfg, batch_size=1, max_len=32, mode=mode)
    ref.submit(Request(0, prompt, max_new_tokens=4))
    want = ref.run()[0].generated

    # eos = a prompt token that never appears in the greedy continuation
    eos_candidates = [int(t) for t in prompt if int(t) not in want]
    assert eos_candidates, "degenerate fixture: reroll the seed"
    eos = eos_candidates[0]

    eng = GenerationEngine(params, cfg, batch_size=1, max_len=32, mode=mode)
    eng.submit(Request(0, prompt, max_new_tokens=4, eos_id=eos))
    got = eng.run()[0].generated
    assert got == want


@pytest.mark.parametrize("mode", ["wave", "continuous"])
def test_generated_eos_terminates_lane(mode):
    cfg, params = _setup()
    rng = np.random.default_rng(8)
    prompt = rng.integers(0, cfg.vocab_size, 5).astype(np.int32)
    ref = GenerationEngine(params, cfg, batch_size=1, max_len=32, mode=mode)
    ref.submit(Request(0, prompt, max_new_tokens=6))
    want = ref.run()[0].generated
    eos = want[2]                       # third generated token
    eng = GenerationEngine(params, cfg, batch_size=1, max_len=32, mode=mode)
    eng.submit(Request(0, prompt, max_new_tokens=6, eos_id=eos))
    got = eng.run()[0].generated
    assert got == want[: want.index(eos) + 1]


def test_prompt_longer_than_max_len_errors_clearly():
    cfg, params = _setup()
    eng = GenerationEngine(params, cfg, batch_size=1, max_len=8)
    with pytest.raises(ValueError, match="max_len"):
        eng.submit(Request(0, np.zeros(8, np.int32)))   # == max_len: no room
    with pytest.raises(ValueError, match="max_len"):
        eng.submit(Request(1, np.zeros(20, np.int32)))


def test_empty_prompt_and_duplicate_rid_error():
    cfg, params = _setup()
    eng = GenerationEngine(params, cfg, batch_size=1, max_len=8)
    with pytest.raises(ValueError, match="empty"):
        eng.submit(Request(0, np.zeros(0, np.int32)))
    eng.submit(Request(1, np.ones(2, np.int32)))
    with pytest.raises(ValueError, match="duplicate"):
        eng.submit(Request(1, np.ones(2, np.int32)))


@pytest.mark.parametrize("mode", ["wave", "continuous"])
def test_empty_queue_run_returns_nothing(mode):
    cfg, params = _setup()
    eng = GenerationEngine(params, cfg, batch_size=2, max_len=16, mode=mode)
    assert eng.run() == {}
    assert eng.metrics.summary()["completed"] == 0


def test_single_request_and_batch_of_one_match_wave():
    cfg, params = _setup()
    rng = np.random.default_rng(9)
    prompt = rng.integers(0, cfg.vocab_size, 4).astype(np.int32)
    out = {}
    for mode in ("wave", "continuous"):
        eng = GenerationEngine(params, cfg, batch_size=1, max_len=24,
                               mode=mode)
        eng.submit(Request(0, prompt, max_new_tokens=5))
        out[mode] = eng.run()[0].generated
    assert out["continuous"] == out["wave"]
    assert len(out["wave"]) == 5


def test_generation_truncated_at_cache_cap():
    """Budget overflowing max_len is cut at the cap, identically in both
    modes (the engine rejects oversized *prompts*, not budgets)."""
    cfg, params = _setup()
    rng = np.random.default_rng(10)
    prompt = rng.integers(0, cfg.vocab_size, 6).astype(np.int32)
    out = {}
    for mode in ("wave", "continuous"):
        eng = GenerationEngine(params, cfg, batch_size=1, max_len=12,
                               mode=mode)
        eng.submit(Request(0, prompt, max_new_tokens=50))
        out[mode] = eng.run()[0].generated
    assert out["continuous"] == out["wave"]
    assert len(out["wave"]) == 12 - 6   # max_len - prompt_len


def test_streaming_callback_sees_tokens_in_order():
    cfg, params = _setup()
    rng = np.random.default_rng(11)
    seen = []
    eng = GenerationEngine(params, cfg, batch_size=2, max_len=24,
                           mode="continuous")
    for rid in range(3):
        eng.submit(Request(
            rid, rng.integers(0, cfg.vocab_size, 4).astype(np.int32),
            max_new_tokens=3,
            on_token=lambda r, t: seen.append((r, t))))
    done = eng.run()
    for rid, r in done.items():
        assert [t for rr, t in seen if rr == rid] == r.generated


def test_temperature_sampling_reproducible_and_diverges_from_greedy():
    cfg, params = _setup()
    rng = np.random.default_rng(12)
    prompts = [rng.integers(0, cfg.vocab_size, 4).astype(np.int32)
               for _ in range(2)]

    def run_once(seed, sampling):
        eng = GenerationEngine(params, cfg, batch_size=2, max_len=32,
                               mode="continuous", sampling=sampling,
                               seed=seed)
        for rid, p in enumerate(prompts):
            eng.submit(Request(rid, p, max_new_tokens=8))
        return {rid: r.generated for rid, r in eng.run().items()}

    hot = SamplingParams(temperature=1.5)
    a = run_once(0, hot)
    b = run_once(0, hot)
    assert a == b                       # threaded PRNG key: reproducible
    g = run_once(0, SamplingParams())
    assert a != g                       # temperature actually samples
