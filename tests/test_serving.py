"""Serving engine: greedy parity with direct decoding + quantized path."""
import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_config, smoke_variant
from repro.launch.quantize import quantize_tree
from repro.launch.steps import make_cache, make_decode_step
from repro.models import init_model
from repro.serving import GenerationEngine, Request


def _setup(arch="llama3.2-1b"):
    cfg = smoke_variant(get_config(arch))
    params = init_model(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _greedy_direct(params, cfg, prompt, n_new, max_len=64):
    cache = make_cache(params, cfg, 1, max_len)
    decode = make_decode_step(cfg)
    toks = list(prompt)
    out = []
    logits = None
    for pos, t in enumerate(toks):
        logits, cache = decode(
            params, cache, jnp.asarray([[t]], jnp.int32),
            jnp.asarray(pos, jnp.int32),
        )
    cur = int(jnp.argmax(logits[0]))
    for i in range(n_new):
        out.append(cur)
        logits, cache = decode(
            params, cache, jnp.asarray([[cur]], jnp.int32),
            jnp.asarray(len(toks) + i, jnp.int32),
        )
        cur = int(jnp.argmax(logits[0]))
    return out


def test_engine_matches_direct_greedy():
    cfg, params = _setup()
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, size=6).astype(np.int32)
               for _ in range(2)]
    engine = GenerationEngine(params, cfg, batch_size=2, max_len=64)
    for rid, p in enumerate(prompts):
        engine.submit(Request(rid, p, max_new_tokens=5))
    done = engine.run()
    for rid, p in enumerate(prompts):
        want = _greedy_direct(params, cfg, p.tolist(), 5)
        assert done[rid].generated == want, (rid, done[rid].generated, want)


def test_engine_queue_overflow_waves():
    cfg, params = _setup()
    rng = np.random.default_rng(1)
    engine = GenerationEngine(params, cfg, batch_size=2, max_len=32)
    for rid in range(5):   # 3 waves of batch 2
        engine.submit(Request(rid, rng.integers(0, cfg.vocab_size, 4)
                              .astype(np.int32), max_new_tokens=3))
    done = engine.run()
    assert sorted(done) == [0, 1, 2, 3, 4]
    assert all(len(r.generated) == 3 for r in done.values())


def test_quantized_engine_runs_and_degrades_gracefully():
    cfg, params = _setup()
    qparams, acct = quantize_tree(params, 8, gamma=0.05)  # near-lossless
    rng = np.random.default_rng(2)
    prompt = rng.integers(0, cfg.vocab_size, 6).astype(np.int32)
    e1 = GenerationEngine(params, cfg, batch_size=1, max_len=32)
    e2 = GenerationEngine(qparams, cfg, batch_size=1, max_len=32)
    e1.submit(Request(0, prompt, max_new_tokens=4))
    e2.submit(Request(0, prompt, max_new_tokens=4))
    g1 = e1.run()[0].generated
    g2 = e2.run()[0].generated
    # 8-bit ICQuant is near-lossless: greedy tokens should mostly agree
    agree = sum(a == b for a, b in zip(g1, g2))
    assert agree >= 3, (g1, g2)
