"""v2 checkpoint sidecar: encode-time invariants + jnp decode parity.

(Separate from test_index_coding.py so it runs without hypothesis.)
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro.core.index_coding import (
    decode_stream,
    decode_to_dense_mask,
    encode_positions,
    selector_from_checkpoints,
    stream_checkpoints,
)
from repro.core.packing import pack_symbols_np, symbol_cols, unpack_codes


def _random_stream(rows=16, d_in=1024, p=51, b=6, seed=0):
    rng = np.random.default_rng(seed)
    positions = np.sort(
        np.stack([rng.choice(d_in, p, replace=False) for _ in range(rows)]),
        axis=-1,
    )
    return encode_positions(positions, d_in, b), positions


def test_checkpoints_offsets_partition_the_stream():
    stream, _ = _random_stream()
    sym = np.asarray(jax.device_get(stream.symbols))
    cnt = np.asarray(jax.device_get(stream.counts))
    tile = 256
    offs, dbase = stream_checkpoints(sym, cnt, stream.b, tile, stream.d_in)
    T = stream.d_in // tile
    assert offs.shape == (sym.shape[0], T + 1)
    assert dbase.shape == (sym.shape[0], T)
    assert offs.dtype == np.uint16 and dbase.dtype == np.uint8
    # offsets are monotone and the sentinel is the per-row symbol count
    assert (np.diff(offs.astype(np.int64), axis=1) >= 0).all()
    np.testing.assert_array_equal(offs[:, -1].astype(np.int64), cnt)
    np.testing.assert_array_equal(offs[:, 0], 0)
    # the base delta fits in b bits (that is what makes it a uint8)
    assert int(dbase.max()) < (1 << stream.b) - 1
    # each tile's run covers exactly the symbols whose decoded position
    # lands in the tile
    pos, mask = map(np.asarray, jax.device_get(decode_stream(stream)))
    for r in range(sym.shape[0]):
        for t in range(T):
            lo, hi = t * tile, (t + 1) * tile
            run = slice(int(offs[r, t]), int(offs[r, t + 1]))
            in_run = pos[r, run][mask[r, run]]
            want = pos[r, mask[r]][(pos[r, mask[r]] >= lo)
                                   & (pos[r, mask[r]] < hi)]
            np.testing.assert_array_equal(np.sort(in_run), np.sort(want))


def test_checkpoint_jnp_decode_matches_dense_mask():
    """selector_from_checkpoints (the XLA-arm / kernel-mirror math)
    reproduces the reference dense decode bit-for-bit, including when
    the tiled range is padded past d_in."""
    for seed, tile in ((0, 128), (1, 256), (2, 512)):
        stream, positions = _random_stream(seed=seed)
        sym = np.asarray(jax.device_get(stream.symbols))
        cnt = np.asarray(jax.device_get(stream.counts))
        total = -(-stream.d_in // tile) * tile + tile   # extra empty tile
        offs, dbase = stream_checkpoints(sym, cnt, stream.b, tile, total)
        words = pack_symbols_np(sym, stream.b)
        S = symbol_cols(words.shape[-1], stream.b)
        sym_cols = unpack_codes(
            jnp.asarray(words), stream.b, S).astype(jnp.int32)
        sel = selector_from_checkpoints(
            sym_cols, jnp.asarray(offs), jnp.asarray(dbase),
            b=stream.b, tile=tile, out_len=stream.d_in)
        ref = np.asarray(decode_to_dense_mask(stream)).astype(np.int32)
        np.testing.assert_array_equal(np.asarray(sel), ref)
        # and the selector marks exactly the encoded positions
        np.testing.assert_array_equal(
            np.nonzero(np.asarray(sel))[1].reshape(positions.shape),
            positions)


def test_checkpoints_empty_rows_and_tiles():
    """Rows whose outliers all sit in one tile leave the other tiles'
    runs empty; all-zero sidecars decode to an all-zero selector."""
    d_in, b, tile = 512, 5, 128
    positions = np.array([[0, 1, 2], [509, 510, 511]])
    stream = encode_positions(positions, d_in, b)
    sym = np.asarray(jax.device_get(stream.symbols))
    cnt = np.asarray(jax.device_get(stream.counts))
    offs, dbase = stream_checkpoints(sym, cnt, b, tile, d_in)
    # row 0: everything decodes in tile 0, rows of trailing tiles empty
    assert offs[0, 1] == offs[0, -1]
    # row 1: tiles 0..2 empty, all symbols belong to the last tile
    assert offs[1, 3] == 0 or (offs[1, 3] <= offs[1, 4])
    words = pack_symbols_np(sym, b)
    S = symbol_cols(words.shape[-1], b)
    sel = selector_from_checkpoints(
        unpack_codes(jnp.asarray(words), b, S).astype(jnp.int32),
        jnp.asarray(offs), jnp.asarray(dbase), b=b, tile=tile, out_len=d_in)
    np.testing.assert_array_equal(
        np.asarray(decode_to_dense_mask(stream)).astype(np.int32),
        np.asarray(sel))
    # zero sidecar (padded rows in the prepared layout) -> zero selector
    z = selector_from_checkpoints(
        jnp.zeros((2, S), jnp.int32),
        jnp.zeros((2, offs.shape[1]), jnp.uint16),
        jnp.zeros((2, dbase.shape[1]), jnp.uint8),
        b=b, tile=tile, out_len=d_in)
    assert int(np.asarray(z).sum()) == 0


def test_pack_symbols_roundtrip_and_empty():
    rng = np.random.default_rng(4)
    for b in (4, 5, 6, 8):
        syms = rng.integers(0, 1 << b, size=(7, 53), dtype=np.uint16)
        words = pack_symbols_np(syms, b)
        assert words.dtype == np.uint32
        S = symbol_cols(words.shape[-1], b)
        assert S >= 53
        out = np.asarray(unpack_codes(jnp.asarray(words), b, 53))
        np.testing.assert_array_equal(out, syms)
    # zero-width streams still produce one word so block shapes hold
    empty = pack_symbols_np(np.zeros((3, 0), np.uint16), 6)
    assert empty.shape == (3, 1) and not empty.any()
