"""Outlier statistics (paper §2): range fraction, uniformity, overhead."""
import numpy as np
import pytest

from repro.core import lemma1_bound
from repro.core.stats import (
    chi_square_uniformity,
    empirical_index_overhead,
    heavy_tailed_weights,
    range_taken_by_outliers,
    synthetic_uniform_overhead,
)


def test_range_fraction_monotonic_and_substantial():
    W = heavy_tailed_weights(128, 4096, seed=0)
    fr = range_taken_by_outliers(W, [0.01, 0.05, 0.10])
    assert fr[0.01] < fr[0.05] < fr[0.10]
    # paper: ~50% of range taken by the top 5% (heavy-tailed weights)
    assert 0.35 <= fr[0.05] <= 0.8


def test_uniformity_iid_weights_low_rejection():
    """iid weights => outlier positions uniform => rejection ~ alpha."""
    W = heavy_tailed_weights(256, 2048, seed=1)
    rej = chi_square_uniformity(W, gamma=0.0625, group=256)
    assert rej < 0.12       # alpha = 0.05 + sampling noise


def test_uniformity_detects_clustered_outliers():
    """Concentrate large values in one block: must be rejected."""
    rng = np.random.default_rng(2)
    W = rng.standard_normal((64, 2048)).astype(np.float32) * 0.01
    W[:, :256] *= 50.0      # outliers all in the first group
    rej = chi_square_uniformity(W, gamma=0.0625, group=256)
    assert rej > 0.9


def test_empirical_overhead_matches_lemma_and_synthetic():
    """Paper Fig 4: empirical ~= synthetic ~= bound at gamma=5%, b=6."""
    W = heavy_tailed_weights(128, 4096, seed=3)
    emp = empirical_index_overhead(W, 0.05, 6)
    syn = synthetic_uniform_overhead(4096, 128, 0.05, 6, seed=4)
    bound = lemma1_bound(0.05, 6)
    assert abs(emp - syn) < 0.02
    assert emp <= bound * 1.02
    assert 0.29 <= emp <= 0.33


def test_overhead_convex_in_b():
    """Fig 4: B(b) is convex — too-small b pays escape flags, too-large
    b pays base cost."""
    vals = [lemma1_bound(0.05, b) for b in range(2, 11)]
    bmin = int(np.argmin(vals))
    assert 0 < bmin < len(vals) - 1
    assert vals[0] > vals[bmin] and vals[-1] > vals[bmin]
