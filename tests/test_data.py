"""Data pipeline: determinism, shard independence, label alignment."""
import numpy as np

from repro.data import CalibrationSet, SyntheticLM


def test_batches_deterministic():
    spec = SyntheticLM(vocab_size=512, seq_len=32, seed=7)
    a = spec.batch(step=5, shard=0, batch_size=4)
    b = spec.batch(step=5, shard=0, batch_size=4)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    np.testing.assert_array_equal(a["labels"], b["labels"])


def test_shards_differ():
    spec = SyntheticLM(vocab_size=512, seq_len=32, seed=7)
    a = spec.batch(step=5, shard=0, batch_size=4)
    b = spec.batch(step=5, shard=1, batch_size=4)
    assert not np.array_equal(a["tokens"], b["tokens"])


def test_steps_differ():
    spec = SyntheticLM(vocab_size=512, seq_len=32, seed=7)
    a = spec.batch(step=5, shard=0, batch_size=4)
    b = spec.batch(step=6, shard=0, batch_size=4)
    assert not np.array_equal(a["tokens"], b["tokens"])


def test_labels_are_shifted_tokens():
    spec = SyntheticLM(vocab_size=512, seq_len=32, seed=7)
    b = spec.batch(step=0, shard=0, batch_size=2)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_tokens_in_range():
    spec = SyntheticLM(vocab_size=100, seq_len=64, seed=1)
    b = spec.batch(step=0, shard=0, batch_size=8)
    assert b["tokens"].min() >= 0 and b["tokens"].max() < 100


def test_calibration_set_fixed():
    spec = SyntheticLM(vocab_size=128, seq_len=16, seed=2)
    cal = CalibrationSet(spec, n_sequences=16, batch_size=4)
    a = cal.batches()
    b = cal.batches()
    assert len(a) == 4
    np.testing.assert_array_equal(
        np.asarray(a[0]["tokens"]), np.asarray(b[0]["tokens"])
    )


def test_learnable_structure():
    """The Markov shaping must lower conditional entropy vs iid zipf —
    proxy: bigram repeat rate above iid baseline."""
    spec = SyntheticLM(vocab_size=1024, seq_len=256, seed=3)
    b = spec.batch(step=0, shard=0, batch_size=8)
    toks = b["tokens"]
    # unigram skew: top-10 tokens should cover a large mass (zipf)
    vals, counts = np.unique(toks, return_counts=True)
    top10 = np.sort(counts)[-10:].sum() / counts.sum()
    assert top10 > 0.2
