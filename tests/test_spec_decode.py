"""Speculative decoding subsystem (ISSUE-10): draft-and-verify through
the dual ICQ kernel arms with paged-KV rollback.

The contract under test: with greedy sampling, ``spec_decode=True``
changes how many launches the output costs — one verify launch at
M = batch * (k+1) replaces ``accepted + 1`` decode launches — never
which tokens come out. Spec output must be token-identical to plain
decode for every drafter (the always-wrong ``reject`` one included),
both KV layouts, fused and split step structures, through preemption
storms and verify-launch faults. Plus: the drafters' host-side
contracts, the engine gates, the env knobs, and the accepted-only
metrics accounting.
"""
import dataclasses

import numpy as np
import jax
import pytest

from repro.configs import get_config, smoke_variant
from repro.models import init_model
from repro.serving import (DRAFTERS, FaultInjector, GenerationEngine,
                           NgramDrafter, RejectDrafter, Request,
                           make_drafter, parse_fault_plan)


def _setup(arch):
    cfg = smoke_variant(get_config(arch))
    params = init_model(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _mixed_specs(cfg, n, seed=0, prompt_hi=9, new_hi=8):
    rng = np.random.default_rng(seed)
    return [dict(rid=rid,
                 prompt=rng.integers(0, cfg.vocab_size,
                                     int(rng.integers(2, prompt_hi))
                                     ).astype(np.int32),
                 max_new_tokens=int(rng.integers(2, new_hi)))
            for rid in range(n)]


def _run(params, cfg, specs, **kw):
    kw.setdefault("batch_size", 2)
    kw.setdefault("max_len", 32)
    eng = GenerationEngine(params, cfg, mode="continuous", **kw)
    for s in specs:
        eng.submit(Request(**s))
    out = {rid: r.generated for rid, r in eng.run().items()}
    eng.check_shutdown_invariants()
    return out, eng


# ---------------------------------------------------------------------------
# drafters: host-side contracts (no engine, no device)
# ---------------------------------------------------------------------------

def test_ngram_drafter_prompt_lookup():
    d = NgramDrafter(max_n=3)
    hist = np.asarray([5, 1, 2, 3, 9, 1, 2, 3], np.int32)
    out = d.propose([0], [hist], [4])
    # trailing 3-gram (1,2,3) last occurred at index 1, followed by 9 —
    # the proposal replays that continuation (cycled out to k)
    assert list(out[0][:2]) == [9, 1]
    assert len(out[0]) == 4 and out[0].dtype == np.int32
    # no n-gram hit anywhere: fall back to repeating the last token
    out = d.propose([1], [np.asarray([4, 7, 2], np.int32)], [3])
    assert list(out[1]) == [2, 2, 2]
    assert d.launches == 0
    with pytest.raises(ValueError):
        NgramDrafter(max_n=0)


def test_reject_drafter_is_deterministically_wrong():
    d = RejectDrafter(vocab_size=11)
    hist = np.asarray([3, 9], np.int32)
    out = d.propose([2], [hist], [5])
    assert list(out[2]) == [(9 + 1 + j) % 11 for j in range(5)]
    assert d.launches == 0


def test_make_drafter_rejects_unknown_kind():
    cfg, params = _setup("llama3.2-1b")
    with pytest.raises(ValueError, match="drafter"):
        make_drafter("banana", params, cfg, 2, 32)


# ---------------------------------------------------------------------------
# parity: spec output token-identical to plain decode
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["llama3.2-1b", "minicpm3-4b"])
def test_spec_parity_layouts_and_steps(arch):
    """gqa + mla, contiguous + paged, split chunked prefill and the
    fused mixed step: every spec variant reproduces plain decode's
    greedy streams request-for-request."""
    cfg, params = _setup(arch)
    specs = _mixed_specs(cfg, 5)
    plain, _ = _run(params, cfg, specs, kv_layout="contiguous")
    sp = dict(spec_decode=True, spec_k=4, spec_draft="ngram")
    runs = (
        ("contig", dict(kv_layout="contiguous")),
        ("paged", dict(kv_layout="paged", kv_block_size=4)),
        ("paged_split", dict(kv_layout="paged", kv_block_size=4,
                             prefill_chunk=4, fused_step=False)),
        ("paged_fused", dict(kv_layout="paged", kv_block_size=4,
                             prefill_chunk=4, fused_step=True)),
    )
    for label, kw in runs:
        out, eng = _run(params, cfg, specs, **sp, **kw)
        assert out == plain, f"{label}: spec diverged from plain decode"
        assert eng.metrics.verify_steps > 0, f"{label}: never speculated"
        if eng._pool is not None:
            eng._pool.check_invariants()
            assert eng._pool.free_blocks == eng._pool.num_blocks


def test_spec_parity_every_drafter_kind():
    """All four registered drafters — including the adversarial
    ``reject`` one, whose every proposal is wrong and whose iterations
    all take the KV-rollback path — keep token parity."""
    cfg, params = _setup("llama3.2-1b")
    specs = _mixed_specs(cfg, 3, seed=2)
    plain, _ = _run(params, cfg, specs, kv_layout="paged", kv_block_size=4)
    for kind in DRAFTERS:
        out, eng = _run(params, cfg, specs, kv_layout="paged",
                        kv_block_size=4, spec_decode=True, spec_k=3,
                        spec_draft=kind)
        assert out == plain, f"{kind}: spec diverged from plain decode"
        assert eng.spec_draft == kind
        s = eng.metrics.summary()
        assert s["verify_steps"] > 0
        if kind == "reject":
            # every draft rejected: zero acceptance, full rollback churn
            assert s["spec_proposed"] > 0 and s["spec_accepted"] == 0
        if kind == "ngram":
            assert s["draft_launches"] == 0   # host-only drafter


def test_spec_preemption_recomputes_identical_streams():
    """Pool sized so lanes get preempted mid-run (the plain +1 growth
    path — drafts themselves clip, never preempt): the replayed lanes'
    spec streams must still match the contiguous plain run, and the
    drafter's host mirror must resync across the fold."""
    cfg, params = _setup("llama3.2-1b")
    rng = np.random.default_rng(1)
    specs = [dict(rid=r,
                  prompt=rng.integers(0, cfg.vocab_size, 4).astype(np.int32),
                  max_new_tokens=16) for r in range(2)]
    plain, _ = _run(params, cfg, specs, kv_layout="contiguous")
    out, eng = _run(params, cfg, specs, kv_layout="paged", kv_block_size=4,
                    kv_blocks=6, spec_decode=True, spec_k=4)
    assert eng.metrics.preemptions >= 1, \
        "pool was large enough that nothing was preempted — bad fixture"
    assert out == plain
    assert eng._pool.free_blocks == eng._pool.num_blocks


def test_spec_verify_fault_degrades_to_plain_token_identical():
    """An injected fault on a verify launch: the iteration falls back to
    the plain decode program from the pre-verify cache, the engine goes
    degraded for ``degrade_steps`` launches, and the streams stay
    token-identical. ``spec_fallbacks`` ledgers the event."""
    cfg, params = _setup("llama3.2-1b")
    rng = np.random.default_rng(3)
    specs = [dict(rid=r,
                  prompt=rng.integers(0, cfg.vocab_size, 2).astype(np.int32),
                  max_new_tokens=24) for r in range(2)]
    plain, _ = _run(params, cfg, specs, kv_layout="contiguous")
    # iteration 0 drains the 2-token prompts; 1+ are speculative, so the
    # planned faults land on verify launches (nan probe + raise path)
    inj = FaultInjector(plan=parse_fault_plan("3:nan,6:raise"))
    out, eng = _run(params, cfg, specs, kv_layout="paged", kv_block_size=4,
                    spec_decode=True, spec_k=4, faults=inj, degrade_steps=2)
    assert out == plain
    s = eng.metrics.summary()
    assert s["spec_fallbacks"] >= 1, "no fault ever hit a verify launch"
    assert s["faults"] >= 1 and s["degraded_steps"] >= 1


# ---------------------------------------------------------------------------
# metrics: accepted-only accounting
# ---------------------------------------------------------------------------

def test_spec_metrics_count_accepted_tokens_only():
    cfg, params = _setup("llama3.2-1b")
    specs = _mixed_specs(cfg, 4, seed=5, new_hi=10)
    out, eng = _run(params, cfg, specs, kv_layout="paged", kv_block_size=4,
                    spec_decode=True, spec_k=4)
    s = eng.metrics.summary()
    # tokens/s numerator == what the requests actually got, not proposals
    assert s["generated_tokens"] == sum(len(g) for g in out.values())
    assert s["spec_proposed"] >= s["spec_accepted"] >= 0
    assert s["verify_steps"] > 0
    lanes = sum(eng.metrics.accept_hist.values())
    assert lanes == eng.metrics.spec_lanes
    assert sum(a * n for a, n in eng.metrics.accept_hist.items()) \
        == eng.metrics.spec_accepted
    if s["spec_proposed"]:
        assert 0.0 <= s["spec_accept_rate"] <= 1.0
    assert s["mean_accept_len"] <= eng.spec_k
    for key in ("draft_launches", "spec_draft_errors", "spec_fallbacks",
                "paged_attn_window_fallbacks"):
        assert key in s


# ---------------------------------------------------------------------------
# gates + env knobs
# ---------------------------------------------------------------------------

def test_spec_gates():
    cfg, params = _setup("llama3.2-1b")
    with pytest.raises(NotImplementedError):   # wave engine: no rollback
        GenerationEngine(params, cfg, batch_size=2, max_len=16,
                         mode="wave", spec_decode=True)
    with pytest.raises(ValueError):
        GenerationEngine(params, cfg, batch_size=2, max_len=16,
                         mode="continuous", spec_decode=True, spec_k=0)
    with pytest.raises(ValueError):
        GenerationEngine(params, cfg, batch_size=2, max_len=16,
                         mode="continuous", spec_decode=True,
                         spec_draft="banana")
    ssm_cfg, ssm_params = _setup("mamba2-130m")
    with pytest.raises(NotImplementedError):   # recurrent state: no rewind
        GenerationEngine(ssm_params, ssm_cfg, batch_size=2, max_len=16,
                         mode="continuous", spec_decode=True)


def test_spec_env_defaults(monkeypatch):
    from repro.serving.engine import (default_spec_decode, default_spec_draft,
                                      default_spec_k)

    for var in ("ICQ_SPEC_DECODE", "ICQ_SPEC_K", "ICQ_SPEC_DRAFT"):
        monkeypatch.delenv(var, raising=False)
    assert default_spec_decode() is False
    assert default_spec_k() == 4
    assert default_spec_draft() == "ngram"
    monkeypatch.setenv("ICQ_SPEC_DECODE", "")     # empty string = unset
    assert default_spec_decode() is False
    monkeypatch.setenv("ICQ_SPEC_DECODE", "on")
    assert default_spec_decode() is True
    monkeypatch.setenv("ICQ_SPEC_DECODE", "banana")
    with pytest.raises(ValueError):
        default_spec_decode()
    monkeypatch.setenv("ICQ_SPEC_K", "7")
    assert default_spec_k() == 7
    for bad in ("0", "-1", "banana"):
        monkeypatch.setenv("ICQ_SPEC_K", bad)
        with pytest.raises(ValueError):
            default_spec_k()
    monkeypatch.setenv("ICQ_SPEC_DRAFT", "reject")
    assert default_spec_draft() == "reject"
    monkeypatch.setenv("ICQ_SPEC_DRAFT", "banana")
    with pytest.raises(ValueError):
        default_spec_draft()


def test_engine_env_selects_spec(monkeypatch):
    cfg, params = _setup("llama3.2-1b")
    monkeypatch.setenv("ICQ_SPEC_DECODE", "1")
    monkeypatch.setenv("ICQ_SPEC_K", "3")
    monkeypatch.setenv("ICQ_SPEC_DRAFT", "reject")
    eng = GenerationEngine(params, cfg, batch_size=2, max_len=16,
                           mode="continuous")
    assert eng.spec_decode and eng.spec_k == 3
    assert eng.spec_draft == "reject"
    assert eng._drafter is not None and eng._drafter.name == "reject"


# ---------------------------------------------------------------------------
# carried-over fix: sliding-window + paged attention fallback is counted
# ---------------------------------------------------------------------------

def test_window_fallback_counter_on_paged_decode():
    """A sliding window inside the rounding band max_len <= window <
    n_pt * block_size routes every paged decode launch to the XLA gather
    arm (models/layers._paged_attn_arm) — silently, until now: the
    engine counts those launches in ``paged_attn_window_fallbacks``."""
    base, params = _setup("llama3.2-1b")
    # max_len 16 <= window 18 < 4 pages * 5 rows = 20: continuous mode
    # admits the config (window >= max_len) but the Pallas kernel would
    # over-attend the 20-row page-table span, so the gate fires
    cfg = dataclasses.replace(base, sliding_window=18)
    specs = _mixed_specs(cfg, 2, seed=7, prompt_hi=5, new_hi=6)
    out_p, eng = _run(params, cfg, specs, max_len=16, kv_layout="paged",
                      kv_block_size=5)
    s = eng.metrics.summary()
    assert s["paged_attn_window_fallbacks"] > 0
    assert s["paged_attn_window_fallbacks"] == eng.metrics.decode_steps
    # the fallback is an arm choice, not a math change: contiguous parity
    out_c, eng_c = _run(params, cfg, specs, max_len=16,
                        kv_layout="contiguous")
    assert out_p == out_c
    assert eng_c.metrics.summary()["paged_attn_window_fallbacks"] == 0
